//! Three-layer integration: the AOT artifacts (L1 Pallas kernel inside
//! the L2 JAX worker task, lowered to HLO text) executed from the Rust
//! coordinator via PJRT, composed with APCP/KCCP + CRME + the simulated
//! cluster — the full stack of DESIGN.md.
//!
//! Requires `make artifacts` (the Makefile test target guarantees it).
//! If the artifacts directory is missing the tests are skipped with a
//! loud message rather than failing, so plain `cargo test` works in a
//! fresh checkout. The whole file is compiled only with the `pjrt`
//! feature (which wraps the `xla` dependency).

#![cfg(feature = "pjrt")]

use fcdcc::cluster::{Cluster, StragglerModel};
use fcdcc::engine::TaskEngine;
use fcdcc::fcdcc::FcdccPlan;
use fcdcc::model::ConvLayer;
use fcdcc::runtime::PjrtService;
use fcdcc::tensor::{conv2d, Tensor3, Tensor4};
use fcdcc::util::{mse, rng::Rng};
use std::path::Path;
use std::sync::Arc;

fn artifacts_dir() -> Option<&'static Path> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(Box::leak(dir.into_boxed_path()))
    } else {
        eprintln!("SKIP: artifacts/manifest.json not found — run `make artifacts`");
        None
    }
}

fn testlayer() -> ConvLayer {
    // Must match LAYERS["testlayer"] in python/compile/aot.py.
    ConvLayer::new("testlayer", 2, 12, 10, 8, 3, 3, 1, 0)
}

#[test]
fn pjrt_worker_task_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let host = PjrtService::spawn(dir).expect("spawn PJRT service");
    let layer = testlayer();
    let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap();
    let mut rng = Rng::new(81);
    let x = Tensor3::random(2, 12, 10, &mut rng);
    let k = Tensor4::random(8, 2, 3, 3, &mut rng);
    let payloads = plan.make_payloads(plan.encode_input(&x), &plan.encode_filters(&k));
    for p in &payloads {
        let native = p.run_local();
        let pjrt = host.handle.run(p).expect("pjrt task");
        assert_eq!(native.blocks.len(), pjrt.blocks.len());
        for (a, b) in native.blocks.iter().zip(&pjrt.blocks) {
            assert_eq!(a.shape(), b.shape());
            let e = mse(&a.data, &b.data);
            assert!(e < 1e-24, "worker {}: mse={e:e}", p.worker_id);
        }
    }
}

#[test]
fn full_stack_cluster_with_pjrt_engine_and_stragglers() {
    let Some(dir) = artifacts_dir() else { return };
    let host = PjrtService::spawn(dir).expect("spawn PJRT service");
    let layer = testlayer();
    let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap(); // delta=2, gamma=2
    let mut rng = Rng::new(82);
    let x = Tensor3::random(2, 12, 10, &mut rng);
    let k = Tensor4::random(8, 2, 3, 3, &mut rng);
    let coded_filters = plan.encode_filters(&k);
    let engine: Arc<dyn TaskEngine> = Arc::new(host.handle.clone());
    let mut cluster = Cluster::new(4, engine);
    let straggler = StragglerModel::FixedCount {
        count: 2,
        delay: std::time::Duration::from_millis(150),
    };
    let (y, report) = cluster
        .run_job(&plan, &x, &coded_filters, &straggler, &mut rng)
        .expect("cluster job");
    cluster.shutdown();
    let want = conv2d(&x, &k, layer.params());
    let e = mse(&y.data, &want.data);
    assert!(e < 1e-22, "mse={e:e}");
    assert_eq!(report.used_workers.len(), 2);
    assert!(report.decode_secs > 0.0);
}

#[test]
fn pjrt_handles_alternate_partitioning() {
    let Some(dir) = artifacts_dir() else { return };
    let host = PjrtService::spawn(dir).expect("spawn PJRT service");
    // testlayer with (k_a, k_b) = (2, 4): second artifact variant.
    let layer = testlayer();
    let plan = FcdccPlan::new_crme(&layer, 2, 4, 4).unwrap();
    let mut rng = Rng::new(83);
    let x = Tensor3::random(2, 12, 10, &mut rng);
    let k = Tensor4::random(8, 2, 3, 3, &mut rng);
    let payloads = plan.make_payloads(plan.encode_input(&x), &plan.encode_filters(&k));
    let results: Vec<_> = payloads[..plan.delta()]
        .iter()
        .map(|p| host.handle.run(p).expect("pjrt"))
        .collect();
    let y = plan.decode(&results).unwrap();
    let want = conv2d(&x, &k, layer.params());
    assert!(mse(&y.data, &want.data) < 1e-22);
}

#[test]
fn unknown_shape_is_a_clean_error() {
    let Some(dir) = artifacts_dir() else { return };
    let host = PjrtService::spawn(dir).expect("spawn PJRT service");
    // A layer shape that was never AOT-compiled.
    let layer = ConvLayer::new("nope", 3, 16, 16, 4, 3, 3, 1, 0);
    let plan = FcdccPlan::new_crme(&layer, 2, 2, 4).unwrap();
    let mut rng = Rng::new(84);
    let x = Tensor3::random(3, 16, 16, &mut rng);
    let k = Tensor4::random(4, 3, 3, 3, &mut rng);
    let payloads = plan.make_payloads(plan.encode_input(&x), &plan.encode_filters(&k));
    let Err(err) = host.handle.run(&payloads[0]) else {
        panic!("expected an error for an unknown artifact shape");
    };
    assert!(
        format!("{err:#}").contains("not in manifest"),
        "unexpected error: {err:#}"
    );
}
