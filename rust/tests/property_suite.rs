//! Property-based test suite over the coordinator invariants (DESIGN.md
//! deliverable (c)): coding-scheme round trips, partitioning identities,
//! optimizer optimality, recovery invertibility, JSON parsing totality.

use fcdcc::coding::{self, Code, CrmeCode};
use fcdcc::coordinator::stability::factor_pair;
use fcdcc::fcdcc::{cost, FcdccPlan};
use fcdcc::linalg::lu;
use fcdcc::model::ConvLayer;
use fcdcc::partition::{merge_output_blocks, ApcpPlan, KccpPlan};
use fcdcc::prop::{ensure, run, Gen};
use fcdcc::tensor::{conv2d, ConvParams, Tensor3, Tensor4};
use fcdcc::util::{json::Json, mse};

/// Random feasible CRME configuration + matching layer geometry.
fn random_config(g: &mut Gen) -> (ConvLayer, usize, usize, usize) {
    let k_a = *g.choose(&[1usize, 2, 4, 6]);
    let k_b = *g.choose(&[1usize, 2, 4, 8]);
    let delta = (k_a * k_b).div_ceil(if k_a == 1 { 1 } else { 2 } * if k_b == 1 { 1 } else { 2 });
    let n = delta + g.usize_in(1, 3);
    let c = g.usize_in(1, 3);
    let kh = *g.choose(&[1usize, 3, 5]);
    let kw = *g.choose(&[1usize, 3]);
    let stride = g.usize_in(1, 2);
    let pad = g.usize_in(0, 1);
    // Ensure H' >= k_a and W' >= 1.
    let h_out_min = k_a.max(2);
    let h = (h_out_min - 1) * stride + kh + g.usize_in(0, 4);
    let h = h.saturating_sub(2 * pad).max(kh);
    let w = kw + stride * g.usize_in(1, 5);
    let n_out = k_b * g.usize_in(1, 3);
    let layer = ConvLayer::new("prop", c, h, w, n_out, kh, kw, stride, pad);
    (layer, k_a, k_b, n)
}

#[test]
fn prop_crme_pipeline_roundtrip_any_subset() {
    run("CRME encode->conv->decode == direct conv", 40, |g| {
        let (layer, k_a, k_b, n) = random_config(g);
        let plan = match FcdccPlan::new_crme(&layer, k_a, k_b, n) {
            Ok(p) => p,
            Err(e) => return Err(format!("plan failed for {layer:?}: {e:#}")),
        };
        let x = Tensor3::random(layer.c, layer.h, layer.w, &mut g.rng);
        let k = Tensor4::random(layer.n, layer.c, layer.kh, layer.kw, &mut g.rng);
        let want = conv2d(&x, &k, layer.params());
        let survivors = g.rng.choose_indices(n, plan.delta());
        let got = plan
            .run_inline(&x, &k, Some(&survivors))
            .map_err(|e| format!("decode failed: {e:#}"))?;
        ensure(got.shape() == want.shape(), "shape mismatch")?;
        let e = mse(&got.data, &want.data);
        ensure(
            e < 1e-16,
            format!(
                "mse {e:e} too large for layer {:?} (k_a={k_a}, k_b={k_b}, n={n}, subset {survivors:?})",
                layer
            ),
        )
    });
}

#[test]
fn prop_apcp_slabs_tile_the_output() {
    run("APCP slab convs tile the direct conv", 60, |g| {
        let kh = *g.choose(&[1usize, 3, 5]);
        let stride = g.usize_in(1, 3);
        let k_a = g.usize_in(1, 5);
        let rows_min = k_a.max(1);
        let h = (rows_min - 1) * stride + kh + g.usize_in(0, 6);
        let c = g.usize_in(1, 3);
        let w = kh + g.usize_in(0, 5);
        let x = Tensor3::random(c, h, w, &mut g.rng);
        let nk = g.usize_in(1, 4);
        let k = Tensor4::random(nk, c, kh, kh.min(w), &mut g.rng);
        let p = ConvParams::new(stride, 0);
        let plan = match ApcpPlan::new(h, kh, stride, k_a) {
            Ok(p) => p,
            Err(_) => return Ok(()), // infeasible split: vacuous
        };
        let want = conv2d(&x, &k, p);
        let rows = plan.rows_per_partition();
        for (i, slab) in plan.partition(&x).iter().enumerate() {
            let y = conv2d(slab, &k, p);
            ensure(y.h == rows, format!("slab {i} rows {} != {rows}", y.h))?;
            let lo = i * rows;
            let hi = ((i + 1) * rows).min(want.h);
            if lo >= want.h {
                continue;
            }
            let got = y.slice_h(0, hi - lo);
            let exp = want.slice_h(lo, hi);
            let e = mse(&got.data, &exp.data);
            ensure(e < 1e-20, format!("slab {i} mse {e:e}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_merge_is_inverse_of_blockwise_conv() {
    run("merge(blocks) == direct conv", 40, |g| {
        let k_a = g.usize_in(1, 4);
        let k_b = g.usize_in(1, 3);
        let c = g.usize_in(1, 3);
        let kh = *g.choose(&[1usize, 3]);
        let h = k_a.max(1) + kh - 1 + g.usize_in(0, 5);
        let w = kh + g.usize_in(0, 4);
        let n_out = k_b * g.usize_in(1, 3);
        let x = Tensor3::random(c, h, w, &mut g.rng);
        let k = Tensor4::random(n_out, c, kh, kh.min(w), &mut g.rng);
        let p = ConvParams::new(1, 0);
        let apcp = match ApcpPlan::new(h, kh, 1, k_a) {
            Ok(a) => a,
            Err(_) => return Ok(()),
        };
        let kccp = KccpPlan::new(n_out, k_b).unwrap();
        let want = conv2d(&x, &k, p);
        let mut blocks = Vec::new();
        for xa in apcp.partition(&x) {
            for kb in kccp.partition(&k) {
                blocks.push(conv2d(&xa, &kb, p));
            }
        }
        let got = merge_output_blocks(&blocks, k_a, k_b, want.h);
        ensure(
            mse(&got.data, &want.data) < 1e-20,
            format!("merge mismatch (k_a={k_a}, k_b={k_b})"),
        )
    });
}

#[test]
fn prop_recovery_invertible_for_random_subsets() {
    run("CRME recovery matrices are invertible", 60, |g| {
        let k_a = *g.choose(&[2usize, 4, 6, 8]);
        let k_b = *g.choose(&[2usize, 4, 8]);
        let delta = k_a * k_b / 4;
        let n = delta + g.usize_in(0, 6);
        let code = match CrmeCode::new(k_a, k_b, n) {
            Ok(c) => c,
            Err(_) => return Ok(()),
        };
        let subset = g.rng.choose_indices(n, delta);
        let e = code.recovery(&subset);
        ensure(e.is_square(), "recovery not square")?;
        ensure(
            lu::Lu::factor(&e).is_ok(),
            format!("singular recovery for k_a={k_a} k_b={k_b} n={n} subset {subset:?}"),
        )
    });
}

#[test]
fn prop_encode_linearity() {
    run("coded slabs are linear in the partitions", 30, |g| {
        let k_a = *g.choose(&[2usize, 4]);
        let n = k_a + g.usize_in(1, 4);
        let code = CrmeCode::new(k_a, k_a, n.max(k_a * k_a / 4 + 1)).unwrap();
        let (c, h, w) = (g.usize_in(1, 2), g.usize_in(2, 5), g.usize_in(2, 5));
        let parts1: Vec<Tensor3> = (0..k_a).map(|_| Tensor3::random(c, h, w, &mut g.rng)).collect();
        let parts2: Vec<Tensor3> = (0..k_a).map(|_| Tensor3::random(c, h, w, &mut g.rng)).collect();
        let a = g.f64_in(-2.0, 2.0);
        let mixed: Vec<Tensor3> = parts1
            .iter()
            .zip(&parts2)
            .map(|(p1, p2)| {
                let mut t = p1.clone();
                t.scale(a);
                t.axpy(1.0, p2);
                t
            })
            .collect();
        let e_mixed = coding::encode_inputs(&code, &mixed);
        let e1 = coding::encode_inputs(&code, &parts1);
        let e2 = coding::encode_inputs(&code, &parts2);
        for i in 0..e_mixed.len() {
            for j in 0..e_mixed[i].len() {
                let mut want = e1[i][j].clone();
                want.scale(a);
                want.axpy(1.0, &e2[i][j]);
                let e = mse(&e_mixed[i][j].data, &want.data);
                ensure(e < 1e-20, format!("encode not linear at ({i},{j}): {e:e}"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_optimizer_is_argmin_over_feasible_set() {
    run("optimizer returns the feasible minimum", 40, |g| {
        let layer = ConvLayer::new(
            "opt",
            g.usize_in(1, 256),
            g.usize_in(16, 224),
            g.usize_in(16, 224),
            *g.choose(&[16usize, 64, 96, 256, 384, 512]),
            *g.choose(&[1usize, 3, 5, 11]),
            3,
            g.usize_in(1, 4),
            g.usize_in(0, 2),
        );
        let cm = cost::CostModel {
            lambda_comm: g.f64_in(0.01, 1.0),
            lambda_comp: 0.0,
            lambda_store: g.f64_in(0.01, 1.0),
        };
        let q = *g.choose(&[16usize, 32, 64]);
        let Some(choice) = cost::optimize(&layer, &cm, q) else {
            return Ok(()); // no feasible pair: vacuous
        };
        for c in &choice.candidates {
            ensure(
                choice.best.total() <= c.total() + 1e-9,
                format!(
                    "candidate ({},{}) beats 'best' ({},{})",
                    c.k_a, c.k_b, choice.best.k_a, choice.best.k_b
                ),
            )?;
            ensure(c.k_a * c.k_b == q, "product violated")?;
        }
        Ok(())
    });
}

#[test]
fn prop_factor_pair_feasibility() {
    run("factor_pair returns valid factors", 60, |g| {
        let p = *g.choose(&[4usize, 8, 16, 36, 64, 100, 128]);
        let n_out = *g.choose(&[8usize, 24, 64, 96, 512]);
        let h_out = g.usize_in(4, 64);
        let even = g.bool();
        match factor_pair(p, n_out, h_out, even) {
            Err(_) => Ok(()), // nothing feasible is a legal outcome
            Ok((ka, kb)) => {
                ensure(ka * kb == p, "product")?;
                ensure(ka <= h_out, "k_a <= H'")?;
                ensure(n_out % kb == 0, "k_b | N")?;
                if even {
                    ensure(ka == 1 || ka % 2 == 0, "k_a even-or-1")?;
                    ensure(kb == 1 || kb % 2 == 0, "k_b even-or-1")?;
                }
                Ok(())
            }
        }
    });
}

#[test]
fn prop_json_numbers_roundtrip() {
    run("JSON number parsing", 100, |g| {
        let v = (g.f64_in(-1e6, 1e6) * 1e3).round() / 1e3;
        let s = format!("{v}");
        let j = Json::parse(&s).map_err(|e| format!("parse {s:?}: {e:#}"))?;
        ensure(j.as_f64() == Some(v), format!("roundtrip {s}"))
    });
}

#[test]
fn prop_tensor_slice_concat_identities() {
    run("tensor slice/concat round trips", 60, |g| {
        let (c, h, w) = (g.usize_in(1, 4), g.usize_in(2, 8), g.usize_in(1, 6));
        let t = Tensor3::random(c, h, w, &mut g.rng);
        let cut = g.usize_in(1, h - 1);
        let a = t.slice_h(0, cut);
        let b = t.slice_h(cut, h);
        ensure(Tensor3::concat_h(&[&a, &b]) == t, "concat_h(slice_h) != id")?;
        if c >= 2 {
            let cc = g.usize_in(1, c - 1);
            let a = t.slice_c(0, cc);
            let b = t.slice_c(cc, c);
            ensure(Tensor3::concat_c(&[&a, &b]) == t, "concat_c(slice_c) != id")?;
        }
        Ok(())
    });
}
