//! Determinism suite for the persistent parallel compute runtime
//! (DESIGN.md §Deterministic parallel runtime): the shared thread pool
//! must produce bit-identical results at every pool size, and the
//! packed GEMM microkernel must reproduce the scalar reference fold bit
//! for bit, including degenerate and remainder shapes.
//!
//! Strategy for the thread-count axis: the pool primitives are compared
//! directly across private pools of 1..N threads (chunk boundaries are
//! problem-shaped, so outputs cannot depend on the pool size), and every
//! pool-backed hot path (fused encode, GEMM batch decode, im2col worker
//! engine) is compared against its *serial scalar reference* — so if the
//! suite passes under any `FCDCC_THREADS`, the hot paths equal the same
//! reference, hence each other, at every thread count. CI runs the whole
//! suite twice (default pool and `FCDCC_THREADS=1`) to pin both ends.

use fcdcc::fcdcc::FcdccPlan;
use fcdcc::linalg::Mat;
use fcdcc::model::ConvLayer;
use fcdcc::tensor::{im2col::conv2d_im2col, Tensor3, Tensor4};
use fcdcc::util::pool::ThreadPool;
use fcdcc::util::rng::Rng;

// --- pool primitives -----------------------------------------------------

#[test]
fn pool_parallel_fill_deterministic_across_pool_sizes() {
    // Chunk-local sequential state (a running recurrence) makes any
    // cross-chunk interference or boundary drift visible immediately.
    let total = 4 * 4704; // four decode-sized sample regions
    let chunk = 4704;
    let mut want: Option<Vec<f64>> = None;
    for threads in [1usize, 2, 5] {
        let pool = ThreadPool::new(threads);
        let mut buf = vec![0.0f64; total];
        // work = MAX forces real dispatch despite the small fixture.
        pool.parallel_chunks_mut(usize::MAX, &mut buf, chunk, |ci, slice| {
            let mut acc = ci as f64 + 1.0;
            for v in slice.iter_mut() {
                acc = acc * 1.000001 + 0.5;
                *v = acc;
            }
        });
        match &want {
            None => want = Some(buf),
            Some(w) => assert_eq!(&buf, w, "threads={threads}: fill diverged"),
        }
    }
}

#[test]
fn pool_zip_chunks_deterministic_across_pool_sizes() {
    let items = 23usize; // deliberately not a multiple of anything
    let chunk = 4;
    let data: Vec<f64> = (0..items * chunk).map(|i| (i as f64) * 0.25 - 3.0).collect();
    let mut want: Option<Vec<f64>> = None;
    for threads in [1usize, 3, 8] {
        let pool = ThreadPool::new(threads);
        let mut src = data.clone();
        let mut sums = vec![0.0f64; items];
        pool.parallel_zip_chunks_mut(usize::MAX, &mut src, chunk, &mut sums, 1, |_, c, out| {
            out[0] = c.iter().fold(0.0, |a, &v| a + v * v);
        });
        match &want {
            None => want = Some(sums),
            Some(w) => assert_eq!(&sums, w, "threads={threads}: zip diverged"),
        }
    }
}

// --- packed GEMM vs the scalar reference fold ----------------------------

/// The scalar reference: one accumulator per element, k ascending from
/// 0.0 — the order the packed microkernel must reproduce exactly.
fn matmul_naive(a: &Mat, b: &Mat) -> Mat {
    let mut out = Mat::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for j in 0..b.cols {
            let mut acc = 0.0f64;
            for k in 0..a.cols {
                acc += a.get(i, k) * b.get(k, j);
            }
            out.set(i, j, acc);
        }
    }
    out
}

#[test]
fn packed_matmul_bit_identical_to_naive_fold() {
    let mut rng = Rng::new(41);
    // Degenerate dims, exact-tile shapes, and remainders around the
    // MR=4 / NR=8 tiles and the 256-wide packing panel.
    let shapes = [
        (0usize, 0usize, 0usize),
        (0, 5, 3),
        (4, 0, 3),
        (4, 5, 0),
        (1, 1, 1),
        (3, 5, 2),
        (4, 8, 4),
        (5, 9, 13),
        (12, 16, 7),
        (33, 65, 21),
        (31, 257, 9),
        (2, 300, 40),
    ];
    for (m, n, k) in shapes {
        let a = Mat::random(m, k, &mut rng);
        let b = Mat::random(k, n, &mut rng);
        let got = a.matmul(&b);
        let want = matmul_naive(&a, &b);
        assert_eq!((got.rows, got.cols), (want.rows, want.cols));
        assert_eq!(got.data, want.data, "matmul {m}x{k} · {k}x{n} diverged");
    }
}

#[test]
fn gemm_t_rows_matches_fold_including_degenerate_shapes() {
    let mut rng = Rng::new(42);
    // (coded rows j_n, output blocks i_n, row length): zero coded rows,
    // zero output columns, zero-length rows, panel-straddling lengths,
    // i_n not a multiple of the tile height.
    let shapes = [
        (0usize, 4usize, 8usize),
        (3, 0, 8),
        (3, 4, 0),
        (1, 1, 1),
        (6, 5, 9),
        (7, 13, 300),
    ];
    for (j_n, i_n, len) in shapes {
        let mut d = Mat::random(j_n, i_n, &mut rng);
        if j_n > 1 && i_n > 1 {
            d.set(1, 1, 0.0); // an exact-zero coefficient
        }
        let rows_data: Vec<Vec<f64>> =
            (0..j_n).map(|_| rng.fill_uniform(len, -1.0, 1.0)).collect();
        let rows: Vec<&[f64]> = rows_data.iter().map(|r| r.as_slice()).collect();
        let mut got = vec![0.0; i_n * len];
        d.gemm_t_rows_into(&rows, &mut got, len);
        for i in 0..i_n {
            for t in 0..len {
                let mut want = 0.0f64;
                for (j, r) in rows_data.iter().enumerate() {
                    want += d.get(j, i) * r[t];
                }
                assert_eq!(got[i * len + t], want, "({i},{t}) of ({j_n},{i_n},{len})");
            }
        }
    }
}

// --- pool-backed hot paths vs their serial scalar references -------------

#[test]
fn inline_batch_pipeline_bit_identical_across_straggler_subsets() {
    // run_inline_batch drives the pooled encode AND the pooled batch
    // decode; per-sample run_inline over the same survivor subset is the
    // (batch-1) reference. Shapes cover stride/padding/APCP-extension
    // branches; subsets rotate so arrival order ≠ worker-id order.
    let mut rng = Rng::new(43);
    let cases = [
        (ConvLayer::new("p1", 2, 12, 10, 8, 3, 3, 1, 0), 4usize, 2usize, 5usize),
        (ConvLayer::new("p2", 3, 11, 9, 6, 3, 3, 1, 1), 2, 6, 5),
        (ConvLayer::new("p3", 2, 23, 17, 4, 5, 5, 4, 0), 2, 4, 4),
    ];
    for (layer, k_a, k_b, n) in cases {
        let plan = FcdccPlan::new_crme(&layer, k_a, k_b, n).unwrap();
        let k = Tensor4::random(layer.n, layer.c, layer.kh, layer.kw, &mut rng);
        let delta = plan.delta();
        for batch in 1..=4usize {
            let xs: Vec<Tensor3> = (0..batch)
                .map(|_| Tensor3::random(layer.c, layer.h, layer.w, &mut rng))
                .collect();
            let refs: Vec<&Tensor3> = xs.iter().collect();
            let survivors: Vec<usize> = (0..delta).map(|i| (i + batch) % n).collect();
            let got = plan.run_inline_batch(&refs, &k, Some(&survivors)).unwrap();
            assert_eq!(got.len(), batch);
            for (x, y) in xs.iter().zip(&got) {
                let want = plan.run_inline(x, &k, Some(&survivors)).unwrap();
                assert_eq!(
                    y.data, want.data,
                    "{}: batch {batch} survivors {survivors:?} diverged",
                    layer.name
                );
            }
        }
    }
}

#[test]
fn pooled_worker_engine_bit_identical_to_per_pair_im2col() {
    // run_im2col fans input slabs out over the pool; the per-pair
    // conv2d_im2col composition is its serial reference.
    let mut rng = Rng::new(44);
    let layer = ConvLayer::new("w", 3, 12, 10, 8, 3, 3, 1, 1);
    let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap();
    let k = Tensor4::random(8, 3, 3, 3, &mut rng);
    let cf = plan.encode_filters(&k);
    for batch in 1..=3usize {
        let xs: Vec<Tensor3> =
            (0..batch).map(|_| Tensor3::random(3, 12, 10, &mut rng)).collect();
        let refs: Vec<&Tensor3> = xs.iter().collect();
        let payloads = plan.make_payloads(plan.encode_input_batch(&refs), &cf);
        for p in &payloads {
            let fused = p.run_im2col();
            let want = p.run_with(|a, b, c| conv2d_im2col(a, b, c));
            assert_eq!(fused.blocks.len(), want.blocks.len());
            for (i, (f, w)) in fused.blocks.iter().zip(&want.blocks).enumerate() {
                assert_eq!(
                    f.data, w.data,
                    "worker {} block {i} diverged (batch {batch})",
                    p.worker_id
                );
            }
        }
    }
}
