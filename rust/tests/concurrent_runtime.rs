//! Integration tests for the concurrent job runtime: N overlapping jobs
//! on one worker pool decode correctly with interleaved and stale
//! replies, a per-job timeout fires without poisoning the other
//! in-flight jobs, and pipelined serving produces bit-identical logits
//! to sequential serving. The batched-job variants cover the same
//! invariants when one coded job carries several samples: batched decode
//! is bit-identical to per-request decode, a timed-out batch fails all
//! of its members at once without poisoning later batches, and late
//! replies of a cancelled batch are discarded.

use fcdcc::cluster::{Cluster, JobHandle, StragglerModel};
use fcdcc::coordinator::{serve_lenet, ServeConfig};
use fcdcc::engine::DirectEngine;
use fcdcc::fcdcc::FcdccPlan;
use fcdcc::model::ConvLayer;
use fcdcc::tensor::{conv2d, Tensor3, Tensor4};
use fcdcc::util::{mse, rng::Rng};
use std::sync::Arc;
use std::time::Duration;

fn setup() -> (ConvLayer, Tensor4) {
    let layer = ConvLayer::new("t", 2, 12, 10, 8, 3, 3, 1, 0);
    let mut rng = Rng::new(321);
    let k = Tensor4::random(8, 2, 3, 3, &mut rng);
    (layer, k)
}

#[test]
fn overlapping_jobs_decode_correctly_with_interleaved_replies() {
    let (layer, k) = setup();
    let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap(); // delta=2, gamma=2
    let cf = plan.encode_filters(&k);
    let mut cluster = Cluster::new(4, Arc::new(DirectEngine));
    let mut rng = Rng::new(1);
    // Distinct inputs so a cross-routed reply would corrupt the output.
    let inputs: Vec<Tensor3> = (0..4).map(|_| Tensor3::random(2, 12, 10, &mut rng)).collect();
    let straggler = StragglerModel::FixedCount {
        count: 2,
        delay: Duration::from_millis(40),
    };
    let handles: Vec<JobHandle> = inputs
        .iter()
        .map(|x| cluster.submit(&plan, x, &cf, &straggler, &mut rng).unwrap())
        .collect();
    assert_eq!(cluster.in_flight(), 4);
    // Wait in reverse submission order: collecting job 4 first forces the
    // collector to demultiplex jobs 1-3's replies (and the stragglers'
    // stale late replies) into the in-flight table instead of dropping
    // or misattributing them.
    for (x, handle) in inputs.iter().zip(handles).rev() {
        let (y, report) = cluster.wait(&plan, handle).unwrap();
        let want = conv2d(x, &k, layer.params());
        assert!(mse(&y.data, &want.data) < 1e-18, "wrong decode for job");
        assert_eq!(report.used_workers.len(), 2);
    }
    assert_eq!(cluster.in_flight(), 0);
    cluster.shutdown();
}

#[test]
fn many_sequentially_waited_jobs_overlap_with_stale_replies() {
    let (layer, k) = setup();
    let plan = FcdccPlan::new_crme(&layer, 4, 2, 5).unwrap(); // delta=2, gamma=3
    let cf = plan.encode_filters(&k);
    let mut cluster = Cluster::new(5, Arc::new(DirectEngine));
    let mut rng = Rng::new(2);
    let straggler = StragglerModel::FixedCount {
        count: 2,
        delay: Duration::from_millis(25),
    };
    // Submit a burst, then wait FIFO while later jobs are still landing:
    // late replies of already-decoded jobs arrive during the collection
    // of the following ones and must be discarded as stale.
    let inputs: Vec<Tensor3> = (0..6).map(|_| Tensor3::random(2, 12, 10, &mut rng)).collect();
    let handles: Vec<JobHandle> = inputs
        .iter()
        .map(|x| cluster.submit(&plan, x, &cf, &straggler, &mut rng).unwrap())
        .collect();
    let mut max_concurrent = 0usize;
    for (x, handle) in inputs.iter().zip(handles) {
        let (y, report) = cluster.wait(&plan, handle).unwrap();
        let want = conv2d(x, &k, layer.params());
        assert!(mse(&y.data, &want.data) < 1e-18);
        max_concurrent = max_concurrent.max(report.concurrent_jobs);
    }
    assert!(max_concurrent >= 2, "jobs never overlapped on the pool");
    cluster.shutdown();
}

#[test]
fn per_job_timeout_does_not_poison_other_jobs() {
    let (layer, k) = setup();
    let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap(); // delta=2
    let cf = plan.encode_filters(&k);
    let mut cluster = Cluster::new(4, Arc::new(DirectEngine));
    cluster.collect_timeout = Duration::from_millis(300);
    let mut rng = Rng::new(3);
    let x = Tensor3::random(2, 12, 10, &mut rng);
    let want = conv2d(&x, &k, layer.params());

    // Job A: every worker fails, so it can never reach delta.
    let doomed = cluster
        .submit(&plan, &x, &cf, &StragglerModel::Failures { count: 4 }, &mut rng)
        .unwrap();
    // Job B overlaps with the doomed job and must be unaffected.
    let healthy = cluster
        .submit(&plan, &x, &cf, &StragglerModel::None, &mut rng)
        .unwrap();
    assert_eq!(cluster.in_flight(), 2);

    let (y, _) = cluster.wait(&plan, healthy).unwrap();
    assert!(mse(&y.data, &want.data) < 1e-18);

    let err = cluster.wait(&plan, doomed).unwrap_err();
    assert!(err.to_string().contains("timed out"), "unexpected error: {err:#}");

    // The pool is still healthy after the timeout.
    let (y, _) = cluster
        .run_job(&plan, &x, &cf, &StragglerModel::None, &mut rng)
        .unwrap();
    assert!(mse(&y.data, &want.data) < 1e-18);
    cluster.shutdown();
}

/// Batched cluster jobs decode each sample bit-identically to the
/// per-request (batch-1) decode, for batch sizes 1..4. With n = δ the
/// surviving subset is always {0, 1}, so the inline reference uses the
/// same recovery inverse and the comparison is exact to the last bit.
#[test]
fn batched_decode_bit_identical_to_per_request() {
    let (layer, k) = setup();
    let plan = FcdccPlan::new_crme(&layer, 4, 2, 2).unwrap(); // delta = 2 = n
    let cf = plan.encode_filters(&k);
    let mut cluster = Cluster::new(2, Arc::new(DirectEngine));
    let mut rng = Rng::new(11);
    for batch in 1..=4usize {
        let xs: Vec<Tensor3> =
            (0..batch).map(|_| Tensor3::random(2, 12, 10, &mut rng)).collect();
        let refs: Vec<&Tensor3> = xs.iter().collect();
        let handle = cluster
            .submit_batch(&plan, &refs, &cf, &StragglerModel::None, &mut rng)
            .unwrap();
        let (ys, report) = cluster.wait_batch(&plan, handle).unwrap();
        assert_eq!(report.batch, batch);
        assert_eq!(ys.len(), batch);
        for (x, y) in xs.iter().zip(&ys) {
            let want = plan.run_inline(x, &k, Some(&[0, 1])).unwrap();
            assert_eq!(y.data, want.data, "batch {batch}: decode diverged bitwise");
        }
    }
    cluster.shutdown();
    // One subset across every decode: exactly one inversion ever ran.
    assert_eq!(plan.inverse_cache().misses(), 1);
}

/// A batch whose job blows its deadline fails **all** member requests in
/// one error, and neither concurrent nor later batches are poisoned.
#[test]
fn batch_timeout_fails_all_members_without_poisoning_later_batches() {
    let (layer, k) = setup();
    let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap(); // delta=2
    let cf = plan.encode_filters(&k);
    let mut cluster = Cluster::new(4, Arc::new(DirectEngine));
    cluster.collect_timeout = Duration::from_millis(300);
    let mut rng = Rng::new(12);
    let xs: Vec<Tensor3> = (0..3).map(|_| Tensor3::random(2, 12, 10, &mut rng)).collect();
    let refs: Vec<&Tensor3> = xs.iter().collect();
    let check = |ys: &[Tensor3]| {
        for (x, y) in xs.iter().zip(ys) {
            let want = conv2d(x, &k, layer.params());
            assert!(mse(&y.data, &want.data) < 1e-18, "member decoded wrong");
        }
    };

    // Doomed batch: every worker fails, so it can never reach delta.
    let doomed = cluster
        .submit_batch(&plan, &refs, &cf, &StragglerModel::Failures { count: 4 }, &mut rng)
        .unwrap();
    // A healthy batch overlapping the doomed one is unaffected.
    let healthy = cluster
        .submit_batch(&plan, &refs, &cf, &StragglerModel::None, &mut rng)
        .unwrap();
    let (ys, _) = cluster.wait_batch(&plan, healthy).unwrap();
    check(&ys);

    let err = cluster.wait_batch(&plan, doomed).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("timed out"), "unexpected error: {msg}");
    assert!(msg.contains("3 member sample"), "error names the whole batch: {msg}");

    // Later batches on the same pool still decode fine.
    let handle = cluster
        .submit_batch(&plan, &refs, &cf, &StragglerModel::None, &mut rng)
        .unwrap();
    let (ys, _) = cluster.wait_batch(&plan, handle).unwrap();
    check(&ys);
    cluster.shutdown();
}

/// Late replies of already-settled (first-δ-decoded and cancelled)
/// batched jobs land while later batches are collecting — the stale
/// filter must drop them. Batch sizes vary across the burst so a
/// misrouted reply would also trip the batch-size consistency check.
#[test]
fn stale_replies_from_cancelled_batch_are_ignored() {
    let (layer, k) = setup();
    let plan = FcdccPlan::new_crme(&layer, 4, 2, 5).unwrap(); // delta=2, gamma=3
    let cf = plan.encode_filters(&k);
    let mut cluster = Cluster::new(5, Arc::new(DirectEngine));
    let mut rng = Rng::new(13);
    let straggler = StragglerModel::FixedCount {
        count: 2,
        delay: Duration::from_millis(25),
    };
    let batches: Vec<Vec<Tensor3>> = (0..4)
        .map(|b| {
            (0..(1 + b % 3))
                .map(|_| Tensor3::random(2, 12, 10, &mut rng))
                .collect()
        })
        .collect();
    let handles: Vec<JobHandle> = batches
        .iter()
        .map(|xs| {
            let refs: Vec<&Tensor3> = xs.iter().collect();
            cluster
                .submit_batch(&plan, &refs, &cf, &straggler, &mut rng)
                .unwrap()
        })
        .collect();
    // Wait FIFO: each settled batch's cancelled stragglers may still
    // reply during the collection of the following ones.
    for (xs, handle) in batches.iter().zip(handles) {
        let (ys, report) = cluster.wait_batch(&plan, handle).unwrap();
        assert_eq!(report.batch, xs.len());
        for (x, y) in xs.iter().zip(&ys) {
            let want = conv2d(x, &k, layer.params());
            assert!(
                mse(&y.data, &want.data) < 1e-18,
                "stale or cross-batch reply corrupted a decode"
            );
        }
    }
    assert_eq!(cluster.in_flight(), 0);
    cluster.shutdown();
}

/// Bit-identical pipelined/batched vs sequential serving. With n = δ
/// every job needs all workers' replies, and the runtime orders the
/// chosen δ replies by worker id before decoding — so the decode (and
/// with it every logit) is deterministic regardless of reply arrival
/// order, pipeline depth, or how requests were coalesced into jobs.
#[test]
fn pipelined_serving_bit_identical_to_sequential() {
    let serve = |depth: usize, window: usize| {
        let mut cfg = ServeConfig::default_with_engine(Arc::new(DirectEngine));
        cfg.n_workers = 2;
        cfg.partitions = [(4, 2), (2, 4)]; // delta = 2 = n for both convs
        cfg.requests = 4;
        cfg.seed = 77;
        cfg.max_in_flight = depth;
        cfg.batch_window = window;
        cfg.verify_every = 1;
        serve_lenet(cfg).unwrap()
    };
    let sequential = serve(1, 1);
    let pipelined = serve(4, 1);
    let batched = serve(4, 2);
    assert_eq!(sequential.class_mismatches, 0);
    assert_eq!(pipelined.class_mismatches, 0);
    assert_eq!(batched.class_mismatches, 0);
    assert!(sequential.mean_logit_mse < 1e-16);
    assert!(batched.mean_batch > 1.0, "coalescing never formed a batch");
    assert_eq!(sequential.logits.len(), pipelined.logits.len());
    assert_eq!(sequential.logits.len(), batched.logits.len());
    for (i, (a, b)) in sequential.logits.iter().zip(&pipelined.logits).enumerate() {
        assert_eq!(a, b, "request {i}: pipelined logits diverged bitwise");
    }
    for (i, (a, b)) in sequential.logits.iter().zip(&batched.logits).enumerate() {
        assert_eq!(a, b, "request {i}: batched logits diverged bitwise");
    }
}
