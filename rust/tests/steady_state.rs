//! Steady-state acceptance suite for plan-resident prepacked weights
//! and the zero-alloc job pipeline (DESIGN.md §Plan-resident packing &
//! arenas).
//!
//! Two contracts are asserted here:
//!
//! 1. **Bit identity.** The prepacked worker path (filter slabs packed
//!    into GEMM panels once at plan build) produces byte-for-byte the
//!    same outputs as per-job worker-side packing, over randomized
//!    shapes, batch sizes 1..4, rotating straggler subsets, and every
//!    bit-exact kernel backend this machine can run.
//! 2. **Zero steady-state work.** Past warm-up, a serving loop performs
//!    zero filter packs (the pack counter freezes at plan build) and
//!    zero hot-path heap allocations (arena misses freeze; every coded
//!    slab, reply block, and staging buffer is a pooled reuse), and the
//!    arena reaches quiescence (every buffer returned) between waves.

use fcdcc::cluster::{Cluster, StragglerModel};
use fcdcc::engine::Im2colEngine;
use fcdcc::fcdcc::{FcdccPlan, ResidentFilters, WorkerResult};
use fcdcc::linalg::kernel;
use fcdcc::model::ConvLayer;
use fcdcc::tensor::{conv2d, Tensor3, Tensor4};
use fcdcc::util::{mse, rng::Rng};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Poll the plan arena until every outstanding buffer has been returned
/// (worker threads recycle asynchronously), failing after `deadline`.
fn await_quiescence(plan: &FcdccPlan, deadline: Duration, what: &str) {
    let t0 = Instant::now();
    while plan.arena().outstanding() != 0 {
        assert!(
            t0.elapsed() < deadline,
            "{what}: {} arena buffers still outstanding after {deadline:?}",
            plan.arena().outstanding()
        );
        std::thread::yield_now();
    }
}

/// Small feasible CRME configurations (layer, k_a, k_b, n) reused from
/// the repo's correctness suites.
fn configs() -> Vec<(ConvLayer, usize, usize, usize)> {
    vec![
        (ConvLayer::new("s1", 2, 12, 10, 8, 3, 3, 1, 0), 4, 2, 5),
        (ConvLayer::new("s2", 2, 12, 10, 8, 3, 3, 1, 0), 4, 2, 4),
        (ConvLayer::new("s3", 3, 16, 8, 4, 3, 3, 1, 1), 2, 2, 4),
    ]
}

/// One coded job on `plan` through the **fused worker path**
/// (`run_im2col` — the path that consumes the prepacked panels),
/// decoding from the given survivor subset and recycling everything.
fn run_once(
    plan: &FcdccPlan,
    xs: &[&Tensor3],
    cf: &[ResidentFilters],
    survivors: &[usize],
) -> Vec<Tensor3> {
    let payloads = plan.make_payloads(plan.encode_input_batch(xs), cf);
    let results: Vec<WorkerResult> =
        survivors.iter().map(|&i| payloads[i].run_im2col()).collect();
    let refs: Vec<&WorkerResult> = results.iter().collect();
    let out = plan.decode_batch_refs(&refs).unwrap();
    drop(refs);
    for r in results {
        r.recycle();
    }
    for p in payloads {
        p.recycle();
    }
    out
}

/// The tentpole's correctness bar: prepacked == per-job packing,
/// bitwise, across shapes × batch sizes × straggler subsets × backends.
/// All `kernel::set_active` switching for this file lives inside this
/// one test (the backend is process-global).
#[test]
fn prepacked_path_bit_identical_across_shapes_batches_survivors_backends() {
    let prev = kernel::active();
    let mut rng = Rng::new(2026);
    for (layer, k_a, k_b, n) in configs() {
        let pre = FcdccPlan::new_crme(&layer, k_a, k_b, n).unwrap();
        let per = FcdccPlan::new_crme(&layer, k_a, k_b, n)
            .unwrap()
            .with_prepack(false);
        assert!(pre.prepack() && !per.prepack());
        let k = Tensor4::random(layer.n, layer.c, layer.kh, layer.kw, &mut rng);
        let cf_pre = pre.encode_filters(&k);
        let cf_per = per.encode_filters(&k);
        assert!(cf_pre.iter().all(|rf| rf.packs.is_some()));
        assert!(cf_per.iter().all(|rf| rf.packs.is_none()));
        let delta = pre.delta();
        for batch in 1..=4usize {
            // Rotate the straggler subset with the batch size so every
            // worker appears in (and drops out of) some decode.
            let survivors: Vec<usize> = (0..delta).map(|i| (i + batch) % n).collect();
            let xs: Vec<Tensor3> = (0..batch)
                .map(|_| Tensor3::random(layer.c, layer.h, layer.w, &mut rng))
                .collect();
            let xrefs: Vec<&Tensor3> = xs.iter().collect();

            kernel::set_active(kernel::Kind::Scalar);
            let scalar_pre = run_once(&pre, &xrefs, &cf_pre, &survivors);
            let scalar_per = run_once(&per, &xrefs, &cf_per, &survivors);
            for (s, (a, b)) in scalar_pre.iter().zip(&scalar_per).enumerate() {
                assert_eq!(
                    a.data, b.data,
                    "{}: sample {s} diverged between prepacked and per-job \
                     packing (batch {batch}, survivors {survivors:?})",
                    layer.name
                );
                let want = conv2d(&xs[s], &k, layer.params());
                assert!(
                    mse(&a.data, &want.data) < 1e-16,
                    "{}: sample {s} diverged from the conv reference",
                    layer.name
                );
            }
            for kind in kernel::available() {
                kernel::set_active(kind);
                let got_pre = run_once(&pre, &xrefs, &cf_pre, &survivors);
                let got_per = run_once(&per, &xrefs, &cf_per, &survivors);
                for (s, got) in got_pre.iter().enumerate() {
                    assert_eq!(
                        got.data,
                        scalar_pre[s].data,
                        "{}: prepacked sample {s} diverged on {} vs scalar",
                        layer.name,
                        kind.name()
                    );
                    assert_eq!(
                        got_per[s].data, scalar_per[s].data,
                        "{}: per-job sample {s} diverged on {} vs scalar",
                        layer.name,
                        kind.name()
                    );
                }
            }
        }
        // The counters tell the two paths apart: plan-resident panels
        // mean the prepacked plan never packed a filter at job time.
        assert_eq!(pre.arena().filter_packs(), 0, "{}", layer.name);
        assert!(per.arena().filter_packs() > 0, "{}", layer.name);
    }
    kernel::set_active(prev);
}

/// The tentpole's steady-state bar, on the live pipelined cluster:
/// several jobs in flight at once, and past the first (warm-up) round
/// the pack counter and the arena miss counter both freeze.
#[test]
fn pipelined_serving_reaches_zero_pack_zero_alloc_steady_state() {
    let layer = ConvLayer::new("t", 2, 12, 10, 8, 3, 3, 1, 0);
    let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap();
    let n = 4usize;
    let k = Tensor4::random(8, 2, 3, 3, &mut Rng::new(5));
    let cf = plan.encode_filters(&k);
    let mut rng = Rng::new(17);
    // Exactly δ workers survive each job: no stale late replies, so the
    // arena reaches true quiescence between rounds.
    let model = StragglerModel::Failures {
        count: n - plan.delta(),
    };
    let mut cluster = Cluster::new(n, Arc::new(Im2colEngine));
    let mut warm_misses = 0u64;
    for round in 0..5u64 {
        // Three jobs in flight at once (batch 2 each) — the pipelined
        // shape, not lock-step sequential serving.
        let waves: Vec<Vec<Tensor3>> = (0..3)
            .map(|_| (0..2).map(|_| Tensor3::random(2, 12, 10, &mut rng)).collect())
            .collect();
        let handles: Vec<_> = waves
            .iter()
            .map(|xs| {
                let refs: Vec<&Tensor3> = xs.iter().collect();
                cluster.submit_batch(&plan, &refs, &cf, &model, &mut rng).unwrap()
            })
            .collect();
        for (xs, h) in waves.iter().zip(handles) {
            let (ys, _) = cluster.wait_batch(&plan, h).unwrap();
            for (x, y) in xs.iter().zip(&ys) {
                let want = conv2d(x, &k, layer.params());
                assert!(mse(&y.data, &want.data) < 1e-16, "round {round}");
            }
        }
        await_quiescence(&plan, Duration::from_secs(10), "pipelined round");
        let st = plan.arena().stats();
        if round == 0 {
            warm_misses = st.misses;
            assert!(warm_misses > 0, "warm-up must populate the arena");
        } else {
            assert_eq!(
                st.misses, warm_misses,
                "round {round}: hot path allocated past warm-up"
            );
        }
        assert_eq!(
            plan.arena().filter_packs(),
            0,
            "round {round}: plan-resident panels were re-packed"
        );
    }
    let st = plan.arena().stats();
    assert!(st.hits > st.misses, "steady state must be hit-dominated");
    cluster.shutdown();
}

/// The `--no-prepack` escape hatch on the live cluster: same outputs,
/// but the pack counter grows with every round — the observable the
/// bench A/B record keys on.
#[test]
fn no_prepack_pipeline_counts_worker_side_packs() {
    let layer = ConvLayer::new("t", 2, 12, 10, 8, 3, 3, 1, 0);
    let plan = FcdccPlan::new_crme(&layer, 4, 2, 4)
        .unwrap()
        .with_prepack(false);
    let k = Tensor4::random(8, 2, 3, 3, &mut Rng::new(5));
    let cf = plan.encode_filters(&k);
    assert!(cf.iter().all(|rf| rf.packs.is_none()));
    let mut rng = Rng::new(23);
    let model = StragglerModel::Failures {
        count: 4 - plan.delta(),
    };
    let mut cluster = Cluster::new(4, Arc::new(Im2colEngine));
    let mut last_packs = 0u64;
    for round in 0..3u64 {
        let x = Tensor3::random(2, 12, 10, &mut rng);
        let (y, _) = cluster.run_job(&plan, &x, &cf, &model, &mut rng).unwrap();
        let want = conv2d(&x, &k, layer.params());
        assert!(mse(&y.data, &want.data) < 1e-16, "round {round}");
        await_quiescence(&plan, Duration::from_secs(10), "no-prepack round");
        let packs = plan.arena().filter_packs();
        assert!(
            packs > last_packs,
            "round {round}: per-job packing must keep counting packs"
        );
        last_packs = packs;
    }
    cluster.shutdown();
}
