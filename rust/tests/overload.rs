//! Overload invariants of the open-loop serving front-end
//! (DESIGN.md §Serving front-end & overload control): the bounded
//! admission queue never exceeds its cap, every arrival resolves to
//! exactly one terminal outcome, a fixed arrival seed reproduces the
//! same shed/expire/complete pattern, completed logits are bit-identical
//! to the unloaded closed-loop path, deadlines out-rank the retry
//! budget, and the slab arena comes home empty under any shedding
//! pattern. The `frontend_*` tests exercise the same terminal-outcome
//! protocol over real loopback TCP (the CI front-end leg).

use fcdcc::cluster::{
    spawn_frontend, ClientReply, FaultKind, FaultPlan, FrontendClient, StragglerModel,
};
use fcdcc::coordinator::{
    serve_frontend_on, serve_lenet, ArrivalSpec, RequestOutcome, ServeConfig, ServeStats,
};
use fcdcc::engine::Im2colEngine;
use fcdcc::tensor::Tensor3;
use fcdcc::util::rng::Rng;
use std::sync::Arc;
use std::time::Duration;

/// Base config for the deterministic-logits tests: δ = 2 at *both* conv
/// stages ((4,2) and (2,4)), workers 2 and 3 crashed from the start, and
/// re-planning off — so exactly workers {0, 1} ever reply, the first-δ
/// reply set is forced to {0, 1} on every job, and decode (which sorts
/// kept replies canonically) is bit-deterministic across runs and load
/// patterns.
fn forced_reply_cfg() -> ServeConfig {
    let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
    cfg.partitions = [(4, 2), (2, 4)];
    cfg.fault_plan = FaultPlan::none()
        .with_fault(
            2,
            FaultKind::Crash {
                after: 0,
                restart_after: None,
            },
        )
        .with_fault(
            3,
            FaultKind::Crash {
                after: 0,
                restart_after: None,
            },
        );
    cfg.replan = false;
    cfg.verify_every = 0;
    cfg.requests = 48;
    cfg
}

/// The invariants every serving run must satisfy, loaded or not.
fn check_accounting(stats: &ServeStats) {
    assert_eq!(stats.arrivals, stats.outcomes.len());
    assert!(
        stats.outcomes.iter().all(Option::is_some),
        "every arrival must resolve to exactly one terminal outcome"
    );
    assert_eq!(
        stats.completed_requests + stats.shed_requests + stats.expired_requests,
        stats.arrivals,
        "completed + shed + expired must cover every arrival"
    );
    assert_eq!(
        stats.completed_requests as u64,
        stats.latency_hist.count(),
        "the latency histogram covers completed requests only"
    );
    assert_eq!(stats.latency.n, stats.completed_requests, "latency over completed only");
    assert!(
        stats.peak_queue_depth <= stats.queue_cap,
        "queue peak {} exceeded cap {}",
        stats.peak_queue_depth,
        stats.queue_cap
    );
    assert_eq!(stats.failed_requests, 0);
    assert_eq!(
        stats.arena_outstanding, 0,
        "slab arena must come home empty under any shedding pattern"
    );
    for (id, o) in stats.outcomes.iter().enumerate() {
        assert_eq!(
            *o == Some(RequestOutcome::Completed),
            !stats.logits[id].is_empty(),
            "request {id}: logits must exist iff it completed"
        );
    }
}

#[test]
fn shed_pattern_is_seed_deterministic_and_completed_logits_match_closed_loop() {
    // Closed-loop reference: demand-paced, zero overload, every request
    // completes. Inputs are drawn from the seeded input stream in id
    // order in *both* loops, so logits are comparable id-for-id.
    let mut reference = forced_reply_cfg();
    reference.max_in_flight = 4;
    let reference = serve_lenet(reference).unwrap();
    assert_eq!(reference.completed_requests, 48);
    check_accounting(&reference);

    // Open-loop: a near-simultaneous 48-arrival flood against a 6-deep
    // queue at depth 4 must shed most arrivals with explicit Busy.
    let open = || {
        let mut cfg = forced_reply_cfg();
        cfg.max_in_flight = 4;
        cfg.queue_cap = 6;
        cfg.arrival = Some(ArrivalSpec::poisson(1_000_000.0, 9));
        serve_lenet(cfg).unwrap()
    };
    let a = open();
    let b = open();
    assert_eq!(a.outcomes, b.outcomes, "fixed seed → identical shed/complete pattern");
    assert_eq!(a.peak_queue_depth, b.peak_queue_depth);
    check_accounting(&a);
    assert!(a.shed_requests > 0, "a 48-burst against queue cap 6 must shed");
    assert!(a.completed_requests > 0, "admitted requests must still complete");
    // The acceptance bar: every completed request's logits are
    // bit-identical to the unloaded closed-loop run.
    for (id, o) in a.outcomes.iter().enumerate() {
        if *o == Some(RequestOutcome::Completed) {
            assert_eq!(a.logits[id], reference.logits[id], "request {id} logits drifted");
        }
    }
}

#[test]
fn deadlines_expire_queued_requests_under_overload() {
    // Depth 1 at a 12 ms deadline (2.4 virtual stage intervals): the
    // head request completes in 10 ms, everything that waits behind it
    // expires, and the flood beyond the 8-deep queue sheds — all three
    // terminal outcomes in one run.
    let mut cfg = forced_reply_cfg();
    cfg.max_in_flight = 1;
    cfg.queue_cap = 8;
    cfg.request_deadline = Some(Duration::from_millis(12));
    cfg.arrival = Some(ArrivalSpec::poisson(1_000_000.0, 3));
    let stats = serve_lenet(cfg).unwrap();
    check_accounting(&stats);
    assert!(stats.completed_requests > 0, "the head request fits its deadline");
    assert!(stats.shed_requests > 0, "the flood must overflow the queue");
    assert!(stats.expired_requests > 0, "queued requests must expire past the deadline");
}

#[test]
fn expired_requests_do_not_ride_the_retry_loop() {
    // Three workers 300 ms slow against a 100 ms collect timeout: every
    // job times out (δ = 2 needs a second reply). With a 120 ms request
    // deadline, the retry path must evict the request after its deadline
    // instead of burning the 50-deep retry budget.
    let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
    cfg.requests = 2;
    cfg.verify_every = 0;
    cfg.replan = false;
    cfg.retry_budget = 50;
    cfg.collect_timeout = Duration::from_millis(100);
    cfg.request_deadline = Some(Duration::from_millis(120));
    let mut plan = FaultPlan::none();
    for w in 1..4 {
        plan = plan.with_fault(
            w,
            FaultKind::Slow {
                delay: Duration::from_millis(300),
            },
        );
    }
    cfg.fault_plan = plan;
    let stats = serve_lenet(cfg).unwrap();
    check_accounting(&stats);
    assert_eq!(stats.expired_requests, 2, "deadline must out-rank the retry budget");
    assert_eq!(stats.completed_requests, 0);
    assert!(
        stats.retries <= 6,
        "retries must stop at the deadline, not the budget: {} re-dispatches",
        stats.retries
    );
    assert_eq!(stats.degraded_requests, 0, "eviction beats degradation past the deadline");
}

#[test]
fn frontend_serves_logits_and_sheds_with_busy_over_loopback() {
    let (listener, rx) = spawn_frontend("127.0.0.1:0").unwrap();
    let addr = listener.addr().to_string();
    let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
    cfg.requests = 6;
    cfg.max_in_flight = 2;
    cfg.queue_cap = 2;
    cfg.verify_every = 0;
    // ~100 ms per coded stage: the 6-request burst lands while the first
    // two are still in service, so the 2-deep queue must overflow.
    cfg.straggler = StragglerModel::FixedCount {
        count: 3,
        delay: Duration::from_millis(100),
    };
    let server = std::thread::spawn(move || serve_frontend_on(cfg, rx).unwrap());

    let mut client = FrontendClient::connect(&addr).unwrap();
    let mut rng = Rng::new(17);
    for id in 0..6u64 {
        let x = Tensor3::random(1, 32, 32, &mut rng);
        client.send(id, None, &x).unwrap();
    }
    let (mut logits_n, mut busy_n, mut expired_n) = (0usize, 0usize, 0usize);
    for _ in 0..6 {
        match client.recv().unwrap() {
            ClientReply::Logits { logits, .. } => {
                assert_eq!(logits.len(), 10, "LeNet-5 logits cross the wire whole");
                logits_n += 1;
            }
            ClientReply::Busy { .. } => busy_n += 1,
            ClientReply::DeadlineExceeded { .. } => expired_n += 1,
        }
    }
    let stats = server.join().unwrap();
    listener.stop();
    check_accounting(&stats);
    assert_eq!(stats.arrivals, 6);
    assert_eq!(stats.completed_requests, logits_n, "one Response frame per completion");
    assert_eq!(stats.shed_requests, busy_n, "one Busy frame per shed");
    assert_eq!(stats.expired_requests, expired_n);
    assert!(logits_n >= 1, "admitted requests must be served");
    assert!(busy_n >= 1, "a 6-burst against depth 2 + queue 2 must shed");
}

#[test]
fn frontend_enforces_wire_deadlines_over_loopback() {
    let (listener, rx) = spawn_frontend("127.0.0.1:0").unwrap();
    let addr = listener.addr().to_string();
    let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
    cfg.requests = 1;
    cfg.verify_every = 0;
    // Service takes ~300 ms against the client's 5 ms wire deadline.
    cfg.straggler = StragglerModel::FixedCount {
        count: 3,
        delay: Duration::from_millis(150),
    };
    let server = std::thread::spawn(move || serve_frontend_on(cfg, rx).unwrap());

    let mut client = FrontendClient::connect(&addr).unwrap();
    let mut rng = Rng::new(41);
    let x = Tensor3::random(1, 32, 32, &mut rng);
    client.send(7, Some(Duration::from_millis(5)), &x).unwrap();
    assert_eq!(
        client.recv().unwrap(),
        ClientReply::DeadlineExceeded { client_id: 7 }
    );
    let stats = server.join().unwrap();
    listener.stop();
    check_accounting(&stats);
    assert_eq!(stats.expired_requests, 1);
    assert_eq!(stats.completed_requests, 0);
}
