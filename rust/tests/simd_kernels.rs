//! Backend-equivalence suite for the runtime-dispatched SIMD kernels
//! (DESIGN.md §SIMD dispatch): every default-path backend available on
//! this machine (scalar always; AVX2/NEON when detected) must produce
//! **bit-identical** results — at the kernel level over remainder and
//! degenerate shapes, and end to end through the coded pipeline and the
//! pipelined serving loop over rotating straggler subsets.
//!
//! Switching the process-global dispatch target mid-suite is safe
//! precisely *because* of the property under test: all default-path
//! backends are `==`-indistinguishable, so concurrent tests cannot
//! observe a swap. The non-bit-exact `fused-ma` backend is never
//! installed globally here; it is exercised through the explicit-kind
//! entry points and relative-error bounds in the `linalg` unit tests.

use fcdcc::cluster::StragglerModel;
use fcdcc::coding::contiguous_subset;
use fcdcc::coordinator::{serve_lenet, ServeConfig};
use fcdcc::engine::Im2colEngine;
use fcdcc::fcdcc::FcdccPlan;
use fcdcc::linalg::{gemm, kernel};
use fcdcc::model::ConvLayer;
use fcdcc::tensor::{Tensor3, Tensor4};
use fcdcc::util::rng::Rng;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Serializes the tests that install a process-global dispatch target:
/// every install here is bit-exact, so racing tests could never observe
/// different *numbers*, but assertions on `ServeStats.kernel` (which
/// backend a run reports) do need the global to hold still.
static KERNEL_LOCK: Mutex<()> = Mutex::new(());

// --- kernel level: the source adapters the hot paths actually use ----------

#[test]
fn decode_and_dense_adapters_bitwise_identical_across_backends() {
    // TransposedA × RowsB is the decode GEMM's shape; RowMajor × ColsB
    // is the batched-Dense shape. Dims straddle the MR=4 / NR=8 tile
    // remainders and include degenerate zeros.
    let mut rng = Rng::new(71);
    for (m, n, kk) in [
        (0usize, 0usize, 0usize),
        (1, 1, 1),
        (3, 7, 2),
        (5, 9, 6),
        (13, 260, 4),
    ] {
        // A as the transpose view of a kk-major matrix.
        let at_data = rng.fill_uniform(kk * m, -1.0, 1.0);
        let a_t = gemm::TransposedA {
            data: &at_data,
            ld: m.max(1),
        };
        // B as independent row slices (coded output blocks).
        let rows_data: Vec<Vec<f64>> =
            (0..kk).map(|_| rng.fill_uniform(n, -1.0, 1.0)).collect();
        let rows: Vec<&[f64]> = rows_data.iter().map(|r| r.as_slice()).collect();
        let b_rows = gemm::RowsB { rows: &rows };
        let mut want = vec![0.0; m * n];
        gemm::gemm_into_kind(
            kernel::Kind::Scalar,
            m,
            n,
            kk,
            &a_t,
            &b_rows,
            &mut want,
            n.max(1),
        );
        for kind in kernel::available() {
            let mut got = vec![0.0; m * n];
            gemm::gemm_into_kind(kind, m, n, kk, &a_t, &b_rows, &mut got, n.max(1));
            assert_eq!(got, want, "TransposedA×RowsB {kind:?} ({m},{n},{kk})");
        }
        // B as independent column slices (batched Dense activations).
        let cols_data: Vec<Vec<f64>> =
            (0..n).map(|_| rng.fill_uniform(kk, -1.0, 1.0)).collect();
        let cols: Vec<&[f64]> = cols_data.iter().map(|c| c.as_slice()).collect();
        let b_cols = gemm::ColsB { cols: &cols };
        let a_data = rng.fill_uniform(m * kk, -1.0, 1.0);
        let a_rm = gemm::RowMajor {
            data: &a_data,
            ld: kk.max(1),
        };
        let mut want = vec![0.0; m * n];
        gemm::gemm_into_kind(
            kernel::Kind::Scalar,
            m,
            n,
            kk,
            &a_rm,
            &b_cols,
            &mut want,
            n.max(1),
        );
        for kind in kernel::available() {
            let mut got = vec![0.0; m * n];
            gemm::gemm_into_kind(kind, m, n, kk, &a_rm, &b_cols, &mut got, n.max(1));
            assert_eq!(got, want, "RowMajor×ColsB {kind:?} ({m},{n},{kk})");
        }
    }
}

#[test]
fn axpy_remainder_tails_bitwise_identical_across_backends() {
    // The encode-fill / coding-combination primitive, over lengths
    // around both SIMD widths (4 for AVX2, 2 for NEON) and zero.
    let mut rng = Rng::new(72);
    for len in [0usize, 1, 2, 3, 4, 5, 6, 7, 8, 9, 15, 33, 128] {
        let src = rng.fill_uniform(len, -1.0, 1.0);
        let base = rng.fill_uniform(len, -1.0, 1.0);
        let coef = rng.uniform(-3.0, 3.0);
        let mut want = base.clone();
        kernel::axpy_kind(kernel::Kind::Scalar, coef, &src, &mut want);
        for kind in kernel::available() {
            let mut got = base.clone();
            kernel::axpy_kind(kind, coef, &src, &mut got);
            assert_eq!(got, want, "axpy {kind:?} len {len}");
        }
    }
}

// --- pipeline level: encode / compute / decode on each active backend ------

#[test]
fn fused_batch_encode_bit_identical_across_backends() {
    let mut rng = Rng::new(73);
    let layer = ConvLayer::new("t", 3, 11, 9, 6, 3, 3, 1, 1);
    let plan = FcdccPlan::new_crme(&layer, 2, 6, 5).unwrap();
    let xs: Vec<Tensor3> =
        (0..3).map(|_| Tensor3::random(3, 11, 9, &mut rng)).collect();
    let refs: Vec<&Tensor3> = xs.iter().collect();
    let _guard = KERNEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = kernel::set_active(kernel::Kind::Scalar);
    let want = plan.encode_input_batch(&refs);
    for kind in kernel::available() {
        kernel::set_active(kind);
        let got = plan.encode_input_batch(&refs);
        assert_eq!(got.len(), want.len());
        for (w, (g, r)) in got.iter().zip(&want).enumerate() {
            for (i, (gs, rs)) in g.iter().zip(r).enumerate() {
                assert_eq!(gs.data, rs.data, "{kind:?}: worker {w} slab {i}");
            }
        }
    }
    kernel::set_active(prev);
}

#[test]
fn inline_pipeline_bit_identical_across_backends_and_rotating_subsets() {
    // Encode → worker im2col GEMMs → GEMM decode, end to end, with the
    // surviving-worker subset rotating through every contiguous
    // δ-window — at every available dispatch level.
    let mut rng = Rng::new(74);
    let layer = ConvLayer::new("t", 2, 12, 10, 8, 3, 3, 1, 0);
    let (k_a, k_b, n) = (4usize, 2usize, 5usize);
    let plan = FcdccPlan::new_crme(&layer, k_a, k_b, n).unwrap(); // delta=2
    let k = Tensor4::random(8, 2, 3, 3, &mut rng);
    let xs: Vec<Tensor3> =
        (0..2).map(|_| Tensor3::random(2, 12, 10, &mut rng)).collect();
    let refs: Vec<&Tensor3> = xs.iter().collect();
    let delta = plan.delta();
    let _guard = KERNEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = kernel::set_active(kernel::Kind::Scalar);
    let wants: Vec<Vec<Tensor3>> = (0..n)
        .map(|r| {
            let survivors = contiguous_subset(n, delta, r);
            plan.run_inline_batch(&refs, &k, Some(&survivors)).unwrap()
        })
        .collect();
    for kind in kernel::available() {
        kernel::set_active(kind);
        for (r, want) in wants.iter().enumerate() {
            let survivors = contiguous_subset(n, delta, r);
            let got = plan.run_inline_batch(&refs, &k, Some(&survivors)).unwrap();
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(want) {
                assert_eq!(g.data, w.data, "{kind:?}: subset rotation {r} diverged");
            }
        }
    }
    kernel::set_active(prev);
}

// --- serving level: the full pipelined scheduler -------------------------

#[test]
fn pipelined_serving_bit_identical_across_backends() {
    // The same pipelined + coalescing serving run must produce
    // bit-identical logits on every available dispatch level, and
    // report the backend it ran on. With n = δ for both convs every
    // job needs all workers' replies and the runtime orders the chosen
    // δ replies by worker id before decoding, so the run is
    // deterministic regardless of reply arrival order — the straggler
    // fates still rotate per job via the seeded fate stream, they only
    // shift latency, never the decoded subset.
    let run = |kind: kernel::Kind| {
        kernel::set_active(kind);
        let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
        cfg.n_workers = 2;
        cfg.partitions = [(4, 2), (2, 4)]; // delta = 2 = n for both convs
        cfg.requests = 4;
        cfg.seed = 78;
        cfg.max_in_flight = 3;
        cfg.batch_window = 2;
        cfg.verify_every = 2;
        cfg.straggler = StragglerModel::FixedCount {
            count: 1,
            delay: Duration::from_millis(5),
        };
        serve_lenet(cfg).unwrap()
    };
    let _guard = KERNEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = kernel::set_active(kernel::Kind::Scalar);
    let want = run(kernel::Kind::Scalar);
    assert_eq!(want.kernel, "scalar");
    assert_eq!(want.logits.len(), 4);
    for kind in kernel::available() {
        let got = run(kind);
        assert_eq!(got.kernel, kind.name(), "stats must report the active backend");
        assert_eq!(got.class_mismatches, 0);
        assert_eq!(got.logits, want.logits, "{kind:?}: serving logits diverged");
    }
    kernel::set_active(prev);
}
