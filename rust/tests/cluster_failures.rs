//! Failure-injection integration tests over the threaded cluster: crash
//! fates, flaky engines, repeated jobs, and recovery-threshold edges.

use fcdcc::cluster::{Cluster, FaultKind, FaultPlan, HealthPolicy, StragglerModel};
use fcdcc::coordinator::{serve_lenet, ServeConfig};
use fcdcc::engine::{DirectEngine, TaskEngine};
use fcdcc::fcdcc::{FcdccPlan, WorkerPayload, WorkerResult};
use fcdcc::model::ConvLayer;
use fcdcc::tensor::{conv2d, Tensor3, Tensor4};
use fcdcc::util::{mse, rng::Rng};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn setup() -> (ConvLayer, Tensor3, Tensor4) {
    let layer = ConvLayer::new("t", 2, 12, 10, 8, 3, 3, 1, 0);
    let mut rng = Rng::new(123);
    let x = Tensor3::random(2, 12, 10, &mut rng);
    let k = Tensor4::random(8, 2, 3, 3, &mut rng);
    (layer, x, k)
}

/// An engine that fails every `period`-th task — models soft errors.
struct FlakyEngine {
    inner: DirectEngine,
    counter: AtomicUsize,
    period: usize,
}

impl TaskEngine for FlakyEngine {
    fn name(&self) -> &str {
        "flaky"
    }

    fn run(&self, payload: &WorkerPayload) -> anyhow::Result<WorkerResult> {
        let n = self.counter.fetch_add(1, Ordering::Relaxed);
        if n % self.period == self.period - 1 {
            anyhow::bail!("injected soft error");
        }
        TaskEngine::run(&self.inner, payload)
    }
}

#[test]
fn exactly_gamma_failures_still_recovers() {
    let (layer, x, k) = setup();
    // delta=2, n=6 => gamma=4.
    let plan = FcdccPlan::new_crme(&layer, 4, 2, 6).unwrap();
    let cf = plan.encode_filters(&k);
    let want = conv2d(&x, &k, layer.params());
    let mut cluster = Cluster::new(6, Arc::new(DirectEngine));
    let mut rng = Rng::new(1);
    let (y, report) = cluster
        .run_job(&plan, &x, &cf, &StragglerModel::Failures { count: 4 }, &mut rng)
        .unwrap();
    cluster.shutdown();
    assert!(mse(&y.data, &want.data) < 1e-18);
    assert_eq!(report.used_workers.len(), 2);
}

#[test]
fn engine_soft_errors_absorbed_by_redundancy() {
    let (layer, x, k) = setup();
    let plan = FcdccPlan::new_crme(&layer, 4, 2, 6).unwrap(); // delta=2
    let cf = plan.encode_filters(&k);
    let want = conv2d(&x, &k, layer.params());
    let engine = Arc::new(FlakyEngine {
        inner: DirectEngine,
        counter: AtomicUsize::new(0),
        period: 3, // every third task dies
    });
    let mut cluster = Cluster::new(6, engine);
    let mut rng = Rng::new(2);
    for _ in 0..4 {
        let (y, _) = cluster
            .run_job(&plan, &x, &cf, &StragglerModel::None, &mut rng)
            .unwrap();
        assert!(mse(&y.data, &want.data) < 1e-18);
    }
    cluster.shutdown();
}

#[test]
fn mixed_failures_and_stragglers() {
    let (layer, x, k) = setup();
    let plan = FcdccPlan::new_crme(&layer, 4, 4, 8).unwrap(); // delta=4, gamma=4
    let cf = plan.encode_filters(&k);
    let want = conv2d(&x, &k, layer.params());
    let mut cluster = Cluster::new(8, Arc::new(DirectEngine));
    let mut rng = Rng::new(3);
    // 2 crashed + 2 delayed = exactly gamma misbehaving workers.
    let (y, _) = cluster
        .run_job(&plan, &x, &cf, &StragglerModel::Failures { count: 2 }, &mut rng)
        .unwrap();
    assert!(mse(&y.data, &want.data) < 1e-18);
    let (y, report) = cluster
        .run_job(
            &plan,
            &x,
            &cf,
            &StragglerModel::FixedCount {
                count: 4,
                delay: Duration::from_millis(150),
            },
            &mut rng,
        )
        .unwrap();
    cluster.shutdown();
    assert!(mse(&y.data, &want.data) < 1e-18);
    // The four prompt workers must have been the ones used.
    assert_eq!(report.used_workers.len(), 4);
    assert!(report.collect_secs < 0.12, "waited for stragglers: {}", report.collect_secs);
}

#[test]
fn bernoulli_availability_over_many_jobs() {
    let (layer, x, k) = setup();
    let plan = FcdccPlan::new_crme(&layer, 2, 4, 6).unwrap(); // delta=2, gamma=4
    let cf = plan.encode_filters(&k);
    let want = conv2d(&x, &k, layer.params());
    let mut cluster = Cluster::new(6, Arc::new(DirectEngine));
    let mut rng = Rng::new(4);
    let model = StragglerModel::Bernoulli {
        p: 0.3,
        delay: Duration::from_millis(40),
    };
    for _ in 0..6 {
        let (y, _) = cluster.run_job(&plan, &x, &cf, &model, &mut rng).unwrap();
        assert!(mse(&y.data, &want.data) < 1e-18);
    }
    cluster.shutdown();
}

#[test]
fn exponential_latency_model_runs() {
    let (layer, x, k) = setup();
    let plan = FcdccPlan::new_crme(&layer, 2, 2, 3).unwrap(); // delta=1
    let cf = plan.encode_filters(&k);
    let want = conv2d(&x, &k, layer.params());
    let mut cluster = Cluster::new(3, Arc::new(DirectEngine));
    let mut rng = Rng::new(5);
    let model = StragglerModel::Exponential {
        mean: Duration::from_millis(10),
    };
    let (y, report) = cluster.run_job(&plan, &x, &cf, &model, &mut rng).unwrap();
    cluster.shutdown();
    assert!(mse(&y.data, &want.data) < 1e-18);
    assert_eq!(report.used_workers.len(), 1);
}

/// An engine that panics on every task — the worst-case worker bug.
/// `worker_loop` must convert the unwinds into error replies, not die.
struct PanicEngine;

impl TaskEngine for PanicEngine {
    fn name(&self) -> &str {
        "panic"
    }

    fn run(&self, _payload: &WorkerPayload) -> anyhow::Result<WorkerResult> {
        panic!("injected task panic");
    }
}

#[test]
fn timed_out_job_recycles_buffers_and_next_job_decodes() {
    let (layer, x, k) = setup();
    let plan = FcdccPlan::new_crme(&layer, 4, 2, 6).unwrap(); // delta=2
    let cf = plan.encode_filters(&k);
    let want = conv2d(&x, &k, layer.params());
    let mut cluster = Cluster::new(6, Arc::new(DirectEngine));
    cluster.collect_timeout = Duration::from_millis(100);
    let mut rng = Rng::new(11);

    // Every worker sleeps past the deadline: the job must time out...
    let slow = StragglerModel::FixedCount {
        count: 6,
        delay: Duration::from_millis(300),
    };
    let err = cluster
        .run_job(&plan, &x, &cf, &slow, &mut rng)
        .expect_err("all-slow job must blow its deadline");
    assert!(
        err.to_string().contains("timed out"),
        "unexpected failure: {err}"
    );
    assert_eq!(cluster.health().counters().timeouts, 6);

    // ...its cancelled straggler tasks must be abandoned (buffers
    // recycled, no stale decode), and the same cluster must serve the
    // clean retry bit-exactly.
    let (y, _) = cluster
        .run_job(&plan, &x, &cf, &StragglerModel::None, &mut rng)
        .unwrap();
    assert!(mse(&y.data, &want.data) < 1e-18);
    cluster.shutdown();
    assert_eq!(
        plan.arena().outstanding(),
        0,
        "timeout/retry path leaked arena buffers"
    );
}

#[test]
fn panicking_engine_fails_fast_and_workers_survive() {
    let (layer, x, k) = setup();
    let plan = FcdccPlan::new_crme(&layer, 4, 2, 6).unwrap(); // delta=2
    let cf = plan.encode_filters(&k);
    let mut cluster = Cluster::new(6, Arc::new(PanicEngine));
    // A huge deadline proves the failure is the undecodable fast path,
    // not a timeout.
    cluster.collect_timeout = Duration::from_secs(30);
    let mut rng = Rng::new(12);

    let t0 = Instant::now();
    let err = cluster
        .run_job(&plan, &x, &cf, &StragglerModel::None, &mut rng)
        .expect_err("every reply is a caught panic");
    assert!(
        err.to_string().contains("undecodable"),
        "unexpected failure: {err}"
    );
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "undecodable job waited for the deadline"
    );

    // The panics unwound inside catch_unwind: the worker threads are
    // still alive and answer the next job (with errors again).
    let err = cluster
        .run_job(&plan, &x, &cf, &StragglerModel::None, &mut rng)
        .expect_err("workers still reply with errors");
    assert!(err.to_string().contains("undecodable"), "got: {err}");
    assert_eq!(cluster.health().counters().errors, 12);
    cluster.shutdown();
    assert_eq!(plan.arena().outstanding(), 0);
}

#[test]
fn quarantine_replan_readmission_round_trip() {
    // Workers 1..3 crash from their first task and restart after three
    // dispatches at them: the serve loop must quarantine all three,
    // degrade conv1 (live=1 < delta=2), re-plan conv2 onto worker 0
    // alone (delta=1), then probe, readmit, and restore the full plan —
    // completing every request and leaking nothing.
    let crash = FaultKind::Crash {
        after: 0,
        restart_after: Some(3),
    };
    let mut cfg = ServeConfig::default_with_engine(Arc::new(DirectEngine));
    cfg.requests = 10;
    cfg.max_in_flight = 1;
    cfg.collect_timeout = Duration::from_millis(150);
    cfg.retry_budget = 2;
    cfg.health = HealthPolicy {
        suspect_after: 1,
        quarantine_after: 2,
        probe_backoff: 1,
        max_backoff: 8,
    };
    cfg.fault_plan = FaultPlan::none()
        .with_fault(1, crash)
        .with_fault(2, crash)
        .with_fault(3, crash);
    let stats = serve_lenet(cfg).unwrap();

    assert_eq!(stats.requests, 10);
    assert_eq!(stats.failed_requests, 0, "requests must never hard-fail");
    assert!(
        stats.quarantine_events >= 3,
        "all three crashers must be quarantined (got {})",
        stats.quarantine_events
    );
    assert!(
        stats.readmissions >= 1,
        "restarted workers must be probed back in (got {})",
        stats.readmissions
    );
    assert!(
        stats.degraded_requests >= 1,
        "conv1 below delta must degrade, not fail"
    );
    assert_eq!(stats.class_mismatches, 0);
    assert!(stats.mean_logit_mse < 1e-12, "mse {}", stats.mean_logit_mse);
    assert_eq!(
        stats.arena_outstanding, 0,
        "quarantine/replan/readmit round trip leaked arena buffers"
    );
}

#[test]
fn retried_job_reproduces_bitwise_logits() {
    // Deterministic first-δ subset: worker 0 prompt, worker 1 pinned
    // 25ms slow, workers 2 and 3 dead. conv1 (delta=2) always decodes
    // from {0,1}; conv2 (delta=1) from {0}.
    let pin = FaultPlan::none()
        .with_fault(
            1,
            FaultKind::Slow {
                delay: Duration::from_millis(25),
            },
        )
        .with_fault(
            2,
            FaultKind::Crash {
                after: 0,
                restart_after: None,
            },
        )
        .with_fault(
            3,
            FaultKind::Crash {
                after: 0,
                restart_after: None,
            },
        );
    let cfg = |fault_plan: FaultPlan| {
        let mut cfg = ServeConfig::default_with_engine(Arc::new(DirectEngine));
        cfg.requests = 3;
        cfg.max_in_flight = 1;
        cfg.collect_timeout = Duration::from_millis(150);
        cfg.retry_budget = 2;
        // Thresholds high enough that the dead workers never leave the
        // dispatch set: both runs keep the full plan, so the retried
        // job re-dispatches over the exact same code.
        cfg.health = HealthPolicy {
            suspect_after: 1,
            quarantine_after: 100,
            probe_backoff: 2,
            max_backoff: 32,
        };
        cfg.fault_plan = fault_plan;
        cfg
    };

    let a = serve_lenet(cfg(pin.clone())).unwrap();
    // Run B: worker 0 additionally errors its first task, so request 1's
    // conv1 job stalls at 1/2 usable replies, times out, and is retried.
    let b = serve_lenet(cfg(pin.with_fault(0, FaultKind::ErrorReply { jobs: 1 }))).unwrap();

    assert_eq!(a.retries, 0);
    assert!(b.retries >= 1, "run B must retry the poisoned first job");
    assert_eq!(a.degraded_requests, 0);
    assert_eq!(b.degraded_requests, 0, "retry must succeed before degrading");
    assert_eq!(a.failed_requests, 0);
    assert_eq!(b.failed_requests, 0);
    assert_eq!(
        a.logits, b.logits,
        "retried requests must reproduce bit-identical logits"
    );
    assert_eq!(a.arena_outstanding, 0);
    assert_eq!(b.arena_outstanding, 0);
}

#[test]
fn chaos_seeded_fault_plan_preserves_invariants() {
    // Any chaos seed draws a single-worker absorbable fault; the serving
    // invariants (full completion, correct logits, zero leaks) must hold
    // for every seed. CI re-runs this with FCDCC_CHAOS_SEED=2024.
    let seed = FaultPlan::chaos_seed_from_env().unwrap_or(7);
    let mut cfg = ServeConfig::default_with_engine(Arc::new(DirectEngine));
    cfg.requests = 6;
    cfg.max_in_flight = 2;
    cfg.collect_timeout = Duration::from_millis(300);
    cfg.fault_plan = FaultPlan::chaos(cfg.n_workers, seed);
    let stats = serve_lenet(cfg).unwrap();

    assert_eq!(stats.failed_requests, 0, "chaos seed {seed}: requests failed");
    assert_eq!(stats.class_mismatches, 0, "chaos seed {seed}");
    assert!(
        stats.mean_logit_mse < 1e-12,
        "chaos seed {seed}: mse {}",
        stats.mean_logit_mse
    );
    assert_eq!(
        stats.arena_outstanding, 0,
        "chaos seed {seed}: leaked arena buffers"
    );
}
