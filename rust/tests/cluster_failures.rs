//! Failure-injection integration tests over the threaded cluster: crash
//! fates, flaky engines, repeated jobs, and recovery-threshold edges.

use fcdcc::cluster::{Cluster, StragglerModel};
use fcdcc::engine::{DirectEngine, TaskEngine};
use fcdcc::fcdcc::{FcdccPlan, WorkerPayload, WorkerResult};
use fcdcc::model::ConvLayer;
use fcdcc::tensor::{conv2d, Tensor3, Tensor4};
use fcdcc::util::{mse, rng::Rng};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn setup() -> (ConvLayer, Tensor3, Tensor4) {
    let layer = ConvLayer::new("t", 2, 12, 10, 8, 3, 3, 1, 0);
    let mut rng = Rng::new(123);
    let x = Tensor3::random(2, 12, 10, &mut rng);
    let k = Tensor4::random(8, 2, 3, 3, &mut rng);
    (layer, x, k)
}

/// An engine that fails every `period`-th task — models soft errors.
struct FlakyEngine {
    inner: DirectEngine,
    counter: AtomicUsize,
    period: usize,
}

impl TaskEngine for FlakyEngine {
    fn name(&self) -> &str {
        "flaky"
    }

    fn run(&self, payload: &WorkerPayload) -> anyhow::Result<WorkerResult> {
        let n = self.counter.fetch_add(1, Ordering::Relaxed);
        if n % self.period == self.period - 1 {
            anyhow::bail!("injected soft error");
        }
        TaskEngine::run(&self.inner, payload)
    }
}

#[test]
fn exactly_gamma_failures_still_recovers() {
    let (layer, x, k) = setup();
    // delta=2, n=6 => gamma=4.
    let plan = FcdccPlan::new_crme(&layer, 4, 2, 6).unwrap();
    let cf = plan.encode_filters(&k);
    let want = conv2d(&x, &k, layer.params());
    let mut cluster = Cluster::new(6, Arc::new(DirectEngine));
    let mut rng = Rng::new(1);
    let (y, report) = cluster
        .run_job(&plan, &x, &cf, &StragglerModel::Failures { count: 4 }, &mut rng)
        .unwrap();
    cluster.shutdown();
    assert!(mse(&y.data, &want.data) < 1e-18);
    assert_eq!(report.used_workers.len(), 2);
}

#[test]
fn engine_soft_errors_absorbed_by_redundancy() {
    let (layer, x, k) = setup();
    let plan = FcdccPlan::new_crme(&layer, 4, 2, 6).unwrap(); // delta=2
    let cf = plan.encode_filters(&k);
    let want = conv2d(&x, &k, layer.params());
    let engine = Arc::new(FlakyEngine {
        inner: DirectEngine,
        counter: AtomicUsize::new(0),
        period: 3, // every third task dies
    });
    let mut cluster = Cluster::new(6, engine);
    let mut rng = Rng::new(2);
    for _ in 0..4 {
        let (y, _) = cluster
            .run_job(&plan, &x, &cf, &StragglerModel::None, &mut rng)
            .unwrap();
        assert!(mse(&y.data, &want.data) < 1e-18);
    }
    cluster.shutdown();
}

#[test]
fn mixed_failures_and_stragglers() {
    let (layer, x, k) = setup();
    let plan = FcdccPlan::new_crme(&layer, 4, 4, 8).unwrap(); // delta=4, gamma=4
    let cf = plan.encode_filters(&k);
    let want = conv2d(&x, &k, layer.params());
    let mut cluster = Cluster::new(8, Arc::new(DirectEngine));
    let mut rng = Rng::new(3);
    // 2 crashed + 2 delayed = exactly gamma misbehaving workers.
    let (y, _) = cluster
        .run_job(&plan, &x, &cf, &StragglerModel::Failures { count: 2 }, &mut rng)
        .unwrap();
    assert!(mse(&y.data, &want.data) < 1e-18);
    let (y, report) = cluster
        .run_job(
            &plan,
            &x,
            &cf,
            &StragglerModel::FixedCount {
                count: 4,
                delay: Duration::from_millis(150),
            },
            &mut rng,
        )
        .unwrap();
    cluster.shutdown();
    assert!(mse(&y.data, &want.data) < 1e-18);
    // The four prompt workers must have been the ones used.
    assert_eq!(report.used_workers.len(), 4);
    assert!(report.collect_secs < 0.12, "waited for stragglers: {}", report.collect_secs);
}

#[test]
fn bernoulli_availability_over_many_jobs() {
    let (layer, x, k) = setup();
    let plan = FcdccPlan::new_crme(&layer, 2, 4, 6).unwrap(); // delta=2, gamma=4
    let cf = plan.encode_filters(&k);
    let want = conv2d(&x, &k, layer.params());
    let mut cluster = Cluster::new(6, Arc::new(DirectEngine));
    let mut rng = Rng::new(4);
    let model = StragglerModel::Bernoulli {
        p: 0.3,
        delay: Duration::from_millis(40),
    };
    for _ in 0..6 {
        let (y, _) = cluster.run_job(&plan, &x, &cf, &model, &mut rng).unwrap();
        assert!(mse(&y.data, &want.data) < 1e-18);
    }
    cluster.shutdown();
}

#[test]
fn exponential_latency_model_runs() {
    let (layer, x, k) = setup();
    let plan = FcdccPlan::new_crme(&layer, 2, 2, 3).unwrap(); // delta=1
    let cf = plan.encode_filters(&k);
    let want = conv2d(&x, &k, layer.params());
    let mut cluster = Cluster::new(3, Arc::new(DirectEngine));
    let mut rng = Rng::new(5);
    let model = StragglerModel::Exponential {
        mean: Duration::from_millis(10),
    };
    let (y, report) = cluster.run_job(&plan, &x, &cf, &model, &mut rng).unwrap();
    cluster.shutdown();
    assert!(mse(&y.data, &want.data) < 1e-18);
    assert_eq!(report.used_workers.len(), 1);
}
