//! Property suite for the fused slab algebra (DESIGN.md §Hot-path
//! memory layout): the fused batch encoder, the GEMM decoder, and the
//! im2col patch-reuse worker path must be **bit-identical** to the
//! scalar reference implementations (`coding::encode_inputs` /
//! `coding::decode_outputs` + `merge_output_blocks`) over randomized
//! layer shapes, batch sizes 1..4, and straggler subsets — and
//! steady-state serving must reuse decode staging buffers instead of
//! allocating per job.

use fcdcc::coding;
use fcdcc::fcdcc::{FcdccPlan, WorkerResult};
use fcdcc::model::ConvLayer;
use fcdcc::partition::merge_output_blocks;
use fcdcc::prop::{ensure, run, Gen};
use fcdcc::tensor::{im2col::conv2d_im2col, Tensor3, Tensor4};

/// Random feasible CRME configuration + matching layer geometry
/// (stride, padding, and non-divisible H'/k_A splits all exercised).
fn random_config(g: &mut Gen) -> (ConvLayer, usize, usize, usize) {
    let k_a = *g.choose(&[1usize, 2, 4, 6]);
    let k_b = *g.choose(&[1usize, 2, 4, 8]);
    let delta = (k_a * k_b).div_ceil(if k_a == 1 { 1 } else { 2 } * if k_b == 1 { 1 } else { 2 });
    let n = delta + g.usize_in(1, 3);
    let c = g.usize_in(1, 3);
    let kh = *g.choose(&[1usize, 3, 5]);
    let kw = *g.choose(&[1usize, 3]);
    let stride = g.usize_in(1, 2);
    let pad = g.usize_in(0, 1);
    let h_out_min = k_a.max(2);
    let h = (h_out_min - 1) * stride + kh + g.usize_in(0, 4);
    let h = h.saturating_sub(2 * pad).max(kh);
    let w = kw + stride * g.usize_in(1, 5);
    let n_out = k_b * g.usize_in(1, 3);
    let layer = ConvLayer::new("prop", c, h, w, n_out, kh, kw, stride, pad);
    (layer, k_a, k_b, n)
}

fn random_batch(g: &mut Gen, layer: &ConvLayer) -> Vec<Tensor3> {
    let batch = g.usize_in(1, 4);
    (0..batch)
        .map(|_| Tensor3::random(layer.c, layer.h, layer.w, &mut g.rng))
        .collect()
}

#[test]
fn prop_fused_batch_encoder_bit_identical_to_reference() {
    run("fused batch encode == per-sample reference encode", 30, |g| {
        let (layer, k_a, k_b, n) = random_config(g);
        let plan = FcdccPlan::new_crme(&layer, k_a, k_b, n)
            .map_err(|e| format!("plan failed for {layer:?}: {e:#}"))?;
        let xs = random_batch(g, &layer);
        let refs: Vec<&Tensor3> = xs.iter().collect();
        let fused = plan.encode_input_batch(&refs);
        // Reference: pad -> APCP partition -> coding::encode_inputs per
        // sample, interleaved sample-major like the fused layout.
        let mut want: Vec<Vec<Tensor3>> = (0..n).map(|_| Vec::new()).collect();
        for x in &xs {
            for (w, slabs) in plan.encode_input(x).into_iter().enumerate() {
                want[w].extend(slabs);
            }
        }
        ensure(fused.len() == want.len(), "worker count mismatch")?;
        for (w, (f, r)) in fused.iter().zip(&want).enumerate() {
            ensure(f.len() == r.len(), format!("worker {w}: slab count"))?;
            for (i, (fs, rs)) in f.iter().zip(r).enumerate() {
                ensure(
                    fs.shape() == rs.shape(),
                    format!("worker {w} slab {i}: shape"),
                )?;
                ensure(
                    fs.data == rs.data,
                    format!(
                        "worker {w} slab {i} diverged bitwise \
                         (layer {layer:?}, k_a={k_a}, k_b={k_b}, n={n}, batch={})",
                        xs.len()
                    ),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_gemm_decoder_bit_identical_to_reference() {
    run("GEMM batch decode == reference decode_outputs + merge", 30, |g| {
        let (layer, k_a, k_b, n) = random_config(g);
        let plan = FcdccPlan::new_crme(&layer, k_a, k_b, n)
            .map_err(|e| format!("plan failed for {layer:?}: {e:#}"))?;
        let xs = random_batch(g, &layer);
        let refs: Vec<&Tensor3> = xs.iter().collect();
        let k = Tensor4::random(layer.n, layer.c, layer.kh, layer.kw, &mut g.rng);
        let cf = plan.encode_filters(&k);
        let payloads = plan.make_payloads(plan.encode_input_batch(&refs), &cf);
        // A random straggler pattern: any delta-subset, in arrival
        // (i.e. arbitrary) order.
        let survivors = g.rng.choose_indices(n, plan.delta());
        let results: Vec<WorkerResult> =
            survivors.iter().map(|&i| payloads[i].run_local()).collect();
        let result_refs: Vec<&WorkerResult> = results.iter().collect();
        let fused = plan
            .decode_batch_refs(&result_refs)
            .map_err(|e| format!("fused decode failed: {e:#}"))?;
        ensure(fused.len() == xs.len(), "one output per sample")?;
        // Reference: scalar per-block combine + tensor-list merge, per
        // sample, over the same worker subset in the same order.
        let spec = plan.spec();
        for (s, got) in fused.iter().enumerate() {
            let blocks: Vec<&[Tensor3]> =
                result_refs.iter().map(|r| r.sample_blocks(s)).collect();
            let decoded =
                coding::decode_outputs(plan.code.as_ref(), &survivors, &blocks)
                    .map_err(|e| format!("reference decode failed: {e:#}"))?;
            let want = merge_output_blocks(&decoded, spec.k_a, spec.k_b, layer.h_out());
            ensure(got.shape() == want.shape(), format!("sample {s}: shape"))?;
            ensure(
                got.data == want.data,
                format!(
                    "sample {s} diverged bitwise (layer {layer:?}, k_a={k_a}, \
                     k_b={k_b}, n={n}, survivors {survivors:?})"
                ),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_im2col_patch_reuse_bit_identical_to_per_pair() {
    run("run_im2col == run_with(conv2d_im2col)", 20, |g| {
        let (layer, k_a, k_b, n) = random_config(g);
        let plan = FcdccPlan::new_crme(&layer, k_a, k_b, n)
            .map_err(|e| format!("plan failed for {layer:?}: {e:#}"))?;
        let xs = random_batch(g, &layer);
        let refs: Vec<&Tensor3> = xs.iter().collect();
        let k = Tensor4::random(layer.n, layer.c, layer.kh, layer.kw, &mut g.rng);
        let cf = plan.encode_filters(&k);
        let payloads = plan.make_payloads(plan.encode_input_batch(&refs), &cf);
        let p = &payloads[g.usize_in(0, n - 1)];
        let fused = p.run_im2col();
        let want = p.run_with(|a, b, c| conv2d_im2col(a, b, c));
        ensure(
            fused.blocks.len() == want.blocks.len(),
            "block count mismatch",
        )?;
        for (i, (f, w)) in fused.blocks.iter().zip(&want.blocks).enumerate() {
            ensure(
                f.data == w.data,
                format!("worker {} block {i} diverged bitwise", p.worker_id),
            )?;
        }
        Ok(())
    });
}

#[test]
fn steady_state_serving_reuses_scratch_buffers() {
    // Arena-hit accounting: the first job allocates every buffer the
    // pipeline needs (encode slabs, reply blocks, decode staging); every
    // further job at the same geometry must only reuse pooled buffers.
    let layer = ConvLayer::new("t", 2, 12, 10, 8, 3, 3, 1, 0);
    let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap();
    let mut rng = fcdcc::util::rng::Rng::new(71);
    let k = Tensor4::random(8, 2, 3, 3, &mut rng);
    let jobs = 6u64;
    let mut warm_misses = 0u64;
    for round in 0..jobs {
        let xs: Vec<Tensor3> =
            (0..3).map(|_| Tensor3::random(2, 12, 10, &mut rng)).collect();
        let refs: Vec<&Tensor3> = xs.iter().collect();
        plan.run_inline_batch(&refs, &k, None).unwrap();
        let st = plan.arena().stats();
        if round == 0 {
            warm_misses = st.misses;
            assert!(warm_misses > 0, "the first job must populate the arena");
        } else {
            assert_eq!(
                st.misses, warm_misses,
                "round {round}: hot path allocated past warm-up"
            );
        }
        assert_eq!(plan.arena().outstanding(), 0, "round {round}: buffer leak");
    }
    let st = plan.arena().stats();
    assert!(st.hits > st.misses, "steady state should be hit-dominated");
}
