//! Loopback integration tests for the framed-TCP transport: real worker
//! nodes on 127.0.0.1 ephemeral ports behind the full serving loop
//! (DESIGN.md §Transport & membership).
//!
//! Survivor subsets are pinned with `FaultKind::Slow` staircases where a
//! test needs bit-identical logits: first-δ decode picks whichever δ
//! replies land first, so both transports must see the same arrival
//! order for their decodes to match bit-for-bit.
//!
//! None of these tests assert `frames_corrupt == 0`: a hard connection
//! teardown (kill, crash fate) can surface to a blocked reader as an
//! ECONNRESET mid-frame, which the codec counts as a corrupt read.

use fcdcc::cluster::{
    spawn_worker_node, FaultKind, FaultPlan, TcpConfig, WorkerNodeConfig, WorkerNodeHandle,
};
use fcdcc::coordinator::{serve_lenet, ServeConfig, ServeStats, TransportKind};
use fcdcc::engine::Im2colEngine;
use std::sync::Arc;
use std::time::Duration;

/// Spawn `n` loopback worker nodes; returns the handles and their
/// resolved addresses (slot i ↔ addrs[i]).
fn spawn_nodes(n: usize) -> (Vec<WorkerNodeHandle>, Vec<String>) {
    let nodes: Vec<WorkerNodeHandle> = (0..n)
        .map(|_| {
            spawn_worker_node(WorkerNodeConfig {
                listen: "127.0.0.1:0".to_string(),
                engine: Arc::new(Im2colEngine),
                threads: 1,
            })
            .expect("spawn loopback worker node")
        })
        .collect();
    let addrs = nodes.iter().map(|h| h.addr().to_string()).collect();
    (nodes, addrs)
}

/// Serve over TCP against `addrs` with `tweak` applied to the config.
fn serve_tcp(addrs: Vec<String>, tweak: impl FnOnce(&mut ServeConfig)) -> ServeStats {
    let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
    cfg.n_workers = addrs.len();
    let mut tcp = TcpConfig::new(addrs);
    tcp.heartbeat = Duration::from_millis(50);
    tcp.miss_threshold = 2;
    cfg.transport = TransportKind::Tcp(tcp);
    tweak(&mut cfg);
    serve_lenet(cfg).expect("tcp serve")
}

/// A `Slow` staircase on workers 1..n pins every job's first-δ subset
/// to {0, …, δ−1}: worker i replies ~i·60ms after worker 0, far past
/// the per-task compute time, so arrival order equals slot order on
/// both transports.
fn survivor_staircase(n: usize) -> FaultPlan {
    (1..n).fold(FaultPlan::none(), |fp, w| {
        fp.with_fault(
            w,
            FaultKind::Slow {
                delay: Duration::from_millis(60 * w as u64),
            },
        )
    })
}

#[test]
fn tcp_logits_are_bit_identical_to_the_channel_transport() {
    let (nodes, addrs) = spawn_nodes(4);
    let pin = |cfg: &mut ServeConfig| {
        cfg.requests = 3;
        cfg.max_in_flight = 2;
        cfg.fault_plan = survivor_staircase(4);
        // Remote nodes always pack filters job-side (panels never travel
        // the wire); run the channel reference on the same path.
        cfg.prepack = false;
    };
    let tcp = serve_tcp(addrs, pin);

    let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
    pin(&mut cfg);
    let local = serve_lenet(cfg).expect("channel serve");

    assert_eq!(tcp.requests, 3);
    assert_eq!(tcp.failed_requests, 0);
    assert_eq!(tcp.class_mismatches, 0);
    assert!(tcp.mean_logit_mse < 1e-16, "mse={:e}", tcp.mean_logit_mse);
    // The acceptance bar: with the survivor subsets pinned, the framed
    // wire is bit-transparent — every logit matches the in-process
    // transport exactly, not just to tolerance.
    assert_eq!(tcp.logits, local.logits, "wire must be bit-transparent");
    assert_eq!(tcp.arena_outstanding, 0, "coordinator arena balanced");
    assert_eq!(local.arena_outstanding, 0);
    // Clean run: the membership never churned.
    assert_eq!(tcp.membership.evictions, 0);
    assert_eq!(tcp.membership.epoch, 4, "epoch = n after rendezvous");
    assert!(tcp.membership.heartbeats_sent > 0, "pings flowed");
    for n in nodes {
        n.kill();
    }
}

#[test]
fn killing_a_node_mid_stream_evicts_replans_and_serves_exact_logits() {
    let (mut nodes, addrs) = spawn_nodes(4);
    // Kill node 2 for real once it has decoded a couple of tasks off the
    // wire: the coordinator sees a dead socket mid-batch, not a goodbye.
    let victim = nodes.remove(2);
    let killer = std::thread::spawn(move || {
        while victim.tasks_seen() < 2 {
            std::thread::sleep(Duration::from_millis(2));
        }
        victim.kill();
    });
    let stats = serve_tcp(addrs, |cfg| {
        cfg.requests = 8;
        cfg.max_in_flight = 2;
        cfg.collect_timeout = Duration::from_millis(2_000);
    });
    killer.join().expect("killer thread");

    assert_eq!(stats.failed_requests, 0, "eviction + re-plan must absorb the kill");
    assert_eq!(stats.class_mismatches, 0);
    assert!(
        stats.mean_logit_mse < 1e-16,
        "replanned decode stays exact: mse={:e}",
        stats.mean_logit_mse
    );
    assert!(stats.membership.evictions >= 1, "the dead peer was evicted");
    assert!(
        stats.membership.epoch >= 5,
        "eviction bumps the epoch past the rendezvous value: {}",
        stats.membership.epoch
    );
    assert!(
        stats.quarantine_events >= 1,
        "PeerDown must quarantine the worker for the re-planner"
    );
    assert_eq!(stats.arena_outstanding, 0, "no leaks across the eviction");
    for n in nodes {
        n.kill();
    }
}

#[test]
fn crash_restart_fate_drives_evict_redial_readmit_churn() {
    let (nodes, addrs) = spawn_nodes(4);
    // The crash fate travels inside task frames and the node acts it out
    // by dropping the connection — so a seeded crash-restart plan drives
    // the full evict → re-dial → readmit arc over a live listener.
    let stats = serve_tcp(addrs, |cfg| {
        cfg.requests = 10;
        cfg.max_in_flight = 2;
        cfg.collect_timeout = Duration::from_millis(2_000);
        cfg.fault_plan = FaultPlan::none().with_fault(
            1,
            FaultKind::Crash {
                after: 0,
                restart_after: Some(3),
            },
        );
    });
    assert_eq!(stats.failed_requests, 0);
    assert_eq!(stats.class_mismatches, 0);
    assert!(stats.mean_logit_mse < 1e-16, "mse={:e}", stats.mean_logit_mse);
    assert!(stats.membership.evictions >= 1, "dropped connection evicts");
    assert!(
        stats.membership.reconnects >= 1,
        "the supervisor re-dialed the surviving listener"
    );
    assert_eq!(stats.arena_outstanding, 0);
    for n in nodes {
        n.kill();
    }
}

#[test]
fn seeded_chaos_over_tcp_completes_every_request() {
    // The CI chaos leg exports FCDCC_CHAOS_SEED; locally any seed must
    // hold — every chaos fault is absorbable at γ ≥ 1, and over TCP the
    // crash kinds additionally exercise real membership churn.
    let seed = FaultPlan::chaos_seed_from_env().unwrap_or(2024);
    let (nodes, addrs) = spawn_nodes(4);
    let stats = serve_tcp(addrs, |cfg| {
        cfg.requests = 8;
        cfg.max_in_flight = 2;
        cfg.collect_timeout = Duration::from_millis(2_000);
        cfg.fault_plan = FaultPlan::chaos(4, seed);
    });
    assert_eq!(stats.failed_requests, 0, "chaos seed {seed} hard-failed");
    assert_eq!(stats.class_mismatches, 0, "chaos seed {seed} corrupted logits");
    assert!(stats.mean_logit_mse < 1e-16, "seed {seed}: mse={:e}", stats.mean_logit_mse);
    assert_eq!(stats.arena_outstanding, 0, "chaos seed {seed} leaked buffers");
    for n in nodes {
        n.kill();
    }
}
