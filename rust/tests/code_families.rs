//! Cross-family correctness: the banded convolutional and weight-w
//! sparse codes must decode **exactly** (to CRME-grade fidelity) from
//! any δ survivors, across shapes, batch sizes, straggler rotations,
//! and every bit-exact kernel backend — and the plan-compiled encode
//! programs must be bit-identical to the reference dense combiners for
//! every family in the registry (the oracle pattern of
//! `tests/fused_hot_path.rs`, extended to code families).

use fcdcc::coding::{self, Code, CodeFamily, ConvCode, CrmeCode, SparseCode};
use fcdcc::fcdcc::{FcdccPlan, WorkerResult};
use fcdcc::linalg::kernel;
use fcdcc::model::ConvLayer;
use fcdcc::tensor::{conv2d, Tensor3, Tensor4};
use fcdcc::util::{mse, rng::Rng};
use std::sync::Arc;

/// Inline batched run: encode the batch, compute the chosen survivors'
/// subtasks, decode — the same path the cluster drives, minus threads.
fn run_batch(
    plan: &FcdccPlan,
    xs: &[&Tensor3],
    kk: &Tensor4,
    survivors: &[usize],
) -> Vec<Tensor3> {
    let cf = plan.encode_filters(kk);
    let payloads = plan.make_payloads(plan.encode_input_batch(xs), &cf);
    let results: Vec<WorkerResult> = survivors.iter().map(|&i| payloads[i].run_im2col()).collect();
    let refs: Vec<&WorkerResult> = results.iter().collect();
    plan.decode_batch_refs(&refs).unwrap()
}

fn shapes() -> Vec<(ConvLayer, usize, usize, usize)> {
    vec![
        // (layer, k_A, k_B, n) — mixed pad/no-pad, δ of 2, 1, 2.
        (ConvLayer::new("s1", 2, 12, 10, 8, 3, 3, 1, 0), 4, 2, 5),
        (ConvLayer::new("s2", 3, 16, 8, 4, 3, 3, 1, 1), 2, 2, 4),
        (ConvLayer::new("s3", 2, 14, 9, 8, 3, 3, 1, 1), 2, 4, 4),
    ]
}

#[test]
fn conv_and_sparse_decode_exactly_under_rotation() {
    let mut rng = Rng::new(7);
    for (layer, k_a, k_b, n) in shapes() {
        let codes: Vec<Arc<dyn Code>> = vec![
            Arc::new(ConvCode::new(k_a, k_b, n).unwrap()),
            Arc::new(SparseCode::new(k_a, k_b, n).unwrap()),
        ];
        for code in codes {
            let name = code.name().to_string();
            let plan = FcdccPlan::with_code(&layer, code).unwrap();
            let delta = plan.delta();
            let kk = Tensor4::random(layer.n, layer.c, layer.kh, layer.kw, &mut rng);
            for batch in 1..=4usize {
                let xs: Vec<Tensor3> = (0..batch)
                    .map(|_| Tensor3::random(layer.c, layer.h, layer.w, &mut rng))
                    .collect();
                let xrefs: Vec<&Tensor3> = xs.iter().collect();
                // Rotate the survivor window with the batch size so every
                // worker ends up both used and dropped across the sweep.
                let survivors: Vec<usize> = (0..delta).map(|i| (i + batch) % n).collect();
                let ys = run_batch(&plan, &xrefs, &kk, &survivors);
                assert_eq!(ys.len(), batch);
                for (x, y) in xs.iter().zip(&ys) {
                    let want = conv2d(x, &kk, layer.params());
                    assert_eq!(y.shape(), want.shape());
                    let e = mse(&y.data, &want.data);
                    assert!(
                        e < 1e-16,
                        "{name} batch {batch} survivors {survivors:?}: mse={e:e}"
                    );
                }
            }
        }
    }
}

#[test]
fn new_families_exact_on_every_bit_exact_backend() {
    let mut rng = Rng::new(11);
    let layer = ConvLayer::new("kb", 2, 12, 10, 8, 3, 3, 1, 0);
    let codes: Vec<Arc<dyn Code>> = vec![
        Arc::new(ConvCode::new(4, 2, 5).unwrap()),
        Arc::new(SparseCode::new(4, 2, 5).unwrap()),
    ];
    let kk = Tensor4::random(layer.n, layer.c, layer.kh, layer.kw, &mut rng);
    let xs: Vec<Tensor3> = (0..2)
        .map(|_| Tensor3::random(layer.c, layer.h, layer.w, &mut rng))
        .collect();
    let xrefs: Vec<&Tensor3> = xs.iter().collect();
    let wants: Vec<Tensor3> = xs.iter().map(|x| conv2d(x, &kk, layer.params())).collect();
    let prev = kernel::active();
    for code in codes {
        let name = code.name().to_string();
        let plan = FcdccPlan::with_code(&layer, code).unwrap();
        let survivors = vec![1usize, 3];
        let mut baseline: Option<Vec<Tensor3>> = None;
        for kind in kernel::available() {
            if !kind.bit_exact() {
                continue;
            }
            kernel::set_active(kind);
            let ys = run_batch(&plan, &xrefs, &kk, &survivors);
            for (y, want) in ys.iter().zip(&wants) {
                let e = mse(&y.data, &want.data);
                assert!(e < 1e-16, "{name} on {}: mse={e:e}", kind.name());
            }
            match &baseline {
                None => baseline = Some(ys),
                Some(b) => {
                    for (a, y) in b.iter().zip(&ys) {
                        assert_eq!(
                            a.data,
                            y.data,
                            "{name}: backend {} diverged bitwise",
                            kind.name()
                        );
                    }
                }
            }
        }
    }
    kernel::set_active(prev);
}

#[test]
fn program_encode_bit_identical_to_reference_for_every_family() {
    let mut rng = Rng::new(21);
    let layer = ConvLayer::new("fam", 2, 12, 10, 8, 3, 3, 1, 0);
    for family in CodeFamily::ALL {
        // Smallest feasible partition pair per embedding (ℓ=2 families
        // need even factors; the ℓ=1 polynomial rivals take k_B=1).
        let (k_a, k_b) = if family.even_partitions() {
            (2, 2)
        } else {
            (2, 1)
        };
        let code = family.build(k_a, k_b, 5).unwrap();
        let plan = FcdccPlan::with_code(&layer, Arc::clone(&code)).unwrap();
        let xs: Vec<Tensor3> = (0..3)
            .map(|_| Tensor3::random(layer.c, layer.h, layer.w, &mut rng))
            .collect();
        let xrefs: Vec<&Tensor3> = xs.iter().collect();

        // Inputs: program walk == dense scan == per-sample reference.
        let got = plan.encode_input_batch(&xrefs);
        let dense = plan.encode_input_batch_dense(&xrefs);
        let per_sample: Vec<Vec<Vec<Tensor3>>> = xs.iter().map(|x| plan.encode_input(x)).collect();
        let s = plan.spec();
        for (worker, (gw, dw)) in got.iter().zip(&dense).enumerate() {
            assert_eq!(gw.len(), xs.len() * s.ell_a);
            assert_eq!(gw.len(), dw.len());
            for (g, d) in gw.iter().zip(dw) {
                assert_eq!(g.data, d.data, "{}: program != dense scan", family.tag());
            }
            // Batch layout: sample-major, ℓ_A slabs per sample.
            for (si, sample) in per_sample.iter().enumerate() {
                for j in 0..s.ell_a {
                    assert_eq!(
                        gw[si * s.ell_a + j].data,
                        sample[worker][j].data,
                        "{}: program != reference encode_inputs",
                        family.tag()
                    );
                }
            }
        }

        // Filters: program-walked prepack == reference dense combiner.
        let kk = Tensor4::random(layer.n, layer.c, layer.kh, layer.kw, &mut rng);
        let got_f = plan.encode_filters(&kk);
        let parts = plan.kccp.partition(&kk);
        let want_f = coding::encode_filters(code.as_ref(), &parts);
        assert_eq!(got_f.len(), want_f.len());
        for (rf, ww) in got_f.iter().zip(&want_f) {
            assert_eq!(rf.slabs.len(), ww.len());
            for (g, w) in rf.slabs.iter().zip(ww) {
                assert_eq!(g.data, w.data, "{}: filter program != reference", family.tag());
            }
        }
    }
}

#[test]
fn encode_counters_are_nnz_proportional() {
    let mut rng = Rng::new(31);
    let layer = ConvLayer::new("cnt", 2, 12, 10, 8, 3, 3, 1, 0);
    let x = Tensor3::random(layer.c, layer.h, layer.w, &mut rng);

    // CRME's rotation structure has exact zeros: the program must do
    // strictly less coefficient work than the dense k_A-scan.
    let plan = FcdccPlan::with_code(&layer, Arc::new(CrmeCode::new(4, 2, 5).unwrap())).unwrap();
    plan.encode_input_batch(&[&x]);
    let es = plan.arena().encode_stats();
    assert!(es.cols > 0);
    assert!(
        es.terms < es.dense_terms,
        "CRME: {} terms vs {} dense slots",
        es.terms,
        es.dense_terms
    );

    // Sparse: encode work scales with the column weight w, not k_A.
    let sc = SparseCode::new(4, 2, 5).unwrap();
    let w = sc.weight_a() as u64;
    assert!(w < 4, "weight must undercut k_A for the scaling claim");
    let plan = FcdccPlan::with_code(&layer, Arc::new(sc)).unwrap();
    plan.encode_input_batch(&[&x]);
    let es = plan.arena().encode_stats();
    assert!(es.cols > 0);
    assert!(
        es.terms <= w * es.cols,
        "sparse: {} terms exceeds w·cols = {}",
        es.terms,
        w * es.cols
    );
    assert!(es.terms < es.dense_terms);

    // The dense-scan baseline books its full slot count.
    let plan = FcdccPlan::with_code(&layer, Arc::new(CrmeCode::new(4, 2, 5).unwrap())).unwrap();
    plan.encode_input_batch_dense(&[&x]);
    let es = plan.arena().encode_stats();
    assert_eq!(es.terms, es.dense_terms);
}
