//! §V-E reproduction + ablations beyond the paper:
//!
//! 1. Master-side overhead (input encode + recovery inversion + output
//!    decode) as a fraction of per-worker compute, as Q = k_A·k_B grows —
//!    the paper predicts the ratio grows monotonically toward the
//!    dominance thresholds of §V-E (validated by the 0.1–1.8% decode
//!    overheads of Table III at moderate Q).
//! 2. Ablation: ℓ=2 CRME vs ℓ=1 real-polynomial code at equal δ — the
//!    stability price in encoding work.
//! 3. Ablation: worker conv engine (direct vs im2col vs PJRT artifact).

use fcdcc::bench_harness::{bench, fast_mode, report, BenchConfig};
use fcdcc::cluster::sim::simulate_job;
use fcdcc::cluster::straggler::WorkerFate;
use fcdcc::coding::vandermonde::{PointSet, VandermondeCode};
use fcdcc::coordinator::stability::factor_pair;
use fcdcc::engine::{DirectEngine, Im2colEngine, TaskEngine};
use fcdcc::fcdcc::FcdccPlan;
use fcdcc::metrics::Table;
use fcdcc::model::{zoo, ConvLayer};
use fcdcc::tensor::{Tensor3, Tensor4};
use fcdcc::util::rng::Rng;
use std::sync::Arc;

fn overhead_vs_q() {
    let layer = zoo::alexnet()[1].scaled_channels(4); // conv2/c4: C=24, N=64
    let mut rng = Rng::new(77);
    let x = Tensor3::random(layer.c, layer.h, layer.w, &mut rng);
    let k = Tensor4::random(layer.n, layer.c, layer.kh, layer.kw, &mut rng);
    let engine = Im2colEngine;

    let mut t = Table::new(
        &format!("§V-E: master overhead vs Q — {}", layer.name),
        &[
            "Q", "delta", "n", "(kA,kB)", "encode (ms)", "decode (ms)",
            "worker compute (ms)", "overhead ratio",
        ],
    );
    let qs: &[usize] = if fast_mode() {
        &[16, 64]
    } else {
        &[4, 16, 64, 128, 256]
    };
    for &q in qs {
        let delta = q / 4;
        let n = delta + 2;
        let Ok((ka, kb)) = factor_pair(q, layer.n, layer.h_out(), true) else {
            continue;
        };
        let Ok(plan) = FcdccPlan::new_crme(&layer, ka, kb, n) else {
            continue;
        };
        let cf = plan.encode_filters(&k);
        let fates = vec![WorkerFate::Prompt; n];
        let job = simulate_job(&plan, &x, &cf, &engine, &fates).expect("sim");
        let worker_ms = job.mean_compute_secs() * 1e3;
        let overhead_ms = (job.encode_secs + job.decode_secs) * 1e3;
        t.row(&[
            q.to_string(),
            delta.to_string(),
            n.to_string(),
            format!("({ka},{kb})"),
            format!("{:.3}", job.encode_secs * 1e3),
            format!("{:.3}", job.decode_secs * 1e3),
            format!("{worker_ms:.3}"),
            format!("{:.1}%", 100.0 * overhead_ms / worker_ms),
        ]);
    }
    t.print();
    println!("\nExpected: ratio grows with Q (paper §V-E dominance thresholds).");
}

fn ell_ablation() {
    // Same δ = 9, same layer: CRME (ℓ=2, Q=36) vs real poly (ℓ=1, Q=9).
    let layer = ConvLayer::new("ablate", 8, 20, 20, 36, 3, 3, 1, 1);
    let n = 12usize;
    let mut rng = Rng::new(78);
    let x = Tensor3::random(layer.c, layer.h, layer.w, &mut rng);
    let k = Tensor4::random(layer.n, layer.c, layer.kh, layer.kw, &mut rng);

    let crme = FcdccPlan::new_crme(&layer, 6, 6, n).unwrap(); // delta=9
    let poly = FcdccPlan::with_code(
        &layer,
        Arc::new(VandermondeCode::new(3, 3, n, PointSet::Equispaced).unwrap()),
    )
    .unwrap(); // delta=9

    let cfg = BenchConfig::default();
    println!("\n### Ablation: ℓ=2 CRME vs ℓ=1 real polynomial at δ=9 (n={n})\n");
    for (name, plan) in [("CRME (l=2)", &crme), ("RealPoly (l=1)", &poly)] {
        let s = bench(cfg, || plan.encode_input(&x));
        report(&format!("{name}: encode_input"), &s);
        let cf = plan.encode_filters(&k);
        let fates = vec![WorkerFate::Prompt; n];
        let engine = Im2colEngine;
        let s = bench(BenchConfig::quick(), || {
            simulate_job(plan, &x, &cf, &engine, &fates).unwrap().decode_secs
        });
        report(&format!("{name}: full job"), &s);
    }
    println!("(CRME does ~4x the coded-combination work for its stability gain)");
}

fn engine_ablation() {
    let layer = ConvLayer::new("testlayer", 2, 12, 10, 8, 3, 3, 1, 0);
    let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap();
    let mut rng = Rng::new(79);
    let x = Tensor3::random(layer.c, layer.h, layer.w, &mut rng);
    let k = Tensor4::random(layer.n, layer.c, layer.kh, layer.kw, &mut rng);
    let payloads = plan.make_payloads(plan.encode_input(&x), &plan.encode_filters(&k));
    let p = &payloads[0];

    println!("\n### Ablation: worker conv engine (one coded subtask)\n");
    let cfg = BenchConfig {
        warmup_iters: 2,
        sample_iters: if fast_mode() { 3 } else { 10 },
    };
    let s = bench(cfg, || DirectEngine.run(p).unwrap());
    report("direct (naive loops)", &s);
    let s = bench(cfg, || Im2colEngine.run(p).unwrap());
    report("im2col + GEMM", &s);
    pjrt_ablation(p, cfg);
}

#[cfg(feature = "pjrt")]
fn pjrt_ablation(p: &fcdcc::fcdcc::WorkerPayload, cfg: BenchConfig) {
    match fcdcc::runtime::PjrtService::spawn("artifacts") {
        Ok(host) => {
            let h = host.handle.clone();
            let s = bench(cfg, || h.run(p).unwrap());
            report("PJRT (AOT JAX/Pallas artifact)", &s);
            std::mem::forget(host);
        }
        Err(e) => println!("PJRT engine skipped: {e}"),
    }
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_ablation(_p: &fcdcc::fcdcc::WorkerPayload, _cfg: BenchConfig) {
    println!("PJRT engine skipped (built without the `pjrt` feature)");
}

fn main() {
    overhead_vs_q();
    ell_ablation();
    engine_ablation();
}
