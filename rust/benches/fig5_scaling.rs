//! Fig. 5 reproduction: average computation time vs (n, δ) at fixed
//! straggler capacity γ = 4, over the AlexNet ConvLs (channel-scaled for
//! the 1-vCPU testbed). Expectation: time decreases as n (and δ = n−γ)
//! grows — more workers, smaller per-worker subtasks.

use fcdcc::bench_harness::fast_mode;
use fcdcc::cluster::sim::simulate_job;
use fcdcc::cluster::straggler::WorkerFate;
use fcdcc::coordinator::stability::factor_pair;
use fcdcc::engine::Im2colEngine;
use fcdcc::fcdcc::FcdccPlan;
use fcdcc::metrics::Table;
use fcdcc::model::zoo;
use fcdcc::tensor::{Tensor3, Tensor4};
use fcdcc::util::rng::Rng;

fn main() {
    let gamma = 4usize;
    let ns: Vec<usize> = if fast_mode() {
        vec![8, 16]
    } else {
        vec![8, 12, 16, 20, 24, 28, 32, 36]
    };
    let trials = if fast_mode() { 1 } else { 2 };
    let layers: Vec<_> = zoo::alexnet()
        .iter()
        .map(|l| l.scaled_channels(2))
        .collect();
    let engine = Im2colEngine;
    let mut rng = Rng::new(55);

    let mut t = Table::new(
        "Fig. 5: average virtual computation time vs (n, delta), gamma = 4 — AlexNet ConvLs",
        &["n", "delta", "avg time (ms)", "avg makespan (ms)", "avg encode (ms)", "avg decode (ms)"],
    );

    for &n in &ns {
        let delta = n - gamma;
        let mut totals = Vec::new();
        let mut makespans = Vec::new();
        let mut encodes = Vec::new();
        let mut decodes = Vec::new();
        for layer in &layers {
            let Ok((ka, kb)) = factor_pair(4 * delta, layer.n, layer.h_out(), true) else {
                eprintln!("skip {} at delta={delta}", layer.name);
                continue;
            };
            let Ok(plan) = FcdccPlan::new_crme(layer, ka, kb, n) else {
                continue;
            };
            let x = Tensor3::random(layer.c, layer.h, layer.w, &mut rng);
            let k = Tensor4::random(layer.n, layer.c, layer.kh, layer.kw, &mut rng);
            let cf = plan.encode_filters(&k);
            let fates = vec![WorkerFate::Prompt; n];
            for _ in 0..trials {
                let job = simulate_job(&plan, &x, &cf, &engine, &fates).expect("sim");
                totals.push(job.total_secs());
                makespans.push(job.makespan_secs);
                encodes.push(job.encode_secs);
                decodes.push(job.decode_secs);
            }
        }
        let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64 * 1e3;
        t.row(&[
            n.to_string(),
            delta.to_string(),
            format!("{:.2}", avg(&totals)),
            format!("{:.2}", avg(&makespans)),
            format!("{:.3}", avg(&encodes)),
            format!("{:.3}", avg(&decodes)),
        ]);
    }
    t.print();
    println!("\nExpected shape (paper): monotone decrease with n (per-worker");
    println!("workload shrinks as delta = n - 4 grows).");
}
