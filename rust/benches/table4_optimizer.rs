//! Table IV + Fig. 7 reproduction: optimal (k_A, k_B) configurations per
//! CNN layer for Q ∈ {16, 32, 64} under the paper's AWS-derived cost
//! coefficients (λ_comm = 0.09, λ_store = 0.023, λ_comp = 0), plus the
//! Fig. 7 cost landscape for the first two AlexNet ConvLs at Q = 32.
//! Fully analytic — runs on the paper's full-size layer geometries.

use fcdcc::fcdcc::cost::{self, CostModel};
use fcdcc::metrics::Table;
use fcdcc::model::zoo;

fn main() {
    let cm = CostModel::paper_exp5();
    let qs = [16usize, 32, 64];

    // Table IV: one table per architecture (VGG uses the paper's
    // five-block representative view).
    let archs: Vec<(&str, Vec<fcdcc::model::ConvLayer>)> = vec![
        ("LeNet-5", zoo::lenet5()),
        ("AlexNet", zoo::alexnet()),
        ("VGGNet (blocks)", zoo::vgg_blocks()),
    ];
    for (name, layers) in &archs {
        let mut header = vec!["Q".to_string()];
        header.extend(layers.iter().map(|l| l.name.clone()));
        let mut t = Table::new(
            &format!("Table IV: optimized (k_A, k_B) for {name}"),
            &header.iter().map(String::as_str).collect::<Vec<_>>(),
        );
        for &q in &qs {
            let mut row = vec![q.to_string()];
            for layer in layers {
                match cost::optimize(layer, &cm, q) {
                    Some(c) => row.push(format!("({}, {})", c.best.k_a, c.best.k_b)),
                    None => row.push("—".to_string()),
                }
            }
            t.row(&row);
        }
        t.print();
    }

    // Fig. 7: the U(k_A, k_B) landscape for AlexNet conv1 & conv2, Q=32.
    for layer in &zoo::alexnet()[..2] {
        let choice = cost::optimize(layer, &cm, 32).expect("feasible");
        let mut t = Table::new(
            &format!(
                "Fig. 7: U(k_A, k_B) for {} at Q=32 (real k_A* = {:.2})",
                layer.name, choice.k_a_star_real
            ),
            &["k_A", "k_B", "C_comm_up", "C_comm_down", "C_store", "U total"],
        );
        for c in &choice.candidates {
            let mark = if (c.k_a, c.k_b) == (choice.best.k_a, choice.best.k_b) {
                " *"
            } else {
                ""
            };
            t.row(&[
                format!("{}{mark}", c.k_a),
                c.k_b.to_string(),
                format!("{:.0}", c.comm_up),
                format!("{:.0}", c.comm_down),
                format!("{:.0}", c.store),
                format!("{:.0}", c.total()),
            ]);
        }
        t.print();
    }
    println!("\nExpected shape (paper Table IV): early layers (large H×W, small N)");
    println!("choose large k_A; deep layers (large N, small H×W) choose large k_B;");
    println!("optimal factors grow with Q.");
}
