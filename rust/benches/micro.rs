//! Micro-benchmarks of the substrates on the hot path — the profiling
//! entry point for the performance pass (EXPERIMENTS.md §Perf): conv
//! engines, coded combination (encode), recovery inversion, decode
//! combination, the tensor primitives, and the **fused slab algebra**
//! (batch encode / GEMM decode / patch-matrix reuse) against its scalar
//! reference path.
//!
//! Besides the human-readable lines, the fused-vs-reference sections
//! emit **one JSON line each** (`{"bench":"micro",...}`) with
//! entries-per-second for both paths and the speedup, so the bench
//! trajectory (`BENCH_*.json`) can track the coded hot path over time.
//! The acceptance bar for the fusion PR is `speedup >= 2` on the
//! `encode_decode_batch` record.

use fcdcc::bench_harness::{bench, emit_json, fast_mode, report, BenchConfig};
use fcdcc::coding::{self, registry, Code, CrmeCode, SparseCode};
use fcdcc::fcdcc::{FcdccPlan, WorkerResult};
use fcdcc::linalg::{cond_2, gemm, kernel, lu, Mat};
use fcdcc::metrics::Stats;
use fcdcc::model::ConvLayer;
use fcdcc::partition::merge_output_blocks;
use fcdcc::tensor::{conv2d, im2col::conv2d_im2col, ConvParams, Tensor3, Tensor4};
use fcdcc::util::rng::Rng;
use std::sync::Arc;

/// One trajectory record: entries/second through the reference and the
/// fused path, plus the speedup. The record carries the compute-pool
/// size and the active dispatched kernel backend so trajectory entries
/// from differently-sized (or differently-vectorized) runners stay
/// interpretable; `FCDCC_BENCH_OUT` appends every record to the
/// committed artifact.
fn json_speed(op: &str, entries: usize, reference: &Stats, fused: &Stats) {
    let e = entries as f64;
    emit_json(&format!(
        "{{\"bench\":\"micro\",\"op\":\"{op}\",\"entries\":{entries},\
         \"threads\":{},\"kernel\":\"{}\",\"code\":\"{}\",\
         \"ref_secs\":{:.6e},\"fused_secs\":{:.6e},\
         \"ref_entries_per_sec\":{:.4e},\"fused_entries_per_sec\":{:.4e},\
         \"speedup\":{:.3}}}",
        fcdcc::util::pool::global().threads(),
        kernel::active().name(),
        registry::default_family().tag(),
        reference.mean,
        fused.mean,
        e / reference.mean,
        e / fused.mean,
        reference.mean / fused.mean,
    ));
}

/// 256×256 matmul through the packed GEMM on an **explicit** backend —
/// the scalar-vs-dispatched comparison for the SIMD trajectory record.
fn matmul_kind(kind: kernel::Kind, a: &Mat, b: &Mat) -> Mat {
    let mut out = Mat::zeros(a.rows, b.cols);
    gemm::gemm_into_kind(
        kind,
        a.rows,
        b.cols,
        a.cols,
        &gemm::RowMajor {
            data: &a.data,
            ld: a.cols,
        },
        &gemm::RowMajor {
            data: &b.data,
            ld: b.cols,
        },
        &mut out.data,
        b.cols,
    );
    out
}

fn main() {
    let cfg = BenchConfig {
        warmup_iters: 1,
        sample_iters: if fast_mode() { 3 } else { 7 },
    };
    let mut rng = Rng::new(99);

    println!("### conv engines (C=64, 28x28, N=64, 3x3, s=1)\n");
    let x = Tensor3::random(64, 28, 28, &mut rng);
    let k = Tensor4::random(64, 64, 3, 3, &mut rng);
    let p = ConvParams::new(1, 1);
    report("conv2d direct", &bench(cfg, || conv2d(&x, &k, p)));
    report("conv2d im2col", &bench(cfg, || conv2d_im2col(&x, &k, p)));

    println!("\n### coded combination (encode) — k_A=8, n=20, slab 16x14x14\n");
    let code = CrmeCode::new(8, 8, 20).unwrap();
    let parts: Vec<Tensor3> = (0..8).map(|_| Tensor3::random(16, 14, 14, &mut rng)).collect();
    report(
        "encode_inputs (8 -> 40 slabs)",
        &bench(cfg, || coding::encode_inputs(&code, &parts)),
    );

    println!("\n### recovery inversion + condition number (kA*kB = 64)\n");
    let subset: Vec<usize> = (0..16).collect();
    let e = code.recovery(&subset);
    report("recovery build (64x64)", &bench(cfg, || code.recovery(&subset)));
    report("LU inverse (64x64)", &bench(cfg, || lu::invert(&e).unwrap()));
    report("cond_2 via Jacobi SVD (64x64)", &bench(cfg, || cond_2(&e)));

    println!("\n### full pipeline stages — alexnet.conv3 geometry /4\n");
    let layer = ConvLayer::new("conv3/c4", 64, 13, 13, 96, 3, 3, 1, 1);
    let plan = FcdccPlan::new_crme(&layer, 4, 8, 10).unwrap(); // delta=8
    let x = Tensor3::random(layer.c, layer.h, layer.w, &mut rng);
    let kk = Tensor4::random(layer.n, layer.c, layer.kh, layer.kw, &mut rng);
    report("encode_filters", &bench(cfg, || plan.encode_filters(&kk)));
    report("encode_input (reference)", &bench(cfg, || plan.encode_input(&x)));
    report(
        "encode_input_batch (fused, batch 1)",
        &bench(cfg, || plan.encode_input_batch(&[&x])),
    );
    let cf = plan.encode_filters(&kk);
    let payloads = plan.make_payloads(plan.encode_input_batch(&[&x]), &cf);
    report(
        "worker subtask (per-pair im2col)",
        &bench(cfg, || payloads[0].run_with(|a, b, c| conv2d_im2col(a, b, c))),
    );
    report(
        "worker subtask (fused patch reuse)",
        &bench(cfg, || payloads[0].run_im2col()),
    );

    // --- Plan-resident prepacked filter panels vs per-job worker-side
    // packing: the same fused subtask, with the filter slabs' packed-A
    // panels built once at plan build (the default) vs re-packed from
    // the raw slab on every job (`--no-prepack`). Bit-identical by
    // construction — asserted here in-bench, not just in tests.
    let plan_nopack = FcdccPlan::new_crme(&layer, 4, 8, 10)
        .unwrap()
        .with_prepack(false);
    let cf_nopack = plan_nopack.encode_filters(&kk);
    let payloads_nopack =
        plan_nopack.make_payloads(plan_nopack.encode_input_batch(&[&x]), &cf_nopack);
    let got_pre = payloads[0].run_im2col();
    let got_per = payloads_nopack[0].run_im2col();
    assert_eq!(got_pre.blocks.len(), got_per.blocks.len());
    for (bp, bj) in got_pre.blocks.iter().zip(&got_per.blocks) {
        assert_eq!(bp.data, bj.data, "prepacked subtask diverged bitwise");
    }
    let sub_entries: usize = got_pre.blocks.iter().map(|b| b.data.len()).sum();
    let sub_perjob = bench(cfg, || payloads_nopack[0].run_im2col());
    let sub_prepacked = bench(cfg, || payloads[0].run_im2col());
    report("worker subtask (per-job filter pack)", &sub_perjob);
    report("worker subtask (plan-resident prepacked)", &sub_prepacked);
    json_speed("prepacked_vs_perjob_pack", sub_entries, &sub_perjob, &sub_prepacked);

    let results: Vec<_> = payloads[..plan.delta()].iter().map(|p| p.run_im2col()).collect();
    report("decode + merge (GEMM)", &bench(cfg, || plan.decode(&results).unwrap()));

    // --- The fusion acceptance bar: batched encode+decode, fused vs the
    // pre-fusion reference chain, on the same machine and inputs.
    let batch = 4usize;
    println!("\n### fused slab algebra vs reference — {}, batch {batch}\n", layer.name);
    let xs: Vec<Tensor3> = (0..batch)
        .map(|_| Tensor3::random(layer.c, layer.h, layer.w, &mut rng))
        .collect();
    let xrefs: Vec<&Tensor3> = xs.iter().collect();
    let spec = plan.spec();

    // Encode: reference = per-sample pad -> partition -> axpy chain.
    let enc_ref = bench(cfg, || {
        xrefs.iter().map(|x| plan.encode_input(x)).collect::<Vec<_>>()
    });
    let enc_fused = bench(cfg, || plan.encode_input_batch(&xrefs));
    report("encode batch (reference chain)", &enc_ref);
    report("encode batch (fused single-pass)", &enc_fused);
    let slab_entries = layer.c * plan.apcp.h_hat * (layer.w + 2 * layer.pad);
    let enc_entries = batch * spec.n * spec.ell_a * slab_entries;
    json_speed("encode_batch", enc_entries, &enc_ref, &enc_fused);

    // Decode: reference = per-sample per-block zeros+axpy combine plus
    // the tensor-list concat merge; fused = pooled GEMM + flat merge.
    // The recovery inverse is precomputed for both (the LRU cache makes
    // it a per-job constant either way).
    let payloads = plan.make_payloads(plan.encode_input_batch(&xrefs), &cf);
    let results: Vec<WorkerResult> =
        payloads[..plan.delta()].iter().map(|p| p.run_im2col()).collect();
    let result_refs: Vec<&WorkerResult> = results.iter().collect();
    let workers: Vec<usize> = result_refs.iter().map(|r| r.worker_id).collect();
    let d = coding::recovery_inverse(plan.code.as_ref(), &workers).unwrap();
    let dec_ref = bench(cfg, || {
        (0..batch)
            .map(|s| {
                let blocks: Vec<&[Tensor3]> =
                    result_refs.iter().map(|r| r.sample_blocks(s)).collect();
                let decoded =
                    coding::decode_outputs_with(plan.code.as_ref(), &d, &blocks).unwrap();
                merge_output_blocks(&decoded, spec.k_a, spec.k_b, layer.h_out())
            })
            .collect::<Vec<_>>()
    });
    let dec_fused = bench(cfg, || plan.decode_batch_refs(&result_refs).unwrap());
    report("decode batch (reference chain)", &dec_ref);
    report("decode batch (fused GEMM + pool)", &dec_fused);
    let dec_entries = batch * layer.n * layer.h_out() * layer.w_out();
    json_speed("decode_batch", dec_entries, &dec_ref, &dec_fused);

    // Combined encode+decode — the PR acceptance record.
    let both_ref = Stats::from(&[enc_ref.mean + dec_ref.mean]);
    let both_fused = Stats::from(&[enc_fused.mean + dec_fused.mean]);
    json_speed("encode_decode_batch", enc_entries + dec_entries, &both_ref, &both_fused);

    // --- Program-compiled encode vs the dense coefficient scan: the
    // same fused batch encoder on a weight-w sparse code, walking the
    // plan-resident CSC program (nonzero coefficients only) vs scanning
    // all k_A coefficient slots per coded column. Bit-identical by
    // construction — asserted here in-bench, not just in tests.
    println!(
        "\n### program-compiled encode vs dense scan — weight-w sparse code, batch {batch}\n"
    );
    let sparse: Arc<dyn Code> = Arc::new(SparseCode::new(4, 8, 10).unwrap());
    let splan = FcdccPlan::with_code(&layer, sparse).unwrap();
    let got_prog = splan.encode_input_batch(&xrefs);
    let got_dense = splan.encode_input_batch_dense(&xrefs);
    assert_eq!(got_prog.len(), got_dense.len());
    for (wp, wd) in got_prog.iter().zip(&got_dense) {
        assert_eq!(wp.len(), wd.len());
        for (pg, dn) in wp.iter().zip(wd) {
            assert_eq!(pg.data, dn.data, "program encode diverged from dense scan");
        }
    }
    let nnz_frac = splan.encode_program_a().nnz_frac();
    let enc_dense = bench(cfg, || splan.encode_input_batch_dense(&xrefs));
    let enc_prog = bench(cfg, || splan.encode_input_batch(&xrefs));
    report("encode batch (dense k_A scan)", &enc_dense);
    report(
        &format!("encode batch (compiled program, nnz frac {nnz_frac:.2})"),
        &enc_prog,
    );
    let sspec = splan.spec();
    let sp_entries =
        batch * sspec.n * sspec.ell_a * layer.c * splan.apcp.h_hat * (layer.w + 2 * layer.pad);
    emit_json(&format!(
        "{{\"bench\":\"micro\",\"op\":\"sparse_program_vs_dense_scan\",\
         \"entries\":{sp_entries},\"threads\":{},\"kernel\":\"{}\",\
         \"code\":\"sparse\",\"nnz_frac\":{:.4},\
         \"ref_secs\":{:.6e},\"fused_secs\":{:.6e},\"speedup\":{:.3}}}",
        fcdcc::util::pool::global().threads(),
        kernel::active().name(),
        nnz_frac,
        enc_dense.mean,
        enc_prog.mean,
        enc_dense.mean / enc_prog.mean,
    ));

    println!("\n### linalg (256x256 matmul / LU / transpose)\n");
    let a = Mat::random(256, 256, &mut rng);
    let b = Mat::random(256, 256, &mut rng);
    // The pre-packing ikj loop, kept here as the scalar baseline for
    // the packed register-tiled microkernel.
    let matmul_ikj = |a: &Mat, b: &Mat| {
        let mut out = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            let arow = a.row(i);
            let orow = &mut out.data[i * b.cols..(i + 1) * b.cols];
            for (k, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                for (o, &bv) in orow.iter_mut().zip(b.row(k)) {
                    *o += av * bv;
                }
            }
        }
        out
    };
    let mm_ref = bench(cfg, || matmul_ikj(&a, &b));
    let mm_packed = bench(cfg, || a.matmul(&b));
    report("matmul 256 (ikj reference)", &mm_ref);
    report("matmul 256 (packed microkernel)", &mm_packed);
    json_speed("matmul_256", 256 * 256, &mm_ref, &mm_packed);

    // Scalar vs runtime-dispatched backend on the *same* packed GEMM —
    // the SIMD-dispatch acceptance record. Outputs are bit-identical
    // (asserted below); only the microkernel's lane width differs.
    let active = kernel::active();
    let mm_scalar = bench(cfg, || matmul_kind(kernel::Kind::Scalar, &a, &b));
    let mm_active = bench(cfg, || matmul_kind(active, &a, &b));
    report("matmul 256 (scalar microkernel)", &mm_scalar);
    report(
        &format!("matmul 256 (dispatched: {})", active.name()),
        &mm_active,
    );
    if active.bit_exact() {
        assert_eq!(
            matmul_kind(kernel::Kind::Scalar, &a, &b).data,
            matmul_kind(active, &a, &b).data,
            "dispatched backend diverged from scalar"
        );
    }
    json_speed("matmul_256_simd", 256 * 256, &mm_scalar, &mm_active);
    report("LU factor 256", &bench(cfg, || lu::Lu::factor(&a).unwrap()));
    let lu256 = lu::Lu::factor(&a).unwrap();
    report("LU inverse 256 (reused RHS buffer)", &bench(cfg, || lu256.inverse()));
    report("transpose 256 (blocked)", &bench(cfg, || a.transpose()));
}
