//! Micro-benchmarks of the substrates on the hot path — the profiling
//! entry point for the performance pass (EXPERIMENTS.md §Perf): conv
//! engines, coded combination (encode), recovery inversion, decode
//! combination, and the tensor primitives.

use fcdcc::bench_harness::{bench, fast_mode, report, BenchConfig};
use fcdcc::coding::{self, CrmeCode, Code};
use fcdcc::fcdcc::FcdccPlan;
use fcdcc::linalg::{cond_2, lu, Mat};
use fcdcc::model::ConvLayer;
use fcdcc::tensor::{conv2d, im2col::conv2d_im2col, ConvParams, Tensor3, Tensor4};
use fcdcc::util::rng::Rng;

fn main() {
    let cfg = BenchConfig {
        warmup_iters: 1,
        sample_iters: if fast_mode() { 3 } else { 7 },
    };
    let mut rng = Rng::new(99);

    println!("### conv engines (C=64, 28x28, N=64, 3x3, s=1)\n");
    let x = Tensor3::random(64, 28, 28, &mut rng);
    let k = Tensor4::random(64, 64, 3, 3, &mut rng);
    let p = ConvParams::new(1, 1);
    report("conv2d direct", &bench(cfg, || conv2d(&x, &k, p)));
    report("conv2d im2col", &bench(cfg, || conv2d_im2col(&x, &k, p)));

    println!("\n### coded combination (encode) — k_A=8, n=20, slab 16x14x14\n");
    let code = CrmeCode::new(8, 8, 20).unwrap();
    let parts: Vec<Tensor3> = (0..8).map(|_| Tensor3::random(16, 14, 14, &mut rng)).collect();
    report(
        "encode_inputs (8 -> 40 slabs)",
        &bench(cfg, || coding::encode_inputs(&code, &parts)),
    );

    println!("\n### recovery inversion + condition number (kA*kB = 64)\n");
    let subset: Vec<usize> = (0..16).collect();
    let e = code.recovery(&subset);
    report("recovery build (64x64)", &bench(cfg, || code.recovery(&subset)));
    report("LU inverse (64x64)", &bench(cfg, || lu::invert(&e).unwrap()));
    report("cond_2 via Jacobi SVD (64x64)", &bench(cfg, || cond_2(&e)));

    println!("\n### full pipeline stages — alexnet.conv3 geometry /4\n");
    let layer = ConvLayer::new("conv3/c4", 64, 13, 13, 96, 3, 3, 1, 1);
    let plan = FcdccPlan::new_crme(&layer, 4, 8, 10).unwrap(); // delta=8
    let x = Tensor3::random(layer.c, layer.h, layer.w, &mut rng);
    let kk = Tensor4::random(layer.n, layer.c, layer.kh, layer.kw, &mut rng);
    report("encode_filters", &bench(cfg, || plan.encode_filters(&kk)));
    report("encode_input", &bench(cfg, || plan.encode_input(&x)));
    let cf = plan.encode_filters(&kk);
    let payloads = plan.make_payloads(plan.encode_input(&x), &cf);
    report(
        "worker subtask (im2col)",
        &bench(cfg, || payloads[0].run_with(|a, b, c| conv2d_im2col(a, b, c))),
    );
    let results: Vec<_> = payloads[..plan.delta()]
        .iter()
        .map(|p| p.run_with(|a, b, c| conv2d_im2col(a, b, c)))
        .collect();
    report("decode + merge", &bench(cfg, || plan.decode(&results).unwrap()));

    println!("\n### linalg (256x256 matmul / LU)\n");
    let a = Mat::random(256, 256, &mut rng);
    let b = Mat::random(256, 256, &mut rng);
    report("matmul 256", &bench(cfg, || a.matmul(&b)));
    report("LU factor 256", &bench(cfg, || lu::Lu::factor(&a).unwrap()));
}
