//! Open-loop overload bench: the serving front-end's admission control,
//! deadline enforcement, and load shedding under synthetic arrivals
//! (DESIGN.md §Serving front-end & overload control).
//!
//! The closed-loop serving bench (`serve_throughput`) can never
//! overload: it only admits a request when pipeline depth frees. This
//! bench drives the same scheduler **open-loop** from seeded
//! Poisson/bursty arrival processes on the virtual clock — at a
//! sustainable rate as the control, and at 8× the sustainable rate where
//! the bounded admission queue must shed. The invariants checked here
//! are the overload acceptance bar: every arrival resolves to exactly
//! one of completed/shed/expired, the queue never exceeds its cap, and
//! the slab arena comes home empty under any shedding pattern.
//!
//! Every config emits one JSON line (`{"bench":"serve_overload",...}`)
//! so the trajectory tracks shed/expired/completed and histogram tail
//! latency over time.

use fcdcc::bench_harness::{emit_json, env_usize, fast_mode};
use fcdcc::coordinator::{serve_lenet, ArrivalSpec, RequestOutcome, ServeConfig, ServeStats};
use fcdcc::engine::Im2colEngine;
use fcdcc::metrics::Table;
use fcdcc::util::json::JsonObj;
use std::sync::Arc;
use std::time::Duration;

fn json_line(name: &str, rate: f64, stats: &ServeStats) {
    let obj = JsonObj::new()
        .field_str("bench", "serve_overload")
        .field_str("workload", name)
        .field_f64("rate_rps", rate)
        .field_u64("threads", fcdcc::util::pool::global().threads() as u64)
        .field_str("kernel", stats.kernel)
        .field_str("code", stats.code)
        .field_u64("depth", stats.max_in_flight as u64)
        .field_u64("batch_window", stats.batch_window as u64)
        .field_u64("queue_cap", stats.queue_cap as u64)
        .field_u64("queue_peak", stats.peak_queue_depth as u64)
        .field_u64("arrivals", stats.arrivals as u64)
        .field_u64("completed", stats.completed_requests as u64)
        .field_u64("shed", stats.shed_requests as u64)
        .field_u64("expired", stats.expired_requests as u64)
        .field_f64("latency_p50_ms", stats.latency_hist.p50() * 1e3)
        .field_f64("latency_p99_ms", stats.latency_hist.p99() * 1e3)
        .field_u64("coded_jobs", stats.coded_jobs as u64)
        .field_u64("arena_outstanding", stats.arena_outstanding);
    emit_json(&obj.finish());
}

/// The overload invariants every config must satisfy, load or no load.
fn check_invariants(name: &str, stats: &ServeStats) {
    assert_eq!(stats.arrivals, stats.outcomes.len(), "{name}: arrival accounting");
    assert!(
        stats.outcomes.iter().all(Option::is_some),
        "{name}: every arrival must resolve to exactly one outcome"
    );
    assert_eq!(
        stats.completed_requests + stats.shed_requests + stats.expired_requests,
        stats.arrivals,
        "{name}: completed + shed + expired must cover every arrival"
    );
    assert_eq!(
        stats.completed_requests as u64,
        stats.latency_hist.count(),
        "{name}: the latency histogram covers completed requests only"
    );
    assert!(
        stats.peak_queue_depth <= stats.queue_cap,
        "{name}: queue peak {} exceeded cap {}",
        stats.peak_queue_depth,
        stats.queue_cap
    );
    assert_eq!(
        stats.arena_outstanding, 0,
        "{name}: slab arena must come home empty under shedding"
    );
    for (id, o) in stats.outcomes.iter().enumerate() {
        let has_logits = !stats.logits[id].is_empty();
        assert_eq!(
            *o == Some(RequestOutcome::Completed),
            has_logits,
            "{name}: request {id} logits must exist iff it completed"
        );
    }
}

fn main() {
    let requests = env_usize("FCDCC_BENCH_REQUESTS", if fast_mode() { 24 } else { 64 });
    // Two conv stages per request at the default virtual stage cost:
    // the sustainable rate is batch_window / (2 · stage_secs).
    let window = 2usize;
    let sustainable = {
        let spec = ArrivalSpec::poisson(1.0, 0);
        window as f64 / (2.0 * spec.stage_secs)
    };
    // (name, arrival spec, per-request deadline).
    let configs = [
        (
            "poisson-0.5x",
            ArrivalSpec::poisson(0.5 * sustainable, 11),
            None,
        ),
        (
            "poisson-8x",
            ArrivalSpec::poisson(8.0 * sustainable, 11),
            None,
        ),
        (
            "burst-8x-deadline",
            ArrivalSpec::burst(8.0 * sustainable, 8, 11),
            Some(Duration::from_millis(60)),
        ),
    ];

    let mut t = Table::new(
        &format!(
            "Open-loop overload: admission control + deadlines \
             (LeNet-5, n=4, {requests} arrivals, window {window}, queue cap 4, \
             sustainable {sustainable:.0} req/s)"
        ),
        &[
            "workload",
            "rate (req/s)",
            "completed",
            "shed",
            "expired",
            "queue peak",
            "p50 (ms)",
            "p99 (ms)",
        ],
    );
    for (name, spec, deadline) in configs {
        let rate = spec.rate;
        let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
        cfg.requests = requests;
        cfg.max_in_flight = 4;
        cfg.batch_window = window;
        cfg.verify_every = 0; // throughput run: no reference pass
        cfg.queue_cap = 4;
        cfg.request_deadline = deadline;
        cfg.arrival = Some(spec);
        let stats = serve_lenet(cfg).expect("serve");
        check_invariants(name, &stats);
        if rate > sustainable {
            assert!(
                stats.shed_requests > 0,
                "{name}: 8x overload with a 4-deep queue must shed"
            );
        }
        t.row(&[
            name.to_string(),
            format!("{rate:.0}"),
            stats.completed_requests.to_string(),
            stats.shed_requests.to_string(),
            stats.expired_requests.to_string(),
            format!("{}/{}", stats.peak_queue_depth, stats.queue_cap),
            format!("{:.2}", stats.latency_hist.p50() * 1e3),
            format!("{:.2}", stats.latency_hist.p99() * 1e3),
        ]);
        json_line(name, rate, &stats);
    }
    t.print();
    println!(
        "\nExpected: the 0.5x control completes nearly everything; at 8x the \
         bounded queue sheds with explicit Busy outcomes (and the deadline \
         config expires stale queue entries) while completed + shed + expired \
         covers every arrival and the slab arena comes home empty."
    );
}
