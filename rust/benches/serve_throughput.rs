//! Serving-throughput bench: sequential vs pipelined vs **batched**
//! distributed LeNet-5 serving over the concurrent job runtime.
//!
//! Sequential serving (depth 1) leaves the worker pool idle during every
//! master-side encode/decode phase and, worse, during straggler sleeps.
//! Pipelined serving keeps up to `depth` requests in flight, so the
//! straggler sleeps of one job overlap the useful compute of the others.
//! Batched serving additionally coalesces requests that reach the same
//! conv stage into one coded job (`batch_window` samples), amortizing
//! the per-job master costs — most importantly the recovery-matrix
//! inversion, which together with the inverse LRU cache drops the
//! inversion count well below one per request.
//!
//! Besides the human-readable table, every config emits **one JSON
//! line** (`{"bench":"serve_throughput",...}`) so the bench trajectory
//! (`BENCH_*.json`) can track requests/sec per mode over time.

use fcdcc::bench_harness::{emit_json, env_usize, fast_mode};
use fcdcc::cluster::StragglerModel;
use fcdcc::coordinator::{serve_lenet, ServeConfig, ServeStats};
use fcdcc::engine::Im2colEngine;
use fcdcc::metrics::Table;
use fcdcc::util::json::JsonObj;
use std::sync::Arc;
use std::time::Duration;

fn json_line(model: &str, mode: &str, stats: &ServeStats) {
    let obj = JsonObj::new()
        .field_str("bench", "serve_throughput")
        .field_str("straggler", model)
        .field_str("mode", mode)
        .field_u64("threads", fcdcc::util::pool::global().threads() as u64)
        .field_str("kernel", stats.kernel)
        .field_str("code", stats.code)
        .field_u64("pack_count", stats.pack_count)
        .field_u64("depth", stats.max_in_flight as u64)
        .field_u64("batch_window", stats.batch_window as u64)
        .field_u64("requests", stats.requests as u64)
        .field_f64("rps", stats.throughput_rps)
        .field_f64("latency_p50_ms", stats.latency.p50 * 1e3)
        .field_f64("latency_p95_ms", stats.latency.p95 * 1e3)
        .field_f64("latency_p99_ms", stats.latency.p99 * 1e3)
        .field_u64("completed", stats.completed_requests as u64)
        .field_u64("shed", stats.shed_requests as u64)
        .field_u64("expired", stats.expired_requests as u64)
        .field_u64("queue_peak", stats.peak_queue_depth as u64)
        .field_u64("coded_jobs", stats.coded_jobs as u64)
        .field_f64("mean_batch", stats.mean_batch)
        .field_u64("inversions", stats.inverse_cache.misses)
        .field_u64("inverse_cache_hits", stats.inverse_cache.hits)
        .field_u64("arena_allocs", stats.arena.misses)
        .field_u64("arena_hits", stats.arena.hits)
        .field_u64("encode_terms", stats.encode.terms)
        .field_u64("encode_dense_terms", stats.encode.dense_terms)
        .field_u64("failed_requests", stats.failed_requests as u64)
        .field_u64("retries", stats.retries as u64)
        .field_u64("degraded_requests", stats.degraded_requests as u64)
        .field_u64("quarantine_events", stats.quarantine_events);
    emit_json(&stats.membership.append_json(obj).finish());
}

fn main() {
    let requests = env_usize("FCDCC_BENCH_REQUESTS", if fast_mode() { 6 } else { 16 });
    let delay_ms = if fast_mode() { 25 } else { 50 };
    let delay = Duration::from_millis(delay_ms);
    // 3 of 4 workers delayed: conv1 (δ=2) must wait for at least one
    // straggler, so the delay sits on the sequential critical path.
    let models = [
        ("none", StragglerModel::None),
        ("fixed3", StragglerModel::FixedCount { count: 3, delay }),
    ];
    // (mode, in-flight depth, coalescing window).
    let configs = [
        ("sequential", 1usize, 1usize),
        ("pipelined", 4, 1),
        ("batched", 4, 4),
    ];

    let mut t = Table::new(
        &format!(
            "Serving throughput: sequential vs pipelined vs batched \
             (LeNet-5, n=4, {requests} requests, straggler delay {delay_ms}ms)"
        ),
        &[
            "straggler",
            "mode",
            "depth",
            "window",
            "req/s",
            "latency p50 (ms)",
            "latency p95 (ms)",
            "jobs",
            "mean batch",
            "inversions",
            "speedup vs seq",
        ],
    );
    for (name, model) in &models {
        let mut base_rps = 0.0;
        for (mode, depth, window) in configs {
            let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
            cfg.requests = requests;
            cfg.straggler = model.clone();
            cfg.max_in_flight = depth;
            cfg.batch_window = window;
            cfg.verify_every = 0; // throughput run: no reference pass
            let stats = serve_lenet(cfg).expect("serve");
            if depth == 1 && window == 1 {
                base_rps = stats.throughput_rps;
            }
            t.row(&[
                name.to_string(),
                mode.to_string(),
                depth.to_string(),
                window.to_string(),
                format!("{:.1}", stats.throughput_rps),
                format!("{:.2}", stats.latency.p50 * 1e3),
                format!("{:.2}", stats.latency.p95 * 1e3),
                stats.coded_jobs.to_string(),
                format!("{:.2}", stats.mean_batch),
                stats.inverse_cache.misses.to_string(),
                format!("{:.2}x", stats.throughput_rps / base_rps),
            ]);
            json_line(name, mode, &stats);
        }
    }
    t.print();
    println!(
        "\nExpected: pipelined beats sequential (straggler sleeps overlap \
         compute); batched additionally amortizes encode/inversion — fewer \
         coded jobs and far fewer inversions than requests."
    );
}
