//! Serving-throughput bench: sequential vs pipelined distributed
//! LeNet-5 serving over the concurrent job runtime.
//!
//! Sequential serving (depth 1) leaves the worker pool idle during every
//! master-side encode/decode phase and, worse, during straggler sleeps.
//! Pipelined serving keeps up to `depth` requests in flight, so while
//! request *i*'s conv2 job is collecting results, request *i+1*'s conv1
//! is already encoded and dispatched — the straggler sleeps of one job
//! overlap the useful compute of the others. Expectation: pipelined
//! serving beats depth 1 on req/s, most visibly under
//! `StragglerModel::FixedCount` where sequential serving eats the
//! injected delay on nearly every request.

use fcdcc::bench_harness::{env_usize, fast_mode};
use fcdcc::cluster::StragglerModel;
use fcdcc::coordinator::{serve_lenet, ServeConfig};
use fcdcc::engine::Im2colEngine;
use fcdcc::metrics::Table;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let requests = env_usize("FCDCC_BENCH_REQUESTS", if fast_mode() { 6 } else { 16 });
    let delay_ms = if fast_mode() { 25 } else { 50 };
    let delay = Duration::from_millis(delay_ms);
    // 3 of 4 workers delayed: conv1 (δ=2) must wait for at least one
    // straggler, so the delay sits on the sequential critical path.
    let models = [
        ("none".to_string(), StragglerModel::None),
        (
            format!("FixedCount(3, {delay_ms}ms)"),
            StragglerModel::FixedCount { count: 3, delay },
        ),
    ];

    let mut t = Table::new(
        &format!("Serving throughput: sequential vs pipelined (LeNet-5, n=4, {requests} requests)"),
        &[
            "straggler model",
            "depth",
            "req/s",
            "latency p50 (ms)",
            "latency p95 (ms)",
            "speedup vs depth 1",
        ],
    );
    for (name, model) in &models {
        let mut base_rps = 0.0;
        for depth in [1usize, 2, 4] {
            let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
            cfg.requests = requests;
            cfg.straggler = model.clone();
            cfg.max_in_flight = depth;
            cfg.verify_every = 0; // throughput run: no reference pass
            let stats = serve_lenet(cfg).expect("serve");
            if depth == 1 {
                base_rps = stats.throughput_rps;
            }
            t.row(&[
                name.clone(),
                depth.to_string(),
                format!("{:.1}", stats.throughput_rps),
                format!("{:.2}", stats.latency.p50 * 1e3),
                format!("{:.2}", stats.latency.p95 * 1e3),
                format!("{:.2}x", stats.throughput_rps / base_rps),
            ]);
        }
    }
    t.print();
    println!("\nExpected: pipelined depths beat depth 1, most under FixedCount stragglers.");
}
