//! Fig. 4 reproduction: recovery-matrix condition numbers (κ₂ via Jacobi
//! SVD) of the CDC schemes across the paper's (n, δ, γ) grid — the
//! numerical-stability core claim, independent of tensor contents.

use fcdcc::bench_harness::{env_usize, fast_mode};
use fcdcc::coordinator::stability::stability_sweep;
use fcdcc::metrics::{fmt_sci, Table};
use fcdcc::model::ConvLayer;

fn main() {
    let samples = if fast_mode() {
        2
    } else {
        env_usize("FCDCC_STABILITY_SAMPLES", 6)
    };
    let layer = ConvLayer::new("vgg.conv4/s", 16, 14, 14, 64, 3, 3, 1, 1);
    let configs = [(5usize, 4usize), (20, 16), (40, 32), (48, 32), (60, 32)];
    let pts = stability_sweep(&layer, &configs, samples, 2);

    let mut t = Table::new(
        "Fig. 4: recovery-matrix condition number by scheme and (n, delta, gamma)",
        &["(n,delta,gamma)", "scheme", "(kA,kB)", "cond median", "cond worst"],
    );
    for p in &pts {
        t.row(&[
            format!("({},{},{})", p.n, p.delta, p.gamma),
            p.scheme.to_string(),
            format!("({},{})", p.k_a, p.k_b),
            fmt_sci(p.cond_median),
            fmt_sci(p.cond_worst),
        ]);
    }
    t.print();
    println!("\nExpected shape (paper): CRME condition stays polynomial (lowest);");
    println!("real Vandermonde grows exponentially with delta; Fahim-Cadambe");
    println!("degrades as gamma grows.");
}
