//! Fig. 4 reproduction: recovery-matrix condition numbers (κ₂ via Jacobi
//! SVD) of the CDC schemes across the paper's (n, δ, γ) grid — the
//! numerical-stability core claim, independent of tensor contents. The
//! sweep now covers the full code registry, so the banded convolutional
//! and weight-w sparse families get condition-number records next to
//! CRME and the polynomial rivals. Every point also emits one JSON line
//! (`{"bench":"fig4_cond",...}`) for the bench trajectory.

use fcdcc::bench_harness::{emit_json, env_usize, fast_mode};
use fcdcc::coordinator::stability::stability_sweep;
use fcdcc::metrics::{fmt_sci, Table};
use fcdcc::model::ConvLayer;

fn main() {
    let samples = if fast_mode() {
        2
    } else {
        env_usize("FCDCC_STABILITY_SAMPLES", 6)
    };
    let layer = ConvLayer::new("vgg.conv4/s", 16, 14, 14, 64, 3, 3, 1, 1);
    let configs = [(5usize, 4usize), (20, 16), (40, 32), (48, 32), (60, 32)];
    let pts = stability_sweep(&layer, &configs, samples, 2);

    let mut t = Table::new(
        "Fig. 4: recovery-matrix condition number by scheme and (n, delta, gamma)",
        &["(n,delta,gamma)", "scheme", "(kA,kB)", "cond median", "cond worst"],
    );
    for p in &pts {
        t.row(&[
            format!("({},{},{})", p.n, p.delta, p.gamma),
            p.scheme.to_string(),
            format!("({},{})", p.k_a, p.k_b),
            fmt_sci(p.cond_median),
            fmt_sci(p.cond_worst),
        ]);
        emit_json(&format!(
            "{{\"bench\":\"fig4_cond\",\"scheme\":\"{}\",\"code\":\"{}\",\
             \"n\":{},\"delta\":{},\"gamma\":{},\"k_a\":{},\"k_b\":{},\
             \"cond_median\":{:.6e},\"cond_worst\":{:.6e},\
             \"threads\":{},\"kernel\":\"{}\"}}",
            p.scheme,
            p.code,
            p.n,
            p.delta,
            p.gamma,
            p.k_a,
            p.k_b,
            p.cond_median,
            p.cond_worst,
            fcdcc::util::pool::global().threads(),
            fcdcc::linalg::kernel::active().name(),
        ));
    }
    t.print();
    println!("\nExpected shape (paper): CRME condition stays polynomial (lowest);");
    println!("real Vandermonde grows exponentially with delta; Fahim-Cadambe");
    println!("degrades as gamma grows. The conv/sparse families sit between:");
    println!("validated at construction to a bounded condition proxy.");
}
