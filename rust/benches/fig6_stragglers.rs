//! Fig. 6 reproduction: robustness under diverse straggler conditions —
//! average virtual computation time with n = 32, δ = 24, γ = 8, varying
//! the straggler count 0..12 at two delay levels (the paper's 1s/2s
//! sleeps, scaled to 100ms/200ms for the testbed). Expectation: flat up
//! to γ = 8 stragglers, then a jump by the injected delay.

use fcdcc::bench_harness::fast_mode;
use fcdcc::cluster::sim::simulate_job;
use fcdcc::cluster::StragglerModel;
use fcdcc::coordinator::stability::factor_pair;
use fcdcc::engine::Im2colEngine;
use fcdcc::fcdcc::FcdccPlan;
use fcdcc::metrics::Table;
use fcdcc::model::zoo;
use fcdcc::tensor::{Tensor3, Tensor4};
use fcdcc::util::rng::Rng;
use std::time::Duration;

fn main() {
    let (n, delta) = (32usize, 24usize);
    let delays_ms: [u64; 2] = [100, 200];
    let straggler_counts: Vec<usize> = if fast_mode() {
        vec![0, 4, 8, 10]
    } else {
        (0..=12).collect()
    };
    let trials = if fast_mode() { 1 } else { 3 };

    // AlexNet conv3 geometry, channel-scaled.
    let layer = zoo::alexnet()[2].scaled_channels(4);
    let (ka, kb) = factor_pair(4 * delta, layer.n, layer.h_out(), true).expect("factor");
    let plan = FcdccPlan::new_crme(&layer, ka, kb, n).expect("plan");
    let mut rng = Rng::new(66);
    let x = Tensor3::random(layer.c, layer.h, layer.w, &mut rng);
    let k = Tensor4::random(layer.n, layer.c, layer.kh, layer.kw, &mut rng);
    let cf = plan.encode_filters(&k);
    let engine = Im2colEngine;

    let mut t = Table::new(
        &format!(
            "Fig. 6: avg virtual time vs straggler count — {} (n={n}, delta={delta}, gamma={}, kA={ka}, kB={kb})",
            layer.name,
            n - delta
        ),
        &["stragglers", "avg time @100ms (ms)", "avg time @200ms (ms)", "within gamma?"],
    );

    for &s in &straggler_counts {
        let mut cols = Vec::new();
        for &d in &delays_ms {
            let model = if s == 0 {
                StragglerModel::None
            } else {
                StragglerModel::FixedCount {
                    count: s,
                    delay: Duration::from_millis(d),
                }
            };
            let mut acc = 0.0;
            for _ in 0..trials {
                let fates = model.draw(n, &mut rng);
                let job = simulate_job(&plan, &x, &cf, &engine, &fates).expect("sim");
                acc += job.total_secs();
            }
            cols.push(format!("{:.1}", acc / trials as f64 * 1e3));
        }
        t.row(&[
            s.to_string(),
            cols[0].clone(),
            cols[1].clone(),
            if s <= n - delta { "yes" } else { "no" }.to_string(),
        ]);
    }
    t.print();
    println!("\nExpected shape (paper): flat until gamma = {} stragglers, then a", n - delta);
    println!("jump by the injected delay (and proportional to it beyond).");
}
