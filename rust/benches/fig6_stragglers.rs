//! Fig. 6 reproduction: robustness under diverse straggler conditions —
//! average virtual computation time with n = 32, δ = 24, γ = 8, varying
//! the straggler count 0..12 at two delay levels (the paper's 1s/2s
//! sleeps, scaled to 100ms/200ms for the testbed). Expectation: flat up
//! to γ = 8 stragglers, then a jump by the injected delay.
//!
//! Extended with a **fault-model sweep**: end-to-end pipelined serving
//! under each injected fault kind (crash / error / corrupt / slow)
//! against one worker, emitting per-model completion-rate and
//! retry-count JSON records — the chaos leg's machine-readable
//! acceptance signal (completion_rate must be 1.0 under every
//! single-worker fault).

use fcdcc::bench_harness::{emit_json, fast_mode};
use fcdcc::cluster::sim::simulate_job;
use fcdcc::cluster::{FaultKind, FaultPlan, StragglerModel};
use fcdcc::coordinator::stability::factor_pair;
use fcdcc::coordinator::ServeConfig;
use fcdcc::engine::Im2colEngine;
use fcdcc::fcdcc::FcdccPlan;
use fcdcc::metrics::{MembershipCounters, Table};
use fcdcc::model::zoo;
use fcdcc::tensor::{Tensor3, Tensor4};
use fcdcc::util::json::JsonObj;
use fcdcc::util::rng::Rng;
use std::sync::Arc;
use std::time::Duration;

fn straggler_sweep() {
    let (n, delta) = (32usize, 24usize);
    let delays_ms: [u64; 2] = [100, 200];
    let straggler_counts: Vec<usize> = if fast_mode() {
        vec![0, 4, 8, 10]
    } else {
        (0..=12).collect()
    };
    let trials = if fast_mode() { 1 } else { 3 };

    // AlexNet conv3 geometry, channel-scaled.
    let layer = zoo::alexnet()[2].scaled_channels(4);
    let (ka, kb) = factor_pair(4 * delta, layer.n, layer.h_out(), true).expect("factor");
    let plan = FcdccPlan::new_crme(&layer, ka, kb, n).expect("plan");
    let mut rng = Rng::new(66);
    let x = Tensor3::random(layer.c, layer.h, layer.w, &mut rng);
    let k = Tensor4::random(layer.n, layer.c, layer.kh, layer.kw, &mut rng);
    let cf = plan.encode_filters(&k);
    let engine = Im2colEngine;

    let mut t = Table::new(
        &format!(
            "Fig. 6: avg virtual time vs straggler count — {} (n={n}, delta={delta}, gamma={}, kA={ka}, kB={kb})",
            layer.name,
            n - delta
        ),
        &["stragglers", "avg time @100ms (ms)", "avg time @200ms (ms)", "within gamma?"],
    );

    for &s in &straggler_counts {
        let mut cols = Vec::new();
        for &d in &delays_ms {
            let model = if s == 0 {
                StragglerModel::None
            } else {
                StragglerModel::FixedCount {
                    count: s,
                    delay: Duration::from_millis(d),
                }
            };
            let mut acc = 0.0;
            for _ in 0..trials {
                let fates = model.draw(n, &mut rng);
                let job = simulate_job(&plan, &x, &cf, &engine, &fates).expect("sim");
                acc += job.total_secs();
            }
            cols.push(format!("{:.1}", acc / trials as f64 * 1e3));
        }
        // Within γ the coded job always completes without retries: the
        // simulated first-δ collection is the whole story. The JSON
        // record carries that explicitly so downstream tooling reads a
        // uniform schema across this sweep and the fault sweep below.
        // The membership block keeps the schema uniform with the serving
        // benches; the simulated sweep has no transport, so it is all
        // zeros here.
        let obj = JsonObj::new()
            .field_str("bench", "fig6_stragglers")
            .field_u64("stragglers", s as u64)
            .field_f64("avg_ms_100", cols[0].parse().unwrap_or(f64::NAN))
            .field_f64("avg_ms_200", cols[1].parse().unwrap_or(f64::NAN))
            .field_bool("within_gamma", s <= n - delta)
            .field_f64("completion_rate", 1.0)
            .field_u64("retries", 0);
        emit_json(&MembershipCounters::default().append_json(obj).finish());
        t.row(&[
            s.to_string(),
            cols[0].clone(),
            cols[1].clone(),
            if s <= n - delta { "yes" } else { "no" }.to_string(),
        ]);
    }
    t.print();
    println!("\nExpected shape (paper): flat until gamma = {} stragglers, then a", n - delta);
    println!("jump by the injected delay (and proportional to it beyond).");
}

/// End-to-end fault sweep: pipelined LeNet serving with one worker under
/// each injected fault kind. Every model must complete 100% of its
/// requests — by redundancy, retry, or degraded fallback — which is the
/// row-level invariant the chaos CI leg checks.
fn fault_sweep() {
    let requests = if fast_mode() { 4 } else { 8 };
    let models: Vec<(&str, FaultPlan)> = vec![
        ("none", FaultPlan::none()),
        (
            "crash",
            FaultPlan::none().with_fault(
                1,
                FaultKind::Crash {
                    after: 0,
                    restart_after: None,
                },
            ),
        ),
        (
            "crash-restart",
            FaultPlan::none().with_fault(
                1,
                FaultKind::Crash {
                    after: 0,
                    restart_after: Some(4),
                },
            ),
        ),
        ("error", FaultPlan::none().with_fault(1, FaultKind::ErrorReply { jobs: 3 })),
        ("corrupt", FaultPlan::none().with_fault(1, FaultKind::CorruptReply { jobs: 3 })),
        (
            "slow",
            FaultPlan::none().with_fault(
                1,
                FaultKind::Slow {
                    delay: Duration::from_millis(if fast_mode() { 10 } else { 40 }),
                },
            ),
        ),
    ];

    let mut t = Table::new(
        "Fault-model sweep: pipelined serving under single-worker faults",
        &["fault", "completed", "retries", "degraded", "quarantines", "mse ok?"],
    );
    for (name, fault_plan) in models {
        let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
        cfg.requests = requests;
        cfg.max_in_flight = 2;
        cfg.collect_timeout = Duration::from_millis(500);
        cfg.fault_plan = fault_plan;
        let stats = fcdcc::coordinator::serve_lenet(cfg).expect("serve");
        let done = stats.requests - stats.failed_requests;
        let completion_rate = done as f64 / stats.requests as f64;
        let mse_ok = stats.class_mismatches == 0 && stats.mean_logit_mse < 1e-12;
        let obj = JsonObj::new()
            .field_str("bench", "fig6_faults")
            .field_str("model", name)
            .field_u64("requests", stats.requests as u64)
            .field_f64("completion_rate", completion_rate)
            .field_u64("retries", stats.retries as u64)
            .field_u64("degraded_requests", stats.degraded_requests as u64)
            .field_u64("failed_requests", stats.failed_requests as u64)
            .field_u64("quarantine_events", stats.quarantine_events)
            .field_u64("readmissions", stats.readmissions)
            .field_u64("arena_outstanding", stats.arena_outstanding)
            .field_bool("mse_ok", mse_ok);
        emit_json(&stats.membership.append_json(obj).finish());
        assert_eq!(
            stats.failed_requests, 0,
            "fault model {name:?} hard-failed requests"
        );
        assert_eq!(
            stats.arena_outstanding, 0,
            "fault model {name:?} leaked arena buffers"
        );
        t.row(&[
            name.to_string(),
            format!("{done}/{}", stats.requests),
            stats.retries.to_string(),
            stats.degraded_requests.to_string(),
            stats.quarantine_events.to_string(),
            if mse_ok { "yes" } else { "NO" }.to_string(),
        ]);
    }
    t.print();
    println!(
        "\nExpected: every row completes all requests (completion_rate 1.0) —\n\
         redundancy absorbs the fault, or retry / degraded fallback covers it."
    );
}

fn main() {
    straggler_sweep();
    fault_sweep();
}
