//! Table III reproduction: FCDCC vs the naive single-node scheme across
//! the ConvLs of LeNet-5, AlexNet and VGGNet — computation time, MSE and
//! master-side decode overhead.
//!
//! Testbed scaling (DESIGN.md §Hardware adaptation): the paper uses 18
//! t2.micro workers; we use n = 18 *virtual* workers (cluster::sim) on
//! one vCPU — per-worker compute is measured in isolation and the
//! parallel makespan reconstructed analytically. AlexNet/VGG channel and
//! spatial dims are scaled down (flagged in the layer name) so the whole
//! table regenerates in minutes; the comparison *shape* (who wins, by
//! roughly what factor; negligible MSE; sub-% decode overhead) is the
//! reproduction target, not absolute seconds.

use fcdcc::bench_harness::{env_usize, fast_mode};
use fcdcc::cluster::sim::simulate_job;
use fcdcc::cluster::straggler::WorkerFate;
use fcdcc::coordinator::stability::factor_pair;
use fcdcc::engine::Im2colEngine;
use fcdcc::fcdcc::FcdccPlan;
use fcdcc::metrics::{fmt_sci, Table};
use fcdcc::model::{zoo, ConvLayer};
use fcdcc::tensor::{im2col::conv2d_im2col, Tensor3, Tensor4};
use fcdcc::util::{mse, rng::Rng};
use std::time::Instant;

/// Pick the largest feasible recovery threshold δ ≤ target for a layer
/// (LeNet's small channel counts cannot reach the paper's δ=16).
fn plan_for(layer: &ConvLayer, n: usize, delta_target: usize) -> Option<(FcdccPlan, usize)> {
    let mut delta = delta_target.min(n);
    while delta >= 1 {
        if let Ok((ka, kb)) = factor_pair(4 * delta, layer.n, layer.h_out(), true) {
            if let Ok(plan) = FcdccPlan::new_crme(layer, ka, kb, n) {
                return Some((plan, delta));
            }
        }
        delta -= 1;
    }
    None
}

fn main() {
    let n = env_usize("FCDCC_TABLE3_N", 18);
    let delta_target = env_usize("FCDCC_TABLE3_DELTA", 16);
    let trials = if fast_mode() { 1 } else { 3 };

    let mut models: Vec<(&str, Vec<ConvLayer>)> = vec![("LeNet-5", zoo::lenet5())];
    let alex: Vec<ConvLayer> = zoo::alexnet()
        .iter()
        .map(|l| l.scaled_channels(2))
        .collect();
    models.push(("AlexNet (channels/2)", alex));
    let vgg: Vec<ConvLayer> = zoo::vggnet()
        .iter()
        .map(|l| l.scaled_spatial(2).scaled_channels(2))
        .collect();
    models.push(("VGGNet (spatial/2, channels/2)", vgg));

    let mut rng = Rng::new(2024);
    let engine = Im2colEngine;

    let mut table = Table::new(
        &format!("Table III: FCDCC (n={n}) vs naive single node"),
        &[
            "model", "layer", "(kA,kB)", "delta", "naive (s)", "FCDCC (s)", "speedup",
            "MSE", "decode (ms)",
        ],
    );

    for (model, layers) in &models {
        for layer in layers {
            let x = Tensor3::random(layer.c, layer.h, layer.w, &mut rng);
            let k = Tensor4::random(layer.n, layer.c, layer.kh, layer.kw, &mut rng);

            // Naive single-node reference (measured).
            let mut naive_secs = f64::INFINITY;
            let mut want = None;
            for _ in 0..trials {
                let t0 = Instant::now();
                let y = conv2d_im2col(&x, &k, layer.params());
                naive_secs = naive_secs.min(t0.elapsed().as_secs_f64());
                want = Some(y);
            }
            let want = want.unwrap();

            let Some((plan, delta)) = plan_for(layer, n, delta_target) else {
                eprintln!("skip {}: no feasible plan", layer.name);
                continue;
            };
            let spec = plan.spec();
            let coded_filters = plan.encode_filters(&k);
            let fates = vec![WorkerFate::Prompt; n];
            let mut best_total = f64::INFINITY;
            let mut job_mse = 0.0;
            let mut decode_ms = 0.0;
            for _ in 0..trials {
                let job = simulate_job(&plan, &x, &coded_filters, &engine, &fates)
                    .expect("sim job");
                if job.total_secs() < best_total {
                    best_total = job.total_secs();
                    decode_ms = job.decode_secs * 1e3;
                    job_mse = mse(&job.output.data, &want.data);
                }
            }
            table.row(&[
                model.to_string(),
                layer.name.clone(),
                format!("({},{})", spec.k_a, spec.k_b),
                delta.to_string(),
                format!("{naive_secs:.4}"),
                format!("{best_total:.4}"),
                format!("{:.1}x", naive_secs / best_total),
                fmt_sci(job_mse),
                format!("{decode_ms:.3}"),
            ]);
        }
    }
    table.print();
    println!("\n(virtual-parallel makespan; see DESIGN.md §Hardware adaptation)");
}
