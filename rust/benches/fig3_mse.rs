//! Fig. 3 reproduction: decode MSE of numerically-stable CDC schemes on
//! the VGG Conv4 geometry across the paper's (n, δ, γ) grid. The layer
//! runs at reduced channel/spatial scale (the code matrices — the object
//! under test — are exactly the paper's sizes; the tensors only average
//! the error).

use fcdcc::bench_harness::{env_usize, fast_mode};
use fcdcc::coordinator::stability::stability_sweep;
use fcdcc::metrics::{fmt_sci, Table};
use fcdcc::model::ConvLayer;

fn main() {
    let samples = if fast_mode() {
        2
    } else {
        env_usize("FCDCC_STABILITY_SAMPLES", 6)
    };
    // VGG conv4 structure at reduced scale: C 256→16, N 512→64, 28→14.
    let layer = ConvLayer::new("vgg.conv4/s", 16, 14, 14, 64, 3, 3, 1, 1);
    let configs = [(5usize, 4usize), (20, 16), (40, 32), (48, 32), (60, 32)];
    let pts = stability_sweep(&layer, &configs, samples, 1);

    let mut t = Table::new(
        "Fig. 3: decode MSE by scheme and (n, delta, gamma) — VGG Conv4 geometry",
        &["(n,delta,gamma)", "scheme", "(kA,kB)", "MSE mean", "MSE worst"],
    );
    for p in &pts {
        t.row(&[
            format!("({},{},{})", p.n, p.delta, p.gamma),
            p.scheme.to_string(),
            format!("({},{})", p.k_a, p.k_b),
            fmt_sci(p.mse_mean),
            fmt_sci(p.mse_worst),
        ]);
    }
    t.print();
    println!("\nExpected shape (paper): CRME lowest everywhere; real polynomial");
    println!("unstable by (40,32,8); Fahim-Cadambe degrades at (60,32,28).");
}
