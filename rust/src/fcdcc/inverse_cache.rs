//! LRU cache of recovery-matrix inverses, keyed by `(stage_idx, ordered
//! surviving-worker subset)`.
//!
//! Under pipelined serving the same few δ-subsets recur job after job
//! (the cluster orders a job's chosen replies by worker id before
//! decoding, so the key is the *sorted* subset), and re-running the
//! `O(δ³)` LU inversion per job dominates the decode hot path. One cache
//! is shared across all conv stages of a `NetworkPlan` — `stage_idx`
//! disambiguates stages whose codes differ — and every decode either
//! hits (reuses the `Arc<Mat>`) or misses (inverts once, inserts). The
//! hit/miss counters are the serving-layer's inversion accounting:
//! `misses()` is exactly the number of recovery-matrix inversions
//! performed through the cache.

use crate::linalg::Mat;
use crate::metrics::CacheStats;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Default capacity: comfortably above the distinct δ-subsets a small
/// cluster can produce per stage (e.g. C(4,2)=6 per stage), so steady
/// serving never thrashes.
pub const DEFAULT_INVERSE_CACHE_CAP: usize = 64;

type Key = (usize, Vec<usize>);

struct CacheState {
    map: HashMap<Key, Arc<Mat>>,
    /// Recency order, least-recently-used first.
    order: Vec<Key>,
}

/// A shared, thread-safe LRU cache of recovery-matrix inverses.
pub struct InverseCache {
    capacity: usize,
    state: Mutex<CacheState>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl InverseCache {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "inverse cache needs capacity >= 1");
        Self {
            capacity,
            state: Mutex::new(CacheState {
                map: HashMap::new(),
                order: Vec::new(),
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Fetch the inverse for `(stage, workers)`, computing and inserting
    /// it via `invert` on a miss. `workers` is the ordered subset the
    /// decode will run with — callers that want cross-job reuse must
    /// order replies canonically (the cluster sorts by worker id).
    pub fn get_or_insert_with(
        &self,
        stage: usize,
        workers: &[usize],
        invert: impl FnOnce() -> Result<Mat>,
    ) -> Result<Arc<Mat>> {
        {
            let mut st = self.state.lock().expect("inverse cache poisoned");
            // Borrow-friendly lookup: find first, then touch recency.
            let key = (stage, workers.to_vec());
            if let Some(found) = st.map.get(&key).cloned() {
                if let Some(pos) = st.order.iter().position(|k| *k == key) {
                    let k = st.order.remove(pos);
                    st.order.push(k);
                }
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok(found);
            }
        }
        // Invert outside the lock: an O(δ³) LU under a mutex would
        // serialize concurrent decoders. Two racing misses on the same
        // key both invert (identical result), last insert wins.
        let inv = Arc::new(invert()?);
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut st = self.state.lock().expect("inverse cache poisoned");
        let key = (stage, workers.to_vec());
        if !st.map.contains_key(&key) {
            while st.map.len() >= self.capacity {
                let evict = st.order.remove(0);
                st.map.remove(&evict);
            }
            st.map.insert(key.clone(), Arc::clone(&inv));
            st.order.push(key);
        }
        Ok(inv)
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Misses == recovery-matrix inversions performed through the cache.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits(),
            misses: self.misses(),
        }
    }

    pub fn len(&self) -> usize {
        self.state.lock().expect("inverse cache poisoned").map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(v: f64) -> Mat {
        Mat::from_vec(1, 1, vec![v])
    }

    #[test]
    fn hit_and_miss_accounting() {
        let c = InverseCache::new(4);
        let a = c.get_or_insert_with(0, &[0, 1], || Ok(mat(1.0))).unwrap();
        assert_eq!(c.misses(), 1);
        let b = c.get_or_insert_with(0, &[0, 1], || panic!("must hit")).unwrap();
        assert_eq!(c.hits(), 1);
        assert!(Arc::ptr_eq(&a, &b));
        // Different stage or subset is a different key.
        c.get_or_insert_with(1, &[0, 1], || Ok(mat(2.0))).unwrap();
        c.get_or_insert_with(0, &[0, 2], || Ok(mat(3.0))).unwrap();
        assert_eq!(c.misses(), 3);
        assert_eq!(c.len(), 3);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let c = InverseCache::new(2);
        c.get_or_insert_with(0, &[0], || Ok(mat(1.0))).unwrap();
        c.get_or_insert_with(0, &[1], || Ok(mat(2.0))).unwrap();
        // Touch [0] so [1] becomes the LRU entry.
        c.get_or_insert_with(0, &[0], || panic!("must hit")).unwrap();
        c.get_or_insert_with(0, &[2], || Ok(mat(3.0))).unwrap(); // evicts [1]
        assert_eq!(c.len(), 2);
        let mut reinverted = false;
        c.get_or_insert_with(0, &[1], || {
            reinverted = true;
            Ok(mat(2.0))
        })
        .unwrap();
        assert!(reinverted, "evicted entry must be recomputed");
        // Re-inserting [1] evicted [0]; [2] is still resident.
        let before = c.hits();
        c.get_or_insert_with(0, &[2], || panic!("must hit")).unwrap();
        assert_eq!(c.hits(), before + 1);
    }

    #[test]
    fn failed_inversion_is_not_cached() {
        let c = InverseCache::new(2);
        assert!(c
            .get_or_insert_with(0, &[0], || anyhow::bail!("singular"))
            .is_err());
        assert_eq!(c.len(), 0);
        assert_eq!(c.misses(), 0);
        assert!(c.is_empty());
        c.get_or_insert_with(0, &[0], || Ok(mat(1.0))).unwrap();
        assert_eq!(c.misses(), 1);
    }
}
