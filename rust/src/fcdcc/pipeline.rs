//! The end-to-end FCDCC pipeline for a single convolutional layer:
//!
//! 1. APCP-partition the (padded) input, KCCP-partition the filters;
//! 2. CRME-encode both partition lists (paper Algs. 2 & 3);
//! 3. hand each worker its coded input slabs + ℓ_B coded filter slabs
//!    (a [`WorkerPayload`]);
//! 4. each worker convolves every (slabA, slabB) pair — any black-box
//!    conv implementation works — returning a [`WorkerResult`];
//! 5. once any δ results arrived, invert the recovery matrix and merge
//!    (paper Alg. 5).
//!
//! One payload carries a **batch** of samples: the coding is linear, so
//! the master-side fixed costs — most importantly the recovery-matrix
//! inversion in step 5 — are paid once per job and amortized over every
//! sample in it. A batch-1 job is exactly the paper's single-inference
//! pipeline.
//!
//! The encode/decode hot path is **fused slab algebra** (DESIGN.md
//! §Hot-path memory layout): [`FcdccPlan::encode_input_batch`] streams
//! rows of the *unpadded* inputs straight into per-worker sample-major
//! slab buffers (padding and APCP overlap are index arithmetic — no
//! padded intermediate, no partition copies);
//! [`FcdccPlan::decode_batch_refs`] runs one packed GEMM per sample
//! against a pooled staging buffer instead of a per-block zeros+axpy
//! sweep. Every hot stage fans out over the persistent compute pool
//! (`util::pool`, DESIGN.md §Deterministic parallel runtime) with fixed
//! problem-shaped chunks: encode per coded worker, decode per sample,
//! the im2col worker engine per input slab. All of them are
//! bit-identical to the scalar reference implementations
//! (`encode_input` per sample / `coding::decode_outputs` +
//! `merge_output_blocks`) at any pool size — the references stay as the
//! correctness oracles.
//!
//! The pipeline is transport-agnostic: the `cluster` module runs payloads
//! on simulated workers; tests run them inline.

use crate::coding::{self, Code, CrmeCode, EncodeProgram};
use crate::fcdcc::inverse_cache::{InverseCache, DEFAULT_INVERSE_CACHE_CAP};
use crate::fcdcc::scratch::{SlabArena, DEFAULT_ARENA_CAP};
use crate::linalg::gemm::{self, PackedA};
use crate::linalg::Mat;
use crate::model::ConvLayer;
use crate::partition::{merge_output_rows, ApcpPlan, KccpPlan};
use crate::tensor::im2col::{
    conv2d_from_patch_multi_prepacked, conv2d_from_patch_multi_with, im2col_into,
};
use crate::tensor::{conv2d, conv2d_shape, ConvParams, Tensor3, Tensor4};
use crate::util::pool;
use anyhow::{ensure, Context, Result};
use std::sync::Arc;

thread_local! {
    /// Per-thread im2col patch buffer for `WorkerPayload::run_im2col`:
    /// every participant of the slab fan-out reuses one allocation
    /// across chunks (and across payloads — pool threads are
    /// long-lived). Taken/put with `Cell` so a hypothetical reentrant
    /// use sees an empty buffer instead of a borrow panic.
    static PATCH_BUF: std::cell::Cell<Vec<f64>> = const { std::cell::Cell::new(Vec::new()) };
}

/// One worker's **plan-resident** coded filters: the ℓ_B coded slabs
/// (paper: filters are encoded once at model load) plus, when
/// prepacking is on, each slab's GEMM-ready packed-A operand
/// (`linalg::gemm::PackedA`), packed once at plan build. Jobs share both
/// by `Arc`, so the steady-state worker conv path never runs `pack_a` —
/// the packed bytes are backend-agnostic, and the contraction over them
/// is bit-identical to packing per call.
#[derive(Clone)]
pub struct ResidentFilters {
    /// ℓ_B coded filter slabs (the V_store payload).
    pub slabs: Arc<Vec<Tensor4>>,
    /// Per-slab prepacked GEMM operands; `None` when the plan was built
    /// with prepacking disabled (`--no-prepack`).
    pub packs: Option<Arc<Vec<PackedA>>>,
}

impl ResidentFilters {
    /// Wrap one worker's coded slabs, packing each into the microkernel
    /// layout when `prepack` is set.
    pub fn new(slabs: Vec<Tensor4>, prepack: bool) -> Self {
        let packs = prepack.then(|| {
            Arc::new(
                slabs
                    .iter()
                    .map(|kb| {
                        let rows = kb.c * kb.kh * kb.kw;
                        PackedA::pack(
                            &gemm::RowMajor {
                                data: &kb.data,
                                ld: rows.max(1),
                            },
                            kb.n,
                            rows,
                        )
                    })
                    .collect::<Vec<_>>(),
            )
        });
        ResidentFilters {
            slabs: Arc::new(slabs),
            packs,
        }
    }

    /// Tensor entries resident on the worker (coded slabs only — the
    /// V_store accounting; packed panels are a local layout copy, not
    /// extra communicated state).
    pub fn store_entries(&self) -> usize {
        self.slabs.iter().map(|t| t.len()).sum()
    }

    /// Packed-panel elements held alongside the slabs (zero-padding
    /// included; 0 when prepacking is off).
    pub fn packed_entries(&self) -> usize {
        self.packs
            .as_ref()
            .map_or(0, |ps| ps.iter().map(PackedA::packed_len).sum())
    }
}

/// Everything worker `worker_id` needs for one coded subtask.
#[derive(Clone)]
pub struct WorkerPayload {
    pub worker_id: usize,
    /// `batch · ℓ_A` coded input slabs, sample-major: slab `j` of sample
    /// `s` is `inputs[s·ℓ_A + j]`. Slab buffers are drawn from the
    /// plan's arena and returned via [`Self::recycle`].
    pub inputs: Vec<Tensor3>,
    /// Samples in this job (1 = the paper's single-inference pipeline).
    pub batch: usize,
    /// ℓ_B coded filter slabs. Pre-distributed in steady state (paper:
    /// filters are encoded once at model load), so every job sharing the
    /// resident slabs clones an `Arc`, never the tensors themselves.
    pub filters: Arc<Vec<Tensor4>>,
    /// The resident slabs' prepacked GEMM operands (shared with
    /// [`ResidentFilters::packs`]); `None` falls back to per-call
    /// packing (counted in the arena's `filter_packs`).
    pub packs: Option<Arc<Vec<PackedA>>>,
    /// Convolution parameters for the slab-level conv (stride s, pad 0 —
    /// APCP already materialized the padding).
    pub conv: ConvParams,
    /// The plan's slab arena: input slabs return here on recycle, and
    /// the im2col path draws its output-block buffers from it.
    pub arena: Arc<SlabArena>,
}

impl WorkerPayload {
    /// Tensor entries uploaded to the worker per inference (coded input
    /// slabs only; filters are resident) — the V_comm_up accounting.
    pub fn upload_entries(&self) -> usize {
        self.inputs.iter().map(|t| t.len()).sum()
    }

    /// Tensor entries resident on the worker (coded filter slabs) —
    /// the V_store accounting.
    pub fn store_entries(&self) -> usize {
        self.filters.iter().map(|t| t.len()).sum()
    }

    /// Coded input slabs per sample (ℓ_A).
    pub fn ell_a(&self) -> usize {
        debug_assert_eq!(self.inputs.len() % self.batch, 0);
        self.inputs.len() / self.batch
    }

    /// Execute the subtask with the reference conv (paper eq. (39):
    /// all ℓ_A·ℓ_B pairwise convolutions once per sample, sample-major ×
    /// slabA-major order).
    pub fn run_local(&self) -> WorkerResult {
        self.run_with(|x, k, p| conv2d(x, k, p))
    }

    /// Execute with a custom conv engine. Iterating the sample-major
    /// input slabs in order yields the `batch · ℓ_A · ℓ_B` output blocks
    /// in the order the decoder expects: sample-major, slabA-major
    /// within a sample.
    pub fn run_with(
        &self,
        conv: impl Fn(&Tensor3, &Tensor4, ConvParams) -> Tensor3,
    ) -> WorkerResult {
        let mut blocks = Vec::with_capacity(self.inputs.len() * self.filters.len());
        for xa in &self.inputs {
            for kb in self.filters.iter() {
                blocks.push(conv(xa, kb, self.conv));
            }
        }
        WorkerResult {
            worker_id: self.worker_id,
            batch: self.batch,
            blocks,
            arena: Arc::clone(&self.arena),
        }
    }

    /// Return the payload's input-slab buffers to the plan arena. Call
    /// once the subtask (or its cancellation) is finished with the
    /// payload — dropping instead merely leaks pooled reuse, never
    /// correctness.
    pub fn recycle(self) {
        let arena = self.arena;
        for t in self.inputs {
            arena.put(t.data);
        }
    }

    /// Execute with the fused im2col path — the optimized default for
    /// cluster workers (`Im2colEngine`). The im2col patch matrix of each
    /// coded input slab is built **once** and reused across all ℓ_B
    /// filter-slab GEMMs (a per-pair `conv2d_im2col` rebuilds it ℓ_B
    /// times). The `batch·ℓ_A` input slabs fan out over the persistent
    /// compute pool, one slab per chunk: each chunk builds its slab's
    /// patch matrix and writes that slab's ℓ_B output blocks — a
    /// disjoint, contiguous region of the block list — through exactly
    /// the serial per-pair arithmetic. Bit-identical to
    /// `run_with(conv2d_im2col)` at any pool size: same patch fill, same
    /// GEMM, same block order. When the payload carries resident
    /// prepacked filters, the filter operand of every GEMM is the
    /// plan-packed panel — the same bytes per-call packing would
    /// produce, so the result stays bit-identical while the steady
    /// state performs **zero** `pack_a` calls and zero block
    /// allocations (buffers come from the plan arena).
    pub fn run_im2col(&self) -> WorkerResult {
        let Some(first) = self.filters.first() else {
            return WorkerResult {
                worker_id: self.worker_id,
                batch: self.batch,
                blocks: Vec::new(),
                arena: Arc::clone(&self.arena),
            };
        };
        let ell_b = self.filters.len();
        for kb in self.filters.iter() {
            assert_eq!(
                (kb.kh, kb.kw, kb.c),
                (first.kh, first.kw, first.c),
                "run_im2col: filter slab shape mismatch"
            );
        }
        let packs = self.packs.as_deref().map(|ps| {
            assert_eq!(ps.len(), ell_b, "run_im2col: pack/slab count mismatch");
            ps.as_slice()
        });
        let filter_refs: Vec<&Tensor4> = self.filters.iter().collect();
        let mut blocks: Vec<Option<Tensor3>> =
            (0..self.inputs.len() * ell_b).map(|_| None).collect();
        // Total coded output entries gate the dispatch.
        let work = self.inputs.first().map_or(0, |x0| {
            let (oh, ow) = conv2d_shape(x0.h, x0.w, first.kh, first.kw, self.conv);
            self.inputs.len() * ell_b * first.n * oh * ow
        });
        pool::global().parallel_chunks_mut(work, &mut blocks, ell_b, |slab_idx, out| {
            let xa = &self.inputs[slab_idx];
            // Keep conv2d_im2col's release-mode shape check: a channel
            // mismatch would silently misalign the GEMM's filter rows.
            assert_eq!(xa.c, first.c, "run_im2col: channel mismatch");
            let (oh, ow) = conv2d_shape(xa.h, xa.w, first.kh, first.kw, self.conv);
            // Patch buffer reuse across chunks: pool threads are
            // long-lived, so each participant keeps one im2col buffer —
            // at pool size 1 this is exactly PR 3's single reused
            // allocation, and im2col_into overwrites every element, so
            // reuse is bit-invisible. The ℓ_B GEMMs then share one
            // packing of the patch operand; with resident packs the
            // filter operand is never packed at all
            // (conv2d_from_patch_multi_prepacked), otherwise each slab
            // pays ℓ_B per-call packs, counted in the arena. Output
            // blocks draw their buffers from the plan arena either way.
            PATCH_BUF.with(|cell| {
                let mut patch = cell.take();
                let (rows, cols) = im2col_into(xa, first.kh, first.kw, self.conv, &mut patch);
                let ys = match packs {
                    Some(ps) => conv2d_from_patch_multi_prepacked(
                        &patch,
                        rows,
                        cols,
                        ps,
                        oh,
                        ow,
                        |len| self.arena.take(len),
                    ),
                    None => {
                        self.arena.note_filter_packs(ell_b as u64);
                        conv2d_from_patch_multi_with(
                            &patch,
                            rows,
                            cols,
                            &filter_refs,
                            oh,
                            ow,
                            |len| self.arena.take(len),
                        )
                    }
                };
                for (slot, y) in out.iter_mut().zip(ys) {
                    *slot = Some(y);
                }
                cell.set(patch);
            });
        });
        WorkerResult {
            worker_id: self.worker_id,
            batch: self.batch,
            blocks: blocks
                .into_iter()
                .map(|b| b.expect("every slab chunk ran"))
                .collect(),
            arena: Arc::clone(&self.arena),
        }
    }
}

/// A worker's coded output blocks: `batch · ℓ_A·ℓ_B` of them,
/// sample-major (slabA-major within each sample).
#[derive(Clone)]
pub struct WorkerResult {
    pub worker_id: usize,
    /// Samples in the job this result belongs to.
    pub batch: usize,
    pub blocks: Vec<Tensor3>,
    /// The arena the block buffers came from (and return to on
    /// recycle). Carried by the result so late/stale replies can be
    /// recycled wherever they surface — the demux loop has no plan.
    pub arena: Arc<SlabArena>,
}

impl WorkerResult {
    /// Tensor entries downloaded from the worker — V_comm_down accounting.
    pub fn download_entries(&self) -> usize {
        self.blocks.iter().map(|t| t.len()).sum()
    }

    /// The ℓ_A·ℓ_B coded output blocks of one sample.
    pub fn sample_blocks(&self, sample: usize) -> &[Tensor3] {
        let bpw = self.blocks.len() / self.batch;
        &self.blocks[sample * bpw..(sample + 1) * bpw]
    }

    /// Return the block buffers to the plan arena (after decode, or for
    /// replies that arrive past δ / past a deadline and are dropped).
    pub fn recycle(self) {
        let arena = self.arena;
        for t in self.blocks {
            arena.put(t.data);
        }
    }
}

/// A fully-planned FCDCC execution for one layer: geometry + code, plus
/// the recovery-inverse cache consulted on every decode.
pub struct FcdccPlan {
    pub layer: ConvLayer,
    pub apcp: ApcpPlan,
    pub kccp: KccpPlan,
    pub code: Arc<dyn Code>,
    /// `mat_a`'s sparsity, compiled once at plan build: per coded slab
    /// column, the ascending-ordered `(partition, coef)` nonzeros. The
    /// fused batch encoder iterates this instead of scanning all k_A
    /// coefficients per column (see `coding::EncodeProgram`).
    program_a: EncodeProgram,
    /// `mat_b`'s compiled sparsity, driving the filter encode.
    program_b: EncodeProgram,
    /// Recovery-inverse cache. Standalone plans own a private one;
    /// `NetworkPlan` shares a single cache across all of its stages.
    inverse_cache: Arc<InverseCache>,
    /// This plan's stage index within the shared cache's key space.
    cache_stage: usize,
    /// The plan's slab arena (see `fcdcc::scratch`): encoded input
    /// slabs, worker reply blocks, and decode staging all draw from and
    /// return to it. Standalone plans own a private one; `NetworkPlan`
    /// shares one across stages.
    arena: Arc<SlabArena>,
    /// Pack coded filter slabs into resident GEMM operands at encode
    /// time (on by default; `--no-prepack` / `FCDCC_NO_PREPACK` turn it
    /// off for A/B measurement).
    prepack: bool,
}

impl FcdccPlan {
    /// Plan a layer with the paper's CRME code.
    pub fn new_crme(layer: &ConvLayer, k_a: usize, k_b: usize, n: usize) -> Result<Self> {
        let code: Arc<dyn Code> = Arc::new(
            CrmeCode::new(k_a, k_b, n)
                .with_context(|| format!("planning {} with CRME", layer.name))?,
        );
        Self::with_code(layer, code)
    }

    /// Plan a layer with an arbitrary scheme (rival codes in the benches).
    pub fn with_code(layer: &ConvLayer, code: Arc<dyn Code>) -> Result<Self> {
        let s = code.spec();
        let h_padded = layer.h + 2 * layer.pad;
        let apcp = ApcpPlan::new(h_padded, layer.kh, layer.stride, s.k_a)
            .with_context(|| format!("APCP plan for {}", layer.name))?;
        let kccp = KccpPlan::new(layer.n, s.k_b)
            .with_context(|| format!("KCCP plan for {}", layer.name))?;
        let program_a = EncodeProgram::compile(code.mat_a());
        let program_b = EncodeProgram::compile(code.mat_b());
        Ok(Self {
            layer: layer.clone(),
            apcp,
            kccp,
            code,
            program_a,
            program_b,
            inverse_cache: Arc::new(InverseCache::new(DEFAULT_INVERSE_CACHE_CAP)),
            cache_stage: 0,
            arena: Arc::new(SlabArena::new(DEFAULT_ARENA_CAP)),
            prepack: true,
        })
    }

    /// Attach a shared recovery-inverse cache: decodes key their
    /// inversions as `(stage_idx, worker subset)` in `cache`.
    pub fn with_inverse_cache(mut self, cache: Arc<InverseCache>, stage_idx: usize) -> Self {
        self.inverse_cache = cache;
        self.cache_stage = stage_idx;
        self
    }

    /// The recovery-inverse cache this plan decodes through.
    pub fn inverse_cache(&self) -> &Arc<InverseCache> {
        &self.inverse_cache
    }

    /// Attach a shared slab arena (one per `NetworkPlan`, shared by
    /// every stage).
    pub fn with_arena(mut self, arena: Arc<SlabArena>) -> Self {
        self.arena = arena;
        self
    }

    /// The slab arena this plan's hot path draws from.
    pub fn arena(&self) -> &Arc<SlabArena> {
        &self.arena
    }

    /// Enable/disable resident filter prepacking for subsequently
    /// encoded filters (on by default).
    pub fn with_prepack(mut self, prepack: bool) -> Self {
        self.prepack = prepack;
        self
    }

    /// Whether [`Self::encode_filters`] packs resident GEMM operands.
    pub fn prepack(&self) -> bool {
        self.prepack
    }

    pub fn spec(&self) -> coding::CodeSpec {
        self.code.spec()
    }

    /// The compiled input-side encode program (`mat_a`'s sparsity).
    pub fn encode_program_a(&self) -> &EncodeProgram {
        &self.program_a
    }

    /// The compiled filter-side encode program (`mat_b`'s sparsity).
    pub fn encode_program_b(&self) -> &EncodeProgram {
        &self.program_b
    }

    /// Recovery threshold δ.
    pub fn delta(&self) -> usize {
        self.spec().delta()
    }

    /// Encode the filter bank once (model initialization): per-worker
    /// resident coded filter slabs, `Arc`-shared so that every subsequent
    /// job reuses them without deep-cloning — and, unless prepacking is
    /// disabled, each slab's packed GEMM operand, so steady-state jobs
    /// never pack the filter side again.
    ///
    /// The combine iterates the compiled `mat_b` program — only the
    /// nonzero coefficients, in the ascending-partition order of the
    /// reference `coding::encode_filters`, hence bit-identical slabs.
    pub fn encode_filters(&self, k: &Tensor4) -> Vec<ResidentFilters> {
        let parts = self.kccp.partition(k);
        let s = self.spec();
        (0..s.n)
            .map(|i| {
                let slabs: Vec<Tensor4> = (0..s.ell_b)
                    .map(|j| self.program_b.combine4(i * s.ell_b + j, &parts))
                    .collect();
                ResidentFilters::new(slabs, self.prepack)
            })
            .collect()
    }

    /// Encode one input tensor (per inference): per-worker coded slabs.
    /// `x` is the **unpadded** input; spatial padding is applied here.
    ///
    /// This is the **reference** chain (pad → APCP partition → per-slab
    /// axpy combine), kept as the correctness oracle for the fused
    /// [`Self::encode_input_batch`] — the property suite asserts the two
    /// are bit-identical.
    pub fn encode_input(&self, x: &Tensor3) -> Vec<Vec<Tensor3>> {
        let xp = x.pad_spatial(self.layer.pad);
        let parts = self.apcp.partition(&xp);
        coding::encode_inputs(self.code.as_ref(), &parts)
    }

    /// Encode a batch of input tensors into per-worker **sample-major**
    /// coded slab lists: worker `i` receives `batch·ℓ_A` slabs, sample
    /// `s`'s slab `j` at index `s·ℓ_A + j`.
    ///
    /// Fused single-pass encoder: rows of the *unpadded* inputs stream
    /// directly into preallocated per-worker slab buffers. Spatial
    /// padding, APCP's overlapping-slab geometry, and the bottom
    /// height-padding are all index arithmetic — no padded intermediate
    /// tensor, no k_A partition copies, no per-slab axpy sweeps. The
    /// coded slab buffers themselves come from the plan's slab arena
    /// (ownership transfers into the workers' payloads and returns on
    /// `WorkerPayload::recycle`), so steady-state encodes allocate
    /// nothing at all. The fill fans
    /// out over the persistent compute pool (`util::pool`), one coded
    /// worker per chunk — chunk boundaries depend only on n, and every
    /// element is written through the identical per-element fold
    /// (coefficients in ascending-partition order, zero coefficients
    /// skipped — the exact order of `coding::encode_inputs`), so the
    /// result is bit-identical to the reference path at any pool size.
    ///
    /// The per-slab coefficient walk iterates the plan's compiled
    /// **encode program** (`mat_a`'s nonzeros, compiled at plan build)
    /// instead of scanning all k_A coefficients per column: the skipped
    /// zeros are exactly the ones the dense scan's `coef == 0.0` test
    /// skipped, so the fold — and hence the output — is unchanged bit
    /// for bit while the work becomes nnz-proportional (the encode-pass
    /// counters on the plan arena record both sides of that ledger).
    pub fn encode_input_batch(&self, xs: &[&Tensor3]) -> Vec<Vec<Tensor3>> {
        self.note_encode_pass(xs.len(), self.program_a.nnz());
        self.encode_input_batch_inner(xs, EncodeScan::Program)
    }

    /// Dense-scan baseline of [`Self::encode_input_batch`]: identical
    /// output (the dense loop tests `coef == 0.0` per column, which
    /// skips exactly the entries the program dropped at compile time),
    /// but visits all `k_A · cols` coefficient slots. Kept callable for
    /// the `sparse_program_vs_dense_scan` A/B bench and the bit-equality
    /// suite — serving always takes the program path.
    pub fn encode_input_batch_dense(&self, xs: &[&Tensor3]) -> Vec<Vec<Tensor3>> {
        let dense = self.program_a.dense_terms();
        self.note_encode_pass(xs.len(), dense);
        self.encode_input_batch_inner(xs, EncodeScan::Dense)
    }

    /// One encode-pass ledger bump, computed analytically: `batch·ℓ_A·n`
    /// coded columns, `terms` coefficient visits actually performed,
    /// against the `k_A·cols` slots a dense scan walks.
    fn note_encode_pass(&self, batch: usize, terms_per_sample: usize) {
        let s = self.spec();
        let cols = (batch * s.ell_a * s.n) as u64;
        self.arena.note_encode(
            cols,
            (batch * terms_per_sample) as u64,
            (batch * self.program_a.dense_terms()) as u64,
        );
    }

    fn encode_input_batch_inner(&self, xs: &[&Tensor3], scan: EncodeScan) -> Vec<Vec<Tensor3>> {
        let s = self.spec();
        for x in xs {
            assert_eq!(
                (x.c, x.h, x.w),
                (self.layer.c, self.layer.h, self.layer.w),
                "encode_input_batch: sample shape does not match layer {}",
                self.layer.name
            );
        }
        let pad = self.layer.pad;
        let wp = self.layer.w + 2 * pad;
        let apcp = self.apcp;
        let ell_a = s.ell_a;
        let mut per_worker: Vec<Vec<Tensor3>> = (0..s.n)
            .map(|_| Vec::with_capacity(xs.len() * ell_a))
            .collect();
        // Total coded output entries — the pool's dispatch gate keeps
        // LeNet-sized encodes inline on the caller.
        let work = xs.len() * ell_a * self.layer.c * apcp.h_hat * wp * s.n;
        let arena = &self.arena;
        let a = self.code.mat_a();
        let program = &self.program_a;
        pool::global().parallel_chunks_mut(work, &mut per_worker, 1, |worker, slabs| {
            match scan {
                EncodeScan::Program => fill_worker_slabs(
                    worker, &mut slabs[0], xs, program, &apcp, pad, ell_a, wp, arena,
                ),
                EncodeScan::Dense => fill_worker_slabs_dense(
                    worker, &mut slabs[0], xs, a, &apcp, pad, ell_a, wp, arena,
                ),
            }
        });
        per_worker
    }

    /// Bundle payloads for all n workers. The resident coded filter slabs
    /// are shared by reference (`Arc`), not copied per job. The batch
    /// size is inferred from the slab count (`batch·ℓ_A` slabs per
    /// worker), so single-sample callers are unchanged.
    pub fn make_payloads(
        &self,
        coded_inputs: Vec<Vec<Tensor3>>,
        coded_filters: &[ResidentFilters],
    ) -> Vec<WorkerPayload> {
        let conv = ConvParams::new(self.layer.stride, 0);
        let ell_a = self.spec().ell_a;
        coded_inputs
            .into_iter()
            .zip(coded_filters)
            .enumerate()
            .map(|(worker_id, (inputs, rf))| {
                debug_assert_eq!(inputs.len() % ell_a, 0);
                WorkerPayload {
                    worker_id,
                    batch: inputs.len() / ell_a,
                    inputs,
                    filters: Arc::clone(&rf.slabs),
                    packs: rf.packs.clone(),
                    conv,
                    arena: Arc::clone(&self.arena),
                }
            })
            .collect()
    }

    /// Decode any δ worker results and merge into the layer output
    /// (N × H' × W').
    pub fn decode(&self, results: &[WorkerResult]) -> Result<Tensor3> {
        let refs: Vec<&WorkerResult> = results.iter().collect();
        self.decode_refs(&refs)
    }

    /// Zero-copy variant of [`Self::decode`] (the batch-1 hot path).
    pub fn decode_refs(&self, results: &[&WorkerResult]) -> Result<Tensor3> {
        let mut outputs = self.decode_batch_refs(results)?;
        ensure!(
            outputs.len() == 1,
            "decode: job carries a batch of {}, use decode_batch_refs",
            outputs.len()
        );
        Ok(outputs.pop().expect("one decoded sample"))
    }

    /// Decode a **batched** job from any δ worker results: one recovery
    /// matrix inversion (LRU-cached across jobs, keyed by the ordered
    /// worker subset) reused for every sample, then one packed GEMM per
    /// sample, fanned out across samples on the compute pool — each
    /// sample's δ·ℓ_A·ℓ_B coded blocks are the rows of a matrix Ỹ and
    /// the true blocks are `Y = Dᵀ·Ỹ` ([`Mat::gemm_t_rows_into`]),
    /// accumulated into that sample's disjoint region of a staging
    /// buffer drawn from the plan's slab arena and merged straight
    /// into the layer output. The per-element summation order matches
    /// the scalar reference (`coding::decode_outputs_with` +
    /// `merge_output_blocks`) exactly, so outputs are bit-identical to
    /// it — and per-sample arithmetic is identical to the batch-1
    /// decode, so batched outputs are bit-identical to per-request
    /// decoding from the same worker subset, at any pool size. Returns
    /// the layer outputs in batch order.
    pub fn decode_batch_refs(&self, results: &[&WorkerResult]) -> Result<Vec<Tensor3>> {
        ensure!(
            results.len() >= self.delta(),
            "decode: need delta={} results, got {}",
            self.delta(),
            results.len()
        );
        let chosen = &results[..self.delta()];
        let batch = chosen[0].batch;
        ensure!(batch >= 1, "decode: empty batch");
        for r in chosen {
            ensure!(
                r.batch == batch,
                "decode: worker {} reports batch {}, expected {batch}",
                r.worker_id,
                r.batch
            );
        }
        let workers: Vec<usize> = chosen.iter().map(|r| r.worker_id).collect();
        let d = self
            .inverse_cache
            .get_or_insert_with(self.cache_stage, &workers, || {
                coding::recovery_inverse(self.code.as_ref(), &workers)
            })?;
        let s = self.spec();
        let bpw = s.blocks_per_worker();
        for r in chosen {
            ensure!(
                r.blocks.len() == batch * bpw,
                "decode: worker {} sent {} blocks, expected {}·{bpw}",
                r.worker_id,
                r.blocks.len(),
                batch
            );
        }
        let (c_b, h_b, w_b) = chosen[0].blocks[0].shape();
        let block_len = c_b * h_b * w_b;
        let kab = s.k_a * s.k_b;
        ensure!(
            d.rows == s.delta() * bpw && d.is_square(),
            "recovery inverse has shape {}x{}, expected {2}x{2}",
            d.rows,
            d.cols,
            s.delta() * bpw
        );
        // Validate every block up front, before drawing the staging
        // buffer: an error past `take` would drop the buffer instead of
        // returning it, leaking the pooled allocation.
        for r in chosen {
            for blk in &r.blocks {
                ensure!(
                    blk.shape() == (c_b, h_b, w_b),
                    "decode: worker {} sent a block of shape {:?}, expected {:?}",
                    r.worker_id,
                    blk.shape(),
                    (c_b, h_b, w_b)
                );
            }
        }
        // One pooled staging buffer for the whole batch (a single
        // take/put per decode), split into fixed per-sample regions so
        // samples decode in parallel on the compute pool: chunk
        // boundaries depend only on the batch geometry, each sample's
        // GEMM + merge is the identical serial arithmetic, and each
        // writes a disjoint staging region and output slot — so batched
        // decode stays bit-identical to per-sample decode at any pool
        // size.
        let sample_len = kab * block_len;
        let delta_bpw = s.delta() * bpw;
        let (k_a, k_b) = (s.k_a, s.k_b);
        let h_out = self.layer.h_out();
        // One row table for the whole batch, built once up front (pure
        // pointer pushes — the single decode-path allocation besides the
        // pooled staging buffer): sample `s`'s coded rows live at
        // `all_rows[s·δ·bpw .. (s+1)·δ·bpw]`, in the reference order.
        let mut all_rows: Vec<&[f64]> = Vec::with_capacity(batch * delta_bpw);
        for sample in 0..batch {
            for r in chosen {
                for blk in r.sample_blocks(sample) {
                    all_rows.push(blk.data.as_slice());
                }
            }
        }
        let mut staging = self.arena.take(batch * sample_len);
        let mut outputs: Vec<Option<Tensor3>> = (0..batch).map(|_| None).collect();
        pool::global().parallel_zip_chunks_mut(
            // Total decoded entries gate the dispatch (tiny decodes on
            // the latency path stay inline).
            batch * sample_len,
            &mut staging,
            sample_len,
            &mut outputs,
            1,
            |sample, stage_buf, out_slot| {
                let rows = &all_rows[sample * delta_bpw..(sample + 1) * delta_bpw];
                d.gemm_t_rows_into(rows, stage_buf, block_len);
                out_slot[0] = Some(merge_output_rows(
                    stage_buf, k_a, k_b, c_b, h_b, w_b, h_out,
                ));
            },
        );
        self.arena.put(staging);
        Ok(outputs
            .into_iter()
            .map(|y| y.expect("every sample chunk ran"))
            .collect())
    }

    /// Run the whole pipeline inline (no cluster): encode, compute every
    /// worker locally, decode from the given worker subset (defaults to
    /// the first δ). The correctness backbone for tests and MSE benches.
    pub fn run_inline(
        &self,
        x: &Tensor3,
        k: &Tensor4,
        survivors: Option<&[usize]>,
    ) -> Result<Tensor3> {
        let mut ys = self.run_inline_batch(&[x], k, survivors)?;
        Ok(ys.pop().expect("one sample"))
    }

    /// Batched counterpart of [`Self::run_inline`]: encode the whole
    /// batch into one coded job, compute every chosen worker's subtask
    /// locally, decode with a single recovery inversion. Returns one
    /// output per sample, in batch order.
    pub fn run_inline_batch(
        &self,
        xs: &[&Tensor3],
        k: &Tensor4,
        survivors: Option<&[usize]>,
    ) -> Result<Vec<Tensor3>> {
        let coded_filters = self.encode_filters(k);
        let coded_inputs = self.encode_input_batch(xs);
        let payloads = self.make_payloads(coded_inputs, &coded_filters);
        // Borrow the survivor subset instead of copying it; the default
        // first-δ range is materialized locally only when needed.
        let first_delta: Vec<usize>;
        let ids: &[usize] = match survivors {
            Some(s) => s,
            None => {
                first_delta = (0..self.delta()).collect();
                &first_delta
            }
        };
        let results: Vec<WorkerResult> = ids.iter().map(|&i| payloads[i].run_local()).collect();
        let refs: Vec<&WorkerResult> = results.iter().collect();
        let outputs = self.decode_batch_refs(&refs);
        drop(refs);
        // Inline jobs recycle like the cluster runtime: coded slabs and
        // output blocks return to the plan arena, so repeated inline
        // runs go allocation-free after the first.
        for r in results {
            r.recycle();
        }
        for p in payloads {
            p.recycle();
        }
        outputs
    }
}

/// Which coefficient walk [`FcdccPlan::encode_input_batch_inner`] runs:
/// the compiled program (serving default) or the dense all-k_A scan
/// (the A/B baseline). Both produce bit-identical slabs.
#[derive(Clone, Copy)]
enum EncodeScan {
    Program,
    Dense,
}

/// Fill one worker's `batch·ℓ_A` coded slabs in a single pass over the
/// unpadded inputs — the per-worker unit of the fused batch encoder.
///
/// Worker `worker`'s slab `j` of a sample is `Σ_α A(α, worker·ℓ_A + j) ·
/// X'_α`, where `X'_α` covers *padded* rows `[α·Ŝ, α·Ŝ + Ĥ)`. The
/// padded row `pr` maps to unpadded row `pr − pad` when that is in
/// `[0, H)`; every other row (top padding, bottom padding, APCP bottom
/// extension) is zero and contributes nothing, so the slab buffer starts
/// zeroed and only real input rows are streamed in, into destination
/// columns `[pad, pad + W)`. Per element, the column's compiled program
/// terms accumulate in ascending-α order — the program holds exactly
/// the coefficients the reference `coding::encode_inputs` would not
/// have skipped as zero, in the same order, hence bit-identical output
/// from nnz-proportional work. The per-row combination runs on the
/// runtime-dispatched SIMD axpy (`linalg::kernel::axpy`) —
/// lane-parallel across the row, per element the same mul-then-add
/// sequence, so dispatch cannot change the fold.
#[allow(clippy::too_many_arguments)]
fn fill_worker_slabs(
    worker: usize,
    slabs: &mut Vec<Tensor3>,
    xs: &[&Tensor3],
    program: &EncodeProgram,
    apcp: &ApcpPlan,
    pad: usize,
    ell_a: usize,
    wp: usize,
    arena: &SlabArena,
) {
    // Resolve the dispatched backend once per fill, not once per row —
    // rows are only W doubles wide, so the per-row cost must stay at
    // one (predictable) match.
    let kind = crate::linalg::kernel::active();
    for x in xs {
        for j in 0..ell_a {
            let col = worker * ell_a + j;
            // The slab buffer is a zeroed arena draw (same contents as
            // `Tensor3::zeros`): steady-state encodes recycle the very
            // buffers earlier jobs returned.
            let mut slab =
                Tensor3::from_vec(x.c, apcp.h_hat, wp, arena.take(x.c * apcp.h_hat * wp));
            for &(alpha, coef) in program.col(col) {
                let pr_base = alpha * apcp.s_hat;
                for c in 0..x.c {
                    for r in 0..apcp.h_hat {
                        let pr = pr_base + r;
                        if pr < pad {
                            continue;
                        }
                        let ur = pr - pad;
                        if ur >= x.h {
                            break; // rows below are padding too
                        }
                        let src = x.row(c, ur);
                        let dst = &mut slab.row_mut(c, r)[pad..pad + x.w];
                        crate::linalg::kernel::axpy_kind(kind, coef, src, dst);
                    }
                }
            }
            slabs.push(slab);
        }
    }
}

/// The pre-program dense fill: scan all k_A coefficients per column,
/// testing each for zero. Retained verbatim as the A/B baseline behind
/// [`FcdccPlan::encode_input_batch_dense`]; the zero test skips exactly
/// the entries [`EncodeProgram::compile`] dropped, so this and
/// [`fill_worker_slabs`] write identical bytes.
#[allow(clippy::too_many_arguments)]
fn fill_worker_slabs_dense(
    worker: usize,
    slabs: &mut Vec<Tensor3>,
    xs: &[&Tensor3],
    a: &Mat,
    apcp: &ApcpPlan,
    pad: usize,
    ell_a: usize,
    wp: usize,
    arena: &SlabArena,
) {
    let kind = crate::linalg::kernel::active();
    for x in xs {
        for j in 0..ell_a {
            let col = worker * ell_a + j;
            let mut slab =
                Tensor3::from_vec(x.c, apcp.h_hat, wp, arena.take(x.c * apcp.h_hat * wp));
            for alpha in 0..apcp.k_a {
                let coef = a.get(alpha, col);
                if coef == 0.0 {
                    continue;
                }
                let pr_base = alpha * apcp.s_hat;
                for c in 0..x.c {
                    for r in 0..apcp.h_hat {
                        let pr = pr_base + r;
                        if pr < pad {
                            continue;
                        }
                        let ur = pr - pad;
                        if ur >= x.h {
                            break; // rows below are padding too
                        }
                        let src = x.row(c, ur);
                        let dst = &mut slab.row_mut(c, r)[pad..pad + x.w];
                        crate::linalg::kernel::axpy_kind(kind, coef, src, dst);
                    }
                }
            }
            slabs.push(slab);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::vandermonde::{PointSet, VandermondeCode};
    use crate::util::{mse, rng::Rng};

    fn reference(layer: &ConvLayer, x: &Tensor3, k: &Tensor4) -> Tensor3 {
        conv2d(x, k, layer.params())
    }

    #[test]
    fn crme_pipeline_exact_over_configs() {
        let mut rng = Rng::new(51);
        // (layer, k_a, k_b, n)
        let cases = [
            (ConvLayer::new("t1", 2, 12, 10, 8, 3, 3, 1, 0), 4, 2, 4),
            (ConvLayer::new("t2", 3, 11, 9, 6, 3, 3, 1, 1), 2, 6, 5),
            (ConvLayer::new("t3", 1, 28, 28, 6, 5, 5, 1, 2), 4, 2, 3),
            (ConvLayer::new("t4", 2, 23, 17, 4, 5, 5, 4, 0), 2, 4, 4),
            (ConvLayer::new("t5", 2, 9, 9, 4, 3, 3, 2, 1), 1, 4, 4),
            (ConvLayer::new("t6", 2, 10, 8, 5, 3, 3, 1, 0), 4, 1, 3),
        ];
        for (layer, k_a, k_b, n) in cases {
            let x = Tensor3::random(layer.c, layer.h, layer.w, &mut rng);
            let k = Tensor4::random(layer.n, layer.c, layer.kh, layer.kw, &mut rng);
            let plan = FcdccPlan::new_crme(&layer, k_a, k_b, n).unwrap();
            let want = reference(&layer, &x, &k);
            let got = plan.run_inline(&x, &k, None).unwrap();
            assert_eq!(got.shape(), want.shape(), "{}", layer.name);
            let e = mse(&got.data, &want.data);
            assert!(e < 1e-20, "{}: mse={e:e}", layer.name);
        }
    }

    #[test]
    fn decoding_works_from_any_subset() {
        let mut rng = Rng::new(52);
        let layer = ConvLayer::new("t", 2, 12, 10, 8, 3, 3, 1, 0);
        let x = Tensor3::random(2, 12, 10, &mut rng);
        let k = Tensor4::random(8, 2, 3, 3, &mut rng);
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 5).unwrap(); // delta=2, n=5
        let want = reference(&layer, &x, &k);
        for a in 0..5 {
            for b in 0..5 {
                if a == b {
                    continue;
                }
                let got = plan.run_inline(&x, &k, Some(&[a, b])).unwrap();
                let e = mse(&got.data, &want.data);
                assert!(e < 1e-18, "subset [{a},{b}]: mse={e:e}");
            }
        }
    }

    #[test]
    fn vandermonde_pipeline_also_exact_small() {
        // The rival codes plug into the same pipeline (Fig. 3 machinery).
        let mut rng = Rng::new(53);
        let layer = ConvLayer::new("t", 2, 10, 10, 6, 3, 3, 1, 0);
        let x = Tensor3::random(2, 10, 10, &mut rng);
        let k = Tensor4::random(6, 2, 3, 3, &mut rng);
        let code = Arc::new(VandermondeCode::new(2, 3, 8, PointSet::Equispaced).unwrap());
        let plan = FcdccPlan::with_code(&layer, code).unwrap(); // delta=6
        let want = reference(&layer, &x, &k);
        let got = plan.run_inline(&x, &k, Some(&[0, 2, 3, 5, 6, 7])).unwrap();
        let e = mse(&got.data, &want.data);
        assert!(e < 1e-12, "mse={e:e}");
    }

    #[test]
    fn insufficient_results_rejected() {
        let layer = ConvLayer::new("t", 1, 8, 8, 4, 3, 3, 1, 0);
        let plan = FcdccPlan::new_crme(&layer, 2, 2, 3).unwrap(); // delta=1
        let r: Vec<WorkerResult> = vec![];
        assert!(plan.decode(&r).is_err());
    }

    #[test]
    fn batched_job_bit_identical_to_per_sample_decode() {
        let mut rng = Rng::new(57);
        let layer = ConvLayer::new("t", 2, 12, 10, 8, 3, 3, 1, 0);
        let k = Tensor4::random(8, 2, 3, 3, &mut rng);
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 5).unwrap(); // delta=2
        let survivors = [3usize, 1];
        for batch in 1..=4usize {
            let xs: Vec<Tensor3> =
                (0..batch).map(|_| Tensor3::random(2, 12, 10, &mut rng)).collect();
            let refs: Vec<&Tensor3> = xs.iter().collect();
            let got = plan.run_inline_batch(&refs, &k, Some(&survivors)).unwrap();
            assert_eq!(got.len(), batch);
            for (x, y) in xs.iter().zip(&got) {
                let want = plan.run_inline(x, &k, Some(&survivors)).unwrap();
                assert_eq!(y.data, want.data, "batched decode diverged bitwise");
            }
        }
        // All 10 decodes above share one worker subset: the recovery
        // matrix was inverted exactly once, everything else hit the LRU.
        assert_eq!(plan.inverse_cache().misses(), 1);
        assert!(plan.inverse_cache().hits() >= 4 + 9);
    }

    #[test]
    fn mismatched_batch_sizes_rejected() {
        let layer = ConvLayer::new("t", 2, 12, 10, 8, 3, 3, 1, 0);
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap(); // delta=2
        let mut rng = Rng::new(58);
        let x = Tensor3::random(2, 12, 10, &mut rng);
        let k = Tensor4::random(8, 2, 3, 3, &mut rng);
        let cf = plan.encode_filters(&k);
        let single = plan.make_payloads(plan.encode_input(&x), &cf);
        let double = plan.make_payloads(plan.encode_input_batch(&[&x, &x]), &cf);
        assert_eq!(single[0].batch, 1);
        assert_eq!(double[0].batch, 2);
        let results = vec![single[0].run_local(), double[1].run_local()];
        assert!(plan.decode(&results).is_err(), "mixed batch sizes must fail");
    }

    #[test]
    fn fused_batch_encoder_bit_identical_to_reference() {
        // Includes a stride-2 layer with APCP bottom padding and a
        // padded layer, so every index-arithmetic branch is exercised.
        let mut rng = Rng::new(61);
        let cases = [
            (ConvLayer::new("t1", 2, 12, 10, 8, 3, 3, 1, 0), 4, 2, 5),
            (ConvLayer::new("t2", 3, 11, 9, 6, 3, 3, 1, 1), 2, 6, 5),
            (ConvLayer::new("t3", 2, 23, 17, 4, 5, 5, 4, 0), 2, 4, 4),
            (ConvLayer::new("t4", 1, 10, 8, 5, 3, 3, 1, 2), 4, 1, 3),
        ];
        for (layer, k_a, k_b, n) in cases {
            let plan = FcdccPlan::new_crme(&layer, k_a, k_b, n).unwrap();
            for batch in 1..=3usize {
                let xs: Vec<Tensor3> = (0..batch)
                    .map(|_| Tensor3::random(layer.c, layer.h, layer.w, &mut rng))
                    .collect();
                let refs: Vec<&Tensor3> = xs.iter().collect();
                let fused = plan.encode_input_batch(&refs);
                // Reference: per-sample pad → partition → axpy chain,
                // interleaved sample-major exactly like the fused path.
                let mut want: Vec<Vec<Tensor3>> = (0..n).map(|_| Vec::new()).collect();
                for x in &xs {
                    for (w, slabs) in plan.encode_input(x).into_iter().enumerate() {
                        want[w].extend(slabs);
                    }
                }
                assert_eq!(fused.len(), want.len());
                for (w, (f, r)) in fused.iter().zip(&want).enumerate() {
                    assert_eq!(f.len(), r.len(), "worker {w} slab count");
                    for (i, (fs, rs)) in f.iter().zip(r).enumerate() {
                        assert_eq!(fs.shape(), rs.shape(), "worker {w} slab {i}");
                        assert_eq!(
                            fs.data, rs.data,
                            "{}: worker {w} slab {i} diverged bitwise",
                            layer.name
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn run_im2col_bit_identical_to_per_pair_im2col() {
        use crate::tensor::im2col::conv2d_im2col;
        let layer = ConvLayer::new("t", 3, 12, 10, 8, 3, 3, 1, 1);
        let mut rng = Rng::new(62);
        let xs: Vec<Tensor3> =
            (0..2).map(|_| Tensor3::random(3, 12, 10, &mut rng)).collect();
        let k = Tensor4::random(8, 3, 3, 3, &mut rng);
        // Both filter regimes — resident prepacked operands and per-call
        // packing — must reproduce the per-pair reference bit for bit.
        for prepack in [true, false] {
            let plan = FcdccPlan::new_crme(&layer, 4, 2, 4)
                .unwrap()
                .with_prepack(prepack);
            let cf = plan.encode_filters(&k);
            for rf in &cf {
                assert_eq!(rf.packs.is_some(), prepack);
            }
            let refs: Vec<&Tensor3> = xs.iter().collect();
            let payloads = plan.make_payloads(plan.encode_input_batch(&refs), &cf);
            for p in &payloads {
                let fused = p.run_im2col();
                let want = p.run_with(|a, b, c| conv2d_im2col(a, b, c));
                assert_eq!(fused.blocks.len(), want.blocks.len());
                for (f, w) in fused.blocks.iter().zip(&want.blocks) {
                    assert_eq!(
                        f.data, w.data,
                        "worker {} block diverged (prepack {prepack})",
                        p.worker_id
                    );
                }
            }
            // Per-call filter packs happen only on the fallback path.
            if prepack {
                assert_eq!(plan.arena().filter_packs(), 0, "prepacked path packed");
            } else {
                assert!(plan.arena().filter_packs() > 0, "fallback packs uncounted");
            }
        }
    }

    #[test]
    fn payloads_share_resident_filters() {
        // Steady-state model: coded filter slabs (and their prepacked
        // GEMM operands) are encoded once and shared across jobs —
        // payload construction must not deep-clone either.
        let layer = ConvLayer::new("t", 2, 12, 10, 8, 3, 3, 1, 0);
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap();
        let mut rng = Rng::new(55);
        let x = Tensor3::random(2, 12, 10, &mut rng);
        let k = Tensor4::random(8, 2, 3, 3, &mut rng);
        let cf = plan.encode_filters(&k);
        let payloads = plan.make_payloads(plan.encode_input(&x), &cf);
        for (p, f) in payloads.iter().zip(&cf) {
            assert!(Arc::ptr_eq(&p.filters, &f.slabs), "filter slabs were copied");
            let (pp, fp) = (p.packs.as_ref().unwrap(), f.packs.as_ref().unwrap());
            assert!(Arc::ptr_eq(pp, fp), "prepacked operands were copied");
            assert!(f.packed_entries() > 0);
        }
    }

    #[test]
    fn inline_batch_reaches_zero_arena_misses() {
        // The allocation-free steady state at the plan level: after the
        // first (warmup) job, every slab/block/staging take hits.
        let layer = ConvLayer::new("t", 2, 12, 10, 8, 3, 3, 1, 0);
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap();
        let mut rng = Rng::new(63);
        let k = Tensor4::random(8, 2, 3, 3, &mut rng);
        let xs: Vec<Tensor3> =
            (0..2).map(|_| Tensor3::random(2, 12, 10, &mut rng)).collect();
        let refs: Vec<&Tensor3> = xs.iter().collect();
        plan.run_inline_batch(&refs, &k, None).unwrap();
        let warm_misses = plan.arena().misses();
        assert!(warm_misses > 0, "warmup must populate the arena");
        for _ in 0..3 {
            plan.run_inline_batch(&refs, &k, None).unwrap();
        }
        assert_eq!(
            plan.arena().misses(),
            warm_misses,
            "steady-state inline jobs must not allocate"
        );
        assert_eq!(plan.arena().outstanding(), 0, "buffers leaked");
    }

    #[test]
    fn accounting_matches_cost_model_building_blocks() {
        let layer = ConvLayer::new("t", 3, 12, 12, 8, 3, 3, 1, 1);
        let plan = FcdccPlan::new_crme(&layer, 2, 4, 4).unwrap();
        let mut rng = Rng::new(54);
        let x = Tensor3::random(3, 12, 12, &mut rng);
        let k = Tensor4::random(8, 3, 3, 3, &mut rng);
        let payloads =
            plan.make_payloads(plan.encode_input(&x), &plan.encode_filters(&k));
        // upload per worker = ell_a · C·Ĥ·(W+2p)
        let want_up = 2 * plan.apcp.entries_per_slab(3, 12 + 2);
        assert_eq!(payloads[0].upload_entries(), want_up);
        // store per worker = ell_b · (N/k_B)·C·K_H·K_W
        let want_store = 2 * plan.kccp.entries_per_partition(3, 3, 3);
        assert_eq!(payloads[0].store_entries(), want_store);
    }
}
