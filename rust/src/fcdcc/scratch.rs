//! The plan-owned slab arena — pooled `f64` buffers for **every**
//! steady-state allocation on the coded hot path.
//!
//! PR 4 introduced a small scratch pool for the decode staging buffer;
//! this generalizes it into one arena per plan that also backs the
//! encoded input slabs (`encode_input_batch` writes coded slabs into
//! pooled buffers), the worker reply blocks (drawn on compute, returned
//! on decode), and the decode staging buffer. Under steady-state
//! serving the same few buffer sizes recur job after job, so after a
//! short warmup every take is a zero-allocation `memset` of a recycled
//! buffer — `misses()` is exactly the number of heap allocations the
//! hot path performed through the arena, and the steady-state
//! regression test asserts it goes flat.
//!
//! Buffers are bucketed by capacity in a `BTreeMap`, so `take(len)`
//! picks the **best fit** (smallest retained capacity `>= len`) instead
//! of the first fit: slab, block, and staging sizes differ per conv
//! stage, and best-fit keeps a large staging buffer from being burned
//! on a small slab request. A full arena retains the largest
//! capacities, for the same reason the old pool did: a retained large
//! buffer serves every smaller request, the converse never holds.
//!
//! The arena is shared per `NetworkPlan` (one arena across all conv
//! stages, like the recovery-inverse cache); standalone `FcdccPlan`s own
//! a private one. It also hosts the plan's `filter_packs` counter — the
//! number of per-call filter `pack_a` operations the worker conv path
//! performed because no plan-resident prepacked operand was available
//! (zero when prepacking is on; see `linalg::gemm::PackedA`).

use crate::metrics::{CacheStats, EncodeStats};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Default number of idle buffers retained. The arena now backs every
/// per-worker input slab and reply block of every in-flight job — for
/// LeNet-scale serving (n·batch·blocks-per-worker buffers per job, a
/// few jobs in flight) a couple hundred idle buffers cover the whole
/// steady state without hoarding unbounded memory.
pub const DEFAULT_ARENA_CAP: usize = 256;

/// A shared, thread-safe arena of reusable `f64` slab buffers.
pub struct SlabArena {
    capacity: usize,
    /// Idle buffers bucketed by `Vec::capacity()`.
    buckets: Mutex<BTreeMap<usize, Vec<Vec<f64>>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    takes: AtomicU64,
    puts: AtomicU64,
    filter_packs: AtomicU64,
    encode_cols: AtomicU64,
    encode_terms: AtomicU64,
    encode_dense_terms: AtomicU64,
}

impl SlabArena {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "slab arena needs capacity >= 1");
        Self {
            capacity,
            buckets: Mutex::new(BTreeMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            takes: AtomicU64::new(0),
            puts: AtomicU64::new(0),
            filter_packs: AtomicU64::new(0),
            encode_cols: AtomicU64::new(0),
            encode_terms: AtomicU64::new(0),
            encode_dense_terms: AtomicU64::new(0),
        }
    }

    /// Take a zeroed buffer of exactly `len` entries, reusing the
    /// best-fitting pooled allocation when one is large enough (a hit);
    /// otherwise allocate fresh (a miss). Return it with [`Self::put`]
    /// when done. Zero-length requests are served without touching the
    /// arena (and without counting): an empty `Vec` never allocates.
    pub fn take(&self, len: usize) -> Vec<f64> {
        if len == 0 {
            return Vec::new();
        }
        self.takes.fetch_add(1, Ordering::Relaxed);
        let reused = {
            let mut buckets = self.buckets.lock().expect("slab arena poisoned");
            match buckets.range(len..).next().map(|(&cap, _)| cap) {
                Some(cap) => {
                    let bucket = buckets.get_mut(&cap).expect("bucket vanished");
                    let buf = bucket.pop().expect("empty bucket retained");
                    if bucket.is_empty() {
                        buckets.remove(&cap);
                    }
                    Some(buf)
                }
                None => None,
            }
        };
        match reused {
            Some(mut b) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                b.clear();
                b.resize(len, 0.0);
                b
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                vec![0.0; len]
            }
        }
    }

    /// Return a buffer to the arena. A full arena retains the *largest*
    /// capacities: buffer sizes scale with the serve batch, and a
    /// retained small buffer can never serve a larger request while a
    /// large one serves every smaller request — so an incoming buffer
    /// bigger than the smallest retained one replaces it (the smaller
    /// is dropped), and steady-state serving converges to all-hits even
    /// when small-batch warmup/stall flushes came first.
    pub fn put(&self, buf: Vec<f64>) {
        let cap = buf.capacity();
        if cap == 0 {
            // The counterpart of the uncounted zero-length take: not a
            // real buffer, so it neither counts nor retains.
            return;
        }
        self.puts.fetch_add(1, Ordering::Relaxed);
        let mut buckets = self.buckets.lock().expect("slab arena poisoned");
        let retained: usize = buckets.values().map(Vec::len).sum();
        if retained >= self.capacity {
            let smallest = *buckets.keys().next().expect("full arena has buffers");
            if smallest >= cap {
                return; // incoming is no improvement; drop it
            }
            let bucket = buckets.get_mut(&smallest).expect("bucket vanished");
            bucket.pop();
            if bucket.is_empty() {
                buckets.remove(&smallest);
            }
        }
        buckets.entry(cap).or_default().push(buf);
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Misses == heap allocations performed through the arena.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits(),
            misses: self.misses(),
        }
    }

    /// Buffers taken and not yet returned (saturating: pre-seeding the
    /// arena with foreign `put`s cannot drive it negative). Steady-state
    /// tests poll this for quiescence between serve waves.
    pub fn outstanding(&self) -> u64 {
        let takes = self.takes.load(Ordering::Relaxed);
        let puts = self.puts.load(Ordering::Relaxed);
        takes.saturating_sub(puts)
    }

    /// Record `n` per-call filter `pack_a` operations on the worker conv
    /// path (the fallback when a payload carries no resident prepacked
    /// filters). Zero growth after plan build is the prepacking
    /// acceptance bar.
    pub fn note_filter_packs(&self, n: u64) {
        self.filter_packs.fetch_add(n, Ordering::Relaxed);
    }

    /// Total per-call filter packs recorded via [`Self::note_filter_packs`].
    pub fn filter_packs(&self) -> u64 {
        self.filter_packs.load(Ordering::Relaxed)
    }

    /// Record one input-encode pass: `cols` coded slabs built via
    /// `terms` nonzero coefficient applications, where a dense
    /// scan-all-`k_A` sweep would have visited `dense` coefficient
    /// slots. The plan computes these analytically from its compiled
    /// encode program — one counter bump per encode call, nothing on
    /// the per-row fill itself.
    pub fn note_encode(&self, cols: u64, terms: u64, dense: u64) {
        self.encode_cols.fetch_add(cols, Ordering::Relaxed);
        self.encode_terms.fetch_add(terms, Ordering::Relaxed);
        self.encode_dense_terms.fetch_add(dense, Ordering::Relaxed);
    }

    /// Accumulated encode-pass accounting (see [`Self::note_encode`]).
    pub fn encode_stats(&self) -> EncodeStats {
        EncodeStats {
            cols: self.encode_cols.load(Ordering::Relaxed),
            terms: self.encode_terms.load(Ordering::Relaxed),
            dense_terms: self.encode_dense_terms.load(Ordering::Relaxed),
        }
    }

    /// Idle buffers currently retained.
    pub fn idle(&self) -> usize {
        self.buckets
            .lock()
            .expect("slab arena poisoned")
            .values()
            .map(Vec::len)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuses_returned_buffers() {
        let p = SlabArena::new(4);
        let b = p.take(16);
        assert_eq!(b.len(), 16);
        assert!(b.iter().all(|&v| v == 0.0));
        assert_eq!(p.misses(), 1);
        p.put(b);
        let b = p.take(16);
        assert_eq!(p.hits(), 1);
        assert_eq!(p.misses(), 1);
        p.put(b);
        // A smaller request reuses the same allocation…
        let b = p.take(8);
        assert_eq!(b.len(), 8);
        assert_eq!(p.hits(), 2);
        p.put(b);
        // …a larger one cannot.
        let b = p.take(64);
        assert_eq!(p.misses(), 2);
        p.put(b);
    }

    #[test]
    fn reused_buffers_come_back_zeroed() {
        let p = SlabArena::new(2);
        let mut b = p.take(4);
        b.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        p.put(b);
        let b = p.take(4);
        assert!(b.iter().all(|&v| v == 0.0), "stale data leaked: {b:?}");
    }

    #[test]
    fn capacity_bounds_retention() {
        let p = SlabArena::new(1);
        p.put(vec![0.0; 4]);
        p.put(vec![0.0; 4]);
        assert_eq!(p.idle(), 1);
    }

    #[test]
    fn full_arena_prefers_larger_buffers() {
        // Batch-scaled staging: small warmup buffers must not pin the
        // arena into allocating for every later large-batch decode.
        let p = SlabArena::new(2);
        p.put(vec![0.0; 4]);
        p.put(vec![0.0; 4]);
        p.put(vec![0.0; 64]); // full arena: evicts one small buffer
        assert_eq!(p.idle(), 2);
        let b = p.take(64);
        assert_eq!(p.hits(), 1, "large request must hit the retained buffer");
        p.put(b);
        // A smaller incoming buffer never evicts a larger retained one.
        p.put(vec![0.0; 8]);
        let b = p.take(64);
        assert_eq!(p.hits(), 2);
        p.put(b);
    }

    #[test]
    fn take_is_best_fit_across_sizes() {
        // With a small and a large buffer retained, a small request must
        // take the small one, leaving the large one for a large request
        // (first-fit would burn the large buffer and miss).
        let p = SlabArena::new(4);
        p.put(vec![0.0; 1024]);
        p.put(vec![0.0; 8]);
        let small = p.take(8);
        assert_eq!(small.capacity(), 8, "best fit must pick the small bucket");
        let large = p.take(1024);
        assert_eq!(p.hits(), 2);
        assert_eq!(p.misses(), 0);
        p.put(small);
        p.put(large);
    }

    #[test]
    fn zero_length_takes_bypass_the_arena() {
        let p = SlabArena::new(2);
        let b = p.take(0);
        assert!(b.is_empty() && b.capacity() == 0);
        assert_eq!(p.hits() + p.misses(), 0);
        assert_eq!(p.outstanding(), 0);
    }

    #[test]
    fn outstanding_tracks_unreturned_buffers() {
        let p = SlabArena::new(2);
        let a = p.take(4);
        let b = p.take(4);
        assert_eq!(p.outstanding(), 2);
        p.put(a);
        assert_eq!(p.outstanding(), 1);
        p.put(b);
        assert_eq!(p.outstanding(), 0);
        // Foreign puts saturate rather than underflow.
        p.put(vec![0.0; 4]);
        assert_eq!(p.outstanding(), 0);
    }

    #[test]
    fn filter_pack_counter_accumulates() {
        let p = SlabArena::new(1);
        assert_eq!(p.filter_packs(), 0);
        p.note_filter_packs(3);
        p.note_filter_packs(2);
        assert_eq!(p.filter_packs(), 5);
    }

    #[test]
    fn encode_counters_accumulate() {
        let p = SlabArena::new(1);
        assert_eq!(p.encode_stats(), Default::default());
        p.note_encode(4, 6, 16);
        p.note_encode(4, 6, 16);
        let e = p.encode_stats();
        assert_eq!((e.cols, e.terms, e.dense_terms), (8, 12, 32));
        assert!((e.nnz_frac() - 0.375).abs() < 1e-12);
    }
}
