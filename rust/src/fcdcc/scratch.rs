//! Scratch-buffer pool for the decode hot path.
//!
//! Every batched decode needs one flat staging buffer holding the
//! batch's `batch·k_A·k_B` output blocks while the per-sample GEMMs
//! accumulate into their disjoint regions (one take/put per decode,
//! split across samples by the compute pool). Allocating that buffer
//! fresh per job (the pre-fusion path allocated one `Tensor3::zeros`
//! per block per sample) churns the allocator exactly where latency
//! matters; under steady-state serving the same few buffer sizes recur
//! job after job, so a small pool turns every decode after the first
//! into an allocation-free `memset`.
//!
//! The pool is shared per `NetworkPlan` (one pool across all conv
//! stages, like the recovery-inverse cache); standalone `FcdccPlan`s own
//! a private one. Hit/miss counters make buffer reuse observable:
//! `misses()` is exactly the number of heap allocations the decode path
//! performed through the pool.

use crate::metrics::CacheStats;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Default number of idle buffers retained. Serving keeps at most a few
/// decodes in flight per plan, so a handful of buffers suffices; excess
/// returns are dropped rather than hoarded.
pub const DEFAULT_SCRATCH_POOL_CAP: usize = 8;

/// A shared, thread-safe pool of reusable `f64` scratch buffers.
pub struct ScratchPool {
    capacity: usize,
    buffers: Mutex<Vec<Vec<f64>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ScratchPool {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "scratch pool needs capacity >= 1");
        Self {
            capacity,
            buffers: Mutex::new(Vec::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Take a zeroed buffer of exactly `len` entries, reusing a pooled
    /// allocation when one is large enough (a hit); otherwise allocate
    /// fresh (a miss). Return it with [`Self::put`] when done.
    pub fn take(&self, len: usize) -> Vec<f64> {
        let reused = {
            let mut bufs = self.buffers.lock().expect("scratch pool poisoned");
            bufs.iter()
                .position(|b| b.capacity() >= len)
                .map(|p| bufs.swap_remove(p))
        };
        match reused {
            Some(mut b) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                b.clear();
                b.resize(len, 0.0);
                b
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                vec![0.0; len]
            }
        }
    }

    /// Return a buffer to the pool. A full pool retains the *largest*
    /// capacities: staging sizes scale with the decode batch, and a
    /// retained small buffer can never serve a larger request while a
    /// large one serves every smaller request — so an incoming buffer
    /// bigger than the smallest retained one replaces it (the smaller
    /// is dropped), and steady-state serving converges to all-hits even
    /// when small-batch warmup/stall flushes came first.
    pub fn put(&self, buf: Vec<f64>) {
        let mut bufs = self.buffers.lock().expect("scratch pool poisoned");
        if bufs.len() < self.capacity {
            bufs.push(buf);
            return;
        }
        if let Some((idx, min_cap)) = bufs
            .iter()
            .enumerate()
            .map(|(i, b)| (i, b.capacity()))
            .min_by_key(|&(_, cap)| cap)
        {
            if buf.capacity() > min_cap {
                bufs[idx] = buf;
            }
        }
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Misses == heap allocations performed through the pool.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits(),
            misses: self.misses(),
        }
    }

    /// Idle buffers currently retained.
    pub fn idle(&self) -> usize {
        self.buffers.lock().expect("scratch pool poisoned").len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuses_returned_buffers() {
        let p = ScratchPool::new(4);
        let b = p.take(16);
        assert_eq!(b.len(), 16);
        assert!(b.iter().all(|&v| v == 0.0));
        assert_eq!(p.misses(), 1);
        p.put(b);
        let b = p.take(16);
        assert_eq!(p.hits(), 1);
        assert_eq!(p.misses(), 1);
        p.put(b);
        // A smaller request reuses the same allocation…
        let b = p.take(8);
        assert_eq!(b.len(), 8);
        assert_eq!(p.hits(), 2);
        p.put(b);
        // …a larger one cannot.
        let b = p.take(64);
        assert_eq!(p.misses(), 2);
        p.put(b);
    }

    #[test]
    fn reused_buffers_come_back_zeroed() {
        let p = ScratchPool::new(2);
        let mut b = p.take(4);
        b.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        p.put(b);
        let b = p.take(4);
        assert!(b.iter().all(|&v| v == 0.0), "stale data leaked: {b:?}");
    }

    #[test]
    fn capacity_bounds_retention() {
        let p = ScratchPool::new(1);
        p.put(vec![0.0; 4]);
        p.put(vec![0.0; 4]);
        assert_eq!(p.idle(), 1);
    }

    #[test]
    fn full_pool_prefers_larger_buffers() {
        // Batch-scaled staging: small warmup buffers must not pin the
        // pool into allocating for every later large-batch decode.
        let p = ScratchPool::new(2);
        p.put(vec![0.0; 4]);
        p.put(vec![0.0; 4]);
        p.put(vec![0.0; 64]); // full pool: evicts one small buffer
        assert_eq!(p.idle(), 2);
        let b = p.take(64);
        assert_eq!(p.hits(), 1, "large request must hit the retained buffer");
        p.put(b);
        // A smaller incoming buffer never evicts a larger retained one.
        p.put(vec![0.0; 8]);
        let b = p.take(64);
        assert_eq!(p.hits(), 2);
        p.put(b);
    }
}
