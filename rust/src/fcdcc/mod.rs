//! The FCDCC framework proper (paper §IV): gluing APCP + KCCP partitioning
//! to an NSCTC code, producing per-worker coded subtasks, and decoding the
//! first-δ results back into the layer output — plus the (k_A,k_B) cost
//! model and optimizer (§IV-E).

pub mod cost;
pub mod inverse_cache;
pub mod network_plan;
pub mod pipeline;
pub mod pooling;
pub mod scratch;

pub use cost::{CostModel, CostBreakdown, PlanChoice};
pub use inverse_cache::{InverseCache, DEFAULT_INVERSE_CACHE_CAP};
pub use network_plan::{ConvStage, NetworkPlan, PlanOptions, StageVariant};
pub use pipeline::{FcdccPlan, ResidentFilters, WorkerPayload, WorkerResult};
pub use pooling::CodedAvgPool;
pub use scratch::{SlabArena, DEFAULT_ARENA_CAP};
