//! The per-worker cost model and (k_A, k_B) optimizer — paper §IV-E,
//! eqs. (50)–(61) and Theorem 1.
//!
//! Costs per worker node for an FCDCC instance with ℓ = 2:
//!   C_comm_up   = λ_comm · 4·C·(H+2p)·(W+2p) / k_A          (eq. 50)
//!   C_comm_down = λ_comm · 4·N·H'·W' / Q                    (eq. 51)
//!   C_comp      = λ_comp · 4·C·N·H·W·K_H·K_W / (s²·Q)       (eq. 53)
//!   C_store     = λ_store · 2·N·C·K_H·K_W / k_B             (eq. 54)
//!
//! U(k_A) = a₁·k_A + a₂/k_A + a₃ is strictly convex (Lemma 1); the real
//! optimum is k*_A = √(a₂/a₁) (Theorem 1) and the integer optimum is found
//! over the feasible divisor set S = {x | x = 1 or x even} with the
//! structural constraints k_A ≤ H′ and k_B | N.

use crate::coding::crme::feasible_k;
use crate::model::ConvLayer;

/// Unit costs (λ_comm, λ_comp, λ_store). The paper's Experiment 5 uses
/// AWS S3-derived λ_store = 0.023, λ_comm = 0.09, λ_comp = 0.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostModel {
    pub lambda_comm: f64,
    pub lambda_comp: f64,
    pub lambda_store: f64,
}

impl CostModel {
    /// The paper's Experiment-5 cost coefficients (AWS S3 pricing ratio).
    pub fn paper_exp5() -> Self {
        Self {
            lambda_comm: 0.09,
            lambda_comp: 0.0,
            lambda_store: 0.023,
        }
    }
}

/// Per-worker cost components for one (k_A, k_B) choice.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostBreakdown {
    pub k_a: usize,
    pub k_b: usize,
    pub comm_up: f64,
    pub comm_down: f64,
    pub comp: f64,
    pub store: f64,
}

impl CostBreakdown {
    pub fn total(&self) -> f64 {
        self.comm_up + self.comm_down + self.comp + self.store
    }

    pub fn comm(&self) -> f64 {
        self.comm_up + self.comm_down
    }
}

/// The optimizer's selected plan plus the real-valued optimum for
/// reference (paper eq. (59)).
#[derive(Clone, Debug)]
pub struct PlanChoice {
    pub best: CostBreakdown,
    /// The unconstrained real optimum k*_A = sqrt(a2/a1).
    pub k_a_star_real: f64,
    /// All feasible candidates evaluated (for the Fig. 7 landscape).
    pub candidates: Vec<CostBreakdown>,
}

/// Evaluate the paper's closed-form per-worker cost (eqs. 50–55) for a
/// layer at (k_A, k_B).
pub fn cost_for(layer: &ConvLayer, cm: &CostModel, k_a: usize, k_b: usize) -> CostBreakdown {
    let q = (k_a * k_b) as f64;
    let c = layer.c as f64;
    let n = layer.n as f64;
    let hp = (layer.h + 2 * layer.pad) as f64;
    let wp = (layer.w + 2 * layer.pad) as f64;
    let (h_out, w_out) = layer.out_shape();
    let (h_out, w_out) = (h_out as f64, w_out as f64);
    let khw = (layer.kh * layer.kw) as f64;
    let s2 = (layer.stride * layer.stride) as f64;
    CostBreakdown {
        k_a,
        k_b,
        comm_up: cm.lambda_comm * 4.0 * c * hp * wp / k_a as f64,
        comm_down: cm.lambda_comm * 4.0 * n * h_out * w_out / q,
        comp: cm.lambda_comp * 4.0 * c * n * (layer.h as f64) * (layer.w as f64) * khw / (s2 * q),
        store: cm.lambda_store * 2.0 * n * c * khw / k_b as f64,
    }
}

/// The real-valued unconstrained optimum k*_A (paper eq. (59)).
pub fn k_a_star_real(layer: &ConvLayer, cm: &CostModel, q: usize) -> f64 {
    let c = layer.c as f64;
    let n = layer.n as f64;
    let hp = (layer.h + 2 * layer.pad) as f64;
    let wp = (layer.w + 2 * layer.pad) as f64;
    let khw = (layer.kh * layer.kw) as f64;
    let a1 = cm.lambda_store * 2.0 * n * c * khw / q as f64;
    let a2 = cm.lambda_comm * 4.0 * c * hp * wp;
    (a2 / a1).sqrt()
}

/// Feasible (k_A, k_B) pairs for a fixed product Q: both in
/// S = {1} ∪ 2ℤ⁺, k_A·k_B = Q, k_A ≤ H′ (spatial splits cannot exceed
/// output rows) and k_B | N (KCCP needs equal channel groups).
pub fn feasible_pairs(layer: &ConvLayer, q: usize) -> Vec<(usize, usize)> {
    let h_out = layer.h_out();
    (1..=q)
        .filter(|k_a| q % k_a == 0)
        .map(|k_a| (k_a, q / k_a))
        .filter(|&(k_a, k_b)| feasible_k(k_a) && feasible_k(k_b))
        .filter(|&(k_a, _)| k_a <= h_out)
        .filter(|&(_, k_b)| layer.n % k_b == 0)
        .collect()
}

/// Exact integer optimization of U(k_A, k_B) over the feasible set
/// (paper Theorem 1 + rounding rule, done by exhaustive divisor search —
/// Q ≤ a few thousand, so this is both exact and instant).
pub fn optimize(layer: &ConvLayer, cm: &CostModel, q: usize) -> Option<PlanChoice> {
    let cands: Vec<CostBreakdown> = feasible_pairs(layer, q)
        .into_iter()
        .map(|(ka, kb)| cost_for(layer, cm, ka, kb))
        .collect();
    let best = cands
        .iter()
        .min_by(|a, b| a.total().partial_cmp(&b.total()).unwrap())?
        .clone();
    Some(PlanChoice {
        best,
        k_a_star_real: k_a_star_real(layer, cm, q),
        candidates: cands,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn convexity_in_k_a() {
        // U(k_A) with k_B = Q/k_A is convex along the divisor chain.
        let layer = &zoo::alexnet()[1];
        let cm = CostModel::paper_exp5();
        let us: Vec<f64> = [1usize, 2, 4, 8, 16, 32]
            .iter()
            .map(|&ka| cost_for(layer, &cm, ka, 32 / ka).total())
            .collect();
        // Strictly convex sequences have a single local minimum.
        let mut dips = 0;
        for i in 1..us.len() - 1 {
            if us[i] < us[i - 1] && us[i] <= us[i + 1] {
                dips += 1;
            }
        }
        assert!(dips <= 1, "U along divisors: {us:?}");
    }

    #[test]
    fn real_optimum_matches_formula() {
        let layer = &zoo::alexnet()[0];
        let cm = CostModel::paper_exp5();
        let k = k_a_star_real(layer, &cm, 32);
        // independent recomputation
        let a1 = cm.lambda_store * 2.0 * 96.0 * 3.0 * 121.0 / 32.0;
        let a2 = cm.lambda_comm * 4.0 * 3.0 * 227.0 * 227.0;
        assert!((k - (a2 / a1).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn early_layers_favor_large_k_a() {
        // Paper Table IV: AlexNet conv1 at Q=32 chooses (32, 1).
        let cm = CostModel::paper_exp5();
        let layer = &zoo::alexnet()[0];
        let choice = optimize(layer, &cm, 32).unwrap();
        assert!(
            choice.best.k_a >= 16,
            "conv1 should be spatial-dominated, got ({}, {})",
            choice.best.k_a,
            choice.best.k_b
        );
    }

    #[test]
    fn deep_layers_favor_large_k_b() {
        // Paper Table IV: AlexNet conv3 at Q=32 chooses (2, 16).
        let cm = CostModel::paper_exp5();
        let layer = &zoo::alexnet()[2];
        let choice = optimize(layer, &cm, 32).unwrap();
        assert!(
            choice.best.k_b >= 8,
            "conv3 should be storage-dominated, got ({}, {})",
            choice.best.k_a,
            choice.best.k_b
        );
    }

    #[test]
    fn feasible_pairs_respect_constraints() {
        let layer = &zoo::lenet5()[0]; // H'=28, N=6
        for (ka, kb) in feasible_pairs(layer, 16) {
            assert_eq!(ka * kb, 16);
            assert!(ka == 1 || ka % 2 == 0);
            assert!(kb == 1 || kb % 2 == 0);
            assert!(ka <= 28);
            assert_eq!(6 % kb, 0);
        }
    }

    #[test]
    fn optimizer_beats_every_candidate() {
        let cm = CostModel::paper_exp5();
        for layer in zoo::alexnet() {
            let choice = optimize(&layer, &cm, 64).unwrap();
            for c in &choice.candidates {
                assert!(choice.best.total() <= c.total() + 1e-9);
            }
        }
    }
}
