//! Coded distributed **average pooling** — the paper's future-work item
//! ("extending the CDC scheme to support pooling layers", §VII),
//! implemented here as an extension: average pooling is linear in the
//! input, so the NSCTC machinery applies unchanged. The input is
//! partitioned along H with the same adaptive geometry as APCP (pool
//! windows play the role of kernels), encoded with a CRME code on the
//! A side only (k_B = 1: there is no filter tensor), pooled by any δ of
//! n workers, and decoded/merged exactly like a convolution.
//!
//! (Max pooling is *not* linear and cannot be coded this way — the same
//! boundary the paper draws.)

use crate::coding::{registry, Code, EncodeProgram};
use crate::model::network::pool;
use crate::partition::ApcpPlan;
use crate::tensor::Tensor3;
use anyhow::{ensure, Context, Result};
use std::sync::Arc;

/// A planned coded average-pooling layer.
pub struct CodedAvgPool {
    pub size: usize,
    pub stride: usize,
    pub apcp: ApcpPlan,
    pub code: Arc<dyn Code>,
    /// Compiled CSC walk of `mat_a` — the pooling encoder iterates this
    /// instead of scanning all `k_A` coefficients per coded slab.
    program_a: EncodeProgram,
    h_in: usize,
}

impl CodedAvgPool {
    /// Plan pooling of an H×W input with square window `size`, stride
    /// `stride`, split into `k_a` coded partitions over `n` workers,
    /// using the session's selected code family (`--code`/`FCDCC_CODE`).
    pub fn new(h_in: usize, size: usize, stride: usize, k_a: usize, n: usize) -> Result<Self> {
        // k_B = 1: single "filter side" partition, ℓ_B = 1.
        let code = registry::default_family().build(k_a, 1, n)?;
        Self::with_code(h_in, size, stride, code)
    }

    /// Like [`CodedAvgPool::new`], but with an explicitly constructed
    /// code (mirrors `FcdccPlan::with_code`). The code must have
    /// `k_B = 1`: pooling has no filter tensor to partition.
    pub fn with_code(
        h_in: usize,
        size: usize,
        stride: usize,
        code: Arc<dyn Code>,
    ) -> Result<Self> {
        ensure!(size >= 1 && stride >= 1);
        let s = code.spec();
        ensure!(
            s.k_b == 1 && s.ell_b == 1,
            "pooling codes must have k_B = ℓ_B = 1 (got k_B={}, ℓ_B={})",
            s.k_b,
            s.ell_b
        );
        let apcp = ApcpPlan::new(h_in, size, stride, s.k_a)
            .context("coded avg-pool partitioning")?;
        let program_a = EncodeProgram::compile(code.mat_a());
        Ok(Self {
            size,
            stride,
            apcp,
            code,
            program_a,
            h_in,
        })
    }

    pub fn delta(&self) -> usize {
        self.code.spec().delta()
    }

    /// Encode the input into per-worker coded slabs (ℓ_A each), walking
    /// the compiled program columns — bit-identical to the reference
    /// `coding::encode_inputs` fold, in nnz-proportional work.
    pub fn encode(&self, x: &Tensor3) -> Vec<Vec<Tensor3>> {
        assert_eq!(x.h, self.h_in, "planned for H={}, got {}", self.h_in, x.h);
        let parts = self.apcp.partition(x);
        let s = self.code.spec();
        (0..s.n)
            .map(|i| {
                (0..s.ell_a)
                    .map(|j| self.program_a.combine3(i * s.ell_a + j, &parts))
                    .collect()
            })
            .collect()
    }

    /// The worker-side computation: average-pool each coded slab.
    pub fn worker_compute(&self, slabs: &[Tensor3]) -> Vec<Tensor3> {
        slabs
            .iter()
            .map(|s| pool(s, self.size, self.stride, false))
            .collect()
    }

    /// Decode any δ workers' pooled coded slabs and merge along H.
    pub fn decode(&self, workers: &[usize], blocks: &[&[Tensor3]]) -> Result<Tensor3> {
        let decoded = coding::decode_outputs(self.code.as_ref(), workers, blocks)?;
        let merged = Tensor3::concat_h(&decoded.iter().collect::<Vec<_>>());
        let h_true = self.apcp.h_out;
        Ok(if merged.h == h_true {
            merged
        } else {
            merged.slice_h(0, h_true)
        })
    }

    /// Inline end-to-end run from a chosen survivor set (tests/benches).
    pub fn run_inline(&self, x: &Tensor3, survivors: &[usize]) -> Result<Tensor3> {
        let coded = self.encode(x);
        let results: Vec<Vec<Tensor3>> = survivors
            .iter()
            .map(|&i| self.worker_compute(&coded[i]))
            .collect();
        let blocks: Vec<&[Tensor3]> = results.iter().map(Vec::as_slice).collect();
        self.decode(survivors, &blocks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{self, CrmeCode, SparseCode};
    use crate::util::{mse, rng::Rng};

    /// Pin the family to CRME so the tight 1e-25 thresholds below hold
    /// regardless of the session default (`FCDCC_CODE` CI legs).
    fn crme_pool(h_in: usize, size: usize, stride: usize, k_a: usize, n: usize) -> CodedAvgPool {
        let code = Arc::new(CrmeCode::new(k_a, 1, n).unwrap());
        CodedAvgPool::with_code(h_in, size, stride, code).unwrap()
    }

    #[test]
    fn coded_avg_pool_matches_local() {
        let mut rng = Rng::new(101);
        for (h, w, size, stride, k_a, n) in [
            (16usize, 10usize, 2usize, 2usize, 4usize, 6usize),
            (18, 8, 3, 3, 2, 3),
            (20, 12, 2, 2, 8, 4), // delta = 2
        ] {
            let x = Tensor3::random(3, h, w, &mut rng);
            let plan = crme_pool(h, size, stride, k_a, n);
            let want = pool(&x, size, stride, false);
            let survivors = rng.choose_indices(n, plan.delta());
            let got = plan.run_inline(&x, &survivors).unwrap();
            assert_eq!(got.shape(), want.shape(), "case {:?}", (h, size, k_a));
            let e = mse(&got.data, &want.data);
            assert!(e < 1e-25, "case {:?}: mse={e:e}", (h, size, k_a, n));
        }
    }

    #[test]
    fn survives_stragglers() {
        let mut rng = Rng::new(102);
        let x = Tensor3::random(2, 16, 6, &mut rng);
        let plan = crme_pool(16, 2, 2, 4, 5); // delta=2, gamma=3
        let want = pool(&x, 2, 2, false);
        // Any 2 of the 5 workers suffice.
        for pair in [[0usize, 4], [1, 3], [2, 4]] {
            let got = plan.run_inline(&x, &pair).unwrap();
            assert!(mse(&got.data, &want.data) < 1e-25, "pair {pair:?}");
        }
    }

    #[test]
    fn program_encode_bit_identical_to_reference() {
        let mut rng = Rng::new(103);
        let x = Tensor3::random(3, 16, 10, &mut rng);
        let plan = crme_pool(16, 2, 2, 4, 6);
        let parts = plan.apcp.partition(&x);
        let want = coding::encode_inputs(plan.code.as_ref(), &parts);
        let got = plan.encode(&x);
        assert_eq!(got.len(), want.len());
        for (gw, ww) in got.iter().zip(&want) {
            for (g, w) in gw.iter().zip(ww) {
                assert_eq!(g.shape(), w.shape());
                assert_eq!(g.data, w.data, "program encode diverged from reference");
            }
        }
    }

    #[test]
    fn alternate_family_pools_exactly() {
        let mut rng = Rng::new(104);
        let x = Tensor3::random(2, 16, 8, &mut rng);
        let code = Arc::new(SparseCode::new(4, 1, 5).unwrap());
        let plan = CodedAvgPool::with_code(16, 2, 2, code).unwrap();
        let want = pool(&x, 2, 2, false);
        for pair in [[0usize, 4], [1, 3], [2, 4]] {
            let got = plan.run_inline(&x, &pair).unwrap();
            assert!(mse(&got.data, &want.data) < 1e-18, "pair {pair:?}");
        }
    }

    #[test]
    fn rejects_oversplit() {
        assert!(CodedAvgPool::new(6, 2, 2, 8, 10).is_err());
    }

    #[test]
    fn rejects_filter_side_partitioning() {
        let code = Arc::new(CrmeCode::new(4, 2, 6).unwrap());
        assert!(CodedAvgPool::with_code(16, 2, 2, code).is_err());
    }
}
