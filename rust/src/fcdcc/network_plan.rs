//! Whole-network FCDCC planning: plan every conv layer of a [`Network`]
//! **once** — an [`FcdccPlan`] plus `Arc`-shared resident coded filter
//! slabs per layer — and own the forward-pass walk over the layer
//! sequence. Both the blocking single-request path
//! ([`NetworkPlan::forward_distributed`]) and the pipelined request
//! scheduler (`coordinator::serve`) are built from the same two steps:
//! [`NetworkPlan::run_local`] advances an [`Activation`] through
//! master-side layers up to the next conv, and
//! [`NetworkPlan::absorb_conv_output`] folds a decoded conv job's output
//! back in. That keeps the layer semantics in exactly one place
//! (`Network::apply_local`) instead of the two near-identical loops the
//! pre-runtime code carried.

use crate::cluster::{Cluster, JobHandle, JobReport, StragglerModel};
use crate::fcdcc::FcdccPlan;
use crate::model::network::add_bias;
use crate::model::{Activation, Layer, Network};
use crate::tensor::{Tensor3, Tensor4};
use crate::util::rng::Rng;
use anyhow::{ensure, Result};
use std::sync::Arc;

/// One planned conv layer: code/geometry plan, resident coded filters
/// (encoded once at model load, shared across every request), bias.
pub struct ConvStage {
    pub plan: FcdccPlan,
    pub coded_filters: Vec<Arc<Vec<Tensor4>>>,
    pub bias: Vec<f64>,
    /// Index of this conv in the network's layer sequence.
    pub layer_idx: usize,
}

impl ConvStage {
    /// Dispatch this stage's coded job for one activation (non-blocking).
    pub fn submit(
        &self,
        cluster: &mut Cluster,
        a: &Activation,
        straggler: &StragglerModel,
        rng: &mut Rng,
    ) -> Result<JobHandle> {
        cluster.submit(&self.plan, a.spatial(), &self.coded_filters, straggler, rng)
    }
}

/// A network compiled against a coded cluster: per-conv [`ConvStage`]s
/// plus the shared forward-pass walk.
pub struct NetworkPlan {
    net: Network,
    stages: Vec<ConvStage>,
}

impl NetworkPlan {
    /// Plan every conv layer of `net` with the given per-conv `(k_A,
    /// k_B)` partitions on an `n_workers` cluster, encoding each filter
    /// bank once (the paper's steady-state model: coded filter slabs are
    /// resident on the workers across requests).
    pub fn new(net: Network, partitions: &[(usize, usize)], n_workers: usize) -> Result<Self> {
        let mut stages = Vec::new();
        for (layer_idx, layer) in net.layers.iter().enumerate() {
            if let Layer::Conv {
                shape,
                weights,
                bias,
            } = layer
            {
                ensure!(
                    stages.len() < partitions.len(),
                    "network has more conv layers than (k_A,k_B) pairs"
                );
                let (k_a, k_b) = partitions[stages.len()];
                let plan = FcdccPlan::new_crme(shape, k_a, k_b, n_workers)?;
                let coded_filters = plan.encode_filters(weights);
                stages.push(ConvStage {
                    plan,
                    coded_filters,
                    bias: bias.clone(),
                    layer_idx,
                });
            }
        }
        ensure!(
            stages.len() == partitions.len(),
            "got {} (k_A,k_B) pairs for {} conv layers",
            partitions.len(),
            stages.len()
        );
        Ok(Self { net, stages })
    }

    pub fn network(&self) -> &Network {
        &self.net
    }

    pub fn stages(&self) -> &[ConvStage] {
        &self.stages
    }

    /// Advance `a` through master-side (non-conv) layers starting at
    /// `*layer_idx`. Returns the stage index of the next conv layer (with
    /// `*layer_idx` pointing at that conv), or `None` when the pass
    /// finished (`*layer_idx` one past the end).
    pub fn run_local(&self, a: &mut Activation, layer_idx: &mut usize) -> Option<usize> {
        while *layer_idx < self.net.layers.len() {
            let layer = &self.net.layers[*layer_idx];
            if matches!(layer, Layer::Conv { .. }) {
                return Some(self.stage_at(*layer_idx));
            }
            self.net.apply_local(layer, a);
            *layer_idx += 1;
        }
        None
    }

    fn stage_at(&self, layer_idx: usize) -> usize {
        self.stages
            .iter()
            .position(|s| s.layer_idx == layer_idx)
            .expect("every conv layer was planned")
    }

    /// Fold a decoded conv output back into the activation (per-channel
    /// bias epilogue) and step past the conv layer.
    pub fn absorb_conv_output(
        &self,
        stage: usize,
        mut y: Tensor3,
        a: &mut Activation,
        layer_idx: &mut usize,
    ) {
        add_bias(&mut y, &self.stages[stage].bias);
        a.set_spatial(y);
        *layer_idx += 1;
    }

    /// One distributed forward pass, blocking per conv layer — the
    /// single-request path shared by tests and examples. Returns the
    /// logits plus one [`JobReport`] per conv stage.
    pub fn forward_distributed(
        &self,
        cluster: &mut Cluster,
        x: &Tensor3,
        straggler: &StragglerModel,
        rng: &mut Rng,
    ) -> Result<(Vec<f64>, Vec<JobReport>)> {
        let mut reports = Vec::with_capacity(self.stages.len());
        let mut a = Activation::new(x);
        let mut layer_idx = 0usize;
        while let Some(s) = self.run_local(&mut a, &mut layer_idx) {
            let handle = self.stages[s].submit(cluster, &a, straggler, rng)?;
            let (y, report) = cluster.wait(&self.stages[s].plan, handle)?;
            reports.push(report);
            self.absorb_conv_output(s, y, &mut a, &mut layer_idx);
        }
        Ok((a.into_logits(), reports))
    }

    /// Single-node reference forward pass (the fidelity oracle).
    pub fn forward_reference(&self, x: &Tensor3) -> Vec<f64> {
        self.net.forward(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Im2colEngine;
    use crate::util::mse;

    #[test]
    fn plans_lenet_and_matches_reference() {
        let net = Network::lenet5_random(31);
        let plan = NetworkPlan::new(net, &[(4, 2), (2, 2)], 4).unwrap();
        assert_eq!(plan.stages().len(), 2);
        let mut cluster = Cluster::new(4, Arc::new(Im2colEngine));
        let mut rng = Rng::new(1);
        let x = Tensor3::random(1, 32, 32, &mut rng);
        let want = plan.forward_reference(&x);
        let (got, reports) = plan
            .forward_distributed(&mut cluster, &x, &StragglerModel::None, &mut rng)
            .unwrap();
        cluster.shutdown();
        assert_eq!(reports.len(), 2);
        assert_eq!(got.len(), want.len());
        assert!(mse(&got, &want) < 1e-16);
    }

    #[test]
    fn partition_count_must_match_conv_count() {
        let net = Network::lenet5_random(32);
        assert!(NetworkPlan::new(net, &[(4, 2)], 4).is_err());
        let net = Network::lenet5_random(32);
        assert!(NetworkPlan::new(net, &[(4, 2), (2, 2), (2, 2)], 4).is_err());
    }
}
