//! Whole-network FCDCC planning: plan every conv layer of a [`Network`]
//! **once** — an [`FcdccPlan`] plus `Arc`-shared resident coded filter
//! slabs per layer — and own the forward-pass walk over the layer
//! sequence. Both the blocking single-request path
//! ([`NetworkPlan::forward_distributed`]) and the pipelined request
//! scheduler (`coordinator::serve`) are built from the same two steps:
//! [`NetworkPlan::run_local`] advances an [`Activation`] through
//! master-side layers up to the next conv, and
//! [`NetworkPlan::absorb_conv_output`] folds a decoded conv job's output
//! back in. That keeps the layer semantics in exactly one place
//! (`Network::apply_local`) instead of the two near-identical loops the
//! pre-runtime code carried.

use crate::cluster::{Cluster, JobHandle, JobReport, StragglerModel};
use crate::coding::{registry, CodeFamily};
use crate::fcdcc::inverse_cache::{InverseCache, DEFAULT_INVERSE_CACHE_CAP};
use crate::fcdcc::scratch::{SlabArena, DEFAULT_ARENA_CAP};
use crate::fcdcc::{FcdccPlan, ResidentFilters};
use crate::metrics::{CacheStats, EncodeStats};
use crate::model::network::add_bias;
use crate::model::{Activation, Layer, Network};
use crate::tensor::{conv2d, Tensor3};
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Result};
use std::sync::Arc;

/// Build-time knobs for [`NetworkPlan`]. The defaults are the paper's
/// steady-state serving model: filters prepacked into GEMM panels at
/// plan-build time, slab buffers pooled in a shared arena.
#[derive(Clone, Copy, Debug)]
pub struct PlanOptions {
    /// Pack every coded filter slab into GEMM-ready panels once at plan
    /// build; workers then contract resident packed panels directly
    /// (`--no-prepack` in the CLI flips this off for A/B measurement).
    pub prepack: bool,
    /// Capacity (buffer count) of the shared slab arena.
    pub arena_capacity: usize,
    /// Code family every conv stage is planned with. Defaults to the
    /// session's selected family (`--code` / `FCDCC_CODE`, else CRME).
    pub code: CodeFamily,
}

impl Default for PlanOptions {
    fn default() -> Self {
        Self {
            prepack: true,
            arena_capacity: DEFAULT_ARENA_CAP,
            code: registry::default_family(),
        }
    }
}

/// One planned conv layer: code/geometry plan, resident coded filters
/// (encoded once at model load — slabs plus, when prepacking is on,
/// their GEMM-ready packed panels — shared across every request), bias.
pub struct ConvStage {
    pub plan: FcdccPlan,
    pub coded_filters: Vec<ResidentFilters>,
    pub bias: Vec<f64>,
    /// Index of this conv in the network's layer sequence.
    pub layer_idx: usize,
}

impl ConvStage {
    /// Dispatch this stage's coded job for one activation (non-blocking).
    pub fn submit(
        &self,
        cluster: &mut Cluster,
        a: &Activation,
        straggler: &StragglerModel,
        rng: &mut Rng,
    ) -> Result<JobHandle> {
        cluster.submit(&self.plan, a.spatial(), &self.coded_filters, straggler, rng)
    }

    /// Dispatch one coded job carrying a batch of activations — the
    /// coalesced-serving path (non-blocking).
    pub fn submit_batch(
        &self,
        cluster: &mut Cluster,
        xs: &[&Tensor3],
        straggler: &StragglerModel,
        rng: &mut Rng,
    ) -> Result<JobHandle> {
        cluster.submit_batch(&self.plan, xs, &self.coded_filters, straggler, rng)
    }
}

/// A re-planned conv stage for a **shrunken live set**: the same layer
/// and `(k_A, k_B)` partition re-coded for `worker_map.len()` workers,
/// with `worker_map[i]` naming the physical worker that computes coded
/// column `i` (dispatch goes through `Cluster::submit_batch_mapped`).
/// Built by [`NetworkPlan::replan_stage`] when quarantine shrinks the
/// cluster, cached by the serving layer, and dropped when the original
/// full-cluster stage is restored on readmission. The variant shares
/// the base plan's slab arena (buffer hygiene stays global) but owns a
/// **private** recovery-inverse cache: the shared cache is keyed by
/// `(stage, worker subset)` where worker ids are coded columns of the
/// *full-n* code, and a variant's columns index a different code
/// entirely.
pub struct StageVariant {
    pub plan: FcdccPlan,
    pub coded_filters: Vec<ResidentFilters>,
    /// Coded column → physical worker id, ascending (so physical arrival
    /// order and coded order coincide, keeping decode subsets — and
    /// therefore bits — deterministic for a fixed reply set).
    pub worker_map: Vec<usize>,
}

/// A network compiled against a coded cluster: per-conv [`ConvStage`]s
/// plus the shared forward-pass walk. All stages decode through one
/// shared recovery-inverse cache, keyed by `(stage_idx, worker subset)`.
pub struct NetworkPlan {
    net: Network,
    stages: Vec<ConvStage>,
    inverse_cache: Arc<InverseCache>,
    /// Slab arena shared by every stage: encode slabs, worker reply
    /// blocks, and decode staging buffers all draw from (and return to)
    /// this one pool, so stages at the same geometry reuse each other's
    /// buffers and differing sizes coexist.
    arena: Arc<SlabArena>,
    /// The knobs this plan was built with — re-used verbatim when a
    /// stage is re-planned for a shrunken live set.
    opts: PlanOptions,
}

impl NetworkPlan {
    /// Plan every conv layer of `net` with the given per-conv `(k_A,
    /// k_B)` partitions on an `n_workers` cluster, encoding each filter
    /// bank once (the paper's steady-state model: coded filter slabs are
    /// resident on the workers across requests). Uses the default
    /// [`PlanOptions`]: filters prepacked, arena-pooled buffers.
    pub fn new(net: Network, partitions: &[(usize, usize)], n_workers: usize) -> Result<Self> {
        Self::with_options(net, partitions, n_workers, PlanOptions::default())
    }

    /// [`Self::new`] with explicit build-time knobs.
    pub fn with_options(
        net: Network,
        partitions: &[(usize, usize)],
        n_workers: usize,
        opts: PlanOptions,
    ) -> Result<Self> {
        let inverse_cache = Arc::new(InverseCache::new(DEFAULT_INVERSE_CACHE_CAP));
        let arena = Arc::new(SlabArena::new(opts.arena_capacity));
        let mut stages = Vec::new();
        for (layer_idx, layer) in net.layers.iter().enumerate() {
            if let Layer::Conv {
                shape,
                weights,
                bias,
            } = layer
            {
                ensure!(
                    stages.len() < partitions.len(),
                    "network has more conv layers than (k_A,k_B) pairs"
                );
                let (k_a, k_b) = partitions[stages.len()];
                let stage_idx = stages.len();
                let code = opts.code.build(k_a, k_b, n_workers)?;
                let plan = FcdccPlan::with_code(shape, code)?
                    .with_inverse_cache(Arc::clone(&inverse_cache), stage_idx)
                    .with_arena(Arc::clone(&arena))
                    .with_prepack(opts.prepack);
                let coded_filters = plan.encode_filters(weights);
                stages.push(ConvStage {
                    plan,
                    coded_filters,
                    bias: bias.clone(),
                    layer_idx,
                });
            }
        }
        ensure!(
            stages.len() == partitions.len(),
            "got {} (k_A,k_B) pairs for {} conv layers",
            partitions.len(),
            stages.len()
        );
        Ok(Self {
            net,
            stages,
            inverse_cache,
            arena,
            opts,
        })
    }

    /// Re-plan one conv stage for a shrunken live set: the same layer
    /// and `(k_A, k_B)` partition, re-coded for `live.len()` workers and
    /// dispatched onto the physical ids in `live` (ascending). The
    /// filters are re-encoded against the new code (model weights are
    /// master-resident, so this is a master-local operation — the
    /// paper's flexibility property: n is a free parameter of the code,
    /// not of the partition). Errors if the shrunken cluster cannot
    /// reach the stage's recovery threshold or the code family rejects
    /// the new n; the caller degrades to local execution in that case.
    pub fn replan_stage(&self, stage: usize, live: &[usize]) -> Result<StageVariant> {
        ensure!(!live.is_empty(), "replan: empty live set");
        ensure!(
            live.windows(2).all(|w| w[0] < w[1]),
            "replan: live set must be strictly ascending"
        );
        let s = &self.stages[stage];
        let spec = s.plan.spec();
        ensure!(
            live.len() >= spec.delta(),
            "replan: {} live workers cannot reach delta={}",
            live.len(),
            spec.delta()
        );
        let Layer::Conv { shape, weights, .. } = &self.net.layers[s.layer_idx] else {
            bail!("stage {stage} does not point at a conv layer");
        };
        let code = self.opts.code.build(spec.k_a, spec.k_b, live.len())?;
        // Deliberately NOT with_inverse_cache: see [`StageVariant`].
        let plan = FcdccPlan::with_code(shape, code)?
            .with_arena(Arc::clone(&self.arena))
            .with_prepack(self.opts.prepack);
        let coded_filters = plan.encode_filters(weights);
        Ok(StageVariant {
            plan,
            coded_filters,
            worker_map: live.to_vec(),
        })
    }

    /// Run one conv stage on the master — the graceful-degradation
    /// fallback when the live set cannot reach the stage's recovery
    /// threshold. Plain uncoded convolution of the full layer, bitwise
    /// identical to the reference forward pass (the bias epilogue is
    /// applied by `absorb_conv_output`, exactly as for decoded outputs).
    pub fn run_stage_local(&self, stage: usize, x: &Tensor3) -> Tensor3 {
        let s = &self.stages[stage];
        let Layer::Conv { shape, weights, .. } = &self.net.layers[s.layer_idx] else {
            unreachable!("every stage points at a conv layer");
        };
        conv2d(x, weights, shape.params())
    }

    pub fn network(&self) -> &Network {
        &self.net
    }

    pub fn stages(&self) -> &[ConvStage] {
        &self.stages
    }

    /// Hit/miss counters of the shared recovery-inverse cache. `misses`
    /// is exactly the number of recovery-matrix inversions performed
    /// across every decode of every stage of this plan.
    pub fn inverse_cache_stats(&self) -> CacheStats {
        self.inverse_cache.stats()
    }

    /// Hit/miss counters of the shared slab arena. `misses` is exactly
    /// the number of hot-path heap allocations (encode slabs, reply
    /// blocks, decode staging) across every stage; in steady-state
    /// serving everything after warm-up should be a hit.
    pub fn arena_stats(&self) -> CacheStats {
        self.arena.stats()
    }

    /// Total filter-slab GEMM packs performed by workers across every
    /// stage. With prepacking on (the default) this stays **zero**: the
    /// panels were packed once at plan build and are plan-resident.
    pub fn filter_packs(&self) -> u64 {
        self.arena.filter_packs()
    }

    /// Encode-pass accounting of the program-compiled input encoder,
    /// accumulated across every stage: coded slabs built, coefficient
    /// terms applied, and the dense-scan slot count the compiled
    /// programs avoided visiting.
    pub fn encode_stats(&self) -> EncodeStats {
        self.arena.encode_stats()
    }

    /// The slab arena shared by every stage of this plan.
    pub fn arena(&self) -> &Arc<SlabArena> {
        &self.arena
    }

    /// Advance `a` through master-side (non-conv) layers starting at
    /// `*layer_idx`. Returns the stage index of the next conv layer (with
    /// `*layer_idx` pointing at that conv), or `None` when the pass
    /// finished (`*layer_idx` one past the end).
    pub fn run_local(&self, a: &mut Activation, layer_idx: &mut usize) -> Option<usize> {
        // A group of one: apply_local_batch short-circuits size-<=1
        // groups to apply_local, so this is the same arithmetic with the
        // layer-walk invariant kept in exactly one place.
        self.run_local_batch(&mut [a], layer_idx)
    }

    /// Advance a **group** of activations that share one layer cursor
    /// through master-side layers in lockstep: Dense layers of the FC
    /// head run as one shared packed GEMM
    /// (`Network::apply_local_batch`), so co-batched requests stream the
    /// weight matrices once per group instead of once per request.
    /// Grouped outputs are bit-identical to advancing each activation
    /// alone through [`Self::run_local`]. Returns the next conv stage
    /// (with `*layer_idx` at that conv) or `None` when the pass ends.
    pub fn run_local_batch(
        &self,
        acts: &mut [&mut Activation],
        layer_idx: &mut usize,
    ) -> Option<usize> {
        while *layer_idx < self.net.layers.len() {
            let layer = &self.net.layers[*layer_idx];
            if matches!(layer, Layer::Conv { .. }) {
                return Some(self.stage_at(*layer_idx));
            }
            self.net.apply_local_batch(layer, acts);
            *layer_idx += 1;
        }
        None
    }

    fn stage_at(&self, layer_idx: usize) -> usize {
        self.stages
            .iter()
            .position(|s| s.layer_idx == layer_idx)
            .expect("every conv layer was planned")
    }

    /// Fold a decoded conv output back into the activation (per-channel
    /// bias epilogue) and step past the conv layer.
    pub fn absorb_conv_output(
        &self,
        stage: usize,
        mut y: Tensor3,
        a: &mut Activation,
        layer_idx: &mut usize,
    ) {
        add_bias(&mut y, &self.stages[stage].bias);
        a.set_spatial(y);
        *layer_idx += 1;
    }

    /// Dispatch one coded job for a batch of conv inputs at `stage`
    /// (non-blocking) — the coalesced-serving submit path.
    pub fn submit_batch(
        &self,
        stage: usize,
        cluster: &mut Cluster,
        xs: &[&Tensor3],
        straggler: &StragglerModel,
        rng: &mut Rng,
    ) -> Result<JobHandle> {
        self.stages[stage].submit_batch(cluster, xs, straggler, rng)
    }

    /// Fold one decoded **batched** conv job back into its member
    /// requests: the i-th decoded sample goes to the i-th `(activation,
    /// layer cursor)` pair. The split-back half of the coalesced-serving
    /// path.
    pub fn absorb_batch_output(
        &self,
        stage: usize,
        ys: Vec<Tensor3>,
        members: &mut [(&mut Activation, &mut usize)],
    ) {
        assert_eq!(ys.len(), members.len(), "one decoded sample per member");
        for (y, (a, layer_idx)) in ys.into_iter().zip(members.iter_mut()) {
            self.absorb_conv_output(stage, y, a, layer_idx);
        }
    }

    /// One distributed forward pass, blocking per conv layer — the
    /// single-request path shared by tests and examples. Returns the
    /// logits plus one [`JobReport`] per conv stage.
    pub fn forward_distributed(
        &self,
        cluster: &mut Cluster,
        x: &Tensor3,
        straggler: &StragglerModel,
        rng: &mut Rng,
    ) -> Result<(Vec<f64>, Vec<JobReport>)> {
        let mut reports = Vec::with_capacity(self.stages.len());
        let mut a = Activation::new(x);
        let mut layer_idx = 0usize;
        while let Some(s) = self.run_local(&mut a, &mut layer_idx) {
            let handle = self.stages[s].submit(cluster, &a, straggler, rng)?;
            let (y, report) = cluster.wait(&self.stages[s].plan, handle)?;
            reports.push(report);
            self.absorb_conv_output(s, y, &mut a, &mut layer_idx);
        }
        Ok((a.into_logits(), reports))
    }

    /// Single-node reference forward pass (the fidelity oracle).
    pub fn forward_reference(&self, x: &Tensor3) -> Vec<f64> {
        self.net.forward(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Im2colEngine;
    use crate::util::mse;

    #[test]
    fn plans_lenet_and_matches_reference() {
        let net = Network::lenet5_random(31);
        let plan = NetworkPlan::new(net, &[(4, 2), (2, 2)], 4).unwrap();
        assert_eq!(plan.stages().len(), 2);
        let mut cluster = Cluster::new(4, Arc::new(Im2colEngine));
        let mut rng = Rng::new(1);
        let x = Tensor3::random(1, 32, 32, &mut rng);
        let want = plan.forward_reference(&x);
        let (got, reports) = plan
            .forward_distributed(&mut cluster, &x, &StragglerModel::None, &mut rng)
            .unwrap();
        cluster.shutdown();
        assert_eq!(reports.len(), 2);
        assert_eq!(got.len(), want.len());
        assert!(mse(&got, &want) < 1e-16);
        // Both conv stages decoded through the shared inverse cache.
        let cs = plan.inverse_cache_stats();
        assert_eq!(cs.lookups(), 2, "one decode per conv stage");
        // Prepacking is on by default: workers never packed a filter.
        assert_eq!(plan.filter_packs(), 0);
        // One program-walked encode pass per conv stage was counted.
        let es = plan.encode_stats();
        assert!(es.cols > 0, "encode passes must be counted");
        assert!(es.terms <= es.dense_terms);
    }

    #[test]
    fn no_prepack_option_falls_back_to_worker_side_packing() {
        let net = Network::lenet5_random(33);
        let opts = PlanOptions {
            prepack: false,
            ..PlanOptions::default()
        };
        let plan = NetworkPlan::with_options(net, &[(4, 2), (2, 2)], 4, opts).unwrap();
        for stage in plan.stages() {
            for rf in &stage.coded_filters {
                assert!(rf.packs.is_none(), "prepack=false must skip packing");
            }
        }
        let mut cluster = Cluster::new(4, Arc::new(Im2colEngine));
        let mut rng = Rng::new(2);
        let x = Tensor3::random(1, 32, 32, &mut rng);
        let want = plan.forward_reference(&x);
        let (got, _) = plan
            .forward_distributed(&mut cluster, &x, &StragglerModel::None, &mut rng)
            .unwrap();
        cluster.shutdown();
        assert!(mse(&got, &want) < 1e-16);
        assert!(plan.filter_packs() > 0, "fallback path packs per job");
    }

    #[test]
    fn replanned_stage_decodes_on_a_live_subset() {
        let net = Network::lenet5_random(34);
        let plan = NetworkPlan::new(net, &[(4, 2), (2, 2)], 4).unwrap();
        let mut cluster = Cluster::new(4, Arc::new(Im2colEngine));
        let mut rng = Rng::new(3);
        let x = Tensor3::random(1, 32, 32, &mut rng);

        // Walk to the first conv, then run it on a re-planned 2-worker
        // variant (delta for (4,2) at n=2 is still 2 — zero resilience,
        // but decodable) mapped onto physical workers {1, 3}.
        let mut a = Activation::new(&x);
        let mut layer_idx = 0usize;
        let stage = plan.run_local(&mut a, &mut layer_idx).unwrap();
        let variant = plan.replan_stage(stage, &[1, 3]).unwrap();
        assert_eq!(variant.plan.spec().n, 2);
        let xs = [a.spatial()];
        let handle = cluster
            .submit_batch_mapped(
                &variant.plan,
                &xs,
                &variant.coded_filters,
                &StragglerModel::None,
                &mut rng,
                Some(&variant.worker_map),
            )
            .unwrap();
        let (mut ys, report) = cluster.wait_batch(&variant.plan, handle).unwrap();
        assert!(report.used_workers.iter().all(|w| [1, 3].contains(w)));

        // The decoded conv must match the uncoded local fallback bitwise
        // (both equal the reference conv of this stage).
        assert_eq!(ys.len(), 1);
        let want = plan.run_stage_local(stage, a.spatial());
        let got = ys.pop().unwrap();
        assert!(mse(&got.data, &want.data) < 1e-18);

        // Finishing the pass through the degraded (local) path for the
        // remaining conv gives the reference logits exactly.
        plan.absorb_conv_output(stage, want, &mut a, &mut layer_idx);
        while let Some(s) = plan.run_local(&mut a, &mut layer_idx) {
            let y = plan.run_stage_local(s, a.spatial());
            plan.absorb_conv_output(s, y, &mut a, &mut layer_idx);
        }
        let logits = a.into_logits();
        let want_logits = plan.forward_reference(&x);
        assert_eq!(logits, want_logits, "degraded path must be bitwise exact");
        cluster.shutdown();
    }

    #[test]
    fn replan_below_delta_is_rejected() {
        let net = Network::lenet5_random(35);
        let plan = NetworkPlan::new(net, &[(4, 2), (2, 2)], 4).unwrap();
        // Stage 0 has delta=2: one live worker cannot reach it.
        assert!(plan.replan_stage(0, &[2]).is_err());
        // Stage 1 has delta=1: a single-worker re-plan is legal.
        let v = plan.replan_stage(1, &[2]).unwrap();
        assert_eq!(v.plan.spec().n, 1);
        assert_eq!(v.worker_map, vec![2]);
        // Live sets must be ascending physical ids.
        assert!(plan.replan_stage(1, &[3, 1]).is_err());
        assert!(plan.replan_stage(1, &[]).is_err());
    }

    #[test]
    fn partition_count_must_match_conv_count() {
        let net = Network::lenet5_random(32);
        assert!(NetworkPlan::new(net, &[(4, 2)], 4).is_err());
        let net = Network::lenet5_random(32);
        assert!(NetworkPlan::new(net, &[(4, 2), (2, 2), (2, 2)], 4).is_err());
    }
}
