//! Whole-network FCDCC planning: plan every conv layer of a [`Network`]
//! **once** — an [`FcdccPlan`] plus `Arc`-shared resident coded filter
//! slabs per layer — and own the forward-pass walk over the layer
//! sequence. Both the blocking single-request path
//! ([`NetworkPlan::forward_distributed`]) and the pipelined request
//! scheduler (`coordinator::serve`) are built from the same two steps:
//! [`NetworkPlan::run_local`] advances an [`Activation`] through
//! master-side layers up to the next conv, and
//! [`NetworkPlan::absorb_conv_output`] folds a decoded conv job's output
//! back in. That keeps the layer semantics in exactly one place
//! (`Network::apply_local`) instead of the two near-identical loops the
//! pre-runtime code carried.

use crate::cluster::{Cluster, JobHandle, JobReport, StragglerModel};
use crate::coding::{registry, CodeFamily};
use crate::fcdcc::inverse_cache::{InverseCache, DEFAULT_INVERSE_CACHE_CAP};
use crate::fcdcc::scratch::{SlabArena, DEFAULT_ARENA_CAP};
use crate::fcdcc::{FcdccPlan, ResidentFilters};
use crate::metrics::{CacheStats, EncodeStats};
use crate::model::network::add_bias;
use crate::model::{Activation, Layer, Network};
use crate::tensor::Tensor3;
use crate::util::rng::Rng;
use anyhow::{ensure, Result};
use std::sync::Arc;

/// Build-time knobs for [`NetworkPlan`]. The defaults are the paper's
/// steady-state serving model: filters prepacked into GEMM panels at
/// plan-build time, slab buffers pooled in a shared arena.
#[derive(Clone, Copy, Debug)]
pub struct PlanOptions {
    /// Pack every coded filter slab into GEMM-ready panels once at plan
    /// build; workers then contract resident packed panels directly
    /// (`--no-prepack` in the CLI flips this off for A/B measurement).
    pub prepack: bool,
    /// Capacity (buffer count) of the shared slab arena.
    pub arena_capacity: usize,
    /// Code family every conv stage is planned with. Defaults to the
    /// session's selected family (`--code` / `FCDCC_CODE`, else CRME).
    pub code: CodeFamily,
}

impl Default for PlanOptions {
    fn default() -> Self {
        Self {
            prepack: true,
            arena_capacity: DEFAULT_ARENA_CAP,
            code: registry::default_family(),
        }
    }
}

/// One planned conv layer: code/geometry plan, resident coded filters
/// (encoded once at model load — slabs plus, when prepacking is on,
/// their GEMM-ready packed panels — shared across every request), bias.
pub struct ConvStage {
    pub plan: FcdccPlan,
    pub coded_filters: Vec<ResidentFilters>,
    pub bias: Vec<f64>,
    /// Index of this conv in the network's layer sequence.
    pub layer_idx: usize,
}

impl ConvStage {
    /// Dispatch this stage's coded job for one activation (non-blocking).
    pub fn submit(
        &self,
        cluster: &mut Cluster,
        a: &Activation,
        straggler: &StragglerModel,
        rng: &mut Rng,
    ) -> Result<JobHandle> {
        cluster.submit(&self.plan, a.spatial(), &self.coded_filters, straggler, rng)
    }

    /// Dispatch one coded job carrying a batch of activations — the
    /// coalesced-serving path (non-blocking).
    pub fn submit_batch(
        &self,
        cluster: &mut Cluster,
        xs: &[&Tensor3],
        straggler: &StragglerModel,
        rng: &mut Rng,
    ) -> Result<JobHandle> {
        cluster.submit_batch(&self.plan, xs, &self.coded_filters, straggler, rng)
    }
}

/// A network compiled against a coded cluster: per-conv [`ConvStage`]s
/// plus the shared forward-pass walk. All stages decode through one
/// shared recovery-inverse cache, keyed by `(stage_idx, worker subset)`.
pub struct NetworkPlan {
    net: Network,
    stages: Vec<ConvStage>,
    inverse_cache: Arc<InverseCache>,
    /// Slab arena shared by every stage: encode slabs, worker reply
    /// blocks, and decode staging buffers all draw from (and return to)
    /// this one pool, so stages at the same geometry reuse each other's
    /// buffers and differing sizes coexist.
    arena: Arc<SlabArena>,
}

impl NetworkPlan {
    /// Plan every conv layer of `net` with the given per-conv `(k_A,
    /// k_B)` partitions on an `n_workers` cluster, encoding each filter
    /// bank once (the paper's steady-state model: coded filter slabs are
    /// resident on the workers across requests). Uses the default
    /// [`PlanOptions`]: filters prepacked, arena-pooled buffers.
    pub fn new(net: Network, partitions: &[(usize, usize)], n_workers: usize) -> Result<Self> {
        Self::with_options(net, partitions, n_workers, PlanOptions::default())
    }

    /// [`Self::new`] with explicit build-time knobs.
    pub fn with_options(
        net: Network,
        partitions: &[(usize, usize)],
        n_workers: usize,
        opts: PlanOptions,
    ) -> Result<Self> {
        let inverse_cache = Arc::new(InverseCache::new(DEFAULT_INVERSE_CACHE_CAP));
        let arena = Arc::new(SlabArena::new(opts.arena_capacity));
        let mut stages = Vec::new();
        for (layer_idx, layer) in net.layers.iter().enumerate() {
            if let Layer::Conv {
                shape,
                weights,
                bias,
            } = layer
            {
                ensure!(
                    stages.len() < partitions.len(),
                    "network has more conv layers than (k_A,k_B) pairs"
                );
                let (k_a, k_b) = partitions[stages.len()];
                let stage_idx = stages.len();
                let code = opts.code.build(k_a, k_b, n_workers)?;
                let plan = FcdccPlan::with_code(shape, code)?
                    .with_inverse_cache(Arc::clone(&inverse_cache), stage_idx)
                    .with_arena(Arc::clone(&arena))
                    .with_prepack(opts.prepack);
                let coded_filters = plan.encode_filters(weights);
                stages.push(ConvStage {
                    plan,
                    coded_filters,
                    bias: bias.clone(),
                    layer_idx,
                });
            }
        }
        ensure!(
            stages.len() == partitions.len(),
            "got {} (k_A,k_B) pairs for {} conv layers",
            partitions.len(),
            stages.len()
        );
        Ok(Self {
            net,
            stages,
            inverse_cache,
            arena,
        })
    }

    pub fn network(&self) -> &Network {
        &self.net
    }

    pub fn stages(&self) -> &[ConvStage] {
        &self.stages
    }

    /// Hit/miss counters of the shared recovery-inverse cache. `misses`
    /// is exactly the number of recovery-matrix inversions performed
    /// across every decode of every stage of this plan.
    pub fn inverse_cache_stats(&self) -> CacheStats {
        self.inverse_cache.stats()
    }

    /// Hit/miss counters of the shared slab arena. `misses` is exactly
    /// the number of hot-path heap allocations (encode slabs, reply
    /// blocks, decode staging) across every stage; in steady-state
    /// serving everything after warm-up should be a hit.
    pub fn arena_stats(&self) -> CacheStats {
        self.arena.stats()
    }

    /// Total filter-slab GEMM packs performed by workers across every
    /// stage. With prepacking on (the default) this stays **zero**: the
    /// panels were packed once at plan build and are plan-resident.
    pub fn filter_packs(&self) -> u64 {
        self.arena.filter_packs()
    }

    /// Encode-pass accounting of the program-compiled input encoder,
    /// accumulated across every stage: coded slabs built, coefficient
    /// terms applied, and the dense-scan slot count the compiled
    /// programs avoided visiting.
    pub fn encode_stats(&self) -> EncodeStats {
        self.arena.encode_stats()
    }

    /// The slab arena shared by every stage of this plan.
    pub fn arena(&self) -> &Arc<SlabArena> {
        &self.arena
    }

    /// Advance `a` through master-side (non-conv) layers starting at
    /// `*layer_idx`. Returns the stage index of the next conv layer (with
    /// `*layer_idx` pointing at that conv), or `None` when the pass
    /// finished (`*layer_idx` one past the end).
    pub fn run_local(&self, a: &mut Activation, layer_idx: &mut usize) -> Option<usize> {
        // A group of one: apply_local_batch short-circuits size-<=1
        // groups to apply_local, so this is the same arithmetic with the
        // layer-walk invariant kept in exactly one place.
        self.run_local_batch(&mut [a], layer_idx)
    }

    /// Advance a **group** of activations that share one layer cursor
    /// through master-side layers in lockstep: Dense layers of the FC
    /// head run as one shared packed GEMM
    /// (`Network::apply_local_batch`), so co-batched requests stream the
    /// weight matrices once per group instead of once per request.
    /// Grouped outputs are bit-identical to advancing each activation
    /// alone through [`Self::run_local`]. Returns the next conv stage
    /// (with `*layer_idx` at that conv) or `None` when the pass ends.
    pub fn run_local_batch(
        &self,
        acts: &mut [&mut Activation],
        layer_idx: &mut usize,
    ) -> Option<usize> {
        while *layer_idx < self.net.layers.len() {
            let layer = &self.net.layers[*layer_idx];
            if matches!(layer, Layer::Conv { .. }) {
                return Some(self.stage_at(*layer_idx));
            }
            self.net.apply_local_batch(layer, acts);
            *layer_idx += 1;
        }
        None
    }

    fn stage_at(&self, layer_idx: usize) -> usize {
        self.stages
            .iter()
            .position(|s| s.layer_idx == layer_idx)
            .expect("every conv layer was planned")
    }

    /// Fold a decoded conv output back into the activation (per-channel
    /// bias epilogue) and step past the conv layer.
    pub fn absorb_conv_output(
        &self,
        stage: usize,
        mut y: Tensor3,
        a: &mut Activation,
        layer_idx: &mut usize,
    ) {
        add_bias(&mut y, &self.stages[stage].bias);
        a.set_spatial(y);
        *layer_idx += 1;
    }

    /// Dispatch one coded job for a batch of conv inputs at `stage`
    /// (non-blocking) — the coalesced-serving submit path.
    pub fn submit_batch(
        &self,
        stage: usize,
        cluster: &mut Cluster,
        xs: &[&Tensor3],
        straggler: &StragglerModel,
        rng: &mut Rng,
    ) -> Result<JobHandle> {
        self.stages[stage].submit_batch(cluster, xs, straggler, rng)
    }

    /// Fold one decoded **batched** conv job back into its member
    /// requests: the i-th decoded sample goes to the i-th `(activation,
    /// layer cursor)` pair. The split-back half of the coalesced-serving
    /// path.
    pub fn absorb_batch_output(
        &self,
        stage: usize,
        ys: Vec<Tensor3>,
        members: &mut [(&mut Activation, &mut usize)],
    ) {
        assert_eq!(ys.len(), members.len(), "one decoded sample per member");
        for (y, (a, layer_idx)) in ys.into_iter().zip(members.iter_mut()) {
            self.absorb_conv_output(stage, y, a, layer_idx);
        }
    }

    /// One distributed forward pass, blocking per conv layer — the
    /// single-request path shared by tests and examples. Returns the
    /// logits plus one [`JobReport`] per conv stage.
    pub fn forward_distributed(
        &self,
        cluster: &mut Cluster,
        x: &Tensor3,
        straggler: &StragglerModel,
        rng: &mut Rng,
    ) -> Result<(Vec<f64>, Vec<JobReport>)> {
        let mut reports = Vec::with_capacity(self.stages.len());
        let mut a = Activation::new(x);
        let mut layer_idx = 0usize;
        while let Some(s) = self.run_local(&mut a, &mut layer_idx) {
            let handle = self.stages[s].submit(cluster, &a, straggler, rng)?;
            let (y, report) = cluster.wait(&self.stages[s].plan, handle)?;
            reports.push(report);
            self.absorb_conv_output(s, y, &mut a, &mut layer_idx);
        }
        Ok((a.into_logits(), reports))
    }

    /// Single-node reference forward pass (the fidelity oracle).
    pub fn forward_reference(&self, x: &Tensor3) -> Vec<f64> {
        self.net.forward(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Im2colEngine;
    use crate::util::mse;

    #[test]
    fn plans_lenet_and_matches_reference() {
        let net = Network::lenet5_random(31);
        let plan = NetworkPlan::new(net, &[(4, 2), (2, 2)], 4).unwrap();
        assert_eq!(plan.stages().len(), 2);
        let mut cluster = Cluster::new(4, Arc::new(Im2colEngine));
        let mut rng = Rng::new(1);
        let x = Tensor3::random(1, 32, 32, &mut rng);
        let want = plan.forward_reference(&x);
        let (got, reports) = plan
            .forward_distributed(&mut cluster, &x, &StragglerModel::None, &mut rng)
            .unwrap();
        cluster.shutdown();
        assert_eq!(reports.len(), 2);
        assert_eq!(got.len(), want.len());
        assert!(mse(&got, &want) < 1e-16);
        // Both conv stages decoded through the shared inverse cache.
        let cs = plan.inverse_cache_stats();
        assert_eq!(cs.lookups(), 2, "one decode per conv stage");
        // Prepacking is on by default: workers never packed a filter.
        assert_eq!(plan.filter_packs(), 0);
        // One program-walked encode pass per conv stage was counted.
        let es = plan.encode_stats();
        assert!(es.cols > 0, "encode passes must be counted");
        assert!(es.terms <= es.dense_terms);
    }

    #[test]
    fn no_prepack_option_falls_back_to_worker_side_packing() {
        let net = Network::lenet5_random(33);
        let opts = PlanOptions {
            prepack: false,
            ..PlanOptions::default()
        };
        let plan = NetworkPlan::with_options(net, &[(4, 2), (2, 2)], 4, opts).unwrap();
        for stage in plan.stages() {
            for rf in &stage.coded_filters {
                assert!(rf.packs.is_none(), "prepack=false must skip packing");
            }
        }
        let mut cluster = Cluster::new(4, Arc::new(Im2colEngine));
        let mut rng = Rng::new(2);
        let x = Tensor3::random(1, 32, 32, &mut rng);
        let want = plan.forward_reference(&x);
        let (got, _) = plan
            .forward_distributed(&mut cluster, &x, &StragglerModel::None, &mut rng)
            .unwrap();
        cluster.shutdown();
        assert!(mse(&got, &want) < 1e-16);
        assert!(plan.filter_packs() > 0, "fallback path packs per job");
    }

    #[test]
    fn partition_count_must_match_conv_count() {
        let net = Network::lenet5_random(32);
        assert!(NetworkPlan::new(net, &[(4, 2)], 4).is_err());
        let net = Network::lenet5_random(32);
        assert!(NetworkPlan::new(net, &[(4, 2), (2, 2), (2, 2)], 4).is_err());
    }
}
