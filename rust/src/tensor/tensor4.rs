//! 4-D filter tensor (N × C × K_H × K_W, row-major) — the paper's filter
//! bank K (Table I).

use crate::tensor::Tensor3;
use crate::util::rng::Rng;

/// Dense f64 filter tensor with shape (n, c, kh, kw), row-major.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor4 {
    pub n: usize,
    pub c: usize,
    pub kh: usize,
    pub kw: usize,
    pub data: Vec<f64>,
}

impl Tensor4 {
    pub fn zeros(n: usize, c: usize, kh: usize, kw: usize) -> Self {
        Self {
            n,
            c,
            kh,
            kw,
            data: vec![0.0; n * c * kh * kw],
        }
    }

    pub fn from_vec(n: usize, c: usize, kh: usize, kw: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), n * c * kh * kw, "Tensor4::from_vec: size mismatch");
        Self { n, c, kh, kw, data }
    }

    pub fn random(n: usize, c: usize, kh: usize, kw: usize, rng: &mut Rng) -> Self {
        Self {
            n,
            c,
            kh,
            kw,
            data: rng.fill_uniform(n * c * kh * kw, -1.0, 1.0),
        }
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize, usize, usize) {
        (self.n, self.c, self.kh, self.kw)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn idx(&self, n: usize, c: usize, i: usize, j: usize) -> usize {
        debug_assert!(n < self.n && c < self.c && i < self.kh && j < self.kw);
        ((n * self.c + c) * self.kh + i) * self.kw + j
    }

    #[inline]
    pub fn get(&self, n: usize, c: usize, i: usize, j: usize) -> f64 {
        self.data[self.idx(n, c, i, j)]
    }

    #[inline]
    pub fn set(&mut self, n: usize, c: usize, i: usize, j: usize, v: f64) {
        let k = self.idx(n, c, i, j);
        self.data[k] = v;
    }

    /// Filters [v, e) along the output-channel axis — KCCP's partition
    /// primitive (paper eq. (33)).
    pub fn slice_n(&self, v: usize, e: usize) -> Self {
        assert!(v <= e && e <= self.n, "slice_n: bad range {v}..{e} (n={})", self.n);
        let per = self.c * self.kh * self.kw;
        Self {
            n: e - v,
            c: self.c,
            kh: self.kh,
            kw: self.kw,
            data: self.data[v * per..e * per].to_vec(),
        }
    }

    /// Concatenate filter banks along the output-channel axis.
    pub fn concat_n(parts: &[&Tensor4]) -> Self {
        assert!(!parts.is_empty());
        let (c, kh, kw) = (parts[0].c, parts[0].kh, parts[0].kw);
        assert!(
            parts.iter().all(|t| t.c == c && t.kh == kh && t.kw == kw),
            "concat_n: shape mismatch"
        );
        let n: usize = parts.iter().map(|t| t.n).sum();
        let mut data = Vec::with_capacity(n * c * kh * kw);
        for t in parts {
            data.extend_from_slice(&t.data);
        }
        Self { n, c, kh, kw, data }
    }

    /// View filter `n` as a 3-D tensor (C × K_H × K_W).
    pub fn filter(&self, n: usize) -> Tensor3 {
        let per = self.c * self.kh * self.kw;
        Tensor3::from_vec(
            self.c,
            self.kh,
            self.kw,
            self.data[n * per..(n + 1) * per].to_vec(),
        )
    }

    /// a ← a + s·b (same shape) — the coded-combination primitive used by
    /// KCCP encoding (paper eq. (37)). Rides the runtime-dispatched
    /// SIMD axpy (`linalg::kernel`), bit-identical to the scalar loop
    /// on the default path.
    pub fn axpy(&mut self, s: f64, other: &Tensor4) {
        assert_eq!(self.shape(), other.shape(), "axpy: shape mismatch");
        crate::linalg::kernel::axpy(s, &other.data, &mut self.data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(n: usize, c: usize, kh: usize, kw: usize) -> Tensor4 {
        Tensor4::from_vec(n, c, kh, kw, (0..n * c * kh * kw).map(|i| i as f64).collect())
    }

    #[test]
    fn indexing_row_major() {
        let t = seq(2, 3, 2, 2);
        assert_eq!(t.get(0, 0, 0, 0), 0.0);
        assert_eq!(t.get(0, 0, 1, 1), 3.0);
        assert_eq!(t.get(0, 1, 0, 0), 4.0);
        assert_eq!(t.get(1, 0, 0, 0), 12.0);
        assert_eq!(t.get(1, 2, 1, 1), 23.0);
    }

    #[test]
    fn slice_concat_n_roundtrip() {
        let t = seq(6, 2, 3, 3);
        let a = t.slice_n(0, 2);
        let b = t.slice_n(2, 6);
        assert_eq!(Tensor4::concat_n(&[&a, &b]), t);
    }

    #[test]
    fn filter_view() {
        let t = seq(3, 2, 2, 2);
        let f = t.filter(1);
        assert_eq!(f.shape(), (2, 2, 2));
        assert_eq!(f.get(0, 0, 0), 8.0);
        assert_eq!(f.get(1, 1, 1), 15.0);
    }
}
