//! 3-D tensor (C × H × W, row-major) — the paper's input / output feature
//! map representation (Table I: X ∈ R^{C×(H+2p)×(W+2p)}, Y ∈ R^{N×H'×W'}).

use crate::util::rng::Rng;

/// Dense f64 tensor with shape (c, h, w), laid out row-major
/// (w fastest, then h, then c).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor3 {
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub data: Vec<f64>,
}

impl Tensor3 {
    pub fn zeros(c: usize, h: usize, w: usize) -> Self {
        Self {
            c,
            h,
            w,
            data: vec![0.0; c * h * w],
        }
    }

    pub fn from_vec(c: usize, h: usize, w: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), c * h * w, "Tensor3::from_vec: size mismatch");
        Self { c, h, w, data }
    }

    /// Fill with iid uniform values in [-1, 1) — the synthetic workload
    /// generator used throughout the benches.
    pub fn random(c: usize, h: usize, w: usize, rng: &mut Rng) -> Self {
        Self {
            c,
            h,
            w,
            data: rng.fill_uniform(c * h * w, -1.0, 1.0),
        }
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize, usize) {
        (self.c, self.h, self.w)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn idx(&self, c: usize, h: usize, w: usize) -> usize {
        debug_assert!(c < self.c && h < self.h && w < self.w);
        (c * self.h + h) * self.w + w
    }

    #[inline]
    pub fn get(&self, c: usize, h: usize, w: usize) -> f64 {
        self.data[self.idx(c, h, w)]
    }

    #[inline]
    pub fn set(&mut self, c: usize, h: usize, w: usize, v: f64) {
        let i = self.idx(c, h, w);
        self.data[i] = v;
    }

    /// Row (c, h, ·) as a contiguous slice — the streaming unit of the
    /// fused batch encoder.
    #[inline]
    pub fn row(&self, c: usize, h: usize) -> &[f64] {
        let i = self.idx(c, h, 0);
        &self.data[i..i + self.w]
    }

    /// Mutable row (c, h, ·) as a contiguous slice.
    #[inline]
    pub fn row_mut(&mut self, c: usize, h: usize) -> &mut [f64] {
        let i = self.idx(c, h, 0);
        let w = self.w;
        &mut self.data[i..i + w]
    }

    /// Zero-pad spatially by `p` on every side (paper's input padding).
    pub fn pad_spatial(&self, p: usize) -> Self {
        if p == 0 {
            return self.clone();
        }
        let mut out = Self::zeros(self.c, self.h + 2 * p, self.w + 2 * p);
        for c in 0..self.c {
            for h in 0..self.h {
                let src = self.idx(c, h, 0);
                let dst = out.idx(c, h + p, p);
                out.data[dst..dst + self.w].copy_from_slice(&self.data[src..src + self.w]);
            }
        }
        out
    }

    /// Zero-pad only at the bottom of the H axis (used by APCP to extend
    /// H' to a multiple of k_A).
    pub fn pad_bottom(&self, extra_h: usize) -> Self {
        if extra_h == 0 {
            return self.clone();
        }
        let mut out = Self::zeros(self.c, self.h + extra_h, self.w);
        for c in 0..self.c {
            let src = self.idx(c, 0, 0);
            let dst = out.idx(c, 0, 0);
            out.data[dst..dst + self.h * self.w]
                .copy_from_slice(&self.data[src..src + self.h * self.w]);
        }
        out
    }

    /// Contiguous slab along H: rows [v, e) of every channel — the paper's
    /// T[:, v:e, :] partition primitive (eq. (26), applied to axis H).
    pub fn slice_h(&self, v: usize, e: usize) -> Self {
        assert!(v <= e && e <= self.h, "slice_h: bad range {v}..{e} (h={})", self.h);
        let nh = e - v;
        let mut out = Self::zeros(self.c, nh, self.w);
        for c in 0..self.c {
            let src = self.idx(c, v, 0);
            let dst = out.idx(c, 0, 0);
            out.data[dst..dst + nh * self.w]
                .copy_from_slice(&self.data[src..src + nh * self.w]);
        }
        out
    }

    /// Slab along the channel axis: channels [v, e).
    pub fn slice_c(&self, v: usize, e: usize) -> Self {
        assert!(v <= e && e <= self.c, "slice_c: bad range {v}..{e} (c={})", self.c);
        let nc = e - v;
        let plane = self.h * self.w;
        Self {
            c: nc,
            h: self.h,
            w: self.w,
            data: self.data[v * plane..e * plane].to_vec(),
        }
    }

    /// Concatenate along the channel axis (paper's concat_axis=0).
    pub fn concat_c(parts: &[&Tensor3]) -> Self {
        assert!(!parts.is_empty());
        let (h, w) = (parts[0].h, parts[0].w);
        assert!(
            parts.iter().all(|t| t.h == h && t.w == w),
            "concat_c: spatial shape mismatch"
        );
        let c: usize = parts.iter().map(|t| t.c).sum();
        let mut data = Vec::with_capacity(c * h * w);
        for t in parts {
            data.extend_from_slice(&t.data);
        }
        Self { c, h, w, data }
    }

    /// Concatenate along the height axis (paper's concat_axis=1).
    pub fn concat_h(parts: &[&Tensor3]) -> Self {
        assert!(!parts.is_empty());
        let (c, w) = (parts[0].c, parts[0].w);
        assert!(
            parts.iter().all(|t| t.c == c && t.w == w),
            "concat_h: shape mismatch"
        );
        let h: usize = parts.iter().map(|t| t.h).sum();
        let mut out = Self::zeros(c, h, w);
        for ci in 0..c {
            let mut hoff = 0usize;
            for t in parts {
                let src = t.idx(ci, 0, 0);
                let dst = out.idx(ci, hoff, 0);
                out.data[dst..dst + t.h * w].copy_from_slice(&t.data[src..src + t.h * w]);
                hoff += t.h;
            }
        }
        out
    }

    /// In-place saturating ReLU (used by the CNN forward pass).
    pub fn relu_inplace(&mut self) {
        for v in &mut self.data {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }

    /// a ← a + s·b (same shape); the coded-combination primitive for
    /// tensor-block-list × matrix multiplication (paper eq. (18)).
    /// Rides the runtime-dispatched SIMD axpy (`linalg::kernel`) —
    /// per element the scalar `a += s·b` sequence, so dispatch never
    /// changes results on the default path.
    pub fn axpy(&mut self, s: f64, other: &Tensor3) {
        assert_eq!(self.shape(), other.shape(), "axpy: shape mismatch");
        crate::linalg::kernel::axpy(s, &other.data, &mut self.data);
    }

    pub fn scale(&mut self, s: f64) {
        for v in &mut self.data {
            *v *= s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(c: usize, h: usize, w: usize) -> Tensor3 {
        Tensor3::from_vec(c, h, w, (0..c * h * w).map(|i| i as f64).collect())
    }

    #[test]
    fn indexing_row_major() {
        let t = seq(2, 3, 4);
        assert_eq!(t.get(0, 0, 0), 0.0);
        assert_eq!(t.get(0, 0, 3), 3.0);
        assert_eq!(t.get(0, 1, 0), 4.0);
        assert_eq!(t.get(1, 0, 0), 12.0);
        assert_eq!(t.get(1, 2, 3), 23.0);
    }

    #[test]
    fn row_views_match_indexing() {
        let mut t = seq(2, 3, 4);
        assert_eq!(t.row(1, 2), &[20.0, 21.0, 22.0, 23.0]);
        t.row_mut(0, 1)[2] = -1.0;
        assert_eq!(t.get(0, 1, 2), -1.0);
    }

    #[test]
    fn pad_spatial_places_interior() {
        let t = seq(1, 2, 2);
        let p = t.pad_spatial(1);
        assert_eq!(p.shape(), (1, 4, 4));
        assert_eq!(p.get(0, 0, 0), 0.0);
        assert_eq!(p.get(0, 1, 1), 0.0); // original (0,0,0)=0
        assert_eq!(p.get(0, 1, 2), 1.0);
        assert_eq!(p.get(0, 2, 1), 2.0);
        assert_eq!(p.get(0, 2, 2), 3.0);
        assert_eq!(p.get(0, 3, 3), 0.0);
    }

    #[test]
    fn slice_concat_h_roundtrip() {
        let t = seq(2, 6, 3);
        let a = t.slice_h(0, 2);
        let b = t.slice_h(2, 5);
        let c = t.slice_h(5, 6);
        let r = Tensor3::concat_h(&[&a, &b, &c]);
        assert_eq!(r, t);
    }

    #[test]
    fn slice_concat_c_roundtrip() {
        let t = seq(4, 2, 3);
        let a = t.slice_c(0, 1);
        let b = t.slice_c(1, 4);
        let r = Tensor3::concat_c(&[&a, &b]);
        assert_eq!(r, t);
    }

    #[test]
    fn pad_bottom_keeps_content() {
        let t = seq(2, 2, 2);
        let p = t.pad_bottom(3);
        assert_eq!(p.shape(), (2, 5, 2));
        assert_eq!(p.slice_h(0, 2), t);
        assert!(p.slice_h(2, 5).data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn axpy_linear() {
        let a0 = seq(1, 2, 2);
        let b = seq(1, 2, 2);
        let mut a = a0.clone();
        a.axpy(2.0, &b);
        for i in 0..4 {
            assert_eq!(a.data[i], a0.data[i] + 2.0 * b.data[i]);
        }
    }

    #[test]
    fn relu_clamps() {
        let mut t = Tensor3::from_vec(1, 1, 3, vec![-1.0, 0.0, 2.0]);
        t.relu_inplace();
        assert_eq!(t.data, vec![0.0, 0.0, 2.0]);
    }
}
