//! im2col convolution: lowers conv to a (N × CK_HK_W)·(CK_HK_W × H'W')
//! GEMM. This is the optimized CPU worker path (and the algorithm RSPCC
//! builds its codes around — here it is just one interchangeable black-box
//! conv implementation, per the paper's generality claim).
//!
//! The two halves are exposed separately: [`im2col_into`] builds the
//! patch matrix into a caller-owned buffer, and [`conv2d_from_patch`]
//! runs the GEMM against it. A coded worker subtask convolves the *same*
//! input slab with ℓ_B filter slabs (and every slab of a batched payload
//! shares one shape), so `WorkerPayload::run_im2col` builds each patch
//! matrix once, reuses it across all ℓ_B GEMMs, and reuses the buffer
//! allocation across the whole batch. [`conv2d_im2col`] is the one-shot
//! composition of the two halves.

use crate::linalg::gemm;
use crate::tensor::{conv2d_shape, ConvParams, Tensor3, Tensor4};

/// Build the im2col patch matrix into `buf` (resized to fit, previous
/// contents irrelevant — every element is overwritten): (C·K_H·K_W) ×
/// (H'·W'), column-major over output positions (column = output pixel
/// (h,w), row = (c,i,j) patch slot). Returns `(rows, cols)`.
pub fn im2col_into(
    x: &Tensor3,
    kh: usize,
    kw: usize,
    p: ConvParams,
    buf: &mut Vec<f64>,
) -> (usize, usize) {
    let xp;
    let x = if p.pad > 0 {
        xp = x.pad_spatial(p.pad);
        &xp
    } else {
        x
    };
    let (oh, ow) = ((x.h - kh) / p.stride + 1, (x.w - kw) / p.stride + 1);
    let rows = x.c * kh * kw;
    let cols = oh * ow;
    // Every element of the rows·cols matrix is written below, so stale
    // data from a previous (same-shape) use never needs zeroing out.
    buf.resize(rows * cols, 0.0);
    let m = &mut buf[..rows * cols];
    for c in 0..x.c {
        for i in 0..kh {
            for j in 0..kw {
                let r = (c * kh + i) * kw + j;
                let row_base = r * cols;
                for h in 0..oh {
                    let src = x.idx(c, h * p.stride + i, j);
                    let dst = row_base + h * ow;
                    if p.stride == 1 {
                        m[dst..dst + ow].copy_from_slice(&x.data[src..src + ow]);
                    } else {
                        for w in 0..ow {
                            m[dst + w] = x.data[src + w * p.stride];
                        }
                    }
                }
            }
        }
    }
    (rows, cols)
}

/// Build the im2col patch matrix in a fresh buffer (see [`im2col_into`]).
pub fn im2col(x: &Tensor3, kh: usize, kw: usize, p: ConvParams) -> (Vec<f64>, usize, usize) {
    let mut buf = Vec::new();
    let (rows, cols) = im2col_into(x, kh, kw, p, &mut buf);
    (buf, rows, cols)
}

/// The GEMM half: contract a prebuilt patch matrix against the filter
/// bank `k`, producing the (N × H' × W') output. `rows`/`cols` are the
/// patch-matrix dims returned by [`im2col_into`]; `(oh, ow)` the output
/// spatial dims (`oh·ow == cols`).
pub fn conv2d_from_patch(
    patch: &[f64],
    rows: usize,
    cols: usize,
    k: &Tensor4,
    oh: usize,
    ow: usize,
) -> Tensor3 {
    debug_assert_eq!(rows, k.c * k.kh * k.kw);
    debug_assert_eq!(cols, oh * ow);
    debug_assert_eq!(patch.len(), rows * cols);
    // GEMM: out[n, pix] = sum_r K[n, r] * M[r, pix], on the shared
    // packed register-tiled microkernel (linalg::gemm, running the
    // runtime-dispatched SIMD backend — bit-identical across scalar/
    // AVX2/NEON). K is already laid out row-major as (N × rows); the
    // patch matrix is the panel-packed B operand, streamed from memory
    // once per column panel instead of once per output channel.
    let mut out = vec![0.0f64; k.n * cols];
    gemm::gemm_into(
        k.n,
        cols,
        rows,
        &gemm::RowMajor {
            data: &k.data,
            ld: rows.max(1),
        },
        &gemm::RowMajor {
            data: patch,
            ld: cols.max(1),
        },
        &mut out,
        cols.max(1),
    );
    Tensor3::from_vec(k.n, oh, ow, out)
}

/// Contract one prebuilt patch matrix against **several** same-shape
/// filter banks: the patch (the large operand) is packed once into the
/// thread's packing scratch (`linalg::gemm::with_packed_b`) and reused
/// across every GEMM, instead of being re-packed per filter bank the
/// way repeated [`conv2d_from_patch`] calls would. Per-element
/// arithmetic is the identical k-ascending fold over the identical
/// packed values, so each output equals the corresponding
/// `conv2d_from_patch` result bit for bit. Outputs come back in
/// `filters` order.
pub fn conv2d_from_patch_multi(
    patch: &[f64],
    rows: usize,
    cols: usize,
    filters: &[&Tensor4],
    oh: usize,
    ow: usize,
) -> Vec<Tensor3> {
    conv2d_from_patch_multi_with(patch, rows, cols, filters, oh, ow, |len| vec![0.0f64; len])
}

/// [`conv2d_from_patch_multi`] with caller-supplied output allocation:
/// `alloc(len)` must return a **zeroed** buffer of exactly `len`
/// entries (the GEMM accumulates into it). The coded worker path passes
/// the plan arena's `take`, making steady-state output blocks
/// allocation-free; `alloc` is otherwise arithmetic-invisible.
pub fn conv2d_from_patch_multi_with(
    patch: &[f64],
    rows: usize,
    cols: usize,
    filters: &[&Tensor4],
    oh: usize,
    ow: usize,
    mut alloc: impl FnMut(usize) -> Vec<f64>,
) -> Vec<Tensor3> {
    debug_assert_eq!(cols, oh * ow);
    debug_assert_eq!(patch.len(), rows * cols);
    if filters.is_empty() {
        return Vec::new();
    }
    gemm::with_packed_b(
        &gemm::RowMajor {
            data: patch,
            ld: cols.max(1),
        },
        rows,
        cols,
        |pb| {
            let mut outs = Vec::with_capacity(filters.len());
            for k in filters {
                debug_assert_eq!(rows, k.c * k.kh * k.kw);
                let mut out = alloc(k.n * cols);
                debug_assert_eq!(out.len(), k.n * cols);
                gemm::gemm_prepacked_into(
                    k.n,
                    &gemm::RowMajor {
                        data: &k.data,
                        ld: rows.max(1),
                    },
                    pb,
                    &mut out,
                    cols.max(1),
                );
                outs.push(Tensor3::from_vec(k.n, oh, ow, out));
            }
            outs
        },
    )
}

/// The **zero-pack** multi-contraction: every filter bank arrives as a
/// plan-resident [`gemm::PackedA`] (packed once at model load), the
/// patch matrix is packed once per call, and each GEMM is pure panel
/// contraction (`gemm::gemm_prepacked_ab_into`). The packed filter
/// bytes are exactly what per-call packing would produce and the fold
/// is unchanged, so outputs equal [`conv2d_from_patch_multi`] bit for
/// bit. `alloc(len)` must return a zeroed buffer of exactly `len`
/// entries; outputs come back in `packs` order.
pub fn conv2d_from_patch_multi_prepacked(
    patch: &[f64],
    rows: usize,
    cols: usize,
    packs: &[gemm::PackedA],
    oh: usize,
    ow: usize,
    mut alloc: impl FnMut(usize) -> Vec<f64>,
) -> Vec<Tensor3> {
    debug_assert_eq!(cols, oh * ow);
    debug_assert_eq!(patch.len(), rows * cols);
    if packs.is_empty() {
        return Vec::new();
    }
    gemm::with_packed_b(
        &gemm::RowMajor {
            data: patch,
            ld: cols.max(1),
        },
        rows,
        cols,
        |pb| {
            let mut outs = Vec::with_capacity(packs.len());
            for pa in packs {
                debug_assert_eq!(rows, pa.kk());
                let mut out = alloc(pa.m() * cols);
                debug_assert_eq!(out.len(), pa.m() * cols);
                gemm::gemm_prepacked_ab_into(pa, pb, &mut out, cols.max(1));
                outs.push(Tensor3::from_vec(pa.m(), oh, ow, out));
            }
            outs
        },
    )
}

/// Convolution via im2col + GEMM. Produces bit-compatible layout with
/// `conv2d` (N × H' × W').
pub fn conv2d_im2col(x: &Tensor3, k: &Tensor4, p: ConvParams) -> Tensor3 {
    assert_eq!(x.c, k.c, "conv2d_im2col: channel mismatch");
    let (oh, ow) = conv2d_shape(x.h, x.w, k.kh, k.kw, p);
    let (cols_mat, rows, cols) = im2col(x, k.kh, k.kw, p);
    conv2d_from_patch(&cols_mat, rows, cols, k, oh, ow)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::conv2d;
    use crate::util::{max_abs_diff, rng::Rng};

    #[test]
    fn matches_direct_conv_over_shapes() {
        let mut rng = Rng::new(11);
        let cases = [
            (1, 5, 5, 1, 3, 3, 1, 0),
            (3, 8, 8, 4, 3, 3, 1, 1),
            (2, 9, 7, 5, 2, 4, 1, 0),
            (3, 11, 11, 2, 3, 3, 2, 1),
            (1, 28, 28, 6, 5, 5, 1, 2),
            (4, 13, 13, 8, 5, 5, 4, 0),
        ];
        for (c, h, w, n, kh, kw, s, pad) in cases {
            let x = Tensor3::random(c, h, w, &mut rng);
            let k = Tensor4::random(n, c, kh, kw, &mut rng);
            let p = ConvParams::new(s, pad);
            let y1 = conv2d(&x, &k, p);
            let y2 = conv2d_im2col(&x, &k, p);
            assert_eq!(y1.shape(), y2.shape());
            assert!(
                max_abs_diff(&y1.data, &y2.data) < 1e-12,
                "mismatch for case {:?}",
                (c, h, w, n, kh, kw, s, pad)
            );
        }
    }

    #[test]
    fn im2col_dims() {
        let mut rng = Rng::new(12);
        let x = Tensor3::random(3, 6, 6, &mut rng);
        let (m, rows, cols) = im2col(&x, 3, 3, ConvParams::unit());
        assert_eq!(rows, 3 * 3 * 3);
        assert_eq!(cols, 4 * 4);
        assert_eq!(m.len(), rows * cols);
    }

    #[test]
    fn multi_filter_patch_contraction_matches_per_filter() {
        // One patch packing shared by several filter banks must produce
        // exactly the per-filter conv2d_from_patch results.
        let mut rng = Rng::new(14);
        let p = ConvParams::new(1, 0);
        let x = Tensor3::random(3, 9, 8, &mut rng);
        let ks: Vec<Tensor4> = (0..3).map(|_| Tensor4::random(4, 3, 3, 3, &mut rng)).collect();
        let (oh, ow) = conv2d_shape(x.h, x.w, 3, 3, p);
        let (patch, rows, cols) = im2col(&x, 3, 3, p);
        let refs: Vec<&Tensor4> = ks.iter().collect();
        let multi = conv2d_from_patch_multi(&patch, rows, cols, &refs, oh, ow);
        assert_eq!(multi.len(), ks.len());
        for (k, y) in ks.iter().zip(&multi) {
            let want = conv2d_from_patch(&patch, rows, cols, k, oh, ow);
            assert_eq!(y.data, want.data, "multi diverged from per-filter");
        }
        assert!(conv2d_from_patch_multi(&patch, rows, cols, &[], oh, ow).is_empty());
    }

    #[test]
    fn prepacked_multi_contraction_matches_per_filter() {
        // The zero-pack worker path: resident PackedA operands against a
        // once-packed patch must equal the pack-per-call results bit for
        // bit, and the alloc hook must be arithmetic-invisible.
        let mut rng = Rng::new(15);
        let p = ConvParams::new(1, 0);
        let x = Tensor3::random(3, 9, 8, &mut rng);
        let ks: Vec<Tensor4> = (0..3).map(|_| Tensor4::random(4, 3, 3, 3, &mut rng)).collect();
        let (oh, ow) = conv2d_shape(x.h, x.w, 3, 3, p);
        let (patch, rows, cols) = im2col(&x, 3, 3, p);
        let packs: Vec<gemm::PackedA> = ks
            .iter()
            .map(|k| {
                gemm::PackedA::pack(
                    &gemm::RowMajor {
                        data: &k.data,
                        ld: rows,
                    },
                    k.n,
                    rows,
                )
            })
            .collect();
        let mut allocs = 0usize;
        let got = conv2d_from_patch_multi_prepacked(&patch, rows, cols, &packs, oh, ow, |len| {
            allocs += 1;
            vec![0.0; len]
        });
        assert_eq!(allocs, ks.len());
        let refs: Vec<&Tensor4> = ks.iter().collect();
        let want = conv2d_from_patch_multi(&patch, rows, cols, &refs, oh, ow);
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.data, w.data, "prepacked diverged from per-call packing");
        }
        assert!(
            conv2d_from_patch_multi_prepacked(&patch, rows, cols, &[], oh, ow, |len| vec![
                0.0;
                len
            ])
            .is_empty()
        );
    }

    #[test]
    fn patch_buffer_reuse_is_bit_identical() {
        // The same buffer filled twice (second fill over stale data of
        // identical shape) must yield the same patch matrix and the same
        // conv output as a fresh one-shot conv2d_im2col.
        let mut rng = Rng::new(13);
        let p = ConvParams::new(1, 1);
        let xs: Vec<Tensor3> = (0..3).map(|_| Tensor3::random(2, 7, 6, &mut rng)).collect();
        let k = Tensor4::random(3, 2, 3, 3, &mut rng);
        let mut buf = Vec::new();
        for x in &xs {
            let (oh, ow) = conv2d_shape(x.h, x.w, k.kh, k.kw, p);
            let (rows, cols) = im2col_into(x, k.kh, k.kw, p, &mut buf);
            let got = conv2d_from_patch(&buf, rows, cols, &k, oh, ow);
            let want = conv2d_im2col(x, &k, p);
            assert_eq!(got.data, want.data, "buffer reuse diverged");
        }
    }
}
