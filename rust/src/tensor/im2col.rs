//! im2col convolution: lowers conv to a (N × CK_HK_W)·(CK_HK_W × H'W')
//! GEMM. This is the optimized CPU worker path (and the algorithm RSPCC
//! builds its codes around — here it is just one interchangeable black-box
//! conv implementation, per the paper's generality claim).
//!
//! The two halves are exposed separately: [`im2col_into`] builds the
//! patch matrix into a caller-owned buffer, and [`conv2d_from_patch`]
//! runs the GEMM against it. A coded worker subtask convolves the *same*
//! input slab with ℓ_B filter slabs (and every slab of a batched payload
//! shares one shape), so `WorkerPayload::run_im2col` builds each patch
//! matrix once, reuses it across all ℓ_B GEMMs, and reuses the buffer
//! allocation across the whole batch. [`conv2d_im2col`] is the one-shot
//! composition of the two halves.

use crate::tensor::{conv2d_shape, ConvParams, Tensor3, Tensor4};

/// Build the im2col patch matrix into `buf` (resized to fit, previous
/// contents irrelevant — every element is overwritten): (C·K_H·K_W) ×
/// (H'·W'), column-major over output positions (column = output pixel
/// (h,w), row = (c,i,j) patch slot). Returns `(rows, cols)`.
pub fn im2col_into(
    x: &Tensor3,
    kh: usize,
    kw: usize,
    p: ConvParams,
    buf: &mut Vec<f64>,
) -> (usize, usize) {
    let xp;
    let x = if p.pad > 0 {
        xp = x.pad_spatial(p.pad);
        &xp
    } else {
        x
    };
    let (oh, ow) = ((x.h - kh) / p.stride + 1, (x.w - kw) / p.stride + 1);
    let rows = x.c * kh * kw;
    let cols = oh * ow;
    // Every element of the rows·cols matrix is written below, so stale
    // data from a previous (same-shape) use never needs zeroing out.
    buf.resize(rows * cols, 0.0);
    let m = &mut buf[..rows * cols];
    for c in 0..x.c {
        for i in 0..kh {
            for j in 0..kw {
                let r = (c * kh + i) * kw + j;
                let row_base = r * cols;
                for h in 0..oh {
                    let src = x.idx(c, h * p.stride + i, j);
                    let dst = row_base + h * ow;
                    if p.stride == 1 {
                        m[dst..dst + ow].copy_from_slice(&x.data[src..src + ow]);
                    } else {
                        for w in 0..ow {
                            m[dst + w] = x.data[src + w * p.stride];
                        }
                    }
                }
            }
        }
    }
    (rows, cols)
}

/// Build the im2col patch matrix in a fresh buffer (see [`im2col_into`]).
pub fn im2col(x: &Tensor3, kh: usize, kw: usize, p: ConvParams) -> (Vec<f64>, usize, usize) {
    let mut buf = Vec::new();
    let (rows, cols) = im2col_into(x, kh, kw, p, &mut buf);
    (buf, rows, cols)
}

/// The GEMM half: contract a prebuilt patch matrix against the filter
/// bank `k`, producing the (N × H' × W') output. `rows`/`cols` are the
/// patch-matrix dims returned by [`im2col_into`]; `(oh, ow)` the output
/// spatial dims (`oh·ow == cols`).
pub fn conv2d_from_patch(
    patch: &[f64],
    rows: usize,
    cols: usize,
    k: &Tensor4,
    oh: usize,
    ow: usize,
) -> Tensor3 {
    debug_assert_eq!(rows, k.c * k.kh * k.kw);
    debug_assert_eq!(cols, oh * ow);
    debug_assert_eq!(patch.len(), rows * cols);
    // GEMM: out[n, pix] = sum_r K[n, r] * M[r, pix]
    // K is already laid out row-major as (N × rows). Two-level blocking
    // (EXPERIMENTS.md §Perf):
    //   * columns are processed in L2-resident panels, so the patch
    //     matrix M is streamed from memory once instead of N times;
    //   * the contraction is blocked by 4, folding four M rows per pass
    //     over the accumulator (4x less accumulator traffic).
    const PANEL: usize = 256; // 576 rows x 256 cols x 8 B ≈ L2-sized
    let mut out = vec![0.0f64; k.n * cols];
    let mut p0 = 0;
    while p0 < cols {
        let pw = PANEL.min(cols - p0);
        for n in 0..k.n {
            let krow = &k.data[n * rows..(n + 1) * rows];
            let orow = &mut out[n * cols + p0..n * cols + p0 + pw];
            let mut r = 0;
            while r + 4 <= rows {
                let (k0, k1, k2, k3) = (krow[r], krow[r + 1], krow[r + 2], krow[r + 3]);
                if k0 != 0.0 || k1 != 0.0 || k2 != 0.0 || k3 != 0.0 {
                    let m0 = &patch[r * cols + p0..r * cols + p0 + pw];
                    let m1 = &patch[(r + 1) * cols + p0..(r + 1) * cols + p0 + pw];
                    let m2 = &patch[(r + 2) * cols + p0..(r + 2) * cols + p0 + pw];
                    let m3 = &patch[(r + 3) * cols + p0..(r + 3) * cols + p0 + pw];
                    for i in 0..pw {
                        orow[i] += k0 * m0[i] + k1 * m1[i] + k2 * m2[i] + k3 * m3[i];
                    }
                }
                r += 4;
            }
            while r < rows {
                let kv = krow[r];
                if kv != 0.0 {
                    let mrow = &patch[r * cols + p0..r * cols + p0 + pw];
                    for (o, &m) in orow.iter_mut().zip(mrow) {
                        *o += kv * m;
                    }
                }
                r += 1;
            }
        }
        p0 += pw;
    }
    Tensor3::from_vec(k.n, oh, ow, out)
}

/// Convolution via im2col + GEMM. Produces bit-compatible layout with
/// `conv2d` (N × H' × W').
pub fn conv2d_im2col(x: &Tensor3, k: &Tensor4, p: ConvParams) -> Tensor3 {
    assert_eq!(x.c, k.c, "conv2d_im2col: channel mismatch");
    let (oh, ow) = conv2d_shape(x.h, x.w, k.kh, k.kw, p);
    let (cols_mat, rows, cols) = im2col(x, k.kh, k.kw, p);
    conv2d_from_patch(&cols_mat, rows, cols, k, oh, ow)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::conv2d;
    use crate::util::{max_abs_diff, rng::Rng};

    #[test]
    fn matches_direct_conv_over_shapes() {
        let mut rng = Rng::new(11);
        let cases = [
            (1, 5, 5, 1, 3, 3, 1, 0),
            (3, 8, 8, 4, 3, 3, 1, 1),
            (2, 9, 7, 5, 2, 4, 1, 0),
            (3, 11, 11, 2, 3, 3, 2, 1),
            (1, 28, 28, 6, 5, 5, 1, 2),
            (4, 13, 13, 8, 5, 5, 4, 0),
        ];
        for (c, h, w, n, kh, kw, s, pad) in cases {
            let x = Tensor3::random(c, h, w, &mut rng);
            let k = Tensor4::random(n, c, kh, kw, &mut rng);
            let p = ConvParams::new(s, pad);
            let y1 = conv2d(&x, &k, p);
            let y2 = conv2d_im2col(&x, &k, p);
            assert_eq!(y1.shape(), y2.shape());
            assert!(
                max_abs_diff(&y1.data, &y2.data) < 1e-12,
                "mismatch for case {:?}",
                (c, h, w, n, kh, kw, s, pad)
            );
        }
    }

    #[test]
    fn im2col_dims() {
        let mut rng = Rng::new(12);
        let x = Tensor3::random(3, 6, 6, &mut rng);
        let (m, rows, cols) = im2col(&x, 3, 3, ConvParams::unit());
        assert_eq!(rows, 3 * 3 * 3);
        assert_eq!(cols, 4 * 4);
        assert_eq!(m.len(), rows * cols);
    }

    #[test]
    fn patch_buffer_reuse_is_bit_identical() {
        // The same buffer filled twice (second fill over stale data of
        // identical shape) must yield the same patch matrix and the same
        // conv output as a fresh one-shot conv2d_im2col.
        let mut rng = Rng::new(13);
        let p = ConvParams::new(1, 1);
        let xs: Vec<Tensor3> = (0..3).map(|_| Tensor3::random(2, 7, 6, &mut rng)).collect();
        let k = Tensor4::random(3, 2, 3, 3, &mut rng);
        let mut buf = Vec::new();
        for x in &xs {
            let (oh, ow) = conv2d_shape(x.h, x.w, k.kh, k.kw, p);
            let (rows, cols) = im2col_into(x, k.kh, k.kw, p, &mut buf);
            let got = conv2d_from_patch(&buf, rows, cols, &k, oh, ow);
            let want = conv2d_im2col(x, &k, p);
            assert_eq!(got.data, want.data, "buffer reuse diverged");
        }
    }
}
