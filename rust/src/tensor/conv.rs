//! Reference 2-D convolution (paper eq. (1)): the correctness oracle for
//! the whole system and the CPU fallback worker implementation.
//!
//! Conventions follow the paper: the convolution is a cross-correlation
//! (no kernel flip), the input is C×H×W, the filter bank is N×C×K_H×K_W,
//! and the output is N×H'×W' with
//!   H' = floor((H + 2p − K_H)/s) + 1,  W' = floor((W + 2p − K_W)/s) + 1.

use crate::tensor::{Tensor3, Tensor4};

/// Stride + padding pair for a convolutional layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvParams {
    pub stride: usize,
    pub pad: usize,
}

impl ConvParams {
    pub fn new(stride: usize, pad: usize) -> Self {
        assert!(stride >= 1, "stride must be >= 1");
        Self { stride, pad }
    }

    pub fn unit() -> Self {
        Self { stride: 1, pad: 0 }
    }
}

/// Output spatial dims (H', W') for input (h, w), kernel (kh, kw).
pub fn conv2d_shape(h: usize, w: usize, kh: usize, kw: usize, p: ConvParams) -> (usize, usize) {
    let hh = h + 2 * p.pad;
    let ww = w + 2 * p.pad;
    assert!(hh >= kh && ww >= kw, "kernel larger than padded input");
    ((hh - kh) / p.stride + 1, (ww - kw) / p.stride + 1)
}

/// Direct (naive triple-loop) convolution — the oracle. Padding is applied
/// internally when `p.pad > 0`.
pub fn conv2d(x: &Tensor3, k: &Tensor4, p: ConvParams) -> Tensor3 {
    assert_eq!(x.c, k.c, "conv2d: channel mismatch (x.c={} k.c={})", x.c, k.c);
    let xp;
    let x = if p.pad > 0 {
        xp = x.pad_spatial(p.pad);
        &xp
    } else {
        x
    };
    let (hp, wp) = (x.h, x.w);
    let (oh, ow) = ((hp - k.kh) / p.stride + 1, (wp - k.kw) / p.stride + 1);
    let mut out = Tensor3::zeros(k.n, oh, ow);
    for n in 0..k.n {
        for c in 0..x.c {
            for i in 0..k.kh {
                for j in 0..k.kw {
                    let kv = k.get(n, c, i, j);
                    if kv == 0.0 {
                        continue;
                    }
                    for h in 0..oh {
                        let xrow = x.idx(c, h * p.stride + i, j);
                        let orow = out.idx(n, h, 0);
                        if p.stride == 1 {
                            // contiguous fast path
                            for w in 0..ow {
                                out.data[orow + w] += kv * x.data[xrow + w];
                            }
                        } else {
                            for w in 0..ow {
                                out.data[orow + w] += kv * x.data[xrow + w * p.stride];
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identity_kernel_is_identity() {
        // 1x1 kernel of value 1 on a single channel reproduces the input.
        let mut rng = Rng::new(1);
        let x = Tensor3::random(1, 5, 5, &mut rng);
        let k = Tensor4::from_vec(1, 1, 1, 1, vec![1.0]);
        let y = conv2d(&x, &k, ConvParams::unit());
        assert_eq!(y, x);
    }

    #[test]
    fn known_small_case() {
        // X = [[1,2],[3,4]], K = [[1,0],[0,1]] -> single output 1+4=5.
        let x = Tensor3::from_vec(1, 2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let k = Tensor4::from_vec(1, 1, 2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let y = conv2d(&x, &k, ConvParams::unit());
        assert_eq!(y.shape(), (1, 1, 1));
        assert_eq!(y.data, vec![5.0]);
    }

    #[test]
    fn stride_and_padding_shapes() {
        let (h, w) = conv2d_shape(28, 28, 5, 5, ConvParams::new(1, 2));
        assert_eq!((h, w), (28, 28));
        let (h, w) = conv2d_shape(227, 227, 11, 11, ConvParams::new(4, 0));
        assert_eq!((h, w), (55, 55)); // AlexNet conv1
        let (h, w) = conv2d_shape(224, 224, 3, 3, ConvParams::new(1, 1));
        assert_eq!((h, w), (224, 224)); // VGG conv
    }

    #[test]
    fn sums_over_channels() {
        // Two channels, 1x1 unit kernels: output = sum of channels.
        let x = Tensor3::from_vec(2, 1, 2, vec![1.0, 2.0, 10.0, 20.0]);
        let k = Tensor4::from_vec(1, 2, 1, 1, vec![1.0, 1.0]);
        let y = conv2d(&x, &k, ConvParams::unit());
        assert_eq!(y.data, vec![11.0, 22.0]);
    }

    #[test]
    fn padding_matches_explicit_prepad() {
        let mut rng = Rng::new(2);
        let x = Tensor3::random(3, 6, 7, &mut rng);
        let k = Tensor4::random(4, 3, 3, 3, &mut rng);
        let y1 = conv2d(&x, &k, ConvParams::new(2, 1));
        let xp = x.pad_spatial(1);
        let y2 = conv2d(&xp, &k, ConvParams::new(2, 0));
        assert_eq!(y1, y2);
    }

    #[test]
    fn linearity_in_both_arguments() {
        // conv(aX1 + bX2, K) = a conv(X1,K) + b conv(X2,K), and similarly in K.
        let mut rng = Rng::new(3);
        let x1 = Tensor3::random(2, 5, 5, &mut rng);
        let x2 = Tensor3::random(2, 5, 5, &mut rng);
        let k = Tensor4::random(3, 2, 3, 3, &mut rng);
        let (a, b) = (2.5, -1.25);
        let mut xc = x1.clone();
        xc.scale(a);
        xc.axpy(b, &x2);
        let lhs = conv2d(&xc, &k, ConvParams::unit());
        let mut rhs = conv2d(&x1, &k, ConvParams::unit());
        rhs.scale(a);
        rhs.axpy(b, &conv2d(&x2, &k, ConvParams::unit()));
        assert!(crate::util::max_abs_diff(&lhs.data, &rhs.data) < 1e-12);
    }
}
