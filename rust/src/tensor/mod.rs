//! Dense tensor substrate: 3-D feature maps (C×H×W), 4-D filter banks
//! (N×C×K_H×K_W), slicing/padding/concatenation primitives, and the
//! convolution oracle (direct and im2col) used by the coordinator, the
//! baselines, and as the correctness reference for the PJRT worker path.

pub mod conv;
pub mod im2col;
pub mod tensor3;
pub mod tensor4;

pub use conv::{conv2d, conv2d_shape, ConvParams};
pub use tensor3::Tensor3;
pub use tensor4::Tensor4;
