//! Worker-side convolution engines. The paper's generality claim is that
//! workers may run *any* black-box tensor-convolution algorithm; this
//! trait is that claim made concrete. Three engines ship:
//!
//! * [`DirectEngine`] — the naive triple-loop oracle,
//! * [`Im2colEngine`] — im2col + GEMM (the optimized CPU path),
//! * `runtime::PjrtService` (feature `pjrt`) — the AOT-compiled
//!   JAX/Pallas artifact executed via PJRT (the L1/L2 layers of the
//!   stack).
//!
//! Engines are shared (`Arc`) across all workers of a cluster, and under
//! the concurrent job runtime a single engine instance serves subtasks
//! of many overlapping jobs — implementations must be `Send + Sync` and
//! reentrant.

use crate::fcdcc::{WorkerPayload, WorkerResult};
use crate::tensor::{conv2d, im2col::conv2d_im2col, ConvParams, Tensor3, Tensor4};

/// A black-box convolution implementation usable by workers.
pub trait ConvEngine: Send + Sync {
    fn name(&self) -> &str;
    fn conv(&self, x: &Tensor3, k: &Tensor4, p: ConvParams) -> Tensor3;
}

/// A whole-subtask executor: runs one coded [`WorkerPayload`] (all
/// pairwise convolutions). A `TaskEngine` sees the whole payload, so it
/// can amortize work across the slab pairs — [`Im2colEngine`] builds
/// each input slab's im2col patch matrix once and reuses it across all
/// ℓ_B filter slabs (and the buffer across the batch); the PJRT runtime
/// implements it directly with the fused AOT artifact.
pub trait TaskEngine: Send + Sync {
    fn name(&self) -> &str;
    fn run(&self, payload: &WorkerPayload) -> anyhow::Result<WorkerResult>;
}

/// Naive direct convolution (paper's "basic, unoptimized" worker) — the
/// correctness oracle.
pub struct DirectEngine;

impl ConvEngine for DirectEngine {
    fn name(&self) -> &str {
        "direct"
    }

    fn conv(&self, x: &Tensor3, k: &Tensor4, p: ConvParams) -> Tensor3 {
        conv2d(x, k, p)
    }
}

impl TaskEngine for DirectEngine {
    fn name(&self) -> &str {
        "direct"
    }

    fn run(&self, payload: &WorkerPayload) -> anyhow::Result<WorkerResult> {
        Ok(payload.run_local())
    }
}

/// im2col + GEMM convolution — the optimized CPU path and the default
/// engine for cluster workers.
pub struct Im2colEngine;

impl ConvEngine for Im2colEngine {
    fn name(&self) -> &str {
        "im2col"
    }

    fn conv(&self, x: &Tensor3, k: &Tensor4, p: ConvParams) -> Tensor3 {
        conv2d_im2col(x, k, p)
    }
}

impl TaskEngine for Im2colEngine {
    fn name(&self) -> &str {
        "im2col"
    }

    /// The fused subtask path: one patch matrix per coded input slab,
    /// reused across every filter slab, buffer reused across the batch.
    fn run(&self, payload: &WorkerPayload) -> anyhow::Result<WorkerResult> {
        Ok(payload.run_im2col())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{max_abs_diff, rng::Rng};

    #[test]
    fn engines_agree() {
        let mut rng = Rng::new(61);
        let x = Tensor3::random(3, 9, 9, &mut rng);
        let k = Tensor4::random(4, 3, 3, 3, &mut rng);
        let p = ConvParams::new(1, 1);
        let a = DirectEngine.conv(&x, &k, p);
        let b = Im2colEngine.conv(&x, &k, p);
        assert!(max_abs_diff(&a.data, &b.data) < 1e-12);
    }
}
