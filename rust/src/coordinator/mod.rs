//! High-level coordinator commands — the application layer behind the
//! `fcdcc` CLI and the examples: single-layer distributed runs, the
//! cost planner, the numerical-stability report, and the pipelined
//! distributed LeNet-5 serving loop (see [`serve`] for the
//! request scheduler over the concurrent job runtime).

pub mod arrival;
pub mod serve;
pub mod stability;

use crate::cluster::{Cluster, StragglerModel};
use crate::coding::CodeFamily;
use crate::engine::{DirectEngine, Im2colEngine, TaskEngine};
use crate::fcdcc::{cost, FcdccPlan};
use crate::metrics::{fmt_secs, fmt_sci, Table};
use crate::model::{zoo, ConvLayer};
use crate::tensor::{conv2d, Tensor3, Tensor4};
use crate::util::{mse, rng::Rng};
use anyhow::{anyhow, Result};
use std::sync::Arc;
use std::time::Duration;

pub use arrival::{ArrivalGen, ArrivalKind, ArrivalSpec};
pub use serve::{
    serve_frontend_on, serve_lenet, RequestOutcome, ServeConfig, ServeStats, TransportKind,
};

/// Resolve a `--engine` name to a TaskEngine (PJRT is resolved by the
/// caller since it needs the artifacts directory).
pub fn engine_by_name(name: &str) -> Result<Arc<dyn TaskEngine>> {
    match name {
        "direct" => Ok(Arc::new(DirectEngine)),
        "im2col" => Ok(Arc::new(Im2colEngine)),
        other => Err(anyhow!(
            "unknown engine {other:?} (expected direct|im2col|pjrt)"
        )),
    }
}

/// Best-available engine for the examples: the PJRT AOT artifacts when
/// the `pjrt` feature is enabled and the artifacts load, otherwise the
/// native im2col fallback. Prints which engine was picked.
#[cfg(feature = "pjrt")]
pub fn pjrt_engine_or_native(artifacts_dir: &str) -> Arc<dyn TaskEngine> {
    match crate::runtime::PjrtService::spawn(artifacts_dir) {
        Ok(host) => {
            println!("engine: PJRT (AOT JAX/Pallas artifacts)");
            let handle = host.handle.clone();
            // Detach the host: the service lives until all handles drop.
            std::mem::forget(host);
            Arc::new(handle)
        }
        Err(e) => {
            println!("engine: native im2col (PJRT unavailable: {e})");
            Arc::new(Im2colEngine)
        }
    }
}

/// Best-available engine: without the `pjrt` feature this is always the
/// native im2col engine.
#[cfg(not(feature = "pjrt"))]
pub fn pjrt_engine_or_native(_artifacts_dir: &str) -> Arc<dyn TaskEngine> {
    println!("engine: native im2col (built without the `pjrt` feature)");
    Arc::new(Im2colEngine)
}

/// Options for a single-layer distributed run.
pub struct RunConfig {
    pub layer: ConvLayer,
    pub k_a: usize,
    pub k_b: usize,
    pub n: usize,
    pub stragglers: usize,
    pub delay: Duration,
    pub engine: Arc<dyn TaskEngine>,
    pub seed: u64,
    /// Code family the layer is planned with (`--code` / `FCDCC_CODE`).
    pub code: CodeFamily,
}

/// Run one convolutional layer through the full FCDCC stack and print a
/// report; returns the MSE vs the single-node reference.
pub fn run_layer(cfg: RunConfig) -> Result<f64> {
    let layer = &cfg.layer;
    println!(
        "layer {}: C={} H={} W={} N={} K={}x{} s={} p={}",
        layer.name, layer.c, layer.h, layer.w, layer.n, layer.kh, layer.kw, layer.stride, layer.pad
    );
    let code = cfg.code.build(cfg.k_a, cfg.k_b, cfg.n)?;
    let plan = FcdccPlan::with_code(layer, code)?;
    println!(
        "plan: code={} k_A={} k_B={} n={} delta={} gamma={}",
        cfg.code.tag(),
        cfg.k_a,
        cfg.k_b,
        cfg.n,
        plan.delta(),
        cfg.n - plan.delta(),
    );
    let mut rng = Rng::new(cfg.seed);
    let x = Tensor3::random(layer.c, layer.h, layer.w, &mut rng);
    let k = Tensor4::random(layer.n, layer.c, layer.kh, layer.kw, &mut rng);

    let coded_filters = plan.encode_filters(&k);
    let mut cluster = Cluster::new(cfg.n, cfg.engine);
    let straggler = if cfg.stragglers == 0 {
        StragglerModel::None
    } else {
        StragglerModel::FixedCount {
            count: cfg.stragglers,
            delay: cfg.delay,
        }
    };
    let (y, report) = cluster.run_job(&plan, &x, &coded_filters, &straggler, &mut rng)?;
    cluster.shutdown();

    let want = conv2d(&x, &k, layer.params());
    let err = mse(&y.data, &want.data);
    println!(
        "done: encode {} | collect {} | decode {} | sim-makespan {} | upload {} entries | download {} entries",
        fmt_secs(report.encode_secs),
        fmt_secs(report.collect_secs),
        fmt_secs(report.decode_secs),
        fmt_secs(report.sim_makespan_secs),
        report.upload_entries,
        report.download_entries,
    );
    println!("used workers: {:?}", report.used_workers);
    println!("MSE vs single-node reference: {}", fmt_sci(err));
    Ok(err)
}

/// The Table-IV cost planner: optimal (k_A, k_B) per layer per Q.
pub fn print_optimizer_table(arch: &str, qs: &[usize]) -> Result<()> {
    let layers = zoo::by_name(arch).ok_or_else(|| anyhow!("unknown architecture {arch:?}"))?;
    let cm = cost::CostModel::paper_exp5();
    let mut header = vec!["Q".to_string()];
    header.extend(layers.iter().map(|l| l.name.clone()));
    let mut t = Table::new(
        &format!("Optimized (k_A, k_B) for {arch} (λ_comm=0.09, λ_store=0.023, λ_comp=0)"),
        &header.iter().map(String::as_str).collect::<Vec<_>>(),
    );
    for &q in qs {
        let mut row = vec![q.to_string()];
        for layer in &layers {
            match cost::optimize(layer, &cm, q) {
                Some(c) => row.push(format!("({}, {})", c.best.k_a, c.best.k_b)),
                None => row.push("—".to_string()),
            }
        }
        t.row(&row);
    }
    t.print();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_lookup() {
        assert!(engine_by_name("direct").is_ok());
        assert!(engine_by_name("im2col").is_ok());
        assert!(engine_by_name("cuda").is_err());
    }

    #[test]
    fn run_layer_small_exact() {
        let cfg = RunConfig {
            layer: ConvLayer::new("t", 2, 12, 10, 8, 3, 3, 1, 0),
            k_a: 4,
            k_b: 2,
            n: 4,
            stragglers: 1,
            delay: Duration::from_millis(50),
            engine: Arc::new(DirectEngine),
            seed: 7,
            // Pin CRME: the 1e-20 bar below is the CRME pipeline's.
            code: CodeFamily::Crme,
        };
        let err = run_layer(cfg).unwrap();
        assert!(err < 1e-20, "mse={err:e}");
    }

    #[test]
    fn optimizer_table_prints() {
        print_optimizer_table("lenet", &[16, 32]).unwrap();
        assert!(print_optimizer_table("nope", &[16]).is_err());
    }
}
