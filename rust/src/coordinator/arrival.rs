//! Deterministic synthetic-time arrival generation for open-loop
//! serving (DESIGN.md §Serving front-end & overload control).
//!
//! An open-loop workload fixes *when* requests arrive instead of waiting
//! for the previous reply — the regime where overload is even possible.
//! To keep overload behavior reproducible offline, arrivals are drawn in
//! **virtual time** from a seeded [`Rng`]: the serving scheduler advances
//! its virtual clock by [`ArrivalSpec::stage_secs`] per coded-job absorb
//! and jumps to the next arrival when idle, so a fixed seed yields a
//! bit-identical shed/expire/complete pattern on every run and machine.
//!
//! Two processes cover the paper-relevant regimes:
//! * **Poisson** — memoryless inter-arrival gaps `Exp(rate)`; the
//!   classic open-loop model.
//! * **Burst** — burst epochs arrive as a Poisson process of rate
//!   `rate / mean_burst`, each carrying `1 + Geometric(1/mean_burst)`
//!   back-to-back requests (mean burst size `mean_burst`, so the
//!   long-run request rate is still `rate`). This is the adversarial
//!   load for a bounded admission queue: a single burst can exceed the
//!   queue capacity even when the average rate is sustainable.

use crate::util::rng::Rng;

/// Default virtual cost of absorbing one coded stage job, in virtual
/// seconds. With `batch_window` w and two conv stages the sustainable
/// request rate is `w / (2 · stage_secs)` ≈ 100·w req/s.
pub const DEFAULT_STAGE_SECS: f64 = 0.005;

/// Which arrival process drives the open loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrivalKind {
    Poisson,
    Burst,
}

/// A seeded open-loop arrival process (`--arrival`, `--arrival-rate`,
/// `--arrival-seed`, `--arrival-burst`).
#[derive(Clone, Debug)]
pub struct ArrivalSpec {
    pub kind: ArrivalKind,
    /// Long-run mean arrival rate, requests per virtual second.
    pub rate: f64,
    pub seed: u64,
    /// Mean requests per burst ([`ArrivalKind::Burst`] only; ≥ 1).
    pub mean_burst: usize,
    /// Virtual seconds one coded-job absorb advances the serving clock.
    pub stage_secs: f64,
}

impl ArrivalSpec {
    pub fn poisson(rate: f64, seed: u64) -> ArrivalSpec {
        ArrivalSpec {
            kind: ArrivalKind::Poisson,
            rate,
            seed,
            mean_burst: 4,
            stage_secs: DEFAULT_STAGE_SECS,
        }
    }

    pub fn burst(rate: f64, mean_burst: usize, seed: u64) -> ArrivalSpec {
        ArrivalSpec {
            kind: ArrivalKind::Burst,
            rate,
            seed,
            mean_burst,
            stage_secs: DEFAULT_STAGE_SECS,
        }
    }
}

/// Iterator-like generator over an [`ArrivalSpec`]: `peek` the next
/// arrival's virtual timestamp without consuming it, `next_arrival` to
/// consume. Timestamps are nondecreasing; burst members share their
/// epoch's timestamp (intra-burst gap 0).
pub struct ArrivalGen {
    rng: Rng,
    kind: ArrivalKind,
    rate: f64,
    mean_burst: usize,
    stage_secs: f64,
    /// Current burst epoch time.
    t: f64,
    /// Arrivals still pending at `t` (burst mode).
    pending: usize,
    /// Cached next arrival time, if already drawn.
    next: Option<f64>,
}

impl ArrivalGen {
    pub fn new(spec: &ArrivalSpec) -> ArrivalGen {
        assert!(spec.rate > 0.0, "arrival rate must be positive");
        assert!(spec.mean_burst >= 1, "mean_burst must be >= 1");
        assert!(spec.stage_secs > 0.0, "stage_secs must be positive");
        ArrivalGen {
            rng: Rng::new(spec.seed),
            kind: spec.kind,
            rate: spec.rate,
            mean_burst: spec.mean_burst,
            stage_secs: spec.stage_secs,
            t: 0.0,
            pending: 0,
            next: None,
        }
    }

    /// Virtual seconds one coded-job absorb advances the serving clock.
    pub fn stage_secs(&self) -> f64 {
        self.stage_secs
    }

    /// Timestamp of the next arrival (virtual seconds), without
    /// consuming it.
    pub fn peek(&mut self) -> f64 {
        if let Some(t) = self.next {
            return t;
        }
        let t = match self.kind {
            ArrivalKind::Poisson => {
                self.t += self.rng.exponential(self.rate);
                self.t
            }
            ArrivalKind::Burst => {
                if self.pending == 0 {
                    // Next burst epoch, then its size: 1 + Geometric so
                    // every burst carries at least one request and the
                    // mean size is exactly `mean_burst`.
                    let epoch_rate = self.rate / self.mean_burst as f64;
                    self.t += self.rng.exponential(epoch_rate);
                    self.pending = 1 + self.rng.geometric(1.0 / self.mean_burst as f64);
                }
                self.pending -= 1;
                self.t
            }
        };
        self.next = Some(t);
        t
    }

    /// Consume and return the next arrival's timestamp.
    pub fn next_arrival(&mut self) -> f64 {
        let t = self.peek();
        self.next = None;
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_monotone() {
        for spec in [ArrivalSpec::poisson(50.0, 7), ArrivalSpec::burst(50.0, 8, 7)] {
            let mut a = ArrivalGen::new(&spec);
            let mut b = ArrivalGen::new(&spec);
            let mut last = 0.0;
            for _ in 0..500 {
                assert_eq!(a.peek(), b.peek(), "peek is stable");
                let t = a.next_arrival();
                assert_eq!(t, b.next_arrival(), "same seed, same stream");
                assert!(t >= last, "timestamps must be nondecreasing");
                last = t;
            }
        }
    }

    #[test]
    fn long_run_rates_match() {
        let n = 20_000;
        for spec in [ArrivalSpec::poisson(40.0, 3), ArrivalSpec::burst(40.0, 8, 3)] {
            let mut g = ArrivalGen::new(&spec);
            let mut t = 0.0;
            for _ in 0..n {
                t = g.next_arrival();
            }
            let rate = n as f64 / t;
            assert!(
                (rate - 40.0).abs() < 2.0,
                "{:?}: empirical rate {rate:.2}",
                spec.kind
            );
        }
    }

    #[test]
    fn bursts_share_a_timestamp() {
        let mut g = ArrivalGen::new(&ArrivalSpec::burst(100.0, 16, 11));
        let ts: Vec<f64> = (0..200).map(|_| g.next_arrival()).collect();
        let repeats = ts.windows(2).filter(|w| w[0] == w[1]).count();
        assert!(repeats > 50, "mean-16 bursts must share epochs: {repeats}");
    }
}
