//! Numerical-stability comparison across CDC schemes (paper Experiment 2,
//! Figs. 3–4): decode MSE and recovery-matrix condition number for
//! CRME/FCDCC vs real-Vandermonde polynomial codes vs Fahim–Cadambe, over
//! the paper's (n, δ, γ) grid.

use crate::coding::CodeFamily;
use crate::fcdcc::FcdccPlan;
use crate::linalg::cond_2;
use crate::model::ConvLayer;
use crate::tensor::{conv2d, Tensor3, Tensor4};
use crate::util::{mse, rng::Rng};
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// One scheme × one (n, δ) configuration result.
#[derive(Clone, Debug)]
pub struct StabilityPoint {
    pub scheme: &'static str,
    /// Machine tag of the family (`CodeFamily::tag()`) for JSON records.
    pub code: &'static str,
    pub n: usize,
    pub delta: usize,
    pub gamma: usize,
    pub k_a: usize,
    pub k_b: usize,
    /// Condition numbers over the sampled δ-subsets.
    pub cond_median: f64,
    pub cond_worst: f64,
    /// Decode MSE vs the single-node reference over the same subsets.
    pub mse_mean: f64,
    pub mse_worst: f64,
}

/// Pick a balanced feasible (k_A, k_B) with k_A·k_B = p, k_B | n_out,
/// k_A ≤ h_out; for CRME both factors must additionally be 1 or even.
pub fn factor_pair(p: usize, n_out: usize, h_out: usize, even: bool) -> Result<(usize, usize)> {
    let feasible = |k: usize| !even || k == 1 || k % 2 == 0;
    let mut best: Option<(usize, usize)> = None;
    for k_a in 1..=p {
        if p % k_a != 0 || k_a > h_out || !feasible(k_a) {
            continue;
        }
        let k_b = p / k_a;
        if n_out % k_b != 0 || !feasible(k_b) {
            continue;
        }
        let balance = (k_a as f64).ln() - (k_b as f64).ln();
        match best {
            Some((ba, bb)) => {
                let prev = (ba as f64).ln() - (bb as f64).ln();
                if balance.abs() < prev.abs() {
                    best = Some((k_a, k_b));
                }
            }
            None => best = Some((k_a, k_b)),
        }
    }
    best.ok_or_else(|| anyhow!("no feasible (k_A,k_B) for product {p} (N={n_out}, H'={h_out})"))
}

/// Evaluate one scheme on one (n, δ) configuration of a layer.
/// `subset_samples` random δ-subsets are drawn (plus the adversarial
/// "first δ workers" subset); condition numbers use the recovery matrix,
/// MSE uses the full inline pipeline on random tensors. Codes come from
/// the shared registry ([`CodeFamily::build`]) — the same constructor
/// path `NetworkPlan`, pooling, and the CLI use.
pub fn evaluate(
    family: CodeFamily,
    layer: &ConvLayer,
    n: usize,
    delta: usize,
    subset_samples: usize,
    seed: u64,
) -> Result<StabilityPoint> {
    let p = family.partition_product(delta);
    let (k_a, k_b) = factor_pair(p, layer.n, layer.h_out(), family.even_partitions())?;
    let code = family.build(k_a, k_b, n)?;
    let plan = FcdccPlan::with_code(layer, Arc::clone(&code))?;
    assert_eq!(plan.delta(), delta, "{:?}: delta mismatch", family);

    let mut rng = Rng::new(seed);
    let x = Tensor3::random(layer.c, layer.h, layer.w, &mut rng);
    let k = Tensor4::random(layer.n, layer.c, layer.kh, layer.kw, &mut rng);
    let want = conv2d(&x, &k, layer.params());

    // Subsets: adversarial contiguous-from-0 plus random draws.
    let mut subsets: Vec<Vec<usize>> = vec![(0..delta).collect()];
    for _ in 0..subset_samples {
        subsets.push(rng.choose_indices(n, delta));
    }

    let mut conds = Vec::with_capacity(subsets.len());
    let mut mses = Vec::with_capacity(subsets.len());
    for s in &subsets {
        conds.push(cond_2(&code.recovery(s)));
        let got = plan.run_inline(&x, &k, Some(s));
        match got {
            Ok(y) => mses.push(mse(&y.data, &want.data)),
            Err(_) => mses.push(f64::INFINITY), // unrecoverable: singular E
        }
    }
    conds.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let cond_median = conds[conds.len() / 2];
    let cond_worst = *conds.last().unwrap();
    let mse_mean = if mses.iter().any(|m| m.is_infinite()) {
        f64::INFINITY
    } else {
        mses.iter().sum::<f64>() / mses.len() as f64
    };
    let mse_worst = mses.iter().cloned().fold(0.0, f64::max);

    Ok(StabilityPoint {
        scheme: family.display_name(),
        code: family.tag(),
        n,
        delta,
        gamma: n - delta,
        k_a,
        k_b,
        cond_median,
        cond_worst,
        mse_mean,
        mse_worst,
    })
}

/// Full sweep over the paper's (n, δ, γ) grid for all schemes.
pub fn stability_sweep(
    layer: &ConvLayer,
    configs: &[(usize, usize)],
    subset_samples: usize,
    seed: u64,
) -> Vec<StabilityPoint> {
    let mut out = Vec::new();
    for &(n, delta) in configs {
        for family in CodeFamily::ALL {
            match evaluate(family, layer, n, delta, subset_samples, seed) {
                Ok(p) => out.push(p),
                Err(e) => eprintln!(
                    "skip {} at (n={n}, delta={delta}): {e:#}",
                    family.display_name()
                ),
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_layer() -> ConvLayer {
        // VGG-conv4-like structure at toy scale: N divisible by many
        // powers of two, H' comfortable.
        ConvLayer::new("vgg4.toy", 8, 14, 14, 32, 3, 3, 1, 1)
    }

    #[test]
    fn factor_pair_balanced_even() {
        let (ka, kb) = factor_pair(64, 512, 28, true).unwrap();
        assert_eq!(ka * kb, 64);
        assert!(ka % 2 == 0 && kb % 2 == 0);
        let (ka, kb) = factor_pair(16, 512, 28, false).unwrap();
        assert_eq!(ka * kb, 16);
    }

    #[test]
    fn crme_beats_real_vandermonde_at_scale() {
        let layer = small_layer();
        // (n, delta) = (20, 16): the regime where real Vandermonde degrades.
        let crme = evaluate(CodeFamily::Crme, &layer, 20, 16, 4, 1).unwrap();
        let real = evaluate(CodeFamily::Vandermonde, &layer, 20, 16, 4, 1).unwrap();
        assert!(
            crme.cond_worst < real.cond_worst,
            "CRME {:.3e} should beat real Vandermonde {:.3e}",
            crme.cond_worst,
            real.cond_worst
        );
        assert!(crme.mse_worst < real.mse_worst);
        assert!(crme.mse_worst < 1e-18, "CRME mse {:e}", crme.mse_worst);
    }

    #[test]
    fn sweep_produces_all_schemes() {
        let layer = small_layer();
        let pts = stability_sweep(&layer, &[(5, 4)], 2, 3);
        assert_eq!(pts.len(), CodeFamily::ALL.len());
        for p in &pts {
            assert_eq!(p.gamma, 1);
            assert!(p.cond_worst >= 1.0);
            assert!(CodeFamily::parse(p.code).is_some(), "tag {:?}", p.code);
        }
    }
}
