//! Distributed LeNet-5 serving: the end-to-end driver (DESIGN.md §E2E).
//! Every convolutional layer of a LeNet-5 runs through the full FCDCC
//! stack (APCP/KCCP → CRME encode → simulated cluster with stragglers →
//! first-δ decode); pooling, ReLU and the FC head run on the master, as
//! in the paper (CDC is applied to ConvLs only).

use crate::cluster::{Cluster, StragglerModel};
use crate::engine::TaskEngine;
use crate::fcdcc::FcdccPlan;
use crate::metrics::Stats;
use crate::model::{network::softmax, Layer, Network};
use crate::tensor::{Tensor3, Tensor4};
use crate::util::{mse, rng::Rng};
use anyhow::{anyhow, Result};
use std::sync::Arc;
use std::time::Instant;

/// Serving-loop configuration.
pub struct ServeConfig {
    pub n_workers: usize,
    pub requests: usize,
    pub straggler: StragglerModel,
    pub engine: Arc<dyn TaskEngine>,
    /// (k_A, k_B) per conv layer (conv1, conv2).
    pub partitions: [(usize, usize); 2],
    pub seed: u64,
}

impl ServeConfig {
    /// The default configuration matching the AOT artifact set:
    /// conv1 (4,2), conv2 (2,2), n = 4 workers.
    pub fn default_with_engine(engine: Arc<dyn TaskEngine>) -> Self {
        Self {
            n_workers: 4,
            requests: 16,
            straggler: StragglerModel::None,
            engine,
            partitions: [(4, 2), (2, 2)],
            seed: 2024,
        }
    }
}

/// Serving-loop results.
#[derive(Clone, Debug)]
pub struct ServeStats {
    pub latency: Stats,
    pub throughput_rps: f64,
    pub decode: Stats,
    /// Logit MSE vs the single-node forward pass, averaged over requests.
    pub mean_logit_mse: f64,
    /// Requests whose argmax class differed from the reference.
    pub class_mismatches: usize,
    pub requests: usize,
}

struct ConvStage {
    plan: FcdccPlan,
    coded_filters: Vec<Vec<Tensor4>>,
    bias: Vec<f64>,
}

/// Run the distributed LeNet-5 serving loop; returns latency/throughput
/// plus fidelity vs the single-node reference.
pub fn serve_lenet(cfg: ServeConfig) -> Result<ServeStats> {
    let net = Network::lenet5_random(42);
    // Pull the two conv layers' weights out of the network definition.
    let mut stages: Vec<ConvStage> = Vec::new();
    for layer in &net.layers {
        if let Layer::Conv {
            shape,
            weights,
            bias,
        } = layer
        {
            let (k_a, k_b) = cfg.partitions[stages.len()];
            let plan = FcdccPlan::new_crme(shape, k_a, k_b, cfg.n_workers)?;
            let coded_filters = plan.encode_filters(weights);
            stages.push(ConvStage {
                plan,
                coded_filters,
                bias: bias.clone(),
            });
        }
    }
    if stages.len() != 2 {
        return Err(anyhow!("expected 2 conv layers in LeNet-5"));
    }

    let mut cluster = Cluster::new(cfg.n_workers, Arc::clone(&cfg.engine));
    let mut rng = Rng::new(cfg.seed);
    let mut latencies = Vec::with_capacity(cfg.requests);
    let mut decodes = Vec::new();
    let mut mses = Vec::with_capacity(cfg.requests);
    let mut mismatches = 0usize;
    let t_all = Instant::now();

    for _ in 0..cfg.requests {
        let x = Tensor3::random(1, 32, 32, &mut rng);
        let t0 = Instant::now();

        // conv1 distributed + bias + relu + pool
        let mut stage_idx = 0usize;
        let mut t = x.clone();
        let mut logits: Vec<f64> = Vec::new();
        let mut flat: Option<Vec<f64>> = None;
        for layer in &net.layers {
            match layer {
                Layer::Conv { .. } => {
                    let stage = &stages[stage_idx];
                    stage_idx += 1;
                    let (mut y, report) = cluster.run_job(
                        &stage.plan,
                        &t,
                        &stage.coded_filters,
                        &cfg.straggler,
                        &mut rng,
                    )?;
                    decodes.push(report.decode_secs);
                    for n in 0..y.c {
                        let base = y.idx(n, 0, 0);
                        let plane = y.h * y.w;
                        for v in &mut y.data[base..base + plane] {
                            *v += stage.bias[n];
                        }
                    }
                    t = y;
                }
                Layer::Relu => {
                    if let Some(f) = &mut flat {
                        for v in f.iter_mut() {
                            if *v < 0.0 {
                                *v = 0.0;
                            }
                        }
                    } else {
                        t.relu_inplace();
                    }
                }
                Layer::MaxPool { size, stride } => {
                    t = crate::model::network::pool(&t, *size, *stride, true);
                }
                Layer::AvgPool { size, stride } => {
                    t = crate::model::network::pool(&t, *size, *stride, false);
                }
                Layer::Dense { w, b } => {
                    let input = flat.take().unwrap_or_else(|| t.data.clone());
                    let mut y = w.matvec(&input);
                    for (yi, bi) in y.iter_mut().zip(b) {
                        *yi += bi;
                    }
                    flat = Some(y);
                }
            }
        }
        if let Some(f) = flat {
            logits = f;
        }
        latencies.push(t0.elapsed().as_secs_f64());

        // Fidelity vs single-node reference.
        let want = net.forward(&x);
        mses.push(mse(&logits, &want));
        let argmax = |v: &[f64]| {
            v.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap()
        };
        let p_got = softmax(&logits);
        let p_want = softmax(&want);
        if argmax(&p_got) != argmax(&p_want) {
            mismatches += 1;
        }
    }
    let total = t_all.elapsed().as_secs_f64();
    cluster.shutdown();

    Ok(ServeStats {
        latency: Stats::from(&latencies),
        throughput_rps: cfg.requests as f64 / total,
        decode: Stats::from(&decodes),
        mean_logit_mse: mses.iter().sum::<f64>() / mses.len() as f64,
        class_mismatches: mismatches,
        requests: cfg.requests,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Im2colEngine;
    use std::time::Duration;

    #[test]
    fn serve_matches_single_node() {
        let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
        cfg.requests = 3;
        cfg.straggler = StragglerModel::FixedCount {
            count: 1,
            delay: Duration::from_millis(30),
        };
        let stats = serve_lenet(cfg).unwrap();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.class_mismatches, 0);
        assert!(stats.mean_logit_mse < 1e-16, "mse={:e}", stats.mean_logit_mse);
        assert!(stats.throughput_rps > 0.0);
    }
}
