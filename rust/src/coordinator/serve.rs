//! Distributed LeNet-5 serving: the end-to-end driver (DESIGN.md §E2E).
//! Every convolutional layer runs through the full FCDCC stack
//! (APCP/KCCP → CRME encode → coded cluster with stragglers → first-δ
//! decode); pooling, ReLU and the FC head run on the master, as in the
//! paper (CDC is applied to ConvLs only).
//!
//! Serving is a **coalescing request scheduler** over the concurrent job
//! runtime: up to [`ServeConfig::max_in_flight`] requests are in flight
//! at once, and requests that reach the same conv stage wait in that
//! stage's queue until [`ServeConfig::batch_window`] of them have
//! gathered (count-based, deterministic) — then the whole window is
//! fused into **one** coded job via `NetworkPlan::submit_batch`. The
//! coding is linear, so the per-job master costs (CRME encode setup,
//! recovery-matrix inversion, dispatch) are paid once per batch instead
//! of once per request, and after decode the batch is split back into
//! per-request activations (`NetworkPlan::absorb_batch_output`). A
//! partial window is flushed only when the pipeline would otherwise
//! stall, so no request waits forever. `batch_window = 1` degenerates to
//! pure pipelined serving, and depth 1 to the old strictly-sequential
//! loop — same code path, no overlap.
//!
//! The scheduler is **fault tolerant** (DESIGN.md §Fault tolerance): a
//! job that times out or becomes undecodable is re-dispatched to the
//! current live set with a bounded retry budget and exponential backoff;
//! when quarantine (fed by the cluster's health tracker) shrinks the
//! live set below full strength, stages are re-planned for the smaller n
//! (the paper's flexibility property — n is a code parameter, not a
//! partition parameter) and restored when workers are readmitted; and
//! when even the live set cannot reach a stage's recovery threshold δ,
//! the stage **degrades** to master-local execution — bitwise identical
//! to the reference conv — so requests complete with `degraded`
//! accounting instead of failing. Under any single-worker fault the loop
//! completes 100% of requests.
//!
//! Serving can also run **open-loop** (DESIGN.md §Serving front-end &
//! overload control): arrivals come from a seeded synthetic-time
//! generator ([`ArrivalSpec`] via [`ServeConfig::arrival`]) or from the
//! TCP front-end ([`serve_frontend_on`]) instead of being demand-paced
//! by completions. Open-loop arrivals pass through a **bounded admission
//! queue** ([`ServeConfig::queue_cap`]): when it is full the newcomer is
//! shed with an explicit `Busy` — never a silent drop. A per-request
//! **deadline** ([`ServeConfig::request_deadline`]) is checked at every
//! stage boundary and on the retry path of a failed job, evicting the
//! request with `DeadlineExceeded` before more coded work is spent on
//! it. Every arrival resolves to exactly one [`RequestOutcome`], and the
//! buffer-hygiene invariant (`arena_outstanding == 0`) holds under any
//! shedding pattern. Synthetic arrivals drive a **virtual clock** (one
//! blocking job absorb = one stage interval; jobs absorb strictly FIFO)
//! so a fixed seed reproduces the same shed/expire/complete pattern on
//! every run and machine.

use crate::cluster::frontend::FrontendRequest;
use crate::cluster::{
    BatchOutcome, Cluster, FaultPlan, HealthPolicy, JobHandle, Responder, StragglerModel,
    TcpConfig, TcpTransport,
};
use crate::coordinator::arrival::{ArrivalGen, ArrivalSpec};
use crate::coding::{registry, CodeFamily};
use crate::engine::{Im2colEngine, TaskEngine};
use crate::fcdcc::{NetworkPlan, PlanOptions, StageVariant};
use crate::metrics::{CacheStats, EncodeStats, LatencyHistogram, MembershipCounters, Stats};
use crate::model::network::softmax;
use crate::model::{Activation, Network};
use crate::tensor::Tensor3;
use crate::util::{mse, rng::Rng};
use anyhow::{ensure, Result};
use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Which wire the cluster runs on.
#[derive(Clone, Debug, Default)]
pub enum TransportKind {
    /// In-process worker threads over mpsc channels — the default:
    /// deterministic, offline, what every tier-1 test runs on.
    #[default]
    InProcess,
    /// Remote worker processes over framed TCP with membership,
    /// heartbeats, and eviction (`--role coordinator --workers …`).
    /// `TcpConfig::workers` must name exactly `n_workers` addresses.
    Tcp(TcpConfig),
}

/// Serving-loop configuration.
pub struct ServeConfig {
    pub n_workers: usize,
    pub requests: usize,
    pub straggler: StragglerModel,
    pub engine: Arc<dyn TaskEngine>,
    /// (k_A, k_B) per conv layer (conv1, conv2).
    pub partitions: [(usize, usize); 2],
    pub seed: u64,
    /// Maximum requests concurrently in flight on the cluster
    /// (1 = strictly sequential serving).
    pub max_in_flight: usize,
    /// Requests coalesced per coded job: a stage queue is flushed as soon
    /// as this many requests gather (partial windows flush only when the
    /// pipeline would stall). 1 = one job per request (no coalescing).
    /// Must not exceed `max_in_flight`, or the window could never fill.
    pub batch_window: usize,
    /// Check every k-th request (0, k, 2k, …) against the single-node
    /// reference forward pass. 0 disables verification entirely, so
    /// throughput numbers aren't dominated by the uncoded reference.
    pub verify_every: usize,
    /// Pack coded filter slabs into GEMM panels once at plan build (the
    /// default). `false` (the CLI's `--no-prepack`) re-packs per job on
    /// the workers — the A/B baseline for the prepack speedup.
    pub prepack: bool,
    /// Code family every conv stage is planned with (`--code` /
    /// `FCDCC_CODE`, defaulting to the session's selected family).
    pub code: CodeFamily,
    /// Deterministic fault injection installed on the cluster
    /// (`--fault-*` / `FCDCC_CHAOS_SEED`; [`FaultPlan::none`] = clean).
    pub fault_plan: FaultPlan,
    /// Re-dispatches allowed per coded job before its members degrade to
    /// master-local execution (`--retry-budget`).
    pub retry_budget: usize,
    /// Thresholds of the worker-health state machine.
    pub health: HealthPolicy,
    /// Re-plan stages for the shrunken live set when quarantine bites
    /// (`false` keeps dispatching the full-n plan and leans on
    /// retry + degradation alone).
    pub replan: bool,
    /// Per-job collection deadline (`--collect-timeout-ms`).
    pub collect_timeout: Duration,
    /// Bounded admission-queue capacity for open-loop sources
    /// (`--queue-cap`). An arrival that finds the queue full is shed
    /// with an explicit `Busy` — load shedding is never a silent drop.
    /// Closed-loop serving is demand-paced and never queues.
    pub queue_cap: usize,
    /// Default per-request deadline (`--request-deadline-ms`): a request
    /// whose deadline passes before its logits are ready is evicted at
    /// the next stage boundary with `DeadlineExceeded` instead of
    /// consuming more coded work. Network clients may override it
    /// per-request; `None` = no deadline. Under a synthetic arrival
    /// process the deadline is measured in virtual seconds.
    pub request_deadline: Option<Duration>,
    /// Open-loop synthetic arrival process (`--arrival`,
    /// `--arrival-rate`, `--arrival-seed`, `--arrival-burst`). `None` =
    /// the classic closed loop: the next request is admitted as soon as
    /// the pipeline depth frees, and overload cannot occur.
    pub arrival: Option<ArrivalSpec>,
    /// The wire the cluster runs on ([`TransportKind::InProcess`] by
    /// default; [`TransportKind::Tcp`] drives real remote workers).
    pub transport: TransportKind,
}

impl ServeConfig {
    /// The default configuration matching the AOT artifact set:
    /// conv1 (4,2), conv2 (2,2), n = 4 workers, sequential serving with
    /// every request verified.
    pub fn default_with_engine(engine: Arc<dyn TaskEngine>) -> Self {
        Self {
            n_workers: 4,
            requests: 16,
            straggler: StragglerModel::None,
            engine,
            partitions: [(4, 2), (2, 2)],
            seed: 2024,
            max_in_flight: 1,
            batch_window: 1,
            verify_every: 1,
            prepack: true,
            code: registry::default_family(),
            fault_plan: FaultPlan::none(),
            retry_budget: 2,
            health: HealthPolicy::default(),
            replan: true,
            collect_timeout: Duration::from_secs(60),
            queue_cap: 64,
            request_deadline: None,
            arrival: None,
            transport: TransportKind::InProcess,
        }
    }
}

impl Default for ServeConfig {
    /// Default serving configuration: workers run the fused im2col
    /// engine (the optimized path; `DirectEngine` stays the correctness
    /// oracle for tests).
    fn default() -> Self {
        Self::default_with_engine(Arc::new(Im2colEngine))
    }
}

/// Terminal outcome of one arrival. Every request that ever arrived
/// resolves to exactly one of these — admission control sheds loudly,
/// never silently.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestOutcome {
    /// Served to completion; its `logits` slot is filled.
    Completed,
    /// Shed at admission with an explicit `Busy`: the bounded queue was
    /// full.
    Shed,
    /// Evicted with `DeadlineExceeded` after its deadline passed — at a
    /// stage boundary, in the admission queue, or on a failed job's
    /// retry path.
    Expired,
}

/// Serving-loop results.
#[derive(Clone, Debug)]
pub struct ServeStats {
    /// Per-request latency over **completed** requests only, arrival →
    /// logits (includes queueing). Shed and expired requests have no
    /// service latency and are excluded rather than silently counted at
    /// whatever instant the run ended.
    pub latency: Stats,
    pub throughput_rps: f64,
    pub decode: Stats,
    /// Logit MSE vs the single-node forward pass, averaged over the
    /// verified requests (0.0 when verification is disabled).
    pub mean_logit_mse: f64,
    /// Verified requests whose argmax class differed from the reference.
    pub class_mismatches: usize,
    pub requests: usize,
    /// Requests actually checked against the reference.
    pub verified: usize,
    /// The in-flight depth the scheduler ran with.
    pub max_in_flight: usize,
    /// The coalescing window the scheduler ran with.
    pub batch_window: usize,
    /// Coded jobs dispatched (= decodes performed). With coalescing
    /// (`2 <= batch_window <= max_in_flight`) this lands strictly below
    /// `requests · conv_stages`. Retries of a failed job are counted in
    /// `retries`, not here.
    pub coded_jobs: usize,
    /// Mean samples per coded job.
    pub mean_batch: f64,
    /// Recovery-inverse cache counters: `misses` is exactly the number
    /// of recovery-matrix inversions performed across the whole run.
    pub inverse_cache: CacheStats,
    /// Slab-arena counters: `misses` is exactly the number of hot-path
    /// heap allocations (encode slabs, worker reply blocks, decode
    /// staging) across the whole run — steady-state serving should
    /// allocate only during warm-up.
    pub arena: CacheStats,
    /// Worker-side filter-slab GEMM packs across the run. With
    /// prepacking on (the default) this is **zero**: panels were packed
    /// once at plan build and stayed plan-resident.
    pub pack_count: u64,
    /// The dispatched compute-kernel backend the run executed on
    /// (`linalg::kernel::active()`): "scalar", "avx2", "neon", or the
    /// opt-in "fused-ma".
    pub kernel: &'static str,
    /// The code family every conv stage was planned with
    /// (`CodeFamily::tag()`): "crme", "conv", "sparse", ….
    pub code: &'static str,
    /// Encode-pass accounting of the program-compiled input encoder,
    /// accumulated across every stage and request: `terms` coefficient
    /// applications performed where a dense scan of all `k_A`
    /// coefficients would have visited `dense_terms` slots.
    pub encode: EncodeStats,
    /// Requests that hard-failed (no logits). Retry + degradation make
    /// this **zero by construction**: a job past its retry budget
    /// degrades its members to master-local execution instead of
    /// erroring.
    pub failed_requests: usize,
    /// Coded jobs re-dispatched after a timeout / undecodable failure.
    pub retries: usize,
    /// Requests that completed with at least one conv stage degraded to
    /// master-local execution (still bit-exact vs the reference conv).
    pub degraded_requests: usize,
    /// Worker quarantine transitions observed by the health tracker.
    pub quarantine_events: u64,
    /// Quarantined workers probed and readmitted to the dispatch set.
    pub readmissions: u64,
    /// Transport/membership counters (heartbeats, evictions, reconnect
    /// readmissions, corrupt frames, epoch). All-zero on the in-process
    /// transport, which has no membership protocol.
    pub membership: MembershipCounters,
    /// Slab-arena buffers still checked out after cluster shutdown —
    /// the buffer-hygiene invariant; **zero** on every path (decoded,
    /// retried, timed out, degraded).
    pub arena_outstanding: u64,
    /// Final logits of every request, in request order (empty for shed
    /// or expired requests).
    pub logits: Vec<Vec<f64>>,
    /// Total arrivals observed (completed + shed + expired). Equals
    /// `requests` — the field exists so overload accounting reads
    /// explicitly at call sites.
    pub arrivals: usize,
    /// Requests that reached [`RequestOutcome::Completed`].
    pub completed_requests: usize,
    /// Arrivals shed at admission with an explicit `Busy`.
    pub shed_requests: usize,
    /// Requests evicted with `DeadlineExceeded`.
    pub expired_requests: usize,
    /// The admission-queue capacity the run enforced.
    pub queue_cap: usize,
    /// High-water mark of the admission queue — never exceeds
    /// `queue_cap` by construction.
    pub peak_queue_depth: usize,
    /// Fixed-bucket log-scale latency histogram over completed requests
    /// (p50/p90/p99/p999 at ≈±10% bucket resolution).
    pub latency_hist: LatencyHistogram,
    /// Terminal outcome per arrival id. `None` never survives a
    /// completed run: every arrival resolves exactly once.
    pub outcomes: Vec<Option<RequestOutcome>>,
}

/// Where one request currently is in its lifecycle.
enum ReqState {
    /// Needs master-side layers run (or has just been un-parked).
    Runnable,
    /// Waiting in a stage's coalescing queue.
    Queued,
    /// Member of an in-flight coded job.
    InJob,
    /// Out of layers; awaiting retirement.
    Done,
}

/// One request moving through the pipeline.
struct Request {
    /// Request index; also its slot in the output logits.
    id: usize,
    a: Activation,
    layer_idx: usize,
    state: ReqState,
    /// Kept only for requests selected for reference verification.
    input: Option<Tensor3>,
    /// Arrival timestamp on the serving clock (seconds).
    t_arr: f64,
    /// Absolute deadline on the serving clock, if any.
    deadline: Option<f64>,
    /// Completion timestamp, set when the request runs out of layers.
    finished_t: Option<f64>,
    /// Reply handle for network-served requests.
    reply: Option<Responder>,
}

/// The serving clock deadlines and latencies are measured on. Closed-loop
/// and network serving run on wall time; synthetic arrivals run on
/// virtual time, where one blocking job absorb advances the clock by one
/// stage interval and idle periods jump to the next arrival — fully
/// deterministic for a fixed seed.
enum Clock {
    Wall(Instant),
    Virtual { now: f64, stage_secs: f64 },
}

impl Clock {
    fn now(&self) -> f64 {
        match self {
            Clock::Wall(t0) => t0.elapsed().as_secs_f64(),
            Clock::Virtual { now, .. } => *now,
        }
    }

    fn advance_stage(&mut self) {
        if let Clock::Virtual { now, stage_secs } = self {
            *now += *stage_secs;
        }
    }

    fn jump_to(&mut self, t: f64) {
        if let Clock::Virtual { now, .. } = self {
            if t > *now {
                *now = t;
            }
        }
    }
}

/// Where requests come from.
enum Source {
    /// Demand-paced: the next request is generated when depth frees.
    Closed,
    /// Seeded synthetic-time arrival process (virtual clock).
    Open(ArrivalGen),
    /// The TCP front-end's request channel (wall clock).
    Net(Receiver<FrontendRequest>),
}

/// One arrival waiting in the bounded admission queue.
struct Pending {
    id: usize,
    input: Tensor3,
    t_arr: f64,
    deadline: Option<f64>,
    reply: Option<Responder>,
}

/// Outcome bookkeeping: one terminal resolution per arrival, latency
/// accounting over completed requests only, and queue-depth tracking.
struct Ledger {
    outcomes: Vec<Option<RequestOutcome>>,
    logits: Vec<Vec<f64>>,
    latencies: Vec<f64>,
    hist: LatencyHistogram,
    shed_n: usize,
    expired_n: usize,
    completed_n: usize,
    peak_queue: usize,
}

impl Ledger {
    fn new() -> Ledger {
        Ledger {
            outcomes: Vec::new(),
            logits: Vec::new(),
            latencies: Vec::new(),
            hist: LatencyHistogram::new(),
            shed_n: 0,
            expired_n: 0,
            completed_n: 0,
            peak_queue: 0,
        }
    }

    fn arrivals(&self) -> usize {
        self.outcomes.len()
    }

    /// Register a new arrival and return its request id.
    fn new_id(&mut self) -> usize {
        self.outcomes.push(None);
        self.logits.push(Vec::new());
        self.outcomes.len() - 1
    }

    fn note_queue_depth(&mut self, depth: usize) {
        self.peak_queue = self.peak_queue.max(depth);
    }

    fn shed(&mut self, id: usize, reply: Option<Responder>) {
        debug_assert!(self.outcomes[id].is_none(), "double terminal for {id}");
        self.outcomes[id] = Some(RequestOutcome::Shed);
        self.shed_n += 1;
        if let Some(r) = reply {
            r.busy();
        }
    }

    fn expire(&mut self, id: usize, reply: Option<Responder>) {
        debug_assert!(self.outcomes[id].is_none(), "double terminal for {id}");
        self.outcomes[id] = Some(RequestOutcome::Expired);
        self.expired_n += 1;
        if let Some(r) = reply {
            r.deadline_exceeded();
        }
    }

    fn complete(&mut self, id: usize, logits: Vec<f64>, latency: f64, reply: Option<Responder>) {
        debug_assert!(self.outcomes[id].is_none(), "double terminal for {id}");
        self.outcomes[id] = Some(RequestOutcome::Completed);
        self.completed_n += 1;
        self.latencies.push(latency);
        self.hist.record(latency);
        if let Some(r) = reply {
            r.logits(&logits);
        }
        self.logits[id] = logits;
    }
}

/// Push an arrival into the bounded admission queue, or shed it with an
/// explicit `Busy` when the queue is full.
fn enqueue_arrival(
    cfg: &ServeConfig,
    ledger: &mut Ledger,
    pending: &mut VecDeque<Pending>,
    p: Pending,
) {
    if pending.len() >= cfg.queue_cap {
        ledger.shed(p.id, p.reply);
    } else {
        pending.push_back(p);
        ledger.note_queue_depth(pending.len());
    }
}

/// Register one front-end request as an arrival. The wire deadline wins
/// over the server default (`0` on the wire = no override).
fn accept_net(
    cfg: &ServeConfig,
    clock: &Clock,
    ledger: &mut Ledger,
    pending: &mut VecDeque<Pending>,
    msg: FrontendRequest,
) {
    let t = clock.now();
    let id = ledger.new_id();
    let deadline = msg
        .deadline
        .or(cfg.request_deadline)
        .map(|d| t + d.as_secs_f64());
    let p = Pending {
        id,
        input: msg.input,
        t_arr: t,
        deadline,
        reply: Some(msg.responder),
    };
    enqueue_arrival(cfg, ledger, pending, p);
}

/// Move one arrival into the pipeline.
fn admit(cfg: &ServeConfig, active: &mut Vec<Request>, p: Pending) {
    let verify = cfg.verify_every > 0 && p.id % cfg.verify_every == 0;
    let a = Activation::new(&p.input);
    active.push(Request {
        id: p.id,
        a,
        layer_idx: 0,
        state: ReqState::Runnable,
        input: verify.then_some(p.input),
        t_arr: p.t_arr,
        deadline: p.deadline,
        finished_t: None,
        reply: p.reply,
    });
}

/// One in-flight coded job and the requests fused into it.
struct BatchJob {
    stage: usize,
    /// Member request ids, in batch (submission) order.
    members: Vec<usize>,
    handle: JobHandle,
    /// Dispatches so far (1 = first attempt).
    attempts: usize,
    /// The re-planned variant this attempt was dispatched with
    /// (`None` = the base full-cluster stage plan).
    variant: Option<Arc<StageVariant>>,
}

/// How the scheduler currently runs one conv stage, derived from the
/// cluster's live set before every dispatch.
enum StageMode {
    /// Full-cluster plan (the live set is complete, or re-planning is
    /// disabled).
    Full,
    /// Re-planned for the shrunken live set, dispatched via
    /// `submit_batch_mapped`.
    Variant(Arc<StageVariant>),
    /// The live set cannot reach this stage's δ: run the conv on the
    /// master (graceful degradation).
    Degraded,
}

/// Mutable fault-handling state threaded through the scheduler.
struct FaultCtx<'a> {
    cfg: &'a ServeConfig,
    /// Re-planned variants, keyed by (stage, live set) — built once per
    /// distinct shrink and reused until readmission restores the full
    /// plan.
    variants: BTreeMap<(usize, Vec<usize>), Arc<StageVariant>>,
    retries: usize,
    /// Per-request: completed with ≥1 degraded stage.
    degraded: Vec<bool>,
}

impl FaultCtx<'_> {
    /// Pick the dispatch mode for `stage` against the current live set.
    fn stage_mode(&mut self, plan: &NetworkPlan, cluster: &Cluster, stage: usize) -> StageMode {
        let live = cluster.live_workers();
        if live.len() == self.cfg.n_workers || !self.cfg.replan {
            return StageMode::Full;
        }
        let delta = plan.stages()[stage].plan.delta();
        if live.len() < delta {
            return StageMode::Degraded;
        }
        let key = (stage, live);
        if let Some(v) = self.variants.get(&key) {
            return StageMode::Variant(Arc::clone(v));
        }
        match plan.replan_stage(stage, &key.1) {
            Ok(v) => {
                let v = Arc::new(v);
                self.variants.insert(key, Arc::clone(&v));
                StageMode::Variant(v)
            }
            // The code family rejected the shrunken n: degrade rather
            // than keep dispatching to quarantined workers.
            Err(_) => StageMode::Degraded,
        }
    }
}

/// Run the distributed LeNet-5 serving loop; returns latency/throughput
/// plus fidelity vs the single-node reference. With
/// [`ServeConfig::arrival`] set, the loop runs open-loop on a virtual
/// clock: overload is possible, and arrivals resolve to
/// completed / shed / expired instead of all completing.
pub fn serve_lenet(cfg: ServeConfig) -> Result<ServeStats> {
    let source = match &cfg.arrival {
        Some(spec) => Source::Open(ArrivalGen::new(spec)),
        None => Source::Closed,
    };
    serve_with_source(cfg, source)
}

/// Serve requests arriving over the TCP front-end: the same pipeline,
/// but arrivals come from `rx` (one [`FrontendRequest`] per client
/// `Request` frame) and every terminal outcome is written back to its
/// client — logits, `Busy`, or `DeadlineExceeded`. Returns after
/// `cfg.requests` arrivals have resolved, or earlier if the listener
/// shuts the channel down.
pub fn serve_frontend_on(cfg: ServeConfig, rx: Receiver<FrontendRequest>) -> Result<ServeStats> {
    ensure!(
        cfg.arrival.is_none(),
        "network serving takes arrivals from clients, not a synthetic process"
    );
    serve_with_source(cfg, Source::Net(rx))
}

fn serve_with_source(cfg: ServeConfig, source: Source) -> Result<ServeStats> {
    ensure!(cfg.requests > 0, "need at least one request");
    ensure!(cfg.queue_cap >= 1, "queue_cap must be >= 1");
    ensure!(cfg.max_in_flight >= 1, "max_in_flight must be >= 1");
    ensure!(cfg.batch_window >= 1, "batch_window must be >= 1");
    // A window wider than the pipeline depth can never fill: every flush
    // would be a stall-path partial of at most `max_in_flight` samples,
    // silently disabling the batching the caller asked for.
    ensure!(
        cfg.batch_window <= cfg.max_in_flight,
        "batch_window ({}) cannot exceed max_in_flight ({}); raise the pipeline depth",
        cfg.batch_window,
        cfg.max_in_flight
    );
    let net = Network::lenet5_random(42);
    let opts = PlanOptions {
        prepack: cfg.prepack,
        code: cfg.code,
        ..PlanOptions::default()
    };
    let plan = NetworkPlan::with_options(net, &cfg.partitions, cfg.n_workers, opts)?;
    let mut cluster = match &cfg.transport {
        TransportKind::InProcess => Cluster::new(cfg.n_workers, Arc::clone(&cfg.engine)),
        TransportKind::Tcp(tcp) => {
            ensure!(
                tcp.workers.len() == cfg.n_workers,
                "TCP transport names {} workers but n_workers = {}",
                tcp.workers.len(),
                cfg.n_workers
            );
            // Reply blocks decode straight into the plan arena, exactly
            // like the in-process path.
            let transport = TcpTransport::connect(tcp.clone(), Arc::clone(plan.arena()))?;
            Cluster::with_transport(Box::new(transport))
        }
    };
    cluster.collect_timeout = cfg.collect_timeout;
    cluster.set_fault_plan(cfg.fault_plan.clone());
    cluster.set_health_policy(cfg.health);
    let stats = run_pipeline(&plan, &mut cluster, &cfg, source);
    cluster.shutdown();
    // Only after shutdown is the hygiene invariant decidable: the
    // workers have drained their queues and every reply was recycled.
    stats.map(|mut s| {
        s.arena_outstanding = plan.arena().outstanding();
        s
    })
}

fn run_pipeline(
    plan: &NetworkPlan,
    cluster: &mut Cluster,
    cfg: &ServeConfig,
    mut source: Source,
) -> Result<ServeStats> {
    // Separate input / fate streams so request inputs are identical at
    // any pipeline depth or window (fate draws interleave differently
    // once jobs overlap and coalesce, inputs must not).
    let mut input_rng = Rng::new(cfg.seed);
    let mut fate_rng = Rng::new(cfg.seed ^ 0x9E37_79B9_7F4A_7C15);
    let n_stages = plan.stages().len();
    let mut clock = match &source {
        Source::Open(gen) => Clock::Virtual {
            now: 0.0,
            stage_secs: gen.stage_secs(),
        },
        _ => Clock::Wall(Instant::now()),
    };
    let mut ledger = Ledger::new();
    // Bounded admission queue (open-loop sources only).
    let mut pending: VecDeque<Pending> = VecDeque::new();
    let mut net_closed = false;
    // Active requests, ascending by id (admission order; retirement
    // preserves order).
    let mut active: Vec<Request> = Vec::new();
    // Per-stage coalescing queues of request ids.
    let mut queues: Vec<VecDeque<usize>> = vec![VecDeque::new(); n_stages];
    // In-flight coded jobs, submission (FIFO) order.
    let mut jobs: VecDeque<BatchJob> = VecDeque::new();
    let mut batch_sizes: Vec<usize> = Vec::new();
    let mut decodes = Vec::new();
    let mut mses = Vec::new();
    let mut mismatches = 0usize;
    let mut ctx = FaultCtx {
        cfg,
        variants: BTreeMap::new(),
        retries: 0,
        degraded: vec![false; cfg.requests],
    };
    let t_all = Instant::now();

    loop {
        // Pull every arrival whose timestamp has come into the bounded
        // admission queue (open-loop sources; the closed loop generates
        // demand-paced arrivals in the admission step below and never
        // queues). Arrivals are capped at `cfg.requests` so every run
        // terminates with full outcome accounting.
        match &mut source {
            Source::Closed => {}
            Source::Open(gen) => {
                while ledger.arrivals() < cfg.requests && gen.peek() <= clock.now() {
                    let t = gen.next_arrival();
                    // Draw the input even when the arrival is about to
                    // be shed: inputs stay id-aligned with the closed
                    // loop, so completed logits are comparable
                    // bit-for-bit across load patterns.
                    let input = Tensor3::random(1, 32, 32, &mut input_rng);
                    let id = ledger.new_id();
                    let deadline = cfg.request_deadline.map(|d| t + d.as_secs_f64());
                    let p = Pending {
                        id,
                        input,
                        t_arr: t,
                        deadline,
                        reply: None,
                    };
                    enqueue_arrival(cfg, &mut ledger, &mut pending, p);
                }
            }
            Source::Net(rx) => {
                while !net_closed && ledger.arrivals() < cfg.requests {
                    match rx.try_recv() {
                        Ok(msg) => accept_net(cfg, &clock, &mut ledger, &mut pending, msg),
                        Err(TryRecvError::Empty) => break,
                        Err(TryRecvError::Disconnected) => net_closed = true,
                    }
                }
            }
        }

        // Admission: move arrivals into the pipeline while depth allows,
        // evicting any whose deadline already passed in the queue.
        if matches!(source, Source::Closed) {
            while active.len() < cfg.max_in_flight && ledger.arrivals() < cfg.requests {
                let input = Tensor3::random(1, 32, 32, &mut input_rng);
                let id = ledger.new_id();
                let t = clock.now();
                let deadline = cfg.request_deadline.map(|d| t + d.as_secs_f64());
                let p = Pending {
                    id,
                    input,
                    t_arr: t,
                    deadline,
                    reply: None,
                };
                admit(cfg, &mut active, p);
            }
        } else {
            while active.len() < cfg.max_in_flight {
                let Some(p) = pending.pop_front() else { break };
                if p.deadline.is_some_and(|d| clock.now() > d) {
                    ledger.expire(p.id, p.reply);
                    continue;
                }
                admit(cfg, &mut active, p);
            }
        }

        // Deadline eviction at the stage boundary: a request that is
        // runnable or parked in a coalescing queue past its deadline is
        // removed *before* any further work is spent on it. Members of
        // an in-flight job are never evicted mid-job (their buffers are
        // on the wire); a failed job's expired members are evicted on
        // its retry path in `absorb_job`.
        let now = clock.now();
        let mut i = 0;
        while i < active.len() {
            let evict = matches!(active[i].state, ReqState::Runnable | ReqState::Queued)
                && active[i].deadline.is_some_and(|d| now > d);
            if !evict {
                i += 1;
                continue;
            }
            let req = active.remove(i);
            for q in queues.iter_mut() {
                q.retain(|&id| id != req.id);
            }
            ledger.expire(req.id, req.reply);
        }

        // Advance every runnable request through master-side layers to
        // its next conv (→ that stage's coalescing queue) or to the end.
        // Requests at the same layer cursor advance as one group
        // (`run_local_batch`): the FC head of co-batched requests runs
        // as a single shared GEMM, bit-identical to advancing each
        // request alone. Groups are keyed by cursor (BTreeMap:
        // deterministic order) and members stay in admission order, so
        // per-queue arrival order is unchanged.
        let mut progressed = false;
        let mut groups: BTreeMap<usize, Vec<&mut Request>> = BTreeMap::new();
        for req in active.iter_mut() {
            if matches!(req.state, ReqState::Runnable) {
                groups.entry(req.layer_idx).or_default().push(req);
            }
        }
        for (cursor0, mut members) in groups {
            progressed = true;
            let mut cursor = cursor0;
            let next_stage = {
                let mut acts: Vec<&mut Activation> =
                    members.iter_mut().map(|r| &mut r.a).collect();
                plan.run_local_batch(&mut acts, &mut cursor)
            };
            for req in members.iter_mut() {
                req.layer_idx = cursor;
                match next_stage {
                    Some(stage) => {
                        queues[stage].push_back(req.id);
                        req.state = ReqState::Queued;
                    }
                    None => {
                        req.state = ReqState::Done;
                        req.finished_t = Some(clock.now());
                    }
                }
            }
        }

        // Retire finished requests (stats are keyed by request id, so
        // out-of-order completion under coalescing is fine). A request
        // only reaches `Done` through the layer walk, so its finish
        // time is always present — unfinished requests never leak into
        // the latency accounting.
        let mut i = 0;
        while i < active.len() {
            if !matches!(active[i].state, ReqState::Done) {
                i += 1;
                continue;
            }
            let req = active.remove(i);
            let finished = req.finished_t.expect("Done requests carry a finish time");
            let out = req.a.into_logits();
            if let Some(x) = req.input {
                let want = plan.forward_reference(&x);
                mses.push(mse(&out, &want));
                if argmax(&softmax(&out)) != argmax(&softmax(&want)) {
                    mismatches += 1;
                }
            }
            ledger.complete(req.id, out, (finished - req.t_arr).max(0.0), req.reply);
        }

        // Fuse every full window into one coded job, lowest stage first
        // (deterministic flush order).
        for stage in 0..n_stages {
            while queues[stage].len() >= cfg.batch_window {
                let count = cfg.batch_window;
                flush_batch(
                    plan, cluster, &mut ctx, &mut active, &mut queues[stage], stage, count,
                    &mut fate_rng, &mut jobs, &mut batch_sizes,
                )?;
                progressed = true;
            }
        }

        // Done once the source is exhausted and every arrival resolved.
        let exhausted = match &source {
            Source::Closed | Source::Open(_) => ledger.arrivals() >= cfg.requests,
            Source::Net(_) => net_closed || ledger.arrivals() >= cfg.requests,
        };
        if exhausted && pending.is_empty() && active.is_empty() {
            break;
        }

        // Absorb every already-decodable job without blocking — this is
        // where a batch is split back into its member requests. Wall
        // clock only: on the virtual clock jobs absorb strictly FIFO
        // through the blocking path below, so the schedule (and with it
        // the shed/expire pattern) is a pure function of the seed, not
        // of thread timing.
        let mut absorbed = false;
        if matches!(clock, Clock::Wall(_)) {
            let mut j = 0;
            while j < jobs.len() {
                if cluster.job_ready(&jobs[j].handle)? {
                    let job = jobs.remove(j).expect("index in bounds");
                    absorb_job(
                        plan, cluster, &mut ctx, &mut active, &mut decodes, &mut fate_rng,
                        &mut jobs, job, &clock, &mut ledger,
                    )?;
                    absorbed = true;
                } else {
                    j += 1;
                }
            }
        }
        if progressed || absorbed {
            continue;
        }

        // Nothing runnable, nothing decodable: block on the oldest job,
        // or — with no job in flight — flush the most senior partial
        // window so the pipeline never stalls on a short queue. With
        // nothing queued either, the only thing left is a future
        // arrival: jump the virtual clock to it, or block on the
        // front-end channel.
        if let Some(job) = jobs.pop_front() {
            absorb_job(
                plan, cluster, &mut ctx, &mut active, &mut decodes, &mut fate_rng, &mut jobs,
                job, &clock, &mut ledger,
            )?;
            // One blocking absorb = one coded stage of virtual service
            // time (no-op on the wall clock).
            clock.advance_stage();
        } else if let Some(stage) = (0..n_stages)
            .filter(|&s| !queues[s].is_empty())
            .min_by_key(|&s| *queues[s].front().expect("non-empty"))
        {
            let count = queues[stage].len();
            flush_batch(
                plan, cluster, &mut ctx, &mut active, &mut queues[stage], stage, count,
                &mut fate_rng, &mut jobs, &mut batch_sizes,
            )?;
        } else {
            match &mut source {
                // Closed-loop: admission always finds work above; the
                // loop only reaches here in the degenerate zero-length
                // deadline case, where re-looping makes progress by
                // expiring fresh admissions.
                Source::Closed => {}
                Source::Open(gen) => {
                    if ledger.arrivals() < cfg.requests {
                        clock.jump_to(gen.peek());
                    }
                }
                Source::Net(rx) => match rx.recv() {
                    Ok(msg) => accept_net(cfg, &clock, &mut ledger, &mut pending, msg),
                    Err(_) => net_closed = true,
                },
            }
        }
    }
    let total = t_all.elapsed().as_secs_f64();

    let verified = mses.len();
    let coded_jobs = batch_sizes.len();
    let health = cluster.health().counters();
    let Ledger {
        outcomes,
        logits,
        latencies,
        hist,
        shed_n,
        expired_n,
        completed_n,
        peak_queue,
    } = ledger;
    Ok(ServeStats {
        latency: Stats::from_or_zero(&latencies),
        throughput_rps: completed_n as f64 / total,
        decode: Stats::from_or_zero(&decodes),
        mean_logit_mse: if mses.is_empty() {
            0.0
        } else {
            mses.iter().sum::<f64>() / verified as f64
        },
        class_mismatches: mismatches,
        requests: outcomes.len(),
        verified,
        max_in_flight: cfg.max_in_flight,
        batch_window: cfg.batch_window,
        coded_jobs,
        mean_batch: if coded_jobs == 0 {
            0.0
        } else {
            batch_sizes.iter().sum::<usize>() as f64 / coded_jobs as f64
        },
        inverse_cache: plan.inverse_cache_stats(),
        arena: plan.arena_stats(),
        pack_count: plan.filter_packs(),
        kernel: crate::linalg::kernel::active().name(),
        code: cfg.code.tag(),
        encode: plan.encode_stats(),
        failed_requests: outcomes.iter().filter(|o| o.is_none()).count(),
        retries: ctx.retries,
        degraded_requests: ctx.degraded.iter().filter(|&&d| d).count(),
        quarantine_events: health.quarantines,
        readmissions: health.readmissions,
        membership: cluster.membership_counters(),
        // Filled in by `serve_with_source` after cluster shutdown.
        arena_outstanding: 0,
        logits,
        arrivals: outcomes.len(),
        completed_requests: completed_n,
        shed_requests: shed_n,
        expired_requests: expired_n,
        queue_cap: cfg.queue_cap,
        peak_queue_depth: peak_queue,
        latency_hist: hist,
        outcomes,
    })
}

/// Fuse the first `count` requests of `queue` into one coded job at
/// `stage` and dispatch it (non-blocking) — or, when the live set cannot
/// reach the stage's δ, run the conv for each member on the master
/// (graceful degradation; the members return to `Runnable` directly).
#[allow(clippy::too_many_arguments)]
fn flush_batch(
    plan: &NetworkPlan,
    cluster: &mut Cluster,
    ctx: &mut FaultCtx<'_>,
    active: &mut [Request],
    queue: &mut VecDeque<usize>,
    stage: usize,
    count: usize,
    fate_rng: &mut Rng,
    jobs: &mut VecDeque<BatchJob>,
    batch_sizes: &mut Vec<usize>,
) -> Result<()> {
    let members: Vec<usize> = queue.drain(..count).collect();
    let mode = ctx.stage_mode(plan, cluster, stage);
    if matches!(mode, StageMode::Degraded) {
        degrade_members(plan, ctx, active, stage, &members);
        return Ok(());
    }
    let variant = match mode {
        StageMode::Variant(v) => Some(v),
        _ => None,
    };
    let handle =
        submit_members(plan, cluster, ctx.cfg, active, stage, &members, &variant, fate_rng)?;
    for req in active.iter_mut() {
        if members.contains(&req.id) {
            req.state = ReqState::InJob;
        }
    }
    batch_sizes.push(members.len());
    jobs.push_back(BatchJob {
        stage,
        members,
        handle,
        attempts: 1,
        variant,
    });
    Ok(())
}

/// Dispatch one coded job for `members` at `stage`, through the base
/// full-cluster plan or a re-planned live-subset variant.
#[allow(clippy::too_many_arguments)]
fn submit_members(
    plan: &NetworkPlan,
    cluster: &mut Cluster,
    cfg: &ServeConfig,
    active: &[Request],
    stage: usize,
    members: &[usize],
    variant: &Option<Arc<StageVariant>>,
    fate_rng: &mut Rng,
) -> Result<JobHandle> {
    let xs: Vec<&Tensor3> = members
        .iter()
        .map(|&id| {
            active
                .iter()
                .find(|r| r.id == id)
                .expect("queued member is active")
                .a
                .spatial()
        })
        .collect();
    match variant {
        None => plan.submit_batch(stage, cluster, &xs, &cfg.straggler, fate_rng),
        Some(v) => cluster.submit_batch_mapped(
            &v.plan,
            &xs,
            &v.coded_filters,
            &cfg.straggler,
            fate_rng,
            Some(&v.worker_map),
        ),
    }
}

/// Graceful degradation: run `stage`'s conv on the master for each
/// member (bitwise identical to the reference conv — the same
/// `conv2d` + bias epilogue the verification oracle uses) and un-park
/// them. Requests never fail; they just lose the distributed speedup for
/// this stage.
fn degrade_members(
    plan: &NetworkPlan,
    ctx: &mut FaultCtx<'_>,
    active: &mut [Request],
    stage: usize,
    members: &[usize],
) {
    for req in active.iter_mut() {
        if !members.contains(&req.id) {
            continue;
        }
        let y = plan.run_stage_local(stage, req.a.spatial());
        plan.absorb_conv_output(stage, y, &mut req.a, &mut req.layer_idx);
        req.state = ReqState::Runnable;
        ctx.degraded[req.id] = true;
    }
}

/// Wait for one coded job (blocking if its δ-th reply is still on the
/// wire), decode the batch with a single (cached) recovery inversion,
/// and split the per-sample outputs back into the member requests. A
/// failed job (timeout / undecodable) is **re-dispatched** to the
/// current live set while the retry budget lasts — with exponential
/// backoff, against a freshly chosen stage mode, its stale replies
/// recycled by the runtime's stale-reply filter — and past the budget
/// its members degrade to master-local execution. Members whose
/// deadline expired while the job was failing are evicted with
/// `DeadlineExceeded` before any retry is dispatched. Either way every
/// member request resolves.
#[allow(clippy::too_many_arguments)]
fn absorb_job(
    plan: &NetworkPlan,
    cluster: &mut Cluster,
    ctx: &mut FaultCtx<'_>,
    active: &mut Vec<Request>,
    decodes: &mut Vec<f64>,
    fate_rng: &mut Rng,
    jobs: &mut VecDeque<BatchJob>,
    job: BatchJob,
    clock: &Clock,
    ledger: &mut Ledger,
) -> Result<()> {
    let stage_plan = match &job.variant {
        Some(v) => &v.plan,
        None => &plan.stages()[job.stage].plan,
    };
    let outcome = cluster.try_wait_batch(stage_plan, job.handle)?;
    let (ys, report) = match outcome {
        BatchOutcome::Decoded { outputs, report } => (outputs, report),
        BatchOutcome::Failed { .. } => {
            // Deadline × fault interaction: a member whose deadline
            // passed while the job was failing must not ride the retry
            // loop — evict it now, before backoff or re-dispatch spends
            // more coded work on a request nobody is waiting for.
            let now = clock.now();
            let mut members = job.members;
            let mut expired: Vec<usize> = Vec::new();
            members.retain(|&id| {
                let dead = active
                    .iter()
                    .find(|r| r.id == id)
                    .and_then(|r| r.deadline)
                    .is_some_and(|d| now > d);
                if dead {
                    expired.push(id);
                }
                !dead
            });
            for id in expired {
                let idx = active.iter().position(|r| r.id == id).expect("member is active");
                let req = active.remove(idx);
                ledger.expire(req.id, req.reply);
            }
            if members.is_empty() {
                return Ok(());
            }
            if job.attempts <= ctx.cfg.retry_budget {
                // Exponential backoff: transient congestion gets a
                // breather; crashed workers get observed (and possibly
                // quarantined) by the failure that brought us here, so
                // the re-pick below sees the shrunken live set.
                let backoff = Duration::from_millis(2u64 << (job.attempts - 1).min(5));
                std::thread::sleep(backoff);
                let mode = ctx.stage_mode(plan, cluster, job.stage);
                if !matches!(mode, StageMode::Degraded) {
                    let variant = match mode {
                        StageMode::Variant(v) => Some(v),
                        _ => None,
                    };
                    let handle = submit_members(
                        plan, cluster, ctx.cfg, active, job.stage, &members, &variant, fate_rng,
                    )?;
                    ctx.retries += 1;
                    jobs.push_back(BatchJob {
                        stage: job.stage,
                        members,
                        handle,
                        attempts: job.attempts + 1,
                        variant,
                    });
                    return Ok(());
                }
            }
            // Budget exhausted (or the live set fell below δ): complete
            // the members on the master instead of failing them.
            degrade_members(plan, ctx, active, job.stage, &members);
            return Ok(());
        }
    };
    decodes.push(report.decode_secs);
    // Pair decoded samples with member ids and sort ascending so the
    // targets (gathered in `active` order, which is ascending by id)
    // line up sample-for-sample.
    let mut pairs: Vec<(usize, Tensor3)> = job.members.into_iter().zip(ys).collect();
    pairs.sort_by_key(|(id, _)| *id);
    let ids: Vec<usize> = pairs.iter().map(|(id, _)| *id).collect();
    let mut targets: Vec<(&mut Activation, &mut usize)> = Vec::with_capacity(ids.len());
    for req in active.iter_mut() {
        if ids.binary_search(&req.id).is_ok() {
            req.state = ReqState::Runnable;
            targets.push((&mut req.a, &mut req.layer_idx));
        }
    }
    debug_assert_eq!(targets.len(), ids.len(), "every member is active");
    let ys_sorted: Vec<Tensor3> = pairs.into_iter().map(|(_, y)| y).collect();
    plan.absorb_batch_output(job.stage, ys_sorted, &mut targets);
    Ok(())
}

fn argmax(v: &[f64]) -> usize {
    v.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::FaultKind;
    use crate::engine::Im2colEngine;

    #[test]
    fn serve_matches_single_node() {
        let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
        cfg.requests = 3;
        cfg.straggler = StragglerModel::FixedCount {
            count: 1,
            delay: Duration::from_millis(30),
        };
        let stats = serve_lenet(cfg).unwrap();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.verified, 3);
        assert_eq!(stats.class_mismatches, 0);
        // The run reports the dispatched backend it executed on (exact
        // name-for-name matching lives in tests/simd_kernels.rs, which
        // serializes its switches of the process-global kernel).
        assert!(
            ["scalar", "avx2", "neon", "fused-ma"].contains(&stats.kernel),
            "unknown kernel tag {:?}",
            stats.kernel
        );
        assert!(stats.mean_logit_mse < 1e-16, "mse={:e}", stats.mean_logit_mse);
        assert!(stats.throughput_rps > 0.0);
        assert_eq!(stats.logits.len(), 3);
        // Sequential unbatched serving: one coded job per request per conv.
        assert_eq!(stats.coded_jobs, 6);
        assert_eq!(stats.mean_batch, 1.0);
        // Clean run: the fault-tolerance path never engaged, and every
        // buffer came home.
        assert_eq!(stats.failed_requests, 0);
        assert_eq!(stats.retries, 0);
        assert_eq!(stats.degraded_requests, 0);
        assert_eq!(stats.quarantine_events, 0);
        assert_eq!(stats.arena_outstanding, 0);
        // The run reports the family it was planned with, and the
        // program-walked encoder did strictly less coefficient work than
        // a dense k_A-scan (CRME's structural zeros; the sparse family's
        // weight-w columns — both strict at the LeNet partitions).
        assert_eq!(stats.code, registry::default_family().tag());
        assert!(stats.encode.cols > 0, "encode passes must be counted");
        assert!(
            stats.encode.terms < stats.encode.dense_terms,
            "program encode must skip slots ({} vs {})",
            stats.encode.terms,
            stats.encode.dense_terms
        );
    }

    #[test]
    fn pipelined_serve_matches_single_node() {
        let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
        cfg.requests = 5;
        cfg.max_in_flight = 3;
        cfg.straggler = StragglerModel::FixedCount {
            count: 1,
            delay: Duration::from_millis(20),
        };
        let stats = serve_lenet(cfg).unwrap();
        assert_eq!(stats.requests, 5);
        assert_eq!(stats.verified, 5);
        assert_eq!(stats.class_mismatches, 0);
        assert!(stats.mean_logit_mse < 1e-16, "mse={:e}", stats.mean_logit_mse);
        assert_eq!(stats.logits.len(), 5);
        assert_eq!(stats.max_in_flight, 3);
    }

    #[test]
    fn batched_serving_amortizes_inversions() {
        let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
        cfg.requests = 16;
        cfg.max_in_flight = 8;
        cfg.batch_window = 4;
        cfg.verify_every = 1;
        cfg.straggler = StragglerModel::FixedCount {
            count: 1,
            delay: Duration::from_millis(5),
        };
        let stats = serve_lenet(cfg).unwrap();
        assert_eq!(stats.class_mismatches, 0);
        assert!(stats.mean_logit_mse < 1e-16, "mse={:e}", stats.mean_logit_mse);
        // Coalescing: strictly fewer coded jobs than request·stage pairs,
        // and batches really formed.
        assert!(stats.coded_jobs < stats.requests * 2, "jobs={}", stats.coded_jobs);
        assert!(stats.mean_batch > 1.0, "mean_batch={}", stats.mean_batch);
        // The acceptance bar: strictly fewer recovery-matrix inversions
        // than requests served, via batch amortization + the LRU cache.
        assert!(
            stats.inverse_cache.misses < stats.requests as u64,
            "{} inversions for {} requests",
            stats.inverse_cache.misses,
            stats.requests
        );
        assert_eq!(
            stats.inverse_cache.lookups(),
            stats.coded_jobs as u64,
            "one cache lookup per decode"
        );
        // The unified slab arena backs encode slabs, reply blocks, AND
        // decode staging, so lookups far exceed one-per-decode; what
        // matters is that steady state mostly reuses buffers and — with
        // prepacking on by default — workers never packed a filter.
        assert!(
            stats.arena.lookups() > stats.coded_jobs as u64,
            "slab takes should dominate decode-staging takes"
        );
        assert!(
            stats.arena.hits > stats.arena.misses,
            "steady state should reuse pooled buffers (hits {} vs misses {})",
            stats.arena.hits,
            stats.arena.misses
        );
        assert_eq!(stats.pack_count, 0, "plan-resident panels: no job-time packs");
    }

    #[test]
    fn no_prepack_config_counts_worker_side_packs() {
        let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
        cfg.requests = 2;
        cfg.prepack = false;
        let stats = serve_lenet(cfg).unwrap();
        assert_eq!(stats.class_mismatches, 0);
        assert!(stats.mean_logit_mse < 1e-16, "mse={:e}", stats.mean_logit_mse);
        assert!(
            stats.pack_count > 0,
            "per-job packing path must count its packs"
        );
    }

    #[test]
    fn window_wider_than_depth_is_rejected() {
        let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
        cfg.batch_window = 4; // depth stays 1: the window could never fill
        let err = serve_lenet(cfg).unwrap_err();
        assert!(err.to_string().contains("batch_window"), "err: {err:#}");
    }

    #[test]
    fn verification_sampling() {
        let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
        cfg.requests = 5;
        cfg.verify_every = 2; // requests 0, 2, 4
        let stats = serve_lenet(cfg).unwrap();
        assert_eq!(stats.verified, 3);
        assert_eq!(stats.class_mismatches, 0);

        let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
        cfg.requests = 2;
        cfg.verify_every = 0; // throughput mode: no reference pass
        let stats = serve_lenet(cfg).unwrap();
        assert_eq!(stats.verified, 0);
        assert_eq!(stats.mean_logit_mse, 0.0);
        assert_eq!(stats.logits.len(), 2);
    }

    #[test]
    fn error_burst_is_retried_not_failed() {
        // Worker 0 error-replies on its first two tasks: with δ=2 on 4
        // workers the first conv1 job stays decodable (3 valid replies
        // suffice), but an all-workers burst would not — pin a fault
        // plan that makes the *first job* undecodable and watch the
        // retry path complete every request regardless.
        let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
        cfg.requests = 3;
        cfg.collect_timeout = Duration::from_millis(500);
        cfg.fault_plan = (0..4).fold(FaultPlan::none(), |fp, w| {
            fp.with_fault(w, FaultKind::ErrorReply { jobs: 1 })
        });
        let stats = serve_lenet(cfg).unwrap();
        assert_eq!(stats.failed_requests, 0, "retry must absorb the burst");
        assert!(stats.retries >= 1, "the undecodable first job re-dispatched");
        assert_eq!(stats.degraded_requests, 0, "live set never fell below δ");
        assert_eq!(stats.class_mismatches, 0);
        assert!(stats.mean_logit_mse < 1e-16, "mse={:e}", stats.mean_logit_mse);
        assert_eq!(stats.arena_outstanding, 0, "no leaked buffers on retry");
    }

    #[test]
    fn single_worker_crash_never_fails_requests() {
        // Acceptance: under a single-worker crash-forever fault,
        // pipelined serving completes 100% of requests with exact
        // logits (γ ≥ 1 at both stages absorbs one silent worker
        // without even needing a retry).
        let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
        cfg.requests = 4;
        cfg.max_in_flight = 2;
        cfg.collect_timeout = Duration::from_millis(500);
        cfg.fault_plan = FaultPlan::none().with_fault(
            2,
            FaultKind::Crash {
                after: 0,
                restart_after: None,
            },
        );
        let stats = serve_lenet(cfg).unwrap();
        assert_eq!(stats.failed_requests, 0);
        assert_eq!(stats.class_mismatches, 0);
        assert!(stats.mean_logit_mse < 1e-16, "mse={:e}", stats.mean_logit_mse);
        assert_eq!(stats.arena_outstanding, 0);
    }
}
