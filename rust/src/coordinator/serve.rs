//! Distributed LeNet-5 serving: the end-to-end driver (DESIGN.md §E2E).
//! Every convolutional layer runs through the full FCDCC stack
//! (APCP/KCCP → CRME encode → coded cluster with stragglers → first-δ
//! decode); pooling, ReLU and the FC head run on the master, as in the
//! paper (CDC is applied to ConvLs only).
//!
//! Serving is a **coalescing request scheduler** over the concurrent job
//! runtime: up to [`ServeConfig::max_in_flight`] requests are in flight
//! at once, and requests that reach the same conv stage wait in that
//! stage's queue until [`ServeConfig::batch_window`] of them have
//! gathered (count-based, deterministic) — then the whole window is
//! fused into **one** coded job via `NetworkPlan::submit_batch`. The
//! coding is linear, so the per-job master costs (CRME encode setup,
//! recovery-matrix inversion, dispatch) are paid once per batch instead
//! of once per request, and after decode the batch is split back into
//! per-request activations (`NetworkPlan::absorb_batch_output`). A
//! partial window is flushed only when the pipeline would otherwise
//! stall, so no request waits forever. `batch_window = 1` degenerates to
//! pure pipelined serving, and depth 1 to the old strictly-sequential
//! loop — same code path, no overlap.
//!
//! The scheduler is **fault tolerant** (DESIGN.md §Fault tolerance): a
//! job that times out or becomes undecodable is re-dispatched to the
//! current live set with a bounded retry budget and exponential backoff;
//! when quarantine (fed by the cluster's health tracker) shrinks the
//! live set below full strength, stages are re-planned for the smaller n
//! (the paper's flexibility property — n is a code parameter, not a
//! partition parameter) and restored when workers are readmitted; and
//! when even the live set cannot reach a stage's recovery threshold δ,
//! the stage **degrades** to master-local execution — bitwise identical
//! to the reference conv — so requests complete with `degraded`
//! accounting instead of failing. Under any single-worker fault the loop
//! completes 100% of requests.

use crate::cluster::{
    BatchOutcome, Cluster, FaultPlan, HealthPolicy, JobHandle, StragglerModel, TcpConfig,
    TcpTransport,
};
use crate::coding::{registry, CodeFamily};
use crate::engine::{Im2colEngine, TaskEngine};
use crate::fcdcc::{NetworkPlan, PlanOptions, StageVariant};
use crate::metrics::{CacheStats, EncodeStats, MembershipCounters, Stats};
use crate::model::network::softmax;
use crate::model::{Activation, Network};
use crate::tensor::Tensor3;
use crate::util::{mse, rng::Rng};
use anyhow::{ensure, Result};
use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Which wire the cluster runs on.
#[derive(Clone, Debug, Default)]
pub enum TransportKind {
    /// In-process worker threads over mpsc channels — the default:
    /// deterministic, offline, what every tier-1 test runs on.
    #[default]
    InProcess,
    /// Remote worker processes over framed TCP with membership,
    /// heartbeats, and eviction (`--role coordinator --workers …`).
    /// `TcpConfig::workers` must name exactly `n_workers` addresses.
    Tcp(TcpConfig),
}

/// Serving-loop configuration.
pub struct ServeConfig {
    pub n_workers: usize,
    pub requests: usize,
    pub straggler: StragglerModel,
    pub engine: Arc<dyn TaskEngine>,
    /// (k_A, k_B) per conv layer (conv1, conv2).
    pub partitions: [(usize, usize); 2],
    pub seed: u64,
    /// Maximum requests concurrently in flight on the cluster
    /// (1 = strictly sequential serving).
    pub max_in_flight: usize,
    /// Requests coalesced per coded job: a stage queue is flushed as soon
    /// as this many requests gather (partial windows flush only when the
    /// pipeline would stall). 1 = one job per request (no coalescing).
    /// Must not exceed `max_in_flight`, or the window could never fill.
    pub batch_window: usize,
    /// Check every k-th request (0, k, 2k, …) against the single-node
    /// reference forward pass. 0 disables verification entirely, so
    /// throughput numbers aren't dominated by the uncoded reference.
    pub verify_every: usize,
    /// Pack coded filter slabs into GEMM panels once at plan build (the
    /// default). `false` (the CLI's `--no-prepack`) re-packs per job on
    /// the workers — the A/B baseline for the prepack speedup.
    pub prepack: bool,
    /// Code family every conv stage is planned with (`--code` /
    /// `FCDCC_CODE`, defaulting to the session's selected family).
    pub code: CodeFamily,
    /// Deterministic fault injection installed on the cluster
    /// (`--fault-*` / `FCDCC_CHAOS_SEED`; [`FaultPlan::none`] = clean).
    pub fault_plan: FaultPlan,
    /// Re-dispatches allowed per coded job before its members degrade to
    /// master-local execution (`--retry-budget`).
    pub retry_budget: usize,
    /// Thresholds of the worker-health state machine.
    pub health: HealthPolicy,
    /// Re-plan stages for the shrunken live set when quarantine bites
    /// (`false` keeps dispatching the full-n plan and leans on
    /// retry + degradation alone).
    pub replan: bool,
    /// Per-job collection deadline (`--collect-timeout-ms`).
    pub collect_timeout: Duration,
    /// The wire the cluster runs on ([`TransportKind::InProcess`] by
    /// default; [`TransportKind::Tcp`] drives real remote workers).
    pub transport: TransportKind,
}

impl ServeConfig {
    /// The default configuration matching the AOT artifact set:
    /// conv1 (4,2), conv2 (2,2), n = 4 workers, sequential serving with
    /// every request verified.
    pub fn default_with_engine(engine: Arc<dyn TaskEngine>) -> Self {
        Self {
            n_workers: 4,
            requests: 16,
            straggler: StragglerModel::None,
            engine,
            partitions: [(4, 2), (2, 2)],
            seed: 2024,
            max_in_flight: 1,
            batch_window: 1,
            verify_every: 1,
            prepack: true,
            code: registry::default_family(),
            fault_plan: FaultPlan::none(),
            retry_budget: 2,
            health: HealthPolicy::default(),
            replan: true,
            collect_timeout: Duration::from_secs(60),
            transport: TransportKind::InProcess,
        }
    }
}

impl Default for ServeConfig {
    /// Default serving configuration: workers run the fused im2col
    /// engine (the optimized path; `DirectEngine` stays the correctness
    /// oracle for tests).
    fn default() -> Self {
        Self::default_with_engine(Arc::new(Im2colEngine))
    }
}

/// Serving-loop results.
#[derive(Clone, Debug)]
pub struct ServeStats {
    /// Per-request latency, admission → logits (includes queueing under
    /// pipelined serving).
    pub latency: Stats,
    pub throughput_rps: f64,
    pub decode: Stats,
    /// Logit MSE vs the single-node forward pass, averaged over the
    /// verified requests (0.0 when verification is disabled).
    pub mean_logit_mse: f64,
    /// Verified requests whose argmax class differed from the reference.
    pub class_mismatches: usize,
    pub requests: usize,
    /// Requests actually checked against the reference.
    pub verified: usize,
    /// The in-flight depth the scheduler ran with.
    pub max_in_flight: usize,
    /// The coalescing window the scheduler ran with.
    pub batch_window: usize,
    /// Coded jobs dispatched (= decodes performed). With coalescing
    /// (`2 <= batch_window <= max_in_flight`) this lands strictly below
    /// `requests · conv_stages`. Retries of a failed job are counted in
    /// `retries`, not here.
    pub coded_jobs: usize,
    /// Mean samples per coded job.
    pub mean_batch: f64,
    /// Recovery-inverse cache counters: `misses` is exactly the number
    /// of recovery-matrix inversions performed across the whole run.
    pub inverse_cache: CacheStats,
    /// Slab-arena counters: `misses` is exactly the number of hot-path
    /// heap allocations (encode slabs, worker reply blocks, decode
    /// staging) across the whole run — steady-state serving should
    /// allocate only during warm-up.
    pub arena: CacheStats,
    /// Worker-side filter-slab GEMM packs across the run. With
    /// prepacking on (the default) this is **zero**: panels were packed
    /// once at plan build and stayed plan-resident.
    pub pack_count: u64,
    /// The dispatched compute-kernel backend the run executed on
    /// (`linalg::kernel::active()`): "scalar", "avx2", "neon", or the
    /// opt-in "fused-ma".
    pub kernel: &'static str,
    /// The code family every conv stage was planned with
    /// (`CodeFamily::tag()`): "crme", "conv", "sparse", ….
    pub code: &'static str,
    /// Encode-pass accounting of the program-compiled input encoder,
    /// accumulated across every stage and request: `terms` coefficient
    /// applications performed where a dense scan of all `k_A`
    /// coefficients would have visited `dense_terms` slots.
    pub encode: EncodeStats,
    /// Requests that hard-failed (no logits). Retry + degradation make
    /// this **zero by construction**: a job past its retry budget
    /// degrades its members to master-local execution instead of
    /// erroring.
    pub failed_requests: usize,
    /// Coded jobs re-dispatched after a timeout / undecodable failure.
    pub retries: usize,
    /// Requests that completed with at least one conv stage degraded to
    /// master-local execution (still bit-exact vs the reference conv).
    pub degraded_requests: usize,
    /// Worker quarantine transitions observed by the health tracker.
    pub quarantine_events: u64,
    /// Quarantined workers probed and readmitted to the dispatch set.
    pub readmissions: u64,
    /// Transport/membership counters (heartbeats, evictions, reconnect
    /// readmissions, corrupt frames, epoch). All-zero on the in-process
    /// transport, which has no membership protocol.
    pub membership: MembershipCounters,
    /// Slab-arena buffers still checked out after cluster shutdown —
    /// the buffer-hygiene invariant; **zero** on every path (decoded,
    /// retried, timed out, degraded).
    pub arena_outstanding: u64,
    /// Final logits of every request, in request order.
    pub logits: Vec<Vec<f64>>,
}

/// Where one request currently is in its lifecycle.
enum ReqState {
    /// Needs master-side layers run (or has just been un-parked).
    Runnable,
    /// Waiting in a stage's coalescing queue.
    Queued,
    /// Member of an in-flight coded job.
    InJob,
    /// Out of layers; awaiting retirement.
    Done,
}

/// One request moving through the pipeline.
struct Request {
    /// Request index; also its slot in the output logits.
    id: usize,
    a: Activation,
    layer_idx: usize,
    state: ReqState,
    /// Kept only for requests selected for reference verification.
    input: Option<Tensor3>,
    admitted_at: Instant,
    finished_at: Option<Instant>,
}

/// One in-flight coded job and the requests fused into it.
struct BatchJob {
    stage: usize,
    /// Member request ids, in batch (submission) order.
    members: Vec<usize>,
    handle: JobHandle,
    /// Dispatches so far (1 = first attempt).
    attempts: usize,
    /// The re-planned variant this attempt was dispatched with
    /// (`None` = the base full-cluster stage plan).
    variant: Option<Arc<StageVariant>>,
}

/// How the scheduler currently runs one conv stage, derived from the
/// cluster's live set before every dispatch.
enum StageMode {
    /// Full-cluster plan (the live set is complete, or re-planning is
    /// disabled).
    Full,
    /// Re-planned for the shrunken live set, dispatched via
    /// `submit_batch_mapped`.
    Variant(Arc<StageVariant>),
    /// The live set cannot reach this stage's δ: run the conv on the
    /// master (graceful degradation).
    Degraded,
}

/// Mutable fault-handling state threaded through the scheduler.
struct FaultCtx<'a> {
    cfg: &'a ServeConfig,
    /// Re-planned variants, keyed by (stage, live set) — built once per
    /// distinct shrink and reused until readmission restores the full
    /// plan.
    variants: BTreeMap<(usize, Vec<usize>), Arc<StageVariant>>,
    retries: usize,
    /// Per-request: completed with ≥1 degraded stage.
    degraded: Vec<bool>,
}

impl FaultCtx<'_> {
    /// Pick the dispatch mode for `stage` against the current live set.
    fn stage_mode(&mut self, plan: &NetworkPlan, cluster: &Cluster, stage: usize) -> StageMode {
        let live = cluster.live_workers();
        if live.len() == self.cfg.n_workers || !self.cfg.replan {
            return StageMode::Full;
        }
        let delta = plan.stages()[stage].plan.delta();
        if live.len() < delta {
            return StageMode::Degraded;
        }
        let key = (stage, live);
        if let Some(v) = self.variants.get(&key) {
            return StageMode::Variant(Arc::clone(v));
        }
        match plan.replan_stage(stage, &key.1) {
            Ok(v) => {
                let v = Arc::new(v);
                self.variants.insert(key, Arc::clone(&v));
                StageMode::Variant(v)
            }
            // The code family rejected the shrunken n: degrade rather
            // than keep dispatching to quarantined workers.
            Err(_) => StageMode::Degraded,
        }
    }
}

/// Run the distributed LeNet-5 serving loop; returns latency/throughput
/// plus fidelity vs the single-node reference.
pub fn serve_lenet(cfg: ServeConfig) -> Result<ServeStats> {
    ensure!(cfg.requests > 0, "need at least one request");
    ensure!(cfg.max_in_flight >= 1, "max_in_flight must be >= 1");
    ensure!(cfg.batch_window >= 1, "batch_window must be >= 1");
    // A window wider than the pipeline depth can never fill: every flush
    // would be a stall-path partial of at most `max_in_flight` samples,
    // silently disabling the batching the caller asked for.
    ensure!(
        cfg.batch_window <= cfg.max_in_flight,
        "batch_window ({}) cannot exceed max_in_flight ({}); raise the pipeline depth",
        cfg.batch_window,
        cfg.max_in_flight
    );
    let net = Network::lenet5_random(42);
    let opts = PlanOptions {
        prepack: cfg.prepack,
        code: cfg.code,
        ..PlanOptions::default()
    };
    let plan = NetworkPlan::with_options(net, &cfg.partitions, cfg.n_workers, opts)?;
    let mut cluster = match &cfg.transport {
        TransportKind::InProcess => Cluster::new(cfg.n_workers, Arc::clone(&cfg.engine)),
        TransportKind::Tcp(tcp) => {
            ensure!(
                tcp.workers.len() == cfg.n_workers,
                "TCP transport names {} workers but n_workers = {}",
                tcp.workers.len(),
                cfg.n_workers
            );
            // Reply blocks decode straight into the plan arena, exactly
            // like the in-process path.
            let transport = TcpTransport::connect(tcp.clone(), Arc::clone(plan.arena()))?;
            Cluster::with_transport(Box::new(transport))
        }
    };
    cluster.collect_timeout = cfg.collect_timeout;
    cluster.set_fault_plan(cfg.fault_plan.clone());
    cluster.set_health_policy(cfg.health);
    let stats = run_pipeline(&plan, &mut cluster, &cfg);
    cluster.shutdown();
    // Only after shutdown is the hygiene invariant decidable: the
    // workers have drained their queues and every reply was recycled.
    stats.map(|mut s| {
        s.arena_outstanding = plan.arena().outstanding();
        s
    })
}

fn run_pipeline(
    plan: &NetworkPlan,
    cluster: &mut Cluster,
    cfg: &ServeConfig,
) -> Result<ServeStats> {
    // Separate input / fate streams so request inputs are identical at
    // any pipeline depth or window (fate draws interleave differently
    // once jobs overlap and coalesce, inputs must not).
    let mut input_rng = Rng::new(cfg.seed);
    let mut fate_rng = Rng::new(cfg.seed ^ 0x9E37_79B9_7F4A_7C15);
    let n_stages = plan.stages().len();
    let mut next_req = 0usize;
    let mut completed = 0usize;
    // Active requests, ascending by id (admission order; retirement
    // preserves order).
    let mut active: Vec<Request> = Vec::new();
    // Per-stage coalescing queues of request ids.
    let mut queues: Vec<VecDeque<usize>> = vec![VecDeque::new(); n_stages];
    // In-flight coded jobs, submission (FIFO) order.
    let mut jobs: VecDeque<BatchJob> = VecDeque::new();
    let mut batch_sizes: Vec<usize> = Vec::new();
    let mut latencies = Vec::with_capacity(cfg.requests);
    let mut decodes = Vec::new();
    let mut logits: Vec<Vec<f64>> = vec![Vec::new(); cfg.requests];
    let mut mses = Vec::new();
    let mut mismatches = 0usize;
    let mut ctx = FaultCtx {
        cfg,
        variants: BTreeMap::new(),
        retries: 0,
        degraded: vec![false; cfg.requests],
    };
    let t_all = Instant::now();

    while completed < cfg.requests {
        // Admit new requests up to the pipeline depth.
        while active.len() < cfg.max_in_flight && next_req < cfg.requests {
            let x = Tensor3::random(1, 32, 32, &mut input_rng);
            let verify = cfg.verify_every > 0 && next_req % cfg.verify_every == 0;
            active.push(Request {
                id: next_req,
                a: Activation::new(&x),
                layer_idx: 0,
                state: ReqState::Runnable,
                input: verify.then_some(x),
                admitted_at: Instant::now(),
                finished_at: None,
            });
            next_req += 1;
        }

        // Advance every runnable request through master-side layers to
        // its next conv (→ that stage's coalescing queue) or to the end.
        // Requests at the same layer cursor advance as one group
        // (`run_local_batch`): the FC head of co-batched requests runs
        // as a single shared GEMM, bit-identical to advancing each
        // request alone. Groups are keyed by cursor (BTreeMap:
        // deterministic order) and members stay in admission order, so
        // per-queue arrival order is unchanged.
        let mut progressed = false;
        let mut groups: BTreeMap<usize, Vec<&mut Request>> = BTreeMap::new();
        for req in active.iter_mut() {
            if matches!(req.state, ReqState::Runnable) {
                groups.entry(req.layer_idx).or_default().push(req);
            }
        }
        for (cursor0, mut members) in groups {
            progressed = true;
            let mut cursor = cursor0;
            let next_stage = {
                let mut acts: Vec<&mut Activation> =
                    members.iter_mut().map(|r| &mut r.a).collect();
                plan.run_local_batch(&mut acts, &mut cursor)
            };
            for req in members.iter_mut() {
                req.layer_idx = cursor;
                match next_stage {
                    Some(stage) => {
                        queues[stage].push_back(req.id);
                        req.state = ReqState::Queued;
                    }
                    None => {
                        req.state = ReqState::Done;
                        req.finished_at = Some(Instant::now());
                    }
                }
            }
        }

        // Retire finished requests (stats are keyed by request id, so
        // out-of-order completion under coalescing is fine).
        let mut i = 0;
        while i < active.len() {
            if !matches!(active[i].state, ReqState::Done) {
                i += 1;
                continue;
            }
            let req = active.remove(i);
            let finished = req.finished_at.unwrap_or_else(Instant::now);
            latencies.push(
                finished
                    .saturating_duration_since(req.admitted_at)
                    .as_secs_f64(),
            );
            let out = req.a.into_logits();
            if let Some(x) = req.input {
                let want = plan.forward_reference(&x);
                mses.push(mse(&out, &want));
                if argmax(&softmax(&out)) != argmax(&softmax(&want)) {
                    mismatches += 1;
                }
            }
            logits[req.id] = out;
            completed += 1;
        }

        // Fuse every full window into one coded job, lowest stage first
        // (deterministic flush order).
        for stage in 0..n_stages {
            while queues[stage].len() >= cfg.batch_window {
                let count = cfg.batch_window;
                flush_batch(
                    plan, cluster, &mut ctx, &mut active, &mut queues[stage], stage, count,
                    &mut fate_rng, &mut jobs, &mut batch_sizes,
                )?;
                progressed = true;
            }
        }

        if completed >= cfg.requests {
            break;
        }

        // Absorb every already-decodable job without blocking — this is
        // where a batch is split back into its member requests.
        let mut absorbed = false;
        let mut j = 0;
        while j < jobs.len() {
            if cluster.job_ready(&jobs[j].handle)? {
                let job = jobs.remove(j).expect("index in bounds");
                absorb_job(
                    plan, cluster, &mut ctx, &mut active, &mut decodes, &mut fate_rng,
                    &mut jobs, job,
                )?;
                absorbed = true;
            } else {
                j += 1;
            }
        }
        if progressed || absorbed {
            continue;
        }

        // Nothing runnable, nothing decodable: block on the oldest job,
        // or — with no job in flight — flush the most senior partial
        // window so the pipeline never stalls on a short queue.
        if let Some(job) = jobs.pop_front() {
            absorb_job(
                plan, cluster, &mut ctx, &mut active, &mut decodes, &mut fate_rng, &mut jobs,
                job,
            )?;
        } else {
            let stage = (0..n_stages)
                .filter(|&s| !queues[s].is_empty())
                .min_by_key(|&s| *queues[s].front().expect("non-empty"))
                .expect("an active request is runnable, queued, or in a job");
            let count = queues[stage].len();
            flush_batch(
                plan, cluster, &mut ctx, &mut active, &mut queues[stage], stage, count,
                &mut fate_rng, &mut jobs, &mut batch_sizes,
            )?;
        }
    }
    let total = t_all.elapsed().as_secs_f64();

    let verified = mses.len();
    let coded_jobs = batch_sizes.len();
    let health = cluster.health().counters();
    Ok(ServeStats {
        latency: Stats::from_or_zero(&latencies),
        throughput_rps: cfg.requests as f64 / total,
        decode: Stats::from_or_zero(&decodes),
        mean_logit_mse: if mses.is_empty() {
            0.0
        } else {
            mses.iter().sum::<f64>() / verified as f64
        },
        class_mismatches: mismatches,
        requests: cfg.requests,
        verified,
        max_in_flight: cfg.max_in_flight,
        batch_window: cfg.batch_window,
        coded_jobs,
        mean_batch: if coded_jobs == 0 {
            0.0
        } else {
            batch_sizes.iter().sum::<usize>() as f64 / coded_jobs as f64
        },
        inverse_cache: plan.inverse_cache_stats(),
        arena: plan.arena_stats(),
        pack_count: plan.filter_packs(),
        kernel: crate::linalg::kernel::active().name(),
        code: cfg.code.tag(),
        encode: plan.encode_stats(),
        failed_requests: logits.iter().filter(|l| l.is_empty()).count(),
        retries: ctx.retries,
        degraded_requests: ctx.degraded.iter().filter(|&&d| d).count(),
        quarantine_events: health.quarantines,
        readmissions: health.readmissions,
        membership: cluster.membership_counters(),
        // Filled in by `serve_lenet` after cluster shutdown.
        arena_outstanding: 0,
        logits,
    })
}

/// Fuse the first `count` requests of `queue` into one coded job at
/// `stage` and dispatch it (non-blocking) — or, when the live set cannot
/// reach the stage's δ, run the conv for each member on the master
/// (graceful degradation; the members return to `Runnable` directly).
#[allow(clippy::too_many_arguments)]
fn flush_batch(
    plan: &NetworkPlan,
    cluster: &mut Cluster,
    ctx: &mut FaultCtx<'_>,
    active: &mut [Request],
    queue: &mut VecDeque<usize>,
    stage: usize,
    count: usize,
    fate_rng: &mut Rng,
    jobs: &mut VecDeque<BatchJob>,
    batch_sizes: &mut Vec<usize>,
) -> Result<()> {
    let members: Vec<usize> = queue.drain(..count).collect();
    let mode = ctx.stage_mode(plan, cluster, stage);
    if matches!(mode, StageMode::Degraded) {
        degrade_members(plan, ctx, active, stage, &members);
        return Ok(());
    }
    let variant = match mode {
        StageMode::Variant(v) => Some(v),
        _ => None,
    };
    let handle = submit_members(plan, cluster, ctx.cfg, active, stage, &members, &variant, fate_rng)?;
    for req in active.iter_mut() {
        if members.contains(&req.id) {
            req.state = ReqState::InJob;
        }
    }
    batch_sizes.push(members.len());
    jobs.push_back(BatchJob {
        stage,
        members,
        handle,
        attempts: 1,
        variant,
    });
    Ok(())
}

/// Dispatch one coded job for `members` at `stage`, through the base
/// full-cluster plan or a re-planned live-subset variant.
#[allow(clippy::too_many_arguments)]
fn submit_members(
    plan: &NetworkPlan,
    cluster: &mut Cluster,
    cfg: &ServeConfig,
    active: &[Request],
    stage: usize,
    members: &[usize],
    variant: &Option<Arc<StageVariant>>,
    fate_rng: &mut Rng,
) -> Result<JobHandle> {
    let xs: Vec<&Tensor3> = members
        .iter()
        .map(|&id| {
            active
                .iter()
                .find(|r| r.id == id)
                .expect("queued member is active")
                .a
                .spatial()
        })
        .collect();
    match variant {
        None => plan.submit_batch(stage, cluster, &xs, &cfg.straggler, fate_rng),
        Some(v) => cluster.submit_batch_mapped(
            &v.plan,
            &xs,
            &v.coded_filters,
            &cfg.straggler,
            fate_rng,
            Some(&v.worker_map),
        ),
    }
}

/// Graceful degradation: run `stage`'s conv on the master for each
/// member (bitwise identical to the reference conv — the same
/// `conv2d` + bias epilogue the verification oracle uses) and un-park
/// them. Requests never fail; they just lose the distributed speedup for
/// this stage.
fn degrade_members(
    plan: &NetworkPlan,
    ctx: &mut FaultCtx<'_>,
    active: &mut [Request],
    stage: usize,
    members: &[usize],
) {
    for req in active.iter_mut() {
        if !members.contains(&req.id) {
            continue;
        }
        let y = plan.run_stage_local(stage, req.a.spatial());
        plan.absorb_conv_output(stage, y, &mut req.a, &mut req.layer_idx);
        req.state = ReqState::Runnable;
        ctx.degraded[req.id] = true;
    }
}

/// Wait for one coded job (blocking if its δ-th reply is still on the
/// wire), decode the batch with a single (cached) recovery inversion,
/// and split the per-sample outputs back into the member requests. A
/// failed job (timeout / undecodable) is **re-dispatched** to the
/// current live set while the retry budget lasts — with exponential
/// backoff, against a freshly chosen stage mode, its stale replies
/// recycled by the runtime's stale-reply filter — and past the budget
/// its members degrade to master-local execution. Either way every
/// member request completes.
#[allow(clippy::too_many_arguments)]
fn absorb_job(
    plan: &NetworkPlan,
    cluster: &mut Cluster,
    ctx: &mut FaultCtx<'_>,
    active: &mut [Request],
    decodes: &mut Vec<f64>,
    fate_rng: &mut Rng,
    jobs: &mut VecDeque<BatchJob>,
    job: BatchJob,
) -> Result<()> {
    let stage_plan = match &job.variant {
        Some(v) => &v.plan,
        None => &plan.stages()[job.stage].plan,
    };
    let outcome = cluster.try_wait_batch(stage_plan, job.handle)?;
    let (ys, report) = match outcome {
        BatchOutcome::Decoded { outputs, report } => (outputs, report),
        BatchOutcome::Failed { .. } => {
            if job.attempts <= ctx.cfg.retry_budget {
                // Exponential backoff: transient congestion gets a
                // breather; crashed workers get observed (and possibly
                // quarantined) by the failure that brought us here, so
                // the re-pick below sees the shrunken live set.
                let backoff = Duration::from_millis(2u64 << (job.attempts - 1).min(5));
                std::thread::sleep(backoff);
                let mode = ctx.stage_mode(plan, cluster, job.stage);
                if !matches!(mode, StageMode::Degraded) {
                    let variant = match mode {
                        StageMode::Variant(v) => Some(v),
                        _ => None,
                    };
                    let handle = submit_members(
                        plan, cluster, ctx.cfg, active, job.stage, &job.members, &variant,
                        fate_rng,
                    )?;
                    ctx.retries += 1;
                    jobs.push_back(BatchJob {
                        stage: job.stage,
                        members: job.members,
                        handle,
                        attempts: job.attempts + 1,
                        variant,
                    });
                    return Ok(());
                }
            }
            // Budget exhausted (or the live set fell below δ): complete
            // the members on the master instead of failing them.
            degrade_members(plan, ctx, active, job.stage, &job.members);
            return Ok(());
        }
    };
    decodes.push(report.decode_secs);
    // Pair decoded samples with member ids and sort ascending so the
    // targets (gathered in `active` order, which is ascending by id)
    // line up sample-for-sample.
    let mut pairs: Vec<(usize, Tensor3)> = job.members.into_iter().zip(ys).collect();
    pairs.sort_by_key(|(id, _)| *id);
    let ids: Vec<usize> = pairs.iter().map(|(id, _)| *id).collect();
    let mut targets: Vec<(&mut Activation, &mut usize)> = Vec::with_capacity(ids.len());
    for req in active.iter_mut() {
        if ids.binary_search(&req.id).is_ok() {
            req.state = ReqState::Runnable;
            targets.push((&mut req.a, &mut req.layer_idx));
        }
    }
    debug_assert_eq!(targets.len(), ids.len(), "every member is active");
    let ys_sorted: Vec<Tensor3> = pairs.into_iter().map(|(_, y)| y).collect();
    plan.absorb_batch_output(job.stage, ys_sorted, &mut targets);
    Ok(())
}

fn argmax(v: &[f64]) -> usize {
    v.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::FaultKind;
    use crate::engine::Im2colEngine;

    #[test]
    fn serve_matches_single_node() {
        let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
        cfg.requests = 3;
        cfg.straggler = StragglerModel::FixedCount {
            count: 1,
            delay: Duration::from_millis(30),
        };
        let stats = serve_lenet(cfg).unwrap();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.verified, 3);
        assert_eq!(stats.class_mismatches, 0);
        // The run reports the dispatched backend it executed on (exact
        // name-for-name matching lives in tests/simd_kernels.rs, which
        // serializes its switches of the process-global kernel).
        assert!(
            ["scalar", "avx2", "neon", "fused-ma"].contains(&stats.kernel),
            "unknown kernel tag {:?}",
            stats.kernel
        );
        assert!(stats.mean_logit_mse < 1e-16, "mse={:e}", stats.mean_logit_mse);
        assert!(stats.throughput_rps > 0.0);
        assert_eq!(stats.logits.len(), 3);
        // Sequential unbatched serving: one coded job per request per conv.
        assert_eq!(stats.coded_jobs, 6);
        assert_eq!(stats.mean_batch, 1.0);
        // Clean run: the fault-tolerance path never engaged, and every
        // buffer came home.
        assert_eq!(stats.failed_requests, 0);
        assert_eq!(stats.retries, 0);
        assert_eq!(stats.degraded_requests, 0);
        assert_eq!(stats.quarantine_events, 0);
        assert_eq!(stats.arena_outstanding, 0);
        // The run reports the family it was planned with, and the
        // program-walked encoder did strictly less coefficient work than
        // a dense k_A-scan (CRME's structural zeros; the sparse family's
        // weight-w columns — both strict at the LeNet partitions).
        assert_eq!(stats.code, registry::default_family().tag());
        assert!(stats.encode.cols > 0, "encode passes must be counted");
        assert!(
            stats.encode.terms < stats.encode.dense_terms,
            "program encode must skip slots ({} vs {})",
            stats.encode.terms,
            stats.encode.dense_terms
        );
    }

    #[test]
    fn pipelined_serve_matches_single_node() {
        let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
        cfg.requests = 5;
        cfg.max_in_flight = 3;
        cfg.straggler = StragglerModel::FixedCount {
            count: 1,
            delay: Duration::from_millis(20),
        };
        let stats = serve_lenet(cfg).unwrap();
        assert_eq!(stats.requests, 5);
        assert_eq!(stats.verified, 5);
        assert_eq!(stats.class_mismatches, 0);
        assert!(stats.mean_logit_mse < 1e-16, "mse={:e}", stats.mean_logit_mse);
        assert_eq!(stats.logits.len(), 5);
        assert_eq!(stats.max_in_flight, 3);
    }

    #[test]
    fn batched_serving_amortizes_inversions() {
        let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
        cfg.requests = 16;
        cfg.max_in_flight = 8;
        cfg.batch_window = 4;
        cfg.verify_every = 1;
        cfg.straggler = StragglerModel::FixedCount {
            count: 1,
            delay: Duration::from_millis(5),
        };
        let stats = serve_lenet(cfg).unwrap();
        assert_eq!(stats.class_mismatches, 0);
        assert!(stats.mean_logit_mse < 1e-16, "mse={:e}", stats.mean_logit_mse);
        // Coalescing: strictly fewer coded jobs than request·stage pairs,
        // and batches really formed.
        assert!(stats.coded_jobs < stats.requests * 2, "jobs={}", stats.coded_jobs);
        assert!(stats.mean_batch > 1.0, "mean_batch={}", stats.mean_batch);
        // The acceptance bar: strictly fewer recovery-matrix inversions
        // than requests served, via batch amortization + the LRU cache.
        assert!(
            stats.inverse_cache.misses < stats.requests as u64,
            "{} inversions for {} requests",
            stats.inverse_cache.misses,
            stats.requests
        );
        assert_eq!(
            stats.inverse_cache.lookups(),
            stats.coded_jobs as u64,
            "one cache lookup per decode"
        );
        // The unified slab arena backs encode slabs, reply blocks, AND
        // decode staging, so lookups far exceed one-per-decode; what
        // matters is that steady state mostly reuses buffers and — with
        // prepacking on by default — workers never packed a filter.
        assert!(
            stats.arena.lookups() > stats.coded_jobs as u64,
            "slab takes should dominate decode-staging takes"
        );
        assert!(
            stats.arena.hits > stats.arena.misses,
            "steady state should reuse pooled buffers (hits {} vs misses {})",
            stats.arena.hits,
            stats.arena.misses
        );
        assert_eq!(stats.pack_count, 0, "plan-resident panels: no job-time packs");
    }

    #[test]
    fn no_prepack_config_counts_worker_side_packs() {
        let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
        cfg.requests = 2;
        cfg.prepack = false;
        let stats = serve_lenet(cfg).unwrap();
        assert_eq!(stats.class_mismatches, 0);
        assert!(stats.mean_logit_mse < 1e-16, "mse={:e}", stats.mean_logit_mse);
        assert!(
            stats.pack_count > 0,
            "per-job packing path must count its packs"
        );
    }

    #[test]
    fn window_wider_than_depth_is_rejected() {
        let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
        cfg.batch_window = 4; // depth stays 1: the window could never fill
        let err = serve_lenet(cfg).unwrap_err();
        assert!(err.to_string().contains("batch_window"), "err: {err:#}");
    }

    #[test]
    fn verification_sampling() {
        let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
        cfg.requests = 5;
        cfg.verify_every = 2; // requests 0, 2, 4
        let stats = serve_lenet(cfg).unwrap();
        assert_eq!(stats.verified, 3);
        assert_eq!(stats.class_mismatches, 0);

        let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
        cfg.requests = 2;
        cfg.verify_every = 0; // throughput mode: no reference pass
        let stats = serve_lenet(cfg).unwrap();
        assert_eq!(stats.verified, 0);
        assert_eq!(stats.mean_logit_mse, 0.0);
        assert_eq!(stats.logits.len(), 2);
    }

    #[test]
    fn error_burst_is_retried_not_failed() {
        // Worker 0 error-replies on its first two tasks: with δ=2 on 4
        // workers the first conv1 job stays decodable (3 valid replies
        // suffice), but an all-workers burst would not — pin a fault
        // plan that makes the *first job* undecodable and watch the
        // retry path complete every request regardless.
        let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
        cfg.requests = 3;
        cfg.collect_timeout = Duration::from_millis(500);
        cfg.fault_plan = (0..4).fold(FaultPlan::none(), |fp, w| {
            fp.with_fault(w, FaultKind::ErrorReply { jobs: 1 })
        });
        let stats = serve_lenet(cfg).unwrap();
        assert_eq!(stats.failed_requests, 0, "retry must absorb the burst");
        assert!(stats.retries >= 1, "the undecodable first job re-dispatched");
        assert_eq!(stats.degraded_requests, 0, "live set never fell below δ");
        assert_eq!(stats.class_mismatches, 0);
        assert!(stats.mean_logit_mse < 1e-16, "mse={:e}", stats.mean_logit_mse);
        assert_eq!(stats.arena_outstanding, 0, "no leaked buffers on retry");
    }

    #[test]
    fn single_worker_crash_never_fails_requests() {
        // Acceptance: under a single-worker crash-forever fault,
        // pipelined serving completes 100% of requests with exact
        // logits (γ ≥ 1 at both stages absorbs one silent worker
        // without even needing a retry).
        let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
        cfg.requests = 4;
        cfg.max_in_flight = 2;
        cfg.collect_timeout = Duration::from_millis(500);
        cfg.fault_plan = FaultPlan::none().with_fault(
            2,
            FaultKind::Crash {
                after: 0,
                restart_after: None,
            },
        );
        let stats = serve_lenet(cfg).unwrap();
        assert_eq!(stats.failed_requests, 0);
        assert_eq!(stats.class_mismatches, 0);
        assert!(stats.mean_logit_mse < 1e-16, "mse={:e}", stats.mean_logit_mse);
        assert_eq!(stats.arena_outstanding, 0);
    }
}
