//! Distributed LeNet-5 serving: the end-to-end driver (DESIGN.md §E2E).
//! Every convolutional layer runs through the full FCDCC stack
//! (APCP/KCCP → CRME encode → coded cluster with stragglers → first-δ
//! decode); pooling, ReLU and the FC head run on the master, as in the
//! paper (CDC is applied to ConvLs only).
//!
//! Serving is a **pipelined request scheduler** over the concurrent job
//! runtime: up to [`ServeConfig::max_in_flight`] requests are in flight
//! at once, so while request *i*'s conv2 job is collecting results,
//! request *i+1*'s conv1 is already encoded and dispatched on the same
//! worker pool. Depth 1 degenerates to the old strictly-sequential
//! serving loop — same code path, no overlap.

use crate::cluster::{Cluster, JobHandle, StragglerModel};
use crate::engine::TaskEngine;
use crate::fcdcc::NetworkPlan;
use crate::metrics::Stats;
use crate::model::network::softmax;
use crate::model::{Activation, Network};
use crate::tensor::Tensor3;
use crate::util::{mse, rng::Rng};
use anyhow::{ensure, Result};
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

/// Serving-loop configuration.
pub struct ServeConfig {
    pub n_workers: usize,
    pub requests: usize,
    pub straggler: StragglerModel,
    pub engine: Arc<dyn TaskEngine>,
    /// (k_A, k_B) per conv layer (conv1, conv2).
    pub partitions: [(usize, usize); 2],
    pub seed: u64,
    /// Maximum requests concurrently in flight on the cluster
    /// (1 = strictly sequential serving).
    pub max_in_flight: usize,
    /// Check every k-th request (0, k, 2k, …) against the single-node
    /// reference forward pass. 0 disables verification entirely, so
    /// throughput numbers aren't dominated by the uncoded reference.
    pub verify_every: usize,
}

impl ServeConfig {
    /// The default configuration matching the AOT artifact set:
    /// conv1 (4,2), conv2 (2,2), n = 4 workers, sequential serving with
    /// every request verified.
    pub fn default_with_engine(engine: Arc<dyn TaskEngine>) -> Self {
        Self {
            n_workers: 4,
            requests: 16,
            straggler: StragglerModel::None,
            engine,
            partitions: [(4, 2), (2, 2)],
            seed: 2024,
            max_in_flight: 1,
            verify_every: 1,
        }
    }
}

/// Serving-loop results.
#[derive(Clone, Debug)]
pub struct ServeStats {
    /// Per-request latency, admission → logits (includes queueing under
    /// pipelined serving).
    pub latency: Stats,
    pub throughput_rps: f64,
    pub decode: Stats,
    /// Logit MSE vs the single-node forward pass, averaged over the
    /// verified requests (0.0 when verification is disabled).
    pub mean_logit_mse: f64,
    /// Verified requests whose argmax class differed from the reference.
    pub class_mismatches: usize,
    pub requests: usize,
    /// Requests actually checked against the reference.
    pub verified: usize,
    /// The in-flight depth the scheduler ran with.
    pub max_in_flight: usize,
    /// Final logits of every request, in request order.
    pub logits: Vec<Vec<f64>>,
}

/// One request moving through the pipeline: its activation, its position
/// in the layer sequence, and (at most) one outstanding conv job.
struct InFlightRequest {
    a: Activation,
    layer_idx: usize,
    pending: Option<(usize, JobHandle)>,
    done: bool,
    /// Kept only for requests selected for reference verification.
    input: Option<Tensor3>,
    admitted_at: Instant,
    /// Set when the request runs out of layers; retirement (and the
    /// verification pass) may happen later, but latency ends here.
    finished_at: Option<Instant>,
}

/// Run the distributed LeNet-5 serving loop; returns latency/throughput
/// plus fidelity vs the single-node reference.
pub fn serve_lenet(cfg: ServeConfig) -> Result<ServeStats> {
    ensure!(cfg.requests > 0, "need at least one request");
    ensure!(cfg.max_in_flight >= 1, "max_in_flight must be >= 1");
    let net = Network::lenet5_random(42);
    let plan = NetworkPlan::new(net, &cfg.partitions, cfg.n_workers)?;
    let mut cluster = Cluster::new(cfg.n_workers, Arc::clone(&cfg.engine));
    let stats = run_pipeline(&plan, &mut cluster, &cfg);
    cluster.shutdown();
    stats
}

fn run_pipeline(
    plan: &NetworkPlan,
    cluster: &mut Cluster,
    cfg: &ServeConfig,
) -> Result<ServeStats> {
    // Separate input / fate streams so request inputs are identical at
    // any pipeline depth (fate draws interleave differently once jobs
    // overlap, inputs must not).
    let mut input_rng = Rng::new(cfg.seed);
    let mut fate_rng = Rng::new(cfg.seed ^ 0x9E37_79B9_7F4A_7C15);
    let mut next_req = 0usize;
    let mut active: VecDeque<InFlightRequest> = VecDeque::new();
    let mut latencies = Vec::with_capacity(cfg.requests);
    let mut decodes = Vec::new();
    let mut logits: Vec<Vec<f64>> = Vec::with_capacity(cfg.requests);
    let mut mses = Vec::new();
    let mut mismatches = 0usize;
    let t_all = Instant::now();

    while next_req < cfg.requests || !active.is_empty() {
        // Admit new requests up to the pipeline depth.
        while active.len() < cfg.max_in_flight && next_req < cfg.requests {
            let x = Tensor3::random(1, 32, 32, &mut input_rng);
            let verify = cfg.verify_every > 0 && next_req % cfg.verify_every == 0;
            active.push_back(InFlightRequest {
                a: Activation::new(&x),
                layer_idx: 0,
                pending: None,
                done: false,
                input: verify.then_some(x),
                admitted_at: Instant::now(),
                finished_at: None,
            });
            next_req += 1;
        }

        // Non-blocking sweep: absorb any finished conv jobs, run
        // master-side layers, dispatch next conv jobs. This is where
        // request i+1's conv1 is encoded and dispatched while request
        // i's conv2 is still in flight.
        for req in active.iter_mut() {
            advance(plan, cluster, cfg, req, &mut fate_rng, &mut decodes, false)?;
        }

        // Retire finished requests in FIFO order.
        while active.front().is_some_and(|r| r.done) {
            let req = active.pop_front().expect("front exists");
            let finished = req.finished_at.unwrap_or_else(Instant::now);
            latencies.push(
                finished
                    .saturating_duration_since(req.admitted_at)
                    .as_secs_f64(),
            );
            let out = req.a.into_logits();
            if let Some(x) = req.input {
                let want = plan.forward_reference(&x);
                mses.push(mse(&out, &want));
                if argmax(&softmax(&out)) != argmax(&softmax(&want)) {
                    mismatches += 1;
                }
            }
            logits.push(out);
        }

        // Guarantee progress: block on the oldest outstanding job.
        if let Some(req) = active.front_mut() {
            if !req.done {
                advance(plan, cluster, cfg, req, &mut fate_rng, &mut decodes, true)?;
            }
        }
    }
    let total = t_all.elapsed().as_secs_f64();

    let verified = mses.len();
    Ok(ServeStats {
        latency: Stats::from_or_zero(&latencies),
        throughput_rps: cfg.requests as f64 / total,
        decode: Stats::from_or_zero(&decodes),
        mean_logit_mse: if mses.is_empty() {
            0.0
        } else {
            mses.iter().sum::<f64>() / verified as f64
        },
        class_mismatches: mismatches,
        requests: cfg.requests,
        verified,
        max_in_flight: cfg.max_in_flight,
        logits,
    })
}

/// Advance one request as far as possible. With `block == false` this
/// never waits: a still-collecting conv job leaves the request parked.
/// With `block == true` it waits for the outstanding job once, absorbs
/// it, and then continues non-blocking (running local layers and
/// dispatching the request's next conv job).
fn advance(
    plan: &NetworkPlan,
    cluster: &mut Cluster,
    cfg: &ServeConfig,
    req: &mut InFlightRequest,
    fate_rng: &mut Rng,
    decodes: &mut Vec<f64>,
    block: bool,
) -> Result<()> {
    if req.done {
        return Ok(());
    }
    let mut may_block = block;
    loop {
        if let Some((stage, handle)) = req.pending.take() {
            if !may_block && !cluster.job_ready(&handle)? {
                req.pending = Some((stage, handle));
                return Ok(());
            }
            may_block = false; // at most one blocking wait per call
            let (y, report) = cluster.wait(&plan.stages()[stage].plan, handle)?;
            decodes.push(report.decode_secs);
            plan.absorb_conv_output(stage, y, &mut req.a, &mut req.layer_idx);
        }
        match plan.run_local(&mut req.a, &mut req.layer_idx) {
            Some(stage) => {
                let handle =
                    plan.stages()[stage].submit(cluster, &req.a, &cfg.straggler, fate_rng)?;
                req.pending = Some((stage, handle));
                if !may_block {
                    return Ok(());
                }
            }
            None => {
                req.done = true;
                req.finished_at = Some(Instant::now());
                return Ok(());
            }
        }
    }
}

fn argmax(v: &[f64]) -> usize {
    v.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Im2colEngine;
    use std::time::Duration;

    #[test]
    fn serve_matches_single_node() {
        let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
        cfg.requests = 3;
        cfg.straggler = StragglerModel::FixedCount {
            count: 1,
            delay: Duration::from_millis(30),
        };
        let stats = serve_lenet(cfg).unwrap();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.verified, 3);
        assert_eq!(stats.class_mismatches, 0);
        assert!(stats.mean_logit_mse < 1e-16, "mse={:e}", stats.mean_logit_mse);
        assert!(stats.throughput_rps > 0.0);
        assert_eq!(stats.logits.len(), 3);
    }

    #[test]
    fn pipelined_serve_matches_single_node() {
        let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
        cfg.requests = 5;
        cfg.max_in_flight = 3;
        cfg.straggler = StragglerModel::FixedCount {
            count: 1,
            delay: Duration::from_millis(20),
        };
        let stats = serve_lenet(cfg).unwrap();
        assert_eq!(stats.requests, 5);
        assert_eq!(stats.verified, 5);
        assert_eq!(stats.class_mismatches, 0);
        assert!(stats.mean_logit_mse < 1e-16, "mse={:e}", stats.mean_logit_mse);
        assert_eq!(stats.logits.len(), 5);
        assert_eq!(stats.max_in_flight, 3);
    }

    #[test]
    fn verification_sampling() {
        let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
        cfg.requests = 5;
        cfg.verify_every = 2; // requests 0, 2, 4
        let stats = serve_lenet(cfg).unwrap();
        assert_eq!(stats.verified, 3);
        assert_eq!(stats.class_mismatches, 0);

        let mut cfg = ServeConfig::default_with_engine(Arc::new(Im2colEngine));
        cfg.requests = 2;
        cfg.verify_every = 0; // throughput mode: no reference pass
        let stats = serve_lenet(cfg).unwrap();
        assert_eq!(stats.verified, 0);
        assert_eq!(stats.mean_logit_mse, 0.0);
        assert_eq!(stats.logits.len(), 2);
    }
}
