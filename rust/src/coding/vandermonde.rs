//! Classical real polynomial codes (Yu et al. [13] style, ℓ = 1) — the
//! numerically *unstable* rival of Fig. 3/4. Worker *i* evaluates the
//! partition-generating polynomials at a real point x_i:
//!
//!   X̃_i = Σ_α x_i^α X'_α,      K̃_i = Σ_β x_i^{k_A·β} K'_β,
//!
//! so a worker's coded output is the degree-(k_A·k_B−1) product polynomial
//! evaluated at x_i and the recovery matrix is the real Vandermonde matrix
//! of any δ = k_A·k_B returned points — whose condition number grows
//! exponentially in δ (Gautschi's bound [25]), the instability the paper's
//! CRME scheme eliminates.

use crate::coding::{Code, CodeSpec};
use crate::linalg::Mat;
use anyhow::{ensure, Result};

/// Evaluation-point families for polynomial codes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PointSet {
    /// Equispaced in [−1, 1] — the textbook "real polynomial" choice.
    Equispaced,
    /// Chebyshev points cos((2i+1)π/2n) — better constants, still
    /// exponential in the monomial basis.
    Chebyshev,
}

pub fn evaluation_points(n: usize, ps: PointSet) -> Vec<f64> {
    match ps {
        PointSet::Equispaced => {
            if n == 1 {
                vec![0.0]
            } else {
                (0..n)
                    .map(|i| -1.0 + 2.0 * i as f64 / (n - 1) as f64)
                    .collect()
            }
        }
        PointSet::Chebyshev => (0..n)
            .map(|i| ((2 * i + 1) as f64 * std::f64::consts::PI / (2 * n) as f64).cos())
            .collect(),
    }
}

/// Real monomial-basis polynomial code.
pub struct VandermondeCode {
    spec: CodeSpec,
    a: Mat,
    b: Mat,
    name: String,
    pub points: Vec<f64>,
}

impl VandermondeCode {
    pub fn new(k_a: usize, k_b: usize, n: usize, ps: PointSet) -> Result<Self> {
        ensure!(k_a >= 1 && k_b >= 1 && n >= 1);
        let spec = CodeSpec {
            k_a,
            k_b,
            n,
            ell_a: 1,
            ell_b: 1,
        };
        ensure!(
            spec.delta() <= n,
            "polynomial code needs k_a*k_b={} <= n={n} workers",
            k_a * k_b
        );
        let pts = evaluation_points(n, ps);
        // A(α, i) = x_i^α ; B(β, i) = x_i^{k_A·β}.
        let mut a = Mat::zeros(k_a, n);
        let mut b = Mat::zeros(k_b, n);
        for (i, &x) in pts.iter().enumerate() {
            let mut p = 1.0;
            for alpha in 0..k_a {
                a.set(alpha, i, p);
                p *= x;
            }
            let step = x.powi(k_a as i32);
            let mut pb = 1.0;
            for beta in 0..k_b {
                b.set(beta, i, pb);
                pb *= step;
            }
        }
        let tag = match ps {
            PointSet::Equispaced => "RealPoly",
            PointSet::Chebyshev => "ChebPointsPoly",
        };
        Ok(Self {
            spec,
            a,
            b,
            name: format!("{tag}(k_A={k_a},k_B={k_b},n={n})"),
            points: pts,
        })
    }
}

impl Code for VandermondeCode {
    fn name(&self) -> &str {
        &self.name
    }

    fn spec(&self) -> CodeSpec {
        self.spec
    }

    fn mat_a(&self) -> &Mat {
        &self.a
    }

    fn mat_b(&self) -> &Mat {
        &self.b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{cond_2, lu};

    #[test]
    fn joint_column_is_monomial_vandermonde() {
        let c = VandermondeCode::new(2, 3, 6, PointSet::Equispaced).unwrap();
        let e = c.recovery(&[0, 1, 2, 3, 4, 5]);
        // Column i must be (x_i^(α·k_b… )) — precisely x_i^{α + 2β} in
        // row order α·k_b + β? No: row (α·k_b + β) holds A(α,i)·B(β,i)
        // = x_i^{α}·x_i^{2β} = x_i^{α+2β}.
        for (i, &x) in c.points.iter().enumerate() {
            for alpha in 0..2 {
                for beta in 0..3 {
                    let want = x.powi((alpha + 2 * beta) as i32);
                    let got = e.get(alpha * 3 + beta, i);
                    assert!((want - got).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn invertible_at_small_scale() {
        let c = VandermondeCode::new(2, 2, 6, PointSet::Equispaced).unwrap();
        let e = c.recovery(&[0, 2, 3, 5]);
        assert!(lu::Lu::factor(&e).is_ok());
    }

    #[test]
    fn condition_explodes_with_delta() {
        // The defining pathology: equispaced real Vandermonde conditioning
        // grows exponentially with the number of points.
        let small = VandermondeCode::new(2, 2, 4, PointSet::Equispaced).unwrap();
        let cs = cond_2(&small.recovery(&[0, 1, 2, 3]));
        let big = VandermondeCode::new(4, 8, 32, PointSet::Equispaced).unwrap();
        let subset: Vec<usize> = (0..32).collect();
        let cb = cond_2(&big.recovery(&subset));
        assert!(cb > 1e10, "expected ill-conditioning, got {cb:e}");
        assert!(cb > cs * 1e6);
    }

    #[test]
    fn chebyshev_points_better_than_equispaced() {
        let subset: Vec<usize> = (0..24).collect();
        let eq = VandermondeCode::new(4, 6, 24, PointSet::Equispaced).unwrap();
        let ch = VandermondeCode::new(4, 6, 24, PointSet::Chebyshev).unwrap();
        let ce = cond_2(&eq.recovery(&subset));
        let cc = cond_2(&ch.recovery(&subset));
        assert!(cc < ce, "chebyshev {cc:e} should beat equispaced {ce:e}");
    }

    #[test]
    fn rejects_insufficient_workers() {
        assert!(VandermondeCode::new(4, 4, 15, PointSet::Equispaced).is_err());
    }
}
