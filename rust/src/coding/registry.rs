//! The shared code-family registry: one constructor path for every
//! linear code the stack can serve with, plus the process-global
//! default family selected by `--code` / `FCDCC_CODE`.
//!
//! Before this module, `coordinator::stability` owned a private
//! `build_code` and the serving path hardcoded CRME; now stability
//! sweeps, `NetworkPlan`, pooling, and the CLI all construct families
//! through [`CodeFamily::build`], and the session default follows the
//! same resolve/warn/fall-back contract as `linalg::kernel`: an
//! unknown family name warns and falls back to CRME, never fails.

use super::{Code, ConvCode, CrmeCode, FahimCadambeCode, SparseCode, VandermondeCode};
use crate::coding::vandermonde::PointSet;
use anyhow::Result;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Arc;

/// Every constructible code family, in sweep/report order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum CodeFamily {
    /// The paper's CRME scheme (rotation-embedded circulant Vandermonde).
    Crme = 0,
    /// Real polynomial code on equispaced points (the Fig. 3/4 rival).
    Vandermonde = 1,
    /// Real polynomial code on Chebyshev points.
    Chebyshev = 2,
    /// Fahim–Cadambe Chebyshev-basis code.
    FahimCadambe = 3,
    /// Banded convolutional code (sparse encode, O(band) per column).
    Conv = 4,
    /// Weight-w sparse random code (sparse encode, O(w) per column).
    Sparse = 5,
}

impl CodeFamily {
    pub const ALL: [CodeFamily; 6] = [
        CodeFamily::Crme,
        CodeFamily::Vandermonde,
        CodeFamily::Chebyshev,
        CodeFamily::FahimCadambe,
        CodeFamily::Conv,
        CodeFamily::Sparse,
    ];

    /// Short machine tag: the `--code` / `FCDCC_CODE` vocabulary, also
    /// carried in `ServeStats` and bench JSON records.
    pub fn tag(self) -> &'static str {
        match self {
            CodeFamily::Crme => "crme",
            CodeFamily::Vandermonde => "vandermonde",
            CodeFamily::Chebyshev => "chebyshev",
            CodeFamily::FahimCadambe => "fahim-cadambe",
            CodeFamily::Conv => "conv",
            CodeFamily::Sparse => "sparse",
        }
    }

    /// Human-readable scheme name used in stability tables.
    pub fn display_name(self) -> &'static str {
        match self {
            CodeFamily::Crme => "FCDCC (CRME)",
            CodeFamily::Vandermonde => "Real polynomial",
            CodeFamily::Chebyshev => "Chebyshev-pts poly",
            CodeFamily::FahimCadambe => "Fahim-Cadambe",
            CodeFamily::Conv => "Conv (banded)",
            CodeFamily::Sparse => "Sparse (weight-w)",
        }
    }

    /// Parse a `tag()` string.
    pub fn parse(name: &str) -> Option<CodeFamily> {
        CodeFamily::ALL.iter().copied().find(|f| f.tag() == name)
    }

    /// Whether the family embeds with `ℓ = 2` per coded side (CRME's
    /// geometry, which Conv/Sparse mirror) — such families need even
    /// partition counts and satisfy `k_A·k_B = 4δ`; the ℓ = 1 polynomial
    /// rivals need `k_A·k_B = δ`.
    pub fn even_partitions(self) -> bool {
        matches!(
            self,
            CodeFamily::Crme | CodeFamily::Conv | CodeFamily::Sparse
        )
    }

    /// The partition product `k_A·k_B` realizing recovery threshold
    /// `delta` under this family's embedding.
    pub fn partition_product(self, delta: usize) -> usize {
        if self.even_partitions() {
            4 * delta
        } else {
            delta
        }
    }

    /// Construct a code instance — the single shared constructor behind
    /// stability sweeps, `NetworkPlan`, pooling, and the CLI.
    pub fn build(self, k_a: usize, k_b: usize, n: usize) -> Result<Arc<dyn Code>> {
        Ok(match self {
            CodeFamily::Crme => Arc::new(CrmeCode::new(k_a, k_b, n)?),
            CodeFamily::Vandermonde => {
                Arc::new(VandermondeCode::new(k_a, k_b, n, PointSet::Equispaced)?)
            }
            CodeFamily::Chebyshev => {
                Arc::new(VandermondeCode::new(k_a, k_b, n, PointSet::Chebyshev)?)
            }
            CodeFamily::FahimCadambe => Arc::new(FahimCadambeCode::new(k_a, k_b, n)?),
            CodeFamily::Conv => Arc::new(ConvCode::new(k_a, k_b, n)?),
            CodeFamily::Sparse => Arc::new(SparseCode::new(k_a, k_b, n)?),
        })
    }

    fn from_u8(v: u8) -> Option<CodeFamily> {
        CodeFamily::ALL.iter().copied().find(|&f| f as u8 == v)
    }
}

/// Resolve a family request: `None` or `"auto"` selects CRME (the
/// paper's scheme); an unknown name warns and falls back rather than
/// failing — same contract as `linalg::kernel::resolve`.
pub fn resolve(request: Option<&str>) -> (CodeFamily, Option<String>) {
    match request {
        None | Some("auto") => (CodeFamily::Crme, None),
        Some(name) => match CodeFamily::parse(name) {
            Some(f) => (f, None),
            None => (
                CodeFamily::Crme,
                Some(format!(
                    "unknown code family {name:?} (expected \
                     auto|crme|vandermonde|chebyshev|fahim-cadambe|conv|sparse); \
                     using crme"
                )),
            ),
        },
    }
}

const FAMILY_UNSET: u8 = u8::MAX;

/// Process-global default family, initialized lazily from `FCDCC_CODE`
/// (the CLI's `--code` overrides it via [`set_default`]).
static DEFAULT: AtomicU8 = AtomicU8::new(FAMILY_UNSET);

/// The session's default code family: `--code` if installed, else
/// `FCDCC_CODE`, else CRME.
pub fn default_family() -> CodeFamily {
    match CodeFamily::from_u8(DEFAULT.load(Ordering::Relaxed)) {
        Some(f) => f,
        None => init_from_env(),
    }
}

#[cold]
fn init_from_env() -> CodeFamily {
    let req = std::env::var("FCDCC_CODE").ok();
    let (family, warning) = resolve(req.as_deref());
    if DEFAULT
        .compare_exchange(
            FAMILY_UNSET,
            family as u8,
            Ordering::Relaxed,
            Ordering::Relaxed,
        )
        .is_ok()
    {
        if let Some(w) = warning {
            eprintln!("fcdcc: {w}");
        }
        family
    } else {
        default_family()
    }
}

/// Install `family` as the process default, returning the previous
/// default (for scoped save/restore in tests).
pub fn set_default(family: CodeFamily) -> CodeFamily {
    let prev = default_family();
    DEFAULT.store(family as u8, Ordering::Relaxed);
    prev
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags_round_trip() {
        for f in CodeFamily::ALL {
            assert_eq!(CodeFamily::parse(f.tag()), Some(f), "{}", f.tag());
            assert_eq!(CodeFamily::from_u8(f as u8), Some(f));
        }
        assert_eq!(CodeFamily::parse("pallas"), None);
    }

    #[test]
    fn resolve_warns_and_falls_back() {
        assert_eq!(resolve(None), (CodeFamily::Crme, None));
        assert_eq!(resolve(Some("auto")), (CodeFamily::Crme, None));
        assert_eq!(resolve(Some("sparse")), (CodeFamily::Sparse, None));
        let (f, warn) = resolve(Some("nope"));
        assert_eq!(f, CodeFamily::Crme);
        assert!(warn.unwrap().contains("nope"));
    }

    #[test]
    fn partition_products_match_embeddings() {
        assert_eq!(CodeFamily::Crme.partition_product(8), 32);
        assert_eq!(CodeFamily::Conv.partition_product(8), 32);
        assert_eq!(CodeFamily::Sparse.partition_product(8), 32);
        assert_eq!(CodeFamily::Vandermonde.partition_product(8), 8);
        assert_eq!(CodeFamily::FahimCadambe.partition_product(8), 8);
    }

    #[test]
    fn every_family_builds_a_feasible_instance() {
        for f in CodeFamily::ALL {
            let p = f.partition_product(2);
            let (k_a, k_b) = if f.even_partitions() { (4, 2) } else { (2, 1) };
            assert_eq!(k_a * k_b, p);
            let code = f.build(k_a, k_b, 4).unwrap();
            assert_eq!(code.spec().delta(), 2, "{}", f.tag());
        }
    }

    #[test]
    fn set_default_returns_previous() {
        // Keep the observable default unchanged: other tests in this
        // binary may construct plans through it concurrently.
        let prev = set_default(default_family());
        assert_eq!(set_default(prev), prev);
    }
}
