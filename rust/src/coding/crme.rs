//! CRME: Circulant and Rotation Matrix Embedding code — the paper's
//! numerically-stable scheme (§III, eqs. (15)–(17), (29), (34)).
//!
//! Worker *j* corresponds to the evaluation angle `j·θ` with `θ = 2π/q`,
//! `q = Nextodd(n)`. All arithmetic stays in ℝ: the complex Vandermonde
//! structure (points on the unit circle — the source of the good
//! conditioning) is embedded via 2×2 rotation blocks
//! `R_θ^m = [[cos mθ, −sin mθ], [sin mθ, cos mθ]]`.
//!
//! * `A` (k_A × 2n): block (α, j) = `R_θ^{j·α}`, α ∈ Z_{k_A/2}, j ∈ Z_n.
//! * `B` (k_B × 2n): block (β, j) = `R_θ^{j·(k_A/2)·β}` — the exponent
//!   stride k_A/2 makes the joint exponents `α + (k_A/2)·β` enumerate
//!   `0..k_A·k_B/4`, the product-code requirement.
//!
//! Degenerate partition counts are permitted per the paper's feasible set
//! `S = {x ≥ 1 | x ≡ 0 (mod 2) or x = 1}`: a side with k = 1 is not
//! partitioned, its "encoding matrix" is a row of ones (every worker holds
//! that tensor uncoded, ℓ = 1 on that side), and the scheme degenerates to
//! CRME on the other side only.

use crate::coding::{Code, CodeSpec};
use crate::linalg::Mat;
use crate::util::next_odd;
use anyhow::{ensure, Result};

/// The CRME code instance (precomputed encoding matrices).
pub struct CrmeCode {
    spec: CodeSpec,
    /// Odd modulus q >= n defining the rotation angle θ = 2π/q.
    pub q: usize,
    a: Mat,
    b: Mat,
    name: String,
}

/// Is `k` in the paper's feasible partition set S (1 or even)?
pub fn feasible_k(k: usize) -> bool {
    k == 1 || (k >= 2 && k % 2 == 0)
}

/// Build the rotation-block Vandermonde matrix with `m` block rows and
/// `n` block columns; block (α, j) = R_θ^{j·stride·α}.
fn rotation_vandermonde(m: usize, n: usize, theta: f64, stride: usize) -> Mat {
    let mut out = Mat::zeros(2 * m, 2 * n);
    for alpha in 0..m {
        for j in 0..n {
            let ang = theta * (j * stride * alpha) as f64;
            let (s, c) = ang.sin_cos();
            // R = [[c, -s], [s, c]]
            out.set(2 * alpha, 2 * j, c);
            out.set(2 * alpha, 2 * j + 1, -s);
            out.set(2 * alpha + 1, 2 * j, s);
            out.set(2 * alpha + 1, 2 * j + 1, c);
        }
    }
    out
}

impl CrmeCode {
    /// Create a CRME code for `k_a` input partitions, `k_b` filter
    /// partitions and `n` workers, with `q = Nextodd(n)`.
    pub fn new(k_a: usize, k_b: usize, n: usize) -> Result<Self> {
        Self::with_q(k_a, k_b, n, next_odd(n))
    }

    /// Same, with an explicit odd modulus `q >= n` (exposed for the
    /// numerical-stability ablations).
    pub fn with_q(k_a: usize, k_b: usize, n: usize, q: usize) -> Result<Self> {
        ensure!(feasible_k(k_a), "k_a={k_a} not in S (must be 1 or even)");
        ensure!(feasible_k(k_b), "k_b={k_b} not in S (must be 1 or even)");
        ensure!(n >= 1, "need at least one worker");
        ensure!(q >= n && q % 2 == 1, "q={q} must be odd and >= n={n}");
        let ell_a = if k_a == 1 { 1 } else { 2 };
        let ell_b = if k_b == 1 { 1 } else { 2 };
        let spec = CodeSpec {
            k_a,
            k_b,
            n,
            ell_a,
            ell_b,
        };
        ensure!(
            spec.delta() <= n,
            "recovery threshold delta={} exceeds n={n} (k_a·k_b too large)",
            spec.delta()
        );
        let theta = 2.0 * std::f64::consts::PI / q as f64;
        let m_a = k_a / ell_a; // block rows of A (1 when k_a == 1)
        let m_b = k_b / ell_b;
        let a = if k_a == 1 {
            Mat::from_vec(1, n, vec![1.0; n])
        } else {
            rotation_vandermonde(m_a, n, theta, 1)
        };
        // The B-side exponent stride is m_a (= k_A/2, or 1 when k_a == 1),
        // so joint exponents α + m_a·β enumerate 0..m_a·m_b.
        let b = if k_b == 1 {
            Mat::from_vec(1, n, vec![1.0; n])
        } else {
            rotation_vandermonde(m_b, n, theta, m_a)
        };
        Ok(Self {
            spec,
            q,
            a,
            b,
            name: format!("CRME(k_A={k_a},k_B={k_b},n={n},q={q})"),
        })
    }
}

impl Code for CrmeCode {
    fn name(&self) -> &str {
        &self.name
    }

    fn spec(&self) -> CodeSpec {
        self.spec
    }

    fn mat_a(&self) -> &Mat {
        &self.a
    }

    fn mat_b(&self) -> &Mat {
        &self.b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::contiguous_subset;
    use crate::linalg::{cond_2, lu};
    use crate::util::rng::Rng;

    #[test]
    fn shapes_and_spec() {
        let c = CrmeCode::new(4, 8, 10).unwrap();
        assert_eq!(c.spec().delta(), 8);
        assert_eq!(c.mat_a().rows, 4);
        assert_eq!(c.mat_a().cols, 20);
        assert_eq!(c.mat_b().rows, 8);
        assert_eq!(c.mat_b().cols, 20);
        assert_eq!(c.q, 11);
    }

    #[test]
    fn first_block_row_is_identity_blocks() {
        // α = 0 ⇒ R^0 = I for every worker (paper eq. (17) first row).
        let c = CrmeCode::new(4, 4, 6).unwrap();
        let a = c.mat_a();
        for j in 0..6 {
            assert_eq!(a.get(0, 2 * j), 1.0);
            assert_eq!(a.get(0, 2 * j + 1), 0.0);
            assert_eq!(a.get(1, 2 * j), 0.0);
            assert_eq!(a.get(1, 2 * j + 1), 1.0);
        }
    }

    #[test]
    fn recovery_invertible_all_delta_subsets_small() {
        // k_a=2, k_b=4 ⇒ delta=2; enumerate every 2-subset of 5 workers.
        let c = CrmeCode::new(2, 4, 5).unwrap();
        for i in 0..5 {
            for j in (i + 1)..5 {
                let e = c.recovery(&[i, j]);
                assert!(e.is_square());
                assert!(
                    lu::Lu::factor(&e).is_ok(),
                    "singular recovery for subset [{i},{j}]"
                );
            }
        }
    }

    #[test]
    fn recovery_invertible_random_subsets_larger() {
        let c = CrmeCode::new(4, 8, 12).unwrap(); // delta = 8
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            let subset = rng.choose_indices(12, 8);
            let e = c.recovery(&subset);
            let k = cond_2(&e);
            assert!(k.is_finite(), "singular recovery for {subset:?}");
        }
    }

    #[test]
    fn degenerate_k_a_one() {
        // k_a = 1: input replicated; scheme reduces to CRME on B.
        let c = CrmeCode::new(1, 8, 6).unwrap(); // delta = 4
        assert_eq!(c.spec().ell_a, 1);
        assert_eq!(c.spec().delta(), 4);
        let e = c.recovery(&[0, 2, 3, 5]);
        assert_eq!(e.rows, 8);
        assert_eq!(e.cols, 8);
        assert!(lu::Lu::factor(&e).is_ok());
    }

    #[test]
    fn degenerate_both_one() {
        let c = CrmeCode::new(1, 1, 3).unwrap(); // pure replication
        assert_eq!(c.spec().delta(), 1);
        let e = c.recovery(&[2]);
        assert_eq!(e.data, vec![1.0]);
    }

    #[test]
    fn rejects_bad_parameters() {
        assert!(CrmeCode::new(3, 4, 10).is_err()); // odd k_a > 1
        assert!(CrmeCode::new(4, 4, 3).is_err()); // delta=4 > n=3
        assert!(CrmeCode::with_q(2, 2, 4, 4).is_err()); // even q
        assert!(CrmeCode::with_q(2, 2, 4, 3).is_err()); // q < n
    }

    #[test]
    fn well_conditioned_at_scale() {
        // The paper's headline: CRME stays usable beyond n >= 20 where real
        // Vandermonde explodes. Full set of workers, delta = 16, n = 20.
        let c = CrmeCode::new(8, 8, 20).unwrap();
        let subset = contiguous_subset(20, 16, 0);
        let k = cond_2(&c.recovery(&subset));
        assert!(k < 1e8, "cond={k:e} too large for CRME at n=20");
    }
}
