//! Plan-compiled CSC-style **encode programs**: the sparsity of an
//! encoding matrix, compiled out of the hot path once at plan build.
//!
//! The reference combiners ([`crate::coding::encode_inputs`] /
//! [`crate::coding::encode_filters`]) and the fused batch encoder all
//! share one numeric contract: per coded slab (one column of `A` or
//! `B`), fold the partitions in **ascending-partition order**, skipping
//! coefficients that are exactly `0.0` (`coef != 0.0`; note `-0.0 ==
//! 0.0` in IEEE comparison, so negative zeros are skipped too — an
//! `axpy` with ±0.0 cannot change any finite accumulator bit pattern
//! the references would produce, but skipping keeps both sides
//! trivially identical). An [`EncodeProgram`] is exactly that contract
//! made explicit: for each column, the ascending-ordered list of
//! `(partition_idx, coef)` nonzeros. Iterating a program therefore
//! performs the *same multiplies in the same order* as the dense scan —
//! bit-identical by construction — while touching only the nonzeros.
//!
//! CRME's rotation-embedded matrices are heavily structurally zero
//! (every `R_θ^0 = I` block contributes `sin 0 = 0` entries), so even
//! the paper's dense scheme wins from this; the banded convolutional
//! and weight-w sparse families ([`crate::coding::ConvCode`] /
//! [`crate::coding::SparseCode`]) are built to make `nnz` per column
//! O(1) instead of O(k).

use crate::linalg::Mat;
use crate::tensor::{Tensor3, Tensor4};

/// Compiled column-major sparsity of one encoding matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct EncodeProgram {
    /// Partition count (matrix rows) the program was compiled from.
    k: usize,
    /// `col_ptr[c]..col_ptr[c + 1]` indexes `terms` for column `c`.
    col_ptr: Vec<usize>,
    /// `(partition_idx, coef)` nonzeros, ascending `partition_idx`
    /// within each column — the reference fold order.
    terms: Vec<(usize, f64)>,
}

impl EncodeProgram {
    /// Compile the nonzero structure of `m` (one program column per
    /// matrix column). Row order within a column is ascending because
    /// the scan is.
    pub fn compile(m: &Mat) -> Self {
        let mut col_ptr = Vec::with_capacity(m.cols + 1);
        let mut terms = Vec::new();
        col_ptr.push(0);
        for c in 0..m.cols {
            for r in 0..m.rows {
                let coef = m.get(r, c);
                if coef != 0.0 {
                    terms.push((r, coef));
                }
            }
            col_ptr.push(terms.len());
        }
        Self {
            k: m.rows,
            col_ptr,
            terms,
        }
    }

    /// Partition count (rows of the compiled matrix).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Number of coded columns.
    pub fn cols(&self) -> usize {
        self.col_ptr.len() - 1
    }

    /// The `(partition_idx, coef)` nonzeros of column `c`, ascending.
    pub fn col(&self, c: usize) -> &[(usize, f64)] {
        &self.terms[self.col_ptr[c]..self.col_ptr[c + 1]]
    }

    /// Total nonzeros across all columns — the per-application coded
    /// `axpy` sweep count.
    pub fn nnz(&self) -> usize {
        self.terms.len()
    }

    /// Coefficient slots a dense scan would visit (`k · cols`).
    pub fn dense_terms(&self) -> usize {
        self.k * self.cols()
    }

    /// `nnz / (k · cols)` — 1.0 means the program saves nothing.
    pub fn nnz_frac(&self) -> f64 {
        if self.dense_terms() == 0 {
            return 0.0;
        }
        self.nnz() as f64 / self.dense_terms() as f64
    }

    /// Combine 3-tensor partitions into coded column `c`: the program
    /// form of the [`crate::coding::encode_inputs`] inner loop
    /// (ascending-partition zeros+axpy fold, bit-identical).
    pub fn combine3(&self, c: usize, parts: &[Tensor3]) -> Tensor3 {
        assert_eq!(parts.len(), self.k, "combine3: expected k partitions");
        let (ch, h, w) = parts[0].shape();
        let mut acc = Tensor3::zeros(ch, h, w);
        for &(alpha, coef) in self.col(c) {
            acc.axpy(coef, &parts[alpha]);
        }
        acc
    }

    /// Combine 4-tensor partitions into coded column `c`: the program
    /// form of the [`crate::coding::encode_filters`] inner loop.
    pub fn combine4(&self, c: usize, parts: &[Tensor4]) -> Tensor4 {
        assert_eq!(parts.len(), self.k, "combine4: expected k partitions");
        let (n, ch, kh, kw) = parts[0].shape();
        let mut acc = Tensor4::zeros(n, ch, kh, kw);
        for &(beta, coef) in self.col(c) {
            acc.axpy(coef, &parts[beta]);
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{self, Code, CrmeCode};
    use crate::util::rng::Rng;

    #[test]
    fn compile_drops_exact_zeros_and_keeps_order() {
        // Columns: col 0 = [1, 0, 3], col 1 = [0, -0.0, 2].
        let m = Mat::from_vec(3, 2, vec![1.0, 0.0, 0.0, -0.0, 3.0, 2.0]);
        let p = EncodeProgram::compile(&m);
        assert_eq!(p.k(), 3);
        assert_eq!(p.cols(), 2);
        assert_eq!(p.col(0), &[(0, 1.0), (2, 3.0)]);
        // -0.0 == 0.0, so the negative zero is dropped like the
        // references skip it.
        assert_eq!(p.col(1), &[(2, 2.0)]);
        assert_eq!(p.nnz(), 3);
        assert_eq!(p.dense_terms(), 6);
        assert!((p.nnz_frac() - 0.5).abs() < 1e-15);
    }

    #[test]
    fn crme_has_structural_zeros() {
        // Every CRME block row α = 0 contributes sin 0 = 0 entries, so
        // the program is strictly sparser than the dense scan.
        let c = CrmeCode::new(4, 8, 10).unwrap();
        let p = EncodeProgram::compile(c.mat_a());
        assert!(p.nnz() < p.dense_terms(), "CRME A has no structural zeros?");
        assert!(p.nnz_frac() < 1.0);
    }

    #[test]
    fn combine_matches_reference_bitwise() {
        let code = CrmeCode::new(4, 2, 5).unwrap();
        let s = code.spec();
        let mut rng = Rng::new(7);
        let parts3: Vec<Tensor3> = (0..s.k_a)
            .map(|_| Tensor3::random(2, 3, 4, &mut rng))
            .collect();
        let parts4: Vec<Tensor4> = (0..s.k_b)
            .map(|_| Tensor4::random(2, 2, 3, 3, &mut rng))
            .collect();
        let pa = EncodeProgram::compile(code.mat_a());
        let pb = EncodeProgram::compile(code.mat_b());
        let want3 = coding::encode_inputs(&code, &parts3);
        let want4 = coding::encode_filters(&code, &parts4);
        for i in 0..s.n {
            for j in 0..s.ell_a {
                let got = pa.combine3(i * s.ell_a + j, &parts3);
                assert_eq!(got.data, want3[i][j].data, "input slab ({i},{j})");
            }
            for j in 0..s.ell_b {
                let got = pb.combine4(i * s.ell_b + j, &parts4);
                assert_eq!(got.data, want4[i][j].data, "filter slab ({i},{j})");
            }
        }
    }
}
