//! Banded convolutional code (Das–Ramamoorthy–Vaswani style): each
//! coded column combines a short sliding **band** of partitions instead
//! of all `k`, so the compiled encode program performs O(band) axpy
//! sweeps per coded slab where CRME pays O(k).
//!
//! Column `c` of a side with `k ≥ 2` partitions has support
//! `{(c + t) mod k : t < band}` — consecutive coded columns slide the
//! band by one, the convolutional-code picture. Coefficients are random
//! signs times magnitudes in `[0.5, 1.5)` (bounded away from zero so a
//! nonzero never cancels structurally), drawn deterministically from
//! `util::rng` seeds mixed over `(k_A, k_B, n, attempt)`.
//!
//! A fixed band is not guaranteed to make every δ-subset recovery
//! matrix invertible, so construction **resamples**: each attempt draws
//! fresh coefficients and, every few failed attempts, widens the band
//! toward dense; every candidate is validated across all rotating
//! contiguous δ-subsets, every δ-subset when the total count is small,
//! and seeded random subsets, with a bounded conditioning proxy (see
//! `coding::validate_recovery_subsets`) — so accepted codes decode
//! exactly at δ survivors under straggler rotation, like CRME.
//!
//! The worker geometry mirrors CRME's embedding (`ℓ = 2` per side
//! unless `k = 1`, partition counts restricted to the paper's feasible
//! set `S = {1} ∪ 2ℕ`), so the family is a δ-preserving drop-in for
//! every CRME configuration.

use crate::coding::crme::feasible_k;
use crate::coding::{mix_seed, random_coef, validate_recovery_subsets, Code, CodeSpec};
use crate::linalg::Mat;
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Result};

/// Nonzeros per coded column before any widening (clamped to `k`).
pub const BASE_BAND: usize = 3;

/// Resampling budget before construction gives up.
const MAX_ATTEMPTS: usize = 64;

/// Widen the band by one every this many failed attempts.
const WIDEN_EVERY: usize = 8;

/// A banded convolutional code instance.
pub struct ConvCode {
    spec: CodeSpec,
    a: Mat,
    b: Mat,
    band_a: usize,
    band_b: usize,
    name: String,
}

fn band_for(k: usize, attempt: usize) -> usize {
    if k == 1 {
        1
    } else {
        (BASE_BAND + attempt / WIDEN_EVERY).min(k)
    }
}

/// `k × cols` banded matrix: column `c` holds random coefficients on
/// rows `{(c + t) mod k : t < band}`. A `k = 1` side is the uncoded row
/// of ones, exactly like CRME's degenerate side.
fn banded(k: usize, cols: usize, band: usize, rng: &mut Rng) -> Mat {
    if k == 1 {
        return Mat::from_vec(1, cols, vec![1.0; cols]);
    }
    let mut m = Mat::zeros(k, cols);
    for c in 0..cols {
        for t in 0..band {
            m.set((c + t) % k, c, random_coef(rng));
        }
    }
    m
}

impl ConvCode {
    /// Build a banded convolutional code for `k_a` input partitions,
    /// `k_b` filter partitions and `n` workers (default seed).
    pub fn new(k_a: usize, k_b: usize, n: usize) -> Result<Self> {
        Self::with_seed(k_a, k_b, n, 0)
    }

    /// Same, with an explicit seed folded into the deterministic
    /// coefficient draws.
    pub fn with_seed(k_a: usize, k_b: usize, n: usize, seed: u64) -> Result<Self> {
        ensure!(feasible_k(k_a), "k_a={k_a} not in S (must be 1 or even)");
        ensure!(feasible_k(k_b), "k_b={k_b} not in S (must be 1 or even)");
        ensure!(n >= 1, "need at least one worker");
        let ell_a = if k_a == 1 { 1 } else { 2 };
        let ell_b = if k_b == 1 { 1 } else { 2 };
        let spec = CodeSpec {
            k_a,
            k_b,
            n,
            ell_a,
            ell_b,
        };
        ensure!(
            spec.delta() <= n,
            "recovery threshold delta={} exceeds n={n} (k_a·k_b too large)",
            spec.delta()
        );
        for attempt in 0..MAX_ATTEMPTS {
            let band_a = band_for(k_a, attempt);
            let band_b = band_for(k_b, attempt);
            let draw = mix_seed(0xC0DE_BA2D ^ seed, &[k_a, k_b, n, attempt]);
            let mut rng = Rng::new(draw);
            let candidate = Self {
                spec,
                a: banded(k_a, ell_a * n, band_a, &mut rng),
                b: banded(k_b, ell_b * n, band_b, &mut rng),
                band_a,
                band_b,
                name: format!(
                    "ConvBand(k_A={k_a},k_B={k_b},n={n},band_A={band_a},band_B={band_b})"
                ),
            };
            if validate_recovery_subsets(&candidate, draw) {
                return Ok(candidate);
            }
        }
        bail!(
            "no well-conditioned banded code after {MAX_ATTEMPTS} attempts \
             for k_a={k_a}, k_b={k_b}, n={n}"
        )
    }

    /// Accepted band width of the input side.
    pub fn band_a(&self) -> usize {
        self.band_a
    }

    /// Accepted band width of the filter side.
    pub fn band_b(&self) -> usize {
        self.band_b
    }
}

impl Code for ConvCode {
    fn name(&self) -> &str {
        &self.name
    }

    fn spec(&self) -> CodeSpec {
        self.spec
    }

    fn mat_a(&self) -> &Mat {
        &self.a
    }

    fn mat_b(&self) -> &Mat {
        &self.b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::contiguous_subset;
    use crate::linalg::{cond_2, lu};
    use crate::util::rng::Rng;

    #[test]
    fn shapes_and_band_structure() {
        let c = ConvCode::new(8, 2, 5).unwrap(); // delta = 4
        assert_eq!(c.spec().delta(), 4);
        assert_eq!(c.mat_a().rows, 8);
        assert_eq!(c.mat_a().cols, 10);
        assert_eq!(c.mat_b().rows, 2);
        assert_eq!(c.mat_b().cols, 10);
        // Every A column carries at most band_a nonzeros on the sliding
        // support rows — the structure the encode program exploits.
        let a = c.mat_a();
        for col in 0..a.cols {
            let nnz = (0..a.rows).filter(|&r| a.get(r, col) != 0.0).count();
            assert!(nnz <= c.band_a(), "col {col}: {nnz} > band {}", c.band_a());
            for t in 0..c.band_a() {
                assert_ne!(a.get((col + t) % 8, col), 0.0, "hole in band at {col}");
            }
        }
    }

    #[test]
    fn recovery_invertible_all_delta_subsets_small() {
        let c = ConvCode::new(2, 4, 5).unwrap(); // delta = 2
        for i in 0..5 {
            for j in (i + 1)..5 {
                let e = c.recovery(&[i, j]);
                assert!(e.is_square());
                assert!(
                    lu::Lu::factor(&e).is_ok(),
                    "singular recovery for subset [{i},{j}]"
                );
            }
        }
    }

    #[test]
    fn recovery_invertible_random_subsets_larger() {
        let c = ConvCode::new(4, 8, 12).unwrap(); // delta = 8
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            let subset = rng.choose_indices(12, 8);
            let k = cond_2(&c.recovery(&subset));
            assert!(k.is_finite(), "singular recovery for {subset:?}");
        }
    }

    #[test]
    fn degenerate_k_a_one() {
        let c = ConvCode::new(1, 8, 6).unwrap(); // delta = 4
        assert_eq!(c.spec().ell_a, 1);
        assert_eq!(c.spec().delta(), 4);
        let e = c.recovery(&contiguous_subset(6, 4, 2));
        assert_eq!(e.rows, 8);
        assert!(lu::Lu::factor(&e).is_ok());
    }

    #[test]
    fn deterministic_construction() {
        let c1 = ConvCode::new(4, 2, 5).unwrap();
        let c2 = ConvCode::new(4, 2, 5).unwrap();
        assert_eq!(c1.mat_a().data, c2.mat_a().data);
        assert_eq!(c1.mat_b().data, c2.mat_b().data);
        let seeded = ConvCode::with_seed(4, 2, 5, 99).unwrap();
        assert_ne!(seeded.mat_a().data, c1.mat_a().data);
    }

    #[test]
    fn rejects_bad_parameters() {
        assert!(ConvCode::new(3, 4, 10).is_err()); // odd k_a > 1
        assert!(ConvCode::new(4, 4, 3).is_err()); // delta=4 > n=3
    }
}
