//! Coding layer: the NSCTC tensor-block-list algebra (paper §III) and the
//! family of linear codes it can be instantiated with — CRME (the paper's
//! scheme), real Vandermonde polynomial codes, and Fahim–Cadambe
//! Chebyshev-basis codes (the rivals of Fig. 3/4).
//!
//! ## The abstraction
//!
//! Every scheme is described by two encoding matrices over ℝ:
//!
//! * `A` of shape `k_a × (ell_a · n)` — column `i·ell_a + j` holds the
//!   linear-combination coefficients producing worker *i*'s *j*-th coded
//!   **input** slab from the `k_a` input partitions,
//! * `B` of shape `k_b × (ell_b · n)` — likewise for the filter partitions.
//!
//! Worker *i* convolves each of its `ell_a` coded input slabs with each of
//! its `ell_b` coded filter slabs, producing `ell_a·ell_b` coded output
//! blocks. Because convolution is bilinear, the coded output blocks are
//! the true output blocks `T_C[a·k_b + b] = X'_a * K'_b` multiplied by the
//! column-blockwise Kronecker (Khatri–Rao) matrix `G` (paper eq. (41)).
//! Any subset of `delta = k_a·k_b / (ell_a·ell_b)` workers yields a square
//! recovery matrix `E` (eq. (42)); decoding is `Y = Ỹ · E⁻¹` (eq. (45)).
//!
//! The coefficient application of every scheme (CRME, Vandermonde,
//! Fahim–Cadambe all flow through the same tensor axpy) rides the
//! runtime-dispatched SIMD backend (`linalg::kernel::axpy`), which is
//! bit-identical to the scalar loop on the default path — so these
//! reference combiners stay valid correctness oracles for the fused
//! hot paths at every dispatch level.

pub mod conv;
pub mod crme;
pub mod fahim_cadambe;
pub mod program;
pub mod registry;
pub mod sparse;
pub mod vandermonde;

use crate::linalg::{kron, lu, Mat};
use crate::tensor::{Tensor3, Tensor4};
use crate::util::rng::{Rng, SplitMix64};
use anyhow::{ensure, Context, Result};

pub use conv::ConvCode;
pub use crme::CrmeCode;
pub use fahim_cadambe::FahimCadambeCode;
pub use program::EncodeProgram;
pub use registry::CodeFamily;
pub use sparse::SparseCode;
pub use vandermonde::VandermondeCode;

/// Static description of a coded-convolution scheme instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CodeSpec {
    /// Number of input-tensor partitions (paper k_A).
    pub k_a: usize,
    /// Number of filter-tensor partitions (paper k_B).
    pub k_b: usize,
    /// Number of worker nodes (paper n).
    pub n: usize,
    /// Coded input slabs held per worker (paper ℓ for the input side).
    pub ell_a: usize,
    /// Coded filter slabs held per worker.
    pub ell_b: usize,
}

impl CodeSpec {
    /// Recovery threshold δ = k_A·k_B / (ℓ_A·ℓ_B) (paper §II-A).
    pub fn delta(&self) -> usize {
        self.k_a * self.k_b / (self.ell_a * self.ell_b)
    }

    /// Straggler resilience γ = n − δ.
    pub fn gamma(&self) -> usize {
        self.n - self.delta()
    }

    /// Coded output blocks produced per worker.
    pub fn blocks_per_worker(&self) -> usize {
        self.ell_a * self.ell_b
    }
}

/// A linear coded-computing scheme for tensor convolution.
pub trait Code: Send + Sync {
    fn name(&self) -> &str;
    fn spec(&self) -> CodeSpec;

    /// Input-side encoding matrix, `k_a × (ell_a·n)`.
    fn mat_a(&self) -> &Mat;

    /// Filter-side encoding matrix, `k_b × (ell_b·n)`.
    fn mat_b(&self) -> &Mat;

    /// The recovery matrix `E` for the given ordered worker subset
    /// (paper eq. (42)): `k_a·k_b` rows, `|workers|·ℓ_A·ℓ_B` columns.
    /// Square exactly when `|workers| == delta()`.
    fn recovery(&self, workers: &[usize]) -> Mat {
        let s = self.spec();
        let blocks: Vec<Mat> = workers
            .iter()
            .map(|&i| {
                let a_i = self.mat_a().slice_cols(i * s.ell_a, (i + 1) * s.ell_a);
                let b_i = self.mat_b().slice_cols(i * s.ell_b, (i + 1) * s.ell_b);
                kron(&a_i, &b_i)
            })
            .collect();
        Mat::hcat(&blocks.iter().collect::<Vec<_>>())
    }
}

/// Encode the input-partition list: worker `i`'s slab `j` is
/// `Σ_α A(α, i·ℓ_A + j) · X'_α` (paper eq. (2)/(32)). Returns
/// `n` vectors of `ell_a` coded slabs.
///
/// This is the **reference** combiner (one zeros+axpy sweep per coded
/// slab). The serving hot path uses the fused single-pass batch encoder
/// (`FcdccPlan::encode_input_batch`), which is bit-identical: per output
/// element both fold the partitions in ascending-α order and skip zero
/// coefficients.
pub fn encode_inputs(code: &dyn Code, parts: &[Tensor3]) -> Vec<Vec<Tensor3>> {
    let s = code.spec();
    assert_eq!(parts.len(), s.k_a, "encode_inputs: expected k_a partitions");
    let a = code.mat_a();
    let (c, h, w) = parts[0].shape();
    (0..s.n)
        .map(|i| {
            (0..s.ell_a)
                .map(|j| {
                    let col = i * s.ell_a + j;
                    let mut acc = Tensor3::zeros(c, h, w);
                    for (alpha, p) in parts.iter().enumerate() {
                        let coef = a.get(alpha, col);
                        if coef != 0.0 {
                            acc.axpy(coef, p);
                        }
                    }
                    acc
                })
                .collect()
        })
        .collect()
}

/// Encode the filter-partition list (paper eq. (3)/(37)).
pub fn encode_filters(code: &dyn Code, parts: &[Tensor4]) -> Vec<Vec<Tensor4>> {
    let s = code.spec();
    assert_eq!(parts.len(), s.k_b, "encode_filters: expected k_b partitions");
    let b = code.mat_b();
    let (n4, c, kh, kw) = parts[0].shape();
    (0..s.n)
        .map(|i| {
            (0..s.ell_b)
                .map(|j| {
                    let col = i * s.ell_b + j;
                    let mut acc = Tensor4::zeros(n4, c, kh, kw);
                    for (beta, p) in parts.iter().enumerate() {
                        let coef = b.get(beta, col);
                        if coef != 0.0 {
                            acc.axpy(coef, p);
                        }
                    }
                    acc
                })
                .collect()
        })
        .collect()
}

/// Invert the recovery matrix for an ordered δ-subset of workers
/// (paper Alg. 5 step 2). Split out of [`decode_outputs`] so the master
/// can compute the inverse **once** and reuse it across every sample of
/// a batched job (and across jobs, via `fcdcc::InverseCache`).
pub fn recovery_inverse(code: &dyn Code, workers: &[usize]) -> Result<Mat> {
    let s = code.spec();
    ensure!(
        workers.len() == s.delta(),
        "recovery_inverse: need exactly delta={} workers, got {}",
        s.delta(),
        workers.len()
    );
    let e = code.recovery(workers);
    ensure!(e.is_square(), "recovery matrix is not square");
    lu::invert(&e).context("recovery matrix inversion failed")
}

/// Decode: given the coded output blocks of exactly `delta` workers
/// (worker `workers[w]` contributed `blocks[w]`, an `ℓ_A·ℓ_B`-long list in
/// ℓ_A-major order, i.e. block `j_a·ℓ_B + j_b` is slabA `j_a` * slabB
/// `j_b`), recover the `k_a·k_b` true output blocks in `a·k_b + b` order
/// (paper Alg. 5 steps 1–5, done blockwise instead of via an explicit
/// vectorize/reshape pair — same arithmetic, fewer copies).
pub fn decode_outputs(
    code: &dyn Code,
    workers: &[usize],
    blocks: &[&[Tensor3]],
) -> Result<Vec<Tensor3>> {
    let d = recovery_inverse(code, workers)?;
    decode_outputs_with(code, &d, blocks)
}

/// Decode one sample's coded output blocks against a **precomputed**
/// recovery-matrix inverse `d` (from [`recovery_inverse`], possibly
/// cached). `d`'s column order must match the worker order the blocks
/// are given in.
///
/// This is the **reference** decoder (per-block zeros+axpy sweep). The
/// serving hot path expresses the same contraction as a packed
/// register-tiled GEMM over pooled staging buffers
/// (`FcdccPlan::decode_batch_refs` via `Mat::gemm_t_rows_into` →
/// `linalg::gemm`), with an identical per-element summation order — the
/// property suite asserts bit-identity between the two.
pub fn decode_outputs_with(
    code: &dyn Code,
    d: &Mat,
    blocks: &[&[Tensor3]],
) -> Result<Vec<Tensor3>> {
    let s = code.spec();
    ensure!(
        blocks.len() == s.delta(),
        "decode_outputs_with: need exactly delta={} block lists, got {}",
        s.delta(),
        blocks.len()
    );
    let bpw = s.blocks_per_worker();
    for (w, bs) in blocks.iter().enumerate() {
        ensure!(
            bs.len() == bpw,
            "block list {} has {} blocks, expected {}",
            w,
            bs.len(),
            bpw
        );
    }
    ensure!(
        d.rows == s.delta() * bpw && d.is_square(),
        "recovery inverse has shape {}x{}, expected {2}x{2}",
        d.rows,
        d.cols,
        s.delta() * bpw
    );
    // Flatten coded blocks into a single list matching E's column order.
    let coded: Vec<&Tensor3> = blocks.iter().flat_map(|b| b.iter()).collect();
    let (c, h, w) = coded[0].shape();
    // Y_i = Σ_j D(j, i) · Ỹ_j  (Y = Ỹ · D, done per output block).
    let kab = s.k_a * s.k_b;
    let mut out = Vec::with_capacity(kab);
    for i in 0..kab {
        let mut acc = Tensor3::zeros(c, h, w);
        for (j, cb) in coded.iter().enumerate() {
            let coef = d.get(j, i);
            if coef != 0.0 {
                acc.axpy(coef, cb);
            }
        }
        out.push(acc);
    }
    Ok(out)
}

/// Worst-case condition number search over all δ-subsets is exponential;
/// the benches use sampled subsets plus the adversarial "first δ of the
/// last workers" pattern that maximizes point spread. This helper returns
/// the recovery matrix for the contiguous subset starting at `start`.
pub fn contiguous_subset(n: usize, delta: usize, start: usize) -> Vec<usize> {
    (0..delta).map(|i| (start + i) % n).collect()
}

/// Fold integer parameters into one deterministic seed (SplitMix64
/// avalanche per component) for the resampling code constructors.
pub(crate) fn mix_seed(tag: u64, parts: &[usize]) -> u64 {
    let mut x = tag;
    for &v in parts {
        x = SplitMix64::new(x ^ v as u64).next_u64();
    }
    x
}

/// A random encoding coefficient: random sign times a magnitude in
/// `[0.5, 1.5)` — bounded away from zero so structural nonzeros stay
/// numerically nonzero.
pub(crate) fn random_coef(rng: &mut Rng) -> f64 {
    let mag = rng.uniform(0.5, 1.5);
    if rng.chance(0.5) {
        mag
    } else {
        -mag
    }
}

/// Conditioning proxy bound accepted by [`validate_recovery_subsets`]:
/// `‖E‖∞·‖E⁻¹‖∞ ≤ MAX_COND_GROWTH · dim`. Tight enough that accepted
/// codes decode LeNet-scale layers to ~1e-20 MSE, loose enough that
/// random sparse structures can pass at sweep scale.
pub(crate) const MAX_COND_GROWTH: f64 = 1e4;

/// Enumerate all `k`-subsets of `0..n` iff there are at most `cap`.
fn enumerate_subsets(n: usize, k: usize, cap: usize) -> Option<Vec<Vec<usize>>> {
    let mut count = 1usize;
    for i in 0..k {
        count = count.checked_mul(n - i)? / (i + 1);
        if count > cap * k {
            return None;
        }
    }
    if count > cap {
        return None;
    }
    let mut out = Vec::with_capacity(count);
    let mut idx: Vec<usize> = (0..k).collect();
    loop {
        out.push(idx.clone());
        // Advance to the next combination in lexicographic order.
        let mut i = k;
        loop {
            if i == 0 {
                return Some(out);
            }
            i -= 1;
            if idx[i] != i + n - k {
                break;
            }
        }
        idx[i] += 1;
        for j in i + 1..k {
            idx[j] = idx[j - 1] + 1;
        }
    }
}

/// Acceptance check for the resampling code constructors (Conv/Sparse):
/// every rotating contiguous δ-subset, every δ-subset outright when
/// there are few enough, and a handful of seeded random δ-subsets must
/// all yield an invertible recovery matrix whose conditioning proxy
/// `‖E‖∞·‖E⁻¹‖∞` stays under [`MAX_COND_GROWTH`]`· dim` — the bar that
/// makes "decodes exactly at δ survivors under straggler rotation" hold
/// for randomly structured families, not just CRME's closed form.
pub(crate) fn validate_recovery_subsets(code: &dyn Code, seed: u64) -> bool {
    let s = code.spec();
    let delta = s.delta();
    let dim = delta * s.blocks_per_worker();
    let bound = MAX_COND_GROWTH * dim as f64;
    let ok = |subset: &[usize]| -> bool {
        let e = code.recovery(subset);
        match lu::invert(&e) {
            Ok(inv) => e.norm_inf() * inv.norm_inf() <= bound,
            Err(_) => false,
        }
    };
    for start in 0..s.n {
        if !ok(&contiguous_subset(s.n, delta, start)) {
            return false;
        }
    }
    match enumerate_subsets(s.n, delta, 64) {
        Some(all) => all.iter().all(|sub| ok(sub)),
        None => {
            let mut rng = Rng::new(seed);
            (0..8).all(|_| ok(&rng.choose_indices(s.n, delta)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_derived_quantities() {
        let s = CodeSpec {
            k_a: 4,
            k_b: 8,
            n: 10,
            ell_a: 2,
            ell_b: 2,
        };
        assert_eq!(s.delta(), 8);
        assert_eq!(s.gamma(), 2);
        assert_eq!(s.blocks_per_worker(), 4);
    }

    #[test]
    fn contiguous_subset_wraps() {
        assert_eq!(contiguous_subset(5, 3, 4), vec![4, 0, 1]);
    }
}
