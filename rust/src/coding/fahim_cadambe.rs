//! Fahim–Cadambe numerically-stable polynomially coded computing [27] —
//! the strongest pre-CRME rival in Fig. 3/4.
//!
//! Faithful-to-the-numerics reconstruction (documented in DESIGN.md): the
//! input-side generator polynomial uses the **Chebyshev basis**
//! `q_A(x) = Σ_α T_α(x)·X'_α` and the filter side uses Chebyshev
//! polynomials with degree stride k_A, `q_B(x) = Σ_β T_{k_A·β}(x)·K'_β`,
//! both evaluated at Chebyshev points of the full n-grid. Products of
//! Chebyshev polynomials expand in at most two Chebyshev terms
//! (T_a·T_b = (T_{a+b} + T_{|a−b|})/2), so the recovery matrix is a
//! Chebyshev-Vandermonde system — well conditioned when the surviving
//! workers still roughly cover the Chebyshev grid (small γ), degrading as
//! the straggler count γ grows, which is exactly the behaviour the paper
//! reports (instability at (n,δ,γ) = (60,32,28)).

use crate::coding::{Code, CodeSpec};
use crate::linalg::Mat;
use anyhow::{ensure, Result};

/// Chebyshev polynomial of the first kind T_m(x), by forward recurrence.
pub fn chebyshev_t(m: usize, x: f64) -> f64 {
    match m {
        0 => 1.0,
        1 => x,
        _ => {
            let (mut a, mut b) = (1.0, x); // T0, T1
            for _ in 2..=m {
                let c = 2.0 * x * b - a;
                a = b;
                b = c;
            }
            b
        }
    }
}

/// Fahim–Cadambe-style Chebyshev-basis polynomial code (ℓ = 1).
pub struct FahimCadambeCode {
    spec: CodeSpec,
    a: Mat,
    b: Mat,
    name: String,
    pub points: Vec<f64>,
}

impl FahimCadambeCode {
    pub fn new(k_a: usize, k_b: usize, n: usize) -> Result<Self> {
        ensure!(k_a >= 1 && k_b >= 1 && n >= 1);
        let spec = CodeSpec {
            k_a,
            k_b,
            n,
            ell_a: 1,
            ell_b: 1,
        };
        ensure!(
            spec.delta() <= n,
            "Fahim-Cadambe code needs k_a*k_b={} <= n={n}",
            k_a * k_b
        );
        let pts: Vec<f64> = (0..n)
            .map(|i| ((2 * i + 1) as f64 * std::f64::consts::PI / (2 * n) as f64).cos())
            .collect();
        let mut a = Mat::zeros(k_a, n);
        let mut b = Mat::zeros(k_b, n);
        for (i, &x) in pts.iter().enumerate() {
            for alpha in 0..k_a {
                a.set(alpha, i, chebyshev_t(alpha, x));
            }
            for beta in 0..k_b {
                b.set(beta, i, chebyshev_t(k_a * beta, x));
            }
        }
        Ok(Self {
            spec,
            a,
            b,
            name: format!("FahimCadambe(k_A={k_a},k_B={k_b},n={n})"),
            points: pts,
        })
    }
}

impl Code for FahimCadambeCode {
    fn name(&self) -> &str {
        &self.name
    }

    fn spec(&self) -> CodeSpec {
        self.spec
    }

    fn mat_a(&self) -> &Mat {
        &self.a
    }

    fn mat_b(&self) -> &Mat {
        &self.b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::vandermonde::{PointSet, VandermondeCode};
    use crate::linalg::{cond_2, lu};

    #[test]
    fn chebyshev_recurrence_known_values() {
        assert_eq!(chebyshev_t(0, 0.3), 1.0);
        assert_eq!(chebyshev_t(1, 0.3), 0.3);
        // T2 = 2x^2 - 1
        assert!((chebyshev_t(2, 0.3) - (2.0 * 0.09 - 1.0)).abs() < 1e-15);
        // T_m(cos t) = cos(m t)
        let t = 0.7f64;
        for m in 0..10 {
            assert!(
                (chebyshev_t(m, t.cos()) - (m as f64 * t).cos()).abs() < 1e-12,
                "m={m}"
            );
        }
    }

    #[test]
    fn invertible_no_stragglers() {
        let c = FahimCadambeCode::new(4, 4, 16).unwrap();
        let all: Vec<usize> = (0..16).collect();
        assert!(lu::Lu::factor(&c.recovery(&all)).is_ok());
    }

    #[test]
    fn beats_monomial_vandermonde_conditioning() {
        let subset: Vec<usize> = (0..24).collect();
        let fc = FahimCadambeCode::new(4, 6, 24).unwrap();
        let vm = VandermondeCode::new(4, 6, 24, PointSet::Equispaced).unwrap();
        let cf = cond_2(&fc.recovery(&subset));
        let cv = cond_2(&vm.recovery(&subset));
        assert!(
            cf < cv / 1e3,
            "Fahim-Cadambe {cf:e} should be far better than real Vandermonde {cv:e}"
        );
    }

    #[test]
    fn degrades_with_large_gamma() {
        // Same delta, growing straggler capacity: conditioning worsens as
        // the surviving points stop covering the Chebyshev grid.
        let delta = 16usize;
        let mut prev = 0.0f64;
        for n in [16usize, 32, 60] {
            let (ka, kb) = (4, 4);
            let c = FahimCadambeCode::new(ka, kb, n).unwrap();
            // Adversarial survivors: the first delta points (one end of the grid).
            let subset: Vec<usize> = (0..delta).collect();
            let k = cond_2(&c.recovery(&subset));
            assert!(k >= prev * 0.5, "n={n} cond={k:e} prev={prev:e}");
            prev = k;
        }
        assert!(prev > 1e8, "expected instability at gamma=44, got {prev:e}");
    }
}
