//! Weight-w sparse random code (Ramamoorthy–Das–Tang style): every
//! coded column combines exactly `w` randomly chosen partitions, so
//! encode cost per coded slab is O(w) axpy sweeps independent of `k` —
//! the family that makes the compiled encode programs pay off at large
//! partition counts.
//!
//! Column `c` of a side with `k ≥ 2` partitions always contains its
//! **anchor** partition `c mod k` (guaranteeing every partition appears
//! in some column of every worker window) plus `w − 1` further distinct
//! partitions drawn uniformly; coefficients are random signs times
//! magnitudes in `[0.5, 1.5)`. All draws come from `util::rng` seeded
//! over `(k_A, k_B, n, attempt)`, so construction is deterministic.
//!
//! Random sparse supports are only invertible with high probability,
//! not surely — construction **resamples** the whole structure until
//! every rotating contiguous δ-subset (plus every δ-subset when the
//! count is small, plus seeded random subsets) yields an invertible
//! recovery matrix with a bounded conditioning proxy
//! (`coding::validate_recovery_subsets`); after repeated failures the
//! effective weight grows toward dense. Accepted codes therefore decode
//! exactly at δ survivors under straggler rotation, like CRME.
//!
//! Worker geometry mirrors CRME's embedding (`ℓ = 2` per side unless
//! `k = 1`, partition counts in the feasible set `S = {1} ∪ 2ℕ`), so
//! the family is a δ-preserving drop-in for every CRME configuration.

use crate::coding::crme::feasible_k;
use crate::coding::{mix_seed, random_coef, validate_recovery_subsets, Code, CodeSpec};
use crate::linalg::Mat;
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Result};

/// Default nonzeros per coded column (clamped to `[2, k]` per side).
pub const DEFAULT_WEIGHT: usize = 3;

/// Resampling budget before construction gives up.
const MAX_ATTEMPTS: usize = 64;

/// Grow the effective weight by one every this many failed attempts.
const GROW_EVERY: usize = 8;

/// A weight-w sparse random code instance.
pub struct SparseCode {
    spec: CodeSpec,
    a: Mat,
    b: Mat,
    weight_a: usize,
    weight_b: usize,
    name: String,
}

fn weight_for(k: usize, w: usize, attempt: usize) -> usize {
    if k == 1 {
        1
    } else {
        // A single-entry column is a scaled unit vector; two workers
        // hitting the same anchor would be trivially singular, so the
        // effective weight never drops below 2 on a coded side.
        (w + attempt / GROW_EVERY).clamp(2, k)
    }
}

/// `k × cols` weight-w matrix: column `c` holds random coefficients on
/// its anchor row `c mod k` plus `w − 1` further random distinct rows.
/// A `k = 1` side is the uncoded row of ones, like CRME's degenerate
/// side.
fn weighted(k: usize, cols: usize, w: usize, rng: &mut Rng) -> Mat {
    if k == 1 {
        return Mat::from_vec(1, cols, vec![1.0; cols]);
    }
    let mut m = Mat::zeros(k, cols);
    for c in 0..cols {
        let anchor = c % k;
        let mut rows = vec![anchor];
        // Draw w−1 distinct rows from 0..k−1, skipping the anchor.
        for idx in rng.choose_indices(k - 1, w - 1) {
            rows.push(if idx >= anchor { idx + 1 } else { idx });
        }
        rows.sort_unstable();
        for r in rows {
            m.set(r, c, random_coef(rng));
        }
    }
    m
}

impl SparseCode {
    /// Build a weight-w sparse random code with the default weight.
    pub fn new(k_a: usize, k_b: usize, n: usize) -> Result<Self> {
        Self::with_weight(k_a, k_b, n, DEFAULT_WEIGHT)
    }

    /// Build with an explicit requested per-column weight (clamped to
    /// `[2, k]` on each coded side; grows on repeated validation
    /// failures).
    pub fn with_weight(k_a: usize, k_b: usize, n: usize, w: usize) -> Result<Self> {
        ensure!(feasible_k(k_a), "k_a={k_a} not in S (must be 1 or even)");
        ensure!(feasible_k(k_b), "k_b={k_b} not in S (must be 1 or even)");
        ensure!(n >= 1, "need at least one worker");
        ensure!(w >= 1, "weight must be >= 1");
        let ell_a = if k_a == 1 { 1 } else { 2 };
        let ell_b = if k_b == 1 { 1 } else { 2 };
        let spec = CodeSpec {
            k_a,
            k_b,
            n,
            ell_a,
            ell_b,
        };
        ensure!(
            spec.delta() <= n,
            "recovery threshold delta={} exceeds n={n} (k_a·k_b too large)",
            spec.delta()
        );
        for attempt in 0..MAX_ATTEMPTS {
            let weight_a = weight_for(k_a, w, attempt);
            let weight_b = weight_for(k_b, w, attempt);
            let draw = mix_seed(0x5BA2_5E17 ^ (w as u64), &[k_a, k_b, n, attempt]);
            let mut rng = Rng::new(draw);
            let candidate = Self {
                spec,
                a: weighted(k_a, ell_a * n, weight_a, &mut rng),
                b: weighted(k_b, ell_b * n, weight_b, &mut rng),
                weight_a,
                weight_b,
                name: format!(
                    "SparseW(k_A={k_a},k_B={k_b},n={n},w_A={weight_a},w_B={weight_b})"
                ),
            };
            if validate_recovery_subsets(&candidate, draw) {
                return Ok(candidate);
            }
        }
        bail!(
            "no well-conditioned weight-{w} sparse code after {MAX_ATTEMPTS} \
             attempts for k_a={k_a}, k_b={k_b}, n={n}"
        )
    }

    /// Accepted per-column weight of the input side.
    pub fn weight_a(&self) -> usize {
        self.weight_a
    }

    /// Accepted per-column weight of the filter side.
    pub fn weight_b(&self) -> usize {
        self.weight_b
    }
}

impl Code for SparseCode {
    fn name(&self) -> &str {
        &self.name
    }

    fn spec(&self) -> CodeSpec {
        self.spec
    }

    fn mat_a(&self) -> &Mat {
        &self.a
    }

    fn mat_b(&self) -> &Mat {
        &self.b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::contiguous_subset;
    use crate::linalg::{cond_2, lu};
    use crate::util::rng::Rng;

    #[test]
    fn shapes_and_weight_structure() {
        let c = SparseCode::new(8, 2, 5).unwrap(); // delta = 4
        assert_eq!(c.spec().delta(), 4);
        assert_eq!(c.mat_a().rows, 8);
        assert_eq!(c.mat_a().cols, 10);
        let a = c.mat_a();
        for col in 0..a.cols {
            let nnz = (0..a.rows).filter(|&r| a.get(r, col) != 0.0).count();
            assert_eq!(nnz, c.weight_a(), "col {col} weight");
            assert_ne!(a.get(col % 8, col), 0.0, "anchor missing in col {col}");
        }
        // The point of the family: per-column work is w, not k.
        assert!(c.weight_a() < 8);
    }

    #[test]
    fn recovery_invertible_all_delta_subsets_small() {
        let c = SparseCode::new(2, 4, 5).unwrap(); // delta = 2
        for i in 0..5 {
            for j in (i + 1)..5 {
                let e = c.recovery(&[i, j]);
                assert!(e.is_square());
                assert!(
                    lu::Lu::factor(&e).is_ok(),
                    "singular recovery for subset [{i},{j}]"
                );
            }
        }
    }

    #[test]
    fn recovery_invertible_random_subsets_larger() {
        let c = SparseCode::new(4, 8, 12).unwrap(); // delta = 8
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            let subset = rng.choose_indices(12, 8);
            let k = cond_2(&c.recovery(&subset));
            assert!(k.is_finite(), "singular recovery for {subset:?}");
        }
    }

    #[test]
    fn degenerate_k_a_one() {
        let c = SparseCode::new(1, 8, 6).unwrap(); // delta = 4
        assert_eq!(c.spec().ell_a, 1);
        assert_eq!(c.spec().delta(), 4);
        let e = c.recovery(&contiguous_subset(6, 4, 1));
        assert_eq!(e.rows, 8);
        assert!(lu::Lu::factor(&e).is_ok());
    }

    #[test]
    fn deterministic_construction() {
        let c1 = SparseCode::new(4, 2, 5).unwrap();
        let c2 = SparseCode::new(4, 2, 5).unwrap();
        assert_eq!(c1.mat_a().data, c2.mat_a().data);
        assert_eq!(c1.mat_b().data, c2.mat_b().data);
    }

    #[test]
    fn rejects_bad_parameters() {
        assert!(SparseCode::new(3, 4, 10).is_err()); // odd k_a > 1
        assert!(SparseCode::new(4, 4, 3).is_err()); // delta=4 > n=3
        assert!(SparseCode::with_weight(4, 4, 4, 0).is_err()); // zero weight
    }
}
