//! # FCDCC — Flexible Coded Distributed Convolution Computing
//!
//! A reproduction of *"Flexible Coded Distributed Convolution Computing
//! for Enhanced Straggler Resilience and Numerical Stability in
//! Distributed CNNs"* (Tan et al., 2024) as a three-layer Rust + JAX +
//! Pallas system:
//!
//! * **L3 (this crate)** — the coordinator: APCP/KCCP coded partitioning,
//!   CRME encoding, a simulated heterogeneous worker cluster with
//!   straggler injection, first-δ decoding, the (k_A,k_B) cost optimizer,
//!   baselines and rival coding schemes.
//! * **L2/L1 (`python/compile`)** — build-time JAX worker-task graph and
//!   Pallas convolution kernel, AOT-lowered to HLO text artifacts that
//!   the `runtime` module loads and executes via PJRT (`xla` crate).
//!   The runtime is gated behind the off-by-default `pjrt` feature, since
//!   the `xla` dependency is unavailable in the offline build environment.
//!
//! See `DESIGN.md` for the full system inventory and per-experiment index.

pub mod baseline;
pub mod bench_harness;
pub mod cli;
pub mod config;
pub mod cluster;
pub mod coding;
pub mod coordinator;
pub mod engine;
pub mod fcdcc;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod partition;
pub mod prop;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod tensor;
pub mod util;

pub use tensor::{conv2d, ConvParams, Tensor3, Tensor4};
