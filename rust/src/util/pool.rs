//! Persistent shared compute pool: long-lived worker threads plus a
//! scoped, deterministically-chunked `parallel_for` — the single
//! scheduling substrate for every hot kernel (fused batch encode, GEMM
//! batch decode, worker-side im2col). Replaces the per-call
//! `std::thread::scope` spawn/join the encoder used to pay: workers are
//! spawned once per process and woken through a condvar'd queue, so
//! dispatching a parallel region costs a queue push instead of N thread
//! spawns.
//!
//! **Determinism contract** (DESIGN.md §Deterministic parallel runtime):
//! callers split their work into chunks whose boundaries are a function
//! of the *problem shape only* — one coded worker per chunk in the
//! encoder, one sample per chunk in the decoder, one input slab per
//! chunk in the im2col engine — never of the thread count. Chunks are
//! claimed dynamically (an atomic ticket), so *which thread* runs a
//! chunk is scheduling noise, but every chunk writes a disjoint output
//! region through the same serial per-element code regardless of who
//! runs it. Outputs are therefore bit-identical for any pool size,
//! including 1 (where everything runs inline on the caller).
//!
//! The calling thread always participates in its own parallel region,
//! so a region completes even when every pool worker is busy with other
//! regions, and a `parallel_for` issued from *inside* a chunk runs
//! inline — concurrent and nested regions cannot deadlock. Panics
//! inside a chunk are caught, the region still joins (the borrowed
//! state must outlive every worker touching it), and the first panic is
//! re-raised on the caller.
//!
//! The process-wide pool ([`global`]) is sized by the `FCDCC_THREADS`
//! env var (the `--threads` CLI flag sets it programmatically via
//! [`configure_global`]), defaulting to `available_parallelism`. Tests
//! build private [`ThreadPool`]s to pin exact sizes.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;
type PanicPayload = Box<dyn Any + Send + 'static>;

/// Work floor (in caller-estimated elements) below which the chunked
/// entry points run inline instead of dispatching to the pool: a
/// dispatch costs boxed helper jobs, a queue lock, and wakeups, which
/// would dominate LeNet-sized regions. One pool-owned constant replaces
/// the per-call-site thresholds the pre-pool code carried. Gating only
/// changes *where* chunks run, never their boundaries or arithmetic, so
/// results are unaffected.
pub const MIN_PARALLEL_WORK: usize = 32 * 1024;

enum Msg {
    /// A helper job, tagged with its region's state address so the
    /// region's caller can cancel still-queued (unclaimed) helpers.
    Job { tag: usize, job: Job },
    Exit,
}

struct Shared {
    queue: Mutex<VecDeque<Msg>>,
    ready: Condvar,
}

thread_local! {
    /// True while this thread is executing chunks of some region. A
    /// `parallel_for` issued from inside a chunk runs inline instead of
    /// enqueuing: a pool worker that enqueued sub-helpers and then
    /// blocked waiting for them could deadlock the pool (every worker
    /// waiting, nobody left to pop), and inline nesting is
    /// deterministic by construction.
    static IN_REGION: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// A persistent pool of worker threads executing scoped parallel loops.
pub struct ThreadPool {
    shared: Arc<Shared>,
    /// Total parallelism of a region: pool workers + the calling thread.
    threads: usize,
    handles: Vec<JoinHandle<()>>,
}

/// Per-region state shared between the caller and its helper jobs.
struct ForState<'a> {
    /// Ticket dispenser: the next unclaimed chunk index.
    next: AtomicUsize,
    chunks: usize,
    f: &'a (dyn Fn(usize) + Sync),
    /// Helper jobs not yet finished; the caller blocks until 0.
    pending: Mutex<usize>,
    done: Condvar,
    panic: Mutex<Option<PanicPayload>>,
}

/// Claim and run chunks until the dispenser runs dry. Each chunk runs
/// exactly once; a panic stops this participant but still lets the
/// region join.
fn drive(st: &ForState<'_>) {
    IN_REGION.with(|c| c.set(true));
    let result = catch_unwind(AssertUnwindSafe(|| loop {
        let i = st.next.fetch_add(1, Ordering::Relaxed);
        if i >= st.chunks {
            break;
        }
        (st.f)(i);
    }));
    IN_REGION.with(|c| c.set(false));
    if let Err(p) = result {
        let mut slot = st.panic.lock().unwrap_or_else(|e| e.into_inner());
        if slot.is_none() {
            *slot = Some(p);
        }
    }
}

impl ThreadPool {
    /// Build a pool with `threads` total parallelism (clamped to >= 1):
    /// `threads - 1` worker threads are spawned, the calling thread is
    /// the last participant of every region.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
        });
        let mut handles = Vec::with_capacity(threads - 1);
        for i in 0..threads - 1 {
            let sh = Arc::clone(&shared);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("fcdcc-pool-{i}"))
                    .spawn(move || loop {
                        let msg = {
                            let mut q = sh.queue.lock().unwrap_or_else(|e| e.into_inner());
                            loop {
                                if let Some(m) = q.pop_front() {
                                    break m;
                                }
                                q = sh.ready.wait(q).unwrap_or_else(|e| e.into_inner());
                            }
                        };
                        match msg {
                            Msg::Job { job, .. } => job(),
                            Msg::Exit => break,
                        }
                    })
                    .expect("spawn pool worker"),
            );
        }
        Self {
            shared,
            threads,
            handles,
        }
    }

    /// Total parallelism of this pool (workers + caller).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The work-floor dispatch gate shared by every chunked entry
    /// point: a region whose caller-estimated `work` sits below
    /// [`MIN_PARALLEL_WORK`] — or any region on a size-1 pool — runs
    /// inline on the caller. One pool-owned predicate instead of the
    /// same comparison duplicated at each entry point; gating only
    /// changes *where* chunks run, never their boundaries or
    /// arithmetic.
    #[inline]
    fn runs_inline(&self, work: usize) -> bool {
        work < MIN_PARALLEL_WORK || self.threads == 1
    }

    /// Run `f(0), f(1), …, f(chunks - 1)`, each exactly once, fanned out
    /// over the pool with the caller participating; returns when every
    /// chunk is done. Chunk-to-thread assignment is dynamic, so `f` must
    /// only depend on the chunk index (the deterministic-chunking rule);
    /// with `chunks <= 1` or a size-1 pool everything runs inline.
    pub fn parallel_for<F: Fn(usize) + Sync>(&self, chunks: usize, f: F) {
        if chunks == 0 {
            return;
        }
        let helpers = (self.threads - 1).min(chunks - 1);
        if helpers == 0 || IN_REGION.with(|c| c.get()) {
            // Size-1 pool, single chunk, or a nested region: inline.
            for i in 0..chunks {
                f(i);
            }
            return;
        }
        let st = ForState {
            next: AtomicUsize::new(0),
            chunks,
            f: &f,
            pending: Mutex::new(helpers),
            done: Condvar::new(),
            panic: Mutex::new(None),
        };
        // The helper jobs live on 'static worker threads but borrow the
        // stack-held region state; the pointer round-trip erases that
        // lifetime. SAFETY: every submitted helper is either executed (it
        // then decrements `pending` exactly once — drive never unwinds, it
        // catches) or cancelled while still queued (removed and dropped
        // without ever dereferencing `addr`, the caller decrementing for
        // it), and this function does not return (or unwind) before
        // `pending` reaches zero — so no helper can touch `st` (or `f`)
        // after they're gone.
        let addr = &st as *const ForState<'_> as usize;
        {
            let mut q = self.shared.queue.lock().unwrap_or_else(|e| e.into_inner());
            for _ in 0..helpers {
                q.push_back(Msg::Job {
                    tag: addr,
                    job: Box::new(move || {
                        let st = unsafe { &*(addr as *const ForState<'static>) };
                        drive(st);
                        let mut left = st.pending.lock().unwrap_or_else(|e| e.into_inner());
                        *left -= 1;
                        if *left == 0 {
                            st.done.notify_all();
                        }
                    }),
                });
            }
        }
        // One wakeup per queued helper: notify_all would stampede every
        // idle worker at the queue lock for regions that enqueued only a
        // few jobs.
        for _ in 0..helpers {
            self.shared.ready.notify_one();
        }
        drive(&st);
        // The caller is done with its chunks (on the normal path the
        // ticket dispenser is dry, so still-queued helpers would be pure
        // no-ops): cancel every helper of THIS region that no worker has
        // claimed yet, instead of sleeping until a busy worker frees up
        // just to pop them. Helpers already running still count down.
        {
            let mut q = self.shared.queue.lock().unwrap_or_else(|e| e.into_inner());
            let before = q.len();
            q.retain(|m| !matches!(m, Msg::Job { tag, .. } if *tag == addr));
            let cancelled = before - q.len();
            if cancelled > 0 {
                let mut left = st.pending.lock().unwrap_or_else(|e| e.into_inner());
                *left -= cancelled;
            }
        }
        let mut left = st.pending.lock().unwrap_or_else(|e| e.into_inner());
        while *left > 0 {
            left = st.done.wait(left).unwrap_or_else(|e| e.into_inner());
        }
        drop(left);
        if let Some(p) = st.panic.lock().unwrap_or_else(|e| e.into_inner()).take() {
            resume_unwind(p);
        }
    }

    /// Split `data` into fixed `chunk_len`-sized chunks (the last may be
    /// short) and run `f(chunk_idx, chunk)` for each in parallel. Chunk
    /// boundaries depend only on `data.len()` and `chunk_len`, never the
    /// thread count — the deterministic-chunking rule made safe: every
    /// chunk is a disjoint `&mut` slice.
    ///
    /// `work` is the caller's estimate of the region's total work (e.g.
    /// output elements): below [`MIN_PARALLEL_WORK`] the chunks run
    /// inline on the caller, so tiny (LeNet-sized) regions never pay the
    /// dispatch cost (boxed helper jobs, queue lock, wakeups). The gate
    /// is one pool-owned constant instead of per-call-site thresholds,
    /// and cannot affect results — only which thread runs a chunk.
    pub fn parallel_chunks_mut<T, F>(&self, work: usize, data: &mut [T], chunk_len: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(chunk_len > 0, "parallel_chunks_mut: chunk_len must be >= 1");
        let len = data.len();
        if len == 0 {
            return;
        }
        if self.runs_inline(work) {
            for (i, chunk) in data.chunks_mut(chunk_len).enumerate() {
                f(i, chunk);
            }
            return;
        }
        let chunks = len.div_ceil(chunk_len);
        let base = SendPtr(data.as_mut_ptr());
        self.parallel_for(chunks, move |i| {
            let start = i * chunk_len;
            let end = (start + chunk_len).min(len);
            // SAFETY: chunk i covers [start, end), disjoint across i;
            // the borrow of `data` outlives parallel_for, which joins
            // every participant before returning.
            let chunk = unsafe { std::slice::from_raw_parts_mut(base.0.add(start), end - start) };
            f(i, chunk);
        });
    }

    /// Two-slice variant of [`Self::parallel_chunks_mut`]: chunk `i` of
    /// `a` (fixed `a_chunk` elements) and chunk `i` of `b` (fixed
    /// `b_chunk` elements) are handed to the same call — e.g. one decode
    /// sample's staging region paired with its output slot. Both slices
    /// must split into the same number of chunks. `work` gates dispatch
    /// exactly as in [`Self::parallel_chunks_mut`].
    pub fn parallel_zip_chunks_mut<A, B, F>(
        &self,
        work: usize,
        a: &mut [A],
        a_chunk: usize,
        b: &mut [B],
        b_chunk: usize,
        f: F,
    ) where
        A: Send,
        B: Send,
        F: Fn(usize, &mut [A], &mut [B]) + Sync,
    {
        assert!(a_chunk > 0 && b_chunk > 0, "zip chunks must be >= 1");
        let chunks = a.len().div_ceil(a_chunk);
        assert_eq!(
            chunks,
            b.len().div_ceil(b_chunk),
            "parallel_zip_chunks_mut: slices split into different chunk counts"
        );
        if chunks == 0 {
            return;
        }
        if self.runs_inline(work) {
            for (i, (ca, cb)) in a.chunks_mut(a_chunk).zip(b.chunks_mut(b_chunk)).enumerate() {
                f(i, ca, cb);
            }
            return;
        }
        let (alen, blen) = (a.len(), b.len());
        let (pa, pb) = (SendPtr(a.as_mut_ptr()), SendPtr(b.as_mut_ptr()));
        self.parallel_for(chunks, move |i| {
            let (s1, e1) = (i * a_chunk, ((i + 1) * a_chunk).min(alen));
            let (s2, e2) = (i * b_chunk, ((i + 1) * b_chunk).min(blen));
            // SAFETY: as in parallel_chunks_mut — disjoint fixed chunks,
            // joined before the borrows of `a`/`b` end.
            let ca = unsafe { std::slice::from_raw_parts_mut(pa.0.add(s1), e1 - s1) };
            let cb = unsafe { std::slice::from_raw_parts_mut(pb.0.add(s2), e2 - s2) };
            f(i, ca, cb);
        });
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // No region can be live here (`parallel_for` borrows &self), so
        // the queue holds no jobs — just wake everyone up to exit.
        {
            let mut q = self.shared.queue.lock().unwrap_or_else(|e| e.into_inner());
            for _ in &self.handles {
                q.push_back(Msg::Exit);
            }
        }
        self.shared.ready.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// A raw pointer that crosses threads; soundness is argued at each
/// construction site (disjoint chunks + join-before-return).
#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();

/// Pool size from the environment: `FCDCC_THREADS=N` (N >= 1) pins it,
/// anything else falls back to `available_parallelism`.
fn default_threads() -> usize {
    match std::env::var("FCDCC_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
    {
        Some(n) if n >= 1 => n,
        _ => std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1),
    }
}

/// Size the process-wide pool explicitly (the `--threads` CLI flag).
/// Returns false when the pool was already built — sizing must happen
/// before first use.
pub fn configure_global(threads: usize) -> bool {
    if GLOBAL.get().is_some() {
        return false;
    }
    GLOBAL.set(ThreadPool::new(threads)).is_ok()
}

/// The process-wide compute pool, built on first use (see
/// [`default_threads`] for sizing).
pub fn global() -> &'static ThreadPool {
    GLOBAL.get_or_init(|| ThreadPool::new(default_threads()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn every_chunk_runs_exactly_once() {
        for threads in [1usize, 2, 4] {
            let pool = ThreadPool::new(threads);
            let counts: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
            pool.parallel_for(97, |i| {
                counts[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                counts.iter().all(|c| c.load(Ordering::Relaxed) == 1),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn chunked_fill_is_deterministic_across_pool_sizes() {
        let total = 1003usize;
        let chunk = 17;
        let want: Vec<f64> = (0..total).map(|i| (i as f64) * 1.5 - 7.0).collect();
        for threads in [1usize, 2, 3, 8] {
            let pool = ThreadPool::new(threads);
            let mut data = vec![0.0f64; total];
            // work = MAX forces real dispatch despite the small fixture.
            pool.parallel_chunks_mut(usize::MAX, &mut data, chunk, |ci, slice| {
                for (k, v) in slice.iter_mut().enumerate() {
                    let i = ci * chunk + k;
                    *v = (i as f64) * 1.5 - 7.0;
                }
            });
            assert_eq!(data, want, "threads={threads}");
        }
    }

    #[test]
    fn zip_chunks_pair_up() {
        let pool = ThreadPool::new(3);
        let mut sums = vec![0.0f64; 5];
        let mut data: Vec<f64> = (0..20).map(|i| i as f64).collect();
        pool.parallel_zip_chunks_mut(usize::MAX, &mut data, 4, &mut sums, 1, |_, chunk, out| {
            out[0] = chunk.iter().sum();
        });
        assert_eq!(sums, vec![6.0, 22.0, 38.0, 54.0, 70.0]);
    }

    #[test]
    fn panics_propagate_and_pool_survives() {
        let pool = ThreadPool::new(4);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.parallel_for(64, |i| {
                if i == 33 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "panic must reach the caller");
        let n = AtomicUsize::new(0);
        pool.parallel_for(8, |_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 8, "pool unusable after panic");
    }

    #[test]
    fn nested_regions_run_inline_without_deadlock() {
        let pool = ThreadPool::new(2);
        let total = AtomicUsize::new(0);
        pool.parallel_for(4, |_| {
            pool.parallel_for(3, |_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 12);
    }

    #[test]
    fn caller_participates_even_with_busy_workers() {
        // A size-1 pool has no workers at all: everything inline.
        let pool = ThreadPool::new(1);
        let n = AtomicUsize::new(0);
        pool.parallel_for(16, |_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 16);
    }
}
