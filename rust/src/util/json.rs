//! Minimal JSON parser (no serde in the offline environment) — enough for
//! the artifact manifest and config files: objects, arrays, strings,
//! numbers, booleans, null; UTF-8 input; `\uXXXX` escapes supported for
//! the BMP. Plus [`JsonObj`], a tiny single-object writer the benches
//! use to emit machine-readable result lines (`FCDCC_BENCH_OUT`)
//! without hand-formatting (and hand-escaping) format strings.

use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Incremental writer for one flat JSON object: fields appear in
/// insertion order, strings are escaped, numbers render with Rust's
/// default `Display` (round-trippable for the counters and rates the
/// benches emit). Output of [`JsonObj::finish`] parses back with
/// [`Json::parse`].
#[derive(Clone, Debug)]
pub struct JsonObj {
    buf: String,
    first: bool,
}

impl JsonObj {
    pub fn new() -> Self {
        Self {
            buf: String::from("{"),
            first: true,
        }
    }

    fn key(&mut self, name: &str) {
        if !self.first {
            self.buf.push(',');
        }
        self.first = false;
        self.buf.push('"');
        escape_into(&mut self.buf, name);
        self.buf.push_str("\":");
    }

    pub fn field_str(mut self, name: &str, value: &str) -> Self {
        self.key(name);
        self.buf.push('"');
        escape_into(&mut self.buf, value);
        self.buf.push('"');
        self
    }

    pub fn field_u64(mut self, name: &str, value: u64) -> Self {
        self.key(name);
        let _ = write!(self.buf, "{value}");
        self
    }

    pub fn field_f64(mut self, name: &str, value: f64) -> Self {
        self.key(name);
        // JSON has no NaN/Inf; clamp to null like serde_json does.
        if value.is_finite() {
            let _ = write!(self.buf, "{value}");
        } else {
            self.buf.push_str("null");
        }
        self
    }

    pub fn field_bool(mut self, name: &str, value: bool) -> Self {
        self.key(name);
        self.buf.push_str(if value { "true" } else { "false" });
        self
    }

    pub fn finish(mut self) -> String {
        self.buf.push('}');
        self.buf
    }
}

impl Default for JsonObj {
    fn default() -> Self {
        Self::new()
    }
}

fn escape_into(buf: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => buf.push_str("\\\""),
            '\\' => buf.push_str("\\\\"),
            '\n' => buf.push_str("\\n"),
            '\r' => buf.push_str("\\r"),
            '\t' => buf.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(buf, "\\u{:04x}", c as u32);
            }
            c => buf.push(c),
        }
    }
}

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: src.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// `usize` array field, e.g. a shape.
    pub fn usize_array(&self, key: &str) -> Option<Vec<usize>> {
        self.get(key)?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect()
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        match self.bump() {
            Some(x) if x == b => Ok(()),
            other => bail!(
                "expected {:?} at byte {}, got {:?}",
                b as char,
                self.pos,
                other.map(|c| c as char)
            ),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                other => bail!("expected ',' or '}}', got {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                other => bail!("expected ',' or ']', got {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => bail!("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().map(|c| c as char);
                            let d = c.and_then(|c| c.to_digit(16));
                            match d {
                                Some(d) => code = code * 16 + d,
                                None => bail!("bad \\u escape"),
                            }
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    other => bail!("bad escape {:?}", other.map(|c| c as char)),
                },
                Some(b) if b < 0x80 => out.push(b as char),
                Some(b) => {
                    // Multi-byte UTF-8: copy the raw bytes through.
                    let start = self.pos - 1;
                    let len = if b >= 0xF0 {
                        4
                    } else if b >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    for _ in 1..len {
                        self.bump();
                    }
                    match std::str::from_utf8(&self.bytes[start..self.pos]) {
                        Ok(s) => out.push_str(s),
                        Err(_) => bail!("invalid utf-8 in string"),
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        match s.parse::<f64>() {
            Ok(n) => Ok(Json::Num(n)),
            Err(_) => bail!("bad number {s:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let src = r#"{
          "dtype": "f64",
          "artifacts": [
            {"name": "wt_x", "x_shape": [2, 2, 5, 10], "stride": 1, "ok": true}
          ]
        }"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("dtype").unwrap().as_str(), Some("f64"));
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].usize_array("x_shape").unwrap(), vec![2, 2, 5, 10]);
        assert_eq!(arts[0].get("stride").unwrap().as_usize(), Some(1));
        assert_eq!(arts[0].get("ok"), Some(&Json::Bool(true)));
    }

    #[test]
    fn scalars_and_nesting() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
        let j = Json::parse(r#"[[1,2],[3,[4]]]"#).unwrap();
        assert_eq!(
            j.as_arr().unwrap()[1].as_arr().unwrap()[1],
            Json::Arr(vec![Json::Num(4.0)])
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse(r#""héllo → ∞""#).unwrap();
        assert_eq!(j.as_str(), Some("héllo → ∞"));
    }

    #[test]
    fn writer_output_parses_back() {
        let line = JsonObj::new()
            .field_str("bench", "fig6_faults")
            .field_str("model", "crash\"q\"")
            .field_u64("retries", 3)
            .field_f64("completion_rate", 1.0)
            .field_f64("nan_is_null", f64::NAN)
            .field_bool("ok", true)
            .finish();
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("bench").unwrap().as_str(), Some("fig6_faults"));
        assert_eq!(j.get("model").unwrap().as_str(), Some("crash\"q\""));
        assert_eq!(j.get("retries").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("completion_rate").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("nan_is_null"), Some(&Json::Null));
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(JsonObj::new().finish(), "{}");
    }
}
