//! Small shared utilities: PRNG, float comparison helpers, timing, and
//! the persistent compute pool.

pub mod json;
pub mod pool;
pub mod rng;
pub mod timer;

/// Relative-or-absolute closeness test for floating point comparisons in
/// tests and oracles (mirrors `numpy.allclose` semantics).
pub fn approx_eq(a: f64, b: f64, rtol: f64, atol: f64) -> bool {
    (a - b).abs() <= atol + rtol * b.abs()
}

/// Max absolute elementwise difference between two slices.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "max_abs_diff: length mismatch");
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

/// Mean squared error between two slices (paper eq. (62), flattened).
pub fn mse(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "mse: length mismatch");
    if a.is_empty() {
        return 0.0;
    }
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>() / a.len() as f64
}

/// Round `x` up to the next multiple of `m` (m > 0).
pub fn next_multiple_of(x: usize, m: usize) -> usize {
    assert!(m > 0);
    x.div_ceil(m) * m
}

/// Smallest odd integer `q >= n.max(1)` (the paper's `Nextodd(n)`).
pub fn next_odd(n: usize) -> usize {
    let n = n.max(1);
    if n % 2 == 1 {
        n
    } else {
        n + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_next_odd() {
        assert_eq!(next_odd(0), 1);
        assert_eq!(next_odd(1), 1);
        assert_eq!(next_odd(4), 5);
        assert_eq!(next_odd(5), 5);
        assert_eq!(next_odd(18), 19);
    }

    #[test]
    fn test_next_multiple_of() {
        assert_eq!(next_multiple_of(0, 4), 0);
        assert_eq!(next_multiple_of(1, 4), 4);
        assert_eq!(next_multiple_of(4, 4), 4);
        assert_eq!(next_multiple_of(5, 4), 8);
    }

    #[test]
    fn test_mse() {
        assert_eq!(mse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((mse(&[1.0, 2.0], &[2.0, 4.0]) - 2.5).abs() < 1e-15);
    }

    #[test]
    fn test_approx_eq() {
        assert!(approx_eq(1.0, 1.0 + 1e-12, 1e-9, 0.0));
        assert!(!approx_eq(1.0, 1.1, 1e-9, 1e-9));
    }
}
