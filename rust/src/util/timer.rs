//! Lightweight phase timing used by the coordinator and benches.

use std::time::{Duration, Instant};

/// A named stopwatch that accumulates durations across start/stop cycles.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    total: Duration,
    started: Option<Instant>,
    laps: usize,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Self {
            total: Duration::ZERO,
            started: None,
            laps: 0,
        }
    }

    pub fn start(&mut self) {
        debug_assert!(self.started.is_none(), "stopwatch already running");
        self.started = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.total += t0.elapsed();
            self.laps += 1;
        }
    }

    /// Time a closure, accumulating its duration.
    pub fn time<R>(&mut self, f: impl FnOnce() -> R) -> R {
        self.start();
        let r = f();
        self.stop();
        r
    }

    pub fn total(&self) -> Duration {
        self.total
    }

    pub fn secs(&self) -> f64 {
        self.total.as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.total.as_secs_f64() * 1e3
    }

    pub fn laps(&self) -> usize {
        self.laps
    }

    pub fn reset(&mut self) {
        *self = Self::new();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let mut sw = Stopwatch::new();
        let x = sw.time(|| {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(x, 42);
        sw.time(|| std::thread::sleep(Duration::from_millis(5)));
        assert!(sw.millis() >= 9.0, "elapsed={}ms", sw.millis());
        assert_eq!(sw.laps(), 2);
        sw.reset();
        assert_eq!(sw.laps(), 0);
        assert_eq!(sw.total(), Duration::ZERO);
    }
}
