//! Deterministic PRNGs for workload generation, straggler simulation and
//! property testing. The environment has no `rand` crate; these are the
//! standard SplitMix64 and xoshiro256** generators, implemented from the
//! reference algorithms (Blackman & Vigna).

/// SplitMix64: tiny, fast, good-quality seeder / standalone generator.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: the general-purpose generator used everywhere we need a
/// stream of randomness (tensor fills, straggler draws, property tests).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 per the xoshiro authors' recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform usize in [0, n) (n > 0). Uses rejection to avoid modulo bias.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform usize in [lo, hi) .
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Exponential with rate `lambda` (mean 1/lambda); used for straggler
    /// latency draws (the standard model in the CDC literature).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        loop {
            let u = self.next_f64();
            if u > 1e-300 {
                return -u.ln() / lambda;
            }
        }
    }

    /// Geometric draw via inversion: the number of Bernoulli(p) failures
    /// before the first success (support 0, 1, 2, …; mean (1−p)/p). Used
    /// for burst sizes in the open-loop arrival generator.
    pub fn geometric(&mut self, p: f64) -> usize {
        assert!(p > 0.0 && p <= 1.0, "geometric needs 0 < p <= 1");
        if p >= 1.0 {
            return 0;
        }
        let lnq = (1.0 - p).ln();
        loop {
            let u = self.next_f64();
            if u > 1e-300 {
                // Both logs are negative, so the quotient is ≥ 0 and
                // `as usize` truncates toward zero (= floor).
                return (u.ln() / lnq) as usize;
            }
        }
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose `k` distinct indices from [0, n) (k <= n), in random order.
    pub fn choose_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Vector of iid uniform values in [lo, hi).
    pub fn fill_uniform(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.uniform(lo, hi)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let x = r.uniform(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Rng::new(2);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn choose_indices_distinct() {
        let mut r = Rng::new(4);
        let idx = r.choose_indices(10, 6);
        assert_eq!(idx.len(), 6);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 6);
        assert!(s.iter().all(|&i| i < 10));
    }

    #[test]
    fn geometric_mean_and_edge() {
        let mut r = Rng::new(6);
        let n = 100_000;
        let p = 0.25;
        let m = (0..n).map(|_| r.geometric(p)).sum::<usize>() as f64 / n as f64;
        // Mean (1-p)/p = 3.0.
        assert!((m - 3.0).abs() < 0.05, "mean={m}");
        assert_eq!(r.geometric(1.0), 0, "p=1 always succeeds immediately");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let m = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.01, "mean={m}");
    }
}
