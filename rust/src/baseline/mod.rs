//! Uncoded baselines (paper Table II): the naive single-node scheme and
//! the three mainstream model-parallel partitionings — spatial [42],
//! output-channel [43], and input-channel [44]. These carry **no coded
//! redundancy**: every worker must respond, so a single straggler stalls
//! the job (the contrast FCDCC's Figs. 5–6 quantify).

use crate::model::ConvLayer;
use crate::partition::{ApcpPlan, KccpPlan};
use crate::tensor::{conv2d, ConvParams, Tensor3, Tensor4};
use anyhow::{ensure, Result};

/// Uncoded model-parallel partitioning strategies (Table II rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UncodedScheme {
    /// Everything on one node.
    Naive,
    /// Split the input along H into `k` slabs (adaptive padding, same
    /// geometry as APCP but uncoded); every worker holds the full filter.
    Spatial { k: usize },
    /// Split the filter bank along N into `k` groups; every worker holds
    /// the full input.
    OutChannel { k: usize },
    /// Split both tensors along C into `k` groups; outputs are **summed**
    /// (the merge cost Table II calls out).
    InChannel { k: usize },
}

/// One uncoded subtask: worker `i` convolves `x` with `k`.
pub struct UncodedSubtask {
    pub worker_id: usize,
    pub x: Tensor3,
    pub k: Tensor4,
    pub conv: ConvParams,
}

impl UncodedSubtask {
    pub fn upload_entries(&self) -> usize {
        self.x.len()
    }

    pub fn store_entries(&self) -> usize {
        self.k.len()
    }

    pub fn run(&self) -> Tensor3 {
        conv2d(&self.x, &self.k, self.conv)
    }
}

/// A planned uncoded execution.
pub struct UncodedPlan {
    pub scheme: UncodedScheme,
    pub layer: ConvLayer,
    apcp: Option<ApcpPlan>,
}

impl UncodedPlan {
    pub fn new(layer: &ConvLayer, scheme: UncodedScheme) -> Result<Self> {
        let apcp = match scheme {
            UncodedScheme::Spatial { k } => Some(ApcpPlan::new(
                layer.h + 2 * layer.pad,
                layer.kh,
                layer.stride,
                k,
            )?),
            UncodedScheme::OutChannel { k } => {
                KccpPlan::new(layer.n, k)?; // validates divisibility
                None
            }
            UncodedScheme::InChannel { k } => {
                ensure!(layer.c % k == 0, "k={k} must divide C={}", layer.c);
                None
            }
            UncodedScheme::Naive => None,
        };
        Ok(Self {
            scheme,
            layer: layer.clone(),
            apcp,
        })
    }

    /// Number of workers the scheme occupies.
    pub fn workers(&self) -> usize {
        match self.scheme {
            UncodedScheme::Naive => 1,
            UncodedScheme::Spatial { k }
            | UncodedScheme::OutChannel { k }
            | UncodedScheme::InChannel { k } => k,
        }
    }

    /// Build every worker's subtask. `x` is the unpadded input.
    pub fn subtasks(&self, x: &Tensor3, k: &Tensor4) -> Vec<UncodedSubtask> {
        let layer = &self.layer;
        match self.scheme {
            UncodedScheme::Naive => vec![UncodedSubtask {
                worker_id: 0,
                x: x.clone(),
                k: k.clone(),
                conv: layer.params(),
            }],
            UncodedScheme::Spatial { .. } => {
                let xp = x.pad_spatial(layer.pad);
                let parts = self.apcp.as_ref().unwrap().partition(&xp);
                parts
                    .into_iter()
                    .enumerate()
                    .map(|(worker_id, slab)| UncodedSubtask {
                        worker_id,
                        x: slab,
                        k: k.clone(),
                        conv: ConvParams::new(layer.stride, 0),
                    })
                    .collect()
            }
            UncodedScheme::OutChannel { k: kb } => {
                let per = layer.n / kb;
                (0..kb)
                    .map(|i| UncodedSubtask {
                        worker_id: i,
                        x: x.clone(),
                        k: k.slice_n(i * per, (i + 1) * per),
                        conv: layer.params(),
                    })
                    .collect()
            }
            UncodedScheme::InChannel { k: kc } => {
                let per = layer.c / kc;
                (0..kc)
                    .map(|i| {
                        let xs = x.slice_c(i * per, (i + 1) * per);
                        // filter slice along input-channel axis
                        let mut kk = Tensor4::zeros(layer.n, per, layer.kh, layer.kw);
                        for n in 0..layer.n {
                            for c in 0..per {
                                for a in 0..layer.kh {
                                    for b in 0..layer.kw {
                                        kk.set(n, c, a, b, k.get(n, i * per + c, a, b));
                                    }
                                }
                            }
                        }
                        UncodedSubtask {
                            worker_id: i,
                            x: xs,
                            k: kk,
                            conv: layer.params(),
                        }
                    })
                    .collect()
            }
        }
    }

    /// Merge all worker outputs (requires every worker's result — no
    /// straggler tolerance by construction).
    pub fn merge(&self, outputs: &[Tensor3]) -> Tensor3 {
        assert_eq!(outputs.len(), self.workers(), "uncoded merge needs all workers");
        match self.scheme {
            UncodedScheme::Naive => outputs[0].clone(),
            UncodedScheme::Spatial { .. } => {
                let merged = Tensor3::concat_h(&outputs.iter().collect::<Vec<_>>());
                // trim the APCP bottom padding rows if H' was rounded up
                let h_true = self.layer.h_out();
                if merged.h == h_true {
                    merged
                } else {
                    merged.slice_h(0, h_true)
                }
            }
            UncodedScheme::OutChannel { .. } => {
                Tensor3::concat_c(&outputs.iter().collect::<Vec<_>>())
            }
            UncodedScheme::InChannel { .. } => {
                let mut acc = outputs[0].clone();
                for o in &outputs[1..] {
                    acc.axpy(1.0, o);
                }
                acc
            }
        }
    }

    /// Run the whole scheme inline.
    pub fn run_inline(&self, x: &Tensor3, k: &Tensor4) -> Tensor3 {
        let outs: Vec<Tensor3> = self.subtasks(x, k).iter().map(|s| s.run()).collect();
        self.merge(&outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{max_abs_diff, rng::Rng};

    fn setup() -> (ConvLayer, Tensor3, Tensor4) {
        let layer = ConvLayer::new("t", 4, 13, 11, 8, 3, 3, 1, 1);
        let mut rng = Rng::new(91);
        let x = Tensor3::random(4, 13, 11, &mut rng);
        let k = Tensor4::random(8, 4, 3, 3, &mut rng);
        (layer, x, k)
    }

    #[test]
    fn all_schemes_match_direct() {
        let (layer, x, k) = setup();
        let want = conv2d(&x, &k, layer.params());
        for scheme in [
            UncodedScheme::Naive,
            UncodedScheme::Spatial { k: 4 },
            UncodedScheme::OutChannel { k: 4 },
            UncodedScheme::InChannel { k: 2 },
        ] {
            let plan = UncodedPlan::new(&layer, scheme).unwrap();
            let got = plan.run_inline(&x, &k);
            assert_eq!(got.shape(), want.shape(), "{scheme:?}");
            assert!(
                max_abs_diff(&got.data, &want.data) < 1e-12,
                "{scheme:?} mismatch"
            );
        }
    }

    #[test]
    fn table2_accounting() {
        // Table II communication entries per scheme (p=0 case).
        let layer = ConvLayer::new("t", 4, 12, 10, 8, 3, 3, 1, 0);
        let mut rng = Rng::new(92);
        let x = Tensor3::random(4, 12, 10, &mut rng);
        let k = Tensor4::random(8, 4, 3, 3, &mut rng);

        // Spatial k=2: upload C·Ĥ·W per worker, full filter stored.
        let sp = UncodedPlan::new(&layer, UncodedScheme::Spatial { k: 2 }).unwrap();
        let st = sp.subtasks(&x, &k);
        assert_eq!(st[0].store_entries(), 8 * 4 * 9);
        assert!(st[0].upload_entries() < x.len());

        // OutChannel k=4: full input uploaded, N/k filters stored.
        let oc = UncodedPlan::new(&layer, UncodedScheme::OutChannel { k: 4 }).unwrap();
        let st = oc.subtasks(&x, &k);
        assert_eq!(st[0].upload_entries(), x.len());
        assert_eq!(st[0].store_entries(), (8 / 4) * 4 * 9);

        // InChannel k=2: C/k of both tensors.
        let ic = UncodedPlan::new(&layer, UncodedScheme::InChannel { k: 2 }).unwrap();
        let st = ic.subtasks(&x, &k);
        assert_eq!(st[0].upload_entries(), x.len() / 2);
        assert_eq!(st[0].store_entries(), k.len() / 2);
    }

    #[test]
    fn rejects_bad_divisors() {
        let (layer, _, _) = setup();
        assert!(UncodedPlan::new(&layer, UncodedScheme::OutChannel { k: 3 }).is_err());
        assert!(UncodedPlan::new(&layer, UncodedScheme::InChannel { k: 3 }).is_err());
    }
}
