//! A minimal criterion-style bench harness (criterion is unavailable in
//! the offline environment): warmup, fixed sample count, summary stats.
//! Used by every target in `rust/benches/` (declared with
//! `harness = false`).

use crate::metrics::{fmt_secs, Stats};
use std::time::Instant;

/// Configuration for one measured benchmark.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub sample_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup_iters: 1,
            sample_iters: 5,
        }
    }
}

impl BenchConfig {
    pub fn quick() -> Self {
        Self {
            warmup_iters: 0,
            sample_iters: 3,
        }
    }
}

/// Time a closure `cfg.sample_iters` times (after warmup) and return the
/// per-iteration stats in seconds.
pub fn bench<R>(cfg: BenchConfig, mut f: impl FnMut() -> R) -> Stats {
    for _ in 0..cfg.warmup_iters {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(cfg.sample_iters);
    for _ in 0..cfg.sample_iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    Stats::from(&samples)
}

/// Print a one-line bench result (criterion-ish).
pub fn report(name: &str, s: &Stats) {
    println!(
        "{name:<44} mean {:>10}  p50 {:>10}  min {:>10}  max {:>10}  (n={})",
        fmt_secs(s.mean),
        fmt_secs(s.p50),
        fmt_secs(s.min),
        fmt_secs(s.max),
        s.n
    );
}

/// Emit one JSON trajectory record: printed to stdout like every other
/// bench line and, when `FCDCC_BENCH_OUT=<path>` is set, **appended** to
/// that file — so a bench run accumulates its records into a committed
/// perf-trajectory artifact (`BENCH_*.json`, one JSON object per line).
/// File errors are deliberately non-fatal: a bench never dies over its
/// telemetry.
pub fn emit_json(line: &str) {
    println!("{line}");
    if let Ok(path) = std::env::var("FCDCC_BENCH_OUT") {
        if path.is_empty() {
            return;
        }
        use std::io::Write;
        match std::fs::OpenOptions::new().create(true).append(true).open(&path) {
            Ok(mut f) => {
                let _ = writeln!(f, "{line}");
            }
            Err(e) => eprintln!("FCDCC_BENCH_OUT: cannot append to {path}: {e}"),
        }
    }
}

/// Read an env-var knob for bench scaling (e.g. FCDCC_BENCH_SAMPLES).
pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// `FCDCC_BENCH_FAST=1` (or the short alias `FCDCC_FAST=1`, used by the
/// CI smoke step) shrinks every bench to smoke-test size.
pub fn fast_mode() -> bool {
    let on = |name: &str| std::env::var(name).map(|v| v == "1").unwrap_or(false);
    on("FCDCC_BENCH_FAST") || on("FCDCC_FAST")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let s = bench(BenchConfig::quick(), || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert_eq!(s.n, 3);
        assert!(s.mean > 0.0);
        assert!(s.min <= s.mean && s.mean <= s.max);
    }

    #[test]
    fn env_knobs() {
        assert_eq!(env_usize("FCDCC_NONEXISTENT_KNOB", 7), 7);
    }
}
