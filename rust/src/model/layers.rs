//! Convolutional-layer geometry (Table I notation) and derived quantities
//! used by the cost model and the benches.

use crate::tensor::{conv2d_shape, ConvParams};

/// One convolutional layer's shape parameters (paper Table I).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ConvLayer {
    pub name: String,
    /// Input channels C.
    pub c: usize,
    /// Unpadded input height H and width W.
    pub h: usize,
    pub w: usize,
    /// Output channels N.
    pub n: usize,
    /// Kernel height/width K_H, K_W.
    pub kh: usize,
    pub kw: usize,
    /// Stride s and padding p.
    pub stride: usize,
    pub pad: usize,
}

impl ConvLayer {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: &str,
        c: usize,
        h: usize,
        w: usize,
        n: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        pad: usize,
    ) -> Self {
        Self {
            name: name.to_string(),
            c,
            h,
            w,
            n,
            kh,
            kw,
            stride,
            pad,
        }
    }

    pub fn params(&self) -> ConvParams {
        ConvParams::new(self.stride, self.pad)
    }

    /// (H', W') output spatial dims.
    pub fn out_shape(&self) -> (usize, usize) {
        conv2d_shape(self.h, self.w, self.kh, self.kw, self.params())
    }

    pub fn h_out(&self) -> usize {
        self.out_shape().0
    }

    pub fn w_out(&self) -> usize {
        self.out_shape().1
    }

    /// Padded input entry count C·(H+2p)·(W+2p).
    pub fn input_entries(&self) -> usize {
        self.c * (self.h + 2 * self.pad) * (self.w + 2 * self.pad)
    }

    /// Filter entry count N·C·K_H·K_W.
    pub fn filter_entries(&self) -> usize {
        self.n * self.c * self.kh * self.kw
    }

    /// Output entry count N·H'·W'.
    pub fn output_entries(&self) -> usize {
        let (h, w) = self.out_shape();
        self.n * h * w
    }

    /// Total MAC count of the layer: N·H'·W'·C·K_H·K_W (paper §V).
    pub fn macs(&self) -> usize {
        self.output_entries() * self.c * self.kh * self.kw
    }

    /// A copy with spatial dims scaled down by `f` (≥1) — used to run
    /// VGG-geometry benches at tractable sizes on this testbed (DESIGN.md
    /// §Hardware adaptation); channel structure is preserved.
    pub fn scaled_spatial(&self, f: usize) -> ConvLayer {
        assert!(f >= 1);
        let mut l = self.clone();
        l.name = if f == 1 {
            l.name
        } else {
            format!("{}/s{f}", l.name)
        };
        l.h = (l.h / f).max(l.kh);
        l.w = (l.w / f).max(l.kw);
        l
    }

    /// A copy with channel counts scaled down by `f` (≥1), keeping the
    /// output-channel count a multiple of 8 (so KCCP divisor choices stay
    /// rich); used with [`Self::scaled_spatial`] by the benches.
    pub fn scaled_channels(&self, f: usize) -> ConvLayer {
        assert!(f >= 1);
        let mut l = self.clone();
        if f == 1 {
            return l;
        }
        l.name = format!("{}/c{f}", l.name);
        l.c = (l.c / f).max(1);
        l.n = ((l.n / f) / 8 * 8).max(8);
        l
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alexnet_conv1_geometry() {
        let l = ConvLayer::new("conv1", 3, 227, 227, 96, 11, 11, 4, 0);
        assert_eq!(l.out_shape(), (55, 55));
        assert_eq!(l.macs(), 96 * 55 * 55 * 3 * 11 * 11);
    }

    #[test]
    fn vgg_conv_keeps_spatial() {
        let l = ConvLayer::new("c", 64, 224, 224, 64, 3, 3, 1, 1);
        assert_eq!(l.out_shape(), (224, 224));
        assert_eq!(l.input_entries(), 64 * 226 * 226);
    }

    #[test]
    fn scaled_spatial_floors_at_kernel() {
        let l = ConvLayer::new("c", 8, 14, 14, 8, 3, 3, 1, 1);
        let s = l.scaled_spatial(8);
        assert_eq!(s.h, 3);
        assert_eq!(s.w, 3);
    }
}
