//! A small CNN inference graph in Rust (conv / ReLU / pool / FC /
//! softmax) — the substrate for the end-to-end distributed-inference
//! example: every Conv layer can be executed either locally or through
//! the FCDCC distributed pipeline (the hook is a callback, so the network
//! definition stays transport-agnostic).

use crate::linalg::gemm;
use crate::model::ConvLayer;
use crate::tensor::{conv2d, Tensor3, Tensor4};
use crate::util::rng::Rng;

/// One layer of the inference graph.
pub enum Layer {
    /// Convolution with weights and per-output-channel bias.
    Conv {
        shape: ConvLayer,
        weights: Tensor4,
        bias: Vec<f64>,
    },
    Relu,
    /// Max pooling with square window `size` and stride `stride`.
    MaxPool { size: usize, stride: usize },
    /// Average pooling.
    AvgPool { size: usize, stride: usize },
    /// Fully connected on the flattened tensor: out = W·x + b.
    Dense {
        w: crate::linalg::Mat,
        b: Vec<f64>,
    },
}

/// How a Conv layer is executed: given (input, weights, shape) produce
/// the output feature map. The default runs locally; the e2e example
/// plugs in the FCDCC distributed pipeline.
pub type ConvExec<'a> = dyn Fn(&Tensor3, &Tensor4, &ConvLayer) -> Tensor3 + 'a;

/// A feed-forward network (sequence of layers).
pub struct Network {
    pub name: String,
    pub layers: Vec<Layer>,
}

/// Square-window pooling (shared by the forward pass and the serving
/// coordinator).
pub fn pool(x: &Tensor3, size: usize, stride: usize, max: bool) -> Tensor3 {
    let oh = (x.h - size) / stride + 1;
    let ow = (x.w - size) / stride + 1;
    let mut out = Tensor3::zeros(x.c, oh, ow);
    for c in 0..x.c {
        for h in 0..oh {
            for w in 0..ow {
                let mut acc = if max { f64::NEG_INFINITY } else { 0.0 };
                for i in 0..size {
                    for j in 0..size {
                        let v = x.get(c, h * stride + i, w * stride + j);
                        if max {
                            acc = acc.max(v);
                        } else {
                            acc += v;
                        }
                    }
                }
                out.set(c, h, w, if max { acc } else { acc / (size * size) as f64 });
            }
        }
    }
    out
}

/// Numerically-stable softmax over a vector.
pub fn softmax(xs: &[f64]) -> Vec<f64> {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let exps: Vec<f64> = xs.iter().map(|x| (x - m).exp()).collect();
    let s: f64 = exps.iter().sum();
    exps.into_iter().map(|e| e / s).collect()
}

/// The value flowing through a network during a forward pass: a spatial
/// feature map until the first Dense layer flattens it, a plain vector
/// afterwards. Requests paused mid-pass (waiting on a distributed conv
/// job) are represented by exactly this state.
pub struct Activation {
    t: Tensor3,
    flat: Option<Vec<f64>>,
}

impl Activation {
    pub fn new(x: &Tensor3) -> Self {
        Self {
            t: x.clone(),
            flat: None,
        }
    }

    /// The spatial feature map (the input of the next conv layer).
    pub fn spatial(&self) -> &Tensor3 {
        &self.t
    }

    /// Replace the spatial feature map (a conv layer's output).
    pub fn set_spatial(&mut self, t: Tensor3) {
        debug_assert!(self.flat.is_none(), "conv applied after flatten");
        self.t = t;
    }

    /// Finish the pass: the logits vector (or the flattened feature map
    /// when the network has no Dense head).
    pub fn into_logits(self) -> Vec<f64> {
        self.flat.unwrap_or(self.t.data)
    }
}

/// Add a per-output-channel bias in place — the master-side epilogue of
/// both local and distributed conv execution.
pub fn add_bias(y: &mut Tensor3, bias: &[f64]) {
    assert_eq!(y.c, bias.len(), "one bias per output channel");
    let plane = y.h * y.w;
    for (chunk, b) in y.data.chunks_mut(plane).zip(bias) {
        for v in chunk {
            *v += b;
        }
    }
}

impl Network {
    /// Forward pass with the default (local) conv executor.
    pub fn forward(&self, x: &Tensor3) -> Vec<f64> {
        self.forward_with(x, &|x, k, shape| conv2d(x, k, shape.params()))
    }

    /// Apply one non-convolutional layer in place — the single
    /// implementation shared by the local forward pass and the
    /// distributed serving scheduler (`fcdcc::NetworkPlan`).
    ///
    /// # Panics
    /// On a `Conv` layer: convolutions are executed by the caller (either
    /// locally or through the FCDCC cluster), never here.
    pub fn apply_local(&self, layer: &Layer, a: &mut Activation) {
        match layer {
            Layer::Conv { .. } => panic!("apply_local cannot execute conv layers"),
            Layer::Relu => {
                if let Some(f) = &mut a.flat {
                    for v in f.iter_mut() {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                } else {
                    a.t.relu_inplace();
                }
            }
            Layer::MaxPool { size, stride } => a.t = pool(&a.t, *size, *stride, true),
            Layer::AvgPool { size, stride } => a.t = pool(&a.t, *size, *stride, false),
            Layer::Dense { w, b } => {
                let input = a.flat.take().unwrap_or_else(|| a.t.data.clone());
                let mut y = w.matvec(&input);
                for (yi, bi) in y.iter_mut().zip(b) {
                    *yi += bi;
                }
                a.flat = Some(y);
            }
        }
    }

    /// Apply one non-convolutional layer to a **group** of activations
    /// at the same pipeline position — the coalesced-serving fast path.
    /// `Dense` layers run as one shared packed GEMM (`linalg::gemm`):
    /// the weight matrix streams from memory once for the whole group
    /// instead of once per request, with the flattened activations read
    /// as the implicit-transposed column operand. Every other layer
    /// type applies per activation.
    ///
    /// Per output element the GEMM is the same k-ascending fold as
    /// `Mat::matvec`, so grouped logits equal per-request
    /// `apply_local` logits exactly — batching requests never moves
    /// their outputs.
    ///
    /// # Panics
    /// On a `Conv` layer, like [`Self::apply_local`].
    pub fn apply_local_batch(&self, layer: &Layer, acts: &mut [&mut Activation]) {
        if acts.len() <= 1 {
            for a in acts.iter_mut() {
                self.apply_local(layer, a);
            }
            return;
        }
        match layer {
            Layer::Dense { w, b } => {
                let inputs: Vec<Vec<f64>> = acts
                    .iter_mut()
                    .map(|a| a.flat.take().unwrap_or_else(|| a.t.data.clone()))
                    .collect();
                let cols: Vec<&[f64]> = inputs.iter().map(|v| v.as_slice()).collect();
                let batch = cols.len();
                for x in &cols {
                    assert_eq!(w.cols, x.len(), "dense: dim mismatch");
                }
                // out (rows × batch) = W · [x_0 … x_{batch-1}].
                let mut out = vec![0.0; w.rows * batch];
                gemm::gemm_into(
                    w.rows,
                    batch,
                    w.cols,
                    &gemm::RowMajor {
                        data: &w.data,
                        ld: w.cols.max(1),
                    },
                    &gemm::ColsB { cols: &cols },
                    &mut out,
                    batch,
                );
                for (sample, a) in acts.iter_mut().enumerate() {
                    let y: Vec<f64> = (0..w.rows)
                        .map(|r| out[r * batch + sample] + b[r])
                        .collect();
                    a.flat = Some(y);
                }
            }
            _ => {
                for a in acts.iter_mut() {
                    self.apply_local(layer, a);
                }
            }
        }
    }

    /// Forward pass with a custom conv executor (e.g. FCDCC distributed).
    pub fn forward_with(&self, x: &Tensor3, conv_exec: &ConvExec) -> Vec<f64> {
        let mut a = Activation::new(x);
        for layer in &self.layers {
            if let Layer::Conv {
                shape,
                weights,
                bias,
            } = layer
            {
                let mut y = conv_exec(a.spatial(), weights, shape);
                add_bias(&mut y, bias);
                a.set_spatial(y);
            } else {
                self.apply_local(layer, &mut a);
            }
        }
        a.into_logits()
    }

    /// LeNet-5 with random (synthetically "trained") weights — the model
    /// served by the e2e example. Deterministic for a given seed.
    pub fn lenet5_random(seed: u64) -> Network {
        let mut rng = Rng::new(seed);
        let shapes = crate::model::zoo::lenet5();
        let scale1 = (2.0f64 / 25.0).sqrt(); // He init
        let w1 = {
            let mut t = Tensor4::random(6, 1, 5, 5, &mut rng);
            t.data.iter_mut().for_each(|v| *v *= scale1);
            t
        };
        let scale2 = (2.0f64 / 150.0).sqrt();
        let w2 = {
            let mut t = Tensor4::random(16, 6, 5, 5, &mut rng);
            t.data.iter_mut().for_each(|v| *v *= scale2);
            t
        };
        // conv2 output: 16×10×10 -> pool -> 16×5×5 = 400 -> 120 -> 84 -> 10
        let dense = |rng: &mut Rng, rows: usize, cols: usize| {
            let scale = (2.0 / cols as f64).sqrt();
            let mut m = crate::linalg::Mat::random(rows, cols, rng);
            m.data.iter_mut().for_each(|v| *v *= scale);
            m
        };
        Network {
            name: "lenet5".into(),
            layers: vec![
                Layer::Conv {
                    shape: shapes[0].clone(),
                    weights: w1,
                    bias: vec![0.01; 6],
                },
                Layer::Relu,
                Layer::MaxPool { size: 2, stride: 2 },
                Layer::Conv {
                    shape: shapes[1].clone(),
                    weights: w2,
                    bias: vec![0.01; 16],
                },
                Layer::Relu,
                Layer::MaxPool { size: 2, stride: 2 },
                Layer::Dense {
                    w: dense(&mut rng, 120, 400),
                    b: vec![0.0; 120],
                },
                Layer::Relu,
                Layer::Dense {
                    w: dense(&mut rng, 84, 120),
                    b: vec![0.0; 84],
                },
                Layer::Relu,
                Layer::Dense {
                    w: dense(&mut rng, 10, 84),
                    b: vec![0.0; 10],
                },
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maxpool_known() {
        let x = Tensor3::from_vec(1, 2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let y = pool(&x, 2, 2, true);
        assert_eq!(y.data, vec![4.0]);
        let a = pool(&x, 2, 2, false);
        assert_eq!(a.data, vec![2.5]);
    }

    #[test]
    fn softmax_normalizes() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn lenet_forward_produces_10_logits() {
        let net = Network::lenet5_random(7);
        let x = Tensor3::random(1, 32, 32, &mut Rng::new(1));
        let logits = net.forward(&x);
        assert_eq!(logits.len(), 10);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn batched_dense_matches_per_sample_bitwise() {
        // The grouped GEMM must not move logits relative to per-request
        // matvec application — serve coalescing relies on this.
        let mut rng = Rng::new(11);
        let net = Network {
            name: "t".into(),
            layers: vec![],
        };
        let w = crate::linalg::Mat::random(5, 12, &mut rng);
        let b = rng.fill_uniform(5, -1.0, 1.0);
        let dense = Layer::Dense { w, b };
        let xs: Vec<Tensor3> = (0..3).map(|_| Tensor3::random(1, 3, 4, &mut rng)).collect();
        let mut singles: Vec<Activation> = xs.iter().map(Activation::new).collect();
        for a in singles.iter_mut() {
            net.apply_local(&dense, a);
        }
        let mut grouped: Vec<Activation> = xs.iter().map(Activation::new).collect();
        let mut refs: Vec<&mut Activation> = grouped.iter_mut().collect();
        net.apply_local_batch(&dense, &mut refs);
        for (s, g) in singles.into_iter().zip(grouped) {
            assert_eq!(s.into_logits(), g.into_logits(), "grouped dense diverged");
        }
    }

    #[test]
    fn custom_exec_matches_default() {
        let net = Network::lenet5_random(9);
        let x = Tensor3::random(1, 32, 32, &mut Rng::new(2));
        let a = net.forward(&x);
        let b = net.forward_with(&x, &|x, k, s| {
            crate::tensor::im2col::conv2d_im2col(x, k, s.params())
        });
        for (u, v) in a.iter().zip(&b) {
            assert!((u - v).abs() < 1e-9);
        }
    }
}
