//! CNN model zoo: the convolutional-layer geometries of LeNet-5, AlexNet
//! and VGG-16 used throughout the paper's evaluation (§VI), plus a full
//! Rust forward pass (conv/ReLU/pool/FC) for the end-to-end example.

pub mod layers;
pub mod network;
pub mod zoo;

pub use layers::ConvLayer;
pub use network::{Activation, Layer, Network};
