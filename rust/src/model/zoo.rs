//! The three CNN architectures evaluated in the paper (§VI): LeNet-5,
//! AlexNet and VGG-16 convolutional-layer geometries.

use crate::model::ConvLayer;

/// LeNet-5 ConvLs (LeCun et al.; 32×32 grayscale input).
pub fn lenet5() -> Vec<ConvLayer> {
    vec![
        ConvLayer::new("lenet.conv1", 1, 32, 32, 6, 5, 5, 1, 0), // -> 6×28×28
        ConvLayer::new("lenet.conv2", 6, 14, 14, 16, 5, 5, 1, 0), // -> 16×10×10
    ]
}

/// AlexNet ConvLs (Krizhevsky et al. [39], single-tower shapes).
pub fn alexnet() -> Vec<ConvLayer> {
    vec![
        ConvLayer::new("alexnet.conv1", 3, 227, 227, 96, 11, 11, 4, 0), // -> 96×55×55
        ConvLayer::new("alexnet.conv2", 96, 27, 27, 256, 5, 5, 1, 2),   // -> 256×27×27
        ConvLayer::new("alexnet.conv3", 256, 13, 13, 384, 3, 3, 1, 1),  // -> 384×13×13
        ConvLayer::new("alexnet.conv4", 384, 13, 13, 384, 3, 3, 1, 1),  // -> 384×13×13
        ConvLayer::new("alexnet.conv5", 384, 13, 13, 256, 3, 3, 1, 1),  // -> 256×13×13
    ]
}

/// VGG-16 ConvLs (Simonyan & Zisserman). Layers with identical geometry
/// are listed once with the paper's combined naming (e.g. conv3_2/3).
pub fn vggnet() -> Vec<ConvLayer> {
    vec![
        ConvLayer::new("vgg.conv1_1", 3, 224, 224, 64, 3, 3, 1, 1),
        ConvLayer::new("vgg.conv1_2", 64, 224, 224, 64, 3, 3, 1, 1),
        ConvLayer::new("vgg.conv2_1", 64, 112, 112, 128, 3, 3, 1, 1),
        ConvLayer::new("vgg.conv2_2", 128, 112, 112, 128, 3, 3, 1, 1),
        ConvLayer::new("vgg.conv3_1", 128, 56, 56, 256, 3, 3, 1, 1),
        ConvLayer::new("vgg.conv3_2/3", 256, 56, 56, 256, 3, 3, 1, 1),
        ConvLayer::new("vgg.conv4_1", 256, 28, 28, 512, 3, 3, 1, 1),
        ConvLayer::new("vgg.conv4_2/3", 512, 28, 28, 512, 3, 3, 1, 1),
        ConvLayer::new("vgg.conv5_1/2/3", 512, 14, 14, 512, 3, 3, 1, 1),
    ]
}

/// The "Conv4 of VGGNet" layer used in the paper's Experiment 2
/// (numerical-stability comparison): the conv4 block geometry.
pub fn vgg_conv4() -> ConvLayer {
    ConvLayer::new("vgg.conv4_1", 256, 28, 28, 512, 3, 3, 1, 1)
}

/// Representative "Conv1..Conv5" five-layer view of VGG used by the
/// paper's Table IV (one representative per block).
pub fn vgg_blocks() -> Vec<ConvLayer> {
    vec![
        ConvLayer::new("vgg.conv1", 3, 224, 224, 64, 3, 3, 1, 1),
        ConvLayer::new("vgg.conv2", 64, 112, 112, 128, 3, 3, 1, 1),
        ConvLayer::new("vgg.conv3", 128, 56, 56, 256, 3, 3, 1, 1),
        ConvLayer::new("vgg.conv4", 256, 28, 28, 512, 3, 3, 1, 1),
        ConvLayer::new("vgg.conv5", 512, 14, 14, 512, 3, 3, 1, 1),
    ]
}

/// Look up an architecture by name ("lenet" | "alexnet" | "vgg").
pub fn by_name(name: &str) -> Option<Vec<ConvLayer>> {
    match name {
        "lenet" | "lenet5" | "lenet-5" => Some(lenet5()),
        "alexnet" => Some(alexnet()),
        "vgg" | "vggnet" | "vgg16" | "vgg-16" => Some(vggnet()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lenet_shapes() {
        let ls = lenet5();
        assert_eq!(ls[0].out_shape(), (28, 28));
        assert_eq!(ls[1].out_shape(), (10, 10));
    }

    #[test]
    fn alexnet_shapes_chain() {
        let ls = alexnet();
        assert_eq!(ls[0].out_shape(), (55, 55));
        assert_eq!(ls[1].out_shape(), (27, 27));
        assert_eq!(ls[2].out_shape(), (13, 13));
        assert_eq!(ls[4].out_shape(), (13, 13));
    }

    #[test]
    fn vgg_preserves_spatial_within_block() {
        for l in vggnet() {
            let (h, w) = l.out_shape();
            assert_eq!((h, w), (l.h, l.w), "{}", l.name);
        }
    }

    #[test]
    fn lookup() {
        assert!(by_name("alexnet").is_some());
        assert!(by_name("resnet").is_none());
    }
}
