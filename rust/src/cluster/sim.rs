//! Virtual-time cluster simulation — the measurement backbone of the
//! benches (Figs. 5–6, Table III).
//!
//! On this 1-vCPU testbed, truly-parallel wall-clock makespan is not
//! observable: n worker threads would serialize. The simulator instead
//! executes each worker's subtask *serially*, timing it in isolation,
//! adds the injected straggler delay, and reconstructs the parallel
//! timeline analytically: worker i finishes at `delay_i + compute_i`,
//! the master decodes after the δ-th earliest finisher (exactly the
//! paper's first-δ semantics), and the job makespan is that order
//! statistic. Failed workers never finish.

use crate::cluster::straggler::WorkerFate;
use crate::engine::TaskEngine;
use crate::fcdcc::{FcdccPlan, ResidentFilters};
use crate::tensor::Tensor3;
use anyhow::{bail, Result};
use std::time::Instant;

/// Virtual-time result of one coded job.
#[derive(Clone, Debug)]
pub struct SimJob {
    /// Master-side encode time (measured).
    pub encode_secs: f64,
    /// Per-worker (injected delay, measured compute) for non-failed
    /// workers; `None` for failed ones.
    pub per_worker: Vec<Option<(f64, f64)>>,
    /// Worker ids used for decoding (the δ earliest finishers).
    pub survivors: Vec<usize>,
    /// Virtual parallel makespan: finish time of the δ-th survivor.
    pub makespan_secs: f64,
    /// Master-side decode time (measured).
    pub decode_secs: f64,
    /// The decoded output tensor.
    pub output: Tensor3,
}

impl SimJob {
    /// Mean pure compute time across survivors.
    pub fn mean_compute_secs(&self) -> f64 {
        let vals: Vec<f64> = self
            .survivors
            .iter()
            .map(|&i| self.per_worker[i].unwrap().1)
            .collect();
        vals.iter().sum::<f64>() / vals.len() as f64
    }

    /// End-to-end virtual job time: encode + makespan + decode.
    pub fn total_secs(&self) -> f64 {
        self.encode_secs + self.makespan_secs + self.decode_secs
    }
}

/// Run one coded job in virtual time (see module docs).
pub fn simulate_job(
    plan: &FcdccPlan,
    x: &Tensor3,
    coded_filters: &[ResidentFilters],
    engine: &dyn TaskEngine,
    fates: &[WorkerFate],
) -> Result<SimJob> {
    let n = plan.spec().n;
    assert_eq!(fates.len(), n, "one fate per worker");
    assert_eq!(coded_filters.len(), n);

    let t0 = Instant::now();
    // The fused batch encoder (batch 1) — the same hot path the live
    // cluster's submit uses, so the measured encode cost is the real one.
    let coded_inputs = plan.encode_input_batch(&[x]);
    let payloads = plan.make_payloads(coded_inputs, coded_filters);
    let encode_secs = t0.elapsed().as_secs_f64();

    // Execute every live worker serially, in isolation.
    let mut per_worker: Vec<Option<(f64, f64)>> = Vec::with_capacity(n);
    let mut results = Vec::with_capacity(n);
    for (payload, fate) in payloads.iter().zip(fates) {
        match fate.delay() {
            None => {
                per_worker.push(None);
                results.push(None);
            }
            Some(d) => {
                let t = Instant::now();
                let r = engine.run(payload)?;
                per_worker.push(Some((d.as_secs_f64(), t.elapsed().as_secs_f64())));
                results.push(Some(r));
            }
        }
    }

    // The δ earliest finishers in virtual time are the survivors.
    let delta = plan.delta();
    let mut finishers: Vec<(f64, usize)> = per_worker
        .iter()
        .enumerate()
        .filter_map(|(i, pw)| pw.map(|(d, c)| (d + c, i)))
        .collect();
    if finishers.len() < delta {
        bail!(
            "only {} workers finished, need delta={delta}",
            finishers.len()
        );
    }
    finishers.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let survivors: Vec<usize> = finishers[..delta].iter().map(|&(_, i)| i).collect();
    let makespan_secs = finishers[delta - 1].0;

    let t2 = Instant::now();
    let chosen: Vec<&crate::fcdcc::WorkerResult> = survivors
        .iter()
        .map(|&i| results[i].as_ref().unwrap())
        .collect();
    let output = plan.decode_refs(&chosen)?;
    let decode_secs = t2.elapsed().as_secs_f64();

    // Benches loop simulate_job over many trials: recycling the coded
    // slabs and blocks keeps those loops allocation-free after the
    // first trial, exactly like the live cluster runtime.
    drop(chosen);
    for r in results.into_iter().flatten() {
        r.recycle();
    }
    for p in payloads {
        p.recycle();
    }

    Ok(SimJob {
        encode_secs,
        per_worker,
        survivors,
        makespan_secs,
        decode_secs,
        output,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::straggler::StragglerModel;
    use crate::engine::Im2colEngine;
    use crate::model::ConvLayer;
    use crate::tensor::{conv2d, Tensor4};
    use crate::util::{mse, rng::Rng};
    use std::time::Duration;

    #[test]
    fn virtual_makespan_respects_gamma() {
        let layer = ConvLayer::new("t", 2, 12, 10, 8, 3, 3, 1, 0);
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 5).unwrap(); // delta=2, gamma=3
        let mut rng = Rng::new(7);
        let x = Tensor3::random(2, 12, 10, &mut rng);
        let k = Tensor4::random(8, 2, 3, 3, &mut rng);
        let cf = plan.encode_filters(&k);
        let want = conv2d(&x, &k, layer.params());
        let delay = Duration::from_millis(500);

        // 3 stragglers (= gamma): makespan must NOT include the delay.
        let fates = StragglerModel::FixedCount { count: 3, delay }.draw(5, &mut rng);
        let job = simulate_job(&plan, &x, &cf, &Im2colEngine, &fates).unwrap();
        assert!(job.makespan_secs < 0.4, "makespan {}", job.makespan_secs);
        assert!(mse(&job.output.data, &want.data) < 1e-18);

        // 4 stragglers (> gamma): the delay is unavoidable.
        let fates = StragglerModel::FixedCount { count: 4, delay }.draw(5, &mut rng);
        let job = simulate_job(&plan, &x, &cf, &Im2colEngine, &fates).unwrap();
        assert!(job.makespan_secs >= 0.5, "makespan {}", job.makespan_secs);
    }

    #[test]
    fn too_many_failures_is_error() {
        let layer = ConvLayer::new("t", 2, 12, 10, 8, 3, 3, 1, 0);
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap(); // delta=2
        let mut rng = Rng::new(8);
        let x = Tensor3::random(2, 12, 10, &mut rng);
        let k = Tensor4::random(8, 2, 3, 3, &mut rng);
        let cf = plan.encode_filters(&k);
        let fates = StragglerModel::Failures { count: 3 }.draw(4, &mut rng);
        assert!(simulate_job(&plan, &x, &cf, &Im2colEngine, &fates).is_err());
    }
}
