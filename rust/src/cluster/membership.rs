//! Coordinator-side membership state machine (DESIGN.md §Transport &
//! membership).
//!
//! Pure and deterministic: no sockets, no threads, no clocks of its
//! own. The TCP supervisor feeds it events (`on_announce`, `on_pong`,
//! `on_conn_lost`) and polls `tick(now)` for the actions to take
//! (pings to send, slots to evict), passing every timestamp in — which
//! makes the whole admission / heartbeat / eviction protocol testable
//! with synthetic time, exactly like the fault plan and health tracker.
//!
//! Per slot the machine is a three-state automaton:
//!
//! ```text
//!            Announce → Accept{session = epoch++}
//!   Joining ───────────────────────────────────────▶ Live
//!      ▲                                              │
//!      │  re-dial + Announce (readmission,            │ miss_threshold
//!      │  epoch++, readmissions++)                    │ heartbeats missed,
//!      │                                              │ or socket error
//!      │                                              ▼ (epoch++, evictions++)
//!      └─────────────────────────────────────────── Down
//! ```
//!
//! The **epoch** bumps on every membership change (admit, evict,
//! readmit). Sessions are epoch values at accept time, so they are
//! unique and monotone — a reply stamped with a session older than the
//! slot's current one is from before a reconnect and must be recycled,
//! never decoded.

use std::time::{Duration, Instant};

use crate::metrics::MembershipCounters;

/// Heartbeat cadence and tolerance.
#[derive(Clone, Copy, Debug)]
pub struct MembershipConfig {
    /// Interval between coordinator-initiated pings.
    pub heartbeat: Duration,
    /// Consecutive missed beats before a Live slot is evicted.
    pub miss_threshold: u32,
}

impl Default for MembershipConfig {
    fn default() -> Self {
        MembershipConfig {
            heartbeat: Duration::from_millis(200),
            miss_threshold: 3,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum SlotState {
    /// Never admitted, or between eviction and readmission.
    Joining,
    Live,
    /// Evicted; a successful re-announce moves it back to Live.
    Down,
}

#[derive(Clone, Copy, Debug)]
struct Slot {
    state: SlotState,
    /// Session epoch granted at the most recent accept.
    session: u64,
    /// Last pong (or accept) time; meaningless unless Live.
    last_pong: Instant,
    /// Consecutive heartbeat intervals with no pong.
    missed: u32,
    /// Whether this slot has ever been Live (readmission vs admission).
    ever_live: bool,
}

/// Outcome of a worker's rendezvous announce.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Admitted into the slot under this session epoch.
    Accept { session: u64 },
    /// Slot not admissible right now; retry after this many ms.
    Later { retry_ms: u64 },
}

/// Actions `tick` tells the supervisor to take.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TickActions {
    /// Send a heartbeat ping to each of these slots.
    pub pings: Vec<usize>,
    /// These slots crossed the missed-beat threshold: evict them
    /// (close the socket, emit PeerDown).
    pub evict: Vec<usize>,
}

pub struct Membership {
    cfg: MembershipConfig,
    slots: Vec<Slot>,
    epoch: u64,
    last_ping: Instant,
    counters: MembershipCounters,
}

impl Membership {
    pub fn new(n: usize, cfg: MembershipConfig, now: Instant) -> Membership {
        Membership {
            cfg,
            slots: vec![
                Slot {
                    state: SlotState::Joining,
                    session: 0,
                    last_pong: now,
                    missed: 0,
                    ever_live: false,
                };
                n
            ],
            epoch: 0,
            last_ping: now,
            counters: MembershipCounters::default(),
        }
    }

    pub fn n(&self) -> usize {
        self.slots.len()
    }

    /// Current membership epoch (bumped on admit / evict / readmit).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn counters(&self) -> MembershipCounters {
        let mut c = self.counters;
        c.epoch = self.epoch;
        c
    }

    /// The slot's current session epoch (replies stamped with an older
    /// session are stale). Returns `None` unless the slot is Live.
    pub fn session(&self, slot: usize) -> Option<u64> {
        let s = &self.slots[slot];
        (s.state == SlotState::Live).then_some(s.session)
    }

    pub fn is_live(&self, slot: usize) -> bool {
        self.slots[slot].state == SlotState::Live
    }

    /// Indices of all Live slots.
    pub fn live(&self) -> Vec<usize> {
        (0..self.slots.len())
            .filter(|&i| self.is_live(i))
            .collect()
    }

    /// A worker dialed in and announced itself for `slot`. Returns
    /// whether it was admitted (`Accept` carries the session epoch the
    /// worker must stamp its replies with) and whether this was a
    /// readmission of a previously-evicted worker.
    pub fn on_announce(&mut self, slot: usize, now: Instant) -> Admission {
        let readmit = {
            let s = &self.slots[slot];
            match s.state {
                // Defensive: a Live slot already has a connection — a
                // second announce is a duplicate dial, told to retry
                // after one heartbeat (by then the stale connection
                // has been noticed and torn down).
                SlotState::Live => {
                    return Admission::Later {
                        retry_ms: self.cfg.heartbeat.as_millis() as u64,
                    }
                }
                SlotState::Down => true,
                SlotState::Joining => self.slots[slot].ever_live,
            }
        };
        self.epoch += 1;
        if readmit {
            self.counters.readmissions += 1;
        }
        let s = &mut self.slots[slot];
        s.state = SlotState::Live;
        s.session = self.epoch;
        s.last_pong = now; // admission grace: a fresh peer owes no pong yet
        s.missed = 0;
        s.ever_live = true;
        Admission::Accept { session: self.epoch }
    }

    /// Heartbeat answer from a Live slot.
    pub fn on_pong(&mut self, slot: usize, now: Instant) {
        let s = &mut self.slots[slot];
        if s.state == SlotState::Live {
            s.last_pong = now;
            s.missed = 0;
        }
    }

    /// The slot's connection died (EOF, write error, corrupt frame).
    /// Returns true if this was a Live→Down transition — the caller
    /// emits exactly one PeerDown per true return, so racing reader
    /// and supervisor threads cannot double-evict.
    pub fn on_conn_lost(&mut self, slot: usize) -> bool {
        let s = &mut self.slots[slot];
        if s.state != SlotState::Live {
            return false;
        }
        s.state = SlotState::Down;
        self.epoch += 1;
        self.counters.evictions += 1;
        true
    }

    /// Advance the protocol to `now`: decide which slots to ping and
    /// which have missed enough beats to evict. Eviction here marks
    /// the slot Down (epoch bump + counter) — the caller still closes
    /// the socket and emits PeerDown for each returned index.
    pub fn tick(&mut self, now: Instant) -> TickActions {
        let mut actions = TickActions::default();
        let due = now.duration_since(self.last_ping) >= self.cfg.heartbeat;
        if due {
            self.last_ping = now;
        }
        for i in 0..self.slots.len() {
            if self.slots[i].state != SlotState::Live {
                continue;
            }
            // Count whole heartbeat intervals elapsed since the last
            // pong beyond those already charged.
            let silent = now.duration_since(self.slots[i].last_pong);
            let owed = (silent.as_nanos() / self.cfg.heartbeat.as_nanos().max(1)) as u32;
            if owed > self.slots[i].missed {
                self.counters.heartbeats_missed += u64::from(owed - self.slots[i].missed);
                self.slots[i].missed = owed;
            }
            if self.slots[i].missed >= self.cfg.miss_threshold {
                self.slots[i].state = SlotState::Down;
                self.epoch += 1;
                self.counters.evictions += 1;
                actions.evict.push(i);
            } else if due {
                self.counters.heartbeats_sent += 1;
                actions.pings.push(i);
            }
        }
        actions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn at(base: Instant, ms: u64) -> Instant {
        base + Duration::from_millis(ms)
    }

    fn cfg() -> MembershipConfig {
        MembershipConfig {
            heartbeat: Duration::from_millis(100),
            miss_threshold: 3,
        }
    }

    #[test]
    fn admission_grants_monotone_sessions_and_bumps_epoch() {
        let base = Instant::now();
        let mut m = Membership::new(3, cfg(), base);
        assert_eq!(m.epoch(), 0);
        assert!(m.live().is_empty());
        let mut sessions = Vec::new();
        for i in 0..3 {
            match m.on_announce(i, base) {
                Admission::Accept { session } => sessions.push(session),
                other => panic!("expected accept, got {other:?}"),
            }
        }
        assert_eq!(sessions, vec![1, 2, 3]);
        assert_eq!(m.epoch(), 3, "epoch = n after initial admission");
        assert_eq!(m.live(), vec![0, 1, 2]);
        assert_eq!(m.counters().readmissions, 0, "first admits are not readmits");
    }

    #[test]
    fn duplicate_announce_on_a_live_slot_gets_later() {
        let base = Instant::now();
        let mut m = Membership::new(1, cfg(), base);
        m.on_announce(0, base);
        assert_eq!(
            m.on_announce(0, at(base, 10)),
            Admission::Later { retry_ms: 100 }
        );
        assert_eq!(m.epoch(), 1, "a rejected announce must not move the epoch");
    }

    #[test]
    fn missed_beats_accumulate_and_cross_the_threshold() {
        let base = Instant::now();
        let mut m = Membership::new(2, cfg(), base);
        m.on_announce(0, base);
        m.on_announce(1, base);
        // Worker 1 pongs on every beat; worker 0 goes silent.
        for beat in 1..=2u64 {
            let t = at(base, beat * 100);
            let a = m.tick(t);
            assert!(a.evict.is_empty(), "no eviction before the threshold");
            assert!(a.pings.contains(&0) && a.pings.contains(&1));
            m.on_pong(1, t);
        }
        // Third silent interval crosses miss_threshold = 3.
        let a = m.tick(at(base, 300));
        assert_eq!(a.evict, vec![0]);
        assert!(a.pings.contains(&1), "survivor still gets pinged");
        assert_eq!(m.live(), vec![1]);
        assert_eq!(m.epoch(), 3, "2 admits + 1 eviction");
        let c = m.counters();
        assert_eq!(c.evictions, 1);
        assert!(c.heartbeats_missed >= 3);
        assert!(c.heartbeats_sent >= 5, "2 slots x 2 beats + survivor");
        assert_eq!(c.epoch, 3);
    }

    #[test]
    fn pongs_keep_a_slot_alive_indefinitely() {
        let base = Instant::now();
        let mut m = Membership::new(1, cfg(), base);
        m.on_announce(0, base);
        for beat in 1..50u64 {
            let t = at(base, beat * 100);
            let a = m.tick(t);
            assert!(a.evict.is_empty(), "ponging slot evicted at beat {beat}");
            m.on_pong(0, t);
        }
        assert_eq!(m.counters().heartbeats_missed, 0);
    }

    #[test]
    fn conn_lost_evicts_once_and_readmission_grants_a_fresh_session() {
        let base = Instant::now();
        let mut m = Membership::new(2, cfg(), base);
        m.on_announce(0, base);
        m.on_announce(1, base);
        let old = m.session(0).unwrap();
        assert!(m.on_conn_lost(0), "live slot loses its connection");
        assert!(!m.on_conn_lost(0), "second report must be a no-op");
        assert_eq!(m.live(), vec![1]);
        assert_eq!(m.session(0), None);
        // Worker re-dials: readmitted under a strictly newer session.
        let Admission::Accept { session } = m.on_announce(0, at(base, 500)) else {
            panic!("readmission expected");
        };
        assert!(session > old, "sessions are monotone across reconnects");
        let c = m.counters();
        assert_eq!((c.evictions, c.readmissions), (1, 1));
        assert_eq!(m.epoch(), 4, "2 admits + evict + readmit");
        // The readmitted slot starts with admission grace, not instant
        // eviction from its pre-eviction silence.
        let a = m.tick(at(base, 550));
        assert!(a.evict.is_empty());
    }

    #[test]
    fn eviction_timing_is_within_one_beat_past_the_threshold() {
        // The acceptance bar: eviction must land within one heartbeat
        // interval of the threshold being crossed.
        let base = Instant::now();
        let mut m = Membership::new(1, cfg(), base);
        m.on_announce(0, base);
        // Just under the threshold: 3 beats = 300ms.
        assert!(m.tick(at(base, 299)).evict.is_empty());
        assert_eq!(m.tick(at(base, 300)).evict, vec![0]);
    }
}
