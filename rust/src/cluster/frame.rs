//! Length-prefixed binary frame codec for the TCP transport — the wire
//! format `WorkerMsg`/`WorkerReply` travel over between a coordinator
//! and remote worker processes (DESIGN.md §Transport & membership).
//!
//! No serde: every payload is explicit little-endian encode/decode over
//! `std::io`. Each frame is
//!
//! ```text
//! [magic u32 LE]["FCDC"] [version u8] [tag u8] [reserved u16 = 0]
//! [len u32 LE] [payload: len bytes] [checksum u64 LE]
//! ```
//!
//! where the checksum is FNV-1a over `(version, tag, reserved, len,
//! payload)` — the whole frame minus the magic and the checksum itself —
//! so any bit flip in transit (header or body) is caught at the frame
//! layer before a byte of payload is interpreted. `read_frame`
//! distinguishes a **clean EOF** (the peer closed between frames:
//! [`ReadOutcome::Eof`], normal connection teardown) from a mid-frame
//! truncation (an error: the peer died with a frame on the wire).
//! Oversized length prefixes are rejected against [`MAX_FRAME`] before
//! any allocation, so a corrupted header cannot OOM the reader.
//!
//! Decode errors are always **clean**: tensor slab buffers drawn from
//! the arena while decoding a task or reply are returned to it before
//! the error surfaces, so a poisoned frame costs the peer a strike —
//! never a panic, a partial slab, or a leaked buffer.

use crate::cluster::straggler::WorkerFate;
use crate::cluster::worker::{ReplyBody, WorkerReply};
use crate::fcdcc::{SlabArena, WorkerPayload, WorkerResult};
use crate::tensor::{ConvParams, Tensor3, Tensor4};
use anyhow::{bail, ensure, Context, Result};
use std::io::{Read, Write};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Frame magic: ASCII "FCDC", little-endian.
pub const MAGIC: u32 = u32::from_le_bytes(*b"FCDC");
/// Wire-protocol version; bumped on any incompatible layout change.
pub const VERSION: u8 = 1;
/// Hard cap on a frame's payload length. A corrupted length prefix is
/// rejected against this before any buffer is allocated.
pub const MAX_FRAME: usize = 1 << 28; // 256 MiB

const HEADER_LEN: usize = 12;

/// What a frame carries — the message kinds of the coordinator/worker
/// duplex plus the membership handshake and heartbeats.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameTag {
    /// Worker → coordinator: rendezvous (capacity + engine name).
    Announce = 1,
    /// Coordinator → worker: admitted (slot + session epoch).
    Accept = 2,
    /// Coordinator → worker: not now; retry after the carried delay.
    Later = 3,
    /// Coordinator → worker: heartbeat probe.
    Ping = 4,
    /// Worker → coordinator: heartbeat answer.
    Pong = 5,
    /// Coordinator → worker: one coded subtask (`WorkerMsg::Task`).
    Task = 6,
    /// Coordinator → worker: `WorkerMsg::Cancel`.
    Cancel = 7,
    /// Coordinator → worker: `WorkerMsg::CancelUpTo`.
    CancelUpTo = 8,
    /// Coordinator → worker: `WorkerMsg::Shutdown`.
    Shutdown = 9,
    /// Worker → coordinator: one `WorkerReply`.
    Reply = 10,
    /// Client → frontend: one inference request (id, deadline, input).
    Request = 11,
    /// Frontend → client: the request's logits.
    Response = 12,
    /// Frontend → client: shed at admission — the bounded queue is full.
    Busy = 13,
    /// Frontend → client: the request's deadline expired before service.
    DeadlineExceeded = 14,
}

impl FrameTag {
    pub fn from_u8(v: u8) -> Option<FrameTag> {
        Some(match v {
            1 => FrameTag::Announce,
            2 => FrameTag::Accept,
            3 => FrameTag::Later,
            4 => FrameTag::Ping,
            5 => FrameTag::Pong,
            6 => FrameTag::Task,
            7 => FrameTag::Cancel,
            8 => FrameTag::CancelUpTo,
            9 => FrameTag::Shutdown,
            10 => FrameTag::Reply,
            11 => FrameTag::Request,
            12 => FrameTag::Response,
            13 => FrameTag::Busy,
            14 => FrameTag::DeadlineExceeded,
            _ => return None,
        })
    }
}

/// One decoded frame: its tag and raw payload bytes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Frame {
    pub tag: FrameTag,
    pub payload: Vec<u8>,
}

/// How one `read_frame` call ended.
pub enum ReadOutcome {
    Frame(Frame),
    /// The peer closed the connection **between** frames — normal
    /// teardown, not an error.
    Eof,
}

/// Incremental FNV-1a (the same constants as the reply checksum).
#[derive(Clone, Copy)]
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= u64::from(b);
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    fn finish(self) -> u64 {
        self.0
    }
}

fn frame_checksum(tag: u8, payload: &[u8]) -> u64 {
    let mut h = Fnv::new();
    h.update(&[VERSION, tag, 0, 0]);
    h.update(&(payload.len() as u32).to_le_bytes());
    h.update(payload);
    h.finish()
}

/// Serialize one frame onto `w` (header + payload + checksum trailer).
pub fn write_frame(w: &mut impl Write, tag: FrameTag, payload: &[u8]) -> std::io::Result<()> {
    debug_assert!(payload.len() <= MAX_FRAME, "frame payload over MAX_FRAME");
    let mut header = [0u8; HEADER_LEN];
    header[0..4].copy_from_slice(&MAGIC.to_le_bytes());
    header[4] = VERSION;
    header[5] = tag as u8;
    // header[6..8] reserved, zero.
    header[8..12].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&header)?;
    w.write_all(payload)?;
    w.write_all(&frame_checksum(tag as u8, payload).to_le_bytes())?;
    w.flush()
}

fn read_full(r: &mut impl Read, buf: &mut [u8], what: &str) -> Result<()> {
    r.read_exact(buf)
        .with_context(|| format!("connection closed mid-frame ({what})"))
}

/// Read one frame off `r`, verifying magic, version, length cap, and
/// the trailing checksum. EOF **at a frame boundary** is reported as
/// [`ReadOutcome::Eof`]; every other irregularity is an error.
pub fn read_frame(r: &mut impl Read) -> Result<ReadOutcome> {
    let mut header = [0u8; HEADER_LEN];
    let mut got = 0usize;
    while got < HEADER_LEN {
        match r.read(&mut header[got..]) {
            Ok(0) if got == 0 => return Ok(ReadOutcome::Eof),
            Ok(0) => bail!("connection closed mid-header ({got}/{HEADER_LEN} bytes)"),
            Ok(k) => got += k,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e).context("reading frame header"),
        }
    }
    let magic = u32::from_le_bytes(header[0..4].try_into().expect("4 bytes"));
    ensure!(magic == MAGIC, "bad frame magic {magic:#010x}");
    ensure!(
        header[4] == VERSION,
        "frame version {} (this build speaks {VERSION})",
        header[4]
    );
    let Some(tag) = FrameTag::from_u8(header[5]) else {
        bail!("unknown frame tag {}", header[5]);
    };
    ensure!(
        header[6] == 0 && header[7] == 0,
        "nonzero reserved header bytes"
    );
    let len = u32::from_le_bytes(header[8..12].try_into().expect("4 bytes")) as usize;
    ensure!(len <= MAX_FRAME, "frame length {len} exceeds cap {MAX_FRAME}");
    let mut payload = vec![0u8; len];
    read_full(r, &mut payload, "payload")?;
    let mut trailer = [0u8; 8];
    read_full(r, &mut trailer, "checksum")?;
    let want = u64::from_le_bytes(trailer);
    let have = frame_checksum(tag as u8, &payload);
    ensure!(
        have == want,
        "frame checksum mismatch (tag {tag:?}, len {len})"
    );
    Ok(ReadOutcome::Frame(Frame { tag, payload }))
}

// ---------------------------------------------------------------------
// Payload byte writer / reader (explicit little-endian, bounds-checked).

pub fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_bits().to_le_bytes());
}

pub fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

/// Length-prefixed f64 slab: `u32` element count + raw LE bit patterns
/// (bit-exact round trip; NaN payloads included).
pub fn put_f64s(buf: &mut Vec<u8>, xs: &[f64]) {
    put_u32(buf, xs.len() as u32);
    for &x in xs {
        buf.extend_from_slice(&x.to_bits().to_le_bytes());
    }
}

/// Bounds-checked little-endian reader over one frame's payload. Every
/// accessor fails cleanly on truncation instead of panicking.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> ByteReader<'a> {
        ByteReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            n <= self.buf.len() - self.pos,
            "payload truncated: need {n} bytes at offset {}, have {}",
            self.pos,
            self.buf.len() - self.pos
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4")))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8")))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn str(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| anyhow::anyhow!("invalid UTF-8 string"))
    }

    /// Read a length-prefixed f64 slab into a buffer drawn from `arena`
    /// (zeroed by `take`, fully overwritten here). The element count is
    /// validated against the remaining payload **before** the arena
    /// buffer is taken, so a lying prefix never checks out a buffer.
    pub fn f64s(&mut self, arena: &SlabArena) -> Result<Vec<f64>> {
        let count = self.u32()? as usize;
        let bytes = self.take(count * 8)?;
        let mut out = arena.take(count);
        for (slot, chunk) in out.iter_mut().zip(bytes.chunks_exact(8)) {
            *slot = f64::from_bits(u64::from_le_bytes(chunk.try_into().expect("8")));
        }
        Ok(out)
    }

    /// Plain-`Vec` variant of [`ByteReader::f64s`] for the small
    /// client-facing payloads (request images, reply logits) that never
    /// touch the slab arena.
    pub fn f64s_vec(&mut self) -> Result<Vec<f64>> {
        let count = self.u32()? as usize;
        let bytes = self.take(count * 8)?;
        Ok(bytes
            .chunks_exact(8)
            .map(|ch| f64::from_bits(u64::from_le_bytes(ch.try_into().expect("8"))))
            .collect())
    }

    /// Every payload byte must be consumed — trailing garbage means the
    /// two sides disagree on the layout.
    pub fn done(&self) -> Result<()> {
        ensure!(
            self.pos == self.buf.len(),
            "{} unread bytes trail the payload",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Control messages.

/// Worker → coordinator rendezvous announcement.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Announce {
    /// Advertised compute capacity (threads).
    pub threads: u32,
    /// The engine the worker runs (`TaskEngine::name`).
    pub engine: String,
}

pub fn encode_announce(a: &Announce) -> Vec<u8> {
    let mut buf = Vec::with_capacity(16 + a.engine.len());
    put_u32(&mut buf, a.threads);
    put_str(&mut buf, &a.engine);
    buf
}

pub fn decode_announce(payload: &[u8]) -> Result<Announce> {
    let mut r = ByteReader::new(payload);
    let threads = r.u32()?;
    let engine = r.str()?;
    r.done()?;
    Ok(Announce { threads, engine })
}

/// Coordinator → worker admission: the slot the worker fills and the
/// membership session epoch its replies must be stamped with.
pub fn encode_accept(worker_id: usize, epoch: u64) -> Vec<u8> {
    let mut buf = Vec::with_capacity(12);
    put_u32(&mut buf, worker_id as u32);
    put_u64(&mut buf, epoch);
    buf
}

pub fn decode_accept(payload: &[u8]) -> Result<(usize, u64)> {
    let mut r = ByteReader::new(payload);
    let worker_id = r.u32()? as usize;
    let epoch = r.u64()?;
    r.done()?;
    Ok((worker_id, epoch))
}

pub fn encode_later(retry_ms: u64) -> Vec<u8> {
    let mut buf = Vec::with_capacity(8);
    put_u64(&mut buf, retry_ms);
    buf
}

pub fn decode_later(payload: &[u8]) -> Result<u64> {
    let mut r = ByteReader::new(payload);
    let retry_ms = r.u64()?;
    r.done()?;
    Ok(retry_ms)
}

/// Ping/Pong/Cancel/CancelUpTo all carry one u64 (heartbeat sequence
/// number, or job id / watermark).
pub fn encode_u64(v: u64) -> Vec<u8> {
    let mut buf = Vec::with_capacity(8);
    put_u64(&mut buf, v);
    buf
}

pub fn decode_u64(payload: &[u8]) -> Result<u64> {
    let mut r = ByteReader::new(payload);
    let v = r.u64()?;
    r.done()?;
    Ok(v)
}

// ---------------------------------------------------------------------
// Task frames.

const FATE_PROMPT: u8 = 0;
const FATE_DELAYED: u8 = 1;
const FATE_FAILED: u8 = 2;
const FATE_ERROR: u8 = 3;
const FATE_CORRUPT: u8 = 4;

fn put_fate(buf: &mut Vec<u8>, fate: WorkerFate) {
    match fate {
        WorkerFate::Prompt => buf.push(FATE_PROMPT),
        WorkerFate::Delayed(d) => {
            buf.push(FATE_DELAYED);
            put_u64(buf, d.as_nanos() as u64);
        }
        WorkerFate::Failed => buf.push(FATE_FAILED),
        WorkerFate::ErrorReply => buf.push(FATE_ERROR),
        WorkerFate::CorruptReply => buf.push(FATE_CORRUPT),
    }
}

fn read_fate(r: &mut ByteReader<'_>) -> Result<WorkerFate> {
    Ok(match r.u8()? {
        FATE_PROMPT => WorkerFate::Prompt,
        FATE_DELAYED => WorkerFate::Delayed(Duration::from_nanos(r.u64()?)),
        FATE_FAILED => WorkerFate::Failed,
        FATE_ERROR => WorkerFate::ErrorReply,
        FATE_CORRUPT => WorkerFate::CorruptReply,
        other => bail!("unknown fate tag {other}"),
    })
}

fn put_tensor3(buf: &mut Vec<u8>, t: &Tensor3) {
    put_u32(buf, t.c as u32);
    put_u32(buf, t.h as u32);
    put_u32(buf, t.w as u32);
    put_f64s(buf, &t.data);
}

fn read_tensor3(r: &mut ByteReader<'_>, arena: &SlabArena) -> Result<Tensor3> {
    let (c, h, w) = (r.u32()? as usize, r.u32()? as usize, r.u32()? as usize);
    let data = r.f64s(arena)?;
    if data.len() != c * h * w {
        // Return the mis-sized buffer before surfacing the error: no
        // partial slab may leak out of a poisoned frame.
        arena.put(data);
        bail!("tensor3 slab carries {c}x{h}x{w} shape with the wrong element count");
    }
    Ok(Tensor3::from_vec(c, h, w, data))
}

fn put_tensor4(buf: &mut Vec<u8>, t: &Tensor4) {
    put_u32(buf, t.n as u32);
    put_u32(buf, t.c as u32);
    put_u32(buf, t.kh as u32);
    put_u32(buf, t.kw as u32);
    put_f64s(buf, &t.data);
}

fn read_tensor4(r: &mut ByteReader<'_>) -> Result<Tensor4> {
    let (n, c, kh, kw) = (
        r.u32()? as usize,
        r.u32()? as usize,
        r.u32()? as usize,
        r.u32()? as usize,
    );
    let count = r.u32()? as usize;
    ensure!(
        count == n * c * kh * kw,
        "tensor4 slab carries {n}x{c}x{kh}x{kw} shape with {count} elements"
    );
    let bytes = r.take(count * 8)?;
    let data: Vec<f64> = bytes
        .chunks_exact(8)
        .map(|ch| f64::from_bits(u64::from_le_bytes(ch.try_into().expect("8"))))
        .collect();
    Ok(Tensor4::from_vec(n, c, kh, kw, data))
}

/// Serialize one `WorkerMsg::Task` as a [`FrameTag::Task`] payload. The
/// payload's prepacked GEMM operands are **not** shipped — the remote
/// worker re-derives nothing and runs the per-call packing path, which
/// is bit-identical to contracting resident panels.
pub fn encode_task(job_id: u64, fate: WorkerFate, payload: &WorkerPayload) -> Vec<u8> {
    let mut buf = Vec::with_capacity(64 + 8 * payload.upload_entries());
    put_u64(&mut buf, job_id);
    put_fate(&mut buf, fate);
    put_u32(&mut buf, payload.worker_id as u32);
    put_u32(&mut buf, payload.batch as u32);
    put_u32(&mut buf, payload.conv.stride as u32);
    put_u32(&mut buf, payload.conv.pad as u32);
    put_u32(&mut buf, payload.filters.len() as u32);
    for kb in payload.filters.iter() {
        put_tensor4(&mut buf, kb);
    }
    put_u32(&mut buf, payload.inputs.len() as u32);
    for xa in &payload.inputs {
        put_tensor3(&mut buf, xa);
    }
    buf
}

/// Decode a [`FrameTag::Task`] payload against the **receiving side's**
/// arena (input slab buffers are drawn from it and return to it on
/// `WorkerPayload::recycle`). On any decode error every already-taken
/// slab is recycled before the error surfaces.
pub fn decode_task(
    payload: &[u8],
    arena: &Arc<SlabArena>,
) -> Result<(u64, WorkerFate, WorkerPayload)> {
    let mut inputs: Vec<Tensor3> = Vec::new();
    match decode_task_inner(payload, arena, &mut inputs) {
        Ok(v) => Ok(v),
        Err(e) => {
            for t in inputs {
                arena.put(t.data);
            }
            Err(e)
        }
    }
}

fn decode_task_inner(
    payload: &[u8],
    arena: &Arc<SlabArena>,
    inputs: &mut Vec<Tensor3>,
) -> Result<(u64, WorkerFate, WorkerPayload)> {
    let mut r = ByteReader::new(payload);
    let job_id = r.u64()?;
    let fate = read_fate(&mut r)?;
    let worker_id = r.u32()? as usize;
    let batch = r.u32()? as usize;
    let conv = ConvParams::new(r.u32()?.max(1) as usize, r.u32()? as usize);
    let n_filters = r.u32()? as usize;
    ensure!(n_filters <= payload.len(), "absurd filter count {n_filters}");
    let mut filters = Vec::with_capacity(n_filters);
    for _ in 0..n_filters {
        filters.push(read_tensor4(&mut r)?);
    }
    let n_inputs = r.u32()? as usize;
    ensure!(n_inputs <= payload.len(), "absurd input count {n_inputs}");
    ensure!(
        batch > 0 && n_inputs % batch == 0,
        "input count {n_inputs} not divisible by batch {batch}"
    );
    for _ in 0..n_inputs {
        inputs.push(read_tensor3(&mut r, arena)?);
    }
    r.done()?;
    let inputs = std::mem::take(inputs);
    Ok((
        job_id,
        fate,
        WorkerPayload {
            worker_id,
            inputs,
            batch,
            filters: Arc::new(filters),
            packs: None,
            conv,
            arena: Arc::clone(arena),
        },
    ))
}

// ---------------------------------------------------------------------
// Reply frames.

const BODY_ERR: u8 = 0;
const BODY_OK: u8 = 1;

/// Serialize one `WorkerReply` as a [`FrameTag::Reply`] payload,
/// stamped with the session `epoch` the worker was accepted under (the
/// coordinator recycles — never decodes — replies from a stale epoch).
pub fn encode_reply(reply: &WorkerReply, epoch: u64) -> Vec<u8> {
    let mut buf = Vec::with_capacity(64);
    put_u64(&mut buf, reply.job_id);
    put_u32(&mut buf, reply.worker_id as u32);
    put_u64(&mut buf, epoch);
    put_f64(&mut buf, reply.compute_secs);
    put_f64(&mut buf, reply.delay_secs);
    match &reply.body {
        ReplyBody::Err(msg) => {
            buf.push(BODY_ERR);
            put_str(&mut buf, msg);
        }
        ReplyBody::Ok { result, checksum } => {
            buf.push(BODY_OK);
            put_u64(&mut buf, *checksum);
            put_u32(&mut buf, result.worker_id as u32);
            put_u32(&mut buf, result.batch as u32);
            put_u32(&mut buf, result.blocks.len() as u32);
            for blk in &result.blocks {
                put_tensor3(&mut buf, blk);
            }
        }
    }
    buf
}

/// Decode a [`FrameTag::Reply`] payload against the coordinator's plan
/// arena; returns the reply plus the epoch it was stamped with.
/// `sent_at` is stamped at decode time (the wire does not carry
/// `Instant`s), which is within one socket hop of the true send time.
/// On any decode error every already-taken block buffer is recycled.
pub fn decode_reply(payload: &[u8], arena: &Arc<SlabArena>) -> Result<(WorkerReply, u64)> {
    let mut blocks: Vec<Tensor3> = Vec::new();
    match decode_reply_inner(payload, arena, &mut blocks) {
        Ok(v) => Ok(v),
        Err(e) => {
            for t in blocks {
                arena.put(t.data);
            }
            Err(e)
        }
    }
}

fn decode_reply_inner(
    payload: &[u8],
    arena: &Arc<SlabArena>,
    blocks: &mut Vec<Tensor3>,
) -> Result<(WorkerReply, u64)> {
    let mut r = ByteReader::new(payload);
    let job_id = r.u64()?;
    let worker_id = r.u32()? as usize;
    let epoch = r.u64()?;
    let compute_secs = r.f64()?;
    let delay_secs = r.f64()?;
    let body = match r.u8()? {
        BODY_ERR => {
            let msg = r.str()?;
            r.done()?;
            ReplyBody::Err(msg)
        }
        BODY_OK => {
            let checksum = r.u64()?;
            let coded_id = r.u32()? as usize;
            let batch = r.u32()? as usize;
            let n_blocks = r.u32()? as usize;
            ensure!(n_blocks <= payload.len(), "absurd block count {n_blocks}");
            ensure!(
                batch > 0 && n_blocks % batch == 0,
                "block count {n_blocks} not divisible by batch {batch}"
            );
            for _ in 0..n_blocks {
                blocks.push(read_tensor3(&mut r, arena)?);
            }
            r.done()?;
            ReplyBody::Ok {
                result: WorkerResult {
                    worker_id: coded_id,
                    batch,
                    blocks: std::mem::take(blocks),
                    arena: Arc::clone(arena),
                },
                checksum,
            }
        }
        other => bail!("unknown reply body tag {other}"),
    };
    Ok((
        WorkerReply {
            job_id,
            worker_id,
            body,
            compute_secs,
            delay_secs,
            sent_at: Instant::now(),
        },
        epoch,
    ))
}

// ---------------------------------------------------------------------
// Client-facing serving frames (the `--role frontend` request path).
//
// These payloads are tiny (one input image / ten logits) and cross the
// trust boundary to arbitrary clients, so they deliberately use plain
// `Vec` buffers instead of the coordinator's slab arena: a malformed
// client frame can never check a slab out of the hot-path pool.

/// Serialize one client request as a [`FrameTag::Request`] payload:
/// client-chosen id, deadline in milliseconds (0 = use the server's
/// default), and the input tensor.
pub fn encode_request(client_id: u64, deadline_ms: u64, x: &Tensor3) -> Vec<u8> {
    let mut buf = Vec::with_capacity(36 + 8 * x.data.len());
    put_u64(&mut buf, client_id);
    put_u64(&mut buf, deadline_ms);
    put_tensor3(&mut buf, x);
    buf
}

/// Decode a [`FrameTag::Request`] payload into (client id, deadline ms,
/// input). The tensor lands in a plain `Vec` — never the arena.
pub fn decode_request(payload: &[u8]) -> Result<(u64, u64, Tensor3)> {
    let mut r = ByteReader::new(payload);
    let client_id = r.u64()?;
    let deadline_ms = r.u64()?;
    let (c, h, w) = (r.u32()? as usize, r.u32()? as usize, r.u32()? as usize);
    let data = r.f64s_vec()?;
    ensure!(
        data.len() == c * h * w,
        "request tensor carries {c}x{h}x{w} shape with {} elements",
        data.len()
    );
    r.done()?;
    Ok((client_id, deadline_ms, Tensor3::from_vec(c, h, w, data)))
}

/// Serialize one [`FrameTag::Response`] payload: the request's client
/// id and its logits.
pub fn encode_response(client_id: u64, logits: &[f64]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(12 + 8 * logits.len());
    put_u64(&mut buf, client_id);
    put_f64s(&mut buf, logits);
    buf
}

/// Decode a [`FrameTag::Response`] payload into (client id, logits).
pub fn decode_response(payload: &[u8]) -> Result<(u64, Vec<f64>)> {
    let mut r = ByteReader::new(payload);
    let client_id = r.u64()?;
    let logits = r.f64s_vec()?;
    r.done()?;
    Ok((client_id, logits))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::worker::result_checksum;
    use crate::util::rng::Rng;

    fn roundtrip(tag: FrameTag, payload: &[u8]) -> Vec<u8> {
        let mut wire = Vec::new();
        write_frame(&mut wire, tag, payload).unwrap();
        wire
    }

    fn read_one(wire: &[u8]) -> Result<ReadOutcome> {
        let mut cursor = wire;
        read_frame(&mut cursor)
    }

    #[test]
    fn frame_roundtrip_and_clean_eof() {
        let wire = roundtrip(FrameTag::Ping, &encode_u64(42));
        let mut cursor = &wire[..];
        let ReadOutcome::Frame(f) = read_frame(&mut cursor).unwrap() else {
            panic!("expected a frame");
        };
        assert_eq!(f.tag, FrameTag::Ping);
        assert_eq!(decode_u64(&f.payload).unwrap(), 42);
        // The stream is now exactly at a frame boundary: clean EOF.
        assert!(matches!(read_frame(&mut cursor).unwrap(), ReadOutcome::Eof));
    }

    #[test]
    fn truncation_at_every_boundary_is_a_clean_error() {
        let wire = roundtrip(FrameTag::Task, b"some payload bytes");
        // Cut the wire at every possible length except 0 (clean EOF)
        // and full (valid frame): header-truncated, payload-truncated,
        // and checksum-truncated prefixes must all error — never panic,
        // never return a frame.
        for cut in 1..wire.len() {
            let err = read_one(&wire[..cut]);
            assert!(err.is_err(), "cut at {cut} bytes decoded a frame");
        }
        assert!(matches!(read_one(&wire).unwrap(), ReadOutcome::Frame(_)));
    }

    #[test]
    fn any_single_bit_flip_is_rejected() {
        let wire = roundtrip(FrameTag::Reply, b"payload under test");
        let mut rng = Rng::new(2026);
        // Every header/trailer byte plus a sample of payload bytes.
        for trial in 0..wire.len().min(64) {
            let byte = if trial < HEADER_LEN + 8 {
                trial
            } else {
                rng.below(wire.len())
            };
            let mut flipped = wire.clone();
            flipped[byte] ^= 1 << rng.below(8);
            if flipped == wire {
                continue;
            }
            assert!(
                read_one(&flipped).is_err(),
                "bit flip in byte {byte} went undetected"
            );
        }
    }

    #[test]
    fn oversized_length_is_rejected_before_allocating() {
        let mut wire = roundtrip(FrameTag::Task, b"x");
        // Forge a length prefix far over the cap; the reader must
        // reject it from the header alone (a buffer that size would
        // OOM the test if it tried).
        wire[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = read_one(&wire).unwrap_err();
        assert!(err.to_string().contains("exceeds cap"), "err: {err:#}");
    }

    #[test]
    fn bad_magic_version_and_tag_are_rejected() {
        let wire = roundtrip(FrameTag::Ping, &encode_u64(1));
        let mut bad = wire.clone();
        bad[0] ^= 0xFF;
        assert!(read_one(&bad).unwrap_err().to_string().contains("magic"));
        let mut bad = wire.clone();
        bad[4] = VERSION + 1;
        assert!(read_one(&bad).unwrap_err().to_string().contains("version"));
        let mut bad = wire;
        bad[5] = 200;
        assert!(read_one(&bad).is_err());
    }

    #[test]
    fn control_payloads_roundtrip() {
        let a = Announce {
            threads: 8,
            engine: "im2col".to_string(),
        };
        assert_eq!(decode_announce(&encode_announce(&a)).unwrap(), a);
        assert_eq!(decode_accept(&encode_accept(3, 17)).unwrap(), (3, 17));
        assert_eq!(decode_later(&encode_later(250)).unwrap(), 250);
        assert_eq!(decode_u64(&encode_u64(u64::MAX)).unwrap(), u64::MAX);
        // Trailing garbage is rejected (layout disagreement).
        let mut long = encode_u64(5);
        long.push(0);
        assert!(decode_u64(&long).is_err());
    }

    #[test]
    fn task_roundtrips_over_random_payload_shapes() {
        let mut rng = Rng::new(99);
        let arena = Arc::new(SlabArena::new(64));
        for trial in 0..12 {
            let batch = 1 + rng.below(3);
            let ell_a = 1 + rng.below(3);
            let ell_b = 1 + rng.below(3);
            let (c, h, w) = (1 + rng.below(3), 2 + rng.below(5), 2 + rng.below(5));
            let (kn, kh, kw) = (1 + rng.below(4), 1 + rng.below(2), 1 + rng.below(2));
            let inputs: Vec<Tensor3> = (0..batch * ell_a)
                .map(|_| Tensor3::random(c, h, w, &mut rng))
                .collect();
            let filters: Vec<Tensor4> = (0..ell_b)
                .map(|_| Tensor4::random(kn, c, kh, kw, &mut rng))
                .collect();
            let payload = WorkerPayload {
                worker_id: trial,
                inputs,
                batch,
                filters: Arc::new(filters),
                packs: None,
                conv: ConvParams::new(1, 0),
                arena: Arc::clone(&arena),
            };
            let fate = match trial % 5 {
                0 => WorkerFate::Prompt,
                1 => WorkerFate::Delayed(Duration::from_millis(7)),
                2 => WorkerFate::Failed,
                3 => WorkerFate::ErrorReply,
                _ => WorkerFate::CorruptReply,
            };
            let bytes = encode_task(trial as u64, fate, &payload);
            let (job_id, got_fate, got) = decode_task(&bytes, &arena).unwrap();
            assert_eq!(job_id, trial as u64);
            assert_eq!(got_fate, fate);
            assert_eq!(got.worker_id, payload.worker_id);
            assert_eq!(got.batch, payload.batch);
            assert_eq!(got.conv, payload.conv);
            assert_eq!(got.filters.len(), payload.filters.len());
            for (a, b) in got.filters.iter().zip(payload.filters.iter()) {
                assert_eq!((a.n, a.c, a.kh, a.kw), (b.n, b.c, b.kh, b.kw));
                assert_eq!(a.data, b.data, "filter slab must round-trip bit-exactly");
            }
            assert_eq!(got.inputs.len(), payload.inputs.len());
            for (a, b) in got.inputs.iter().zip(payload.inputs.iter()) {
                assert_eq!((a.c, a.h, a.w), (b.c, b.h, b.w));
                assert_eq!(a.data, b.data, "input slab must round-trip bit-exactly");
            }
            assert!(got.packs.is_none(), "packs never travel the wire");
            got.recycle();
            payload.recycle();
        }
        assert_eq!(arena.outstanding(), 0, "decode must balance the arena");
    }

    #[test]
    fn reply_roundtrips_and_checksum_survives_the_wire() {
        let mut rng = Rng::new(7);
        let arena = Arc::new(SlabArena::new(32));
        let blocks: Vec<Tensor3> = (0..4).map(|_| Tensor3::random(2, 3, 3, &mut rng)).collect();
        let result = WorkerResult {
            worker_id: 2,
            batch: 2,
            blocks,
            arena: Arc::clone(&arena),
        };
        let checksum = result_checksum(&result);
        let reply = WorkerReply {
            job_id: 9,
            worker_id: 1,
            body: ReplyBody::Ok { result, checksum },
            compute_secs: 0.25,
            delay_secs: 0.5,
            sent_at: Instant::now(),
        };
        let bytes = encode_reply(&reply, 11);
        let (got, epoch) = decode_reply(&bytes, &arena).unwrap();
        assert_eq!(epoch, 11);
        assert_eq!(got.job_id, 9);
        assert_eq!(got.worker_id, 1);
        assert_eq!(got.compute_secs, 0.25);
        assert_eq!(got.delay_secs, 0.5);
        let ReplyBody::Ok { result, checksum: c } = &got.body else {
            panic!("ok body expected");
        };
        assert_eq!(*c, checksum);
        assert_eq!(
            result_checksum(result),
            checksum,
            "blocks must survive the wire bit-exactly"
        );
        got.body.recycle();
        reply.body.recycle();

        // Error bodies round-trip too.
        let err_reply = WorkerReply {
            job_id: 10,
            worker_id: 3,
            body: ReplyBody::Err("engine panic: boom".to_string()),
            compute_secs: 0.0,
            delay_secs: 0.0,
            sent_at: Instant::now(),
        };
        let bytes = encode_reply(&err_reply, 12);
        let (got, epoch) = decode_reply(&bytes, &arena).unwrap();
        assert_eq!(epoch, 12);
        assert!(matches!(&got.body, ReplyBody::Err(m) if m.contains("boom")));
        assert_eq!(arena.outstanding(), 0);
    }

    #[test]
    fn client_request_and_response_roundtrip() {
        let mut rng = Rng::new(21);
        let x = Tensor3::random(1, 32, 32, &mut rng);
        let (id, ms, got) = decode_request(&encode_request(77, 250, &x)).unwrap();
        assert_eq!((id, ms), (77, 250));
        assert_eq!((got.c, got.h, got.w), (1, 32, 32));
        assert_eq!(got.data, x.data, "input must round-trip bit-exactly");

        let logits = vec![0.5, -1.25, f64::MIN_POSITIVE, 3e300];
        let (id, got) = decode_response(&encode_response(9, &logits)).unwrap();
        assert_eq!(id, 9);
        assert_eq!(got, logits, "logits must round-trip bit-exactly");

        // A shape lie inside an otherwise-intact request is rejected.
        let mut bad = encode_request(1, 0, &x);
        bad[16..20].copy_from_slice(&2u32.to_le_bytes()); // claim c=2
        assert!(decode_request(&bad).is_err());
        // Truncations fail cleanly at every prefix.
        let wire = encode_request(1, 0, &x);
        for cut in 0..wire.len() {
            assert!(decode_request(&wire[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn corrupt_task_payload_never_leaks_a_slab() {
        let mut rng = Rng::new(3);
        let arena = Arc::new(SlabArena::new(32));
        let payload = WorkerPayload {
            worker_id: 0,
            inputs: (0..4).map(|_| Tensor3::random(2, 4, 4, &mut rng)).collect(),
            batch: 2,
            filters: Arc::new(vec![Tensor4::random(2, 2, 2, 2, &mut rng)]),
            packs: None,
            conv: ConvParams::new(1, 0),
            arena: Arc::clone(&arena),
        };
        let bytes = encode_task(1, WorkerFate::Prompt, &payload);
        payload.recycle();
        let baseline = arena.outstanding();
        // Truncate the payload at every prefix: each must fail cleanly
        // with the arena balanced (taken slabs recycled on error).
        for cut in 0..bytes.len() {
            assert!(decode_task(&bytes[..cut], &arena).is_err());
            assert_eq!(arena.outstanding(), baseline, "leak at cut {cut}");
        }
        // And a shape/count lie inside an otherwise-intact payload.
        let (job_id, fate, ok) = decode_task(&bytes, &arena).unwrap();
        assert_eq!((job_id, fate), (1, WorkerFate::Prompt));
        ok.recycle();
        assert_eq!(arena.outstanding(), baseline);
    }
}
