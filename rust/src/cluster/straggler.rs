//! Straggler injection — the paper simulates stragglers with `sleep()`
//! and randomized worker availability (§VI-A); this module reproduces
//! that, plus exponential-latency and hard-failure models from the CDC
//! literature.

use crate::util::rng::Rng;
use std::time::Duration;

/// What happens to a worker on a given job.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum WorkerFate {
    /// Responds after `delay` of artificial extra latency.
    Delayed(Duration),
    /// Responds immediately (no injected latency).
    Prompt,
    /// Never responds (crash / upload failure / download failure).
    Failed,
}

/// Straggler model applied per (job, worker) pair.
#[derive(Clone, Debug)]
pub enum StragglerModel {
    /// No stragglers at all.
    None,
    /// A fixed set of workers is delayed by a fixed amount (the paper's
    /// Experiment 4: `count` stragglers with 1s/2s sleeps).
    FixedCount { count: usize, delay: Duration },
    /// Each worker independently straggles with probability `p`
    /// (the paper's `random.random()` availability), delayed by `delay`.
    Bernoulli { p: f64, delay: Duration },
    /// Exponentially-distributed extra latency with the given mean —
    /// the classical CDC latency model.
    Exponential { mean: Duration },
    /// A fixed set of workers fails outright.
    Failures { count: usize },
}

impl StragglerModel {
    /// Draw the fate of every worker for one job. Which workers straggle
    /// is itself random (drawn from `rng`), matching the paper's setup.
    pub fn draw(&self, n: usize, rng: &mut Rng) -> Vec<WorkerFate> {
        match self {
            StragglerModel::None => vec![WorkerFate::Prompt; n],
            StragglerModel::FixedCount { count, delay } => {
                let mut fates = vec![WorkerFate::Prompt; n];
                for &i in rng.choose_indices(n, (*count).min(n)).iter() {
                    fates[i] = WorkerFate::Delayed(*delay);
                }
                fates
            }
            StragglerModel::Bernoulli { p, delay } => (0..n)
                .map(|_| {
                    if rng.chance(*p) {
                        WorkerFate::Delayed(*delay)
                    } else {
                        WorkerFate::Prompt
                    }
                })
                .collect(),
            StragglerModel::Exponential { mean } => (0..n)
                .map(|_| {
                    let d = rng.exponential(1.0 / mean.as_secs_f64());
                    WorkerFate::Delayed(Duration::from_secs_f64(d))
                })
                .collect(),
            StragglerModel::Failures { count } => {
                let mut fates = vec![WorkerFate::Prompt; n];
                for &i in rng.choose_indices(n, (*count).min(n)).iter() {
                    fates[i] = WorkerFate::Failed;
                }
                fates
            }
        }
    }
}

impl WorkerFate {
    pub fn delay(&self) -> Option<Duration> {
        match self {
            WorkerFate::Prompt => Some(Duration::ZERO),
            WorkerFate::Delayed(d) => Some(*d),
            WorkerFate::Failed => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_all_prompt() {
        let mut rng = Rng::new(1);
        let fates = StragglerModel::None.draw(5, &mut rng);
        assert!(fates.iter().all(|f| *f == WorkerFate::Prompt));
    }

    #[test]
    fn fixed_count_delays_exactly_k() {
        let mut rng = Rng::new(2);
        let m = StragglerModel::FixedCount {
            count: 3,
            delay: Duration::from_millis(10),
        };
        let fates = m.draw(8, &mut rng);
        let delayed = fates
            .iter()
            .filter(|f| matches!(f, WorkerFate::Delayed(_)))
            .count();
        assert_eq!(delayed, 3);
    }

    #[test]
    fn failures_never_respond() {
        let mut rng = Rng::new(3);
        let m = StragglerModel::Failures { count: 2 };
        let fates = m.draw(6, &mut rng);
        assert_eq!(fates.iter().filter(|f| **f == WorkerFate::Failed).count(), 2);
        assert!(fates.iter().any(|f| f.delay().is_none()));
    }

    #[test]
    fn bernoulli_rate_roughly_holds() {
        let mut rng = Rng::new(4);
        let m = StragglerModel::Bernoulli {
            p: 0.3,
            delay: Duration::from_millis(1),
        };
        let mut total = 0usize;
        for _ in 0..200 {
            total += m
                .draw(10, &mut rng)
                .iter()
                .filter(|f| matches!(f, WorkerFate::Delayed(_)))
                .count();
        }
        let rate = total as f64 / 2000.0;
        assert!((rate - 0.3).abs() < 0.05, "rate={rate}");
    }
}
