//! Straggler and fault injection — the paper simulates stragglers with
//! `sleep()` and randomized worker availability (§VI-A); this module
//! reproduces that, plus exponential-latency and hard-failure models
//! from the CDC literature.
//!
//! Two layers of injection compose here:
//!
//! * [`StragglerModel`] draws a fresh, memoryless fate vector **per
//!   job** — the paper's per-round availability model.
//! * [`FaultPlan`] overlays **persistent per-worker fault states** on
//!   top of those draws: a crashed worker stays crashed across jobs
//!   (optionally restarting after a fixed number of dispatches), an
//!   erroring worker answers with explicit failures, a corrupting
//!   worker perturbs its reply blocks (caught by the master's reply
//!   checksum), a slow worker adds fixed latency to every task. The
//!   plan is deterministic: fault activation is keyed by the per-worker
//!   dispatch count, never by wall clock or a shared RNG, so the same
//!   plan replayed over the same job sequence yields the same fates.
//!   `FaultPlan::chaos` derives a randomized single-worker plan from a
//!   seed (`FCDCC_CHAOS_SEED` in the CI chaos leg).

use crate::util::rng::{Rng, SplitMix64};
use std::time::Duration;

/// What happens to a worker on a given job.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum WorkerFate {
    /// Responds after `delay` of artificial extra latency.
    Delayed(Duration),
    /// Responds immediately (no injected latency).
    Prompt,
    /// Never responds (crash / upload failure / download failure).
    Failed,
    /// Responds immediately with an **explicit error** instead of a
    /// result — the "worker process alive, compute broken" failure mode
    /// (injected, or the real fate of an engine error / panic).
    ErrorReply,
    /// Computes honestly, then its reply blocks are perturbed in
    /// transit. The worker checksums the blocks *before* the
    /// perturbation, so the master's integrity check rejects the reply.
    CorruptReply,
}

impl WorkerFate {
    /// Injected latency before the worker acts, or `None` when it never
    /// replies at all. Error replies are immediate but carry no result,
    /// so for makespan purposes (`cluster::sim`) they count as failures.
    pub fn delay(&self) -> Option<Duration> {
        match self {
            WorkerFate::Prompt | WorkerFate::CorruptReply => Some(Duration::ZERO),
            WorkerFate::Delayed(d) => Some(*d),
            WorkerFate::Failed | WorkerFate::ErrorReply => None,
        }
    }
}

/// Straggler model applied per (job, worker) pair.
#[derive(Clone, Debug)]
pub enum StragglerModel {
    /// No stragglers at all.
    None,
    /// A fixed set of workers is delayed by a fixed amount (the paper's
    /// Experiment 4: `count` stragglers with 1s/2s sleeps).
    FixedCount { count: usize, delay: Duration },
    /// Each worker independently straggles with probability `p`
    /// (the paper's `random.random()` availability), delayed by `delay`.
    Bernoulli { p: f64, delay: Duration },
    /// Exponentially-distributed extra latency with the given mean —
    /// the classical CDC latency model.
    Exponential { mean: Duration },
    /// A fixed set of workers fails outright.
    Failures { count: usize },
}

impl StragglerModel {
    /// Draw the fate of every worker for one job. Which workers straggle
    /// is itself random (drawn from `rng`), matching the paper's setup.
    pub fn draw(&self, n: usize, rng: &mut Rng) -> Vec<WorkerFate> {
        match self {
            StragglerModel::None => vec![WorkerFate::Prompt; n],
            StragglerModel::FixedCount { count, delay } => {
                let mut fates = vec![WorkerFate::Prompt; n];
                for &i in rng.choose_indices(n, (*count).min(n)).iter() {
                    fates[i] = WorkerFate::Delayed(*delay);
                }
                fates
            }
            StragglerModel::Bernoulli { p, delay } => (0..n)
                .map(|_| {
                    if rng.chance(*p) {
                        WorkerFate::Delayed(*delay)
                    } else {
                        WorkerFate::Prompt
                    }
                })
                .collect(),
            StragglerModel::Exponential { mean } => (0..n)
                .map(|_| {
                    let d = rng.exponential(1.0 / mean.as_secs_f64());
                    WorkerFate::Delayed(Duration::from_secs_f64(d))
                })
                .collect(),
            StragglerModel::Failures { count } => {
                let mut fates = vec![WorkerFate::Prompt; n];
                for &i in rng.choose_indices(n, (*count).min(n)).iter() {
                    fates[i] = WorkerFate::Failed;
                }
                fates
            }
        }
    }
}

/// A persistent per-worker fault. Activation is keyed by `t`, the
/// number of tasks previously dispatched to that worker — job counts,
/// not wall clock, so the same plan over the same job sequence is
/// exactly reproducible.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// Dead (never replies) from its `after`-th task on. With
    /// `restart_after = Some(r)` the worker "restarts" and is healthy
    /// again once `r` tasks have been dispatched at it while down.
    Crash {
        after: u64,
        restart_after: Option<u64>,
    },
    /// Answers its first `jobs` tasks with an explicit error reply,
    /// healthy afterwards (`u64::MAX` = errors forever).
    ErrorReply { jobs: u64 },
    /// Perturbs the reply blocks of its first `jobs` tasks (caught by
    /// the master's checksum), honest afterwards.
    CorruptReply { jobs: u64 },
    /// Fixed extra latency on **every** task — a deterministic pin for
    /// tests that need a reproducible first-δ reply subset.
    Slow { delay: Duration },
}

impl FaultKind {
    /// The fate this fault forces on the worker's `t`-th task (0-based),
    /// or `None` when the fault is not active for that task.
    fn fate_at(&self, t: u64) -> Option<WorkerFate> {
        match *self {
            FaultKind::Crash {
                after,
                restart_after,
            } => {
                let down = t >= after
                    && match restart_after {
                        Some(r) => t < after.saturating_add(r),
                        None => true,
                    };
                down.then_some(WorkerFate::Failed)
            }
            FaultKind::ErrorReply { jobs } => (t < jobs).then_some(WorkerFate::ErrorReply),
            FaultKind::CorruptReply { jobs } => (t < jobs).then_some(WorkerFate::CorruptReply),
            FaultKind::Slow { delay } => Some(WorkerFate::Delayed(delay)),
        }
    }
}

/// Deterministic, seeded fault-injection plan: persistent per-worker
/// [`FaultKind`]s overlaid on the per-job [`StragglerModel`] draws at
/// dispatch time. Owned by the `Cluster`; `--fault-*` CLI flags and
/// `FCDCC_CHAOS_SEED` build one.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// (physical worker id, fault) pairs; at most one fault per worker
    /// applies (first match wins).
    faults: Vec<(usize, FaultKind)>,
    /// Per-worker dispatch counters, grown on demand.
    tasks_seen: Vec<u64>,
}

impl FaultPlan {
    /// A plan with no faults (the default).
    pub fn none() -> Self {
        Self::default()
    }

    /// Attach a persistent fault to a physical worker id (builder).
    pub fn with_fault(mut self, worker: usize, kind: FaultKind) -> Self {
        self.faults.push((worker, kind));
        self
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Derive a randomized single-worker fault plan from a seed: the
    /// victim and the fault kind (transient crash / error burst /
    /// corrupt burst / slow) are both seed-determined. Every kind it
    /// can produce is absorbable by a cluster with γ ≥ 1, so chaos
    /// tests can assert full completion for *any* seed.
    pub fn chaos(n: usize, seed: u64) -> Self {
        let mut s = SplitMix64::new(seed);
        let worker = (s.next_u64() % n.max(1) as u64) as usize;
        let kind = match s.next_u64() % 4 {
            0 => FaultKind::Crash {
                after: 0,
                restart_after: Some(2 + s.next_u64() % 3),
            },
            1 => FaultKind::ErrorReply {
                jobs: 1 + s.next_u64() % 3,
            },
            2 => FaultKind::CorruptReply {
                jobs: 1 + s.next_u64() % 3,
            },
            _ => FaultKind::Slow {
                delay: Duration::from_millis(5 + s.next_u64() % 20),
            },
        };
        Self::none().with_fault(worker, kind)
    }

    /// The chaos seed from `FCDCC_CHAOS_SEED`, if set and parseable.
    pub fn chaos_seed_from_env() -> Option<u64> {
        std::env::var("FCDCC_CHAOS_SEED").ok()?.trim().parse().ok()
    }

    /// The fate of one task dispatched at physical worker `worker`,
    /// given the straggler model already drew `base` for it. Advances
    /// the worker's dispatch counter. An active fault overrides the
    /// draw, except `Slow`, which combines with an existing delay by
    /// taking the larger of the two.
    pub fn fate_for_dispatch(&mut self, worker: usize, base: WorkerFate) -> WorkerFate {
        if worker >= self.tasks_seen.len() {
            self.tasks_seen.resize(worker + 1, 0);
        }
        let t = self.tasks_seen[worker];
        self.tasks_seen[worker] += 1;
        let Some((_, kind)) = self.faults.iter().find(|(w, _)| *w == worker) else {
            return base;
        };
        match kind.fate_at(t) {
            Some(WorkerFate::Delayed(d)) => match base {
                WorkerFate::Delayed(d0) => WorkerFate::Delayed(d0.max(d)),
                WorkerFate::Failed => WorkerFate::Failed,
                _ => WorkerFate::Delayed(d),
            },
            Some(forced) => forced,
            None => base,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_all_prompt() {
        let mut rng = Rng::new(1);
        let fates = StragglerModel::None.draw(5, &mut rng);
        assert!(fates.iter().all(|f| *f == WorkerFate::Prompt));
    }

    #[test]
    fn fixed_count_delays_exactly_k() {
        let mut rng = Rng::new(2);
        let m = StragglerModel::FixedCount {
            count: 3,
            delay: Duration::from_millis(10),
        };
        let fates = m.draw(8, &mut rng);
        let delayed = fates
            .iter()
            .filter(|f| matches!(f, WorkerFate::Delayed(_)))
            .count();
        assert_eq!(delayed, 3);
    }

    #[test]
    fn failures_never_respond() {
        let mut rng = Rng::new(3);
        let m = StragglerModel::Failures { count: 2 };
        let fates = m.draw(6, &mut rng);
        assert_eq!(fates.iter().filter(|f| **f == WorkerFate::Failed).count(), 2);
        assert!(fates.iter().any(|f| f.delay().is_none()));
    }

    #[test]
    fn bernoulli_rate_roughly_holds() {
        let mut rng = Rng::new(4);
        let m = StragglerModel::Bernoulli {
            p: 0.3,
            delay: Duration::from_millis(1),
        };
        let mut total = 0usize;
        for _ in 0..200 {
            total += m
                .draw(10, &mut rng)
                .iter()
                .filter(|f| matches!(f, WorkerFate::Delayed(_)))
                .count();
        }
        let rate = total as f64 / 2000.0;
        assert!((rate - 0.3).abs() < 0.05, "rate={rate}");
    }

    #[test]
    fn error_and_corrupt_fates_have_expected_delays() {
        assert_eq!(WorkerFate::ErrorReply.delay(), None);
        assert_eq!(WorkerFate::CorruptReply.delay(), Some(Duration::ZERO));
    }

    #[test]
    fn crash_with_restart_counts_dispatches() {
        let mut fp = FaultPlan::none().with_fault(
            1,
            FaultKind::Crash {
                after: 1,
                restart_after: Some(2),
            },
        );
        // Worker 1: healthy, down, down, healthy again.
        assert_eq!(fp.fate_for_dispatch(1, WorkerFate::Prompt), WorkerFate::Prompt);
        assert_eq!(fp.fate_for_dispatch(1, WorkerFate::Prompt), WorkerFate::Failed);
        assert_eq!(fp.fate_for_dispatch(1, WorkerFate::Prompt), WorkerFate::Failed);
        assert_eq!(fp.fate_for_dispatch(1, WorkerFate::Prompt), WorkerFate::Prompt);
        // Other workers are never touched.
        assert_eq!(fp.fate_for_dispatch(0, WorkerFate::Prompt), WorkerFate::Prompt);
    }

    #[test]
    fn error_burst_is_bounded_and_crash_forever_is_not() {
        let mut fp = FaultPlan::none()
            .with_fault(0, FaultKind::ErrorReply { jobs: 2 })
            .with_fault(
                2,
                FaultKind::Crash {
                    after: 0,
                    restart_after: None,
                },
            );
        assert_eq!(fp.fate_for_dispatch(0, WorkerFate::Prompt), WorkerFate::ErrorReply);
        assert_eq!(fp.fate_for_dispatch(0, WorkerFate::Prompt), WorkerFate::ErrorReply);
        assert_eq!(fp.fate_for_dispatch(0, WorkerFate::Prompt), WorkerFate::Prompt);
        for _ in 0..10 {
            assert_eq!(fp.fate_for_dispatch(2, WorkerFate::Prompt), WorkerFate::Failed);
        }
    }

    #[test]
    fn slow_fault_combines_with_drawn_delay() {
        let slow = Duration::from_millis(50);
        let mut fp = FaultPlan::none().with_fault(0, FaultKind::Slow { delay: slow });
        assert_eq!(
            fp.fate_for_dispatch(0, WorkerFate::Prompt),
            WorkerFate::Delayed(slow)
        );
        assert_eq!(
            fp.fate_for_dispatch(0, WorkerFate::Delayed(Duration::from_millis(200))),
            WorkerFate::Delayed(Duration::from_millis(200)),
            "the larger of the two delays wins"
        );
        assert_eq!(
            fp.fate_for_dispatch(0, WorkerFate::Failed),
            WorkerFate::Failed,
            "a drawn hard failure is not resurrected by a slow fault"
        );
    }

    #[test]
    fn chaos_plans_are_seed_deterministic() {
        let a = FaultPlan::chaos(4, 2024);
        let b = FaultPlan::chaos(4, 2024);
        assert_eq!(a.faults, b.faults);
        assert_eq!(a.faults.len(), 1);
        assert!(a.faults[0].0 < 4);
        // Different seeds eventually pick different faults.
        let any_different = (0..16).any(|s| FaultPlan::chaos(4, s).faults != a.faults);
        assert!(any_different);
    }
}
