//! Real TCP transport: remote worker processes over the frame codec
//! (`cluster::frame`), governed by the membership state machine
//! (`cluster::membership`) — DESIGN.md §Transport & membership.
//!
//! **Roles.** A *worker node* ([`spawn_worker_node`], or `--role worker`
//! on the CLI) listens on an address and serves one coordinator
//! connection at a time: it announces itself on accept, runs the exact
//! same [`worker_loop`] as the in-process pool behind the socket, and
//! goes back to accepting when the connection ends — reconnection is
//! just the next accept. The *coordinator* side ([`TcpTransport`],
//! `--role coordinator --workers <addrs>`) dials every worker address,
//! performs the rendezvous handshake (Announce → Accept/Later), sends
//! periodic heartbeat pings, and turns missed-beat thresholds and
//! socket errors into [`TransportEvent::PeerDown`] — which the master
//! converts into health quarantine and fast job failure, and the
//! serving layer into (n, k) re-planning onto the live set. A
//! supervisor thread keeps re-dialing down peers with exponential
//! backoff; a successful re-dial readmits the worker under a **fresh
//! session epoch**, and replies stamped with a stale session are
//! recycled, never decoded.
//!
//! **Fault injection over the wire.** Dispatch fates travel inside task
//! frames. Four of the five act exactly as on the channel transport
//! (the compute side is the shared [`worker_loop`]); `Failed` — the
//! crash fate — is acted out by the *node*, which drops the connection
//! instead of silently eating the task. Over TCP a crash is a dead
//! socket, so the same seeded fault plans that drive the chaos tests
//! drive real membership churn: crash → evict → re-dial → readmit.

use crate::cluster::frame::{self, Frame, FrameTag, ReadOutcome};
use crate::cluster::membership::{Admission, Membership, MembershipConfig};
use crate::cluster::straggler::WorkerFate;
use crate::cluster::transport::{Transport, TransportEvent};
use crate::cluster::worker::{worker_loop, WorkerMsg, WorkerReply};
use crate::engine::TaskEngine;
use crate::fcdcc::SlabArena;
use crate::metrics::MembershipCounters;
use anyhow::{bail, Context, Result};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

// =====================================================================
// Worker node (the listening side).

/// Configuration of one worker-node process/thread.
pub struct WorkerNodeConfig {
    /// Listen address, e.g. `127.0.0.1:0` (0 = ephemeral port).
    pub listen: String,
    /// The conv engine tasks run on.
    pub engine: Arc<dyn TaskEngine>,
    /// Advertised compute capacity (informational, sent in Announce).
    pub threads: usize,
}

struct NodeShared {
    stop: AtomicBool,
    /// Tasks decoded off the wire (tests use this to time a mid-batch
    /// kill).
    tasks_seen: AtomicU64,
    /// Write half of the active connection, if any — `kill` shuts it
    /// down to break a blocked reader.
    conn: Mutex<Option<TcpStream>>,
}

/// Handle to a spawned worker node.
pub struct WorkerNodeHandle {
    addr: SocketAddr,
    shared: Arc<NodeShared>,
    thread: JoinHandle<()>,
}

impl WorkerNodeHandle {
    /// The bound listen address (resolves the ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Tasks this node has decoded off the wire so far.
    pub fn tasks_seen(&self) -> u64 {
        self.shared.tasks_seen.load(Ordering::SeqCst)
    }

    /// Kill the node hard: tear down the active connection (the
    /// coordinator sees a dead socket, not a goodbye) and stop the
    /// accept loop. Blocks until the node thread exits.
    pub fn kill(self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(conn) = self.shared.conn.lock().expect("node conn lock").take() {
            let _ = conn.shutdown(Shutdown::Both);
        }
        // Unblock a listener parked in accept().
        let _ = TcpStream::connect(self.addr);
        let _ = self.thread.join();
    }

    /// Block until the node exits on its own (a coordinator Shutdown
    /// frame stops it gracefully).
    pub fn wait(self) {
        let _ = self.thread.join();
    }
}

/// Bind `cfg.listen` and serve coordinator connections on a background
/// thread until killed or told to shut down.
pub fn spawn_worker_node(cfg: WorkerNodeConfig) -> Result<WorkerNodeHandle> {
    let listener = TcpListener::bind(&cfg.listen)
        .with_context(|| format!("worker node: bind {}", cfg.listen))?;
    let addr = listener.local_addr().context("worker node: local_addr")?;
    let shared = Arc::new(NodeShared {
        stop: AtomicBool::new(false),
        tasks_seen: AtomicU64::new(0),
        conn: Mutex::new(None),
    });
    let node = Arc::clone(&shared);
    let thread = std::thread::Builder::new()
        .name(format!("fcdcc-node-{addr}"))
        .spawn(move || {
            // One worker-local arena shared across connections: task
            // input slabs and result blocks live here, so the node's
            // buffer hygiene mirrors the coordinator's.
            let arena = Arc::new(SlabArena::new(64));
            while !node.stop.load(Ordering::SeqCst) {
                let Ok((stream, _)) = listener.accept() else {
                    break;
                };
                if node.stop.load(Ordering::SeqCst) {
                    break; // the kill() wake-up connection
                }
                serve_connection(stream, &node, &cfg, &arena);
            }
        })
        .expect("spawn worker node");
    Ok(WorkerNodeHandle {
        addr,
        shared,
        thread,
    })
}

/// Serve one coordinator connection: announce, await admission, then
/// bridge frames ↔ the in-process [`worker_loop`] until the connection
/// dies or a Shutdown frame arrives.
fn serve_connection(
    stream: TcpStream,
    node: &Arc<NodeShared>,
    cfg: &WorkerNodeConfig,
    arena: &Arc<SlabArena>,
) {
    let _ = stream.set_nodelay(true);
    let Ok(write_half) = stream.try_clone() else {
        return;
    };
    // All frame writes (pongs from the reader, replies from the
    // forwarder) serialize on this mutex — whole frames only, so two
    // writers can never interleave mid-frame. Heartbeat pongs go out
    // directly from the reader and never queue behind a large reply.
    let writer = Arc::new(Mutex::new(write_half));
    {
        let mut conn = node.conn.lock().expect("node conn lock");
        if let Ok(c) = stream.try_clone() {
            *conn = Some(c);
        }
    }

    let session = match handshake_as_worker(&stream, &writer, cfg) {
        Ok(Some(session)) => session,
        // Later, or a handshake error: drop the connection and let the
        // coordinator re-dial.
        Ok(None) | Err(_) => {
            node.conn.lock().expect("node conn lock").take();
            return;
        }
    };

    // The compute side is the exact in-process worker loop, bridged by
    // two local channels: frames in → task_tx, reply_rx → frames out.
    let (task_tx, task_rx) = channel::<WorkerMsg>();
    let (reply_tx, reply_rx) = channel::<WorkerReply>();
    let engine = Arc::clone(&cfg.engine);
    // The wire worker id is per-connection (the Accept frame names the
    // slot); replies carry it so the coordinator routes by physical id.
    let slot = session.worker_id;
    let compute = std::thread::Builder::new()
        .name(format!("fcdcc-node-compute-{slot}"))
        .spawn(move || worker_loop(slot, engine, task_rx, reply_tx))
        .expect("spawn node compute");
    let forwarder = {
        let writer = Arc::clone(&writer);
        let epoch = session.epoch;
        std::thread::Builder::new()
            .name(format!("fcdcc-node-fwd-{slot}"))
            .spawn(move || {
                let mut wire_dead = false;
                for reply in reply_rx {
                    if !wire_dead {
                        let bytes = frame::encode_reply(&reply, epoch);
                        let mut w = writer.lock().expect("node writer lock");
                        if frame::write_frame(&mut *w, FrameTag::Reply, &bytes).is_err() {
                            // Keep draining (and recycling) so the
                            // compute loop never blocks on a dead wire.
                            let _ = w.shutdown(Shutdown::Both);
                            wire_dead = true;
                        }
                    }
                    reply.body.recycle();
                }
            })
            .expect("spawn node forwarder")
    };

    // Reader: runs inline on this connection's thread.
    let mut read_half = &stream;
    loop {
        let frame = match frame::read_frame(&mut read_half) {
            Ok(ReadOutcome::Frame(f)) => f,
            Ok(ReadOutcome::Eof) | Err(_) => break,
        };
        match frame.tag {
            FrameTag::Ping => {
                let Ok(seq) = frame::decode_u64(&frame.payload) else {
                    break;
                };
                let mut w = writer.lock().expect("node writer lock");
                if frame::write_frame(&mut *w, FrameTag::Pong, &frame::encode_u64(seq)).is_err() {
                    break;
                }
            }
            FrameTag::Task => {
                let Ok((job_id, fate, payload)) = frame::decode_task(&frame.payload, arena) else {
                    break;
                };
                node.tasks_seen.fetch_add(1, Ordering::SeqCst);
                if fate == WorkerFate::Failed {
                    // The crash fate, acted out for real: drop the
                    // connection. The coordinator sees a dead socket
                    // and runs the full evict → re-dial → readmit arc.
                    payload.recycle();
                    break;
                }
                if task_tx
                    .send(WorkerMsg::Task {
                        job_id,
                        payload: Box::new(payload),
                        fate,
                    })
                    .is_err()
                {
                    break;
                }
            }
            FrameTag::Cancel => {
                let Ok(id) = frame::decode_u64(&frame.payload) else {
                    break;
                };
                if task_tx.send(WorkerMsg::Cancel(id)).is_err() {
                    break;
                }
            }
            FrameTag::CancelUpTo => {
                let Ok(mark) = frame::decode_u64(&frame.payload) else {
                    break;
                };
                if task_tx.send(WorkerMsg::CancelUpTo(mark)).is_err() {
                    break;
                }
            }
            FrameTag::Shutdown => {
                let _ = task_tx.send(WorkerMsg::Shutdown);
                node.stop.store(true, Ordering::SeqCst);
                break;
            }
            // Anything else is a protocol violation from the peer.
            _ => break,
        }
    }

    // Closing the task channel makes worker_loop drain (recycling every
    // queued payload) and exit; the forwarder exits when the last
    // reply sender drops.
    drop(task_tx);
    let _ = stream.shutdown(Shutdown::Both);
    let _ = compute.join();
    let _ = forwarder.join();
    node.conn.lock().expect("node conn lock").take();
}

struct WorkerSession {
    worker_id: usize,
    epoch: u64,
}

/// Announce, then await Accept (→ session) or Later (→ `None`).
fn handshake_as_worker(
    stream: &TcpStream,
    writer: &Arc<Mutex<TcpStream>>,
    cfg: &WorkerNodeConfig,
) -> Result<Option<WorkerSession>> {
    let announce = frame::encode_announce(&frame::Announce {
        threads: cfg.threads as u32,
        engine: cfg.engine.name().to_string(),
    });
    {
        let mut w = writer.lock().expect("node writer lock");
        frame::write_frame(&mut *w, FrameTag::Announce, &announce)?;
    }
    // Bound the wait for the admission verdict; a coordinator that
    // dialed and went silent must not wedge the accept loop.
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    let mut read_half = stream;
    let outcome = frame::read_frame(&mut read_half);
    stream.set_read_timeout(None)?;
    let ReadOutcome::Frame(f) = outcome? else {
        bail!("coordinator closed during handshake");
    };
    match f.tag {
        FrameTag::Accept => {
            let (worker_id, epoch) = frame::decode_accept(&f.payload)?;
            Ok(Some(WorkerSession { worker_id, epoch }))
        }
        FrameTag::Later => Ok(None),
        other => bail!("expected Accept/Later, got {other:?}"),
    }
}

// =====================================================================
// Coordinator transport (the dialing side).

/// Coordinator-side TCP configuration.
#[derive(Clone, Debug)]
pub struct TcpConfig {
    /// Worker node addresses; slot i ↔ `workers[i]`.
    pub workers: Vec<String>,
    /// Heartbeat ping cadence.
    pub heartbeat: Duration,
    /// Consecutive missed beats before eviction.
    pub miss_threshold: u32,
    /// Startup budget: all workers must rendezvous within this window.
    pub connect_timeout: Duration,
    /// Initial re-dial backoff for down peers (doubles, capped).
    pub reconnect_backoff: Duration,
}

impl TcpConfig {
    pub fn new(workers: Vec<String>) -> TcpConfig {
        TcpConfig {
            workers,
            heartbeat: Duration::from_millis(200),
            miss_threshold: 3,
            connect_timeout: Duration::from_secs(5),
            reconnect_backoff: Duration::from_millis(50),
        }
    }
}

struct Peer {
    addr: String,
    /// Write half of the live connection; `None` while down. Whole
    /// frames only under the lock, so dispatch and heartbeats never
    /// interleave mid-frame.
    writer: Mutex<Option<TcpStream>>,
    /// Whether this slot ever completed a handshake (distinguishes
    /// reconnects from first connects, and drives the startup give-up).
    ever_connected: AtomicBool,
    /// Whether a PeerDown was already emitted for a slot that never
    /// connected at all (give-up dedup).
    gave_up: AtomicBool,
}

struct TcpShared {
    peers: Vec<Peer>,
    membership: Mutex<Membership>,
    reconnects: AtomicU64,
    frames_corrupt: AtomicU64,
    stop: AtomicBool,
    arena: Arc<SlabArena>,
    events_tx: Sender<TransportEvent>,
}

impl TcpShared {
    /// Record a dead connection exactly once: whichever thread wins the
    /// Live→Down transition closes the socket and emits PeerDown.
    fn conn_lost(&self, slot: usize) {
        let lost = self
            .membership
            .lock()
            .expect("membership lock")
            .on_conn_lost(slot);
        if lost {
            if let Some(s) = self.peers[slot].writer.lock().expect("peer writer").take() {
                let _ = s.shutdown(Shutdown::Both);
            }
            let _ = self.events_tx.send(TransportEvent::PeerDown { worker: slot });
        }
    }
}

/// The coordinator's framed-TCP [`Transport`]: one writer mutex per
/// peer, one reader thread per live connection, and one supervisor
/// thread running dial/heartbeat/eviction.
pub struct TcpTransport {
    n: usize,
    shared: Arc<TcpShared>,
    events_rx: Receiver<TransportEvent>,
    supervisor: Option<JoinHandle<()>>,
}

impl TcpTransport {
    /// Dial every worker and block until all `n` are live (or the
    /// startup window closes — then bail, tearing everything down).
    /// `arena` is the plan arena reply blocks decode into.
    pub fn connect(cfg: TcpConfig, arena: Arc<SlabArena>) -> Result<TcpTransport> {
        let n = cfg.workers.len();
        if n == 0 {
            bail!("TcpTransport: no worker addresses");
        }
        let (events_tx, events_rx) = channel::<TransportEvent>();
        let shared = Arc::new(TcpShared {
            peers: cfg
                .workers
                .iter()
                .map(|a| Peer {
                    addr: a.clone(),
                    writer: Mutex::new(None),
                    ever_connected: AtomicBool::new(false),
                    gave_up: AtomicBool::new(false),
                })
                .collect(),
            membership: Mutex::new(Membership::new(
                n,
                MembershipConfig {
                    heartbeat: cfg.heartbeat,
                    miss_threshold: cfg.miss_threshold,
                },
                Instant::now(),
            )),
            reconnects: AtomicU64::new(0),
            frames_corrupt: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            arena,
            events_tx,
        });
        let supervisor = {
            let shared = Arc::clone(&shared);
            let cfg = cfg.clone();
            std::thread::Builder::new()
                .name("fcdcc-tcp-supervisor".to_string())
                .spawn(move || supervise(shared, cfg))
                .expect("spawn tcp supervisor")
        };
        let transport = TcpTransport {
            n,
            shared,
            events_rx,
            supervisor: Some(supervisor),
        };
        // Rendezvous barrier: every slot live before the first dispatch.
        let deadline = Instant::now() + cfg.connect_timeout;
        loop {
            let live = transport
                .shared
                .membership
                .lock()
                .expect("membership lock")
                .live()
                .len();
            if live == n {
                return Ok(transport);
            }
            if Instant::now() >= deadline {
                Box::new(transport).shutdown();
                bail!("TcpTransport: only {live}/{n} workers rendezvoused within the startup window");
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    fn send_frame(&self, worker: usize, tag: FrameTag, bytes: &[u8]) -> Result<()> {
        let mut guard = self.shared.peers[worker].writer.lock().expect("peer writer");
        let Some(stream) = guard.as_mut() else {
            bail!("worker {worker} is down");
        };
        if frame::write_frame(stream, tag, bytes).is_err() {
            let _ = stream.shutdown(Shutdown::Both);
            drop(guard);
            self.shared.conn_lost(worker);
            bail!("worker {worker}: write failed, peer marked down");
        }
        Ok(())
    }
}

impl Transport for TcpTransport {
    fn n(&self) -> usize {
        self.n
    }

    fn send(&mut self, worker: usize, msg: WorkerMsg) -> Result<()> {
        // Encode first, recycling a task's payload immediately — once
        // the bytes own the data, the arena ledger is balanced no
        // matter what the socket does.
        let (tag, bytes) = match msg {
            WorkerMsg::Task {
                job_id,
                payload,
                fate,
            } => {
                let b = frame::encode_task(job_id, fate, &payload);
                payload.recycle();
                (FrameTag::Task, b)
            }
            WorkerMsg::Cancel(id) => (FrameTag::Cancel, frame::encode_u64(id)),
            WorkerMsg::CancelUpTo(mark) => (FrameTag::CancelUpTo, frame::encode_u64(mark)),
            WorkerMsg::Shutdown => (FrameTag::Shutdown, Vec::new()),
        };
        self.send_frame(worker, tag, &bytes)
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<TransportEvent>> {
        match self.events_rx.recv_timeout(timeout) {
            Ok(ev) => Ok(Some(ev)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => bail!("tcp transport supervisor gone"),
        }
    }

    fn try_recv(&mut self) -> Result<Option<TransportEvent>> {
        match self.events_rx.try_recv() {
            Ok(ev) => Ok(Some(ev)),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => bail!("tcp transport supervisor gone"),
        }
    }

    fn counters(&self) -> MembershipCounters {
        let mut c = self
            .shared
            .membership
            .lock()
            .expect("membership lock")
            .counters();
        c.reconnects = self.shared.reconnects.load(Ordering::SeqCst);
        c.frames_corrupt = self.shared.frames_corrupt.load(Ordering::SeqCst);
        c
    }

    fn epoch(&self) -> u64 {
        self.shared.membership.lock().expect("membership lock").epoch()
    }

    fn shutdown(self: Box<Self>) {
        // Goodbye to every live peer (best-effort), then tear down.
        for w in 0..self.n {
            let _ = self.send_frame(w, FrameTag::Shutdown, &[]);
        }
        self.shared.stop.store(true, Ordering::SeqCst);
        for p in &self.shared.peers {
            if let Some(s) = p.writer.lock().expect("peer writer").take() {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
        if let Some(h) = self.supervisor {
            let _ = h.join(); // joins the reader threads too
        }
        // Only after every producer thread is gone is the event queue
        // final: recycle the replies still parked in it.
        while let Ok(ev) = self.events_rx.try_recv() {
            if let TransportEvent::Reply(r) = ev {
                r.body.recycle();
            }
        }
    }
}

/// The supervisor loop: heartbeat pings, missed-beat eviction, and
/// re-dialing down peers with exponential backoff.
fn supervise(shared: Arc<TcpShared>, cfg: TcpConfig) {
    let n = shared.peers.len();
    let start = Instant::now();
    let mut next_dial = vec![start; n];
    let mut backoff = vec![cfg.reconnect_backoff; n];
    let mut ping_seq = 0u64;
    let mut readers: Vec<JoinHandle<()>> = Vec::new();
    let pace = (cfg.heartbeat / 4).clamp(Duration::from_millis(2), Duration::from_millis(50));

    while !shared.stop.load(Ordering::SeqCst) {
        let now = Instant::now();
        let actions = shared.membership.lock().expect("membership lock").tick(now);
        // tick() already marked the evicted slots Down (so a racing
        // reader can't double-report); finish the job: close + notify.
        for &slot in &actions.evict {
            if let Some(s) = shared.peers[slot].writer.lock().expect("peer writer").take() {
                let _ = s.shutdown(Shutdown::Both);
            }
            let _ = shared.events_tx.send(TransportEvent::PeerDown { worker: slot });
            next_dial[slot] = now + backoff[slot];
        }
        for &slot in &actions.pings {
            ping_seq += 1;
            let bytes = frame::encode_u64(ping_seq);
            let mut guard = shared.peers[slot].writer.lock().expect("peer writer");
            if let Some(stream) = guard.as_mut() {
                if frame::write_frame(stream, FrameTag::Ping, &bytes).is_err() {
                    let _ = stream.shutdown(Shutdown::Both);
                    drop(guard);
                    shared.conn_lost(slot);
                }
            }
        }

        // Re-dial whatever is not live and due.
        for slot in 0..n {
            let live = shared.membership.lock().expect("membership lock").is_live(slot);
            if live || now < next_dial[slot] {
                continue;
            }
            match dial_worker(&shared, &cfg, slot) {
                Ok(reader) => {
                    readers.push(reader);
                    backoff[slot] = cfg.reconnect_backoff;
                }
                Err(_) => {
                    next_dial[slot] = Instant::now() + backoff[slot];
                    backoff[slot] = (backoff[slot] * 2).min(Duration::from_secs(2));
                    // A slot that never rendezvoused at all still has to
                    // be declared dead eventually, or the master would
                    // wait on it forever: give up once the startup
                    // window closes.
                    let p = &shared.peers[slot];
                    if !p.ever_connected.load(Ordering::SeqCst)
                        && Instant::now() >= start + cfg.connect_timeout
                        && !p.gave_up.swap(true, Ordering::SeqCst)
                    {
                        let _ = shared.events_tx.send(TransportEvent::PeerDown { worker: slot });
                    }
                }
            }
        }
        std::thread::sleep(pace);
    }
    for r in readers {
        let _ = r.join();
    }
}

/// Dial one worker and run the coordinator side of the rendezvous. On
/// success the peer is Live, its writer is installed, and its reader
/// thread (returned) is pumping replies.
fn dial_worker(shared: &Arc<TcpShared>, cfg: &TcpConfig, slot: usize) -> Result<JoinHandle<()>> {
    let addr: SocketAddr = shared.peers[slot]
        .addr
        .parse()
        .with_context(|| format!("worker address {:?}", shared.peers[slot].addr))?;
    let stream = TcpStream::connect_timeout(&addr, Duration::from_millis(250))
        .with_context(|| format!("dial worker {slot} at {addr}"))?;
    let _ = stream.set_nodelay(true);

    // Rendezvous: the worker announces, we admit (or defer).
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    let mut read_half = &stream;
    let outcome = frame::read_frame(&mut read_half);
    stream.set_read_timeout(None)?;
    let ReadOutcome::Frame(f) = outcome? else {
        bail!("worker {slot} closed during handshake");
    };
    if f.tag != FrameTag::Announce {
        bail!("worker {slot}: expected Announce, got {:?}", f.tag);
    }
    let _announce = frame::decode_announce(&f.payload)?;
    let admission = shared
        .membership
        .lock()
        .expect("membership lock")
        .on_announce(slot, Instant::now());
    let session = match admission {
        Admission::Accept { session } => session,
        Admission::Later { retry_ms } => {
            let mut w = &stream;
            let _ = frame::write_frame(&mut w, FrameTag::Later, &frame::encode_later(retry_ms));
            bail!("worker {slot} deferred (already live)");
        }
    };
    {
        let mut w = &stream;
        if let Err(e) = frame::write_frame(&mut w, FrameTag::Accept, &frame::encode_accept(slot, session)) {
            shared.conn_lost(slot);
            return Err(e).with_context(|| format!("worker {slot}: accept write"));
        }
    }

    // Live: install the writer, count the reconnect, start the reader.
    let write_half = stream.try_clone().context("clone write half")?;
    *shared.peers[slot].writer.lock().expect("peer writer") = Some(write_half);
    if shared.peers[slot].ever_connected.swap(true, Ordering::SeqCst) {
        shared.reconnects.fetch_add(1, Ordering::SeqCst);
    }
    let _ = shared.events_tx.send(TransportEvent::PeerUp { worker: slot });

    let shared = Arc::clone(shared);
    let reader = std::thread::Builder::new()
        .name(format!("fcdcc-tcp-reader-{slot}"))
        .spawn(move || read_peer(shared, slot, stream, session))
        .expect("spawn tcp reader");
    Ok(reader)
}

/// Reader thread for one live connection: pongs feed the membership,
/// replies are decoded against the plan arena (stale sessions recycled,
/// corrupt frames strike the peer), and any wire irregularity reports
/// the connection lost.
fn read_peer(shared: Arc<TcpShared>, slot: usize, stream: TcpStream, session: u64) {
    let mut read_half = &stream;
    loop {
        let frame: Frame = match frame::read_frame(&mut read_half) {
            Ok(ReadOutcome::Frame(f)) => f,
            Ok(ReadOutcome::Eof) => break,
            Err(_) => {
                shared.frames_corrupt.fetch_add(1, Ordering::SeqCst);
                break;
            }
        };
        match frame.tag {
            FrameTag::Pong => {
                if frame::decode_u64(&frame.payload).is_ok() {
                    shared
                        .membership
                        .lock()
                        .expect("membership lock")
                        .on_pong(slot, Instant::now());
                } else {
                    shared.frames_corrupt.fetch_add(1, Ordering::SeqCst);
                    break;
                }
            }
            FrameTag::Reply => {
                match frame::decode_reply(&frame.payload, &shared.arena) {
                    Ok((reply, reply_epoch)) => {
                        // Stale-session replies (from before a
                        // reconnect) are recycled, never decoded into
                        // a job — the epoch rule.
                        let current = shared
                            .membership
                            .lock()
                            .expect("membership lock")
                            .session(slot);
                        if current == Some(reply_epoch) && reply_epoch == session {
                            let _ = shared.events_tx.send(TransportEvent::Reply(reply));
                        } else {
                            reply.body.recycle();
                        }
                    }
                    Err(_) => {
                        shared.frames_corrupt.fetch_add(1, Ordering::SeqCst);
                        break;
                    }
                }
            }
            _ => {
                shared.frames_corrupt.fetch_add(1, Ordering::SeqCst);
                break;
            }
        }
    }
    // Only report the loss if this reader's session is still the
    // current one — a reader of a superseded connection exiting must
    // not evict the slot's fresh successor.
    let still_current = shared
        .membership
        .lock()
        .expect("membership lock")
        .session(slot)
        == Some(session);
    if still_current {
        shared.conn_lost(slot);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::DirectEngine;

    #[test]
    fn worker_node_binds_ephemeral_and_dies_on_kill() {
        let node = spawn_worker_node(WorkerNodeConfig {
            listen: "127.0.0.1:0".to_string(),
            engine: Arc::new(DirectEngine),
            threads: 1,
        })
        .unwrap();
        assert_ne!(node.addr().port(), 0, "ephemeral port resolved");
        assert_eq!(node.tasks_seen(), 0);
        node.kill(); // joins: the accept loop must actually exit
    }

    #[test]
    fn connect_fails_cleanly_when_no_worker_listens() {
        // A port nobody listens on: bind-then-drop reserves one.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let mut cfg = TcpConfig::new(vec![addr.to_string()]);
        cfg.connect_timeout = Duration::from_millis(300);
        let arena = Arc::new(SlabArena::new(8));
        let err = TcpTransport::connect(cfg, arena).unwrap_err();
        assert!(err.to_string().contains("rendezvoused"), "err: {err:#}");
    }

    #[test]
    fn rendezvous_heartbeats_and_graceful_shutdown() {
        let nodes: Vec<WorkerNodeHandle> = (0..2)
            .map(|_| {
                spawn_worker_node(WorkerNodeConfig {
                    listen: "127.0.0.1:0".to_string(),
                    engine: Arc::new(DirectEngine),
                    threads: 1,
                })
                .unwrap()
            })
            .collect();
        let mut cfg = TcpConfig::new(nodes.iter().map(|n| n.addr().to_string()).collect());
        cfg.heartbeat = Duration::from_millis(25);
        let arena = Arc::new(SlabArena::new(8));
        let transport = TcpTransport::connect(cfg, arena).unwrap();
        assert_eq!(transport.epoch(), 2, "epoch = n after initial rendezvous");
        // Let a few heartbeat rounds pass; nobody must get evicted.
        std::thread::sleep(Duration::from_millis(120));
        let c = transport.counters();
        assert!(c.heartbeats_sent >= 4, "pings flowed: {c:?}");
        assert_eq!(c.evictions, 0, "healthy peers stay live: {c:?}");
        Box::new(transport).shutdown();
        // The Shutdown frames stop the nodes gracefully.
        for n in nodes {
            n.wait();
        }
    }
}
