//! The heterogeneous cluster (DESIGN.md §Hardware adaptation): workers
//! behind a pluggable [`Transport`], straggler injection in the worker
//! loop, and a master that decodes as soon as any δ results arrive —
//! the same semantics as the paper's EC2/mpi4py testbed. The default
//! wire is in-process mpsc channels ([`ChannelTransport`]:
//! deterministic, offline); [`TcpTransport`] drives real remote worker
//! processes over framed TCP with membership, heartbeats, and eviction
//! (DESIGN.md §Transport & membership).
//!
//! The master is a **job runtime**: `Cluster::submit` is non-blocking and
//! any number of jobs (e.g. conv layers of different serving requests)
//! overlap on the same pool; a collector demultiplexes replies into a
//! per-job in-flight table with first-δ completion and per-job deadlines
//! (DESIGN.md §Job runtime).
//!
//! Because the testbed has a single vCPU, wall-clock parallel speedup is
//! not observable; the cluster therefore *also* computes the simulated
//! makespan (per-worker completion = straggler delay + measured compute
//! time; job completion = δ-th order statistic), which is the quantity
//! the paper's Figs. 5–6 plot.

pub mod frame;
pub mod frontend;
pub mod health;
pub mod master;
pub mod membership;
pub mod sim;
pub mod straggler;
pub mod tcp;
pub mod transport;
pub mod worker;

pub use frontend::{
    spawn_frontend, ClientReply, FrontendClient, FrontendListener, FrontendRequest, Responder,
};
pub use health::{HealthPolicy, HealthTracker, WorkerState};
pub use master::{BatchOutcome, Cluster, JobHandle, JobReport};
pub use membership::{Admission, Membership, MembershipConfig};
pub use sim::{simulate_job, SimJob};
pub use straggler::{FaultKind, FaultPlan, StragglerModel};
pub use tcp::{spawn_worker_node, TcpConfig, TcpTransport, WorkerNodeConfig, WorkerNodeHandle};
pub use transport::{ChannelTransport, Transport, TransportEvent};
