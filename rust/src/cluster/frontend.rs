//! Client-facing serving front-end: the network edge of `--role
//! frontend` (DESIGN.md §Serving front-end & overload control).
//!
//! [`spawn_frontend`] binds a TCP listener and funnels every client's
//! [`FrameTag::Request`] frames into one mpsc channel of
//! [`FrontendRequest`]s for the serving scheduler, each carrying a
//! [`Responder`] bound to its connection. The scheduler replies through
//! the responder with exactly one terminal frame per request —
//! [`FrameTag::Response`] (logits), [`FrameTag::Busy`] (shed at
//! admission), or [`FrameTag::DeadlineExceeded`] (expired before
//! service) — which is also the backpressure signal: a client that keeps
//! pipelining past its `Busy` replies just keeps getting shed.
//!
//! Client payloads deliberately stay in plain `Vec`s (see
//! `frame::decode_request`): nothing a client sends can check a slab out
//! of the coordinator's hot-path arena, so malformed or hostile traffic
//! costs its own connection and nothing else.

use crate::cluster::frame::{self, FrameTag, ReadOutcome};
use crate::tensor::Tensor3;
use anyhow::{Context, Result};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// One client request as the serving scheduler sees it.
pub struct FrontendRequest {
    /// Per-request deadline carried on the wire (`None` = the frame's
    /// deadline field was 0: use the server's `--request-deadline-ms`).
    pub deadline: Option<Duration>,
    /// The input image, in a plain (non-arena) buffer.
    pub input: Tensor3,
    /// Reply handle for this request's terminal outcome.
    pub responder: Responder,
}

/// Write half of one client connection, bound to one request's id.
/// Sends are best-effort: a client that disconnected mid-flight loses
/// its reply, never the scheduler.
#[derive(Clone)]
pub struct Responder {
    writer: Arc<Mutex<TcpStream>>,
    client_id: u64,
}

impl Responder {
    fn write(&self, tag: FrameTag, payload: &[u8]) {
        if let Ok(mut w) = self.writer.lock() {
            if frame::write_frame(&mut *w, tag, payload).is_err() {
                let _ = w.shutdown(Shutdown::Both);
            }
        }
    }

    /// Terminal outcome: the request completed; deliver its logits.
    pub fn logits(&self, logits: &[f64]) {
        self.write(
            FrameTag::Response,
            &frame::encode_response(self.client_id, logits),
        );
    }

    /// Terminal outcome: shed at admission (queue full).
    pub fn busy(&self) {
        self.write(FrameTag::Busy, &frame::encode_u64(self.client_id));
    }

    /// Terminal outcome: the deadline expired before service finished.
    pub fn deadline_exceeded(&self) {
        self.write(FrameTag::DeadlineExceeded, &frame::encode_u64(self.client_id));
    }
}

struct FrontShared {
    stop: AtomicBool,
    /// Read-half clones of every accepted connection, for shutdown.
    conns: Mutex<Vec<TcpStream>>,
}

/// Handle on a running front-end listener.
pub struct FrontendListener {
    addr: SocketAddr,
    shared: Arc<FrontShared>,
    accept_thread: JoinHandle<()>,
}

impl FrontendListener {
    /// The bound address (resolves `127.0.0.1:0` to the ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, tear down every client connection, and join the
    /// accept loop (which in turn joins its per-connection readers).
    pub fn stop(self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Ok(conns) = self.shared.conns.lock() {
            for c in conns.iter() {
                let _ = c.shutdown(Shutdown::Both);
            }
        }
        // Unblock the accept call with a throwaway dial.
        let _ = TcpStream::connect(self.addr);
        let _ = self.accept_thread.join();
    }
}

/// Bind `listen` and start the accept loop. Returns the listener handle
/// and the scheduler's end of the request channel. Each connection gets
/// a reader thread that decodes [`FrameTag::Request`] frames until EOF
/// or a protocol violation (which costs that connection only).
pub fn spawn_frontend(listen: &str) -> Result<(FrontendListener, Receiver<FrontendRequest>)> {
    let listener =
        TcpListener::bind(listen).with_context(|| format!("frontend bind {listen}"))?;
    let addr = listener.local_addr().context("frontend local_addr")?;
    let (tx, rx) = channel();
    let shared = Arc::new(FrontShared {
        stop: AtomicBool::new(false),
        conns: Mutex::new(Vec::new()),
    });
    let accept_shared = Arc::clone(&shared);
    let accept_thread = std::thread::Builder::new()
        .name("fcdcc-frontend-accept".to_string())
        .spawn(move || accept_loop(listener, accept_shared, tx))
        .context("spawn frontend accept thread")?;
    Ok((
        FrontendListener {
            addr,
            shared,
            accept_thread,
        },
        rx,
    ))
}

fn accept_loop(listener: TcpListener, shared: Arc<FrontShared>, tx: Sender<FrontendRequest>) {
    let mut readers: Vec<JoinHandle<()>> = Vec::new();
    while !shared.stop.load(Ordering::SeqCst) {
        let Ok((stream, _)) = listener.accept() else {
            break;
        };
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        if let Ok(clone) = stream.try_clone() {
            if let Ok(mut conns) = shared.conns.lock() {
                conns.push(clone);
            }
        }
        let tx = tx.clone();
        if let Ok(h) = std::thread::Builder::new()
            .name("fcdcc-frontend-conn".to_string())
            .spawn(move || client_reader(stream, tx))
        {
            readers.push(h);
        }
    }
    drop(tx);
    for r in readers {
        let _ = r.join();
    }
}

/// Decode one connection's request stream into the scheduler channel.
fn client_reader(stream: TcpStream, tx: Sender<FrontendRequest>) {
    let _ = stream.set_nodelay(true);
    let Ok(write_half) = stream.try_clone() else {
        return;
    };
    let writer = Arc::new(Mutex::new(write_half));
    let mut read_half = &stream;
    loop {
        match frame::read_frame(&mut read_half) {
            Ok(ReadOutcome::Frame(f)) if f.tag == FrameTag::Request => {
                let Ok((client_id, deadline_ms, input)) = frame::decode_request(&f.payload)
                else {
                    break;
                };
                let req = FrontendRequest {
                    deadline: (deadline_ms > 0).then(|| Duration::from_millis(deadline_ms)),
                    input,
                    responder: Responder {
                        writer: Arc::clone(&writer),
                        client_id,
                    },
                };
                if tx.send(req).is_err() {
                    break;
                }
            }
            // EOF, transport error, or a non-Request tag: this
            // connection is done.
            _ => break,
        }
    }
    let _ = stream.shutdown(Shutdown::Both);
}

/// A request's terminal outcome as seen by a client.
#[derive(Clone, Debug, PartialEq)]
pub enum ClientReply {
    Logits { client_id: u64, logits: Vec<f64> },
    Busy { client_id: u64 },
    DeadlineExceeded { client_id: u64 },
}

/// Minimal blocking client for the front-end protocol (tests, examples,
/// and the loopback CI leg).
pub struct FrontendClient {
    stream: TcpStream,
}

impl FrontendClient {
    pub fn connect(addr: &str) -> Result<FrontendClient> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("frontend connect {addr}"))?;
        let _ = stream.set_nodelay(true);
        Ok(FrontendClient { stream })
    }

    /// Send one request. `deadline: None` defers to the server default.
    pub fn send(&mut self, client_id: u64, deadline: Option<Duration>, x: &Tensor3) -> Result<()> {
        let ms = deadline.map_or(0, |d| d.as_millis() as u64);
        frame::write_frame(
            &mut self.stream,
            FrameTag::Request,
            &frame::encode_request(client_id, ms, x),
        )
        .context("send request frame")
    }

    /// Block for the next terminal reply. Replies may arrive in any
    /// order relative to pipelined sends; match on `client_id`.
    pub fn recv(&mut self) -> Result<ClientReply> {
        let mut r = &self.stream;
        match frame::read_frame(&mut r)? {
            ReadOutcome::Frame(f) => match f.tag {
                FrameTag::Response => {
                    let (client_id, logits) = frame::decode_response(&f.payload)?;
                    Ok(ClientReply::Logits { client_id, logits })
                }
                FrameTag::Busy => Ok(ClientReply::Busy {
                    client_id: frame::decode_u64(&f.payload)?,
                }),
                FrameTag::DeadlineExceeded => Ok(ClientReply::DeadlineExceeded {
                    client_id: frame::decode_u64(&f.payload)?,
                }),
                other => anyhow::bail!("unexpected frame tag {other:?} from the frontend"),
            },
            ReadOutcome::Eof => anyhow::bail!("frontend closed the connection"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn request_flows_in_and_every_reply_kind_flows_out() {
        let (listener, rx) = spawn_frontend("127.0.0.1:0").unwrap();
        let mut client = FrontendClient::connect(&listener.addr().to_string()).unwrap();
        let mut rng = Rng::new(5);
        let x = Tensor3::random(1, 4, 4, &mut rng);
        client.send(1, Some(Duration::from_millis(80)), &x).unwrap();
        client.send(2, None, &x).unwrap();
        client.send(3, None, &x).unwrap();

        let r1 = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(r1.deadline, Some(Duration::from_millis(80)));
        assert_eq!(r1.input.data, x.data, "input crosses the wire bit-exactly");
        let r2 = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(r2.deadline, None, "deadline 0 defers to the server");
        let r3 = rx.recv_timeout(Duration::from_secs(5)).unwrap();

        r1.responder.logits(&[1.0, 2.0]);
        r2.responder.busy();
        r3.responder.deadline_exceeded();
        let mut got = vec![client.recv().unwrap(), client.recv().unwrap(), client.recv().unwrap()];
        got.sort_by_key(|r| match r {
            ClientReply::Logits { client_id, .. }
            | ClientReply::Busy { client_id }
            | ClientReply::DeadlineExceeded { client_id } => *client_id,
        });
        assert_eq!(
            got[0],
            ClientReply::Logits {
                client_id: 1,
                logits: vec![1.0, 2.0]
            }
        );
        assert_eq!(got[1], ClientReply::Busy { client_id: 2 });
        assert_eq!(got[2], ClientReply::DeadlineExceeded { client_id: 3 });
        listener.stop();
    }

    #[test]
    fn malformed_client_frame_drops_only_that_connection() {
        let (listener, rx) = spawn_frontend("127.0.0.1:0").unwrap();
        let addr = listener.addr().to_string();
        // A connection that speaks a non-Request tag is dropped…
        let mut bad = TcpStream::connect(&addr).unwrap();
        frame::write_frame(&mut bad, FrameTag::Ping, &frame::encode_u64(1)).unwrap();
        let mut r = &bad;
        assert!(matches!(
            frame::read_frame(&mut r),
            Ok(ReadOutcome::Eof) | Err(_)
        ));
        // …while a well-formed client on another connection still works.
        let mut ok = FrontendClient::connect(&addr).unwrap();
        let mut rng = Rng::new(6);
        ok.send(7, None, &Tensor3::random(1, 2, 2, &mut rng)).unwrap();
        let req = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        req.responder.busy();
        assert_eq!(ok.recv().unwrap(), ClientReply::Busy { client_id: 7 });
        listener.stop();
    }
}
