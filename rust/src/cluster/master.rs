//! The master node: owns the worker pool, runs coded jobs end to end
//! (encode → dispatch → first-δ collection → decode → merge), and
//! accounts every phase (paper §II-C phases and §VI metrics).

use crate::cluster::straggler::StragglerModel;
use crate::cluster::worker::{worker_loop, WorkerMsg, WorkerReply};
use crate::engine::TaskEngine;
use crate::fcdcc::FcdccPlan;
use crate::tensor::{Tensor3, Tensor4};
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Per-job metrics (the rows of Table III and the points of Figs. 5–6).
#[derive(Clone, Debug)]
pub struct JobReport {
    pub job_id: u64,
    pub n: usize,
    pub delta: usize,
    /// Worker ids whose results were used for decoding, in arrival order.
    pub used_workers: Vec<usize>,
    /// Master-side input encoding time (APCP partition + CRME combine).
    pub encode_secs: f64,
    /// Wall-clock from dispatch to δ-th arrival (measured; serialized on
    /// a 1-vCPU testbed, see `sim_makespan_secs` for the parallel view).
    pub collect_secs: f64,
    /// Master-side decode time: recovery inversion + blockwise combine +
    /// merge (the paper's "Decode (ms)" column).
    pub decode_secs: f64,
    /// Simulated parallel makespan: the δ-th smallest per-worker
    /// (injected delay + compute) — what an actually-parallel cluster
    /// would observe; the quantity plotted in Figs. 5–6.
    pub sim_makespan_secs: f64,
    /// Mean pure compute time over used workers.
    pub mean_compute_secs: f64,
    /// Tensor entries uploaded to all n workers (coded input slabs).
    pub upload_entries: usize,
    /// Tensor entries downloaded from the δ used workers.
    pub download_entries: usize,
}

/// A pool of worker threads plus the result channel.
pub struct Cluster {
    n: usize,
    senders: Vec<Sender<WorkerMsg>>,
    results: Receiver<WorkerReply>,
    handles: Vec<JoinHandle<()>>,
    next_job: u64,
    /// Per-job collection timeout (guards against >γ failures).
    pub collect_timeout: Duration,
}

impl Cluster {
    /// Spawn `n` workers all running the same conv engine.
    pub fn new(n: usize, engine: Arc<dyn TaskEngine>) -> Self {
        let (reply_tx, results) = channel::<WorkerReply>();
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for worker_id in 0..n {
            let (tx, rx) = channel::<WorkerMsg>();
            let engine = Arc::clone(&engine);
            let reply_tx = reply_tx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("fcdcc-worker-{worker_id}"))
                    .spawn(move || worker_loop(worker_id, engine, rx, reply_tx))
                    .expect("spawn worker"),
            );
            senders.push(tx);
        }
        Self {
            n,
            senders,
            results,
            handles,
            next_job: 1,
            collect_timeout: Duration::from_secs(60),
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Run one coded convolution job end to end. `coded_filters` are the
    /// per-worker resident filter slabs from `plan.encode_filters`
    /// (encoded once at model load, per the paper's steady-state model).
    pub fn run_job(
        &mut self,
        plan: &FcdccPlan,
        x: &Tensor3,
        coded_filters: &[Vec<Tensor4>],
        straggler: &StragglerModel,
        rng: &mut Rng,
    ) -> Result<(Tensor3, JobReport)> {
        assert_eq!(coded_filters.len(), self.n, "filters for every worker");
        assert_eq!(plan.spec().n, self.n, "plan/cluster n mismatch");
        let job_id = self.next_job;
        self.next_job += 1;
        let delta = plan.delta();

        // --- Encode phase (master).
        let t0 = Instant::now();
        let coded_inputs = plan.encode_input(x);
        let payloads = plan.make_payloads(coded_inputs, coded_filters);
        let encode_secs = t0.elapsed().as_secs_f64();
        let upload_entries: usize = payloads.iter().map(|p| p.upload_entries()).sum();

        // --- Dispatch with straggler fates.
        let fates = straggler.draw(self.n, rng);
        let t1 = Instant::now();
        for (payload, fate) in payloads.into_iter().zip(fates.iter()) {
            let wid = payload.worker_id;
            self.senders[wid]
                .send(WorkerMsg::Task {
                    job_id,
                    payload: Box::new(payload),
                    fate: *fate,
                })
                .with_context(|| format!("worker {wid} channel closed"))?;
        }

        // --- Collect the first δ results for THIS job.
        let mut replies: Vec<WorkerReply> = Vec::with_capacity(delta);
        let deadline = Instant::now() + self.collect_timeout;
        while replies.len() < delta {
            let now = Instant::now();
            if now >= deadline {
                bail!(
                    "job {job_id}: timed out with {}/{delta} results (>{} workers failed?)",
                    replies.len(),
                    self.n - delta
                );
            }
            match self.results.recv_timeout(deadline - now) {
                Ok(r) if r.job_id == job_id => replies.push(r),
                Ok(_) => {} // stale result from a previous job: drop
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => bail!("all workers gone"),
            }
        }
        let collect_secs = t1.elapsed().as_secs_f64();

        // Cancel the stragglers' superseded subtasks so their injected
        // delays don't cascade into the next job.
        for tx in &self.senders {
            let _ = tx.send(WorkerMsg::Cancel(job_id));
        }

        // --- Decode phase (master).
        let t2 = Instant::now();
        let results: Vec<&crate::fcdcc::WorkerResult> =
            replies.iter().map(|r| &r.result).collect();
        let out = plan.decode_refs(&results)?;
        let decode_secs = t2.elapsed().as_secs_f64();

        let download_entries = results.iter().map(|r| r.download_entries()).sum();
        let used_workers: Vec<usize> = replies.iter().map(|r| r.worker_id).collect();
        let sim_makespan_secs = replies
            .iter()
            .map(|r| r.delay_secs + r.compute_secs)
            .fold(0.0, f64::max);
        let mean_compute_secs =
            replies.iter().map(|r| r.compute_secs).sum::<f64>() / replies.len() as f64;

        Ok((
            out,
            JobReport {
                job_id,
                n: self.n,
                delta,
                used_workers,
                encode_secs,
                collect_secs,
                decode_secs,
                sim_makespan_secs,
                mean_compute_secs,
                upload_entries,
                download_entries,
            },
        ))
    }

    /// Graceful shutdown: tell every worker to exit and join the threads.
    pub fn shutdown(self) {
        for tx in &self.senders {
            let _ = tx.send(WorkerMsg::Shutdown);
        }
        for h in self.handles {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::DirectEngine;
    use crate::model::ConvLayer;
    use crate::tensor::conv2d;
    use crate::util::mse;

    fn small_setup() -> (ConvLayer, Tensor3, Tensor4) {
        let layer = ConvLayer::new("t", 2, 12, 10, 8, 3, 3, 1, 0);
        let mut rng = Rng::new(71);
        let x = Tensor3::random(2, 12, 10, &mut rng);
        let k = Tensor4::random(8, 2, 3, 3, &mut rng);
        (layer, x, k)
    }

    #[test]
    fn cluster_job_matches_reference() {
        let (layer, x, k) = small_setup();
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap(); // delta=2
        let coded_filters = plan.encode_filters(&k);
        let mut cluster = Cluster::new(4, Arc::new(DirectEngine));
        let mut rng = Rng::new(1);
        let (y, report) = cluster
            .run_job(&plan, &x, &coded_filters, &StragglerModel::None, &mut rng)
            .unwrap();
        cluster.shutdown();
        let want = conv2d(&x, &k, layer.params());
        assert!(mse(&y.data, &want.data) < 1e-20);
        assert_eq!(report.delta, 2);
        assert_eq!(report.used_workers.len(), 2);
        assert!(report.upload_entries > 0);
        assert!(report.download_entries > 0);
    }

    #[test]
    fn tolerates_up_to_gamma_failures() {
        let (layer, x, k) = small_setup();
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 5).unwrap(); // delta=2, gamma=3
        let coded_filters = plan.encode_filters(&k);
        let mut cluster = Cluster::new(5, Arc::new(DirectEngine));
        let mut rng = Rng::new(2);
        let (y, _) = cluster
            .run_job(
                &plan,
                &x,
                &coded_filters,
                &StragglerModel::Failures { count: 3 },
                &mut rng,
            )
            .unwrap();
        cluster.shutdown();
        let want = conv2d(&x, &k, layer.params());
        assert!(mse(&y.data, &want.data) < 1e-18);
    }

    #[test]
    fn too_many_failures_times_out() {
        let (layer, x, k) = small_setup();
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap(); // delta=2, gamma=2
        let coded_filters = plan.encode_filters(&k);
        let mut cluster = Cluster::new(4, Arc::new(DirectEngine));
        cluster.collect_timeout = Duration::from_millis(200);
        let mut rng = Rng::new(3);
        let r = cluster.run_job(
            &plan,
            &x,
            &coded_filters,
            &StragglerModel::Failures { count: 3 },
            &mut rng,
        );
        cluster.shutdown();
        assert!(r.is_err());
    }

    #[test]
    fn stragglers_do_not_block_completion() {
        let (layer, x, k) = small_setup();
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap(); // delta=2, gamma=2
        let coded_filters = plan.encode_filters(&k);
        let mut cluster = Cluster::new(4, Arc::new(DirectEngine));
        let mut rng = Rng::new(4);
        let t0 = Instant::now();
        let (_, report) = cluster
            .run_job(
                &plan,
                &x,
                &coded_filters,
                &StragglerModel::FixedCount {
                    count: 2,
                    delay: Duration::from_millis(300),
                },
                &mut rng,
            )
            .unwrap();
        let wall = t0.elapsed();
        cluster.shutdown();
        // The two prompt workers suffice; we must not have waited ~300ms.
        assert!(
            wall < Duration::from_millis(250),
            "took {wall:?}, straggler delay leaked into the critical path"
        );
        assert_eq!(report.used_workers.len(), 2);
    }

    #[test]
    fn back_to_back_jobs_ignore_stale_results() {
        let (layer, x, k) = small_setup();
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap();
        let coded_filters = plan.encode_filters(&k);
        let mut cluster = Cluster::new(4, Arc::new(DirectEngine));
        let mut rng = Rng::new(5);
        let want = conv2d(&x, &k, layer.params());
        for _ in 0..3 {
            let (y, _) = cluster
                .run_job(&plan, &x, &coded_filters, &StragglerModel::None, &mut rng)
                .unwrap();
            assert!(mse(&y.data, &want.data) < 1e-18);
        }
        cluster.shutdown();
    }
}
