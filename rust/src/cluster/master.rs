//! The master node: owns the worker pool and a job-oriented runtime.
//!
//! [`Cluster::submit_batch`] is non-blocking: it encodes a **batch** of
//! samples into one coded job, dispatches, and registers the job in a
//! per-job in-flight table (keyed by `job_id`, first-δ completion,
//! per-job deadline) — job_id = batch, so the table, collector, and
//! cancellation protocol are untouched by batching. A collector
//! demultiplexes every [`WorkerReply`] coming off the shared result
//! channel into that table, so **any number of jobs overlap on the same
//! worker pool** — e.g. conv layers of different serving requests.
//! [`Cluster::wait_batch`] blocks until one job is decodable (routing
//! other jobs' replies while it waits) and returns its per-sample
//! outputs + [`JobReport`]; a timed-out job fails **all** of its member
//! samples in one error without touching the other in-flight jobs.
//! [`Cluster::submit`]/[`Cluster::wait`] are the batch-1 conveniences,
//! and [`Cluster::run_job`] is submit+wait for single-job callers. Every
//! phase is accounted (paper §II-C phases and §VI metrics).

use crate::cluster::straggler::StragglerModel;
use crate::cluster::worker::{worker_loop, WorkerMsg, WorkerReply};
use crate::engine::{Im2colEngine, TaskEngine};
use crate::fcdcc::{FcdccPlan, ResidentFilters};
use crate::tensor::Tensor3;
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Per-job metrics (the rows of Table III and the points of Figs. 5–6).
#[derive(Clone, Debug)]
pub struct JobReport {
    pub job_id: u64,
    pub n: usize,
    pub delta: usize,
    /// Worker ids whose results were used for decoding: the first δ to
    /// arrive, ordered by worker id (so decoding is deterministic for a
    /// fixed reply set).
    pub used_workers: Vec<usize>,
    /// Master-side input encoding time (APCP partition + CRME combine).
    pub encode_secs: f64,
    /// Wall-clock from dispatch to δ-th arrival (measured; serialized on
    /// a 1-vCPU testbed, see `sim_makespan_secs` for the parallel view).
    pub collect_secs: f64,
    /// Master-side decode time: recovery inversion + blockwise combine +
    /// merge (the paper's "Decode (ms)" column).
    pub decode_secs: f64,
    /// Simulated parallel makespan: the δ-th smallest per-worker
    /// (injected delay + compute) — what an actually-parallel cluster
    /// would observe; the quantity plotted in Figs. 5–6.
    pub sim_makespan_secs: f64,
    /// Mean pure compute time over used workers.
    pub mean_compute_secs: f64,
    /// Tensor entries uploaded to all n workers (coded input slabs).
    pub upload_entries: usize,
    /// Tensor entries downloaded from the δ used workers.
    pub download_entries: usize,
    /// Jobs in flight on the pool when this one was dispatched
    /// (including itself): 1 = sequential, >1 = pipelined.
    pub concurrent_jobs: usize,
    /// Samples carried by this job (1 = unbatched).
    pub batch: usize,
}

/// Handle to a submitted job. Consume it with [`Cluster::wait`]; every
/// submitted job should eventually be waited on (abandoned handles keep
/// a slot in the in-flight table alive).
#[must_use = "wait() on the handle to collect the job's output"]
pub struct JobHandle {
    job_id: u64,
}

impl JobHandle {
    pub fn job_id(&self) -> u64 {
        self.job_id
    }
}

/// Collection state of one in-flight job.
#[derive(Clone, Copy)]
enum JobPhase {
    /// Fewer than δ replies so far.
    Collecting,
    /// δ replies arrived; `collect_secs` is dispatch → δ-th arrival.
    Done { collect_secs: f64 },
    /// The per-job deadline passed before δ replies arrived.
    TimedOut,
}

/// One row of the in-flight table.
struct InFlight {
    delta: usize,
    batch: usize,
    replies: Vec<WorkerReply>,
    phase: JobPhase,
    deadline: Instant,
    dispatched_at: Instant,
    encode_secs: f64,
    upload_entries: usize,
    concurrent_jobs: usize,
}

/// A pool of worker threads plus the demultiplexing collector.
pub struct Cluster {
    n: usize,
    senders: Vec<Sender<WorkerMsg>>,
    results: Receiver<WorkerReply>,
    handles: Vec<JoinHandle<()>>,
    next_job: u64,
    /// Per-job collection timeout (guards against >γ failures). Applied
    /// at submit time: changing it affects subsequently submitted jobs.
    pub collect_timeout: Duration,
    /// In-flight table: job id → collection state. A `BTreeMap` so the
    /// smallest outstanding id (the workers' prune watermark) is cheap.
    jobs: BTreeMap<u64, InFlight>,
    watermark_sent: u64,
}

impl Cluster {
    /// Spawn `n` workers all running the same conv engine.
    pub fn new(n: usize, engine: Arc<dyn TaskEngine>) -> Self {
        let (reply_tx, results) = channel::<WorkerReply>();
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for worker_id in 0..n {
            let (tx, rx) = channel::<WorkerMsg>();
            let engine = Arc::clone(&engine);
            let reply_tx = reply_tx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("fcdcc-worker-{worker_id}"))
                    .spawn(move || worker_loop(worker_id, engine, rx, reply_tx))
                    .expect("spawn worker"),
            );
            senders.push(tx);
        }
        Self {
            n,
            senders,
            results,
            handles,
            next_job: 1,
            collect_timeout: Duration::from_secs(60),
            jobs: BTreeMap::new(),
            watermark_sent: 0,
        }
    }

    /// Spawn `n` workers on the default engine: im2col with per-slab
    /// patch-matrix reuse ([`Im2colEngine`]) — the optimized production
    /// path. `DirectEngine` stays available as the correctness oracle.
    pub fn with_default_engine(n: usize) -> Self {
        Self::new(n, Arc::new(Im2colEngine))
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of jobs currently collecting replies.
    pub fn in_flight(&self) -> usize {
        self.jobs
            .values()
            .filter(|j| matches!(j.phase, JobPhase::Collecting))
            .count()
    }

    /// Batch-1 convenience over [`Self::submit_batch`].
    pub fn submit(
        &mut self,
        plan: &FcdccPlan,
        x: &Tensor3,
        coded_filters: &[ResidentFilters],
        straggler: &StragglerModel,
        rng: &mut Rng,
    ) -> Result<JobHandle> {
        self.submit_batch(plan, &[x], coded_filters, straggler, rng)
    }

    /// Encode one job carrying a batch of samples against `plan`,
    /// dispatch the coded subtasks to all n workers, and register the
    /// job in the in-flight table — non-blocking. Each worker convolves
    /// its slab pairs once per sample; the whole batch completes (or
    /// times out) as one unit. `coded_filters` are the per-worker
    /// resident filter slabs (plus their prepacked GEMM operands) from
    /// `plan.encode_filters` (encoded once at model load, per the
    /// paper's steady-state model).
    pub fn submit_batch(
        &mut self,
        plan: &FcdccPlan,
        xs: &[&Tensor3],
        coded_filters: &[ResidentFilters],
        straggler: &StragglerModel,
        rng: &mut Rng,
    ) -> Result<JobHandle> {
        assert_eq!(coded_filters.len(), self.n, "filters for every worker");
        assert_eq!(plan.spec().n, self.n, "plan/cluster n mismatch");
        ensure!(!xs.is_empty(), "submit_batch: empty batch");
        let batch = xs.len();
        let job_id = self.next_job;
        self.next_job += 1;

        // --- Encode phase (master): the fused single-pass batch encoder
        // (no padded intermediate, no partition copies; the per-worker
        // fills fan out on the shared compute pool).
        let t0 = Instant::now();
        let coded_inputs = plan.encode_input_batch(xs);
        let payloads = plan.make_payloads(coded_inputs, coded_filters);
        let encode_secs = t0.elapsed().as_secs_f64();
        let upload_entries: usize = payloads.iter().map(|p| p.upload_entries()).sum();

        // --- Dispatch with straggler fates.
        let fates = straggler.draw(self.n, rng);
        let dispatched_at = Instant::now();
        for (payload, fate) in payloads.into_iter().zip(fates.iter()) {
            let wid = payload.worker_id;
            self.senders[wid]
                .send(WorkerMsg::Task {
                    job_id,
                    payload: Box::new(payload),
                    fate: *fate,
                })
                .with_context(|| format!("worker {wid} channel closed"))?;
        }

        let concurrent_jobs = 1 + self.in_flight();
        self.jobs.insert(
            job_id,
            InFlight {
                delta: plan.delta(),
                batch,
                replies: Vec::with_capacity(plan.delta()),
                phase: JobPhase::Collecting,
                deadline: dispatched_at + self.collect_timeout,
                dispatched_at,
                encode_secs,
                upload_entries,
                concurrent_jobs,
            },
        );
        Ok(JobHandle { job_id })
    }

    /// Batch-1 convenience over [`Self::wait_batch`].
    pub fn wait(&mut self, plan: &FcdccPlan, handle: JobHandle) -> Result<(Tensor3, JobReport)> {
        let (mut outputs, report) = self.wait_batch(plan, handle)?;
        ensure!(
            outputs.len() == 1,
            "wait: job {} carries a batch of {}, use wait_batch",
            report.job_id,
            outputs.len()
        );
        Ok((outputs.pop().expect("one sample"), report))
    }

    /// Block until the job behind `handle` has its first δ results, then
    /// decode every sample of the batch (one recovery inversion, reused)
    /// and report. Replies for *other* in-flight jobs arriving in the
    /// meantime are routed into the table, never dropped. `plan` must be
    /// the plan the job was submitted with. A timeout fails the whole
    /// batch — the caller owns fanning the error out to the member
    /// requests — and leaves every other in-flight job untouched.
    pub fn wait_batch(
        &mut self,
        plan: &FcdccPlan,
        handle: JobHandle,
    ) -> Result<(Vec<Tensor3>, JobReport)> {
        let job_id = handle.job_id;
        loop {
            self.drain_ready()?;
            self.expire_deadlines();
            let Some(job) = self.jobs.get(&job_id) else {
                bail!("job {job_id} is not in flight");
            };
            let (phase, got, delta, deadline) =
                (job.phase, job.replies.len(), job.delta, job.deadline);
            match phase {
                JobPhase::Done { .. } => break,
                JobPhase::TimedOut => {
                    let job = self.remove_job(job_id);
                    // The partial replies are useless now; return their
                    // block buffers before failing the batch.
                    for r in job.replies {
                        r.result.recycle();
                    }
                    let batch = job.batch;
                    bail!(
                        "job {job_id}: timed out with {got}/{delta} results \
                         (>{} workers failed?); all {batch} member sample(s) fail",
                        self.n - delta
                    );
                }
                JobPhase::Collecting => {
                    let wait_for = deadline.saturating_duration_since(Instant::now());
                    match self.results.recv_timeout(wait_for) {
                        Ok(r) => self.route(r),
                        // The loop re-checks this job's deadline.
                        Err(RecvTimeoutError::Timeout) => {}
                        Err(RecvTimeoutError::Disconnected) => bail!("all workers gone"),
                    }
                }
            }
        }

        let mut job = self.remove_job(job_id);
        let JobPhase::Done { collect_secs } = job.phase else {
            unreachable!("loop exits only on Done");
        };
        ensure!(
            plan.delta() == job.delta,
            "job {job_id}: wait() called with a different plan (delta {} vs submitted {})",
            plan.delta(),
            job.delta
        );
        // First-δ semantics: the δ earliest arrivals were kept; order them
        // by worker id so decoding is deterministic for a fixed reply set.
        // Any replies past δ (impossible today — routing stops at δ —
        // but kept defensive) are recycled, not silently dropped.
        if job.replies.len() > job.delta {
            for r in job.replies.drain(job.delta..) {
                r.result.recycle();
            }
        }
        job.replies.sort_by_key(|r| r.worker_id);

        // --- Decode phase (master): one recovery inversion (cached),
        // reused across every sample of the batch.
        let t2 = Instant::now();
        let results: Vec<&crate::fcdcc::WorkerResult> =
            job.replies.iter().map(|r| &r.result).collect();
        let outputs = plan.decode_batch_refs(&results);
        let decode_secs = t2.elapsed().as_secs_f64();

        let download_entries = results.iter().map(|r| r.download_entries()).sum();
        drop(results);
        let used_workers: Vec<usize> = job.replies.iter().map(|r| r.worker_id).collect();
        let sim_makespan_secs = job
            .replies
            .iter()
            .map(|r| r.delay_secs + r.compute_secs)
            .fold(0.0, f64::max);
        let mean_compute_secs =
            job.replies.iter().map(|r| r.compute_secs).sum::<f64>() / job.replies.len() as f64;
        // Decoded (or failed): either way the coded blocks are spent —
        // return their buffers to the plan arena before reporting.
        for r in job.replies {
            r.result.recycle();
        }
        let outputs = outputs?;

        Ok((
            outputs,
            JobReport {
                job_id,
                n: self.n,
                delta: job.delta,
                used_workers,
                encode_secs: job.encode_secs,
                collect_secs,
                decode_secs,
                sim_makespan_secs,
                mean_compute_secs,
                upload_entries: job.upload_entries,
                download_entries,
                concurrent_jobs: job.concurrent_jobs,
                batch: job.batch,
            },
        ))
    }

    /// Non-blocking poll: true once the job has either collected its δ
    /// replies or timed out, i.e. once `wait` would return immediately.
    pub fn job_ready(&mut self, handle: &JobHandle) -> Result<bool> {
        self.drain_ready()?;
        self.expire_deadlines();
        match self.jobs.get(&handle.job_id) {
            Some(j) => Ok(!matches!(j.phase, JobPhase::Collecting)),
            None => bail!("job {} is not in flight", handle.job_id),
        }
    }

    /// Run one coded convolution job end to end (submit + wait) — the
    /// blocking single-job path.
    pub fn run_job(
        &mut self,
        plan: &FcdccPlan,
        x: &Tensor3,
        coded_filters: &[ResidentFilters],
        straggler: &StragglerModel,
        rng: &mut Rng,
    ) -> Result<(Tensor3, JobReport)> {
        let handle = self.submit(plan, x, coded_filters, straggler, rng)?;
        self.wait(plan, handle)
    }

    /// Route one reply into the in-flight table. Replies for settled jobs
    /// (already decoded, timed out, or superseded) are **recycled** —
    /// their block buffers return to the plan arena — and then dropped;
    /// that is the demultiplexer's stale-result filter. Under
    /// `StragglerModel::None` this is the common fate of n−δ replies per
    /// job, so without the recycle the arena would leak every job.
    fn route(&mut self, reply: WorkerReply) {
        let job_id = reply.job_id;
        // Collection ends when the δ-th reply was *sent*, not when the
        // master got around to draining it — under pipelined serving the
        // two differ by arbitrary scheduler work.
        let sent_at = reply.sent_at;
        let mut finished = false;
        let mut stale = Some(reply);
        if let Some(job) = self.jobs.get_mut(&job_id) {
            if matches!(job.phase, JobPhase::Collecting) {
                job.replies.push(stale.take().expect("reply routed once"));
                if job.replies.len() >= job.delta {
                    job.phase = JobPhase::Done {
                        collect_secs: sent_at
                            .saturating_duration_since(job.dispatched_at)
                            .as_secs_f64(),
                    };
                    finished = true;
                }
            }
        }
        if let Some(r) = stale {
            r.result.recycle();
        }
        if finished {
            // Cancel the stragglers' superseded subtasks so their injected
            // delays don't cascade into the other in-flight jobs.
            self.broadcast_cancel(job_id);
        }
    }

    /// Drain every reply that is already buffered, without blocking.
    fn drain_ready(&mut self) -> Result<()> {
        loop {
            match self.results.try_recv() {
                Ok(r) => self.route(r),
                Err(TryRecvError::Empty) => return Ok(()),
                Err(TryRecvError::Disconnected) => bail!("all workers gone"),
            }
        }
    }

    /// Mark jobs whose per-job deadline has passed as timed out and tell
    /// the workers to drop their subtasks. Other in-flight jobs are
    /// untouched — one job blowing its deadline never poisons the rest.
    fn expire_deadlines(&mut self) {
        let now = Instant::now();
        let expired: Vec<u64> = self
            .jobs
            .iter()
            .filter(|(_, j)| matches!(j.phase, JobPhase::Collecting) && now >= j.deadline)
            .map(|(&id, _)| id)
            .collect();
        for id in expired {
            if let Some(j) = self.jobs.get_mut(&id) {
                j.phase = JobPhase::TimedOut;
            }
            self.broadcast_cancel(id);
        }
    }

    /// Remove a settled job from the table and, if the smallest
    /// outstanding id advanced, raise the workers' prune watermark.
    fn remove_job(&mut self, job_id: u64) -> InFlight {
        let job = self.jobs.remove(&job_id).expect("job in table");
        let watermark = self.jobs.keys().next().map_or(self.next_job - 1, |&m| m - 1);
        if watermark > self.watermark_sent {
            self.watermark_sent = watermark;
            for tx in &self.senders {
                let _ = tx.send(WorkerMsg::CancelUpTo(watermark));
            }
        }
        job
    }

    fn broadcast_cancel(&self, job_id: u64) {
        for tx in &self.senders {
            let _ = tx.send(WorkerMsg::Cancel(job_id));
        }
    }

    /// Graceful shutdown: tell every worker to exit and join the threads.
    pub fn shutdown(self) {
        for tx in &self.senders {
            let _ = tx.send(WorkerMsg::Shutdown);
        }
        for h in self.handles {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::DirectEngine;
    use crate::model::ConvLayer;
    use crate::tensor::{conv2d, Tensor4};
    use crate::util::mse;

    fn small_setup() -> (ConvLayer, Tensor3, Tensor4) {
        let layer = ConvLayer::new("t", 2, 12, 10, 8, 3, 3, 1, 0);
        let mut rng = Rng::new(71);
        let x = Tensor3::random(2, 12, 10, &mut rng);
        let k = Tensor4::random(8, 2, 3, 3, &mut rng);
        (layer, x, k)
    }

    #[test]
    fn cluster_job_matches_reference() {
        let (layer, x, k) = small_setup();
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap(); // delta=2
        let coded_filters = plan.encode_filters(&k);
        let mut cluster = Cluster::new(4, Arc::new(DirectEngine));
        let mut rng = Rng::new(1);
        let (y, report) = cluster
            .run_job(&plan, &x, &coded_filters, &StragglerModel::None, &mut rng)
            .unwrap();
        cluster.shutdown();
        let want = conv2d(&x, &k, layer.params());
        assert!(mse(&y.data, &want.data) < 1e-20);
        assert_eq!(report.delta, 2);
        assert_eq!(report.used_workers.len(), 2);
        assert_eq!(report.concurrent_jobs, 1);
        assert!(report.upload_entries > 0);
        assert!(report.download_entries > 0);
    }

    #[test]
    fn batched_job_matches_reference_per_sample() {
        let (layer, _x, k) = small_setup();
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap(); // delta=2
        let coded_filters = plan.encode_filters(&k);
        let mut cluster = Cluster::new(4, Arc::new(DirectEngine));
        let mut rng = Rng::new(9);
        let xs: Vec<Tensor3> = (0..3).map(|_| Tensor3::random(2, 12, 10, &mut rng)).collect();
        let refs: Vec<&Tensor3> = xs.iter().collect();
        let handle = cluster
            .submit_batch(&plan, &refs, &coded_filters, &StragglerModel::None, &mut rng)
            .unwrap();
        let (ys, report) = cluster.wait_batch(&plan, handle).unwrap();
        cluster.shutdown();
        assert_eq!(report.batch, 3);
        assert_eq!(ys.len(), 3);
        for (x, y) in xs.iter().zip(&ys) {
            let want = conv2d(x, &k, layer.params());
            assert!(mse(&y.data, &want.data) < 1e-20, "sample decoded wrong");
        }
        // The whole batch shares one decode: exactly one inversion.
        assert_eq!(plan.inverse_cache().misses(), 1);
    }

    #[test]
    fn default_engine_cluster_matches_reference() {
        // The default worker engine is the fused im2col path; it must
        // agree with the direct-conv oracle end to end.
        let (layer, x, k) = small_setup();
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap();
        let coded_filters = plan.encode_filters(&k);
        let mut cluster = Cluster::with_default_engine(4);
        let mut rng = Rng::new(12);
        let (y, _) = cluster
            .run_job(&plan, &x, &coded_filters, &StragglerModel::None, &mut rng)
            .unwrap();
        cluster.shutdown();
        let want = conv2d(&x, &k, layer.params());
        assert!(mse(&y.data, &want.data) < 1e-18);
    }

    #[test]
    fn tolerates_up_to_gamma_failures() {
        let (layer, x, k) = small_setup();
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 5).unwrap(); // delta=2, gamma=3
        let coded_filters = plan.encode_filters(&k);
        let mut cluster = Cluster::new(5, Arc::new(DirectEngine));
        let mut rng = Rng::new(2);
        let (y, _) = cluster
            .run_job(
                &plan,
                &x,
                &coded_filters,
                &StragglerModel::Failures { count: 3 },
                &mut rng,
            )
            .unwrap();
        cluster.shutdown();
        let want = conv2d(&x, &k, layer.params());
        assert!(mse(&y.data, &want.data) < 1e-18);
    }

    #[test]
    fn too_many_failures_times_out() {
        let (layer, x, k) = small_setup();
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap(); // delta=2, gamma=2
        let coded_filters = plan.encode_filters(&k);
        let mut cluster = Cluster::new(4, Arc::new(DirectEngine));
        cluster.collect_timeout = Duration::from_millis(200);
        let mut rng = Rng::new(3);
        let r = cluster.run_job(
            &plan,
            &x,
            &coded_filters,
            &StragglerModel::Failures { count: 3 },
            &mut rng,
        );
        cluster.shutdown();
        assert!(r.is_err());
    }

    #[test]
    fn stragglers_do_not_block_completion() {
        let (layer, x, k) = small_setup();
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap(); // delta=2, gamma=2
        let coded_filters = plan.encode_filters(&k);
        let mut cluster = Cluster::new(4, Arc::new(DirectEngine));
        let mut rng = Rng::new(4);
        let t0 = Instant::now();
        let (_, report) = cluster
            .run_job(
                &plan,
                &x,
                &coded_filters,
                &StragglerModel::FixedCount {
                    count: 2,
                    delay: Duration::from_millis(300),
                },
                &mut rng,
            )
            .unwrap();
        let wall = t0.elapsed();
        cluster.shutdown();
        // The two prompt workers suffice; we must not have waited ~300ms.
        assert!(
            wall < Duration::from_millis(250),
            "took {wall:?}, straggler delay leaked into the critical path"
        );
        assert_eq!(report.used_workers.len(), 2);
    }

    #[test]
    fn back_to_back_jobs_ignore_stale_results() {
        let (layer, x, k) = small_setup();
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap();
        let coded_filters = plan.encode_filters(&k);
        let mut cluster = Cluster::new(4, Arc::new(DirectEngine));
        let mut rng = Rng::new(5);
        let want = conv2d(&x, &k, layer.params());
        for _ in 0..3 {
            let (y, _) = cluster
                .run_job(&plan, &x, &coded_filters, &StragglerModel::None, &mut rng)
                .unwrap();
            assert!(mse(&y.data, &want.data) < 1e-18);
        }
        cluster.shutdown();
    }

    #[test]
    fn overlapping_jobs_wait_in_any_order() {
        let (layer, x, k) = small_setup();
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap(); // delta=2
        let coded_filters = plan.encode_filters(&k);
        let mut cluster = Cluster::new(4, Arc::new(DirectEngine));
        let mut rng = Rng::new(6);
        let want = conv2d(&x, &k, layer.params());
        let handles: Vec<JobHandle> = (0..3)
            .map(|_| {
                cluster
                    .submit(&plan, &x, &coded_filters, &StragglerModel::None, &mut rng)
                    .unwrap()
            })
            .collect();
        assert_eq!(cluster.in_flight(), 3);
        // Waiting in reverse forces the collector to demultiplex replies
        // of the not-yet-waited jobs into the in-flight table.
        for handle in handles.into_iter().rev() {
            let (y, report) = cluster.wait(&plan, handle).unwrap();
            assert!(mse(&y.data, &want.data) < 1e-18);
            assert!(report.concurrent_jobs >= 1);
        }
        assert_eq!(cluster.in_flight(), 0);
        cluster.shutdown();
    }
}
