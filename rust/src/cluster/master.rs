//! The master node: owns the worker pool and a job-oriented runtime.
//!
//! [`Cluster::submit_batch`] is non-blocking: it encodes a **batch** of
//! samples into one coded job, dispatches, and registers the job in a
//! per-job in-flight table (keyed by `job_id`, first-δ completion,
//! per-job deadline) — job_id = batch, so the table, collector, and
//! cancellation protocol are untouched by batching. A collector
//! demultiplexes every [`WorkerReply`] coming off the shared result
//! channel into that table, so **any number of jobs overlap on the same
//! worker pool** — e.g. conv layers of different serving requests.
//! [`Cluster::wait_batch`] blocks until one job is decodable (routing
//! other jobs' replies while it waits) and returns its per-sample
//! outputs + [`JobReport`]; a timed-out job fails **all** of its member
//! samples in one error without touching the other in-flight jobs.
//! [`Cluster::try_wait_batch`] is the non-bailing variant: it reports a
//! failed job as a [`BatchOutcome::Failed`] value instead of an error,
//! which is what the serving layer's retry/degradation logic consumes.
//! [`Cluster::submit`]/[`Cluster::wait`] are the batch-1 conveniences,
//! and [`Cluster::run_job`] is submit+wait for single-job callers. Every
//! phase is accounted (paper §II-C phases and §VI metrics).
//!
//! Fault tolerance lives here too: the cluster owns a deterministic
//! [`FaultPlan`] overlaid on every dispatch, validates each reply's
//! integrity checksum (rejecting corrupt blocks before they reach the
//! decoder), fails a job fast once error replies make δ unreachable,
//! and feeds every observation — valid reply, error reply, corrupt
//! reply, missed deadline — into a [`HealthTracker`] whose live set the
//! serving layer re-plans against. Re-planned jobs dispatch through
//! [`Cluster::submit_batch_mapped`], which maps the plan's coded
//! columns onto an arbitrary subset of physical workers.

use crate::cluster::health::{HealthPolicy, HealthTracker};
use crate::cluster::straggler::{FaultPlan, StragglerModel};
use crate::cluster::transport::{ChannelTransport, Transport, TransportEvent};
use crate::cluster::worker::{result_checksum, ReplyBody, WorkerMsg, WorkerReply};
use crate::engine::{Im2colEngine, TaskEngine};
use crate::fcdcc::{FcdccPlan, ResidentFilters, WorkerResult};
use crate::metrics::MembershipCounters;
use crate::tensor::Tensor3;
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Result};
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Per-job metrics (the rows of Table III and the points of Figs. 5–6).
#[derive(Clone, Debug)]
pub struct JobReport {
    pub job_id: u64,
    pub n: usize,
    pub delta: usize,
    /// Physical worker ids whose results were used for decoding: the
    /// first δ to arrive, ordered by coded column (so decoding is
    /// deterministic for a fixed reply set).
    pub used_workers: Vec<usize>,
    /// Master-side input encoding time (APCP partition + CRME combine).
    pub encode_secs: f64,
    /// Wall-clock from dispatch to δ-th arrival (measured; serialized on
    /// a 1-vCPU testbed, see `sim_makespan_secs` for the parallel view).
    pub collect_secs: f64,
    /// Master-side decode time: recovery inversion + blockwise combine +
    /// merge (the paper's "Decode (ms)" column).
    pub decode_secs: f64,
    /// Simulated parallel makespan: the δ-th smallest per-worker
    /// (injected delay + compute) — what an actually-parallel cluster
    /// would observe; the quantity plotted in Figs. 5–6.
    pub sim_makespan_secs: f64,
    /// Mean pure compute time over used workers.
    pub mean_compute_secs: f64,
    /// Tensor entries uploaded to all n workers (coded input slabs).
    pub upload_entries: usize,
    /// Tensor entries downloaded from the δ used workers.
    pub download_entries: usize,
    /// Jobs in flight on the pool when this one was dispatched
    /// (including itself): 1 = sequential, >1 = pipelined.
    pub concurrent_jobs: usize,
    /// Samples carried by this job (1 = unbatched).
    pub batch: usize,
    /// Error replies (explicit failures + rejected corrupt replies)
    /// observed on this job before it completed.
    pub errors: usize,
}

/// Handle to a submitted job. Consume it with [`Cluster::wait`]; every
/// submitted job should eventually be waited on (abandoned handles keep
/// a slot in the in-flight table alive).
#[must_use = "wait() on the handle to collect the job's output"]
pub struct JobHandle {
    job_id: u64,
}

impl JobHandle {
    pub fn job_id(&self) -> u64 {
        self.job_id
    }
}

/// Collection state of one in-flight job.
#[derive(Clone, Copy)]
enum JobPhase {
    /// Fewer than δ replies so far.
    Collecting,
    /// δ replies arrived; `collect_secs` is dispatch → δ-th arrival.
    Done { collect_secs: f64 },
    /// The per-job deadline passed before δ replies arrived.
    TimedOut,
    /// Enough workers replied with errors (or corrupt blocks) that δ
    /// valid results can no longer arrive — failed fast, ahead of the
    /// deadline.
    Undecodable,
}

/// How a waited-on job ended: decoded output, or a failure the caller
/// can retry / degrade on without unwinding through an `Err`.
pub enum BatchOutcome {
    Decoded {
        outputs: Vec<Tensor3>,
        report: JobReport,
    },
    /// δ valid replies never arrived (deadline, or too many errors).
    /// The job is out of the in-flight table and every buffer it held
    /// has been recycled.
    Failed {
        got: usize,
        needed: usize,
        batch: usize,
        reason: String,
    },
}

/// One row of the in-flight table.
struct InFlight {
    delta: usize,
    batch: usize,
    /// Valid (checksum-passing) replies only.
    replies: Vec<WorkerReply>,
    /// Physical ids that answered with an error or a corrupt reply.
    errors: Vec<usize>,
    /// Physical worker id per coded column, as dispatched.
    dispatched_to: Vec<usize>,
    phase: JobPhase,
    deadline: Instant,
    dispatched_at: Instant,
    encode_secs: f64,
    upload_entries: usize,
    concurrent_jobs: usize,
}

/// A pool of workers behind a [`Transport`] plus the demultiplexing
/// collector.
pub struct Cluster {
    n: usize,
    transport: Box<dyn Transport>,
    next_job: u64,
    /// Per-job collection timeout (guards against >γ failures). Applied
    /// at submit time: changing it affects subsequently submitted jobs.
    pub collect_timeout: Duration,
    /// In-flight table: job id → collection state. A `BTreeMap` so the
    /// smallest outstanding id (the workers' prune watermark) is cheap.
    jobs: BTreeMap<u64, InFlight>,
    watermark_sent: u64,
    /// Deterministic fault injection overlaid on every dispatch.
    fault_plan: FaultPlan,
    /// Per-worker health fed by reply/timeout observations.
    health: HealthTracker,
}

impl Cluster {
    /// Spawn `n` in-process workers all running the same conv engine —
    /// the default [`ChannelTransport`] pool.
    pub fn new(n: usize, engine: Arc<dyn TaskEngine>) -> Self {
        Self::with_transport(Box::new(ChannelTransport::spawn(n, engine)))
    }

    /// Build a cluster over an already-connected transport (e.g. a
    /// [`TcpTransport`](crate::cluster::tcp::TcpTransport) driving real
    /// remote worker processes).
    pub fn with_transport(transport: Box<dyn Transport>) -> Self {
        let n = transport.n();
        Self {
            n,
            transport,
            next_job: 1,
            collect_timeout: Duration::from_secs(60),
            jobs: BTreeMap::new(),
            watermark_sent: 0,
            fault_plan: FaultPlan::none(),
            health: HealthTracker::new(n, HealthPolicy::default()),
        }
    }

    /// Spawn `n` workers on the default engine: im2col with per-slab
    /// patch-matrix reuse ([`Im2colEngine`]) — the optimized production
    /// path. `DirectEngine` stays available as the correctness oracle.
    pub fn with_default_engine(n: usize) -> Self {
        Self::new(n, Arc::new(Im2colEngine))
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Install a deterministic fault-injection plan. Applies to
    /// subsequently dispatched tasks; per-worker dispatch counters start
    /// at the plan's own state (fresh plans start at zero).
    pub fn set_fault_plan(&mut self, plan: FaultPlan) {
        self.fault_plan = plan;
    }

    /// Replace the health tracker with a fresh one under `policy`
    /// (forgetting all prior observations).
    pub fn set_health_policy(&mut self, policy: HealthPolicy) {
        self.health = HealthTracker::new(self.n, policy);
    }

    /// The worker-health tracker (read side: states, live set, counters).
    pub fn health(&self) -> &HealthTracker {
        &self.health
    }

    /// Membership/transport counters (all-zero on the in-process
    /// channel transport, which has no membership protocol).
    pub fn membership_counters(&self) -> MembershipCounters {
        self.transport.counters()
    }

    /// Physical worker ids currently in the dispatch set (everything not
    /// quarantined), ascending.
    pub fn live_workers(&self) -> Vec<usize> {
        self.health.live_set()
    }

    /// Number of jobs currently collecting replies.
    pub fn in_flight(&self) -> usize {
        self.jobs
            .values()
            .filter(|j| matches!(j.phase, JobPhase::Collecting))
            .count()
    }

    /// Batch-1 convenience over [`Self::submit_batch`].
    pub fn submit(
        &mut self,
        plan: &FcdccPlan,
        x: &Tensor3,
        coded_filters: &[ResidentFilters],
        straggler: &StragglerModel,
        rng: &mut Rng,
    ) -> Result<JobHandle> {
        self.submit_batch(plan, &[x], coded_filters, straggler, rng)
    }

    /// Encode one job carrying a batch of samples against `plan`,
    /// dispatch the coded subtasks to all n workers, and register the
    /// job in the in-flight table — non-blocking. Each worker convolves
    /// its slab pairs once per sample; the whole batch completes (or
    /// times out) as one unit. `coded_filters` are the per-worker
    /// resident filter slabs (plus their prepacked GEMM operands) from
    /// `plan.encode_filters` (encoded once at model load, per the
    /// paper's steady-state model).
    pub fn submit_batch(
        &mut self,
        plan: &FcdccPlan,
        xs: &[&Tensor3],
        coded_filters: &[ResidentFilters],
        straggler: &StragglerModel,
        rng: &mut Rng,
    ) -> Result<JobHandle> {
        self.submit_batch_mapped(plan, xs, coded_filters, straggler, rng, None)
    }

    /// [`Self::submit_batch`] with an explicit coded-column → physical
    /// worker mapping — the re-planning dispatch path. `worker_map[i]`
    /// is the physical worker that computes coded column `i` of a plan
    /// built for `worker_map.len()` (≤ n) workers; `None` is the
    /// identity full-cluster mapping. Decode is untouched: result
    /// blocks keep their coded column index, only the wire address
    /// changes.
    pub fn submit_batch_mapped(
        &mut self,
        plan: &FcdccPlan,
        xs: &[&Tensor3],
        coded_filters: &[ResidentFilters],
        straggler: &StragglerModel,
        rng: &mut Rng,
        worker_map: Option<&[usize]>,
    ) -> Result<JobHandle> {
        let n_coded = plan.spec().n;
        assert_eq!(coded_filters.len(), n_coded, "filters for every coded column");
        match worker_map {
            None => assert_eq!(n_coded, self.n, "plan/cluster n mismatch"),
            Some(map) => {
                assert_eq!(map.len(), n_coded, "one physical worker per coded column");
                assert!(
                    map.iter().all(|&w| w < self.n),
                    "worker map targets a worker outside the pool"
                );
            }
        }
        ensure!(!xs.is_empty(), "submit_batch: empty batch");
        let batch = xs.len();
        let job_id = self.next_job;
        self.next_job += 1;

        // --- Encode phase (master): the fused single-pass batch encoder
        // (no padded intermediate, no partition copies; the per-worker
        // fills fan out on the shared compute pool).
        let t0 = Instant::now();
        let coded_inputs = plan.encode_input_batch(xs);
        let payloads = plan.make_payloads(coded_inputs, coded_filters);
        let encode_secs = t0.elapsed().as_secs_f64();
        let upload_entries: usize = payloads.iter().map(|p| p.upload_entries()).sum();

        // --- Dispatch with straggler fates (per-job draw) overlaid by
        // the persistent fault plan (keyed by physical worker id).
        let fates = straggler.draw(n_coded, rng);
        let dispatched_at = Instant::now();
        let mut dispatched_to = Vec::with_capacity(n_coded);
        let mut failed_sends = Vec::new();
        for (payload, fate) in payloads.into_iter().zip(fates.iter()) {
            let coded = payload.worker_id;
            let wid = worker_map.map_or(coded, |m| m[coded]);
            let fate = self.fault_plan.fate_for_dispatch(wid, *fate);
            dispatched_to.push(wid);
            // A dead peer fails *this column*, not the whole submit:
            // the transport recycled the payload, and the failure is
            // charged to the job below (an unreachable worker is an
            // error reply that arrived instantly). The coded scheme
            // absorbs up to γ of these like any other fault.
            if self
                .transport
                .send(
                    wid,
                    WorkerMsg::Task {
                        job_id,
                        payload: Box::new(payload),
                        fate,
                    },
                )
                .is_err()
            {
                failed_sends.push(wid);
            }
        }
        self.health.tick_job();

        let concurrent_jobs = 1 + self.in_flight();
        self.jobs.insert(
            job_id,
            InFlight {
                delta: plan.delta(),
                batch,
                replies: Vec::with_capacity(plan.delta()),
                errors: Vec::new(),
                dispatched_to,
                phase: JobPhase::Collecting,
                deadline: dispatched_at + self.collect_timeout,
                dispatched_at,
                encode_secs,
                upload_entries,
                concurrent_jobs,
            },
        );
        for wid in failed_sends {
            self.note_job_error(job_id, wid);
        }
        Ok(JobHandle { job_id })
    }

    /// Batch-1 convenience over [`Self::wait_batch`].
    pub fn wait(&mut self, plan: &FcdccPlan, handle: JobHandle) -> Result<(Tensor3, JobReport)> {
        let (mut outputs, report) = self.wait_batch(plan, handle)?;
        ensure!(
            outputs.len() == 1,
            "wait: job {} carries a batch of {}, use wait_batch",
            report.job_id,
            outputs.len()
        );
        Ok((outputs.pop().expect("one sample"), report))
    }

    /// Block until the job behind `handle` has its first δ results, then
    /// decode every sample of the batch (one recovery inversion, reused)
    /// and report. Replies for *other* in-flight jobs arriving in the
    /// meantime are routed into the table, never dropped. `plan` must be
    /// the plan the job was submitted with. A timeout fails the whole
    /// batch — the caller owns fanning the error out to the member
    /// requests — and leaves every other in-flight job untouched.
    pub fn wait_batch(
        &mut self,
        plan: &FcdccPlan,
        handle: JobHandle,
    ) -> Result<(Vec<Tensor3>, JobReport)> {
        let job_id = handle.job_id;
        match self.try_wait_batch(plan, handle)? {
            BatchOutcome::Decoded { outputs, report } => Ok((outputs, report)),
            BatchOutcome::Failed {
                got,
                needed,
                batch,
                reason,
            } => bail!(
                "job {job_id}: {reason} — {got}/{needed} usable results; \
                 all {batch} member sample(s) fail"
            ),
        }
    }

    /// [`Self::wait_batch`] that reports job failure as a value instead
    /// of an error: the retry/degradation layer treats a timed-out or
    /// undecodable job as a scheduling outcome, not a crash. Real
    /// runtime errors (worker pool gone, decode failure on valid
    /// replies, unknown job) still surface as `Err`.
    pub fn try_wait_batch(&mut self, plan: &FcdccPlan, handle: JobHandle) -> Result<BatchOutcome> {
        let job_id = handle.job_id;
        loop {
            self.drain_ready()?;
            self.expire_deadlines();
            let Some(job) = self.jobs.get(&job_id) else {
                bail!("job {job_id} is not in flight");
            };
            let (phase, got, delta, deadline) =
                (job.phase, job.replies.len(), job.delta, job.deadline);
            match phase {
                JobPhase::Done { .. } => break,
                JobPhase::TimedOut | JobPhase::Undecodable => {
                    let job = self.remove_job(job_id);
                    // The partial replies are useless now; return their
                    // block buffers before failing the batch.
                    for r in job.replies {
                        r.body.recycle();
                    }
                    let reason = match phase {
                        JobPhase::TimedOut => format!(
                            "timed out with {got}/{delta} results (>{} workers failed?)",
                            job.dispatched_to.len().saturating_sub(delta)
                        ),
                        _ => format!(
                            "undecodable: {} of {} workers replied with errors",
                            job.errors.len(),
                            job.dispatched_to.len()
                        ),
                    };
                    return Ok(BatchOutcome::Failed {
                        got,
                        needed: delta,
                        batch: job.batch,
                        reason,
                    });
                }
                JobPhase::Collecting => {
                    let wait_for = deadline.saturating_duration_since(Instant::now());
                    // `None` = nothing arrived: the loop re-checks this
                    // job's deadline.
                    if let Some(ev) = self.transport.recv_timeout(wait_for)? {
                        self.on_event(ev);
                    }
                }
            }
        }

        let mut job = self.remove_job(job_id);
        let JobPhase::Done { collect_secs } = job.phase else {
            unreachable!("loop exits only on Done");
        };
        ensure!(
            plan.delta() == job.delta,
            "job {job_id}: wait() called with a different plan (delta {} vs submitted {})",
            plan.delta(),
            job.delta
        );
        // First-δ semantics: the δ earliest arrivals were kept; order
        // them by coded column so decoding is deterministic for a fixed
        // reply set (physical and coded order coincide for identity
        // maps and ascending worker maps, but coded order is the one
        // decode actually keys on). Any replies past δ (impossible
        // today — routing stops at δ — but kept defensive) are
        // recycled, not silently dropped.
        if job.replies.len() > job.delta {
            for r in job.replies.drain(job.delta..) {
                r.body.recycle();
            }
        }
        job.replies
            .sort_by_key(|r| r.body.coded_id().unwrap_or(usize::MAX));

        // --- Decode phase (master): one recovery inversion (cached),
        // reused across every sample of the batch.
        let t2 = Instant::now();
        let results: Vec<&WorkerResult> = job
            .replies
            .iter()
            .map(|r| match &r.body {
                ReplyBody::Ok { result, .. } => result,
                ReplyBody::Err(_) => unreachable!("only valid replies are kept"),
            })
            .collect();
        let outputs = plan.decode_batch_refs(&results);
        let decode_secs = t2.elapsed().as_secs_f64();

        let download_entries = results.iter().map(|r| r.download_entries()).sum();
        drop(results);
        let used_workers: Vec<usize> = job.replies.iter().map(|r| r.worker_id).collect();
        let sim_makespan_secs = job
            .replies
            .iter()
            .map(|r| r.delay_secs + r.compute_secs)
            .fold(0.0, f64::max);
        let mean_compute_secs =
            job.replies.iter().map(|r| r.compute_secs).sum::<f64>() / job.replies.len() as f64;
        // Decoded (or failed): either way the coded blocks are spent —
        // return their buffers to the plan arena before reporting.
        for r in job.replies {
            r.body.recycle();
        }
        let outputs = outputs?;

        Ok(BatchOutcome::Decoded {
            outputs,
            report: JobReport {
                job_id,
                n: self.n,
                delta: job.delta,
                used_workers,
                encode_secs: job.encode_secs,
                collect_secs,
                decode_secs,
                sim_makespan_secs,
                mean_compute_secs,
                upload_entries: job.upload_entries,
                download_entries,
                concurrent_jobs: job.concurrent_jobs,
                batch: job.batch,
                errors: job.errors.len(),
            },
        })
    }

    /// Non-blocking poll: true once the job has either collected its δ
    /// replies or failed (timeout / undecodable), i.e. once `wait` would
    /// return immediately.
    pub fn job_ready(&mut self, handle: &JobHandle) -> Result<bool> {
        self.drain_ready()?;
        self.expire_deadlines();
        match self.jobs.get(&handle.job_id) {
            Some(j) => Ok(!matches!(j.phase, JobPhase::Collecting)),
            None => bail!("job {} is not in flight", handle.job_id),
        }
    }

    /// Run one coded convolution job end to end (submit + wait) — the
    /// blocking single-job path.
    pub fn run_job(
        &mut self,
        plan: &FcdccPlan,
        x: &Tensor3,
        coded_filters: &[ResidentFilters],
        straggler: &StragglerModel,
        rng: &mut Rng,
    ) -> Result<(Tensor3, JobReport)> {
        let handle = self.submit(plan, x, coded_filters, straggler, rng)?;
        self.wait(plan, handle)
    }

    /// Apply one transport event: replies are routed into the in-flight
    /// table; membership transitions feed the health tracker and the
    /// in-flight jobs (a dead peer's silent dispatches fail fast,
    /// within one heartbeat interval, instead of running out their
    /// deadlines).
    fn on_event(&mut self, ev: TransportEvent) {
        match ev {
            TransportEvent::Reply(r) => self.route(r),
            TransportEvent::PeerDown { worker } => {
                self.health.evict(worker);
                self.note_peer_down(worker);
            }
            TransportEvent::PeerUp { worker } => self.health.readmit(worker),
        }
    }

    /// Charge a dead peer to every collecting job that dispatched to it
    /// and has heard nothing back from it: each such column can never
    /// arrive now, which is exactly an error reply's effect.
    fn note_peer_down(&mut self, worker: usize) {
        let affected: Vec<u64> = self
            .jobs
            .iter()
            .filter(|(_, j)| {
                matches!(j.phase, JobPhase::Collecting)
                    && j.dispatched_to.contains(&worker)
                    && !j.errors.contains(&worker)
                    && !j.replies.iter().any(|r| r.worker_id == worker)
            })
            .map(|(&id, _)| id)
            .collect();
        for id in affected {
            self.note_job_error(id, worker);
        }
    }

    /// Route one reply into the in-flight table. Every reply — live,
    /// stale, error, corrupt — first feeds the health tracker; error
    /// replies and checksum-failing replies are counted against their
    /// job (failing it fast once δ valid results become unreachable),
    /// and replies for settled jobs are **recycled** — their block
    /// buffers return to the plan arena — and then dropped; that is the
    /// demultiplexer's stale-result filter. Under `StragglerModel::None`
    /// this is the common fate of n−δ replies per job, so without the
    /// recycle the arena would leak every job.
    fn route(&mut self, reply: WorkerReply) {
        let job_id = reply.job_id;
        let phys = reply.worker_id;
        let valid = match &reply.body {
            ReplyBody::Err(_) => {
                self.health.observe_error(phys);
                false
            }
            ReplyBody::Ok { result, checksum } => {
                // Integrity gate: a perturbed reply must never reach the
                // decoder. The checksum was computed worker-side before
                // the (injected) corruption.
                let intact = result_checksum(result) == *checksum;
                if intact {
                    self.health.observe_ok(phys);
                } else {
                    self.health.observe_corrupt(phys);
                }
                intact
            }
        };
        if !valid {
            reply.body.recycle();
            self.note_job_error(job_id, phys);
            return;
        }
        // Collection ends when the δ-th reply was *sent*, not when the
        // master got around to draining it — under pipelined serving the
        // two differ by arbitrary scheduler work.
        let sent_at = reply.sent_at;
        let mut finished = false;
        let mut stale = Some(reply);
        if let Some(job) = self.jobs.get_mut(&job_id) {
            if matches!(job.phase, JobPhase::Collecting) {
                job.replies.push(stale.take().expect("reply routed once"));
                if job.replies.len() >= job.delta {
                    job.phase = JobPhase::Done {
                        collect_secs: sent_at
                            .saturating_duration_since(job.dispatched_at)
                            .as_secs_f64(),
                    };
                    finished = true;
                }
            }
        }
        if let Some(r) = stale {
            r.body.recycle();
        }
        if finished {
            // Cancel the stragglers' superseded subtasks so their injected
            // delays don't cascade into the other in-flight jobs.
            self.broadcast_cancel(job_id);
        }
    }

    /// Count one failed (error / corrupt) reply against its job, and
    /// fail the job fast once the remaining silent workers cannot bring
    /// the valid-reply count up to δ.
    fn note_job_error(&mut self, job_id: u64, phys: usize) {
        let mut undecodable = false;
        if let Some(job) = self.jobs.get_mut(&job_id) {
            if matches!(job.phase, JobPhase::Collecting) {
                job.errors.push(phys);
                if job.dispatched_to.len() - job.errors.len() < job.delta {
                    job.phase = JobPhase::Undecodable;
                    undecodable = true;
                }
            }
        }
        if undecodable {
            self.broadcast_cancel(job_id);
        }
    }

    /// Drain every event that is already buffered, without blocking.
    fn drain_ready(&mut self) -> Result<()> {
        while let Some(ev) = self.transport.try_recv()? {
            self.on_event(ev);
        }
        Ok(())
    }

    /// Mark jobs whose per-job deadline has passed as timed out and tell
    /// the workers to drop their subtasks. Other in-flight jobs are
    /// untouched — one job blowing its deadline never poisons the rest.
    /// Every dispatched worker that neither replied nor errored is
    /// charged a missed-deadline observation in the health tracker.
    fn expire_deadlines(&mut self) {
        let now = Instant::now();
        let expired: Vec<u64> = self
            .jobs
            .iter()
            .filter(|(_, j)| matches!(j.phase, JobPhase::Collecting) && now >= j.deadline)
            .map(|(&id, _)| id)
            .collect();
        for id in expired {
            let mut missing: Vec<usize> = Vec::new();
            if let Some(j) = self.jobs.get_mut(&id) {
                j.phase = JobPhase::TimedOut;
                missing = j
                    .dispatched_to
                    .iter()
                    .copied()
                    .filter(|w| {
                        !j.errors.contains(w) && !j.replies.iter().any(|r| r.worker_id == *w)
                    })
                    .collect();
            }
            for w in missing {
                self.health.observe_timeout(w);
            }
            self.broadcast_cancel(id);
        }
    }

    /// Remove a settled job from the table and, if the smallest
    /// outstanding id advanced, raise the workers' prune watermark.
    /// The sends are best-effort: an already-disconnected worker has
    /// nothing to prune, so a failure here is not a new fault — it is
    /// neither charged to any job nor struck against `health` (the
    /// PeerDown event already did both, exactly once).
    fn remove_job(&mut self, job_id: u64) -> InFlight {
        let job = self.jobs.remove(&job_id).expect("job in table");
        let watermark = self.jobs.keys().next().map_or(self.next_job - 1, |&m| m - 1);
        if watermark > self.watermark_sent {
            self.watermark_sent = watermark;
            for w in 0..self.n {
                let _ = self.transport.send(w, WorkerMsg::CancelUpTo(watermark));
            }
        }
        job
    }

    /// Best-effort, like the watermark in [`Self::remove_job`]: a
    /// cancel that cannot be delivered is moot.
    fn broadcast_cancel(&mut self, job_id: u64) {
        for w in 0..self.n {
            let _ = self.transport.send(w, WorkerMsg::Cancel(job_id));
        }
    }

    /// Graceful shutdown: tear the transport down (it stops its
    /// workers, joins its threads, and recycles every reply still
    /// buffered inside it), then recycle the replies parked in the
    /// in-flight table — after this, the plan arena's outstanding count
    /// is exactly zero (the buffer-hygiene invariant the failure tests
    /// assert).
    pub fn shutdown(self) {
        let Cluster {
            transport, jobs, ..
        } = self;
        transport.shutdown();
        for (_, j) in jobs {
            for r in j.replies {
                r.body.recycle();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::DirectEngine;
    use crate::model::ConvLayer;
    use crate::tensor::{conv2d, Tensor4};
    use crate::util::mse;

    fn small_setup() -> (ConvLayer, Tensor3, Tensor4) {
        let layer = ConvLayer::new("t", 2, 12, 10, 8, 3, 3, 1, 0);
        let mut rng = Rng::new(71);
        let x = Tensor3::random(2, 12, 10, &mut rng);
        let k = Tensor4::random(8, 2, 3, 3, &mut rng);
        (layer, x, k)
    }

    #[test]
    fn cluster_job_matches_reference() {
        let (layer, x, k) = small_setup();
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap(); // delta=2
        let coded_filters = plan.encode_filters(&k);
        let mut cluster = Cluster::new(4, Arc::new(DirectEngine));
        let mut rng = Rng::new(1);
        let (y, report) = cluster
            .run_job(&plan, &x, &coded_filters, &StragglerModel::None, &mut rng)
            .unwrap();
        cluster.shutdown();
        let want = conv2d(&x, &k, layer.params());
        assert!(mse(&y.data, &want.data) < 1e-20);
        assert_eq!(report.delta, 2);
        assert_eq!(report.used_workers.len(), 2);
        assert_eq!(report.concurrent_jobs, 1);
        assert_eq!(report.errors, 0);
        assert!(report.upload_entries > 0);
        assert!(report.download_entries > 0);
    }

    #[test]
    fn batched_job_matches_reference_per_sample() {
        let (layer, _x, k) = small_setup();
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap(); // delta=2
        let coded_filters = plan.encode_filters(&k);
        let mut cluster = Cluster::new(4, Arc::new(DirectEngine));
        let mut rng = Rng::new(9);
        let xs: Vec<Tensor3> = (0..3).map(|_| Tensor3::random(2, 12, 10, &mut rng)).collect();
        let refs: Vec<&Tensor3> = xs.iter().collect();
        let handle = cluster
            .submit_batch(&plan, &refs, &coded_filters, &StragglerModel::None, &mut rng)
            .unwrap();
        let (ys, report) = cluster.wait_batch(&plan, handle).unwrap();
        cluster.shutdown();
        assert_eq!(report.batch, 3);
        assert_eq!(ys.len(), 3);
        for (x, y) in xs.iter().zip(&ys) {
            let want = conv2d(x, &k, layer.params());
            assert!(mse(&y.data, &want.data) < 1e-20, "sample decoded wrong");
        }
        // The whole batch shares one decode: exactly one inversion.
        assert_eq!(plan.inverse_cache().misses(), 1);
    }

    #[test]
    fn default_engine_cluster_matches_reference() {
        // The default worker engine is the fused im2col path; it must
        // agree with the direct-conv oracle end to end.
        let (layer, x, k) = small_setup();
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap();
        let coded_filters = plan.encode_filters(&k);
        let mut cluster = Cluster::with_default_engine(4);
        let mut rng = Rng::new(12);
        let (y, _) = cluster
            .run_job(&plan, &x, &coded_filters, &StragglerModel::None, &mut rng)
            .unwrap();
        cluster.shutdown();
        let want = conv2d(&x, &k, layer.params());
        assert!(mse(&y.data, &want.data) < 1e-18);
    }

    #[test]
    fn tolerates_up_to_gamma_failures() {
        let (layer, x, k) = small_setup();
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 5).unwrap(); // delta=2, gamma=3
        let coded_filters = plan.encode_filters(&k);
        let mut cluster = Cluster::new(5, Arc::new(DirectEngine));
        let mut rng = Rng::new(2);
        let (y, _) = cluster
            .run_job(
                &plan,
                &x,
                &coded_filters,
                &StragglerModel::Failures { count: 3 },
                &mut rng,
            )
            .unwrap();
        cluster.shutdown();
        let want = conv2d(&x, &k, layer.params());
        assert!(mse(&y.data, &want.data) < 1e-18);
    }

    #[test]
    fn too_many_failures_times_out() {
        let (layer, x, k) = small_setup();
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap(); // delta=2, gamma=2
        let coded_filters = plan.encode_filters(&k);
        let mut cluster = Cluster::new(4, Arc::new(DirectEngine));
        cluster.collect_timeout = Duration::from_millis(200);
        let mut rng = Rng::new(3);
        let r = cluster.run_job(
            &plan,
            &x,
            &coded_filters,
            &StragglerModel::Failures { count: 3 },
            &mut rng,
        );
        cluster.shutdown();
        assert!(r.is_err());
    }

    #[test]
    fn stragglers_do_not_block_completion() {
        let (layer, x, k) = small_setup();
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap(); // delta=2, gamma=2
        let coded_filters = plan.encode_filters(&k);
        let mut cluster = Cluster::new(4, Arc::new(DirectEngine));
        let mut rng = Rng::new(4);
        let t0 = Instant::now();
        let (_, report) = cluster
            .run_job(
                &plan,
                &x,
                &coded_filters,
                &StragglerModel::FixedCount {
                    count: 2,
                    delay: Duration::from_millis(300),
                },
                &mut rng,
            )
            .unwrap();
        let wall = t0.elapsed();
        cluster.shutdown();
        // The two prompt workers suffice; we must not have waited ~300ms.
        assert!(
            wall < Duration::from_millis(250),
            "took {wall:?}, straggler delay leaked into the critical path"
        );
        assert_eq!(report.used_workers.len(), 2);
    }

    #[test]
    fn back_to_back_jobs_ignore_stale_results() {
        let (layer, x, k) = small_setup();
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap();
        let coded_filters = plan.encode_filters(&k);
        let mut cluster = Cluster::new(4, Arc::new(DirectEngine));
        let mut rng = Rng::new(5);
        let want = conv2d(&x, &k, layer.params());
        for _ in 0..3 {
            let (y, _) = cluster
                .run_job(&plan, &x, &coded_filters, &StragglerModel::None, &mut rng)
                .unwrap();
            assert!(mse(&y.data, &want.data) < 1e-18);
        }
        cluster.shutdown();
    }

    #[test]
    fn overlapping_jobs_wait_in_any_order() {
        let (layer, x, k) = small_setup();
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap(); // delta=2
        let coded_filters = plan.encode_filters(&k);
        let mut cluster = Cluster::new(4, Arc::new(DirectEngine));
        let mut rng = Rng::new(6);
        let want = conv2d(&x, &k, layer.params());
        let handles: Vec<JobHandle> = (0..3)
            .map(|_| {
                cluster
                    .submit(&plan, &x, &coded_filters, &StragglerModel::None, &mut rng)
                    .unwrap()
            })
            .collect();
        assert_eq!(cluster.in_flight(), 3);
        // Waiting in reverse forces the collector to demultiplex replies
        // of the not-yet-waited jobs into the in-flight table.
        for handle in handles.into_iter().rev() {
            let (y, report) = cluster.wait(&plan, handle).unwrap();
            assert!(mse(&y.data, &want.data) < 1e-18);
            assert!(report.concurrent_jobs >= 1);
        }
        assert_eq!(cluster.in_flight(), 0);
        cluster.shutdown();
    }

    #[test]
    fn mapped_dispatch_decodes_on_a_live_subset() {
        // A plan built for 3 workers dispatched onto physical workers
        // {0, 2, 3} of a 4-worker pool: coded columns keep their index,
        // only the wire addresses change — decode must be exact.
        let (layer, x, k) = small_setup();
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 3).unwrap(); // delta=2
        let coded_filters = plan.encode_filters(&k);
        let mut cluster = Cluster::new(4, Arc::new(DirectEngine));
        let mut rng = Rng::new(13);
        let map = [0usize, 2, 3];
        let handle = cluster
            .submit_batch_mapped(
                &plan,
                &[&x],
                &coded_filters,
                &StragglerModel::None,
                &mut rng,
                Some(&map),
            )
            .unwrap();
        let (ys, report) = cluster.wait_batch(&plan, handle).unwrap();
        cluster.shutdown();
        let want = conv2d(&x, &k, layer.params());
        assert!(mse(&ys[0].data, &want.data) < 1e-18);
        // Used workers are reported by physical id, all from the map.
        assert!(report.used_workers.iter().all(|w| map.contains(w)));
    }

    #[test]
    fn all_error_replies_fail_fast_without_timeout() {
        let (layer, x, k) = small_setup();
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap(); // delta=2
        let coded_filters = plan.encode_filters(&k);
        let mut cluster = Cluster::new(4, Arc::new(DirectEngine));
        // Long timeout: only the error fail-fast can end the job quickly.
        cluster.collect_timeout = Duration::from_secs(30);
        cluster.set_fault_plan(
            (0..4).fold(FaultPlan::none(), |fp, w| {
                fp.with_fault(w, crate::cluster::straggler::FaultKind::ErrorReply { jobs: 1 })
            }),
        );
        let mut rng = Rng::new(14);
        let t0 = Instant::now();
        let err = cluster
            .run_job(&plan, &x, &coded_filters, &StragglerModel::None, &mut rng)
            .unwrap_err();
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "fail-fast should beat the 30s deadline"
        );
        assert!(err.to_string().contains("undecodable"), "err: {err:#}");
        // The workers are alive (error replies, not crashes): the same
        // cluster completes the next job, whose tasks are fault-free.
        let want = conv2d(&x, &k, layer.params());
        let (y, report) = cluster
            .run_job(&plan, &x, &coded_filters, &StragglerModel::None, &mut rng)
            .unwrap();
        assert!(mse(&y.data, &want.data) < 1e-18);
        assert_eq!(report.errors, 0);
        assert_eq!(cluster.health().counters().errors, 4);
        cluster.shutdown();
    }

    #[test]
    fn corrupt_replies_are_rejected_not_decoded() {
        let (layer, x, k) = small_setup();
        let plan = FcdccPlan::new_crme(&layer, 4, 2, 4).unwrap(); // delta=2
        let coded_filters = plan.encode_filters(&k);
        let mut cluster = Cluster::new(4, Arc::new(DirectEngine));
        cluster.set_fault_plan(FaultPlan::none().with_fault(
            0,
            crate::cluster::straggler::FaultKind::CorruptReply { jobs: u64::MAX },
        ));
        let mut rng = Rng::new(15);
        let want = conv2d(&x, &k, layer.params());
        for _ in 0..3 {
            let (y, _) = cluster
                .run_job(&plan, &x, &coded_filters, &StragglerModel::None, &mut rng)
                .unwrap();
            assert!(
                mse(&y.data, &want.data) < 1e-18,
                "a corrupt block must never reach the decoder"
            );
        }
        assert_eq!(cluster.health().counters().corruptions, 3);
        cluster.shutdown();
    }
}
