//! The wire abstraction between the master and its workers.
//!
//! [`Cluster`](crate::cluster::Cluster) speaks one duplex — send a
//! [`WorkerMsg`], receive a [`WorkerReply`] — and [`Transport`] is that
//! duplex as a trait, so the same job runtime drives either
//!
//! * [`ChannelTransport`] — the in-process worker pool over
//!   `std::sync::mpsc` (the default: deterministic, toolchain-offline,
//!   what every tier-1 test runs on), or
//! * [`TcpTransport`](crate::cluster::tcp::TcpTransport) — real remote
//!   worker processes over framed TCP with membership, heartbeats, and
//!   eviction (DESIGN.md §Transport & membership).
//!
//! Beyond replies, a transport can surface **membership events**: a
//! peer found dead ([`TransportEvent::PeerDown`]) or readmitted
//! ([`TransportEvent::PeerUp`]). The channel transport never emits
//! them — an in-process worker thread cannot vanish — so the master's
//! handling of both is exercised only by the TCP tests, while the
//! channel path behaves exactly as before this abstraction existed.

use crate::cluster::worker::{worker_loop, WorkerMsg, WorkerReply};
use crate::engine::TaskEngine;
use crate::metrics::MembershipCounters;
use anyhow::{bail, Result};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Something the master pulls off its transport.
pub enum TransportEvent {
    /// A worker's reply (valid, error, or corrupt — routing decides).
    Reply(WorkerReply),
    /// The transport declared this physical worker dead (socket error,
    /// missed heartbeats). The master quarantines it and fails its
    /// silent in-flight dispatches fast.
    PeerDown { worker: usize },
    /// A previously-dead worker reconnected and was readmitted into
    /// the membership. The master moves it back toward the live set.
    PeerUp { worker: usize },
}

/// One master-side endpoint of the cluster duplex.
pub trait Transport: Send {
    /// Number of worker slots (fixed for the transport's lifetime; the
    /// *live* subset varies underneath on membership transports).
    fn n(&self) -> usize;

    /// Send one message to a worker slot. On failure the message's
    /// payload has already been recycled (arena hygiene is the
    /// transport's job on the send path) — the caller only decides
    /// what the failure means for the job.
    fn send(&mut self, worker: usize, msg: WorkerMsg) -> Result<()>;

    /// Block up to `timeout` for the next event. `Ok(None)` = nothing
    /// arrived in time; `Err` = the transport is unusable (every
    /// worker gone).
    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<TransportEvent>>;

    /// Non-blocking variant of [`Self::recv_timeout`].
    fn try_recv(&mut self) -> Result<Option<TransportEvent>>;

    /// Membership/transport counters (all-zero on transports without a
    /// membership protocol).
    fn counters(&self) -> MembershipCounters {
        MembershipCounters::default()
    }

    /// Current membership epoch (0 on membership-less transports).
    fn epoch(&self) -> u64 {
        0
    }

    /// Tear the transport down: stop the workers it owns, join its
    /// threads, and recycle every reply still buffered inside it. After
    /// this returns, the transport holds no arena buffers.
    fn shutdown(self: Box<Self>);
}

/// The in-process transport: `n` worker threads sharing one result
/// channel — exactly the pool `Cluster` used to own directly.
pub struct ChannelTransport {
    n: usize,
    senders: Vec<Sender<WorkerMsg>>,
    results: Receiver<WorkerReply>,
    handles: Vec<JoinHandle<()>>,
}

impl ChannelTransport {
    /// Spawn `n` worker threads all running `engine`.
    pub fn spawn(n: usize, engine: Arc<dyn TaskEngine>) -> ChannelTransport {
        let (reply_tx, results) = channel::<WorkerReply>();
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for worker_id in 0..n {
            let (tx, rx) = channel::<WorkerMsg>();
            let engine = Arc::clone(&engine);
            let reply_tx = reply_tx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("fcdcc-worker-{worker_id}"))
                    .spawn(move || worker_loop(worker_id, engine, rx, reply_tx))
                    .expect("spawn worker"),
            );
            senders.push(tx);
        }
        ChannelTransport {
            n,
            senders,
            results,
            handles,
        }
    }
}

impl Transport for ChannelTransport {
    fn n(&self) -> usize {
        self.n
    }

    fn send(&mut self, worker: usize, msg: WorkerMsg) -> Result<()> {
        if let Err(e) = self.senders[worker].send(msg) {
            // The channel hands the unsent message back: recycle a
            // task's payload before surfacing the failure, so a dead
            // worker never costs the arena a slab.
            if let WorkerMsg::Task { payload, .. } = e.0 {
                payload.recycle();
            }
            bail!("worker {worker} channel closed");
        }
        Ok(())
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<TransportEvent>> {
        match self.results.recv_timeout(timeout) {
            Ok(r) => Ok(Some(TransportEvent::Reply(r))),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => bail!("all workers gone"),
        }
    }

    fn try_recv(&mut self) -> Result<Option<TransportEvent>> {
        match self.results.try_recv() {
            Ok(r) => Ok(Some(TransportEvent::Reply(r))),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => bail!("all workers gone"),
        }
    }

    fn shutdown(self: Box<Self>) {
        for tx in &self.senders {
            let _ = tx.send(WorkerMsg::Shutdown);
        }
        for h in self.handles {
            let _ = h.join();
        }
        // The workers drained their queues before exiting, so every
        // reply they ever sent is now buffered here.
        while let Ok(r) = self.results.try_recv() {
            r.body.recycle();
        }
    }
}
