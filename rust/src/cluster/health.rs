//! Worker-health tracking: a per-worker state machine the master feeds
//! with reply/timeout observations and the serving layer reads to pick
//! its dispatch set.
//!
//! ```text
//!            strikes >= suspect_after        strikes >= quarantine_after
//!  Healthy ───────────────────────▶ Suspect ───────────────────────▶ Quarantined
//!     ▲                               │  ok                              │
//!     │◀──────────────────────────────┘                    cooldown jobs │
//!     │                                                    elapse        ▼
//!     │◀────────────────────────── Probation ◀──────────────────── (probe due)
//!     │        probe task ok           │
//!     └────────────────────────────────┘ bad → Quarantined, backoff ×2
//! ```
//!
//! Observations are **job-count based**, never wall-clock: a strike is
//! one bad observation (explicit error reply, corrupt reply, or a
//! missed deadline on a timed-out job), and quarantine cooldowns are
//! measured in jobs dispatched — so a fault-injection replay produces
//! the identical health trajectory every run. Workers that merely lose
//! the first-δ race are *not* observed at all: with first-δ semantics
//! the n−δ cancelled stragglers per job are normal, so absence from a
//! completed job is no evidence of ill health. Redundancy absorbs those
//! silently; the tracker only reacts to faults that actually cost a job
//! (timeout) or announce themselves (error / corrupt replies).
//!
//! Readmission is probing-by-readmission: once a quarantined worker's
//! cooldown expires it moves to `Probation` and re-enters the dispatch
//! set, so its next task *is* the probe — the coded redundancy of that
//! job shields the cluster if the probe fails. A valid reply readmits
//! it (Healthy); another bad observation re-quarantines it with the
//! cooldown doubled (capped).

use crate::metrics::HealthCounters;

/// Where one worker currently stands.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerState {
    /// In the dispatch set, no recent strikes.
    Healthy,
    /// In the dispatch set, but accumulating strikes.
    Suspect,
    /// Out of the dispatch set, cooling down until the next probe.
    Quarantined,
    /// Back in the dispatch set tentatively; the next observation
    /// decides between readmission and re-quarantine.
    Probation,
}

/// Thresholds and backoff of the health state machine.
#[derive(Clone, Copy, Debug)]
pub struct HealthPolicy {
    /// Consecutive strikes before Healthy → Suspect.
    pub suspect_after: u32,
    /// Consecutive strikes before → Quarantined.
    pub quarantine_after: u32,
    /// Initial quarantine cooldown, in dispatched jobs.
    pub probe_backoff: u64,
    /// Cap for the exponential cooldown growth.
    pub max_backoff: u64,
}

impl Default for HealthPolicy {
    fn default() -> Self {
        Self {
            suspect_after: 1,
            quarantine_after: 3,
            probe_backoff: 2,
            max_backoff: 32,
        }
    }
}

#[derive(Clone, Copy, Debug)]
struct WorkerHealth {
    state: WorkerState,
    /// Consecutive bad observations (reset by any valid reply).
    strikes: u32,
    /// Current cooldown length (jobs); doubles per failed probe.
    backoff: u64,
    /// Jobs remaining until the next probe (only while Quarantined).
    cooldown: u64,
}

/// The master-resident tracker: one [`WorkerHealth`] per physical
/// worker plus the transition counters surfaced in `ServeStats`.
#[derive(Clone, Debug)]
pub struct HealthTracker {
    policy: HealthPolicy,
    workers: Vec<WorkerHealth>,
    counters: HealthCounters,
}

impl HealthTracker {
    pub fn new(n: usize, policy: HealthPolicy) -> Self {
        Self {
            policy,
            workers: vec![
                WorkerHealth {
                    state: WorkerState::Healthy,
                    strikes: 0,
                    backoff: policy.probe_backoff.max(1),
                    cooldown: 0,
                };
                n
            ],
            counters: HealthCounters::default(),
        }
    }

    pub fn policy(&self) -> HealthPolicy {
        self.policy
    }

    pub fn state(&self, worker: usize) -> WorkerState {
        self.workers[worker].state
    }

    pub fn counters(&self) -> HealthCounters {
        self.counters
    }

    /// Workers currently in the dispatch set (everything but
    /// `Quarantined`), ascending — the live set serving plans against.
    pub fn live_set(&self) -> Vec<usize> {
        (0..self.workers.len())
            .filter(|&i| self.workers[i].state != WorkerState::Quarantined)
            .collect()
    }

    /// A valid (decodable) reply arrived from `worker`.
    pub fn observe_ok(&mut self, worker: usize) {
        let w = &mut self.workers[worker];
        if w.state == WorkerState::Probation {
            self.counters.readmissions += 1;
            w.backoff = self.policy.probe_backoff.max(1);
        }
        w.state = WorkerState::Healthy;
        w.strikes = 0;
    }

    /// `worker` answered with an explicit error reply.
    pub fn observe_error(&mut self, worker: usize) {
        self.counters.errors += 1;
        self.strike(worker);
    }

    /// `worker`'s reply failed the master's integrity check.
    pub fn observe_corrupt(&mut self, worker: usize) {
        self.counters.corruptions += 1;
        self.strike(worker);
    }

    /// `worker` had not replied when its job's deadline expired.
    pub fn observe_timeout(&mut self, worker: usize) {
        self.counters.timeouts += 1;
        self.strike(worker);
    }

    /// One job was dispatched: advance quarantine cooldowns, promoting
    /// workers whose cooldown expired to `Probation` (their next task is
    /// the probe).
    pub fn tick_job(&mut self) {
        for w in self.workers.iter_mut() {
            if w.state == WorkerState::Quarantined {
                w.cooldown = w.cooldown.saturating_sub(1);
                if w.cooldown == 0 {
                    w.state = WorkerState::Probation;
                    self.counters.probes += 1;
                }
            }
        }
    }

    /// The transport declared `worker` dead (socket error or missed
    /// heartbeats): quarantine it immediately and **pin** the cooldown
    /// open — a dead peer must not auto-probe its way back on a job
    /// counter; only the transport's readmission ([`Self::readmit`])
    /// reopens it. Idempotent: a second eviction of an already-pinned
    /// worker changes nothing (no double-strike, no double-count).
    pub fn evict(&mut self, worker: usize) {
        let w = &mut self.workers[worker];
        if w.state != WorkerState::Quarantined {
            w.state = WorkerState::Quarantined;
            w.strikes = 0;
            self.counters.quarantines += 1;
        }
        w.cooldown = u64::MAX;
    }

    /// The transport readmitted `worker` (it reconnected and the
    /// membership accepted it back): move it to `Probation` so its next
    /// dispatch is the probe, exactly like a cooldown expiry. Only
    /// meaningful on a quarantined worker; otherwise a no-op.
    pub fn readmit(&mut self, worker: usize) {
        let w = &mut self.workers[worker];
        if w.state == WorkerState::Quarantined {
            w.state = WorkerState::Probation;
            w.cooldown = 0;
            self.counters.probes += 1;
        }
    }

    fn strike(&mut self, worker: usize) {
        let policy = self.policy;
        let w = &mut self.workers[worker];
        match w.state {
            WorkerState::Quarantined => {
                // Late evidence against an already-quarantined worker
                // (e.g. a second timed-out job observed after the
                // quarantining one): keep it down, no backoff change.
            }
            WorkerState::Probation => {
                // Failed probe: back off exponentially before retrying.
                w.backoff = (w.backoff * 2).min(policy.max_backoff.max(1));
                w.cooldown = w.backoff;
                w.state = WorkerState::Quarantined;
                self.counters.quarantines += 1;
            }
            WorkerState::Healthy | WorkerState::Suspect => {
                w.strikes += 1;
                if w.strikes >= policy.quarantine_after {
                    w.state = WorkerState::Quarantined;
                    w.cooldown = w.backoff;
                    self.counters.quarantines += 1;
                } else if w.strikes >= policy.suspect_after && w.state == WorkerState::Healthy {
                    w.state = WorkerState::Suspect;
                    self.counters.suspects += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> HealthPolicy {
        HealthPolicy {
            suspect_after: 1,
            quarantine_after: 2,
            probe_backoff: 2,
            max_backoff: 8,
        }
    }

    #[test]
    fn strikes_walk_healthy_suspect_quarantined() {
        let mut t = HealthTracker::new(3, policy());
        assert_eq!(t.state(1), WorkerState::Healthy);
        t.observe_timeout(1);
        assert_eq!(t.state(1), WorkerState::Suspect);
        assert_eq!(t.live_set(), vec![0, 1, 2], "suspects stay dispatchable");
        t.observe_error(1);
        assert_eq!(t.state(1), WorkerState::Quarantined);
        assert_eq!(t.live_set(), vec![0, 2]);
        let c = t.counters();
        assert_eq!(c.suspects, 1);
        assert_eq!(c.quarantines, 1);
        assert_eq!(c.timeouts, 1);
        assert_eq!(c.errors, 1);
    }

    #[test]
    fn ok_reply_resets_strikes() {
        let mut t = HealthTracker::new(2, policy());
        t.observe_corrupt(0);
        assert_eq!(t.state(0), WorkerState::Suspect);
        t.observe_ok(0);
        assert_eq!(t.state(0), WorkerState::Healthy);
        // The streak restarts: one more strike is Suspect again, not
        // Quarantined.
        t.observe_timeout(0);
        assert_eq!(t.state(0), WorkerState::Suspect);
    }

    #[test]
    fn cooldown_probes_then_readmits() {
        let mut t = HealthTracker::new(2, policy());
        t.observe_timeout(0);
        t.observe_timeout(0);
        assert_eq!(t.state(0), WorkerState::Quarantined);
        // Two jobs dispatch while it cools down.
        t.tick_job();
        assert_eq!(t.state(0), WorkerState::Quarantined);
        t.tick_job();
        assert_eq!(t.state(0), WorkerState::Probation);
        assert_eq!(t.live_set(), vec![0, 1], "probation rejoins dispatch");
        t.observe_ok(0);
        assert_eq!(t.state(0), WorkerState::Healthy);
        assert_eq!(t.counters().probes, 1);
        assert_eq!(t.counters().readmissions, 1);
    }

    #[test]
    fn failed_probe_doubles_backoff_up_to_cap() {
        let mut t = HealthTracker::new(1, policy());
        t.observe_timeout(0);
        t.observe_timeout(0);
        let mut seen = Vec::new();
        for _ in 0..4 {
            // Tick through the cooldown until probation, then fail the
            // probe.
            let mut ticks = 0u64;
            while t.state(0) == WorkerState::Quarantined {
                t.tick_job();
                ticks += 1;
                assert!(ticks <= 64, "cooldown never expired");
            }
            seen.push(ticks);
            assert_eq!(t.state(0), WorkerState::Probation);
            t.observe_timeout(0);
            assert_eq!(t.state(0), WorkerState::Quarantined);
        }
        assert_eq!(seen, vec![2, 4, 8, 8], "exponential backoff, capped");
        // A successful probe resets the backoff to the initial value.
        while t.state(0) == WorkerState::Quarantined {
            t.tick_job();
        }
        t.observe_ok(0);
        t.observe_timeout(0);
        t.observe_timeout(0);
        assert_eq!(t.state(0), WorkerState::Quarantined);
        let mut ticks = 0u64;
        while t.state(0) == WorkerState::Quarantined {
            t.tick_job();
            ticks += 1;
        }
        assert_eq!(ticks, 2, "readmission resets the probe backoff");
    }

    #[test]
    fn eviction_pins_quarantine_until_transport_readmission() {
        let mut t = HealthTracker::new(2, policy());
        t.evict(0);
        assert_eq!(t.state(0), WorkerState::Quarantined);
        assert_eq!(t.live_set(), vec![1]);
        // A second eviction report is idempotent.
        let q = t.counters().quarantines;
        t.evict(0);
        assert_eq!(t.counters().quarantines, q, "no double-count");
        // No number of dispatched jobs auto-probes a dead peer.
        for _ in 0..100 {
            t.tick_job();
        }
        assert_eq!(t.state(0), WorkerState::Quarantined);
        // Transport readmission makes the next dispatch the probe.
        t.readmit(0);
        assert_eq!(t.state(0), WorkerState::Probation);
        assert_eq!(t.live_set(), vec![0, 1]);
        t.observe_ok(0);
        assert_eq!(t.state(0), WorkerState::Healthy);
        assert_eq!(t.counters().readmissions, 1);
        // Readmitting a healthy worker is a no-op.
        t.readmit(1);
        assert_eq!(t.state(1), WorkerState::Healthy);
    }

    #[test]
    fn late_evidence_against_quarantined_worker_is_inert() {
        let mut t = HealthTracker::new(1, policy());
        t.observe_timeout(0);
        t.observe_timeout(0);
        let q = t.counters().quarantines;
        t.observe_timeout(0);
        assert_eq!(t.counters().quarantines, q, "no double-quarantine");
        t.tick_job();
        t.tick_job();
        assert_eq!(t.state(0), WorkerState::Probation, "cooldown unchanged");
    }
}
