//! Worker node: a thread that receives coded subtasks, applies its
//! injected straggler fate, computes the pairwise coded convolutions with
//! its [`TaskEngine`], and sends the coded result back.
//!
//! The engine sees the **whole payload**, not individual (slabA, slabB)
//! pairs, so it can amortize per-slab work: the default `Im2colEngine`
//! builds each coded input slab's im2col patch matrix once and reuses it
//! across all ℓ_B filter slabs (`WorkerPayload::run_im2col`), and fans
//! the slabs out over the shared compute pool (`util::pool`) — worker
//! threads and the master's encode/decode draw from one pool, with the
//! calling thread always participating, so oversubscription degrades to
//! inline execution instead of deadlock.
//!
//! A subtask may carry a whole **batch** of samples (`WorkerPayload`'s
//! batch axis); the wire protocol is oblivious to it — one job id, one
//! task message, one reply — so batched jobs flow through dispatch,
//! cancellation, and watermark pruning unchanged. A cancelled batch's
//! late reply is dropped by the master's stale-reply filter exactly like
//! an unbatched one.
//!
//! Replies carry an explicit **body**: a successful result ships with a
//! checksum over its block payload computed *before* the reply leaves
//! the worker, so the master can reject corrupted replies (injected via
//! [`WorkerFate::CorruptReply`], or real wire/memory damage in a future
//! remote transport) instead of decoding garbage. Engine errors — and,
//! via `catch_unwind`, engine **panics** — produce an error-reply body
//! rather than a silent drop or a dead thread, so the master can account
//! the failure and feed its health tracker while the coded redundancy
//! absorbs the missing block.
//!
//! Under the concurrent job runtime any number of jobs are in flight at
//! once and they complete **out of order**, so cancellation is per-job:
//! the master sends `Cancel(job_id)` as soon as a job has its δ results
//! (or times out), and periodically `CancelUpTo(watermark)` once every
//! job below a watermark is settled, which lets workers prune their
//! cancellation memory. A straggler sleeping out its injected delay
//! watches the channel and abandons the subtask the moment its job is
//! canceled — superseded work is dropped, not slept out, so one job's
//! stragglers don't cascade delay into the other in-flight jobs.

use crate::cluster::straggler::WorkerFate;
use crate::engine::TaskEngine;
use crate::fcdcc::{WorkerPayload, WorkerResult};
use std::collections::{HashSet, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Master → worker messages.
pub enum WorkerMsg {
    Task {
        job_id: u64,
        payload: Box<WorkerPayload>,
        fate: WorkerFate,
    },
    /// This specific job is settled (decoded or timed out); drop its task.
    Cancel(u64),
    /// Every job with id <= the watermark is settled; prune per-job state.
    CancelUpTo(u64),
    Shutdown,
}

/// What a reply carries: a result with its integrity checksum, or an
/// explicit failure.
pub enum ReplyBody {
    /// Coded result blocks plus [`result_checksum`] over them, computed
    /// before the reply left the worker — the master rejects replies
    /// whose blocks no longer match.
    Ok { result: WorkerResult, checksum: u64 },
    /// The worker is alive but could not produce a result: an injected
    /// error fate, an engine error, or an engine panic.
    Err(String),
}

impl ReplyBody {
    /// Return any carried block buffers to the plan arena.
    pub fn recycle(self) {
        if let ReplyBody::Ok { result, .. } = self {
            result.recycle();
        }
    }

    /// The coded column index this body decodes as (`None` for errors).
    pub fn coded_id(&self) -> Option<usize> {
        match self {
            ReplyBody::Ok { result, .. } => Some(result.worker_id),
            ReplyBody::Err(_) => None,
        }
    }
}

/// Worker → master replies.
pub struct WorkerReply {
    pub job_id: u64,
    /// Physical worker id (the thread that sent this reply) — feeds the
    /// master's health tracker. The *coded* column index lives in the
    /// result body; the two differ when a re-planned job maps coded
    /// columns onto a live-worker subset.
    pub worker_id: usize,
    pub body: ReplyBody,
    /// Pure compute time (excludes the injected straggler delay).
    pub compute_secs: f64,
    /// The injected delay actually slept.
    pub delay_secs: f64,
    /// When the worker finished (sent) this reply — lets the master
    /// account collection time up to arrival rather than up to whenever
    /// it next drains the channel (they differ under pipelined serving).
    pub sent_at: Instant,
}

/// Order-sensitive FNV-1a-style hash over a result's block payload
/// (f64 bit patterns). Cheap relative to the convolutions that produced
/// the blocks, and any single-bit perturbation flips it.
pub fn result_checksum(result: &WorkerResult) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for blk in &result.blocks {
        for &v in &blk.data {
            h ^= v.to_bits();
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// The set of jobs this worker must not compute: a low watermark (all
/// ids at or below it are settled) plus the individual ids canceled
/// above it — jobs finish out of order, so both parts are needed.
struct CancelSet {
    up_to: u64,
    ids: HashSet<u64>,
}

impl CancelSet {
    fn new() -> Self {
        Self {
            up_to: 0,
            ids: HashSet::new(),
        }
    }

    fn cancel(&mut self, id: u64) {
        if id > self.up_to {
            self.ids.insert(id);
        }
    }

    fn raise_watermark(&mut self, watermark: u64) {
        if watermark > self.up_to {
            self.up_to = watermark;
            self.ids.retain(|&id| id > watermark);
        }
    }

    fn contains(&self, id: u64) -> bool {
        id <= self.up_to || self.ids.contains(&id)
    }
}

/// The worker event loop. Runs until `Shutdown` or the channel closes.
pub fn worker_loop(
    worker_id: usize,
    engine: Arc<dyn TaskEngine>,
    rx: Receiver<WorkerMsg>,
    tx: Sender<WorkerReply>,
) {
    let mut canceled = CancelSet::new();
    let mut pending: VecDeque<WorkerMsg> = VecDeque::new();
    'outer: loop {
        let msg = match pending.pop_front() {
            Some(m) => m,
            None => match rx.recv() {
                Ok(m) => m,
                Err(_) => break,
            },
        };
        match msg {
            WorkerMsg::Shutdown => break,
            WorkerMsg::Cancel(id) => canceled.cancel(id),
            WorkerMsg::CancelUpTo(w) => canceled.raise_watermark(w),
            WorkerMsg::Task {
                job_id,
                payload,
                fate,
            } => {
                if canceled.contains(job_id) {
                    // Superseded before we even started. Recycling the
                    // undropped payload keeps the plan arena warm.
                    payload.recycle();
                    continue;
                }
                if matches!(fate, WorkerFate::ErrorReply) {
                    // Alive-but-broken: answer immediately with an
                    // explicit failure the master can account.
                    payload.recycle();
                    let _ = tx.send(WorkerReply {
                        job_id,
                        worker_id,
                        body: ReplyBody::Err("injected error-reply fault".to_string()),
                        compute_secs: 0.0,
                        delay_secs: 0.0,
                        sent_at: Instant::now(),
                    });
                    continue;
                }
                let delay = match fate.delay() {
                    Some(d) => d,
                    None => {
                        // Crashed worker: silently drop the task (but
                        // still return its slab buffers to the arena).
                        payload.recycle();
                        continue;
                    }
                };
                if !delay.is_zero() {
                    // Interruptible straggler sleep: cancellations take
                    // effect immediately (a Cancel for THIS job abandons
                    // the subtask instead of sleeping it out), other
                    // messages queue up in arrival order.
                    let deadline = Instant::now() + delay;
                    loop {
                        if canceled.contains(job_id) {
                            break;
                        }
                        let now = Instant::now();
                        if now >= deadline {
                            break;
                        }
                        match rx.recv_timeout(deadline - now) {
                            Ok(WorkerMsg::Cancel(id)) => canceled.cancel(id),
                            Ok(WorkerMsg::CancelUpTo(w)) => canceled.raise_watermark(w),
                            Ok(m) => pending.push_back(m),
                            Err(RecvTimeoutError::Timeout) => break,
                            Err(RecvTimeoutError::Disconnected) => break 'outer,
                        }
                    }
                    if canceled.contains(job_id) {
                        // The job was decoded (or abandoned) without us.
                        payload.recycle();
                        continue;
                    }
                }
                let t0 = Instant::now();
                // A panicking engine must cost this worker one error
                // reply, not the thread (a dead thread would eventually
                // disconnect the whole cluster). The payload is only
                // read by the engine, so unwinding past the borrow is
                // benign and it can still be recycled afterwards.
                let ran = catch_unwind(AssertUnwindSafe(|| engine.run(&payload)));
                let compute_secs = t0.elapsed().as_secs_f64();
                payload.recycle();
                let body = match ran {
                    Ok(Ok(mut result)) => {
                        let checksum = result_checksum(&result);
                        if matches!(fate, WorkerFate::CorruptReply) {
                            // Perturb one block entry *after* the
                            // checksum: models damage in transit, which
                            // the master's integrity check must catch.
                            if let Some(v) =
                                result.blocks.first_mut().and_then(|b| b.data.first_mut())
                            {
                                *v += 1.0;
                            }
                        }
                        ReplyBody::Ok { result, checksum }
                    }
                    Ok(Err(e)) => {
                        eprintln!("worker {worker_id}: task failed: {e:#}");
                        ReplyBody::Err(format!("engine error: {e:#}"))
                    }
                    Err(panic) => {
                        let msg = panic
                            .downcast_ref::<&str>()
                            .map(|s| s.to_string())
                            .or_else(|| panic.downcast_ref::<String>().cloned())
                            .unwrap_or_else(|| "<non-string panic>".to_string());
                        eprintln!("worker {worker_id}: engine panicked: {msg}");
                        ReplyBody::Err(format!("engine panic: {msg}"))
                    }
                };
                // The master may have moved on (enough results already);
                // a send error is normal shutdown noise.
                let _ = tx.send(WorkerReply {
                    job_id,
                    worker_id,
                    body,
                    compute_secs,
                    delay_secs: delay.as_secs_f64(),
                    sent_at: Instant::now(),
                });
            }
        }
    }
    // Drain the channel's unprocessed backlog so queued task payloads
    // return to the arena instead of being dropped with the receiver —
    // shutdown must leave the arena's outstanding counter at zero.
    while let Ok(msg) = rx.recv_timeout(Duration::ZERO) {
        if let WorkerMsg::Task { payload, .. } = msg {
            payload.recycle();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fcdcc::scratch::SlabArena;
    use crate::tensor::Tensor3;
    use crate::util::rng::Rng;

    #[test]
    fn cancel_set_tracks_out_of_order_completions() {
        let mut c = CancelSet::new();
        c.cancel(5); // job 5 finished before jobs 2..4
        c.cancel(3);
        assert!(c.contains(5));
        assert!(c.contains(3));
        assert!(!c.contains(2));
        assert!(!c.contains(4));
    }

    #[test]
    fn watermark_prunes_and_subsumes() {
        let mut c = CancelSet::new();
        c.cancel(2);
        c.cancel(7);
        c.raise_watermark(4);
        assert!(c.contains(1), "below the watermark");
        assert!(c.contains(2));
        assert!(c.contains(4));
        assert!(c.contains(7), "individual cancel above the watermark");
        assert!(!c.contains(5));
        // Pruned ids at or below the watermark; kept the one above.
        assert_eq!(c.ids.len(), 1);
        // Watermarks never move backwards.
        c.raise_watermark(3);
        assert_eq!(c.up_to, 4);
    }

    #[test]
    fn checksum_flips_on_any_perturbation() {
        let mut rng = Rng::new(41);
        let blocks = vec![Tensor3::random(2, 3, 3, &mut rng), Tensor3::random(2, 3, 3, &mut rng)];
        let mut result = WorkerResult {
            worker_id: 0,
            batch: 1,
            blocks,
            arena: Arc::new(SlabArena::new(8)),
        };
        let h0 = result_checksum(&result);
        assert_eq!(h0, result_checksum(&result), "checksum is deterministic");
        result.blocks[1].data[4] += 1e-9;
        assert_ne!(h0, result_checksum(&result), "tiny perturbation detected");
    }
}
