//! Worker node: a thread that receives coded subtasks, applies its
//! injected straggler fate, computes the pairwise coded convolutions with
//! its [`TaskEngine`], and sends the coded result back.
//!
//! The engine sees the **whole payload**, not individual (slabA, slabB)
//! pairs, so it can amortize per-slab work: the default `Im2colEngine`
//! builds each coded input slab's im2col patch matrix once and reuses it
//! across all ℓ_B filter slabs (`WorkerPayload::run_im2col`), and fans
//! the slabs out over the shared compute pool (`util::pool`) — worker
//! threads and the master's encode/decode draw from one pool, with the
//! calling thread always participating, so oversubscription degrades to
//! inline execution instead of deadlock.
//!
//! A subtask may carry a whole **batch** of samples (`WorkerPayload`'s
//! batch axis); the wire protocol is oblivious to it — one job id, one
//! task message, one reply — so batched jobs flow through dispatch,
//! cancellation, and watermark pruning unchanged. A cancelled batch's
//! late reply is dropped by the master's stale-reply filter exactly like
//! an unbatched one.
//!
//! Under the concurrent job runtime any number of jobs are in flight at
//! once and they complete **out of order**, so cancellation is per-job:
//! the master sends `Cancel(job_id)` as soon as a job has its δ results
//! (or times out), and periodically `CancelUpTo(watermark)` once every
//! job below a watermark is settled, which lets workers prune their
//! cancellation memory. A straggler sleeping out its injected delay
//! watches the channel and abandons the subtask the moment its job is
//! canceled — superseded work is dropped, not slept out, so one job's
//! stragglers don't cascade delay into the other in-flight jobs.

use crate::cluster::straggler::WorkerFate;
use crate::engine::TaskEngine;
use crate::fcdcc::{WorkerPayload, WorkerResult};
use std::collections::{HashSet, VecDeque};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Instant;

/// Master → worker messages.
pub enum WorkerMsg {
    Task {
        job_id: u64,
        payload: Box<WorkerPayload>,
        fate: WorkerFate,
    },
    /// This specific job is settled (decoded or timed out); drop its task.
    Cancel(u64),
    /// Every job with id <= the watermark is settled; prune per-job state.
    CancelUpTo(u64),
    Shutdown,
}

/// Worker → master replies.
pub struct WorkerReply {
    pub job_id: u64,
    pub worker_id: usize,
    pub result: WorkerResult,
    /// Pure compute time (excludes the injected straggler delay).
    pub compute_secs: f64,
    /// The injected delay actually slept.
    pub delay_secs: f64,
    /// When the worker finished (sent) this reply — lets the master
    /// account collection time up to arrival rather than up to whenever
    /// it next drains the channel (they differ under pipelined serving).
    pub sent_at: Instant,
}

/// The set of jobs this worker must not compute: a low watermark (all
/// ids at or below it are settled) plus the individual ids canceled
/// above it — jobs finish out of order, so both parts are needed.
struct CancelSet {
    up_to: u64,
    ids: HashSet<u64>,
}

impl CancelSet {
    fn new() -> Self {
        Self {
            up_to: 0,
            ids: HashSet::new(),
        }
    }

    fn cancel(&mut self, id: u64) {
        if id > self.up_to {
            self.ids.insert(id);
        }
    }

    fn raise_watermark(&mut self, watermark: u64) {
        if watermark > self.up_to {
            self.up_to = watermark;
            self.ids.retain(|&id| id > watermark);
        }
    }

    fn contains(&self, id: u64) -> bool {
        id <= self.up_to || self.ids.contains(&id)
    }
}

/// The worker event loop. Runs until `Shutdown` or the channel closes.
pub fn worker_loop(
    worker_id: usize,
    engine: Arc<dyn TaskEngine>,
    rx: Receiver<WorkerMsg>,
    tx: Sender<WorkerReply>,
) {
    let mut canceled = CancelSet::new();
    let mut pending: VecDeque<WorkerMsg> = VecDeque::new();
    'outer: loop {
        let msg = match pending.pop_front() {
            Some(m) => m,
            None => match rx.recv() {
                Ok(m) => m,
                Err(_) => break,
            },
        };
        match msg {
            WorkerMsg::Shutdown => break,
            WorkerMsg::Cancel(id) => canceled.cancel(id),
            WorkerMsg::CancelUpTo(w) => canceled.raise_watermark(w),
            WorkerMsg::Task {
                job_id,
                payload,
                fate,
            } => {
                if canceled.contains(job_id) {
                    // Superseded before we even started. Recycling the
                    // undropped payload keeps the plan arena warm.
                    payload.recycle();
                    continue;
                }
                let delay = match fate.delay() {
                    Some(d) => d,
                    None => {
                        // Failed worker: silently drop the task (but
                        // still return its slab buffers to the arena).
                        payload.recycle();
                        continue;
                    }
                };
                if !delay.is_zero() {
                    // Interruptible straggler sleep: cancellations take
                    // effect immediately (a Cancel for THIS job abandons
                    // the subtask instead of sleeping it out), other
                    // messages queue up in arrival order.
                    let deadline = Instant::now() + delay;
                    loop {
                        if canceled.contains(job_id) {
                            break;
                        }
                        let now = Instant::now();
                        if now >= deadline {
                            break;
                        }
                        match rx.recv_timeout(deadline - now) {
                            Ok(WorkerMsg::Cancel(id)) => canceled.cancel(id),
                            Ok(WorkerMsg::CancelUpTo(w)) => canceled.raise_watermark(w),
                            Ok(m) => pending.push_back(m),
                            Err(RecvTimeoutError::Timeout) => break,
                            Err(RecvTimeoutError::Disconnected) => break 'outer,
                        }
                    }
                    if canceled.contains(job_id) {
                        // The job was decoded (or abandoned) without us.
                        payload.recycle();
                        continue;
                    }
                }
                let t0 = Instant::now();
                let result = match engine.run(&payload) {
                    Ok(r) => r,
                    Err(e) => {
                        // An engine error behaves like a worker failure:
                        // the coded redundancy absorbs it.
                        eprintln!("worker {worker_id}: task failed: {e:#}");
                        payload.recycle();
                        continue;
                    }
                };
                let compute_secs = t0.elapsed().as_secs_f64();
                // The subtask is done with its coded inputs; return the
                // slab buffers before the reply even ships.
                payload.recycle();
                // The master may have moved on (enough results already);
                // a send error is normal shutdown noise.
                let _ = tx.send(WorkerReply {
                    job_id,
                    worker_id,
                    result,
                    compute_secs,
                    delay_secs: delay.as_secs_f64(),
                    sent_at: Instant::now(),
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cancel_set_tracks_out_of_order_completions() {
        let mut c = CancelSet::new();
        c.cancel(5); // job 5 finished before jobs 2..4
        c.cancel(3);
        assert!(c.contains(5));
        assert!(c.contains(3));
        assert!(!c.contains(2));
        assert!(!c.contains(4));
    }

    #[test]
    fn watermark_prunes_and_subsumes() {
        let mut c = CancelSet::new();
        c.cancel(2);
        c.cancel(7);
        c.raise_watermark(4);
        assert!(c.contains(1), "below the watermark");
        assert!(c.contains(2));
        assert!(c.contains(4));
        assert!(c.contains(7), "individual cancel above the watermark");
        assert!(!c.contains(5));
        // Pruned ids at or below the watermark; kept the one above.
        assert_eq!(c.ids.len(), 1);
        // Watermarks never move backwards.
        c.raise_watermark(3);
        assert_eq!(c.up_to, 4);
    }
}
