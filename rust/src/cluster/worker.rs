//! Worker node: a thread that receives coded subtasks, applies its
//! injected straggler fate, computes the pairwise coded convolutions with
//! its [`TaskEngine`], and sends the coded result back.
//!
//! The master broadcasts `Cancel(job_id)` once it has decoded a job;
//! a worker that wakes from a straggler sleep checks for cancellation
//! before computing, so superseded subtasks are dropped instead of
//! cascading delay into subsequent jobs (the paper's per-job straggler
//! independence).

use crate::cluster::straggler::WorkerFate;
use crate::engine::TaskEngine;
use crate::fcdcc::{WorkerPayload, WorkerResult};
use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::time::Instant;

/// Master → worker messages.
pub enum WorkerMsg {
    Task {
        job_id: u64,
        payload: Box<WorkerPayload>,
        fate: WorkerFate,
    },
    /// All jobs with id <= the given one are complete; drop their tasks.
    Cancel(u64),
    Shutdown,
}

/// Worker → master replies.
pub struct WorkerReply {
    pub job_id: u64,
    pub worker_id: usize,
    pub result: WorkerResult,
    /// Pure compute time (excludes the injected straggler delay).
    pub compute_secs: f64,
    /// The injected delay actually slept.
    pub delay_secs: f64,
}

/// The worker event loop. Runs until `Shutdown` or the channel closes.
pub fn worker_loop(
    worker_id: usize,
    engine: Arc<dyn TaskEngine>,
    rx: Receiver<WorkerMsg>,
    tx: Sender<WorkerReply>,
) {
    let mut canceled_up_to = 0u64;
    let mut pending: VecDeque<WorkerMsg> = VecDeque::new();
    'outer: loop {
        let msg = match pending.pop_front() {
            Some(m) => m,
            None => match rx.recv() {
                Ok(m) => m,
                Err(_) => break,
            },
        };
        match msg {
            WorkerMsg::Shutdown => break,
            WorkerMsg::Cancel(id) => canceled_up_to = canceled_up_to.max(id),
            WorkerMsg::Task {
                job_id,
                payload,
                fate,
            } => {
                if job_id <= canceled_up_to {
                    continue; // superseded before we even started
                }
                let delay = match fate.delay() {
                    Some(d) => d,
                    None => continue, // failed worker: silently drop the task
                };
                if !delay.is_zero() {
                    std::thread::sleep(delay);
                    // Drain whatever arrived while we slept; cancellations
                    // take effect immediately, tasks queue up in order.
                    loop {
                        match rx.try_recv() {
                            Ok(WorkerMsg::Cancel(id)) => {
                                canceled_up_to = canceled_up_to.max(id)
                            }
                            Ok(m) => pending.push_back(m),
                            Err(TryRecvError::Empty) => break,
                            Err(TryRecvError::Disconnected) => break 'outer,
                        }
                    }
                    if job_id <= canceled_up_to {
                        continue; // the sleep outlived the job
                    }
                }
                let t0 = Instant::now();
                let result = match engine.run(&payload) {
                    Ok(r) => r,
                    Err(e) => {
                        // An engine error behaves like a worker failure:
                        // the coded redundancy absorbs it.
                        eprintln!("worker {worker_id}: task failed: {e:#}");
                        continue;
                    }
                };
                let compute_secs = t0.elapsed().as_secs_f64();
                // The master may have moved on (enough results already);
                // a send error is normal shutdown noise.
                let _ = tx.send(WorkerReply {
                    job_id,
                    worker_id,
                    result,
                    compute_secs,
                    delay_secs: delay.as_secs_f64(),
                });
            }
        }
    }
}
