//! Minimal declarative CLI argument parser (clap is unavailable in the
//! offline environment): `--key value` / `--flag` pairs plus a leading
//! subcommand word.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::time::Duration;

/// Parsed command line: subcommand + options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (no program name).
    pub fn parse_from<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.command = Some(it.next().unwrap());
            }
        }
        while let Some(a) = it.next() {
            let Some(key) = a.strip_prefix("--") else {
                bail!("unexpected positional argument {a:?}");
            };
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    let v = it.next().unwrap();
                    out.opts.insert(key.to_string(), v);
                }
                _ => out.flags.push(key.to_string()),
            }
        }
        Ok(out)
    }

    pub fn parse() -> Result<Args> {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(String::as_str)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects a number, got {v:?}")),
        }
    }

    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Millisecond-valued option parsed into a [`Duration`].
    pub fn get_duration_ms(&self, name: &str, default_ms: u64) -> Result<Duration> {
        match self.get(name) {
            None => Ok(Duration::from_millis(default_ms)),
            Some(v) => v
                .parse()
                .map(Duration::from_millis)
                .map_err(|_| anyhow!("--{name} expects milliseconds, got {v:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("run --arch alexnet --n 18 --verbose");
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.get("arch"), Some("alexnet"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 18);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse("optimize");
        assert_eq!(a.get_usize("q", 32).unwrap(), 32);
        assert_eq!(a.get_str("arch", "lenet"), "lenet");
        assert_eq!(a.get_f64("delay", 1.5).unwrap(), 1.5);
    }

    #[test]
    fn duration_ms_values() {
        let a = parse("serve --collect-timeout-ms 250");
        assert_eq!(
            a.get_duration_ms("collect-timeout-ms", 60_000).unwrap(),
            Duration::from_millis(250)
        );
        assert_eq!(
            a.get_duration_ms("request-deadline-ms", 40).unwrap(),
            Duration::from_millis(40)
        );
        let bad = parse("serve --collect-timeout-ms soon");
        assert!(bad.get_duration_ms("collect-timeout-ms", 0).is_err());
    }

    #[test]
    fn negative_number_values() {
        let a = parse("x --bias -3");
        // "-3" doesn't start with "--", so it's a value.
        assert_eq!(a.get_f64("bias", 0.0).unwrap(), -3.0);
    }

    #[test]
    fn rejects_stray_positional() {
        assert!(Args::parse_from(vec!["run".into(), "oops".into()]).is_err());
        let bad = Args::parse_from(vec!["run".into(), "--n".into(), "x".into()]).unwrap();
        assert!(bad.get_usize("n", 1).is_err());
    }
}
