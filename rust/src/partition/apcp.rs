//! Adaptive-Padding (Coded) Partitioning of the input tensor — paper
//! §IV-A, Algorithm 2 (the partitioning half; the coding half is the
//! generic `coding::encode_inputs`).
//!
//! The input is assumed **already spatially padded** (the paper's
//! X ∈ ℝ^{C×(H+2p)×(W+2p)}); APCP splits it along the height axis into
//! `k_A` *overlapping* slabs of height Ĥ = (H′/k_A − 1)·s + K_H starting
//! at stride Ŝ = (H′/k_A)·s, so each slab convolves (stride s, no extra
//! padding) into exactly the corresponding H′/k_A rows of the output.
//! When H′ is not a multiple of k_A the input is zero-padded at the
//! bottom to extend H′ to the next multiple; the merge step trims.

use crate::tensor::Tensor3;
use anyhow::{ensure, Result};

/// Precomputed APCP geometry for one convolutional layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ApcpPlan {
    /// Number of input partitions (paper k_A).
    pub k_a: usize,
    /// Kernel height K_H.
    pub k_h: usize,
    /// Stride s.
    pub stride: usize,
    /// Height of the (pre-padded) input this plan was built for.
    pub h_in: usize,
    /// True output height H′ of the layer.
    pub h_out: usize,
    /// Output height after rounding up to a multiple of k_A.
    pub h_out_pad: usize,
    /// Adaptive slab height Ĥ (paper eq. (24), on the padded output).
    pub h_hat: usize,
    /// Slab start stride Ŝ (paper eq. (25)).
    pub s_hat: usize,
    /// Bottom zero-padding added to the input before slicing.
    pub pad_bottom: usize,
}

impl ApcpPlan {
    /// Build the plan for a pre-padded input of height `h_in`, kernel
    /// height `k_h`, stride `stride`, and `k_a` partitions.
    pub fn new(h_in: usize, k_h: usize, stride: usize, k_a: usize) -> Result<Self> {
        ensure!(k_a >= 1, "k_a must be >= 1");
        ensure!(stride >= 1, "stride must be >= 1");
        ensure!(h_in >= k_h, "input height {h_in} smaller than kernel {k_h}");
        let h_out = (h_in - k_h) / stride + 1;
        ensure!(
            h_out >= k_a,
            "cannot split H'={h_out} output rows into k_a={k_a} partitions"
        );
        let h_out_pad = h_out.div_ceil(k_a) * k_a;
        let rows_per = h_out_pad / k_a;
        let h_hat = (rows_per - 1) * stride + k_h; // eq. (24)
        let s_hat = rows_per * stride; // eq. (25)
        // The last slab ends at (k_a-1)·Ŝ + Ĥ = (H'_pad - 1)s + K_H.
        let needed = (h_out_pad - 1) * stride + k_h;
        let pad_bottom = needed.saturating_sub(h_in);
        Ok(Self {
            k_a,
            k_h,
            stride,
            h_in,
            h_out,
            h_out_pad,
            h_hat,
            s_hat,
            pad_bottom,
        })
    }

    /// Output rows produced per partition (H′_pad / k_A).
    pub fn rows_per_partition(&self) -> usize {
        self.h_out_pad / self.k_a
    }

    /// Slice the (pre-padded) input into the k_A overlapping slabs
    /// (paper eq. (27)).
    pub fn partition(&self, x: &Tensor3) -> Vec<Tensor3> {
        assert_eq!(
            x.h, self.h_in,
            "ApcpPlan built for input height {}, got {}",
            self.h_in, x.h
        );
        let xp;
        let x = if self.pad_bottom > 0 {
            xp = x.pad_bottom(self.pad_bottom);
            &xp
        } else {
            x
        };
        (0..self.k_a)
            .map(|i| x.slice_h(i * self.s_hat, i * self.s_hat + self.h_hat))
            .collect()
    }

    /// Tensor entries uploaded per coded slab — the V_comm_up building
    /// block of the cost model (§IV-E): C·Ĥ·W for a width-W input.
    pub fn entries_per_slab(&self, c: usize, w: usize) -> usize {
        c * self.h_hat * w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{conv2d, ConvParams, Tensor4};
    use crate::util::{max_abs_diff, rng::Rng};

    #[test]
    fn paper_figure2_geometry() {
        // Fig. 2: 10×10 input, 3×3 kernel, s=1, k_A=4 ⇒ H'=8, Ĥ=4, Ŝ=2.
        let plan = ApcpPlan::new(10, 3, 1, 4).unwrap();
        assert_eq!(plan.h_out, 8);
        assert_eq!(plan.h_out_pad, 8);
        assert_eq!(plan.h_hat, 4);
        assert_eq!(plan.s_hat, 2);
        assert_eq!(plan.pad_bottom, 0);
    }

    #[test]
    fn slab_conv_rows_match_direct() {
        let mut rng = Rng::new(31);
        for (h, kh, s, k_a) in [(10, 3, 1, 4), (28, 5, 1, 4), (23, 5, 4, 2), (11, 3, 2, 5)] {
            let x = Tensor3::random(2, h, 7 + kh, &mut rng);
            let k = Tensor4::random(3, 2, kh, kh, &mut rng);
            let p = ConvParams::new(s, 0);
            let want = conv2d(&x, &k, p);
            let plan = ApcpPlan::new(h, kh, s, k_a).unwrap();
            let rows = plan.rows_per_partition();
            for (i, slab) in plan.partition(&x).iter().enumerate() {
                assert_eq!(slab.h, plan.h_hat);
                let y = conv2d(slab, &k, p);
                assert_eq!(y.h, rows, "partition {i}");
                // Rows beyond the true H' are the zero-pad artifact; only
                // compare the real ones.
                let lo = i * rows;
                let hi = ((i + 1) * rows).min(want.h);
                if lo >= want.h {
                    continue;
                }
                let got = y.slice_h(0, hi - lo);
                let exp = want.slice_h(lo, hi);
                assert!(
                    max_abs_diff(&got.data, &exp.data) < 1e-12,
                    "partition {i} of case {:?}",
                    (h, kh, s, k_a)
                );
            }
        }
    }

    #[test]
    fn pads_when_not_divisible() {
        // H'=8 rows into k_A=3 ⇒ padded to 9, one extra bottom row needed.
        let plan = ApcpPlan::new(10, 3, 1, 3).unwrap();
        assert_eq!(plan.h_out_pad, 9);
        assert_eq!(plan.rows_per_partition(), 3);
        assert!(plan.pad_bottom > 0);
        let x = Tensor3::random(1, 10, 5, &mut Rng::new(1));
        let parts = plan.partition(&x);
        assert_eq!(parts.len(), 3);
        assert!(parts.iter().all(|p| p.h == plan.h_hat));
    }

    #[test]
    fn k_a_one_is_whole_input() {
        let plan = ApcpPlan::new(9, 3, 1, 1).unwrap();
        let x = Tensor3::random(2, 9, 4, &mut Rng::new(2));
        let parts = plan.partition(&x);
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0], x);
    }

    #[test]
    fn rejects_oversplit() {
        assert!(ApcpPlan::new(5, 3, 1, 4).is_err()); // H'=3 < k_A=4
    }
}
