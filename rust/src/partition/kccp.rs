//! Kernel-Channel (Coded) Partitioning of the filter tensor — paper
//! §IV-B, Algorithm 3 (partitioning half). The filter bank
//! K ∈ ℝ^{N×C×K_H×K_W} is split into k_B disjoint banks of N/k_B output
//! channels each (eq. (33)); kernel geometry and input channels are
//! untouched, so each partition convolves independently.

use crate::tensor::Tensor4;
use anyhow::{ensure, Result};

/// Precomputed KCCP geometry for one convolutional layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KccpPlan {
    /// Total output channels N.
    pub n_out: usize,
    /// Number of filter partitions (paper k_B); must divide N.
    pub k_b: usize,
}

impl KccpPlan {
    pub fn new(n_out: usize, k_b: usize) -> Result<Self> {
        ensure!(k_b >= 1, "k_b must be >= 1");
        ensure!(
            n_out % k_b == 0,
            "k_b={k_b} must divide the output-channel count N={n_out}"
        );
        Ok(Self { n_out, k_b })
    }

    /// Output channels per partition (N / k_B).
    pub fn channels_per_partition(&self) -> usize {
        self.n_out / self.k_b
    }

    /// Split the filter bank into the k_B channel groups (eq. (33)).
    pub fn partition(&self, k: &Tensor4) -> Vec<Tensor4> {
        assert_eq!(
            k.n, self.n_out,
            "KccpPlan built for N={}, got {}",
            self.n_out, k.n
        );
        let per = self.channels_per_partition();
        (0..self.k_b)
            .map(|i| k.slice_n(i * per, (i + 1) * per))
            .collect()
    }

    /// Filter entries stored per partition — the V_store building block
    /// of the cost model: (N/k_B)·C·K_H·K_W.
    pub fn entries_per_partition(&self, c: usize, kh: usize, kw: usize) -> usize {
        self.channels_per_partition() * c * kh * kw
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn partitions_cover_disjointly() {
        let mut rng = Rng::new(41);
        let k = Tensor4::random(8, 3, 3, 3, &mut rng);
        let plan = KccpPlan::new(8, 4).unwrap();
        let parts = plan.partition(&k);
        assert_eq!(parts.len(), 4);
        assert!(parts.iter().all(|p| p.n == 2));
        let merged = Tensor4::concat_n(&parts.iter().collect::<Vec<_>>());
        assert_eq!(merged, k);
    }

    #[test]
    fn k_b_one_is_whole_bank() {
        let k = Tensor4::random(6, 2, 3, 3, &mut Rng::new(42));
        let plan = KccpPlan::new(6, 1).unwrap();
        let parts = plan.partition(&k);
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0], k);
    }

    #[test]
    fn rejects_nondivisor() {
        assert!(KccpPlan::new(8, 3).is_err());
    }

    #[test]
    fn storage_accounting() {
        let plan = KccpPlan::new(64, 8).unwrap();
        assert_eq!(plan.entries_per_partition(16, 3, 3), 8 * 16 * 9);
    }
}
