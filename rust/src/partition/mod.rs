//! Tensor partitioning for FCDCC: APCP for the input tensor (spatial,
//! overlapping, adaptive padding — paper §IV-A) and KCCP for the filter
//! tensor (output-channel, disjoint — paper §IV-B), plus the inverse
//! merge of decoded output blocks (paper Alg. 5 step 6).

pub mod apcp;
pub mod kccp;

pub use apcp::ApcpPlan;
pub use kccp::KccpPlan;

use crate::tensor::Tensor3;

/// Reassemble the `k_a·k_b` decoded output blocks (ordered `a·k_b + b`,
/// each `N/k_b × H'_pad/k_a × W'`) into the output tensor `N × H' × W'`:
/// concatenate along H within each channel group, then along channels,
/// finally trimming the APCP height padding (paper eqs. (48)–(49)).
pub fn merge_output_blocks(
    blocks: &[Tensor3],
    k_a: usize,
    k_b: usize,
    h_out_true: usize,
) -> Tensor3 {
    assert_eq!(blocks.len(), k_a * k_b, "merge: expected k_a*k_b blocks");
    let groups: Vec<Tensor3> = (0..k_b)
        .map(|b| {
            let slabs: Vec<&Tensor3> = (0..k_a).map(|a| &blocks[a * k_b + b]).collect();
            Tensor3::concat_h(&slabs)
        })
        .collect();
    let full = Tensor3::concat_c(&groups.iter().collect::<Vec<_>>());
    if full.h == h_out_true {
        full
    } else {
        full.slice_h(0, h_out_true)
    }
}

/// Flat-buffer variant of [`merge_output_blocks`] for the GEMM decode
/// hot path: `flat` holds the `k_a·k_b` decoded blocks back to back
/// (block `a·k_b + b` at offset `(a·k_b + b)·c_b·h_b·w_b`, each block
/// `c_b × h_b × w_b` row-major). Instead of materializing per-group
/// `concat_h` / `concat_c` intermediates and trimming with a final copy,
/// every output row is copied exactly once, straight from the staging
/// buffer into its final position; rows beyond `h_out_true` (the APCP
/// height padding) are simply never copied. Produces the same tensor as
/// `merge_output_blocks` over the same blocks.
pub fn merge_output_rows(
    flat: &[f64],
    k_a: usize,
    k_b: usize,
    c_b: usize,
    h_b: usize,
    w_b: usize,
    h_out_true: usize,
) -> Tensor3 {
    let block_len = c_b * h_b * w_b;
    assert_eq!(flat.len(), k_a * k_b * block_len, "merge: flat buffer size");
    let mut out = Tensor3::zeros(k_b * c_b, h_out_true, w_b);
    for a in 0..k_a {
        let row_base = a * h_b;
        if row_base >= h_out_true {
            break;
        }
        let rows_here = h_b.min(h_out_true - row_base);
        for b in 0..k_b {
            let blk = &flat[(a * k_b + b) * block_len..(a * k_b + b + 1) * block_len];
            for c in 0..c_b {
                for r in 0..rows_here {
                    let src = (c * h_b + r) * w_b;
                    let dst = out.idx(b * c_b + c, row_base + r, 0);
                    out.data[dst..dst + w_b].copy_from_slice(&blk[src..src + w_b]);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{conv2d, ConvParams, Tensor4};
    use crate::util::{max_abs_diff, rng::Rng};

    /// Partition with APCP+KCCP, convolve every (a,b) pair directly, merge,
    /// and compare against the monolithic convolution — the uncoded
    /// correctness core of the whole framework (paper eq. (14)).
    #[test]
    fn partition_convolve_merge_equals_direct() {
        let mut rng = Rng::new(21);
        // (c, h, w, n, kh, kw, stride, pad, k_a, k_b)
        let cases = [
            (3, 12, 10, 8, 3, 3, 1, 0, 4, 2),
            (2, 11, 9, 6, 3, 3, 1, 1, 2, 3),
            (1, 28, 28, 6, 5, 5, 1, 2, 4, 2),
            (3, 23, 17, 4, 5, 5, 4, 0, 2, 4),
            (2, 9, 9, 4, 3, 3, 2, 1, 4, 1),
            (2, 10, 8, 5, 3, 3, 1, 0, 1, 5),
        ];
        for (c, h, w, n, kh, kw, s, pad, k_a, k_b) in cases {
            let x = crate::tensor::Tensor3::random(c, h, w, &mut rng);
            let k = Tensor4::random(n, c, kh, kw, &mut rng);
            let p = ConvParams::new(s, pad);
            let want = conv2d(&x, &k, p);

            let xp = x.pad_spatial(pad);
            let apcp = ApcpPlan::new(xp.h, kh, s, k_a).unwrap();
            let kccp = KccpPlan::new(n, k_b).unwrap();
            let xparts = apcp.partition(&xp);
            let kparts = kccp.partition(&k);
            let mut blocks = Vec::new();
            for xa in &xparts {
                for kb in &kparts {
                    blocks.push(conv2d(xa, kb, ConvParams::new(s, 0)));
                }
            }
            let got = merge_output_blocks(&blocks, k_a, k_b, want.h);
            assert_eq!(got.shape(), want.shape(), "case {:?}", (c, h, w, k_a, k_b));
            assert!(
                max_abs_diff(&got.data, &want.data) < 1e-12,
                "case {:?}",
                (c, h, w, k_a, k_b)
            );

            // The flat-buffer merge must agree bitwise with the
            // tensor-list merge over the same blocks.
            let (c_b, h_b, w_b) = blocks[0].shape();
            let mut flat = Vec::with_capacity(blocks.len() * c_b * h_b * w_b);
            for blk in &blocks {
                flat.extend_from_slice(&blk.data);
            }
            let got_flat = merge_output_rows(&flat, k_a, k_b, c_b, h_b, w_b, want.h);
            assert_eq!(got_flat.shape(), got.shape());
            assert_eq!(got_flat.data, got.data, "flat merge diverged");
        }
    }
}
