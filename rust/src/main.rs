//! The `fcdcc` CLI — the L3 leader entrypoint.
//!
//! ```text
//! fcdcc run       --arch alexnet --layer 2 --ka 2 --kb 16 --n 18 \
//!                 [--stragglers 2] [--delay-ms 100] [--engine im2col|direct|pjrt]
//! fcdcc optimize  --arch vgg [--q 16,32,64]          # Table IV planner
//! fcdcc stability [--samples 6]                      # Fig. 3/4 report
//! fcdcc serve     [--requests 16] [--n 4] [--stragglers 1] [--engine pjrt] \
//!                 [--max-in-flight 4] [--batch-window 4]
//! fcdcc artifacts [--dir artifacts]                  # verify AOT artifacts
//! ```

use anyhow::{anyhow, bail, Result};
use fcdcc::cli::Args;
use fcdcc::cluster::{
    spawn_frontend, spawn_worker_node, FaultKind, FaultPlan, StragglerModel, TcpConfig,
    WorkerNodeConfig,
};
use fcdcc::coordinator::{self, stability, ArrivalSpec, RunConfig, ServeConfig, TransportKind};
use fcdcc::engine::TaskEngine;
use fcdcc::metrics::{fmt_sci, Table};
use fcdcc::model::zoo;
use std::sync::Arc;
use std::time::Duration;

const USAGE: &str = "\
fcdcc — Flexible Coded Distributed Convolution Computing

USAGE:
  fcdcc run       --arch <lenet|alexnet|vgg> [--layer I] [--ka K] [--kb K]
                  [--n N] [--stragglers S] [--delay-ms MS]
                  [--engine direct|im2col|pjrt] [--scale F] [--seed S]
  fcdcc optimize  [--arch NAME] [--q Q1,Q2,...]
  fcdcc stability [--samples N] [--seed S]
  fcdcc serve     [--requests R] [--n N] [--stragglers S] [--delay-ms MS]
                  [--engine direct|im2col|pjrt] [--max-in-flight D]
                  [--batch-window B] [--verify-every K] [--no-prepack]
                  [--fault-worker W --fault-kind KIND] [--fault-jobs J]
                  [--fault-delay-ms MS] [--chaos-seed S]
                  [--retry-budget R] [--collect-timeout-ms MS] [--no-replan]
                  [--role local|coordinator|worker|frontend] [--listen ADDR]
                  [--workers A1,A2,...] [--heartbeat-ms MS]
                  [--miss-threshold B] [--connect-timeout-ms MS]
                  [--queue-cap Q] [--request-deadline-ms MS]
                  [--arrival poisson|burst] [--arrival-rate R]
                  [--arrival-seed S] [--arrival-burst B]
  fcdcc artifacts [--dir DIR]   (needs the `pjrt` feature)

distributed serving (--role; see DESIGN.md §Transport & membership):
  --role local        default: the whole cluster runs in-process over
                      channels (deterministic, offline)
  --role worker       run one worker node: bind --listen (default
                      127.0.0.1:0), print the bound address, and serve
                      framed-TCP tasks until the coordinator shuts the
                      session down
  --role coordinator  drive remote worker nodes over TCP: --workers is
                      the comma-separated node address list (its length
                      becomes the pool size, overriding --n); workers
                      that die are heartbeat-evicted, the stage is
                      re-planned for the live set, and reconnecting
                      nodes are readmitted
  --role frontend     network serving front-end (DESIGN.md §Serving
                      front-end & overload control): bind --listen,
                      print the bound address, and serve client Request
                      frames until --requests arrivals have resolved.
                      Every request gets exactly one terminal reply —
                      logits, Busy (shed at admission), or
                      DeadlineExceeded. Add --workers to back the
                      front-end with remote TCP worker nodes.
  --listen ADDR            worker / frontend bind address (default
                           127.0.0.1:0)
  --workers A1,A2,...      coordinator's node addresses (required)
  --heartbeat-ms MS        ping cadence (default 200)
  --miss-threshold B       silent heartbeats before eviction (default 3)
  --connect-timeout-ms MS  rendezvous deadline at startup (default 5000)

overload control (open-loop serving; see DESIGN.md §Serving front-end &
overload control):
  --queue-cap Q            bounded admission-queue capacity (default
                           64). An arrival that finds the queue full is
                           shed with an explicit Busy reply — load
                           shedding is never a silent drop.
  --request-deadline-ms MS default per-request deadline; a request whose
                           deadline passes is evicted with
                           DeadlineExceeded at the next stage boundary
                           (0 = no deadline; network clients may carry
                           their own per-request deadline on the wire)
  --arrival KIND           open-loop synthetic arrival process: poisson
                           (memoryless) or burst (Poisson burst epochs,
                           geometric burst sizes). Runs on a seeded
                           virtual clock, so a fixed seed reproduces the
                           same shed/expire/complete pattern on every
                           machine. Omit for the classic closed loop.
  --arrival-rate R         mean arrivals per virtual second (default
                           100; sustainable rate is about
                           100 x batch-window req/s)
  --arrival-seed S         arrival-process seed (default 1)
  --arrival-burst B        mean requests per burst (burst only,
                           default 4)

serve options:
  --no-prepack  disable plan-resident filter prepacking: workers re-pack
                every coded filter slab into GEMM panels per job instead
                of contracting panels packed once at plan build. The A/B
                baseline for the prepack speedup; outputs are
                bit-identical either way. Also via FCDCC_NO_PREPACK=1.

fault injection (deterministic, job-count keyed — see DESIGN.md §Fault
tolerance):
  --fault-worker W       physical worker the injected fault targets
  --fault-kind KIND      crash (dead from its --fault-jobs'th task on),
                         crash-restart (dead for --fault-jobs tasks,
                         then healthy), error (error-replies its first
                         --fault-jobs tasks), corrupt (perturbs the
                         blocks of its first --fault-jobs replies;
                         caught by the master's checksum), slow (adds
                         --fault-delay-ms to every task)
  --fault-jobs J         burst length / restart delay, in per-worker
                         dispatched tasks (default 1)
  --fault-delay-ms MS    injected delay for --fault-kind slow
                         (default 20)
  --chaos-seed S         derive a randomized single-worker fault plan
                         from seed S instead of the --fault-* flags
                         (also via FCDCC_CHAOS_SEED)
  --retry-budget R       re-dispatches per failed coded job before its
                         requests degrade to master-local execution
                         (default 2)
  --collect-timeout-ms MS  per-job collection deadline (default 60000)
  --no-replan            keep dispatching full-cluster plans while
                         workers are quarantined (retry + degradation
                         only); default is to re-plan stages for the
                         live set and restore on readmission

Every command also accepts:
  --threads T   size of the persistent compute pool the hot kernels
                (encode/decode/worker GEMMs) fan out on. Defaults to
                the FCDCC_THREADS env var, then to all cores; outputs
                are bit-identical at any setting.
  --kernel K    SIMD microkernel backend: auto (default; runtime
                feature detection), scalar, avx2, neon, or fused-ma
                (opt-in FMA contraction — validated by error bounds,
                not bit identity). Also via FCDCC_KERNEL; requesting a
                backend this machine cannot run warns and falls back.
                Default-path outputs are bit-identical across backends.
  --code C      linear code family planned for every coded layer: auto
                (default: crme, the paper's scheme), crme, vandermonde,
                chebyshev, fahim-cadambe, conv (banded convolutional),
                or sparse (weight-w random, nnz-proportional encode).
                Also via FCDCC_CODE; an unknown name warns and falls
                back to crme. All families decode exactly from any
                delta survivors; they differ in conditioning and
                encode cost.

The worker --engine defaults to im2col (fused patch-matrix reuse);
direct is the naive correctness oracle.
";

#[cfg(feature = "pjrt")]
fn pjrt_engine(artifacts_dir: &str) -> Result<Arc<dyn TaskEngine>> {
    let host = fcdcc::runtime::PjrtService::spawn(artifacts_dir)?;
    let handle = host.handle.clone();
    // Detach the host: the service lives until all handles drop.
    std::mem::forget(host);
    Ok(Arc::new(handle))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_engine(_artifacts_dir: &str) -> Result<Arc<dyn TaskEngine>> {
    bail!("built without the `pjrt` feature (enable it and add the `xla` dependency)")
}

fn resolve_engine(name: &str, artifacts_dir: &str) -> Result<Arc<dyn TaskEngine>> {
    if name == "pjrt" {
        pjrt_engine(artifacts_dir)
    } else {
        coordinator::engine_by_name(name)
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let arch = args.get_str("arch", "lenet");
    let layers = zoo::by_name(arch).ok_or_else(|| anyhow!("unknown arch {arch:?}"))?;
    let idx = args.get_usize("layer", 0)?;
    let layer = layers
        .get(idx)
        .ok_or_else(|| anyhow!("{arch} has only {} conv layers", layers.len()))?;
    let scale = args.get_usize("scale", 1)?;
    let layer = layer.scaled_spatial(scale);
    let k_a = args.get_usize("ka", 2)?;
    let k_b = args.get_usize("kb", 2)?;
    let n = args.get_usize("n", 4)?;
    let engine = resolve_engine(
        args.get_str("engine", "im2col"),
        args.get_str("artifacts", "artifacts"),
    )?;
    coordinator::run_layer(RunConfig {
        layer,
        k_a,
        k_b,
        n,
        stragglers: args.get_usize("stragglers", 0)?,
        delay: Duration::from_millis(args.get_usize("delay-ms", 100)? as u64),
        engine,
        seed: args.get_usize("seed", 7)? as u64,
        code: fcdcc::coding::registry::default_family(),
    })?;
    Ok(())
}

fn cmd_optimize(args: &Args) -> Result<()> {
    let qs: Vec<usize> = args
        .get_str("q", "16,32,64")
        .split(',')
        .map(|s| s.trim().parse().map_err(|_| anyhow!("bad Q list")))
        .collect::<Result<_>>()?;
    match args.get("arch") {
        Some(arch) => coordinator::print_optimizer_table(arch, &qs)?,
        None => {
            for arch in ["lenet", "alexnet", "vgg"] {
                coordinator::print_optimizer_table(arch, &qs)?;
            }
        }
    }
    Ok(())
}

fn cmd_stability(args: &Args) -> Result<()> {
    let samples = args.get_usize("samples", 4)?;
    let seed = args.get_usize("seed", 1)? as u64;
    // VGG conv4 structure at reduced scale (see DESIGN.md §Hardware
    // adaptation): channel geometry preserved, spatial/channel scale
    // reduced so the sweep runs in seconds.
    let layer = fcdcc::model::ConvLayer::new("vgg.conv4/s", 16, 14, 14, 64, 3, 3, 1, 1);
    let configs = [(5, 4), (20, 16), (40, 32), (48, 32), (60, 32)];
    let pts = stability::stability_sweep(&layer, &configs, samples, seed);
    let mut t = Table::new(
        "Numerical stability across CDC schemes (paper Figs. 3-4)",
        &[
            "scheme",
            "n",
            "delta",
            "gamma",
            "(kA,kB)",
            "cond median",
            "cond worst",
            "MSE mean",
            "MSE worst",
        ],
    );
    for p in &pts {
        t.row(&[
            p.scheme.to_string(),
            p.n.to_string(),
            p.delta.to_string(),
            p.gamma.to_string(),
            format!("({},{})", p.k_a, p.k_b),
            fmt_sci(p.cond_median),
            fmt_sci(p.cond_worst),
            fmt_sci(p.mse_mean),
            fmt_sci(p.mse_worst),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let engine = resolve_engine(
        args.get_str("engine", "im2col"),
        args.get_str("artifacts", "artifacts"),
    )?;
    let role = args.get_str("role", "local");
    if role == "worker" {
        let handle = spawn_worker_node(WorkerNodeConfig {
            listen: args.get_str("listen", "127.0.0.1:0").to_string(),
            engine,
            threads: args.get_usize("threads", 0)?,
        })?;
        println!("worker node listening on {}", handle.addr());
        handle.wait();
        return Ok(());
    }
    let mut cfg = ServeConfig::default_with_engine(engine);
    cfg.requests = args.get_usize("requests", 16)?;
    cfg.n_workers = args.get_usize("n", 4)?;
    match role {
        "local" => {}
        // A front-end without --workers runs the cluster in-process.
        "frontend" if args.get("workers").is_none() => {}
        "coordinator" | "frontend" => {
            let addrs: Vec<String> = args
                .get("workers")
                .ok_or_else(|| anyhow!("--role coordinator needs --workers A1,A2,..."))?
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            if addrs.is_empty() {
                bail!("--workers names no addresses");
            }
            cfg.n_workers = addrs.len();
            let mut tcp = TcpConfig::new(addrs);
            tcp.heartbeat = Duration::from_millis(args.get_usize("heartbeat-ms", 200)? as u64);
            tcp.miss_threshold = args.get_usize("miss-threshold", 3)? as u32;
            tcp.connect_timeout =
                Duration::from_millis(args.get_usize("connect-timeout-ms", 5000)? as u64);
            cfg.transport = TransportKind::Tcp(tcp);
        }
        other => bail!("unknown --role {other:?} (local, coordinator, worker, frontend)"),
    }
    // `--depth` is the historical spelling of `--max-in-flight`.
    let depth = args.get_usize("depth", 1)?;
    cfg.max_in_flight = args.get_usize("max-in-flight", depth)?;
    cfg.batch_window = args.get_usize("batch-window", 1)?;
    if args.get("max-in-flight").is_none() && args.get("depth").is_none() {
        // A wider window implies at least that many requests in flight;
        // widen the default pipeline depth to match. Explicitly passed
        // depths are left alone (serve_lenet rejects the conflict).
        cfg.max_in_flight = cfg.max_in_flight.max(cfg.batch_window);
    }
    cfg.verify_every = args.get_usize("verify-every", 1)?;
    cfg.prepack = !(args.flag("no-prepack")
        || std::env::var("FCDCC_NO_PREPACK").is_ok_and(|v| v == "1"));
    let stragglers = args.get_usize("stragglers", 0)?;
    if stragglers > 0 {
        cfg.straggler = StragglerModel::FixedCount {
            count: stragglers,
            delay: Duration::from_millis(args.get_usize("delay-ms", 100)? as u64),
        };
    }
    cfg.fault_plan = fault_plan_from_args(args, cfg.n_workers)?;
    cfg.retry_budget = args.get_usize("retry-budget", 2)?;
    cfg.collect_timeout =
        Duration::from_millis(args.get_usize("collect-timeout-ms", 60_000)? as u64);
    cfg.replan = !args.flag("no-replan");
    cfg.queue_cap = args.get_usize("queue-cap", 64)?;
    let deadline = args.get_duration_ms("request-deadline-ms", 0)?;
    if !deadline.is_zero() {
        cfg.request_deadline = Some(deadline);
    }
    cfg.arrival = arrival_from_args(args)?;
    let stats = if role == "frontend" {
        if cfg.arrival.is_some() {
            bail!("--role frontend takes arrivals from clients; drop --arrival");
        }
        let (listener, rx) = spawn_frontend(args.get_str("listen", "127.0.0.1:0"))?;
        println!("frontend listening on {}", listener.addr());
        let stats = coordinator::serve_frontend_on(cfg, rx)?;
        listener.stop();
        stats
    } else {
        coordinator::serve_lenet(cfg)?
    };
    println!(
        "served {} requests (depth {}, window {}, kernel {}, code {}): \
         mean latency {:.2}ms (p95 {:.2}ms, p99 {:.2}ms), {:.1} req/s",
        stats.requests,
        stats.max_in_flight,
        stats.batch_window,
        stats.kernel,
        stats.code,
        stats.latency.mean * 1e3,
        stats.latency.p95 * 1e3,
        stats.latency.p99 * 1e3,
        stats.throughput_rps
    );
    println!(
        "overload: {} arrivals -> {} completed / {} shed / {} expired | \
         queue peak {}/{} | completed latency p50 {:.2}ms p99 {:.2}ms",
        stats.arrivals,
        stats.completed_requests,
        stats.shed_requests,
        stats.expired_requests,
        stats.peak_queue_depth,
        stats.queue_cap,
        stats.latency_hist.p50() * 1e3,
        stats.latency_hist.p99() * 1e3
    );
    println!(
        "decode mean {:.3}ms | logit MSE {} | class mismatches {}/{} verified",
        stats.decode.mean * 1e3,
        fmt_sci(stats.mean_logit_mse),
        stats.class_mismatches,
        stats.verified
    );
    println!(
        "batching: {} coded jobs (mean batch {:.2}) | recovery inversions {} \
         (inverse cache: {} hits / {} misses, {:.0}% hit rate)",
        stats.coded_jobs,
        stats.mean_batch,
        stats.inverse_cache.misses,
        stats.inverse_cache.hits,
        stats.inverse_cache.misses,
        stats.inverse_cache.hit_rate() * 100.0
    );
    println!(
        "hot path: slab arena {} hits / {} allocations ({:.0}% reuse) | \
         filter packs {}{}",
        stats.arena.hits,
        stats.arena.misses,
        stats.arena.hit_rate() * 100.0,
        stats.pack_count,
        if stats.pack_count == 0 {
            " (plan-resident prepacked panels)"
        } else {
            " (per-job worker-side packing)"
        }
    );
    println!(
        "encode programs: {} coded slabs via {} coefficient terms \
         (dense scan would visit {}; nnz fraction {:.2})",
        stats.encode.cols,
        stats.encode.terms,
        stats.encode.dense_terms,
        stats.encode.nnz_frac()
    );
    println!(
        "fault tolerance: {} failed | {} retries | {} degraded | \
         {} quarantines / {} readmissions | {} arena buffers outstanding",
        stats.failed_requests,
        stats.retries,
        stats.degraded_requests,
        stats.quarantine_events,
        stats.readmissions,
        stats.arena_outstanding
    );
    let m = &stats.membership;
    println!(
        "membership: epoch {} | {} heartbeats ({} missed) | {} evictions / \
         {} readmissions | {} reconnects | {} corrupt frames",
        m.epoch,
        m.heartbeats_sent,
        m.heartbeats_missed,
        m.evictions,
        m.readmissions,
        m.reconnects,
        m.frames_corrupt
    );
    Ok(())
}

/// Assemble the open-loop arrival process from the `--arrival*` flags
/// (`None` = the classic demand-paced closed loop).
fn arrival_from_args(args: &Args) -> Result<Option<ArrivalSpec>> {
    let Some(kind) = args.get("arrival") else {
        return Ok(None);
    };
    let rate = args.get_f64("arrival-rate", 100.0)?;
    let seed = args.get_usize("arrival-seed", 1)? as u64;
    let spec = match kind {
        "poisson" => ArrivalSpec::poisson(rate, seed),
        "burst" => ArrivalSpec::burst(rate, args.get_usize("arrival-burst", 4)?, seed),
        other => bail!("unknown --arrival {other:?} (poisson, burst)"),
    };
    Ok(Some(spec))
}

/// Assemble the serve command's fault-injection plan: `--chaos-seed` /
/// `FCDCC_CHAOS_SEED` derive a randomized single-worker plan; otherwise
/// `--fault-worker` + `--fault-kind` pin an explicit one; otherwise the
/// plan is empty (clean run).
fn fault_plan_from_args(args: &Args, n_workers: usize) -> Result<FaultPlan> {
    if let Some(seed) = args.get("chaos-seed") {
        let seed: u64 = seed.parse().map_err(|_| anyhow!("bad --chaos-seed"))?;
        return Ok(FaultPlan::chaos(n_workers, seed));
    }
    if args.get("chaos-seed").is_none() && args.get("fault-worker").is_none() {
        if let Some(seed) = FaultPlan::chaos_seed_from_env() {
            return Ok(FaultPlan::chaos(n_workers, seed));
        }
        return Ok(FaultPlan::none());
    }
    let worker = args.get_usize("fault-worker", 0)?;
    if worker >= n_workers {
        bail!("--fault-worker {worker} is outside the {n_workers}-worker pool");
    }
    let jobs = args.get_usize("fault-jobs", 1)? as u64;
    let kind = match args.get_str("fault-kind", "crash") {
        "crash" => FaultKind::Crash {
            after: 0,
            restart_after: None,
        },
        "crash-restart" => FaultKind::Crash {
            after: 0,
            restart_after: Some(jobs),
        },
        "error" => FaultKind::ErrorReply { jobs },
        "corrupt" => FaultKind::CorruptReply { jobs },
        "slow" => FaultKind::Slow {
            delay: Duration::from_millis(args.get_usize("fault-delay-ms", 20)? as u64),
        },
        other => bail!(
            "unknown --fault-kind {other:?} (crash, crash-restart, error, corrupt, slow)"
        ),
    };
    Ok(FaultPlan::none().with_fault(worker, kind))
}

#[cfg(feature = "pjrt")]
fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = args.get_str("dir", "artifacts");
    let manifest = fcdcc::runtime::Manifest::load(
        std::path::Path::new(dir).join("manifest.json").as_path(),
    )?;
    println!("manifest OK: {} artifacts", manifest.artifacts.len());
    let host = fcdcc::runtime::PjrtService::spawn(dir)?;
    println!("PJRT compile OK (all artifacts)");
    drop(host);
    for a in &manifest.artifacts {
        println!(
            "  {}  x{:?} k{:?} -> out{:?} (stride {})",
            a.name, a.x_shape, a.k_shape, a.out_shape, a.stride
        );
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_artifacts(_args: &Args) -> Result<()> {
    bail!("the artifacts command needs the `pjrt` feature (and the `xla` dependency)")
}

fn main() -> Result<()> {
    let args = Args::parse()?;
    // Size the compute pool before any command touches a hot path (the
    // pool is built on first use and cannot be resized after).
    let threads = args.get_usize("threads", 0)?;
    if threads > 0 {
        fcdcc::util::pool::configure_global(threads);
    }
    // Install the SIMD kernel backend before any hot path dispatches:
    // --kernel overrides FCDCC_KERNEL; unavailable or unknown requests
    // warn and fall back to runtime detection instead of failing.
    if let Some(name) = args.get("kernel") {
        let (kind, warning) = fcdcc::linalg::kernel::resolve(Some(name));
        if let Some(w) = warning {
            eprintln!("fcdcc: {w}");
        }
        fcdcc::linalg::kernel::set_active(kind);
    }
    // Install the code family before any command builds a plan: --code
    // overrides FCDCC_CODE; unknown names warn and fall back to crme.
    if let Some(name) = args.get("code") {
        let (family, warning) = fcdcc::coding::registry::resolve(Some(name));
        if let Some(w) = warning {
            eprintln!("fcdcc: {w}");
        }
        fcdcc::coding::registry::set_default(family);
    }
    // Logged once at startup so every run records which backend and
    // code family it ran.
    eprintln!(
        "fcdcc: compute kernel = {}",
        fcdcc::linalg::kernel::active().name()
    );
    eprintln!(
        "fcdcc: code family = {}",
        fcdcc::coding::registry::default_family().tag()
    );
    match args.command.as_deref() {
        Some("run") => cmd_run(&args),
        Some("optimize") => cmd_optimize(&args),
        Some("stability") => cmd_stability(&args),
        Some("serve") => cmd_serve(&args),
        Some("artifacts") => cmd_artifacts(&args),
        Some(other) => bail!("unknown command {other:?}\n{USAGE}"),
        None => {
            print!("{USAGE}");
            Ok(())
        }
    }
}
