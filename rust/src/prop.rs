//! Mini property-testing harness (proptest is unavailable offline):
//! seeded generators + a runner that reports the failing case number and
//! seed so any failure is reproducible with one env var.
//!
//! ```ignore
//! prop::run("decode roundtrip", 100, |g| {
//!     let k_a = g.choose(&[1, 2, 4, 6]);
//!     ...
//!     prop::ensure(cond, "message")
//! });
//! ```
//!
//! `FCDCC_PROP_SEED` overrides the base seed; `FCDCC_PROP_CASES` scales
//! the case count.

use crate::util::rng::Rng;

/// Case-generation context handed to every property.
pub struct Gen {
    pub rng: Rng,
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi_incl: usize) -> usize {
        self.rng.range(lo, hi_incl + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }
}

/// A property outcome: `Ok(())` passes, `Err(msg)` fails with context.
pub type PropResult = Result<(), String>;

/// Check helper.
pub fn ensure(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

fn base_seed() -> u64 {
    std::env::var("FCDCC_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xFCDC_2024)
}

fn scaled_cases(cases: usize) -> usize {
    match std::env::var("FCDCC_PROP_CASES").ok().and_then(|v| v.parse::<usize>().ok()) {
        Some(n) => n,
        None => cases,
    }
}

/// Run `cases` random cases of a property; panics (test failure) on the
/// first failing case with full reproduction info.
pub fn run(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen) -> PropResult) {
    let seed = base_seed();
    let cases = scaled_cases(cases);
    for case in 0..cases {
        // Independent stream per case: failures reproduce in isolation.
        let mut g = Gen {
            rng: Rng::new(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15)),
            case,
        };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property {name:?} failed at case {case}/{cases}: {msg}\n\
                 reproduce with FCDCC_PROP_SEED={seed} (case index {case})"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run("trivial", 10, |g| {
            count += 1;
            ensure(g.usize_in(0, 5) <= 5, "in range")
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property \"failing\" failed")]
    fn failing_property_panics_with_context() {
        run("failing", 10, |g| {
            ensure(g.case < 3, format!("case {} too big", g.case))
        });
    }

    #[test]
    fn gen_helpers_in_bounds() {
        run("gen bounds", 50, |g| {
            let v = g.usize_in(2, 7);
            ensure((2..=7).contains(&v), format!("usize_in out of bounds: {v}"))?;
            let f = g.f64_in(-1.0, 1.0);
            ensure((-1.0..1.0).contains(&f), format!("f64_in out of bounds: {f}"))?;
            let c = *g.choose(&[10, 20, 30]);
            ensure([10, 20, 30].contains(&c), "choose out of set")
        });
    }
}
