//! AVX2 (`std::arch::x86_64`, 4 × f64 lanes) implementations of the
//! kernel primitives, wrapped by `kernel::Avx2`.
//!
//! Bit-identity argument (DESIGN.md §SIMD dispatch): vectorization is
//! across the `NR` output columns of the microkernel and across the
//! elements of `axpy` — each output element owns one accumulator lane
//! folding products in k-ascending order, with a separate
//! `_mm256_mul_pd` rounding and `_mm256_add_pd` rounding per step.
//! That is exactly the scalar per-element sequence; there is no FMA,
//! no horizontal reduction, and no re-association, so results equal
//! the scalar backend's bit for bit.

use super::kernel::{MR, NR};
use std::arch::x86_64::*;

// The lane layout below (4 rows × two 4-lane B vectors) is written for
// exactly this tile geometry; retuning MR/NR in `kernel.rs` must come
// with a matching rewrite here, not a silent recompile.
const _: () = assert!(MR == 4 && NR == 8);

/// The MR×NR microkernel over packed strips (see `Backend::microkernel`).
///
/// # Safety
/// Requires AVX2 support; the `kernel::Avx2` wrapper verifies it with
/// `is_x86_feature_detected!` before every call.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn microkernel(a_strip: &[f64], b_strip: &[f64]) -> [[f64; NR]; MR] {
    // Clamp to the shorter operand — the scalar kernel's
    // `chunks_exact().zip()` semantics — so no slice-length combination
    // can drive the raw-pointer reads out of bounds (packed strips from
    // the GEMM driver always match exactly).
    let kk = (a_strip.len() / MR).min(b_strip.len() / NR);
    let ap = a_strip.as_ptr();
    let bp = b_strip.as_ptr();
    // The accumulator block: 4 rows × two 4-lane vectors = 8 ymm
    // registers; plus two B vectors and one broadcast per step this
    // fits x86-64's 16 ymm registers without spills.
    let mut acc = [[_mm256_setzero_pd(); 2]; MR];
    for k in 0..kk {
        let b0 = _mm256_loadu_pd(bp.add(k * NR));
        let b1 = _mm256_loadu_pd(bp.add(k * NR + 4));
        for (r, accr) in acc.iter_mut().enumerate() {
            let av = _mm256_set1_pd(*ap.add(k * MR + r));
            // mul then add — two roundings, the scalar sequence.
            accr[0] = _mm256_add_pd(accr[0], _mm256_mul_pd(av, b0));
            accr[1] = _mm256_add_pd(accr[1], _mm256_mul_pd(av, b1));
        }
    }
    let mut out = [[0.0f64; NR]; MR];
    for (o, accr) in out.iter_mut().zip(&acc) {
        _mm256_storeu_pd(o.as_mut_ptr(), accr[0]);
        _mm256_storeu_pd(o.as_mut_ptr().add(4), accr[1]);
    }
    out
}

/// `dst += coef·src`, 4 lanes at a time with a scalar tail.
///
/// # Safety
/// Requires AVX2 support; the `kernel::Avx2` wrapper verifies it with
/// `is_x86_feature_detected!` before every call.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn axpy(coef: f64, src: &[f64], dst: &mut [f64]) {
    // Clamp to the shorter slice (the scalar `zip` semantics) so the
    // raw-pointer loop stays in bounds for any caller; the dispatcher
    // asserts equal lengths up front.
    let n = dst.len().min(src.len());
    let sp = src.as_ptr();
    let dp = dst.as_mut_ptr();
    let c = _mm256_set1_pd(coef);
    let mut i = 0usize;
    while i + 4 <= n {
        let d = _mm256_loadu_pd(dp.add(i));
        let s = _mm256_loadu_pd(sp.add(i));
        _mm256_storeu_pd(dp.add(i), _mm256_add_pd(d, _mm256_mul_pd(c, s)));
        i += 4;
    }
    while i < n {
        *dp.add(i) += coef * *sp.add(i);
        i += 1;
    }
}
