//! Dense row-major f64 matrix.

use crate::linalg::gemm;
use crate::util::rng::Rng;
use std::fmt;

/// Dense matrix, row-major storage.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for r in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for c in 0..self.cols.min(8) {
                write!(f, "{:10.4} ", self.get(r, c))?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "Mat::from_vec: size mismatch");
        Self { rows, cols, data }
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        assert!(!rows.is_empty());
        let cols = rows[0].len();
        assert!(rows.iter().all(|r| r.len() == cols));
        Self {
            rows: rows.len(),
            cols,
            data: rows.concat(),
        }
    }

    pub fn random(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        Self {
            rows,
            cols,
            data: rng.fill_uniform(rows * cols, -1.0, 1.0),
        }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn col(&self, c: usize) -> Vec<f64> {
        (0..self.rows).map(|r| self.get(r, c)).collect()
    }

    /// Cache-blocked transpose: both source and destination are walked
    /// in `B × B` tiles so one of the two strided streams always stays
    /// resident while the tile is processed (the naive row-major read /
    /// column-major write walk misses on every destination store once
    /// `rows` exceeds a cache way).
    pub fn transpose(&self) -> Mat {
        const B: usize = 32;
        let mut t = Mat::zeros(self.cols, self.rows);
        let mut r0 = 0;
        while r0 < self.rows {
            let r1 = (r0 + B).min(self.rows);
            let mut c0 = 0;
            while c0 < self.cols {
                let c1 = (c0 + B).min(self.cols);
                for r in r0..r1 {
                    let src = r * self.cols;
                    for c in c0..c1 {
                        t.data[c * self.rows + r] = self.data[src + c];
                    }
                }
                c0 = c1;
            }
            r0 = r1;
        }
        t
    }

    /// Matrix product through the shared packed GEMM microkernel
    /// ([`crate::linalg::gemm`]). Per output element the contraction is
    /// the k-ascending scalar fold from 0.0, so results match the
    /// textbook triple loop bit for bit (see the gemm module docs for
    /// the exact-zero caveat).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(
            self.cols, other.rows,
            "matmul: inner dim mismatch {}x{} · {}x{}",
            self.rows, self.cols, other.rows, other.cols
        );
        let mut out = Mat::zeros(self.rows, other.cols);
        gemm::gemm_into(
            self.rows,
            other.cols,
            self.cols,
            &gemm::RowMajor {
                data: &self.data,
                ld: self.cols.max(1),
            },
            &gemm::RowMajor {
                data: &other.data,
                ld: other.cols.max(1),
            },
            &mut out.data,
            other.cols.max(1),
        );
        out
    }

    /// y = A·x for a dense vector x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len(), "matvec: dim mismatch");
        (0..self.rows)
            .map(|r| self.row(r).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// Columns [v, e) as a new matrix.
    pub fn slice_cols(&self, v: usize, e: usize) -> Mat {
        assert!(v <= e && e <= self.cols);
        let mut out = Mat::zeros(self.rows, e - v);
        for r in 0..self.rows {
            let src = r * self.cols + v;
            let dst = r * (e - v);
            out.data[dst..dst + (e - v)].copy_from_slice(&self.data[src..src + (e - v)]);
        }
        out
    }

    /// Horizontal concatenation of column blocks.
    pub fn hcat(blocks: &[&Mat]) -> Mat {
        assert!(!blocks.is_empty());
        let rows = blocks[0].rows;
        assert!(blocks.iter().all(|b| b.rows == rows), "hcat: row mismatch");
        let cols: usize = blocks.iter().map(|b| b.cols).sum();
        let mut out = Mat::zeros(rows, cols);
        for r in 0..rows {
            let mut off = 0usize;
            for b in blocks {
                let dst = r * cols + off;
                out.data[dst..dst + b.cols].copy_from_slice(b.row(r));
                off += b.cols;
            }
        }
        out
    }

    /// Gather the given columns (in order) into a new matrix. Row-sliced:
    /// each source/destination row is taken as one slice so the inner
    /// gather runs over contiguous memory instead of recomputing strided
    /// `get`/`set` index math per element.
    pub fn gather_cols(&self, idx: &[usize]) -> Mat {
        let k = idx.len();
        let mut out = Mat::zeros(self.rows, k);
        for r in 0..self.rows {
            let src = &self.data[r * self.cols..(r + 1) * self.cols];
            let dst = &mut out.data[r * k..(r + 1) * k];
            for (d, &c) in dst.iter_mut().zip(idx) {
                *d = src[c];
            }
        }
        out
    }

    /// `out = selfᵀ · Ỹ` over row-pointer operands — the decode hot
    /// path's GEMM. `self` is the `J × I` coefficient matrix (the
    /// recovery inverse `D`), `rows` holds the `J` coded rows of `Ỹ`
    /// (each `row_len` long, typically the data of one coded output
    /// block), and `out` is the `I·row_len` accumulator, which the
    /// caller must pass **zeroed**.
    ///
    /// Runs on the packed register-tiled microkernel
    /// ([`crate::linalg::gemm`]): `Dᵀ` is read through a transposed
    /// adapter (never materialized) and packed once, `Ỹ`'s rows are
    /// packed panel-by-panel. Per output element the contraction is the
    /// j-ascending scalar fold — the summation order of the reference
    /// `coding::decode_outputs_with` — so decoded outputs equal the
    /// scalar chain's bit for bit (exact-zero coefficients are added as
    /// ±0.0 instead of skipped; see the gemm module docs for why that
    /// is indistinguishable under `==`).
    pub fn gemm_t_rows_into(&self, rows: &[&[f64]], out: &mut [f64], row_len: usize) {
        let j_n = self.rows;
        let i_n = self.cols;
        assert_eq!(rows.len(), j_n, "gemm_t_rows_into: need {j_n} coded rows");
        assert_eq!(
            out.len(),
            i_n * row_len,
            "gemm_t_rows_into: out must be {i_n}·{row_len}"
        );
        for (j, r) in rows.iter().enumerate() {
            assert_eq!(r.len(), row_len, "gemm_t_rows_into: row {j} length mismatch");
        }
        gemm::gemm_into(
            i_n,
            row_len,
            j_n,
            &gemm::TransposedA {
                data: &self.data,
                ld: i_n.max(1),
            },
            &gemm::RowsB { rows },
            out,
            row_len.max(1),
        );
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Max absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, v| m.max(v.abs()))
    }

    /// Induced 1-norm (max column abs sum).
    pub fn norm_1(&self) -> f64 {
        (0..self.cols)
            .map(|c| (0..self.rows).map(|r| self.get(r, c).abs()).sum::<f64>())
            .fold(0.0, f64::max)
    }

    /// Induced inf-norm (max row abs sum).
    pub fn norm_inf(&self) -> f64 {
        (0..self.rows)
            .map(|r| self.row(r).iter().map(|v| v.abs()).sum::<f64>())
            .fold(0.0, f64::max)
    }

    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn identity_neutral() {
        let mut rng = Rng::new(1);
        let a = Mat::random(4, 4, &mut rng);
        let i = Mat::identity(4);
        assert_eq!(a.matmul(&i).data, a.data);
        assert_eq!(i.matmul(&a).data, a.data);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(2);
        let a = Mat::random(3, 5, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn transpose_matches_naive_across_tile_boundaries() {
        // Shapes straddling the 32-wide tile: the blocked walk must
        // produce exactly the per-element definition.
        let mut rng = Rng::new(7);
        for (r, c) in [(1, 1), (5, 70), (33, 32), (64, 31), (100, 3)] {
            let a = Mat::random(r, c, &mut rng);
            let t = a.transpose();
            assert_eq!((t.rows, t.cols), (c, r));
            for i in 0..r {
                for j in 0..c {
                    assert_eq!(t.get(j, i), a.get(i, j), "({i},{j}) of {r}x{c}");
                }
            }
        }
    }

    #[test]
    fn gather_cols_arbitrary_order_and_repeats() {
        let mut rng = Rng::new(8);
        let a = Mat::random(4, 6, &mut rng);
        let g = a.gather_cols(&[5, 0, 0, 3]);
        assert_eq!((g.rows, g.cols), (4, 4));
        for r in 0..4 {
            for (j, &c) in [5usize, 0, 0, 3].iter().enumerate() {
                assert_eq!(g.get(r, j), a.get(r, c));
            }
        }
        let empty = a.gather_cols(&[]);
        assert_eq!((empty.rows, empty.cols), (4, 0));
    }

    #[test]
    fn gemm_t_rows_matches_scalar_reference() {
        // out[i] = Σ_j D(j,i)·rows[j], j ascending, zero coefs skipped —
        // verify bit-identity against that exact fold on a row length
        // that spans multiple 256-wide panels.
        let mut rng = Rng::new(9);
        let (j_n, i_n, len) = (6, 4, 600);
        let mut d = Mat::random(j_n, i_n, &mut rng);
        d.set(2, 1, 0.0); // exercise the zero-skip path
        let rows_data: Vec<Vec<f64>> =
            (0..j_n).map(|_| rng.fill_uniform(len, -1.0, 1.0)).collect();
        let rows: Vec<&[f64]> = rows_data.iter().map(|r| r.as_slice()).collect();
        let mut out = vec![0.0; i_n * len];
        d.gemm_t_rows_into(&rows, &mut out, len);
        for i in 0..i_n {
            for t in 0..len {
                let mut want = 0.0f64;
                for j in 0..j_n {
                    let c = d.get(j, i);
                    if c != 0.0 {
                        want += c * rows_data[j][t];
                    }
                }
                assert_eq!(out[i * len + t], want, "element ({i},{t})");
            }
        }
    }

    #[test]
    fn hcat_and_slice_cols() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 5.0, 6.0]);
        let b = Mat::from_vec(2, 1, vec![3.0, 7.0]);
        let c = Mat::hcat(&[&a, &b]);
        assert_eq!(c.data, vec![1.0, 2.0, 3.0, 5.0, 6.0, 7.0]);
        assert_eq!(c.slice_cols(1, 3).data, vec![2.0, 3.0, 6.0, 7.0]);
        assert_eq!(c.gather_cols(&[2, 0]).data, vec![3.0, 1.0, 7.0, 5.0]);
    }

    #[test]
    fn norms() {
        let a = Mat::from_vec(2, 2, vec![1.0, -2.0, 3.0, 4.0]);
        assert_eq!(a.norm_1(), 6.0);
        assert_eq!(a.norm_inf(), 7.0);
        assert_eq!(a.max_abs(), 4.0);
        assert!((a.fro_norm() - 30f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn matvec_known() {
        let a = Mat::from_vec(2, 3, vec![1.0, 0.0, 2.0, 0.0, 1.0, -1.0]);
        assert_eq!(a.matvec(&[1.0, 2.0, 3.0]), vec![7.0, -1.0]);
    }
}
