//! Dense row-major f64 matrix.

use crate::util::rng::Rng;
use std::fmt;

/// Dense matrix, row-major storage.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for r in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for c in 0..self.cols.min(8) {
                write!(f, "{:10.4} ", self.get(r, c))?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "Mat::from_vec: size mismatch");
        Self { rows, cols, data }
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        assert!(!rows.is_empty());
        let cols = rows[0].len();
        assert!(rows.iter().all(|r| r.len() == cols));
        Self {
            rows: rows.len(),
            cols,
            data: rows.concat(),
        }
    }

    pub fn random(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        Self {
            rows,
            cols,
            data: rng.fill_uniform(rows * cols, -1.0, 1.0),
        }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn col(&self, c: usize) -> Vec<f64> {
        (0..self.rows).map(|r| self.get(r, c)).collect()
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.set(c, r, self.get(r, c));
            }
        }
        t
    }

    /// Matrix product (ikj loop order for cache friendliness).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(
            self.cols, other.rows,
            "matmul: inner dim mismatch {}x{} · {}x{}",
            self.rows, self.cols, other.rows, other.cols
        );
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            let arow = self.row(i);
            let orow = &mut out.data[i * other.cols..(i + 1) * other.cols];
            for (k, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let brow = other.row(k);
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// y = A·x for a dense vector x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len(), "matvec: dim mismatch");
        (0..self.rows)
            .map(|r| self.row(r).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// Columns [v, e) as a new matrix.
    pub fn slice_cols(&self, v: usize, e: usize) -> Mat {
        assert!(v <= e && e <= self.cols);
        let mut out = Mat::zeros(self.rows, e - v);
        for r in 0..self.rows {
            let src = r * self.cols + v;
            let dst = r * (e - v);
            out.data[dst..dst + (e - v)].copy_from_slice(&self.data[src..src + (e - v)]);
        }
        out
    }

    /// Horizontal concatenation of column blocks.
    pub fn hcat(blocks: &[&Mat]) -> Mat {
        assert!(!blocks.is_empty());
        let rows = blocks[0].rows;
        assert!(blocks.iter().all(|b| b.rows == rows), "hcat: row mismatch");
        let cols: usize = blocks.iter().map(|b| b.cols).sum();
        let mut out = Mat::zeros(rows, cols);
        for r in 0..rows {
            let mut off = 0usize;
            for b in blocks {
                let dst = r * cols + off;
                out.data[dst..dst + b.cols].copy_from_slice(b.row(r));
                off += b.cols;
            }
        }
        out
    }

    /// Gather the given columns (in order) into a new matrix.
    pub fn gather_cols(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(self.rows, idx.len());
        for r in 0..self.rows {
            for (j, &c) in idx.iter().enumerate() {
                out.set(r, j, self.get(r, c));
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Max absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, v| m.max(v.abs()))
    }

    /// Induced 1-norm (max column abs sum).
    pub fn norm_1(&self) -> f64 {
        (0..self.cols)
            .map(|c| (0..self.rows).map(|r| self.get(r, c).abs()).sum::<f64>())
            .fold(0.0, f64::max)
    }

    /// Induced inf-norm (max row abs sum).
    pub fn norm_inf(&self) -> f64 {
        (0..self.rows)
            .map(|r| self.row(r).iter().map(|v| v.abs()).sum::<f64>())
            .fold(0.0, f64::max)
    }

    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn identity_neutral() {
        let mut rng = Rng::new(1);
        let a = Mat::random(4, 4, &mut rng);
        let i = Mat::identity(4);
        assert_eq!(a.matmul(&i).data, a.data);
        assert_eq!(i.matmul(&a).data, a.data);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(2);
        let a = Mat::random(3, 5, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn hcat_and_slice_cols() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 5.0, 6.0]);
        let b = Mat::from_vec(2, 1, vec![3.0, 7.0]);
        let c = Mat::hcat(&[&a, &b]);
        assert_eq!(c.data, vec![1.0, 2.0, 3.0, 5.0, 6.0, 7.0]);
        assert_eq!(c.slice_cols(1, 3).data, vec![2.0, 3.0, 6.0, 7.0]);
        assert_eq!(c.gather_cols(&[2, 0]).data, vec![3.0, 1.0, 7.0, 5.0]);
    }

    #[test]
    fn norms() {
        let a = Mat::from_vec(2, 2, vec![1.0, -2.0, 3.0, 4.0]);
        assert_eq!(a.norm_1(), 6.0);
        assert_eq!(a.norm_inf(), 7.0);
        assert_eq!(a.max_abs(), 4.0);
        assert!((a.fro_norm() - 30f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn matvec_known() {
        let a = Mat::from_vec(2, 3, vec![1.0, 0.0, 2.0, 0.0, 1.0, -1.0]);
        assert_eq!(a.matvec(&[1.0, 2.0, 3.0]), vec![7.0, -1.0]);
    }
}
