//! Runtime-dispatched SIMD backend family for the hot-path kernels: the
//! packed GEMM microkernel (`linalg::gemm`) and the `axpy`
//! row-combination primitive shared by the fused batch encoder
//! (`FcdccPlan::encode_input_batch`) and the CRME/Vandermonde
//! coefficient application in `coding/` (`Tensor3::axpy` /
//! `Tensor4::axpy`).
//!
//! A [`Backend`] bundles the four kernel primitives; three default-path
//! implementations exist — portable [`Scalar`], [`Avx2`]
//! (`std::arch::x86_64`, 4 × f64 lanes), and [`Neon`]
//! (`std::arch::aarch64`, 2 × f64 lanes) — selected once per process by
//! runtime feature detection ([`auto_kind`]) and overridable with the
//! `--kernel` CLI flag / `FCDCC_KERNEL={auto,scalar,avx2,neon,fused-ma}`
//! env var. Requests for a backend this machine cannot run degrade to
//! the auto choice with a warning instead of failing ([`resolve`]).
//!
//! **Bit-identity by construction** (DESIGN.md §SIMD dispatch): the
//! SIMD backends vectorize across the `NR` output-column lanes of the
//! microkernel (and across the elements of `axpy`), so every output
//! element keeps its own accumulator lane folding `a·b` products in
//! k-ascending order with a separate multiply rounding and add rounding
//! per step — exactly the scalar sequence, hence `==`-identical
//! results. No FMA contraction, no horizontal reductions, no
//! re-association anywhere on the default path. Packing is shared
//! scalar data movement, so every backend consumes identical packed
//! bytes. The one exception is the opt-in [`FusedMa`] backend, which
//! contracts each multiply-add into a single `mul_add` rounding: it is
//! *not* on the bit-identity contract ([`Kind::bit_exact`] is false)
//! and is validated by relative-error bounds instead of `==`.

use super::gemm::{SrcA, SrcB};
use std::sync::atomic::{AtomicU8, Ordering};

/// Microkernel tile height (rows of A per packed strip). Single home of
/// the tile geometry; `linalg::gemm` re-exports these.
pub const MR: usize = 4;
/// Microkernel tile width (columns of B per packed strip) — also the
/// SIMD lane axis: backends vectorize across these NR output columns.
pub const NR: usize = 8;
/// Column-panel width: B is packed and consumed `NC` columns at a time
/// so the packed panel (`K·NC` doubles) stays cache-resident across all
/// A strips. A multiple of `NR`.
pub const NC: usize = 256;

/// One kernel backend: the microkernel + packing + axpy primitives the
/// hot paths monomorphize over. Implementations are zero-sized types
/// dispatched through [`Kind`] (one match per top-level call, so the
/// inner loops stay fully monomorphized).
pub trait Backend {
    /// Name used in logs, bench JSON tags, and `ServeStats`.
    const NAME: &'static str;

    /// The MR×NR microkernel: fold one packed A strip against one
    /// packed B strip, k ascending, one accumulator per output element
    /// (a lane, for the SIMD backends), starting from 0.0.
    fn microkernel(a_strip: &[f64], b_strip: &[f64]) -> [[f64; NR]; MR];

    /// `dst += coef·src` (equal lengths). Per element this must be the
    /// scalar two-rounding sequence (multiply, then add) on the
    /// default path; [`FusedMa`] is the documented exception.
    fn axpy(coef: f64, src: &[f64], dst: &mut [f64]);

    /// Pack all of A into MR-row strips, k-major, tail rows
    /// zero-padded: strip `s` holds rows `[s·MR, s·MR + MR)`; within a
    /// strip, the MR values of column k sit at `[k·MR, (k+1)·MR)`.
    /// Every element of the used prefix is written (padding lanes
    /// explicitly zeroed), so a reused scratch buffer never leaks stale
    /// data. Returns the strip count.
    ///
    /// Default: shared scalar packing. Packing is pure data movement —
    /// every backend packs identical bytes (part of the bit-identity
    /// argument), and the generic `SrcA` adapters defeat vector loads
    /// anyway; a backend would only override this for a concrete
    /// layout it can bulk-load.
    fn pack_a<A: SrcA>(a: &A, m: usize, kk: usize, packed: &mut Vec<f64>) -> usize {
        let strips = m.div_ceil(MR);
        let need = strips * kk * MR;
        if packed.len() < need {
            packed.resize(need, 0.0);
        }
        for s in 0..strips {
            let r0 = s * MR;
            let mh = MR.min(m - r0);
            let base = s * kk * MR;
            for k in 0..kk {
                let dst = base + k * MR;
                for r in 0..mh {
                    packed[dst + r] = a.at(r0 + r, k);
                }
                for r in mh..MR {
                    packed[dst + r] = 0.0;
                }
            }
        }
        strips
    }

    /// Pack the B panel covering columns `[j0, j0 + nw)` into NR-column
    /// strips, k-major, tail columns zero-padded. `packed` must hold
    /// `nw.div_ceil(NR) · kk · NR` values. Default: shared scalar
    /// packing (see [`Backend::pack_a`]).
    fn pack_b_panel<B: SrcB>(b: &B, kk: usize, j0: usize, nw: usize, packed: &mut [f64]) {
        let strips = nw.div_ceil(NR);
        for t in 0..strips {
            let c0 = j0 + t * NR;
            let cw = NR.min(j0 + nw - c0);
            let base = t * kk * NR;
            for k in 0..kk {
                let dst = base + k * NR;
                for l in 0..cw {
                    packed[dst + l] = b.at(k, c0 + l);
                }
                for l in cw..NR {
                    packed[dst + l] = 0.0;
                }
            }
        }
    }
}

/// Portable scalar backend — the reference fold every other backend
/// must reproduce (bit for bit on the default path).
pub struct Scalar;

impl Backend for Scalar {
    const NAME: &'static str = "scalar";

    #[inline]
    fn microkernel(a_strip: &[f64], b_strip: &[f64]) -> [[f64; NR]; MR] {
        let mut acc = [[0.0f64; NR]; MR];
        for (av, bv) in a_strip.chunks_exact(MR).zip(b_strip.chunks_exact(NR)) {
            for (accr, &a) in acc.iter_mut().zip(av) {
                for (o, &b) in accr.iter_mut().zip(bv) {
                    *o += a * b;
                }
            }
        }
        acc
    }

    #[inline]
    fn axpy(coef: f64, src: &[f64], dst: &mut [f64]) {
        debug_assert_eq!(src.len(), dst.len());
        for (d, &s) in dst.iter_mut().zip(src) {
            *d += coef * s;
        }
    }
}

/// AVX2 backend (x86_64): 4 × f64 lanes across the NR output columns.
/// The safe wrappers re-check feature availability (a cached atomic
/// test) and fall back to [`Scalar`] — same bits either way — so they
/// are sound even if called outside the dispatcher.
#[cfg(target_arch = "x86_64")]
pub struct Avx2;

#[cfg(target_arch = "x86_64")]
impl Backend for Avx2 {
    const NAME: &'static str = "avx2";

    #[inline]
    fn microkernel(a_strip: &[f64], b_strip: &[f64]) -> [[f64; NR]; MR] {
        if std::arch::is_x86_feature_detected!("avx2") {
            // SAFETY: AVX2 presence verified just above.
            unsafe { super::simd_avx2::microkernel(a_strip, b_strip) }
        } else {
            Scalar::microkernel(a_strip, b_strip)
        }
    }

    #[inline]
    fn axpy(coef: f64, src: &[f64], dst: &mut [f64]) {
        debug_assert_eq!(src.len(), dst.len());
        if std::arch::is_x86_feature_detected!("avx2") {
            // SAFETY: AVX2 presence verified just above.
            unsafe { super::simd_avx2::axpy(coef, src, dst) }
        } else {
            Scalar::axpy(coef, src, dst);
        }
    }
}

/// NEON backend (aarch64): 2 × f64 lanes across the NR output columns.
/// NEON is baseline on every aarch64 target this crate builds for; the
/// safe wrappers still re-check and fall back to [`Scalar`].
#[cfg(target_arch = "aarch64")]
pub struct Neon;

#[cfg(target_arch = "aarch64")]
impl Backend for Neon {
    const NAME: &'static str = "neon";

    #[inline]
    fn microkernel(a_strip: &[f64], b_strip: &[f64]) -> [[f64; NR]; MR] {
        if std::arch::is_aarch64_feature_detected!("neon") {
            // SAFETY: NEON presence verified just above.
            unsafe { super::simd_neon::microkernel(a_strip, b_strip) }
        } else {
            Scalar::microkernel(a_strip, b_strip)
        }
    }

    #[inline]
    fn axpy(coef: f64, src: &[f64], dst: &mut [f64]) {
        debug_assert_eq!(src.len(), dst.len());
        if std::arch::is_aarch64_feature_detected!("neon") {
            // SAFETY: NEON presence verified just above.
            unsafe { super::simd_neon::axpy(coef, src, dst) }
        } else {
            Scalar::axpy(coef, src, dst);
        }
    }
}

/// Opt-in fused multiply-add backend: contracts each `acc + a·b` step
/// into one `mul_add` rounding. **Not** on the bit-identity contract —
/// results differ from the scalar fold by at most the dropped
/// intermediate roundings and are validated by relative-error bounds
/// (see `tests/simd_kernels.rs`). Never auto-selected; only active via
/// `--kernel fused-ma` / `FCDCC_KERNEL=fused-ma`. Portable: on targets
/// without hardware FMA, `mul_add` falls back to (slow but correct)
/// software fma — acceptable for an explicit opt-in.
pub struct FusedMa;

impl Backend for FusedMa {
    const NAME: &'static str = "fused-ma";

    #[inline]
    fn microkernel(a_strip: &[f64], b_strip: &[f64]) -> [[f64; NR]; MR] {
        let mut acc = [[0.0f64; NR]; MR];
        for (av, bv) in a_strip.chunks_exact(MR).zip(b_strip.chunks_exact(NR)) {
            for (accr, &a) in acc.iter_mut().zip(av) {
                for (o, &b) in accr.iter_mut().zip(bv) {
                    *o = a.mul_add(b, *o);
                }
            }
        }
        acc
    }

    #[inline]
    fn axpy(coef: f64, src: &[f64], dst: &mut [f64]) {
        debug_assert_eq!(src.len(), dst.len());
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = coef.mul_add(s, *d);
        }
    }
}

/// The dispatchable backend set. Variants exist on every architecture
/// (so CLI/env parsing is portable); [`Kind::is_available`] says
/// whether this machine can actually run one.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Kind {
    Scalar = 0,
    Avx2 = 1,
    Neon = 2,
    FusedMa = 3,
}

#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_available() -> bool {
    false
}

#[cfg(target_arch = "aarch64")]
fn neon_available() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

#[cfg(not(target_arch = "aarch64"))]
fn neon_available() -> bool {
    false
}

impl Kind {
    /// The name used by `--kernel` / `FCDCC_KERNEL`, logs, and bench
    /// JSON tags.
    pub fn name(self) -> &'static str {
        match self {
            Kind::Scalar => Scalar::NAME,
            Kind::Avx2 => "avx2",
            Kind::Neon => "neon",
            Kind::FusedMa => FusedMa::NAME,
        }
    }

    /// Parse a `--kernel` / `FCDCC_KERNEL` value (`"auto"` is handled
    /// by [`resolve`], not here).
    pub fn parse(name: &str) -> Option<Kind> {
        match name {
            "scalar" => Some(Kind::Scalar),
            "avx2" => Some(Kind::Avx2),
            "neon" => Some(Kind::Neon),
            "fused-ma" | "fused_ma" | "fma" => Some(Kind::FusedMa),
            _ => None,
        }
    }

    /// Can this machine run the backend? (`Scalar` and `FusedMa` are
    /// always runnable; SIMD kinds need the right architecture and CPU
    /// feature.)
    pub fn is_available(self) -> bool {
        match self {
            Kind::Scalar | Kind::FusedMa => true,
            Kind::Avx2 => avx2_available(),
            Kind::Neon => neon_available(),
        }
    }

    /// Whether the backend is on the bit-identity contract (`==`
    /// against the scalar fold). Only [`FusedMa`] is not: it is
    /// validated by relative-error bounds instead.
    pub fn bit_exact(self) -> bool {
        !matches!(self, Kind::FusedMa)
    }

    fn from_u8(v: u8) -> Option<Kind> {
        match v {
            0 => Some(Kind::Scalar),
            1 => Some(Kind::Avx2),
            2 => Some(Kind::Neon),
            3 => Some(Kind::FusedMa),
            _ => None,
        }
    }
}

/// Every **default-path** (bit-exact) kind available on this machine,
/// scalar first — the set the differential tests iterate and assert
/// `==` over. [`FusedMa`] is deliberately excluded: it is opt-in and
/// validated by error bounds, not bit identity.
pub fn available() -> Vec<Kind> {
    let mut kinds = vec![Kind::Scalar];
    for k in [Kind::Avx2, Kind::Neon] {
        if k.is_available() {
            kinds.push(k);
        }
    }
    kinds
}

/// The backend runtime feature detection picks on this machine: the
/// widest available SIMD kind, else scalar. Never [`FusedMa`] — FMA
/// contraction is strictly opt-in.
pub fn auto_kind() -> Kind {
    if avx2_available() {
        Kind::Avx2
    } else if neon_available() {
        Kind::Neon
    } else {
        Kind::Scalar
    }
}

/// Resolve a requested kernel name to a runnable [`Kind`], with
/// graceful fallback: `None` / `"auto"` run detection; an unknown name
/// or an unavailable target degrades to [`auto_kind`] and returns a
/// warning message for the caller to log (requests never fail hard —
/// a mis-set `FCDCC_KERNEL` must not take serving down).
pub fn resolve(request: Option<&str>) -> (Kind, Option<String>) {
    match request.map(str::trim).filter(|s| !s.is_empty()) {
        None | Some("auto") => (auto_kind(), None),
        Some(name) => match Kind::parse(name) {
            Some(kind) if kind.is_available() => (kind, None),
            Some(kind) => {
                let auto = auto_kind();
                (
                    auto,
                    Some(format!(
                        "kernel {:?} is unavailable on this machine; falling back to {:?}",
                        kind.name(),
                        auto.name()
                    )),
                )
            }
            None => {
                let auto = auto_kind();
                (
                    auto,
                    Some(format!(
                        "unknown kernel {name:?} (expected auto|scalar|avx2|neon|fused-ma); \
                         using {:?}",
                        auto.name()
                    )),
                )
            }
        },
    }
}

const KIND_UNSET: u8 = u8::MAX;

/// The process-wide dispatch target, initialized lazily from
/// `FCDCC_KERNEL` (default `auto`) on first use.
static ACTIVE: AtomicU8 = AtomicU8::new(KIND_UNSET);

/// The active dispatch target. First call resolves `FCDCC_KERNEL`
/// (logging the fallback warning, once, if the request was
/// unavailable); later calls are one relaxed atomic load.
pub fn active() -> Kind {
    match Kind::from_u8(ACTIVE.load(Ordering::Relaxed)) {
        Some(kind) => kind,
        None => init_from_env(),
    }
}

#[cold]
fn init_from_env() -> Kind {
    let (kind, warning) = resolve(std::env::var("FCDCC_KERNEL").ok().as_deref());
    if ACTIVE
        .compare_exchange(KIND_UNSET, kind as u8, Ordering::Relaxed, Ordering::Relaxed)
        .is_ok()
    {
        if let Some(w) = warning {
            eprintln!("fcdcc: {w}");
        }
        kind
    } else {
        // Lost the init race to another thread (or to set_active).
        Kind::from_u8(ACTIVE.load(Ordering::Relaxed)).unwrap_or(Kind::Scalar)
    }
}

/// Install `kind` as the process-wide dispatch target (the `--kernel`
/// CLI path, and the cross-backend tests/benches), returning the
/// previously active kind so callers can restore it. Panics if `kind`
/// is unavailable here — use [`resolve`] for the graceful-fallback
/// path. Safe to switch mid-process: every bit-exact backend produces
/// identical results, so in-flight work cannot observe the swap (the
/// non-bit-exact [`FusedMa`] should only be installed process-wide by
/// an explicit operator opt-in, never mid-run).
pub fn set_active(kind: Kind) -> Kind {
    assert!(
        kind.is_available(),
        "kernel {:?} is not available on this machine",
        kind.name()
    );
    match Kind::from_u8(ACTIVE.swap(kind as u8, Ordering::Relaxed)) {
        Some(prev) => prev,
        // First set of the process: report what lazy init would have
        // picked, so restoring with this value is meaningful.
        None => resolve(std::env::var("FCDCC_KERNEL").ok().as_deref()).0,
    }
}

/// `dst += coef·src` on the **active** backend — the shared
/// row-combination primitive behind `Tensor3::axpy` / `Tensor4::axpy`
/// (the CRME/Vandermonde coefficient application in `coding/`) and the
/// fused batch encoder's per-row fill. Per element this is the scalar
/// `d += coef * s` two-rounding sequence on every default-path
/// backend, so dispatch never changes results.
#[inline]
pub fn axpy(coef: f64, src: &[f64], dst: &mut [f64]) {
    axpy_kind(active(), coef, src, dst);
}

/// [`axpy`] on an explicit backend (differential tests and benches).
pub fn axpy_kind(kind: Kind, coef: f64, src: &[f64], dst: &mut [f64]) {
    assert_eq!(src.len(), dst.len(), "axpy: length mismatch");
    match kind {
        Kind::Scalar => Scalar::axpy(coef, src, dst),
        #[cfg(target_arch = "x86_64")]
        Kind::Avx2 => Avx2::axpy(coef, src, dst),
        #[cfg(target_arch = "aarch64")]
        Kind::Neon => Neon::axpy(coef, src, dst),
        Kind::FusedMa => FusedMa::axpy(coef, src, dst),
        // A SIMD kind can never be *active* on a foreign architecture
        // (the dispatcher only installs available kinds); scalar keeps
        // the match total for direct callers.
        #[cfg(not(target_arch = "x86_64"))]
        Kind::Avx2 => Scalar::axpy(coef, src, dst),
        #[cfg(not(target_arch = "aarch64"))]
        Kind::Neon => Scalar::axpy(coef, src, dst),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn names_parse_round_trip() {
        for kind in [Kind::Scalar, Kind::Avx2, Kind::Neon, Kind::FusedMa] {
            assert_eq!(Kind::parse(kind.name()), Some(kind), "{kind:?}");
        }
        assert_eq!(Kind::parse("fma"), Some(Kind::FusedMa));
        assert_eq!(Kind::parse("sse9"), None);
    }

    #[test]
    fn auto_and_available_are_runnable_and_bit_exact() {
        assert!(auto_kind().is_available());
        assert!(auto_kind().bit_exact(), "FMA must never be auto-selected");
        let kinds = available();
        assert_eq!(kinds[0], Kind::Scalar);
        for k in kinds {
            assert!(k.is_available() && k.bit_exact(), "{k:?}");
        }
    }

    #[test]
    fn resolve_falls_back_gracefully() {
        assert_eq!(resolve(None), (auto_kind(), None));
        assert_eq!(resolve(Some("auto")), (auto_kind(), None));
        assert_eq!(resolve(Some("scalar")), (Kind::Scalar, None));
        // An unknown name warns and degrades to auto instead of failing.
        let (kind, warn) = resolve(Some("quantum"));
        assert_eq!(kind, auto_kind());
        assert!(warn.is_some());
        // At most one of avx2/neon exists on any one machine, so the
        // other must fall back with a warning.
        let foreign = if Kind::Avx2.is_available() { "neon" } else { "avx2" };
        let (kind, warn) = resolve(Some(foreign));
        assert!(kind.is_available());
        assert!(warn.is_some(), "unavailable {foreign} must warn");
    }

    #[test]
    fn set_active_round_trips() {
        let prev = set_active(Kind::Scalar);
        assert!(prev.is_available());
        assert_eq!(active(), Kind::Scalar);
        set_active(prev);
        assert_eq!(active(), prev);
    }

    #[test]
    fn axpy_backends_match_scalar_bitwise() {
        let mut rng = Rng::new(23);
        // Lengths around the 4- and 2-lane vector widths, incl. 0.
        for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 31, 100] {
            let src = rng.fill_uniform(len, -1.0, 1.0);
            let base = rng.fill_uniform(len, -1.0, 1.0);
            let coef = rng.uniform(-2.0, 2.0);
            let mut want = base.clone();
            axpy_kind(Kind::Scalar, coef, &src, &mut want);
            for kind in available() {
                let mut got = base.clone();
                axpy_kind(kind, coef, &src, &mut got);
                assert_eq!(got, want, "kind {kind:?} len {len}");
            }
        }
    }

    #[test]
    fn fused_ma_axpy_within_relative_error() {
        let mut rng = Rng::new(24);
        let src = rng.fill_uniform(257, -1.0, 1.0);
        let base = rng.fill_uniform(257, -1.0, 1.0);
        let mut want = base.clone();
        axpy_kind(Kind::Scalar, 0.7, &src, &mut want);
        let mut got = base.clone();
        axpy_kind(Kind::FusedMa, 0.7, &src, &mut got);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() <= 1e-14 * (w.abs() + 1.0), "{g} vs {w}");
        }
    }
}
