//! NEON (`std::arch::aarch64`, 2 × f64 lanes) implementations of the
//! kernel primitives, wrapped by `kernel::Neon`.
//!
//! Bit-identity argument (DESIGN.md §SIMD dispatch): vectorization is
//! across the `NR` output columns of the microkernel and across the
//! elements of `axpy` — each output element owns one accumulator lane
//! folding products in k-ascending order, with a separate `vmulq_f64`
//! rounding and `vaddq_f64` rounding per step. That is exactly the
//! scalar per-element sequence; there is no `vfmaq` contraction, no
//! horizontal reduction, and no re-association, so results equal the
//! scalar backend's bit for bit.

use super::kernel::{MR, NR};
use std::arch::aarch64::*;

// The lane layout below (4 rows × four 2-lane B vectors) is written for
// exactly this tile geometry; retuning MR/NR in `kernel.rs` must come
// with a matching rewrite here, not a silent recompile.
const _: () = assert!(MR == 4 && NR == 8);

/// The MR×NR microkernel over packed strips (see `Backend::microkernel`).
///
/// # Safety
/// Requires NEON support; the `kernel::Neon` wrapper verifies it with
/// `is_aarch64_feature_detected!` before every call (NEON is baseline
/// on aarch64 targets, so the check never fails in practice).
#[target_feature(enable = "neon")]
pub(super) unsafe fn microkernel(a_strip: &[f64], b_strip: &[f64]) -> [[f64; NR]; MR] {
    // Clamp to the shorter operand — the scalar kernel's
    // `chunks_exact().zip()` semantics — so no slice-length combination
    // can drive the raw-pointer reads out of bounds (packed strips from
    // the GEMM driver always match exactly).
    let kk = (a_strip.len() / MR).min(b_strip.len() / NR);
    let ap = a_strip.as_ptr();
    let bp = b_strip.as_ptr();
    // 4 rows × four 2-lane vectors = 16 accumulator registers; with
    // four B vectors and one broadcast this sits comfortably in
    // aarch64's 32 × 128-bit register file.
    let mut acc = [[vdupq_n_f64(0.0); 4]; MR];
    for k in 0..kk {
        let b0 = vld1q_f64(bp.add(k * NR));
        let b1 = vld1q_f64(bp.add(k * NR + 2));
        let b2 = vld1q_f64(bp.add(k * NR + 4));
        let b3 = vld1q_f64(bp.add(k * NR + 6));
        for (r, accr) in acc.iter_mut().enumerate() {
            let av = vdupq_n_f64(*ap.add(k * MR + r));
            // mul then add — two roundings, the scalar sequence.
            accr[0] = vaddq_f64(accr[0], vmulq_f64(av, b0));
            accr[1] = vaddq_f64(accr[1], vmulq_f64(av, b1));
            accr[2] = vaddq_f64(accr[2], vmulq_f64(av, b2));
            accr[3] = vaddq_f64(accr[3], vmulq_f64(av, b3));
        }
    }
    let mut out = [[0.0f64; NR]; MR];
    for (o, accr) in out.iter_mut().zip(&acc) {
        vst1q_f64(o.as_mut_ptr(), accr[0]);
        vst1q_f64(o.as_mut_ptr().add(2), accr[1]);
        vst1q_f64(o.as_mut_ptr().add(4), accr[2]);
        vst1q_f64(o.as_mut_ptr().add(6), accr[3]);
    }
    out
}

/// `dst += coef·src`, 2 lanes at a time with a scalar tail.
///
/// # Safety
/// Requires NEON support; the `kernel::Neon` wrapper verifies it with
/// `is_aarch64_feature_detected!` before every call.
#[target_feature(enable = "neon")]
pub(super) unsafe fn axpy(coef: f64, src: &[f64], dst: &mut [f64]) {
    // Clamp to the shorter slice (the scalar `zip` semantics) so the
    // raw-pointer loop stays in bounds for any caller; the dispatcher
    // asserts equal lengths up front.
    let n = dst.len().min(src.len());
    let sp = src.as_ptr();
    let dp = dst.as_mut_ptr();
    let c = vdupq_n_f64(coef);
    let mut i = 0usize;
    while i + 2 <= n {
        let d = vld1q_f64(dp.add(i));
        let s = vld1q_f64(sp.add(i));
        vst1q_f64(dp.add(i), vaddq_f64(d, vmulq_f64(c, s)));
        i += 2;
    }
    while i < n {
        *dp.add(i) += coef * *sp.add(i);
        i += 1;
    }
}
