//! Singular values via one-sided Jacobi — used for exact 2-norm condition
//! numbers of recovery matrices (paper Fig. 4). One-sided Jacobi is slow
//! but extremely accurate for small/ill-conditioned matrices, which is
//! exactly the regime of interest (k_A·k_B ≤ ~128).

use crate::linalg::Mat;

/// Singular values of `a` (descending). One-sided Jacobi on the columns of
/// a working copy of A (rows >= cols is handled by transposing as needed).
pub fn singular_values(a: &Mat) -> Vec<f64> {
    // Work on the matrix with rows >= cols.
    let work = if a.rows >= a.cols { a.clone() } else { a.transpose() };
    let m = work.rows;
    let n = work.cols;
    // Column-major copy for cheap column access.
    let mut u = vec![0.0f64; m * n];
    for r in 0..m {
        for c in 0..n {
            u[c * m + r] = work.get(r, c);
        }
    }
    let eps = f64::EPSILON;
    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Compute [app apq; apq aqq] of A^T A for columns p,q.
                let (mut app, mut aqq, mut apq) = (0.0, 0.0, 0.0);
                for i in 0..m {
                    let x = u[p * m + i];
                    let y = u[q * m + i];
                    app += x * x;
                    aqq += y * y;
                    apq += x * y;
                }
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                off = off.max(apq.abs() / (app * aqq).sqrt().max(f64::MIN_POSITIVE));
                // Jacobi rotation annihilating apq.
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let x = u[p * m + i];
                    let y = u[q * m + i];
                    u[p * m + i] = c * x - s * y;
                    u[q * m + i] = s * x + c * y;
                }
            }
        }
        if off < 1e-15 {
            break;
        }
    }
    let mut sv: Vec<f64> = (0..n)
        .map(|c| (0..m).map(|i| u[c * m + i] * u[c * m + i]).sum::<f64>().sqrt())
        .collect();
    sv.sort_by(|a, b| b.partial_cmp(a).unwrap());
    sv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn diagonal_matrix() {
        let a = Mat::from_vec(3, 3, vec![3.0, 0.0, 0.0, 0.0, -5.0, 0.0, 0.0, 0.0, 1.0]);
        let sv = singular_values(&a);
        assert!((sv[0] - 5.0).abs() < 1e-12);
        assert!((sv[1] - 3.0).abs() < 1e-12);
        assert!((sv[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn orthogonal_matrix_all_ones() {
        let th = 0.3f64;
        let a = Mat::from_vec(2, 2, vec![th.cos(), -th.sin(), th.sin(), th.cos()]);
        let sv = singular_values(&a);
        assert!((sv[0] - 1.0).abs() < 1e-12);
        assert!((sv[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rank_deficient_has_zero_sv() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 4.0]);
        let sv = singular_values(&a);
        assert!(sv[1].abs() < 1e-12, "sv={sv:?}");
    }

    #[test]
    fn frobenius_consistency_random() {
        let mut rng = Rng::new(9);
        for (r, c) in [(4, 4), (6, 3), (3, 6), (12, 12)] {
            let a = Mat::random(r, c, &mut rng);
            let sv = singular_values(&a);
            let fro2: f64 = sv.iter().map(|s| s * s).sum();
            assert!(
                (fro2.sqrt() - a.fro_norm()).abs() < 1e-9,
                "{r}x{c}: {} vs {}",
                fro2.sqrt(),
                a.fro_norm()
            );
        }
    }

    #[test]
    fn matches_known_2x2() {
        // A = [[1,1],[0,1]]: singular values are golden-ratio related:
        // sigma = sqrt((3±sqrt(5))/2)
        let a = Mat::from_vec(2, 2, vec![1.0, 1.0, 0.0, 1.0]);
        let sv = singular_values(&a);
        let s1 = ((3.0 + 5f64.sqrt()) / 2.0).sqrt();
        let s2 = ((3.0 - 5f64.sqrt()) / 2.0).sqrt();
        assert!((sv[0] - s1).abs() < 1e-12);
        assert!((sv[1] - s2).abs() < 1e-12);
    }
}
