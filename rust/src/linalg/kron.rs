//! Kronecker product — the joint encoding matrix G = A ⊗ B (paper
//! eq. (41)) and the per-worker column blocks G_i = A_i ⊗ B_i.

use crate::linalg::Mat;

/// Kronecker product A ⊗ B: (a.rows·b.rows) × (a.cols·b.cols).
pub fn kron(a: &Mat, b: &Mat) -> Mat {
    let mut out = Mat::zeros(a.rows * b.rows, a.cols * b.cols);
    for ar in 0..a.rows {
        for ac in 0..a.cols {
            let av = a.get(ar, ac);
            if av == 0.0 {
                continue;
            }
            for br in 0..b.rows {
                let orow = (ar * b.rows + br) * out.cols + ac * b.cols;
                let brow = br * b.cols;
                for bc in 0..b.cols {
                    out.data[orow + bc] = av * b.data[brow + bc];
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn kron_known_2x2() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]);
        let k = kron(&a, &b);
        assert_eq!(k.rows, 4);
        assert_eq!(k.cols, 4);
        #[rustfmt::skip]
        let expect = vec![
            0.0, 1.0, 0.0, 2.0,
            1.0, 0.0, 2.0, 0.0,
            0.0, 3.0, 0.0, 4.0,
            3.0, 0.0, 4.0, 0.0,
        ];
        assert_eq!(k.data, expect);
    }

    #[test]
    fn kron_with_identity() {
        let mut rng = Rng::new(5);
        let a = Mat::random(3, 3, &mut rng);
        let i1 = Mat::identity(1);
        assert_eq!(kron(&a, &i1), a);
        assert_eq!(kron(&i1, &a), a);
    }

    #[test]
    fn mixed_product_property() {
        // (A⊗B)(C⊗D) = (AC)⊗(BD)
        let mut rng = Rng::new(6);
        let a = Mat::random(2, 3, &mut rng);
        let b = Mat::random(2, 2, &mut rng);
        let c = Mat::random(3, 2, &mut rng);
        let d = Mat::random(2, 2, &mut rng);
        let lhs = kron(&a, &b).matmul(&kron(&c, &d));
        let rhs = kron(&a.matmul(&c), &b.matmul(&d));
        let err: f64 = lhs
            .data
            .iter()
            .zip(&rhs.data)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f64::max);
        assert!(err < 1e-12);
    }
}
