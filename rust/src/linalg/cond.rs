//! Condition numbers of recovery matrices (paper Fig. 4 and §V-A).

use crate::linalg::{lu, singular_values, Mat};

/// Exact 2-norm condition number via Jacobi SVD: κ₂ = σ_max / σ_min.
/// Returns `f64::INFINITY` for (numerically) singular matrices.
pub fn cond_2(a: &Mat) -> f64 {
    let sv = singular_values(a);
    let smax = sv.first().copied().unwrap_or(0.0);
    let smin = sv.last().copied().unwrap_or(0.0);
    if smin <= 0.0 || !smin.is_finite() {
        f64::INFINITY
    } else {
        smax / smin
    }
}

/// 1-norm condition estimate κ₁ = ‖A‖₁·‖A⁻¹‖₁ computed with an explicit
/// inverse (fine at recovery-matrix sizes). Returns INFINITY when the
/// factorization fails.
pub fn cond_1_estimate(a: &Mat) -> f64 {
    match lu::invert(a) {
        Ok(inv) => a.norm_1() * inv.norm_1(),
        Err(_) => f64::INFINITY,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_cond_is_one() {
        let i = Mat::identity(6);
        assert!((cond_2(&i) - 1.0).abs() < 1e-12);
        assert!((cond_1_estimate(&i) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn diag_cond_ratio() {
        let a = Mat::from_vec(2, 2, vec![100.0, 0.0, 0.0, 0.5]);
        assert!((cond_2(&a) - 200.0).abs() < 1e-9);
    }

    #[test]
    fn singular_is_infinite() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 4.0]);
        assert_eq!(cond_2(&a), f64::INFINITY);
        assert_eq!(cond_1_estimate(&a), f64::INFINITY);
    }

    #[test]
    fn norm_bounds_hold() {
        // For any n x n matrix: cond_1 / n <= cond_2 <= n * cond_1.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(17);
        for n in [3usize, 6, 10] {
            let a = Mat::random(n, n, &mut rng);
            let c2 = cond_2(&a);
            let c1 = cond_1_estimate(&a);
            assert!(c2 <= c1 * n as f64 * (1.0 + 1e-9), "n={n} c2={c2} c1={c1}");
            assert!(c2 >= c1 / n as f64 * (1.0 - 1e-9), "n={n} c2={c2} c1={c1}");
        }
    }
}
