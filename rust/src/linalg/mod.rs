//! Dense f64 linear algebra substrate: the recovery-matrix machinery of
//! the coding layer (inversion, condition numbers, Kronecker products).
//! No external crates are available; LU and Jacobi-SVD are implemented
//! from the standard algorithms.

pub mod cond;
pub mod gemm;
pub mod kron;
pub mod lu;
pub mod mat;
pub mod svd;

pub use cond::{cond_1_estimate, cond_2};
pub use kron::kron;
pub use lu::Lu;
pub use mat::Mat;
pub use svd::singular_values;
