//! Dense f64 linear algebra substrate: the recovery-matrix machinery of
//! the coding layer (inversion, condition numbers, Kronecker products).
//! No external crates are available; LU and Jacobi-SVD are implemented
//! from the standard algorithms. The hot-path contraction primitives
//! (packed GEMM microkernel, axpy) live in a runtime-dispatched SIMD
//! backend family: see [`kernel`].

pub mod cond;
pub mod gemm;
pub mod kernel;
pub mod kron;
pub mod lu;
pub mod mat;
#[cfg(target_arch = "x86_64")]
mod simd_avx2;
#[cfg(target_arch = "aarch64")]
mod simd_neon;
pub mod svd;

pub use cond::{cond_1_estimate, cond_2};
pub use kron::kron;
pub use lu::Lu;
pub use mat::Mat;
pub use svd::singular_values;
